"""Serving example: batched online inference (serve_p99 style) + bulk
retrieval scoring with a MIND multi-interest model.

The cache runs read-only (writeback=False): misses fault rows in from the
slow tier, so the engine warms itself from live traffic — watch the p99 drop.

Run:  PYTHONPATH=src python examples/serve_recsys.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import synth
from repro.models.recsys_models import MINDConfig, MINDModel
from repro.serve.engine import ServeEngine

cfg = MINDConfig(n_items=200_000, n_users=20_000, embed_dim=32, seq_len=50,
                 batch_size=256, cache_ratio=0.05)
model = MINDModel(cfg)
state = model.init(jax.random.PRNGKey(0))

pad = {
    "hist_items": np.zeros((cfg.seq_len,), np.int32),
    "hist_len": np.zeros((), np.int32),
    "user": np.zeros((), np.int32),
    "target_item": np.zeros((), np.int32),
    "label": np.zeros((), np.float32),
}
engine = ServeEngine(model.serve_step, state, batch_size=256, pad_example=pad)

for i in range(8):
    b = synth.recsys_batch(cfg.n_items, cfg.n_users, cfg.seq_len, 200, seed=1, step=i)
    scores = engine.score(b)
print("online scoring:", engine.stats.summary())
hit = float(model.collection.metrics(engine.state["emb"])["hit_rate"])
print(f"cache hit rate after traffic: {hit:.1%}")

# ---- retrieval: one user against 100k candidates (batched dot, no loop) ---
b = synth.recsys_batch(cfg.n_items, cfg.n_users, cfg.seq_len, 1, seed=2, step=0)
ret = {
    "hist_items": jnp.asarray(b["hist_items"]),
    "hist_len": jnp.asarray(b["hist_len"]),
    "user": jnp.asarray(b["user"]),
    "candidates": jnp.arange(100_000, dtype=jnp.int32),
}
scores, _ = jax.jit(model.retrieval_score)(engine.state, ret)
top = np.argsort(np.asarray(scores))[::-1][:5]
print("retrieval top-5 candidates:", top.tolist())
