"""End-to-end driver: train the paper's DLRM on a synthetic Criteo-like
stream for a few hundred steps with the full production stack — planner-driven
embedding collection, async checkpointing, auto-resume, straggler detection.

With ``--device-budget-mb`` the ``PlacementPlanner`` promotes small/hot
tables to DEVICE residency and serves the rest through per-table caches
(mixed placement); without it every table shares one cache arena — the
paper's original layout.

Kill it mid-run and start it again: it resumes exactly (same loss curve).

With ``--pipeline-depth k`` the ``PipelinedTrainer`` runs groups of k steps
off one merged cache plan, dispatching the next group's plan while the
current group's dense compute runs (plan t+1 under compute t at k=1); the
lookahead window prefetches rows before they miss.  Loss-bit-identical to the
serial path — ``--verify-pipeline`` runs both and asserts it.  Note k > 1
needs the cache to hold a whole group's unique rows (raise --cache-ratio).

With ``--host-precision {fp16,int8,auto}`` the host-resident table is stored
through a mixed-precision ``HostStore``: the cached working set stays fp32,
the cold majority costs 2-4x fewer host bytes, and cache misses cross the
host link encoded (the bandwidth win).  fp32 (default) is bit-exact with the
pre-store layout.

Run:  PYTHONPATH=src python examples/train_dlrm.py [--steps 300]
      PYTHONPATH=src python examples/train_dlrm.py --steps 50 \
          --cache-ratio 0.05 --pipeline-depth 2 --verify-pipeline
      PYTHONPATH=src python examples/train_dlrm.py --steps 100 --host-precision int8
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import freq
from repro.data import synth
from repro.models.dlrm import DLRM, DLRMConfig
from repro.train.trainer import PipelinedTrainer, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_dlrm_ckpt")
    ap.add_argument("--cache-ratio", type=float, default=0.015)
    ap.add_argument("--device-budget-mb", type=float, default=None,
                    help="planner budget; omit for the paper's single-arena mode")
    ap.add_argument("--pipeline-depth", type=int, default=0,
                    help="0 = serial; k >= 1 = pipelined groups of k steps "
                         "per merged cache plan (lookahead prefetch)")
    ap.add_argument("--verify-pipeline", action="store_true",
                    help="run serial AND pipelined, assert bit-identical losses")
    ap.add_argument("--host-precision", default="fp32",
                    choices=["fp32", "fp16", "int8", "auto"],
                    help="host-tier embedding storage codec: fp32 keeps the "
                         "bit-exact pre-store behavior; fp16/int8 store the "
                         "host-resident table (and cross the host link) at "
                         "2x/4x fewer bytes; auto picks per slab from the "
                         "frequency scan's coverage")
    args = ap.parse_args()

    cfg = DLRMConfig(
        vocab_sizes=(200_000, 100_000, 50_000, 20_000, 10_000),
        embed_dim=32, batch_size=args.batch, cache_ratio=args.cache_ratio,
        lr=0.3, bottom_mlp=(128, 64, 32), top_mlp=(128, 64),
        device_budget_bytes=(
            int(args.device_budget_mb * 1e6) if args.device_budget_mb else None
        ),
        host_precision=args.host_precision,
    )
    model = DLRM(cfg)
    print("placement plan:", model.collection.plan.summary())
    spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)

    # static module: id frequency scan (paper §4.2)
    total_vocab = sum(cfg.vocab_sizes)
    counts = freq.collect_counts(synth.count_stream(spec, args.batch, 20, seed=0), total_vocab)

    def make_batch(step):
        return {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, args.batch, 0, step).items()}

    def build_trainer(m, pipeline_depth, ckpt_dir):
        tc = TrainerConfig(max_steps=args.steps, ckpt_dir=ckpt_dir, ckpt_every=50,
                           pipeline_depth=pipeline_depth)
        kw = dict(
            init_fn=lambda: m.init(jax.random.PRNGKey(0), counts=counts),
            make_batch=make_batch,
            flush_fn=m.flush,
            on_straggler=lambda s, dt: print(f"[straggler] step {s}: {dt*1e3:.0f} ms"),
        )
        # without checkpointing nothing else holds the old state: donate it so
        # pass-through leaves (the big tables) alias instead of copying
        don = dict(donate_argnums=0) if ckpt_dir is None else {}
        if pipeline_depth > 0:
            return PipelinedTrainer(
                tc,
                plan_fn=jax.jit(m.plan_step),
                compute_fn=jax.jit(m.compute_step, **don),
                apply_fn=jax.jit(m.apply_step, **don),
                **kw,
            )
        return Trainer(tc, step_fn=jax.jit(m.train_step, **don), **kw)

    if args.verify_pipeline:
        depth = max(args.pipeline_depth, 1)
        serial = build_trainer(DLRM(cfg), 0, None)  # no ckpt: fresh runs only
        serial.run()
        model = DLRM(cfg)  # the final summary reads this (trained) instance
        piped = build_trainer(model, depth, None)
        state = piped.run()
        s_loss = [h["loss"] for h in serial.history]
        p_loss = [h["loss"] for h in piped.history]
        if args.host_precision == "fp32":
            assert s_loss == p_loss, "pipelined losses diverged from serial!"
        else:
            # lossy host codecs: lookahead pinning AVOIDS quantize/dequantize
            # round trips the serial schedule pays (a pinned row is never
            # evicted+reloaded between its uses), so the two schedules read
            # rows that differ by codec noise — equality holds to tolerance,
            # not bitwise.
            import numpy as _np
            _np.testing.assert_allclose(p_loss, s_loss, rtol=1e-4, atol=1e-5)
        ms = [h["time_s"] for h in serial.history[2:]] or [h["time_s"] for h in serial.history]
        mp = [h["time_s"] for h in piped.history[2:]] or [h["time_s"] for h in piped.history]
        med = lambda xs: sorted(xs)[len(xs) // 2] * 1e3
        claim = ("LOSS-BIT-IDENTICAL to serial" if args.host_precision == "fp32"
                 else f"loss-equal to serial within codec noise "
                      f"({args.host_precision} host store, rtol=1e-4)")
        print(f"pipelined (depth={depth}) is {claim} over "
              f"{len(s_loss)} steps; median step {med(ms):.1f} -> {med(mp):.1f} ms")
        trainer = piped
    else:
        trainer = build_trainer(model, args.pipeline_depth, args.ckpt_dir)
        state = trainer.run()

    h = trainer.history
    dev_bytes = model.collection.device_bytes()
    if h:
        print(f"\nsteps {h[0]['step']}..{h[-1]['step']}")
        print(f"loss  {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f}")
        print(f"auc   {h[0].get('auc', 0):.4f} -> {h[-1].get('auc', 0):.4f}")
        print(f"cache hit rate: {h[-1].get('hit_rate', 0):.1%}")
        print(f"host precision {model.collection.host_precision}: "
              f"saved {dev_bytes['host_bytes_saved']/1e6:.1f} MB vs fp32; "
              f"host<->device traffic {h[-1].get('host_wire_bytes', 0)/1e6:.1f} MB total")
    print(f"device-resident: {dev_bytes['device_total']/1e6:.1f} MB "
          f"vs slow tier {dev_bytes['slow_tier_bytes']/1e6:.1f} MB "
          f"(budget: {dev_bytes['budget_bytes']})")


if __name__ == "__main__":
    main()
