"""End-to-end driver: train the paper's DLRM on a synthetic Criteo-like
stream for a few hundred steps with the full production stack — planner-driven
embedding collection, async checkpointing, auto-resume, straggler detection.

With ``--device-budget-mb`` the ``PlacementPlanner`` promotes small/hot
tables to DEVICE residency and serves the rest through per-table caches
(mixed placement); without it every table shares one cache arena — the
paper's original layout.

Kill it mid-run and start it again: it resumes exactly (same loss curve).

Run:  PYTHONPATH=src python examples/train_dlrm.py [--steps 300]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import freq
from repro.data import synth
from repro.models.dlrm import DLRM, DLRMConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_dlrm_ckpt")
    ap.add_argument("--cache-ratio", type=float, default=0.015)
    ap.add_argument("--device-budget-mb", type=float, default=None,
                    help="planner budget; omit for the paper's single-arena mode")
    args = ap.parse_args()

    cfg = DLRMConfig(
        vocab_sizes=(200_000, 100_000, 50_000, 20_000, 10_000),
        embed_dim=32, batch_size=args.batch, cache_ratio=args.cache_ratio,
        lr=0.3, bottom_mlp=(128, 64, 32), top_mlp=(128, 64),
        device_budget_bytes=(
            int(args.device_budget_mb * 1e6) if args.device_budget_mb else None
        ),
    )
    model = DLRM(cfg)
    print("placement plan:", model.collection.plan.summary())
    spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)

    # static module: id frequency scan (paper §4.2)
    total_vocab = sum(cfg.vocab_sizes)
    counts = freq.collect_counts(synth.count_stream(spec, args.batch, 20, seed=0), total_vocab)

    def make_batch(step):
        return {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, args.batch, 0, step).items()}

    trainer = Trainer(
        TrainerConfig(max_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50),
        init_fn=lambda: model.init(jax.random.PRNGKey(0), counts=counts),
        step_fn=jax.jit(model.train_step),
        make_batch=make_batch,
        flush_fn=model.flush,
        on_straggler=lambda s, dt: print(f"[straggler] step {s}: {dt*1e3:.0f} ms"),
    )
    state = trainer.run()

    h = trainer.history
    if h:
        print(f"\nsteps {h[0]['step']}..{h[-1]['step']}")
        print(f"loss  {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f}")
        print(f"auc   {h[0].get('auc', 0):.4f} -> {h[-1].get('auc', 0):.4f}")
        print(f"cache hit rate: {h[-1].get('hit_rate', 0):.1%}")
    dev_bytes = model.collection.device_bytes()
    print(f"device-resident: {dev_bytes['device_total']/1e6:.1f} MB "
          f"vs slow tier {dev_bytes['slow_tier_bytes']/1e6:.1f} MB "
          f"(budget: {dev_bytes['budget_bytes']})")


if __name__ == "__main__":
    main()
