"""Hybrid-parallel scaling demo (paper §4.4): the sharded EmbeddingCollection
over an emulated 8-device (data=2, model=4) mesh — dense/MLP params train
data-parallel, each of the 4 model shards owns its own frequency-aware cache
arena and HostStore slice, ids bucketize to their owner shard and rows come
back through the combined-address gather.  Exactness is preserved: the loss
trajectory matches the single-device collection.

Run:  PYTHONPATH=src python examples/multi_device_scaling.py
(sets XLA_FLAGS itself — run in a fresh interpreter)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

import repro.dist.partitioning as dist  # noqa: E402
from repro.data import synth  # noqa: E402
from repro.launch.mesh import make_hybrid_mesh  # noqa: E402
from repro.models.dlrm import DLRM, DLRMConfig  # noqa: E402

MODEL_SHARDS = 4
cfg = DLRMConfig(vocab_sizes=(100_000, 50_000), embed_dim=32, batch_size=512,
                 cache_ratio=0.05, lr=0.3, bottom_mlp=(64, 32), top_mlp=(64,),
                 model_shards=MODEL_SHARDS)
model = DLRM(cfg)

# frequency counts drive BOTH the cache layout and the RecShard-style
# device assignment (balance expected hot-row traffic per shard)
spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)
from repro.core import freq as freq_lib  # noqa: E402

counts = freq_lib.collect_counts(
    (synth.sparse_batch(spec, 512, 0, s)["sparse"]
     + freq_lib.concat_table_offsets(cfg.vocab_sizes)[None, :]
     for s in range(20)),
    vocab=sum(cfg.vocab_sizes),
)
state = model.init(jax.random.PRNGKey(0), counts=counts)

mesh = make_hybrid_mesh(MODEL_SHARDS)  # (data=2, model=4) on 8 devices
print("mesh:", mesh)
for sname, a in model.collection.assignments.items():
    print(f"slab {sname}: rows/shard {a.shard_rows.tolist()}, "
          f"traffic imbalance {a.imbalance():.3f}x")

emb_specs = model.collection.shard_specs()
sh = lambda t: jax.tree_util.tree_map(lambda p: NamedSharding(mesh, p), t,
                                      is_leaf=lambda x: isinstance(x, P))
state_specs = {
    "params": jax.tree_util.tree_map(lambda _: P(), state["params"]),
    "opt": jax.tree_util.tree_map(lambda _: P(), state["opt"]),
    "emb": emb_specs,
    "step": P(),
}
batch_specs = {"dense": P("data", None), "sparse": P("data", None), "label": P("data")}

state = jax.device_put(state, sh(state_specs))

with dist.axis_rules(mesh, dist.hybrid_rules()):
    step = jax.jit(model.train_step, in_shardings=(sh(state_specs), sh(batch_specs)))
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, 512, 0, i).items()}
        state, metrics = step(state, batch)
        print(f"step {i}: loss={float(metrics['loss']):.4f} "
              f"hit_rate={float(metrics['hit_rate']):.2%} "
              f"exchange={float(metrics['exchange_bytes'])/1e6:.2f} MB cum "
              f"imbalance={float(metrics['shard_imbalance']):.2f}x")

from repro.core.collection import SHARED_ARENA  # noqa: E402

w = state["emb"].slabs[SHARED_ARENA].cache.cached_rows["weight"]
print("cached weight sharding:", w.sharding.spec,
      "-> one cache arena per 'model' device (hybrid parallel)")
db = model.collection.device_bytes()
print(f"per-shard device bytes: {db['device_per_shard']/1e6:.2f} MB "
      f"(total {db['device_total']/1e6:.2f} MB over {MODEL_SHARDS} shards)")
