"""Hybrid-parallel scaling demo (paper §4.4): column-wise TP embedding +
data-parallel dense on an emulated 8-device mesh, exactness preserved.

Run:  PYTHONPATH=src python examples/multi_device_scaling.py
(sets XLA_FLAGS itself — run in a fresh interpreter)
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8 " + os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

import repro.dist.partitioning as dist  # noqa: E402
from repro.data import synth  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402
from repro.models.dlrm import DLRM, DLRMConfig  # noqa: E402

cfg = DLRMConfig(vocab_sizes=(100_000, 50_000), embed_dim=32, batch_size=512,
                 cache_ratio=0.05, lr=0.3, bottom_mlp=(64, 32), top_mlp=(64,))
model = DLRM(cfg)
state = model.init(jax.random.PRNGKey(0))

mesh = make_mesh((2, 4), ("data", "model"))
print("mesh:", mesh)

emb_specs = model.collection.shard_specs(mode="column")
sh = lambda t: jax.tree_util.tree_map(lambda p: NamedSharding(mesh, p), t,
                                      is_leaf=lambda x: isinstance(x, P))
state_specs = {
    "params": jax.tree_util.tree_map(lambda _: P(), state["params"]),
    "opt": jax.tree_util.tree_map(lambda _: P(), state["opt"]),
    "emb": emb_specs,
    "step": P(),
}
batch_specs = {"dense": P("data", None), "sparse": P("data", None), "label": P("data")}

state = jax.device_put(state, sh(state_specs))
spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)

with dist.axis_rules(mesh, {"batch": ("data",)}):
    step = jax.jit(model.train_step, in_shardings=(sh(state_specs), sh(batch_specs)))
    for i in range(5):
        batch = {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, 512, 0, i).items()}
        state, metrics = step(state, batch)
        print(f"step {i}: loss={float(metrics['loss']):.4f} "
              f"hit_rate={float(metrics['hit_rate']):.2%}")

from repro.core.collection import SHARED_ARENA  # noqa: E402

w = state["emb"].slabs[SHARED_ARENA].cache.cached_rows["weight"]
print("cached weight sharding:", w.sharding.spec, "-> dim split over 'model' (paper column-TP)")
