"""Quickstart: the frequency-aware software cache in 60 lines.

Builds a 100k-row embedding table whose slow tier would live in host DRAM on
a real TPU, serves it through a 2%-capacity device cache, and shows the three
paper claims in miniature: exact lookups, high hit rate on skewed traffic,
bounded per-step transfer.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cached_embedding as ce
from repro.core import freq

VOCAB, DIM, BATCH = 100_000, 64, 4096

# --- static module: scan the dataset once for id frequencies (paper §4.2) --
rng = np.random.default_rng(0)
train_ids = (rng.zipf(1.3, size=(200, BATCH)) % VOCAB).astype(np.int64)
counts = freq.collect_counts(iter(train_ids), VOCAB)
print(f"skew: top 1% of ids = {freq.coverage(counts, [0.01])[0.01]:.0%} of accesses")

cfg = ce.CachedEmbeddingConfig(
    vocab_sizes=(VOCAB,), dim=DIM, ids_per_step=BATCH,
    cache_ratio=0.02,            # 2% of rows live on-device
    buffer_rows=1024,            # bounded transmitter buffer (paper §4.3)
)
state = ce.init_state(jax.random.PRNGKey(0), cfg, counts=counts)
print(f"cache: {cfg.capacity} / {cfg.vocab} rows on the fast tier")

# --- training-style loop through the cache ---------------------------------
@jax.jit
def lookup(state, ids):
    state, slots = ce.prepare_ids(cfg, state, ids)   # Algorithm 1 (on device)
    return state, ce.gather_slots(state, slots)      # differentiable gather

for step in range(30):
    ids = jnp.asarray(train_ids[step % len(train_ids)], jnp.int32)
    state, emb = lookup(state, ids)

print(f"hit rate after 30 steps: {float(state.cache.hit_rate()):.1%}")
print(f"rows moved host->device: {int(state.cache.misses)}")
print(f"rows evicted device->host: {int(state.cache.evictions)}")

# --- exactness: flush and compare against the dense table ------------------
flushed = ce.flush_state(cfg, state)
ids = jnp.asarray(train_ids[0][:16], jnp.int32)
_, emb = lookup(state, jnp.asarray(train_ids[0], jnp.int32))
ref = ce.dense_reference_lookup(flushed, ids[:, None])[:, 0]
print("cache == dense table:", bool(jnp.allclose(emb[:16], ref)))
