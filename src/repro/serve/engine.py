"""Batched serving engine: request micro-batching over a jitted score fn.

The cache tier runs with ``writeback=False`` (read-only rows); misses still
fault rows in, so a cold engine warms itself from traffic.  With a
mixed-precision host store the faulted rows are dequantized on load — the
cached working set serves at full precision while the host-resident long
tail costs fp16/int8 bytes (and crosses the link encoded).  Requests are
padded to the compiled batch size (recsys serve shapes are fixed) and
latency/hit-rate stats are tracked per batch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ServeEngine", "ServeStats"]


@dataclasses.dataclass
class ServeStats:
    """Latency telemetry with O(1) memory under sustained traffic.

    ``latencies`` is a fixed-size reservoir (Vitter's Algorithm R with a
    seeded rng, so summaries are reproducible): every batch is counted in
    ``batches``/``total_latency_s``, while the reservoir keeps a uniform
    sample of per-batch latencies for the percentile estimates.
    """

    requests: int = 0
    batches: int = 0
    total_latency_s: float = 0.0
    reservoir_size: int = 2048
    latencies: List[float] = dataclasses.field(default_factory=list)
    _rng: np.random.Generator = dataclasses.field(
        default_factory=lambda: np.random.default_rng(0), repr=False
    )

    def observe(self, dt: float) -> None:
        self.batches += 1
        self.total_latency_s += dt
        if len(self.latencies) < self.reservoir_size:
            self.latencies.append(dt)
        else:  # replace with probability size/seen — uniform over all batches
            j = int(self._rng.integers(0, self.batches))
            if j < self.reservoir_size:
                self.latencies[j] = dt

    def p(self, q: float) -> float:
        return float(np.percentile(self.latencies, q)) if self.latencies else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "requests": self.requests,
            "batches": self.batches,
            "mean_ms": 1e3 * self.total_latency_s / max(self.batches, 1),
            "p50_ms": 1e3 * self.p(50),
            "p99_ms": 1e3 * self.p(99),
        }


class ServeEngine:
    def __init__(
        self,
        score_fn: Callable[[Any, Dict], Any],  # (state, batch) -> (scores, emb_state|None)
        state: Any,
        batch_size: int,
        pad_example: Dict[str, np.ndarray],  # one padding row per field
        state_stats_fn: Optional[Callable[[Any], Dict[str, Any]]] = None,
        # ^ optional embedding-tier telemetry read from the live state (e.g.
        #   ``lambda s: collection.metrics(s["emb"])`` — hit rate, host wire
        #   bytes of the mixed-precision store); merged into ``summary()``.
        refresh_fn: Optional[Callable[[Any], Any]] = None,
        refresh_every: Optional[int] = None,
        # ^ adaptive frequency refresh hook: every ``refresh_every`` scored
        #   batches the engine runs ``refresh_fn`` (usually
        #   ``lambda s: model.refresh(s, writeback=False)`` — the read-only
        #   cache's rows are clean, so the re-rank skips write-backs) over its
        #   live state, re-ranking the cache toward the traffic it actually
        #   serves.  Scores are unchanged (pure reindexing); only hit rates
        #   move.  Runs between batches, never during a score call.
    ):
        self.score_fn = jax.jit(score_fn)
        self.state = state
        self.batch_size = batch_size
        self.pad_example = pad_example
        self.state_stats_fn = state_stats_fn
        self.refresh_fn = refresh_fn
        self.refresh_every = refresh_every
        self._batches_since_refresh = 0
        self.stats = ServeStats()
        # wrap-free exact hit/miss totals (see collection.ExactCounterTotals)
        from repro.core.collection import ExactCounterTotals

        self._exact_hits = ExactCounterTotals()
        self._exact_misses = ExactCounterTotals()

    def summary(self) -> Dict[str, float]:
        """Latency stats plus (when wired) embedding-tier telemetry.

        Byte counters with exact per-slab representations (see
        ``collection.exact_metric_bytes``) are recomputed host-side as exact
        Python ints — the in-jit float32 scalars drift past 2^24 bytes."""
        from repro.core.collection import exact_metric_bytes

        out = dict(self.stats.summary())
        if self.state_stats_fn is not None:
            stats = self.state_stats_fn(self.state)
            for k, v in stats.items():
                if isinstance(v, dict):  # per-slab counter dicts stay internal
                    continue
                out[k] = float(jax.device_get(v))
            wire = exact_metric_bytes(stats, "host_moved_rows", "host_row_bytes")
            if wire is not None:
                out["host_wire_bytes"] = wire
            xchg = exact_metric_bytes(
                stats, "exchange_routed_lanes", "exchange_lane_bytes"
            )
            if xchg is not None:
                out["exchange_bytes"] = xchg
            # exact hit/miss totals from the per-slab int32 counters — the
            # in-jit accumulators wrap past 2^31 under sustained traffic, so
            # the exact Python ints also rebuild an exact hit_rate.
            if "slab_hits" in stats and "slab_misses" in stats:
                h = self._exact_hits.update(stats["slab_hits"])
                m = self._exact_misses.update(stats["slab_misses"])
                out["cache_hits"] = h
                out["cache_misses"] = m
                out["hit_rate"] = h / max(h + m, 1)
        return out

    def _pad(self, batch: Dict[str, np.ndarray], n: int) -> Dict[str, jnp.ndarray]:
        out = {}
        for k, v in batch.items():
            pad_rows = self.batch_size - n
            if pad_rows > 0:
                pad = np.broadcast_to(self.pad_example[k], (pad_rows,) + v.shape[1:])
                v = np.concatenate([v, pad], axis=0)
            out[k] = jnp.asarray(v)
        return out

    def score(self, batch: Dict[str, np.ndarray]) -> np.ndarray:
        """Score up to ``batch_size`` requests; returns scores for real rows."""
        n = len(next(iter(batch.values())))
        assert n <= self.batch_size, "split upstream"
        t0 = time.perf_counter()
        scores, emb_state = self.score_fn(self.state, self._pad(batch, n))
        scores = np.asarray(jax.device_get(scores))[:n]
        if emb_state is not None:  # cache stays warm across requests
            self.state = dict(self.state, emb=emb_state)
        dt = time.perf_counter() - t0
        self.stats.requests += n
        self.stats.observe(dt)
        if self.refresh_fn is not None and self.refresh_every:
            self._batches_since_refresh += 1
            if self._batches_since_refresh >= self.refresh_every:
                self.state = self.refresh_fn(self.state)
                self._batches_since_refresh = 0
        return scores
