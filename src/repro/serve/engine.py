"""Batched serving engine: request micro-batching over a jitted score fn.

The cache tier runs with ``writeback=False`` (read-only rows); misses still
fault rows in, so a cold engine warms itself from traffic.  With a
mixed-precision host store the faulted rows are dequantized on load — the
cached working set serves at full precision while the host-resident long
tail costs fp16/int8 bytes (and crosses the link encoded).  Requests are
padded to the compiled batch size (recsys serve shapes are fixed) and
latency/hit-rate stats are tracked per batch through the observability
layer: deterministic fixed-bucket latency histograms (``repro.obs.hist``)
and the same exact-int counter hub the trainer uses (``repro.obs.hub``).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import NULL_TRACER, FixedHistogram, MetricsHub, Tracer

__all__ = ["ServeEngine", "ServeStats"]


@dataclasses.dataclass
class ServeStats:
    """Latency telemetry with O(1) memory and DETERMINISTIC percentiles.

    Every batch lands in a fixed log-bucket histogram
    (:class:`repro.obs.hist.FixedHistogram`), which replaced the seeded
    sampling reservoir: the reservoir's percentiles were a random function
    of arrival ORDER (two identical latency populations could summarize
    differently), while the histogram is order-independent and reports a
    guaranteed upper BOUND per quantile with <=~26% relative bucket error —
    the right direction to be wrong in for latency SLOs.  ``summary()``
    keeps the original ``p50_ms``/``p99_ms`` keys and adds ``p95_ms``/
    ``p999_ms``; the tail above the top bucket reports the exact max.
    """

    requests: int = 0
    batches: int = 0
    total_latency_s: float = 0.0
    hist: FixedHistogram = dataclasses.field(default_factory=FixedHistogram.latency)

    def observe(self, dt: float) -> None:
        self.batches += 1
        self.total_latency_s += dt
        self.hist.observe(dt)

    def p(self, q: float) -> float:
        """Latency quantile bound in seconds (``q`` in percent, e.g. 99)."""
        return self.hist.quantile(q / 100.0)

    def summary(self) -> Dict[str, float]:
        return {
            "requests": self.requests,
            "batches": self.batches,
            "mean_ms": 1e3 * self.total_latency_s / max(self.batches, 1),
            "p50_ms": 1e3 * self.p(50),
            "p95_ms": 1e3 * self.p(95),
            "p99_ms": 1e3 * self.p(99),
            "p999_ms": 1e3 * self.p(99.9),
        }


class ServeEngine:
    def __init__(
        self,
        score_fn: Callable[[Any, Dict], Any],  # (state, batch) -> (scores, emb_state|None)
        state: Any,
        batch_size: int,
        pad_example: Dict[str, np.ndarray],  # one padding row per field
        state_stats_fn: Optional[Callable[[Any], Dict[str, Any]]] = None,
        # ^ optional embedding-tier telemetry read from the live state (e.g.
        #   ``lambda s: collection.metrics(s["emb"])`` — hit rate, host wire
        #   bytes of the mixed-precision store); merged into ``summary()``.
        refresh_fn: Optional[Callable[[Any], Any]] = None,
        refresh_every: Optional[int] = None,
        # ^ adaptive frequency refresh hook: every ``refresh_every`` scored
        #   batches the engine runs ``refresh_fn`` (usually
        #   ``lambda s: model.refresh(s, writeback=False)`` — the read-only
        #   cache's rows are clean, so the re-rank skips write-backs) over its
        #   live state, re-ranking the cache toward the traffic it actually
        #   serves.  Scores are unchanged (pure reindexing); only hit rates
        #   move.  Runs between batches, never during a score call.
        obs_dir: Optional[str] = None,
        obs_run: str = "serve",
        # ^ None keeps the hub sink-less (counters still exact, spans off).
        #   With a directory, per-batch records + the latency histogram +
        #   span aggregates stream to <obs_dir>/<obs_run>.jsonl and a Chrome
        #   trace is exported by ``close()``.
        obs_annotate: bool = False,
    ):
        self.score_fn = jax.jit(score_fn)
        self.state = state
        self.batch_size = batch_size
        self.pad_example = pad_example
        self.state_stats_fn = state_stats_fn
        self.refresh_fn = refresh_fn
        self.refresh_every = refresh_every
        self._batches_since_refresh = 0
        self.stats = ServeStats()
        self.obs_dir = obs_dir
        self.obs_run = obs_run
        # same hub the trainer uses: the ONE wrap-safe reconstruction point
        # for the cumulative in-jit int32 counters (hits/misses, host rows
        # and encoded wire bytes, exchange lanes) — exact Python ints even
        # under sustained traffic that wraps the device accumulators.
        self.hub = MetricsHub(run_dir=obs_dir, run=obs_run)
        self.tracer = (
            Tracer(annotate=obs_annotate)
            if (obs_dir or obs_annotate)
            else NULL_TRACER
        )
        self.trace_path: Optional[str] = None

    def summary(self) -> Dict[str, float]:
        """Latency stats plus (when wired) embedding-tier telemetry.

        Every cumulative int32 counter family in the stats dict reconstructs
        to exact wrap-free Python ints through the hub (the one family table
        in ``repro.obs.hub``) — the in-jit float32 scalars drift past 2^24
        and the int32 counters wrap past 2^31.  ``hit_rate`` is re-derived
        from the exact totals when the per-slab hit families are present."""
        out = dict(self.stats.summary())
        if self.state_stats_fn is not None:
            stats = self.state_stats_fn(self.state)
            for k, v in stats.items():
                if isinstance(v, dict):  # per-slab counter dicts stay internal
                    continue
                out[k] = float(jax.device_get(v))
            exact = self.hub.observe_embedding_metrics(stats)
            out.update(exact)
            if "hit_rate_exact" in exact:
                out["hit_rate"] = exact["hit_rate_exact"]
        return out

    def close(self) -> None:
        """Flush observability artifacts: the latency histogram, the span
        aggregate, the counter summary, and the Chrome trace.  Safe to call
        twice; a sink-less engine only drops its (empty) tracer state."""
        self.hub.log_hist("serve_latency_s", self.stats.hist)
        self.hub.log_spans(self.tracer)
        if self.obs_dir:
            self.trace_path = self.tracer.export_chrome_trace(
                os.path.join(self.obs_dir, f"{self.obs_run}.trace.json")
            )
        self.hub.close()

    def _pad(self, batch: Dict[str, np.ndarray], n: int) -> Dict[str, jnp.ndarray]:
        out = {}
        for k, v in batch.items():
            pad_rows = self.batch_size - n
            if pad_rows > 0:
                pad = np.broadcast_to(self.pad_example[k], (pad_rows,) + v.shape[1:])
                v = np.concatenate([v, pad], axis=0)
            out[k] = jnp.asarray(v)
        return out

    def score(self, batch: Dict[str, np.ndarray]) -> np.ndarray:
        """Score up to ``batch_size`` requests; returns scores for real rows.

        The per-batch device->host fetch of the scores is serving's one
        deliberate sync point — it IS the response — so the whole call is a
        single ``score`` span and its latency lands in the deterministic
        histogram."""
        n = len(next(iter(batch.values())))
        assert n <= self.batch_size, "split upstream"
        t0 = time.perf_counter()
        with self.tracer.span("score"):
            scores, emb_state = self.score_fn(self.state, self._pad(batch, n))
            scores = np.asarray(jax.device_get(scores))[:n]
        if emb_state is not None:  # cache stays warm across requests
            self.state = dict(self.state, emb=emb_state)
        dt = time.perf_counter() - t0
        self.stats.requests += n
        self.stats.observe(dt)
        self.hub.log(
            "serve_batch",
            {"batch": self.stats.batches, "rows": n,
             "requests": self.stats.requests},
            wall={"latency_s": dt},
        )
        if self.refresh_fn is not None and self.refresh_every:
            self._batches_since_refresh += 1
            if self._batches_since_refresh >= self.refresh_every:
                with self.tracer.span("refresh"):
                    self.state = self.refresh_fn(self.state)
                self._batches_since_refresh = 0
        return scores
