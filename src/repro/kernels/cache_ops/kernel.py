"""Pallas TPU kernels for the cache hot path (ROADMAP item 3).

Three kernels, each the accelerator lowering of a ``ref.py`` function and
verified bit-identical against it in interpret mode:

* ``victim_threshold_pallas`` — the tiled streaming reducer behind bounded
  top-K victim selection.  The eviction-key array streams HBM -> VMEM one
  tile at a time; SMEM carries the running radix threshold and per-round
  count across grid steps (grid iteration is sequential on TPU).  32 bit
  rounds + one greater-than round produce ``(t, n_gt)`` — the kv-th largest
  key and the count strictly above it — after which the O(kv) select/sort
  epilogue runs in XLA (shared verbatim with the reference route).
* ``bucketize_pallas`` — the [S, lanes] per-shard routing image, one shard
  row per grid step (the id all-to-all payload of the sharded collection).
* ``gather_decode_pallas`` — the tiered-arena fused gather+decode: slot ids
  are scalar-prefetched so the BlockSpec index maps pick the head row OR
  tail payload row per lane, and the kernel decodes tail lanes in-register
  (fp16 upcast / int8 scale+zero-point) instead of decoding a full gathered
  block and selecting afterwards.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "bucketize_pallas",
    "gather_decode_pallas",
    "victim_threshold_pallas",
]


# ---------------------------------------------------------------------------
# bounded top-K: the threshold reducer
# ---------------------------------------------------------------------------


def _threshold_kernel(u_ref, t_ref, ngt_ref, cur_ref, cnt_ref, *, kv: int):
    b, j = pl.program_id(0), pl.program_id(1)
    tiles = pl.num_programs(1)

    @pl.when((b == 0) & (j == 0))
    def _init():
        cur_ref[0, 0] = jnp.uint32(0)
        cnt_ref[0, 0] = jnp.int32(0)

    @pl.when((b > 0) & (j == 0))
    def _commit():
        # close bit round b-1: keep its candidate iff >= kv keys reach it
        prev_bit = jnp.uint32(1) << (jnp.uint32(32) - b.astype(jnp.uint32))
        cand = cur_ref[0, 0] | prev_bit
        cur_ref[0, 0] = jnp.where(cnt_ref[0, 0] >= kv, cand, cur_ref[0, 0])
        cnt_ref[0, 0] = jnp.int32(0)

    tile = u_ref[...]

    @pl.when(b < 32)
    def _count_ge():
        cand = cur_ref[0, 0] | (
            jnp.uint32(1) << (jnp.uint32(31) - b.astype(jnp.uint32))
        )
        cnt_ref[0, 0] += jnp.sum((tile >= cand).astype(jnp.int32))

    @pl.when(b == 32)
    def _count_gt():  # final round: count keys strictly above the threshold
        cnt_ref[0, 0] += jnp.sum((tile > cur_ref[0, 0]).astype(jnp.int32))

    @pl.when((b == 32) & (j == tiles - 1))
    def _finalize():
        t_ref[0, 0] = cur_ref[0, 0]
        ngt_ref[0, 0] = cnt_ref[0, 0]


def victim_threshold_pallas(
    u: jnp.ndarray,  # uint32 [C] order-transformed eviction keys
    kv: int,
    tile_rows: int = 2048,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(t, n_gt): the kv-th largest of ``u`` and the count strictly above it.

    Padding note: ``u`` is padded with 0 (the minimum of the transformed
    domain).  Every bit-round candidate has at least one bit set (> 0) and
    the final round compares strictly, so pad lanes never count.
    """
    c = u.shape[0]
    tile_rows = min(tile_rows, c)
    tiles = -(-c // tile_rows)
    pad = tiles * tile_rows - c
    if pad:
        u = jnp.concatenate([u, jnp.zeros((pad,), jnp.uint32)])
    u2 = u.reshape(tiles, tile_rows)
    t, ngt = pl.pallas_call(
        functools.partial(_threshold_kernel, kv=int(kv)),
        grid=(33, tiles),
        in_specs=[pl.BlockSpec((1, tile_rows), lambda b, j: (j, 0))],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.uint32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.SMEM((1, 1), jnp.uint32),  # running threshold
            pltpu.SMEM((1, 1), jnp.int32),  # per-round count
        ],
        interpret=interpret,
    )(u2)
    return t[0, 0], ngt[0, 0]


# ---------------------------------------------------------------------------
# [S, lanes] bucketize
# ---------------------------------------------------------------------------


def _bucketize_kernel(owner_ref, local_ref, out_ref):
    s = pl.program_id(0)
    local = local_ref[...]
    mine = (owner_ref[...] == s) & (local >= 0)
    out_ref[...] = jnp.where(mine, local, -1)


def bucketize_pallas(
    owner: jnp.ndarray,  # int32 [U] owning shard (-1 pad/replicated)
    local: jnp.ndarray,  # int32 [U] shard-local row (-1 pad/replicated)
    num_shards: int,
    interpret: bool = True,
) -> jnp.ndarray:
    u = owner.shape[0]
    return pl.pallas_call(
        _bucketize_kernel,
        grid=(int(num_shards),),
        in_specs=[
            pl.BlockSpec((1, u), lambda s: (0, 0)),
            pl.BlockSpec((1, u), lambda s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, u), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((int(num_shards), u), jnp.int32),
        interpret=interpret,
    )(owner.reshape(1, u), local.reshape(1, u))


# ---------------------------------------------------------------------------
# tiered-arena fused gather + decode
# ---------------------------------------------------------------------------


def _gather_decode_kernel(
    slots_ref, head_ref, tail_ref, side_ref, out_ref, *, h: int, t: int, codec: str
):
    i = pl.program_id(0)
    slot = slots_ref[i]
    in_tail = slot >= h
    valid = (slot >= 0) & (slot < h + t)  # OOB slots give zero rows, like the
    # reference route's fill-gather (whose zero payload decodes to zero)
    head_row = head_ref[...].astype(out_ref.dtype)
    if codec == "int8":
        scale = side_ref[0, 0]
        zp = side_ref[0, 1]
        # f32 accumulate then cast — the exact codec decode order
        tail_row = (tail_ref[...].astype(jnp.float32) * scale + zp).astype(
            out_ref.dtype
        )
    else:  # fp16: plain upcast
        tail_row = tail_ref[...].astype(out_ref.dtype)
    row = jnp.where(in_tail, tail_row, head_row)
    out_ref[...] = jnp.where(valid, row, jnp.zeros_like(row))


def gather_decode_pallas(
    head: jnp.ndarray,  # [H, D] fp32 head rows
    tail: jnp.ndarray,  # [T, D] encoded tail payload
    sideband: Optional[jnp.ndarray],  # [T, 2] (scale, zero_point) or None
    slots: jnp.ndarray,  # int32 [K] arena slots (-1 padding)
    codec: str,
    out_dtype=jnp.float32,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused decode-on-read gather: one [K, D] pass, each lane streaming
    either its head row or its tail payload (+ sideband) through VMEM and
    decoding in-register.  Bit-identical to ``ref.arena_gather`` with the
    store codecs (fp16 upcast; int8 ``payload * scale + zero_point``)."""
    if codec not in ("fp16", "int8"):
        raise ValueError(f"gather_decode_pallas supports fp16/int8, got {codec!r}")
    h, d = head.shape
    t = tail.shape[0]
    k = slots.shape[0]
    side = sideband
    if side is None:  # fp16: dummy sideband keeps the spec list static
        side = jnp.zeros((max(t, 1), 2), jnp.float32)

    def head_index(i, slots_pf):
        s = slots_pf[i]
        return jnp.where((s >= 0) & (s < h), s, 0), 0

    def tail_index(i, slots_pf):
        s = slots_pf[i]
        return jnp.where((s >= h) & (s < h + t), s - h, 0), 0

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # slots
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, d), head_index),
            pl.BlockSpec((1, d), tail_index),
            pl.BlockSpec((1, 2), tail_index),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, slots_pf: (i, 0)),
    )
    fn = pl.pallas_call(
        functools.partial(_gather_decode_kernel, h=h, t=t, codec=codec),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, d), jnp.dtype(out_dtype)),
        interpret=interpret,
    )
    return fn(slots, head, tail, side)
