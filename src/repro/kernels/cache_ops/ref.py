"""XLA reference implementations of the cache hot-path ops.

Every function here is bit-identical to the historical ``jnp.unique`` /
full-capacity ``jnp.argsort`` route it replaces (property-tested in
``tests/test_cache_ops.py``), while staying O(K)-sorted instead of
O(capacity)-sorted:

* ``victim_topk`` — the K worst eviction keys via a 32-round bitwise
  threshold descent (count-based radix select) + a K-sized final sort.  The
  only sort is over ``kv`` lanes; the capacity-sized work is compare/sum
  passes, which is exactly what the Pallas tiled reducer streams on TPU.
* ``dedup`` — ``jnp.unique(size=k, fill_value=s)`` from ONE ``jnp.sort``
  (flag first occurrences, cumsum-compact), sharing the sorted buffer with
  the overflow count the caller previously paid a second sort for.
* ``compact_front`` / ``merge_candidates`` — the stable miss-compaction
  argsorts replaced by cumsum scatters and a lane select.
* ``arena_gather`` — the tiered-arena decode-on-read gather as one function
  of raw leaves (head + tail payload + sideband), so the transmitter and
  ``ArenaStore.gather_slots`` share a single fusable body.

These run as the CPU fast path; the Pallas kernels in ``kernel.py`` lower
the same math for accelerators and are verified bit-identical against this
module in interpret mode.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "PlanImage",
    "arena_gather",
    "bucketize",
    "compact_front",
    "dedup",
    "merge_candidates",
    "plan_image",
    "victim_topk",
]

_SIGN = jnp.uint32(0x80000000)


def ordered_u32(key: jnp.ndarray) -> jnp.ndarray:
    """Order-preserving int32 -> uint32 transform (flip the sign bit)."""
    return key.astype(jnp.uint32) ^ _SIGN


def victim_topk(key: jnp.ndarray, kv: int) -> jnp.ndarray:
    """Indices of the ``kv`` largest entries of ``key`` in stable descending
    order — bit-identical to ``jnp.argsort(key, descending=True)[:kv]``
    (ties broken by ascending index) without sorting all of ``key``.

    Three stages, mirroring the Pallas streaming reducer:
      1. threshold: 32-round bitwise descent finds ``t`` = the kv-th largest
         value (each round one masked count over the array);
      2. select: lanes with ``key > t`` plus the first ``kv - n_gt`` ties at
         ``t`` (exclusive cumsum rank), compacted index-ascending by binary
         search over the selection's inclusive cumsum (a gather — XLA CPU
         serializes scatters, and exactly ``kv`` lanes are selected, so
         every query hits);
      3. order: ONE ``kv``-sized stable descending argsort of the selected
         keys — index-ascending compaction makes it reproduce the full
         argsort's tie order exactly.
    """
    kv = int(kv)
    u = ordered_u32(key)

    def bit_step(i, t):
        cand = t | (jnp.uint32(1) << (jnp.uint32(31) - i.astype(jnp.uint32)))
        cnt = jnp.sum((u >= cand).astype(jnp.int32))
        return jnp.where(cnt >= kv, cand, t)

    t = jax.lax.fori_loop(0, 32, bit_step, jnp.uint32(0))
    n_gt = jnp.sum((u > t).astype(jnp.int32))
    return topk_select(u, t, n_gt, key, kv)


def topk_select(
    u: jnp.ndarray, t: jnp.ndarray, n_gt: jnp.ndarray, key: jnp.ndarray, kv: int
) -> jnp.ndarray:
    """Stages 2+3 of ``victim_topk`` given the threshold ``t`` and the
    strictly-greater count ``n_gt`` (also the epilogue of the Pallas
    threshold kernel)."""
    kv = int(kv)
    eq = (u == t).astype(jnp.int32)
    eq_rank = jnp.cumsum(eq) - eq  # exclusive rank among ties
    sel = (u > t) | ((eq == 1) & (eq_rank < kv - n_gt))
    csel = jnp.cumsum(sel.astype(jnp.int32))  # inclusive; csel[-1] == kv
    slots = jnp.searchsorted(
        csel, jnp.arange(1, kv + 1, dtype=jnp.int32)
    ).astype(jnp.int32)
    order = jnp.argsort(key[slots], descending=True)  # kv-sized, stable
    return slots[order]


def dedup(rows: jnp.ndarray, k: int, fill: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``jnp.unique(rows, size=k, fill_value=fill)`` plus the TRUE distinct
    count, from one sort.  ``fill`` must be the maximum sentinel the caller
    pads with (``int32 max`` in the cache; ``_PAD_RANK`` in the sharded
    router) — sentinel lanes are excluded from the count and collapse into
    the padding, exactly like the historical unique-then-count-again route.

    Returns ``(uniq, n_distinct)``: ``uniq`` ascending, ``fill``-padded.
    """
    k = int(k)
    srt = jnp.sort(rows)
    first = jnp.concatenate(
        [jnp.ones((1,), bool), jnp.diff(srt) != 0]
    ) & (srt != fill)
    n_distinct = jnp.sum(first.astype(jnp.int32))
    pos = jnp.cumsum(first.astype(jnp.int32)) - 1
    uniq = jnp.full((k,), fill, rows.dtype).at[
        jnp.where(first & (pos < k), pos, k)
    ].set(srt, mode="drop")
    return uniq, n_distinct


def compact_front(mask: jnp.ndarray, values: jnp.ndarray, out_len: int) -> jnp.ndarray:
    """``values[jnp.argsort(~mask, stable=True)][:out_len]`` on the masked
    lanes — i.e. masked values compacted to the front in original order —
    as a cumsum scatter (lanes past the masked count are -1; callers mask
    them with their own ``active`` select, like the argsort route did)."""
    out_len = int(out_len)
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1
    return jnp.full((out_len,), -1, values.dtype).at[
        jnp.where(mask & (pos < out_len), pos, out_len)
    ].set(values, mode="drop")


def merge_candidates(
    now: jnp.ndarray, n_now: jnp.ndarray, fut: jnp.ndarray, kv: int
) -> jnp.ndarray:
    """Lane ``j`` of the merged candidate list: current-batch compacted
    misses first (``j < n_now``), then lookahead compacted misses — the
    select-form of the historical priority-argsort over the concatenated
    candidate arrays (bit-identical under the caller's ``active`` mask,
    which never exposes lanes past the two compacted runs)."""
    kv = int(kv)
    j = jnp.arange(kv, dtype=jnp.int32)
    now_v = jnp.take(now, jnp.clip(j, 0, now.shape[0] - 1))
    fut_v = jnp.take(fut, jnp.clip(j - n_now, 0, fut.shape[0] - 1))
    return jnp.where(j < n_now, now_v, fut_v)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PlanImage:
    """Fused dedup -> residency-probe output (one sort, no lane argsorts)."""

    uniq: jnp.ndarray  # int32 [k] ascending distinct rows, -1 padded
    uniq_sorted: jnp.ndarray  # int32 [k] same, sentinel-padded (membership)
    uniq_valid: jnp.ndarray  # bool [k]
    uniq_slots: jnp.ndarray  # int32 [k] resident slot per unique (-1 miss)
    miss: jnp.ndarray  # bool [k] valid + unresident
    miss_rows: jnp.ndarray  # int32 [k] miss rows compacted to the front (-1)
    n_miss: jnp.ndarray  # int32 []
    n_distinct: jnp.ndarray  # int32 [] TRUE distinct count (overflow guard)


def plan_image(rows: jnp.ndarray, row_to_slot: jnp.ndarray, k: int) -> PlanImage:
    """Dedup ``rows`` (sentinel-padded with int32 max) into a ``k``-lane
    unique buffer, probe residency through ``row_to_slot``, and compact the
    missed uniques to the front — the fused form of the cache planner's
    ``jnp.unique`` + second sort + stable miss argsort."""
    int_max = jnp.iinfo(jnp.int32).max
    uniq_sorted, n_distinct = dedup(rows, k, int_max)
    uniq_valid = uniq_sorted != int_max
    uniq = jnp.where(uniq_valid, uniq_sorted, -1)
    uniq_slots = row_to_slot.at[jnp.where(uniq_valid, uniq, 0)].get(
        mode="fill", fill_value=-1
    )
    miss = (uniq_slots < 0) & uniq_valid
    return PlanImage(
        uniq=uniq,
        uniq_sorted=uniq_sorted,
        uniq_valid=uniq_valid,
        uniq_slots=uniq_slots,
        miss=miss,
        miss_rows=compact_front(miss, uniq, k),
        n_miss=jnp.sum(miss.astype(jnp.int32)),
        n_distinct=n_distinct,
    )


def bucketize(owner: jnp.ndarray, local: jnp.ndarray, num_shards: int) -> jnp.ndarray:
    """[lanes] routing -> [S, lanes] per-shard local-row image (-1 off-shard)
    — the id all-to-all payload of the sharded collection."""
    sids = jnp.arange(int(num_shards), dtype=jnp.int32)[:, None]
    return jnp.where(
        (owner[None, :] == sids) & (local[None, :] >= 0), local[None, :], -1
    ).astype(jnp.int32)


def arena_gather(
    head: jnp.ndarray,
    tail: jnp.ndarray,
    sideband: Optional[jnp.ndarray],
    slots: jnp.ndarray,
    decode,
    out_dtype,
) -> jnp.ndarray:
    """Decode-on-read gather over one tiered leaf: head lanes bit-exact,
    tail lanes ``decode(payload, sideband)``, negative/OOB lanes zero rows.
    ``decode(payload, side, out_dtype)`` is the store codec's row decode.
    Bit-identical to ``ArenaStore.gather_slots`` on the same leaf."""
    h = head.shape[0]
    in_tail = slots >= h
    safe_h = jnp.where((slots >= 0) & ~in_tail, slots, h)
    head_rows = jnp.take(head, safe_h, axis=0, mode="fill", fill_value=0)
    safe_t = jnp.where(in_tail, slots - h, tail.shape[0])
    payload = jnp.take(tail, safe_t, axis=0, mode="fill", fill_value=0)
    side = None
    if sideband is not None:
        side = jnp.take(sideband, safe_t, axis=0, mode="fill", fill_value=0)
    tail_rows = decode(payload, side, out_dtype)
    mask = in_tail.reshape(in_tail.shape + (1,) * (head_rows.ndim - in_tail.ndim))
    return jnp.where(mask, tail_rows, head_rows)
