"""Dispatching entry points for the cache hot-path kernels.

Two layers:

* ``*_impl`` functions — UN-jitted, called inline from ``cache.plan_prepare``
  / ``sharded.plan_prepare`` / ``ArenaStore.gather_slots`` so they trace into
  the caller's jaxpr (the analyzer's sort-bound pass sees through them).  On
  CPU they run the XLA references from ``ref.py``; on TPU/GPU (or under
  ``REPRO_FORCE_PALLAS_CACHE_OPS=1``, which the interpret-mode CI smokes set)
  the capacity-streaming pieces lower through the Pallas kernels.
* registered jit wrappers below — the analyzer/bench surface.  Each carries a
  ``@contract`` whose ``max_sort_size`` pins the bounded-top-K claim: at the
  smoke geometry nothing here may sort more than the unique buffer.

The dispatch decision is trace-time static (backend + env var), so a jitted
caller specializes per route exactly like the store-codec dispatch does.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.contracts import contract
from repro.kernels.cache_ops import kernel as _kernel
from repro.kernels.cache_ops import ref as _ref
from repro.kernels.cache_ops.ref import PlanImage

INTERPRET = True  # flip to False on real TPU

__all__ = [
    "INTERPRET",
    "PlanImage",
    "arena_gather",
    "arena_gather_impl",
    "bucketize_impl",
    "chunked_move",
    "compact_front_impl",
    "dedup_impl",
    "kernels_enabled",
    "merge_candidates_impl",
    "plan_image",
    "plan_image_impl",
    "shard_bucketize",
    "victim_topk",
    "victim_topk_impl",
]


def kernels_enabled() -> bool:
    """Pallas lowering: on for accelerator backends, forceable for CPU CI
    (interpret mode) via ``REPRO_FORCE_PALLAS_CACHE_OPS=1``."""
    if os.environ.get("REPRO_FORCE_PALLAS_CACHE_OPS") == "1":
        return True
    return jax.default_backend() in ("tpu", "gpu")


# ---------------------------------------------------------------------------
# impl layer (inlined into callers)
# ---------------------------------------------------------------------------


def victim_topk_impl(key: jnp.ndarray, kv: int) -> jnp.ndarray:
    """Bounded top-K victim selection — bit-identical to
    ``jnp.argsort(key, descending=True)[:kv].astype(int32)``."""
    if kernels_enabled():
        u = _ref.ordered_u32(key)
        t, n_gt = _kernel.victim_threshold_pallas(u, kv, interpret=INTERPRET)
        # select + order epilogue shared with the reference route
        return _ref.topk_select(u, t, n_gt, key, kv)
    return _ref.victim_topk(key, kv)


def dedup_impl(rows: jnp.ndarray, k: int, fill: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return _ref.dedup(rows, k, fill)


def compact_front_impl(mask, values, out_len: int) -> jnp.ndarray:
    return _ref.compact_front(mask, values, out_len)


def merge_candidates_impl(now, n_now, fut, kv: int) -> jnp.ndarray:
    return _ref.merge_candidates(now, n_now, fut, kv)


def plan_image_impl(rows, row_to_slot, k: int) -> PlanImage:
    return _ref.plan_image(rows, row_to_slot, k)


def bucketize_impl(owner, local, num_shards: int) -> jnp.ndarray:
    if kernels_enabled():
        return _kernel.bucketize_pallas(owner, local, num_shards, interpret=INTERPRET)
    return _ref.bucketize(owner, local, num_shards)


def arena_gather_impl(
    head: jnp.ndarray,
    tail: jnp.ndarray,
    sideband: Optional[jnp.ndarray],
    slots: jnp.ndarray,
    codec: str,
    decode,
    out_dtype,
) -> jnp.ndarray:
    """Fused tiered-arena gather+decode for one leaf.  ``decode`` is the
    store codec's row decode (used by the reference route and by codecs the
    kernel does not special-case)."""
    if kernels_enabled() and codec in ("fp16", "int8") and head.ndim == 2:
        return _kernel.gather_decode_pallas(
            head, tail, sideband, slots, codec, out_dtype, interpret=INTERPRET
        )
    return _ref.arena_gather(head, tail, sideband, slots, decode, out_dtype)


# ---------------------------------------------------------------------------
# registered jit entry points (analyzer / bench / test surface)
# ---------------------------------------------------------------------------


@contract(max_sort_size=64)
@functools.partial(jax.jit, static_argnames=("kv",))
def victim_topk(key: jnp.ndarray, kv: int) -> jnp.ndarray:
    """The K worst eviction keys, stable-descending — no capacity-sized sort
    (the declared ``max_sort_size`` bounds the kv-sized epilogue sort at the
    smoke geometry)."""
    return victim_topk_impl(key, kv)


@contract(max_sort_size=64)
@functools.partial(jax.jit, static_argnames=("k",))
def plan_image(rows: jnp.ndarray, row_to_slot: jnp.ndarray, k: int) -> PlanImage:
    """Fused dedup -> residency probe -> miss compaction (one k-ish sort)."""
    return plan_image_impl(rows, row_to_slot, k)


@contract(max_sort_size=64)
@functools.partial(jax.jit, static_argnames=("num_shards", "u"))
def shard_bucketize(
    rank: jnp.ndarray,
    rank_owner: jnp.ndarray,
    rank_local: jnp.ndarray,
    rep_k: int,
    num_shards: int,
    u: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fused sharded-router front end: dedup ranks, route, and build the
    [S, U] bucketize image in one pass.  Returns ``(uniq, pos, owner_u,
    local_u, rows_sh)`` — bit-identical to the historical ``_dedup`` /
    ``_route`` / ``_bucketize`` composition."""
    pad = jnp.iinfo(jnp.int32).max
    key = jnp.where(rank >= 0, rank, pad)
    uniq, _ = dedup_impl(key, u, pad)
    uniq = uniq.astype(jnp.int32)
    pos = jnp.minimum(jnp.searchsorted(uniq, key), u - 1).astype(jnp.int32)
    ok = uniq >= rep_k  # replicated head lanes never enter the exchange
    owner_u = jnp.where(
        ok,
        rank_owner.at[jnp.where(ok, uniq, 0)].get(mode="fill", fill_value=-1),
        -1,
    )
    local_u = jnp.where(
        ok,
        rank_local.at[jnp.where(ok, uniq, 0)].get(mode="fill", fill_value=-1),
        -1,
    )
    rows_sh = bucketize_impl(owner_u, local_u, num_shards)
    return uniq, pos, owner_u, local_u, rows_sh


@contract(max_sort_size=0)
@functools.partial(jax.jit, static_argnames=("codec", "out_dtype"))
def arena_gather(
    head: jnp.ndarray,
    tail: jnp.ndarray,
    sideband: Optional[jnp.ndarray],
    slots: jnp.ndarray,
    codec: str = "fp16",
    out_dtype: str = "float32",
) -> jnp.ndarray:
    """Fused tiered-arena gather+decode over one leaf (bench/test surface;
    the cache calls ``arena_gather_impl`` inline via ``ArenaStore``)."""
    from repro.store.codec import get_codec

    c = get_codec(codec)
    return arena_gather_impl(
        head, tail, sideband, slots, codec, c.decode, jnp.dtype(out_dtype)
    )


@contract(max_sort_size=64)
@functools.partial(
    jax.jit, static_argnames=("buffer_rows", "src_chunk_rows", "dst_chunk_rows")
)
def chunked_move(
    src_tree: Any,
    dst_tree: Any,
    src_idx: jnp.ndarray,
    dst_idx: jnp.ndarray,
    active: jnp.ndarray,
    buffer_rows: int,
    src_chunk_rows: int = 0,
    dst_chunk_rows: int = 0,
) -> Any:
    """Chunk-granularity transmitter round (registered surface for the
    analyzer: the per-round chunk dedup sorts ``buffer_rows`` lanes, never
    the table).  Thin wrapper over ``transmitter.move_rows``."""
    from repro.core import transmitter

    return transmitter.move_rows(
        src_tree,
        dst_tree,
        src_idx,
        dst_idx,
        active,
        buffer_rows=buffer_rows,
        src_chunk_rows=src_chunk_rows,
        dst_chunk_rows=dst_chunk_rows,
    )
