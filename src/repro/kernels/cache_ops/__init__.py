"""Cache hot-path kernels (ROADMAP item 3): bounded top-K victim selection,
fused dedup -> residency-probe -> slot-assign, fused arena gather+decode.

``ops`` holds the dispatching entry points (Pallas on accelerators,
bit-identical XLA references on CPU); ``ref`` the XLA implementations;
``kernel`` the Pallas bodies (interpret-mode capable for CPU CI).
"""
from repro.kernels.cache_ops.ops import (
    INTERPRET,
    arena_gather,
    chunked_move,
    kernels_enabled,
    plan_image,
    shard_bucketize,
    victim_topk,
)
from repro.kernels.cache_ops.ref import PlanImage

__all__ = [
    "INTERPRET",
    "PlanImage",
    "arena_gather",
    "chunked_move",
    "kernels_enabled",
    "plan_image",
    "shard_bucketize",
    "victim_topk",
]
