"""Jit'd wrapper for the FM-interaction kernel (pads batch to block size)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.contracts import contract
from repro.kernels.fm_interaction.kernel import fm_interaction_pallas

INTERPRET = True  # flip to False on real TPU


@contract(max_sort_size=0)
@jax.jit
def fm_interaction(v: jnp.ndarray) -> jnp.ndarray:
    b = v.shape[0]
    block = min(1024, b)
    pad = (-b) % block
    if pad:
        v = jnp.concatenate([v, jnp.zeros((pad,) + v.shape[1:], v.dtype)], axis=0)
    out = fm_interaction_pallas(v, block_b=block, interpret=INTERPRET)
    return out[:b]
