"""Pallas TPU FM interaction: fused sum-square-trick pooling.

One grid step per batch block: loads a [block_b, F, D] tile into VMEM,
computes 0.5 * ((sum_f v)^2 - sum_f v^2) . sum_d entirely in registers, and
writes a [block_b] partial.  F*D per sample is tiny (recsys: 39 x 10), so the
block_b dimension is what keeps the MXU/VPU busy; the fusion avoids
materializing the [B, D] sum and [B, F, D] square in HBM, which is what the
XLA path does (3 HBM round-trips -> 1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(v_ref, out_ref):
    v = v_ref[...].astype(jnp.float32)  # [bb, F, D]
    s = v.sum(axis=1)  # [bb, D]
    sq = (v * v).sum(axis=1)
    out_ref[...] = (0.5 * (s * s - sq).sum(axis=-1)).astype(out_ref.dtype)


def fm_interaction_pallas(
    v: jnp.ndarray,  # [B, F, D]
    block_b: int = 1024,
    interpret: bool = True,  # CPU container: validate in interpret mode
) -> jnp.ndarray:
    b, f, d = v.shape
    block_b = min(block_b, b)
    assert b % block_b == 0, "batch must divide block_b"
    grid = (b // block_b,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, f, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), v.dtype),
        interpret=interpret,
    )(v)
