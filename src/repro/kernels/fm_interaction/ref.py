"""Pure-jnp oracle for the FM pairwise-interaction kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fm_interaction_ref(v: jnp.ndarray) -> jnp.ndarray:
    """v [B, F, D] -> [B]: sum_{i<j} <v_i, v_j> via the sum-square trick."""
    s = v.sum(axis=-2)
    sq = (v * v).sum(axis=-2)
    return 0.5 * (s * s - sq).sum(axis=-1)


def fm_interaction_naive(v: jnp.ndarray) -> jnp.ndarray:
    """O(F^2) literal definition (cross-check for the trick itself)."""
    g = jnp.einsum("bfd,bgd->bfg", v, v)
    f = v.shape[-2]
    iu, ju = jnp.triu_indices(f, k=1)
    return g[:, iu, ju].sum(-1)
