"""Pure-jnp oracle for the flash-attention kernel (dense masked softmax)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(
    q: jnp.ndarray,  # [B, H, Sq, D]
    k: jnp.ndarray,  # [B, Hkv, Sk, D]
    v: jnp.ndarray,  # [B, Hkv, Sk, D]
    causal: bool = True,
    window: Optional[int] = None,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk.astype(jnp.float32))
    scores = scores / np.sqrt(d)
    qi = jnp.arange(sq)[:, None]
    ki = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((sq, k.shape[2]), bool)
    if causal:
        mask &= qi >= ki
    if window is not None:
        mask &= (qi - ki) < window
    scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)).astype(q.dtype)
