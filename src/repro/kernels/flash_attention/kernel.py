"""Pallas TPU flash attention (forward): blocked online-softmax, causal and
sliding-window masks, GQA via head-index mapping (no KV replication in HBM).

Grid: (B, Hq, nq, nk) with the KV loop innermost; running max / sum / output
accumulator live in VMEM scratch and the output tile is written on the last
KV step (the canonical FlashAttention schedule on TPU: q tile stays resident,
K/V tiles stream through VMEM).  Fully-masked KV blocks are skipped by a
block-level predicate (for causal this halves work; for sliding-window it
makes cost O(S * W)).

Used for the LM archs when ``config.use_pallas`` (real TPU); XLA's chunked
attention (nn.layers.gqa_attention) is the CPU/dry-run path.  The backward
pass recomputes through the jnp reference via ``jax.custom_vjp`` in ops.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, causal, window, block_q, block_k, nk):
    iq, ik = pl.program_id(2), pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window

    # block-level skip: any overlap at all?
    q_lo, q_hi = iq * block_q, (iq + 1) * block_q - 1
    k_lo, k_hi = ik * block_k, (ik + 1) * block_k - 1
    live = jnp.bool_(True)
    if causal:
        live &= q_hi >= k_lo
    if window is not None:
        live &= (q_lo - k_hi) < window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, d]
        s = q @ k.T  # [bq, bk]
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + p.sum(-1)
        m_scr[...] = m_new
        vv = v_ref[0, 0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + p @ vv

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,  # [B, Hq, Sq, D]
    k: jnp.ndarray,  # [B, Hkv, Sk, D]
    v: jnp.ndarray,  # [B, Hkv, Sk, D]
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = True,  # CPU container: validate in interpret mode
) -> jnp.ndarray:
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / np.sqrt(d)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, iq, ik: (b_, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h, iq, ik: (b_, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
