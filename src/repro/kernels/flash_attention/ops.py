"""Jit'd wrapper: [B,S,H,hd] layout glue + custom_vjp (bwd recomputes via the
jnp oracle — standard recompute-in-backward; a dedicated bwd kernel is the
real-TPU follow-up)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.analysis.contracts import contract
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref

INTERPRET = True  # flip to False on real TPU


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _attn(q, k, v, causal, window):
    return flash_attention_pallas(q, k, v, causal=causal, window=window, interpret=INTERPRET)


def _attn_fwd(q, k, v, causal, window):
    return _attn(q, k, v, causal, window), (q, k, v)


def _attn_bwd(causal, window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: attention_ref(q_, k_, v_, causal, window), q, k, v)
    return vjp(g)


_attn.defvjp(_attn_fwd, _attn_bwd)


@contract(max_sort_size=0)
def flash_attention(
    q: jnp.ndarray,  # [B, S, Hq, hd] (model layout)
    k: jnp.ndarray,  # [B, S, Hkv, hd]
    v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
) -> jnp.ndarray:
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = _attn(qt, kt, vt, causal, window)
    return o.transpose(0, 2, 1, 3)
