"""Pallas TPU embedding-bag: fused gather + segment-sum.

This is the paper's hot op (torch ``EmbeddingBag``, Fig. 1) as a TPU kernel.
Bags are presented DENSE: ``ids_dense`` [num_segments, max_bag] with -1
padding (the jit wrapper densifies CSR-style sorted segment ids).  The grid
is (dim_blocks, segments, max_bag); the id matrix is scalar-prefetched (SMEM)
so the table-row BlockSpec ``index_map`` picks the HBM row per step, and the
output block index (segment, dim_block) depends only on grid coordinates —
the canonical Pallas reduction pattern (same-block revisits are consecutive,
init at t == 0, accumulate afterwards).  Rows stream HBM -> VMEM one
[1, block_d] tile at a time; padding lanes multiply by 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, row_ref, out_ref):
    j, b, t = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    max_bag = pl.num_programs(2)
    valid = (ids_ref[b * max_bag + t] >= 0).astype(row_ref.dtype)
    row = row_ref[...] * valid

    @pl.when(t == 0)
    def _init():
        out_ref[...] = row

    @pl.when(t > 0)
    def _acc():
        out_ref[...] += row


def embedding_bag_pallas(
    table: jnp.ndarray,  # [V, D]
    ids_dense: jnp.ndarray,  # [num_segments, max_bag] int32, -1 padding
    block_d: int = 512,
    interpret: bool = True,  # CPU container: validate in interpret mode
) -> jnp.ndarray:
    v, d = table.shape
    s, max_bag = ids_dense.shape
    block_d = min(block_d, d)
    assert d % block_d == 0, "dim must divide block_d"
    nd = d // block_d

    def row_index(j, b, t, ids):
        return jnp.maximum(ids[b * max_bag + t], 0), j

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # flattened ids_dense
        grid=(nd, s, max_bag),
        in_specs=[pl.BlockSpec((1, block_d), row_index)],
        out_specs=pl.BlockSpec((1, block_d), lambda j, b, t, ids: (b, j)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, d), table.dtype),
        interpret=interpret,
    )
    return fn(ids_dense.reshape(-1), table)
