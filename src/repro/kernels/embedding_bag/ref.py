"""Pure-jnp oracle for the embedding-bag kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(
    table: jnp.ndarray,  # [V, D]
    flat_ids: jnp.ndarray,  # [N] int32, -1 = padding
    segment_ids: jnp.ndarray,  # [N] int32, SORTED non-decreasing
    num_segments: int,
    combiner: str = "sum",
) -> jnp.ndarray:
    safe = jnp.where(flat_ids >= 0, flat_ids, table.shape[0])  # negatives wrap in jax
    rows = jnp.take(table, safe, axis=0, mode="fill", fill_value=0)
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if combiner == "mean":
        cnt = jax.ops.segment_sum(
            (flat_ids >= 0).astype(table.dtype), segment_ids, num_segments=num_segments
        )
        out = out / jnp.maximum(cnt, 1)[:, None]
    return out
