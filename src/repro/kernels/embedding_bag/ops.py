"""Jit'd wrapper for the embedding-bag kernel.

Densifies (sorted) CSR-style segment ids into [num_segments, max_bag] and
invokes the Pallas kernel; handles the mean combiner and empty bags.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag_pallas

INTERPRET = True  # flip to False on real TPU


def densify(flat_ids: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int, max_bag: int):
    """[N] (sorted segments) -> [num_segments, max_bag] id matrix, -1 padded."""
    n = flat_ids.shape[0]
    starts = jnp.searchsorted(segment_ids, jnp.arange(num_segments), side="left")
    pos = jnp.arange(n) - starts[segment_ids]
    slot = jnp.where(pos < max_bag, segment_ids * max_bag + pos, num_segments * max_bag)
    dense = jnp.full((num_segments * max_bag,), -1, jnp.int32)
    dense = dense.at[slot].set(flat_ids, mode="drop")
    return dense.reshape(num_segments, max_bag)


@functools.partial(jax.jit, static_argnames=("num_segments", "combiner", "max_bag"))
def embedding_bag(
    table: jnp.ndarray,
    flat_ids: jnp.ndarray,
    segment_ids: jnp.ndarray,
    num_segments: int,
    combiner: str = "sum",
    max_bag: int = 0,
) -> jnp.ndarray:
    if max_bag <= 0:
        max_bag = int(flat_ids.shape[0])  # worst case (one hot bag)
    dense = densify(flat_ids, segment_ids, num_segments, max_bag)
    out = embedding_bag_pallas(table, dense, interpret=INTERPRET)
    if combiner == "mean":
        valid = jnp.sum((dense >= 0).astype(jnp.float32), axis=1)
        out = out / jnp.maximum(valid, 1)[:, None].astype(out.dtype)
    return out
