"""Jit'd wrapper for the embedding-bag kernel.

Densifies (sorted) CSR-style segment ids into [num_segments, max_bag] and
invokes the Pallas kernel; handles the mean combiner and empty bags.

Differentiable: the fused gather+pool has a custom VJP (the standard
embedding-bag backward — scatter-add of the pooled cotangent into the touched
rows), so the cached-embedding pooled path can run the kernel inside the loss
closure and still deliver gradients to the fast-tier weights.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import contract
from repro.kernels.embedding_bag.kernel import embedding_bag_pallas

INTERPRET = True  # flip to False on real TPU


def densify(flat_ids: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int, max_bag: int):
    """[N] (sorted segments) -> [num_segments, max_bag] id matrix, -1 padded."""
    n = flat_ids.shape[0]
    starts = jnp.searchsorted(segment_ids, jnp.arange(num_segments), side="left")
    pos = jnp.arange(n) - starts[segment_ids]
    slot = jnp.where(pos < max_bag, segment_ids * max_bag + pos, num_segments * max_bag)
    dense = jnp.full((num_segments * max_bag,), -1, jnp.int32)
    dense = dense.at[slot].set(flat_ids, mode="drop")
    return dense.reshape(num_segments, max_bag)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _embedding_bag(table, flat_ids, segment_ids, num_segments, combiner, max_bag):
    dense = densify(flat_ids, segment_ids, num_segments, max_bag)
    out = embedding_bag_pallas(table, dense, interpret=INTERPRET)
    if combiner == "mean":
        valid = jnp.sum((dense >= 0).astype(jnp.float32), axis=1)
        out = out / jnp.maximum(valid, 1)[:, None].astype(out.dtype)
    return out


def _fwd(table, flat_ids, segment_ids, num_segments, combiner, max_bag):
    out = _embedding_bag(table, flat_ids, segment_ids, num_segments, combiner, max_bag)
    proto = jnp.zeros((0,) + table.shape[1:], table.dtype)  # shape/dtype carrier
    return out, (table.shape[0], proto, flat_ids, segment_ids)


def _bwd(num_segments, combiner, max_bag, res, g):
    vocab, proto, flat_ids, segment_ids = res
    dtype = proto.dtype
    # the forward pools only the lanes densify kept — a bag overflowing
    # max_bag is truncated — so the backward must use the SAME lane mask
    # (and the same per-bag count for the mean combiner)
    starts = jnp.searchsorted(segment_ids, jnp.arange(num_segments), side="left")
    pos = jnp.arange(flat_ids.shape[0]) - starts[segment_ids]
    valid = (flat_ids >= 0) & (pos < max_bag)
    g_rows = jnp.take(g, segment_ids, axis=0)  # [N, D] pooled cotangent per lane
    if combiner == "mean":
        cnt = jax.ops.segment_sum(
            valid.astype(g.dtype), segment_ids, num_segments=num_segments
        )
        g_rows = g_rows / jnp.maximum(cnt, 1.0)[segment_ids][:, None]
    g_rows = g_rows * valid[:, None].astype(g.dtype)
    safe = jnp.where(valid, flat_ids, vocab)  # padding lanes dropped OOB
    d_table = (
        jnp.zeros((vocab, g.shape[-1]), dtype).at[safe].add(g_rows.astype(dtype), mode="drop")
    )
    f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)  # int inputs: zero cotangent
    return d_table, f0(flat_ids), f0(segment_ids)


_embedding_bag.defvjp(_fwd, _bwd)


@contract(max_sort_size=0)
@functools.partial(jax.jit, static_argnames=("num_segments", "combiner", "max_bag"))
def embedding_bag(
    table: jnp.ndarray,
    flat_ids: jnp.ndarray,
    segment_ids: jnp.ndarray,
    num_segments: int,
    combiner: str = "sum",
    max_bag: int = 0,
) -> jnp.ndarray:
    if max_bag <= 0:
        max_bag = int(flat_ids.shape[0])  # worst case (one hot bag)
    return _embedding_bag(table, flat_ids, segment_ids, num_segments, combiner, max_bag)
