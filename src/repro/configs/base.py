"""Cell machinery: every (arch x input-shape) pair resolves to a ``Cell`` —
a step function + ShapeDtypeStruct args + PartitionSpec trees + logical-axis
rules — which ``launch.dryrun`` lowers and compiles on the production mesh.

Per-family builders live here; per-arch files define the exact published
config and its rule table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.collection import EmbeddingCollection
import repro.dist.partitioning as dist
from repro.nn import transformer as T

__all__ = ["Cell", "dp_axes", "lm_state_specs", "replicated_like", "emb_state_specs", "Arch"]


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str  # train | prefill | decode | serve | retrieval
    step_fn: Callable
    args: Tuple[Any, ...]  # pytree of ShapeDtypeStruct, positional
    in_specs: Tuple[Any, ...]  # PartitionSpec pytrees matching args
    rules: Dict[str, Any]
    donate: Tuple[int, ...] = ()
    note: str = ""


@dataclasses.dataclass
class Arch:
    """One assigned architecture: config + cells + reduced smoke runner."""

    name: str
    family: str  # lm | gnn | recsys
    shapes: Tuple[str, ...]
    build_cell: Callable[..., Optional[Cell]]  # (shape, mesh_axes) -> Cell | None (skip)
    smoke: Callable[[], Dict[str, Any]]  # tiny CPU run; returns metrics
    notes: str = ""


def dp_axes(mesh_axes: Sequence[str]) -> Tuple[str, ...]:
    """The data-parallel mesh axes ('pod' composes with 'data')."""
    return tuple(a for a in ("pod", "data") if a in mesh_axes)


def replicated_like(tree: Any) -> Any:
    """Fully-replicated PartitionSpec tree matching ``tree``'s structure."""
    return jax.tree_util.tree_map(lambda _: P(), tree)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def lm_state_specs(model, cfg: T.TransformerConfig, rules: Dict[str, Any]) -> Dict[str, Any]:
    """PartitionSpec tree for the LM train state under ``rules``."""
    axes = T.lm_param_axes(cfg)
    with dist.axis_rules(None, rules):
        pspecs = dist.specs_for_axes(axes)
    return {
        "params": pspecs,
        "opt": {"m": pspecs, "v": pspecs},
        "step": P(),
    }


def lm_cell(
    arch: str,
    shape: str,
    model,
    cfg: T.TransformerConfig,
    kind: str,
    batch: int,
    seq: int,
    rules: Dict[str, Any],
) -> Cell:
    dp = rules["batch"]
    if kind == "train":
        state_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        batch_specs = {"tokens": P(dp, None), "labels": P(dp, None)}
        args = (state_shapes, model.train_specs(batch, seq))
        in_specs = (lm_state_specs(model, cfg, rules), batch_specs)
        step = model.train_step
        donate = (0,)
    elif kind == "prefill":
        params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))["params"]
        args = (params_shapes, model.prefill_specs(batch, seq))
        in_specs = (
            lm_state_specs(model, cfg, rules)["params"],
            {"tokens": P(dp, None)},
        )
        step = model.prefill_step
        donate = ()
    elif kind == "decode":
        params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))["params"]
        specs = model.decode_specs(batch, seq)
        kv_seq = rules.get("kv_seq")
        heads = rules.get("kv_heads_eff")

        def cache_spec(leaf):
            if len(leaf.shape) == 5:  # [G, B, S, H, hd]
                return P(None, dp, kv_seq, heads, None)
            return P(dp, kv_seq, heads, None)  # [B, S, H, hd]

        cache_specs = jax.tree_util.tree_map(cache_spec, specs["caches"])
        args = (params_shapes, specs["caches"], specs["token"], specs["pos"])
        in_specs = (
            lm_state_specs(model, cfg, rules)["params"],
            cache_specs,
            P(dp, None),
            P(),
        )
        step = model.decode_fn
        donate = (1,)
    else:
        raise ValueError(kind)
    return Cell(arch, shape, kind, step, args, in_specs, rules, donate)


# ---------------------------------------------------------------------------
# Recsys family
# ---------------------------------------------------------------------------


def emb_state_specs(collection: EmbeddingCollection, mode: str) -> Any:
    return collection.shard_specs(mode=mode)


def recsys_state_specs(state_shapes, collection: EmbeddingCollection, mode: str) -> Dict[str, Any]:
    specs = {
        "params": replicated_like(state_shapes["params"]),
        "opt": replicated_like(state_shapes["opt"]),
        "emb": emb_state_specs(collection, mode),
        "step": P(),
    }
    return specs


def recsys_cell(
    arch: str,
    shape: str,
    model,
    kind: str,
    batch_specs: Dict[str, Any],
    batch_in_specs: Dict[str, Any],
    emb_mode: str,
    rules: Dict[str, Any],
) -> Cell:
    state_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    state_specs = recsys_state_specs(state_shapes, model.collection, emb_mode)
    if kind == "train":
        step = model.train_step
    elif kind == "serve":
        step = model.serve_step
    elif kind == "retrieval":
        step = model.retrieval_score
    else:
        raise ValueError(kind)
    return Cell(
        arch,
        shape,
        kind,
        step,
        (state_shapes, batch_specs),
        (state_specs, batch_in_specs),
        rules,
        donate=(0,) if kind == "train" else (),
    )


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


def gnn_cell(
    arch: str,
    shape: str,
    model,
    kind: str,
    batch_specs: Dict[str, Any],
    batch_in_specs: Dict[str, Any],
    rules: Dict[str, Any],
) -> Cell:
    state_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    state_specs = {
        "params": replicated_like(state_shapes["params"]),
        "opt": replicated_like(state_shapes["opt"]),
        "step": P(),
    }
    step = model.train_step if kind == "train" else model.serve_step
    return Cell(
        arch,
        shape,
        kind,
        step,
        (state_shapes, batch_specs),
        (state_specs, batch_in_specs),
        rules,
        donate=(0,) if kind == "train" else (),
    )
