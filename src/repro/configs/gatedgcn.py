"""gatedgcn [arXiv:2003.00982]: 16 layers, d_hidden=70, gated aggregator.
Shape-dependent feature dims: cora (1433/7), reddit-sampled (602/41),
ogbn-products (100/47), molecules (16, graph regression)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import shapes as S
from repro.configs.base import Arch, dp_axes, gnn_cell
from repro.data import graphs
from repro.models.gatedgcn import GatedGCNConfig, GatedGCNModel

def _pad512(n):
    # jit in_shardings need divisible dims; pad nodes/edges with -1 sentinels
    # (the message-passing layer drops them) up to a 512 multiple.
    return -(-n // 512) * 512


SHAPE_CFG = {
    # shape: (kind, n_nodes, n_edges, d_feat, n_classes, task, extras)
    "full_graph_sm": ("train", _pad512(2708), _pad512(10556), 1433, 7, "node", {}),
    "minibatch_lg": ("train", 1024 * (1 + 15 + 150), 1024 * (15 + 150), 602, 41, "node", {}),
    "ogb_products": ("train", _pad512(2_449_029), _pad512(61_859_140), 100, 47, "node", {}),
    "molecule": ("train", 128 * 30, 128 * 64, 16, 1, "graph", {"n_graphs": 128}),
}

def build_cell(shape, mesh_axes):
    kind, n_nodes, n_edges, d_feat, n_classes, task, extra = SHAPE_CFG[shape]
    dp = dp_axes(mesh_axes)
    cfg = GatedGCNConfig(d_feat=d_feat, n_classes=n_classes, n_layers=16,
                         d_hidden=70, task=task)
    model = GatedGCNModel(cfg)
    specs = model.input_specs(n_nodes, n_edges, n_graphs=extra.get("n_graphs", 0))
    in_specs = {
        "feat": P(dp, None), "src": P(dp), "dst": P(dp),
    }
    if task == "graph":
        in_specs.update(graph_id=P(dp), node_mask=P(dp), label=P(dp))
    else:
        in_specs.update(label=P(dp), label_mask=P(dp))
    rules = {"batch": dp, "node": dp, "edge": dp, "seq": None}
    return gnn_cell("gatedgcn", shape, model, kind, specs, in_specs, rules)

def smoke():
    cfg = GatedGCNConfig(d_feat=12, n_classes=5, n_layers=3, d_hidden=16)
    m = GatedGCNModel(cfg)
    st = m.init(jax.random.PRNGKey(0))
    b = graphs.full_graph_batch(64, 256, 12, 5)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    st, metrics = jax.jit(m.train_step)(st, b)
    # sampled-block path
    indptr, indices, _ = graphs.random_graph_csr(200, 800, 1)
    import numpy as np
    sb = graphs.sampled_batch(indptr, indices, np.random.default_rng(0).normal(
        size=(200, 12)).astype("float32"), np.zeros(200, "int32"), 8, (3, 2), 0, 0)
    sb = {k: jnp.asarray(v) for k, v in sb.items()}
    st, m2 = jax.jit(m.train_step)(st, sb)
    return {"loss": float(metrics["loss"]),
            "finite": bool(jnp.isfinite(metrics["loss"])) and bool(jnp.isfinite(m2["loss"])),
            "logits_shape": ()}

ARCH = Arch("gatedgcn", "gnn", S.GNN_SHAPES, build_cell, smoke,
            notes="segment-sum message passing; real neighbor sampler for minibatch_lg")
