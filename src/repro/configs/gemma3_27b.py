"""gemma3-27b [hf:google/gemma-3]: 62L d_model=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144, 5:1 local:global sliding-window (window 1024),
head_dim 128 (decoupled from d_model/n_heads).

long_500k RUNS for this arch: local layers keep a 1024-token ring-buffer KV,
global layers shard the 512k KV over the data axis (flash-decoding style
split-softmax, realized by SPMD from the kv_seq sharding rule).
"""
import jax.numpy as jnp

from repro.configs.lm_common import BF16, make_lm_arch
from repro.nn.layers import Dtypes
from repro.nn.transformer import TransformerConfig

CONFIG = TransformerConfig(
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_head=128,
    d_ff=21504, vocab=262144, pattern=("local",) * 5 + ("global",),
    window=1024, dtypes=BF16, remat=True,
)

SMOKE = TransformerConfig(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=128,
    vocab=256, pattern=("local",) * 5 + ("global",), window=8, kv_repeat=2,
    dtypes=Dtypes(param=jnp.float32, compute=jnp.float32), block_q=16, block_k=16,
)

ARCH = make_lm_arch(
    "gemma3-27b", CONFIG, long_ok=True, smoke_cfg=SMOKE,
    notes="5:1 local:global; long_500k runs with data-sharded global KV",
)
