"""smollm-360m [hf:HuggingFaceTB/SmolLM-360M]: 32L d_model=960 15H (GQA kv=5)
d_ff=2560 vocab=49152 (llama arch).

15 heads are indivisible by model=16: at 360M params the production layout is
(FSDP-)data parallel for attention with TP only on FFN (2560/16) and vocab
(49152/16) — attention params replicated over the model axis (DESIGN.md).
Full attention -> long_500k skipped.
"""
import jax.numpy as jnp

from repro.configs.lm_common import BF16, make_lm_arch
from repro.nn.layers import Dtypes
from repro.nn.transformer import TransformerConfig

CONFIG = TransformerConfig(
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_head=64,
    d_ff=2560, vocab=49152, dtypes=BF16, remat=True,
)

SMOKE = TransformerConfig(
    n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, d_head=20, d_ff=160,
    vocab=256, dtypes=Dtypes(param=jnp.float32, compute=jnp.float32),
    block_q=16, block_k=16,
)

ARCH = make_lm_arch(
    "smollm-360m", CONFIG, tp_attn=False, long_ok=False, smoke_cfg=SMOKE,
    notes="15 heads indivisible by tp=16 -> attention DP, FFN/vocab TP; long_500k skipped",
)
