"""fm [Rendle ICDM'10]: 39 sparse fields, embed_dim=10, 2-way interactions via
the O(nk) sum-square trick.  Tables served through the frequency-aware cache
(row-sharded slow tier: dim 10 cannot split over model=16 — DESIGN.md)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import shapes as S
from repro.configs.base import Arch, Cell, dp_axes, recsys_cell
from repro.data import synth
from repro.models.recsys_models import FMConfig, FMModel

CONFIG = FMConfig(
    vocab_sizes=S.FM_VOCABS, embed_dim=10, batch_size=65536,
    cache_ratio=0.015, max_unique_per_step=1 << 21, lr=0.05,
    arena_precision="fp32",  # device-arena tail codec; set fp16/int8 to tier the cache arena
)

def _rules(mesh_axes):
    dp = dp_axes(mesh_axes)
    return {"batch": dp, "seq": None}

def build_cell(shape, mesh_axes):
    kind, batch = S.RECSYS_DEFS[shape]
    dp = dp_axes(mesh_axes)
    model = FMModel(CONFIG)
    if kind == "retrieval":
        specs = model.input_specs(1, n_candidates=S.N_CANDIDATES)
        in_specs = {"sparse": P(None, None), "candidates": P(dp)}
    else:
        specs = model.input_specs(batch)
        in_specs = {"sparse": P(dp, None), "label": P(dp)}
    return recsys_cell("fm", shape, FMModel(CONFIG if kind == "train" else _serve_cfg(batch, kind)),
                       kind, specs, in_specs, "row", _rules(mesh_axes))

def _serve_cfg(batch, kind):
    import dataclasses
    return dataclasses.replace(CONFIG, batch_size=batch if kind != "retrieval" else 1)

def smoke():
    cfg = FMConfig(vocab_sizes=(64,) * 6, embed_dim=4, batch_size=16, cache_ratio=0.3)
    m = FMModel(cfg)
    st = m.init(jax.random.PRNGKey(0))
    b = synth.sparse_batch(synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes), 16, 0, 0)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    st, metrics = jax.jit(m.train_step)(st, b)
    sc, _ = jax.jit(m.retrieval_score)(st, {
        "sparse": b["sparse"][:1, :5], "candidates": jnp.arange(32, dtype=jnp.int32)})
    return {"loss": float(metrics["loss"]),
            "finite": bool(jnp.isfinite(metrics["loss"])) and bool(jnp.isfinite(sc).all()),
            "logits_shape": tuple(sc.shape)}

ARCH = Arch("fm", "recsys", S.RECSYS_SHAPES, build_cell, smoke,
            notes="cache row-mode (dim 10 < tp); retrieval = context-factored FM scan")
