"""DLRM on Criteo Kaggle — the paper's own evaluation config (§5.1):
embed dim 128, bottom MLP 512-256-128, top MLP 1024-1024-512-256-1,
batch 16k, SGD lr=1.0, cache ratio 1.5%%."""
import jax
import jax.numpy as jnp

from repro.configs import shapes as S
from repro.configs.base import Arch, dp_axes, recsys_cell
from jax.sharding import PartitionSpec as P
from repro.data import synth
from repro.models.dlrm import DLRM, DLRMConfig

CONFIG = DLRMConfig(
    vocab_sizes=S.CRITEO_VOCABS, n_dense=13, embed_dim=128,
    batch_size=16384, cache_ratio=0.015, lr=1.0, max_unique_per_step=1 << 19,
    arena_precision="fp32",  # device-arena tail codec; set fp16/int8 to tier the cache arena
)

PAPER_SHAPES = ("paper_16k",)

def build_cell(shape, mesh_axes):
    dp = dp_axes(mesh_axes)
    model = DLRM(CONFIG)
    specs = model.input_specs(CONFIG.batch_size)
    in_specs = {"dense": P(dp, None), "sparse": P(dp, None), "label": P(dp)}
    return recsys_cell("dlrm-criteo", shape, model, "train", specs, in_specs,
                       "column", {"batch": dp, "seq": None})

def smoke():
    cfg = DLRMConfig(vocab_sizes=(128, 64, 256), embed_dim=16, batch_size=16,
                     cache_ratio=0.3, lr=0.1,
                     bottom_mlp=(32, 16), top_mlp=(32, 16))
    m = DLRM(cfg)
    st = m.init(jax.random.PRNGKey(0))
    b = synth.sparse_batch(synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13), 16, 0, 0)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    st, metrics = jax.jit(m.train_step)(st, b)
    return {"loss": float(metrics["loss"]), "finite": bool(jnp.isfinite(metrics["loss"])),
            "logits_shape": ()}

ARCH = Arch("dlrm-criteo", "recsys", PAPER_SHAPES, build_cell, smoke,
            notes="the paper's model; column-TP cache, dim 128")
