"""internlm2-20b [arXiv:2403.17297]: 48L d_model=6144 48H (GQA kv=8)
d_ff=16384 vocab=92544.

kv_repeat=2 (8 kv heads -> 16 for the model axis); full attention ->
long_500k skipped.
"""
import jax.numpy as jnp

from repro.configs.lm_common import BF16, make_lm_arch
from repro.nn.layers import Dtypes
from repro.nn.transformer import TransformerConfig

CONFIG = TransformerConfig(
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=92544, kv_repeat=2, dtypes=BF16, remat=True,
)

SMOKE = TransformerConfig(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    kv_repeat=2, dtypes=Dtypes(param=jnp.float32, compute=jnp.float32),
    block_q=16, block_k=16,
)

ARCH = make_lm_arch(
    "internlm2-20b", CONFIG, tp_kv_param=False, long_ok=False, smoke_cfg=SMOKE,
    notes="dense GQA; kv_repeat=2; long_500k skipped (full attn)",
)
