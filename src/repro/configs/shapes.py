"""Assigned shape sets (see the assignment matrix)."""
RECSYS_SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")
RECSYS_DEFS = {
    "train_batch": ("train", 65536),
    "serve_p99": ("serve", 512),
    "serve_bulk": ("serve", 262144),
    "retrieval_cand": ("retrieval", 1),  # + n_candidates=1_000_000
}
N_CANDIDATES = 1_000_000

GNN_SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")

# Criteo Kaggle per-field cardinalities (public; sum = 33,762,577 incl. rounding)
CRITEO_VOCABS = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18, 15,
    286181, 105, 142572,
)
# Avazu-like 13-field split; last field adjusted so the total matches Table 1.
_AVAZU_BASE = (241, 8, 8, 3697, 4614, 25, 6_500_000, 2_500_000, 26, 8, 10, 432, 0)
AVAZU_VOCABS = _AVAZU_BASE[:-1] + (9_445_823 - sum(_AVAZU_BASE[:-1]),)
assert sum(AVAZU_VOCABS) == 9_445_823

# FM (criteo-full featurization): 26 categorical + 13 bucketized-dense fields,
# plus a synthetic padding field so the row-sharded slow tier divides by 512.
_FM_RAW = CRITEO_VOCABS + (100,) * 13
FM_VOCABS = _FM_RAW + (-(-sum(_FM_RAW) // 512) * 512 - sum(_FM_RAW),)
assert sum(FM_VOCABS) % 512 == 0
