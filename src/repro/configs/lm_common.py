"""Shared machinery for the 5 assigned LM archs.

Shapes (assignment):
  train_4k     seq 4096,  global batch 256   -> train_step (fwd+bwd+adamw)
  prefill_32k  seq 32768, global batch 32    -> prefill forward
  decode_32k   kv 32768,  global batch 128   -> one-token decode vs KV cache
  long_500k    kv 524288, global batch 1     -> sub-quadratic archs only
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import Arch, Cell, dp_axes, lm_cell
from repro.models.lm import LMModel
from repro.nn import transformer as T
from repro.nn.layers import Dtypes

LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
SHAPE_DEFS = {
    "train_4k": ("train", 256, 4096),
    "prefill_32k": ("prefill", 32, 32768),
    "decode_32k": ("decode", 128, 32768),
    "long_500k": ("decode", 1, 524288),
}

# bf16 weights + fp32 Adam moments: 314B params / 256 chips needs
# 2.45 (p) + 4.9 (m) + 4.9 (v) = 12.3 GB/chip, inside the v5e 16 GB budget.
BF16 = Dtypes(param=jnp.bfloat16, compute=jnp.bfloat16)


def lm_rules(
    mesh_axes: Sequence[str],
    kind: str,
    *,
    tp_attn: bool = True,
    tp_kv_param: bool = True,
    moe: Optional[str] = None,  # None | "ep" | "tp"
    kv_seq=None,
    fsdp: bool = True,
) -> Dict[str, object]:
    dp = dp_axes(mesh_axes)
    return {
        "batch": dp,
        "seq": None,
        "embed": "data" if fsdp else None,  # FSDP/ZeRO param shard
        "heads": "model" if tp_attn else None,
        "kv_heads": "model" if (tp_attn and tp_kv_param) else None,
        "kv_heads_eff": "model" if tp_attn else None,
        "mlp": "model",
        "vocab": "model",
        "layer_groups": None,
        "experts": "model" if moe == "ep" else None,
        "expert_mlp": "model" if moe == "tp" else None,
        "kv_seq": kv_seq,
    }


def make_lm_arch(
    name: str,
    cfg: T.TransformerConfig,
    *,
    moe: Optional[str] = None,
    tp_attn: bool = True,
    tp_kv_param: bool = True,
    long_ok: bool = False,
    long_kv_seq: Optional[str] = "data",
    smoke_cfg: T.TransformerConfig,
    notes: str = "",
) -> Arch:
    def build_cell(shape: str, mesh_axes: Sequence[str]) -> Optional[Cell]:
        if shape == "long_500k" and not long_ok:
            return None  # pure full-attention arch: documented skip (DESIGN.md)
        kind, batch, seq = SHAPE_DEFS[shape]
        kv_seq = long_kv_seq if shape == "long_500k" else None
        rules = lm_rules(
            mesh_axes, kind, tp_attn=tp_attn, tp_kv_param=tp_kv_param, moe=moe, kv_seq=kv_seq
        )
        if shape == "long_500k":
            # batch=1: the data axis belongs to the sharded KV sequence
            # (flash-decoding split), not to batch.
            rules["batch"] = None
        model = LMModel(cfg)
        return lm_cell(name, shape, model, cfg, kind, batch, seq, rules)

    def smoke() -> Dict[str, object]:
        model = LMModel(smoke_cfg, lr=1e-3)
        state = model.init(jax.random.PRNGKey(0))
        b, s = 2, 16
        toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, smoke_cfg.vocab)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        state, metrics = jax.jit(model.train_step)(state, batch)
        caches = T.init_decode_caches(smoke_cfg, b, s, dtype=jnp.float32)
        logits, caches = jax.jit(model.decode_fn)(
            state["params"], caches, toks[:, :1], jnp.zeros((), jnp.int32)
        )
        return {
            "loss": float(metrics["loss"]),
            "logits_shape": tuple(logits.shape),
            "finite": bool(jnp.isfinite(metrics["loss"]))
            and bool(jnp.isfinite(logits).all()),
        }

    return Arch(
        name=name,
        family="lm",
        shapes=LM_SHAPES,
        build_cell=build_cell,
        smoke=smoke,
        notes=notes,
    )
