"""Arch registry: ``--arch <id>`` resolves here.

The 10 assigned architectures plus the paper's own DLRM configs.
"""
from repro.configs import (
    dien,
    din,
    dlrm_avazu,
    dlrm_criteo,
    fm,
    gatedgcn,
    gemma3_27b,
    grok_1_314b,
    internlm2_20b,
    mind,
    olmoe_1b_7b,
    smollm_360m,
)

REGISTRY = {
    a.name: a
    for a in (
        grok_1_314b.ARCH,
        olmoe_1b_7b.ARCH,
        gemma3_27b.ARCH,
        smollm_360m.ARCH,
        internlm2_20b.ARCH,
        gatedgcn.ARCH,
        din.ARCH,
        dien.ARCH,
        fm.ARCH,
        mind.ARCH,
        dlrm_criteo.ARCH,
        dlrm_avazu.ARCH,
    )
}

ASSIGNED = [n for n in REGISTRY if not n.startswith("dlrm")]


def get(name: str):
    return REGISTRY[name]
