"""mind [arXiv:1904.08030]: embed_dim=64 n_interests=4 capsule_iters=3.
Taobao-scale tables (4M items / 1M users); column-wise TP cache (64/16=4)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import shapes as S
from repro.configs.base import Arch, dp_axes, recsys_cell
from repro.data import synth
from repro.models.recsys_models import MINDConfig, MINDModel

CONFIG = MINDConfig(
    n_items=4_000_000, n_users=1_000_000, embed_dim=64, seq_len=100,
    n_interests=4, capsule_iters=3, batch_size=65536,
    cache_ratio=0.015, max_unique_per_step=1 << 22, lr=0.05,
    arena_precision="fp32",  # device-arena tail codec; set fp16/int8 to tier the cache arena
)

def build_cell(shape, mesh_axes):
    kind, batch = S.RECSYS_DEFS[shape]
    dp = dp_axes(mesh_axes)
    model = MINDModel(CONFIG)
    if kind == "retrieval":
        specs = model.input_specs(1, n_candidates=S.N_CANDIDATES)
        in_specs = {"hist_items": P(None, None), "hist_len": P(None),
                    "user": P(None), "candidates": P(dp)}
    else:
        specs = model.input_specs(batch)
        in_specs = {"hist_items": P(dp, None), "hist_len": P(dp), "user": P(dp),
                    "target_item": P(dp), "label": P(dp)}
    return recsys_cell("mind", shape, model, kind, specs, in_specs,
                       "column", {"batch": dp, "seq": None})

def smoke():
    cfg = MINDConfig(n_items=512, n_users=32, embed_dim=16, seq_len=8,
                     batch_size=8, cache_ratio=0.3)
    m = MINDModel(cfg)
    st = m.init(jax.random.PRNGKey(0))
    b = synth.recsys_batch(512, 32, 8, 8, 0, 0)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    st, metrics = jax.jit(m.train_step)(st, b)
    sc, _ = jax.jit(m.retrieval_score)(st, {
        "hist_items": b["hist_items"][:1], "hist_len": b["hist_len"][:1],
        "user": b["user"][:1], "candidates": jnp.arange(64, dtype=jnp.int32)})
    return {"loss": float(metrics["loss"]),
            "finite": bool(jnp.isfinite(metrics["loss"])) and bool(jnp.isfinite(sc).all()),
            "logits_shape": tuple(sc.shape)}

ARCH = Arch("mind", "recsys", S.RECSYS_SHAPES, build_cell, smoke,
            notes="column-TP cache (dim 64); retrieval = max-over-interests matmul")
