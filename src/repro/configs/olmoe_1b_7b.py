"""olmoe-1b-7b [arXiv:2409.02060]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64 experts top-8.

Sharding: 64 small experts >= model=16 -> true EP (experts over model,
all-to-all dispatch); full attention -> long_500k skipped.
"""
import jax.numpy as jnp

from repro.configs.lm_common import BF16, make_lm_arch
from repro.nn.layers import Dtypes
from repro.nn.transformer import TransformerConfig

CONFIG = TransformerConfig(
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
    vocab=50304, ffn="moe", n_experts=64, top_k=8, dtypes=BF16, remat=True,
    moe_impl="shard_map",  # §Perf olmoe it4
)

SMOKE = TransformerConfig(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32, vocab=256,
    ffn="moe", n_experts=8, top_k=4,
    dtypes=Dtypes(param=jnp.float32, compute=jnp.float32), block_q=16, block_k=16,
)

ARCH = make_lm_arch(
    "olmoe-1b-7b", CONFIG, moe="ep", long_ok=False, smoke_cfg=SMOKE,
    notes="MoE 64e top-8; expert parallel over model axis; long_500k skipped (full attn)",
)
