"""DLRM on Avazu — the paper's second config (batch 64k, lr 5e-2)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import shapes as S
from repro.configs.base import Arch, dp_axes, recsys_cell
from repro.data import synth
from repro.models.dlrm import DLRM, DLRMConfig

CONFIG = DLRMConfig(
    vocab_sizes=S.AVAZU_VOCABS, n_dense=8, embed_dim=128,
    batch_size=65536, cache_ratio=0.015, lr=5e-2, max_unique_per_step=1 << 20,
    arena_precision="fp32",  # device-arena tail codec; set fp16/int8 to tier the cache arena
)

PAPER_SHAPES = ("paper_64k",)

def build_cell(shape, mesh_axes):
    dp = dp_axes(mesh_axes)
    model = DLRM(CONFIG)
    specs = model.input_specs(CONFIG.batch_size)
    in_specs = {"dense": P(dp, None), "sparse": P(dp, None), "label": P(dp)}
    return recsys_cell("dlrm-avazu", shape, model, "train", specs, in_specs,
                       "column", {"batch": dp, "seq": None})

def smoke():
    cfg = DLRMConfig(vocab_sizes=(64, 32), n_dense=8, embed_dim=8, batch_size=8,
                     cache_ratio=0.5, lr=0.05, bottom_mlp=(16, 8), top_mlp=(16,))
    m = DLRM(cfg)
    st = m.init(jax.random.PRNGKey(0))
    b = synth.sparse_batch(synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=8), 8, 0, 0)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    st, metrics = jax.jit(m.train_step)(st, b)
    return {"loss": float(metrics["loss"]), "finite": bool(jnp.isfinite(metrics["loss"])),
            "logits_shape": ()}

ARCH = Arch("dlrm-avazu", "recsys", PAPER_SHAPES, build_cell, smoke,
            notes="the paper's Avazu config")
