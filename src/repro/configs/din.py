"""din [arXiv:1706.06978]: embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80,
target attention.  Amazon-scale tables (10M items / 1M cates / 1M users)
through the frequency-aware cache (row-mode: dim 18 < tp)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import shapes as S
from repro.configs.base import Arch, dp_axes, recsys_cell
from repro.data import synth
from repro.models.recsys_models import DINConfig, DINModel

CONFIG = DINConfig(
    n_items=10_000_000, n_cates=1_000_000, n_users=1_000_256,  # total % 512 == 0 (row-sharded tier)
    embed_dim=18, seq_len=100, attn_mlp=(80, 40), mlp=(200, 80),
    batch_size=65536, cache_ratio=0.015, max_unique_per_step=1 << 22, lr=0.05,
    arena_precision="fp32",  # device-arena tail codec; set fp16/int8 to tier the cache arena
)

MODEL_CLS = DINModel

def _batch_in_specs(model, kind, dp):
    if kind == "retrieval":
        return {
            "hist_items": P(None, None), "hist_cates": P(None, None),
            "hist_len": P(None), "user": P(None),
            "candidates": P(dp), "candidate_cates": P(dp),
        }
    s = {k: (P(dp, None) if k.startswith("hist_i") or k.startswith("hist_c") else P(dp))
         for k in ("hist_items", "hist_cates", "hist_len", "target_item",
                   "target_cate", "user", "label")}
    return s

def build_cell(shape, mesh_axes, config=None, arch_name="din", model_cls=None):
    cfg = config or CONFIG
    model_cls = model_cls or MODEL_CLS
    kind, batch = S.RECSYS_DEFS[shape]
    dp = dp_axes(mesh_axes)
    model = model_cls(cfg)
    if kind == "retrieval":
        specs = model.input_specs(1, n_candidates=S.N_CANDIDATES)
    else:
        specs = model.input_specs(batch)
    in_specs = _batch_in_specs(model, kind, dp)
    in_specs = {k: v for k, v in in_specs.items() if k in specs}
    return recsys_cell(arch_name, shape, model, kind, specs, in_specs, "row",
                       {"batch": dp, "seq": None})

def smoke(config=None, model_cls=None):
    cfg = (config or DINConfig)(n_items=512, n_cates=64, n_users=32, seq_len=8,
                                batch_size=8, cache_ratio=0.3)
    m = (model_cls or DINModel)(cfg)
    st = m.init(jax.random.PRNGKey(0))
    b = synth.recsys_batch(512, 32, 8, 8, 0, 0, n_cates=64)
    b = {k: jnp.asarray(v) for k, v in b.items()}
    st, metrics = jax.jit(m.train_step)(st, b)
    ret = {"hist_items": b["hist_items"][:1], "hist_cates": b["hist_cates"][:1],
           "hist_len": b["hist_len"][:1], "user": b["user"][:1],
           "candidates": jnp.arange(32, dtype=jnp.int32),
           "candidate_cates": (jnp.arange(32, dtype=jnp.int32) % 64)}
    sc, _ = jax.jit(m.retrieval_score)(st, ret)
    return {"loss": float(metrics["loss"]),
            "finite": bool(jnp.isfinite(metrics["loss"])) and bool(jnp.isfinite(sc).all()),
            "logits_shape": tuple(sc.shape)}

ARCH = Arch("din", "recsys", S.RECSYS_SHAPES, build_cell, smoke,
            notes="cache row-mode; retrieval shares user encoding across 1M candidates")
