"""dien [arXiv:1809.03672]: embed_dim=18 seq_len=100 gru_dim=108 mlp=200-80,
AUGRU interest evolution.  Same tables/cache layout as DIN."""
import jax
import jax.numpy as jnp

from repro.configs import shapes as S
from repro.configs.base import Arch
from repro.configs.din import build_cell as din_build_cell, smoke as din_smoke
from repro.models.recsys_models import DIENConfig, DIENModel

CONFIG = DIENConfig(
    n_items=10_000_000, n_cates=1_000_000, n_users=1_000_256,  # total % 512 == 0 (row-sharded tier)
    embed_dim=18, seq_len=100, gru_dim=108, mlp=(200, 80),
    batch_size=65536, cache_ratio=0.015, max_unique_per_step=1 << 22, lr=0.05,
    arena_precision="fp32",  # device-arena tail codec; set fp16/int8 to tier the cache arena
)

def build_cell(shape, mesh_axes):
    return din_build_cell(shape, mesh_axes, config=CONFIG, arch_name="dien",
                          model_cls=DIENModel)

def smoke():
    def mk(**kw):
        return DIENConfig(gru_dim=12, **kw)
    return din_smoke(config=mk, model_cls=DIENModel)

ARCH = Arch("dien", "recsys", S.RECSYS_SHAPES, build_cell, smoke,
            notes="AUGRU; retrieval stage scores on GRU1 interest states (DESIGN.md)")
