"""grok-1-314b [hf:xai-org/grok-1]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.

Sharding: 8 big experts < model=16 -> in-expert TP (d_ff over model, experts
unsharded, no all-to-all); kv heads replicated 2x so the 16-way model axis
shards attention (Megatron KV-duplication); FSDP over data; full attention ->
long_500k skipped (DESIGN.md).
"""
import jax.numpy as jnp

from repro.configs.lm_common import BF16, make_lm_arch
from repro.nn.layers import Dtypes
from repro.nn.transformer import TransformerConfig

CONFIG = TransformerConfig(
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab=131072, ffn="moe", n_experts=8, top_k=2, kv_repeat=2,
    dtypes=BF16, remat=True, moe_impl="shard_map",  # §Perf grok_train it2
)

SMOKE = TransformerConfig(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    ffn="moe", n_experts=8, top_k=2, kv_repeat=2,
    dtypes=Dtypes(param=jnp.float32, compute=jnp.float32), block_q=16, block_k=16,
)

ARCH = make_lm_arch(
    "grok-1-314b", CONFIG, moe="tp", tp_kv_param=False, long_ok=False, smoke_cfg=SMOKE,
    notes="MoE 8e top-2; in-expert TP; kv_repeat=2; long_500k skipped (full attn)",
)
