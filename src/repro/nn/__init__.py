from repro.nn.layers import Dtypes
