"""Config-driven decoder-only transformer covering the 5 assigned LM archs.

Features: GQA (with optional KV-head replication so small-kv archs shard over
a 16-way model axis), RoPE, RMSNorm, SwiGLU dense FFN or top-k MoE, local
(sliding-window) / global attention layer patterns (Gemma-style), flash-style
chunked attention, scan-over-layer-groups (one compiled group body regardless
of depth) with optional remat, and KV-cache decode with ring buffers for
windowed layers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.partitioning import Param, constrain, split_params
from repro.nn import layers as L
from repro.nn import moe as M

__all__ = ["TransformerConfig", "init_lm", "forward", "prefill", "decode_step", "init_decode_caches"]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    ffn: str = "dense"  # "dense" | "moe"
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    pattern: Tuple[str, ...] = ("global",)  # attention kinds, cycled over layers
    window: int = 1024
    kv_repeat: int = 1  # replicate kv heads (sharding over model axis > kv heads)
    rope_theta: float = 10000.0
    dtypes: L.Dtypes = L.Dtypes()
    remat: bool = True
    block_q: int = 512
    block_k: int = 512
    use_pallas: bool = False
    moe_dp_groups: int = 1  # MoE dispatch groups (G = data-axis size shards
    # the dispatch buffer over 'exp_dp' -> data; see nn/moe.py + §Perf)
    moe_impl: str = "global"  # "global" (baseline) | "shard_map" (local
    # dispatch + single psum per layer — §Perf olmoe/grok_train)
    kv_cache_int8: bool = False  # KVQuant-style int8 cache with per-position
    # scales; scores/values use s8 x s8 -> s32 dots with scales factored out
    # (beyond-paper perf lever — EXPERIMENTS.md §Perf grok decode)

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def eff_kv_heads(self) -> int:
        return self.n_kv_heads * self.kv_repeat

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_rem(self) -> int:
        return self.n_layers % len(self.pattern)

    def layer_kind(self, pos_in_pattern: int) -> str:
        return self.pattern[pos_in_pattern]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(rng, cfg: TransformerConfig):
    dt = cfg.dtypes
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 6)
    s = 1.0 / np.sqrt(cfg.d_model)
    p = {
        "ln_attn": L.rmsnorm_init(cfg.d_model, dt),
        "wq": Param(jax.random.normal(ks[0], (cfg.d_model, hq, hd), dt.param) * s, ("embed", "heads", None)),
        "wk": Param(jax.random.normal(ks[1], (cfg.d_model, hkv, hd), dt.param) * s, ("embed", "kv_heads", None)),
        "wv": Param(jax.random.normal(ks[2], (cfg.d_model, hkv, hd), dt.param) * s, ("embed", "kv_heads", None)),
        "wo": Param(
            jax.random.normal(ks[3], (hq, hd, cfg.d_model), dt.param) * (1.0 / np.sqrt(hq * hd)),
            ("heads", None, "embed"),
        ),
        "ln_ffn": L.rmsnorm_init(cfg.d_model, dt),
    }
    if cfg.ffn == "moe":
        p["moe"] = M.moe_init(ks[4], cfg.d_model, cfg.d_ff, cfg.n_experts, dt)
    else:
        p["ffn"] = M.ffn_init(ks[4], cfg.d_model, cfg.d_ff, dt)
    return p


def init_lm(rng, cfg: TransformerConfig):
    """Returns (params, logical-axes tree). Group params are stacked [G, ...]."""
    return split_params(init_lm_tree(rng, cfg))


def lm_param_axes(cfg: TransformerConfig):
    """Logical-axes tree without allocating (eval_shape keeps Param aux data)."""
    tree = jax.eval_shape(lambda: init_lm_tree(jax.random.PRNGKey(0), cfg))
    return split_params(tree)[1]


def init_lm_tree(rng, cfg: TransformerConfig):
    k_embed, k_groups, k_rem, k_head = jax.random.split(rng, 4)
    lp = len(cfg.pattern)

    def group_init(rng):
        return {f"p{i}": _layer_init(k, cfg) for i, k in enumerate(jax.random.split(rng, lp))}

    tree = {"embed": L.embed_init(k_embed, cfg.vocab, cfg.d_model, cfg.dtypes)}
    if cfg.n_groups > 0:
        gkeys = jax.random.split(k_groups, cfg.n_groups)
        stacked = jax.vmap(group_init)(gkeys)  # Param is a pytree node; axes survive
        from repro.dist.partitioning import prepend_axis

        tree["groups"] = prepend_axis(stacked, "layer_groups")
    if cfg.n_rem:
        tree["rem"] = {
            f"p{i}": _layer_init(k, cfg)
            for i, k in enumerate(jax.random.split(k_rem, cfg.n_rem))
        }
    tree["final_norm"] = L.rmsnorm_init(cfg.d_model, cfg.dtypes)
    tree["head"] = {
        "w": Param(
            jax.random.normal(k_head, (cfg.d_model, cfg.vocab), cfg.dtypes.param)
            * (1.0 / np.sqrt(cfg.d_model)),
            ("embed", "vocab"),
        )
    }
    return tree


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _attn_block(p, x, cfg: TransformerConfig, kind: str, positions):
    dt = cfg.dtypes
    h = L.rmsnorm(p["ln_attn"], x, dt)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(dt.compute))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(dt.compute))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(dt.compute))
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    if cfg.kv_repeat > 1:
        k = jnp.repeat(k, cfg.kv_repeat, axis=2)
        v = jnp.repeat(v, cfg.kv_repeat, axis=2)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads_eff", None)
    v = constrain(v, "batch", "seq", "kv_heads_eff", None)
    window = cfg.window if kind == "local" else None
    o = L.gqa_attention(
        q, k, v, causal=True, window=window,
        block_q=cfg.block_q, block_k=cfg.block_k, use_pallas=cfg.use_pallas,
    )
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt.compute))
    return x + o, (k, v)


def _ffn_block(p, x, cfg: TransformerConfig):
    dt = cfg.dtypes
    h = L.rmsnorm(p["ln_ffn"], x, dt)
    if cfg.ffn == "moe":
        if cfg.moe_impl == "shard_map":
            from repro.dist.partitioning import resolve

            dp = resolve("batch") or ()
            dp = (dp,) if isinstance(dp, str) else tuple(dp)
            out, aux = M.moe_apply_shard_map(
                p["moe"], h, dt, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, data_axes=dp)
        else:
            out, aux = M.moe_apply(p["moe"], h, dt, top_k=cfg.top_k,
                                   capacity_factor=cfg.capacity_factor,
                                   dp_groups=cfg.moe_dp_groups)
    else:
        out, aux = M.ffn_apply(p["ffn"], h, dt), jnp.zeros((), jnp.float32)
    return x + out, aux


def _layer_fwd(p, x, cfg: TransformerConfig, kind: str, positions):
    x, _ = _attn_block(p, x, cfg, kind, positions)
    x, aux = _ffn_block(p, x, cfg)
    x = constrain(x, "batch", "seq", None)
    return x, aux


def _group_fwd(gp, x, cfg: TransformerConfig, positions):
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.pattern):
        x, a = _layer_fwd(gp[f"p{i}"], x, cfg, kind, positions)
        aux = aux + a
    return x, aux


def forward(params, cfg: TransformerConfig, tokens: jnp.ndarray):
    """tokens [B, S] -> (logits [B, S, V], aux loss)."""
    dt = cfg.dtypes
    b, s = tokens.shape
    x = jnp.take(params["embed"]["table"], tokens, axis=0).astype(dt.compute)
    x = constrain(x, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    body = functools.partial(_group_fwd, cfg=cfg, positions=positions)
    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)

    if cfg.n_groups > 0:
        def scan_fn(carry, gp):
            x, aux = carry
            x, a = body(gp, x)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(scan_fn, (x, jnp.zeros((), jnp.float32)), params["groups"])
    else:
        aux = jnp.zeros((), jnp.float32)
    if cfg.n_rem:
        for i in range(cfg.n_rem):
            x, a = _layer_fwd(params["rem"][f"p{i}"], x, cfg, cfg.layer_kind(i), positions)
            aux = aux + a
    x = L.rmsnorm(params["final_norm"], x, dt)
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"].astype(dt.compute))
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, aux


# ---------------------------------------------------------------------------
# decode with KV caches
# ---------------------------------------------------------------------------


def _cache_len(cfg: TransformerConfig, kind: str, max_len: int) -> int:
    return min(cfg.window, max_len) if kind == "local" else max_len


def init_decode_caches(cfg: TransformerConfig, batch: int, max_len: int, dtype=None):
    """Zeroed KV caches: {"groups": {f"p{i}": (k, v)}, "rem": ...}.

    Group caches are stacked [G, B, S_kind, Hkv_eff, hd]; local layers get
    ring buffers of size ``window``.
    """
    dtype = dtype or cfg.dtypes.compute
    hd, hkv = cfg.head_dim, cfg.eff_kv_heads

    def kv(s, lead=()):
        shape = tuple(lead) + (batch, s, hkv, hd)
        if cfg.kv_cache_int8:
            sshape = tuple(lead) + (batch, s, hkv)
            return (
                jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8),
                jnp.zeros(sshape, jnp.float32), jnp.zeros(sshape, jnp.float32),
            )
        return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))

    caches = {}
    if cfg.n_groups > 0:
        caches["groups"] = {
            f"p{i}": kv(_cache_len(cfg, kind, max_len), (cfg.n_groups,))
            for i, kind in enumerate(cfg.pattern)
        }
    if cfg.n_rem:
        caches["rem"] = {
            f"p{i}": kv(_cache_len(cfg, cfg.layer_kind(i), max_len))
            for i in range(cfg.n_rem)
        }
    return caches


def _decode_layer(p, x, cache, cfg: TransformerConfig, kind: str, pos):
    """x [B,1,D]; cache (k,v) [B,S_k,H,hd]; pos scalar current position."""
    dt = cfg.dtypes
    h = L.rmsnorm(p["ln_attn"], x, dt)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"].astype(dt.compute))
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"].astype(dt.compute))
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"].astype(dt.compute))
    posb = jnp.broadcast_to(pos[None], (x.shape[0], 1))
    q = L.rope(q, posb, cfg.rope_theta)
    k = L.rope(k, posb, cfg.rope_theta)
    if cfg.kv_repeat > 1:
        k = jnp.repeat(k, cfg.kv_repeat, axis=2)
        v = jnp.repeat(v, cfg.kv_repeat, axis=2)
    if cfg.kv_cache_int8:
        kc, vc, ks, vs = cache
        s_cache = kc.shape[1]
        idx = pos % s_cache if kind == "local" else pos
        # quantize the new token's K/V per (batch, head)
        kq, ksc = _quant_i8(k)
        vq, vsc = _quant_i8(v)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, kq, idx, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, vq, idx, axis=1)
        ks = jax.lax.dynamic_update_slice_in_dim(ks, ksc, idx, axis=1)
        vs = jax.lax.dynamic_update_slice_in_dim(vs, vsc, idx, axis=1)
        kc = constrain(kc, "batch", "kv_seq", "kv_heads_eff", None)
        vc = constrain(vc, "batch", "kv_seq", "kv_heads_eff", None)
        valid = jnp.minimum(pos + 1, s_cache) if kind == "local" else pos + 1
        o = _decode_attention_i8(q, kc, vc, ks, vs, valid)
        new_cache = (kc, vc, ks, vs)
    else:
        kc, vc = cache
        s_cache = kc.shape[1]
        idx = pos % s_cache if kind == "local" else pos
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), idx, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), idx, axis=1)
        kc = constrain(kc, "batch", "kv_seq", "kv_heads_eff", None)
        vc = constrain(vc, "batch", "kv_seq", "kv_heads_eff", None)
        valid = jnp.minimum(pos + 1, s_cache) if kind == "local" else pos + 1
        o = L.decode_attention(q, kc, vc, valid, window=None)
        new_cache = (kc, vc)
    o = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt.compute))
    x = x + o
    x, _ = _ffn_block(p, x, cfg)
    return x, new_cache


def _quant_i8(x: jnp.ndarray):
    """[B,1,H,hd] -> (int8 values, f32 scale [B,1,H])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _decode_attention_i8(q, kc, vc, ks, vs, cache_len):
    """int8-KV decode attention with scales factored OUT of the s8 dots.

    scores_j = (q8 . k8_j) * qs * ks_j / sqrt(hd)           (s8 x s8 -> s32)
    out_d    = (sum_j w8_j * v8_j[d]) * ws / 127             (s8 x s8 -> s32)
    where w_j = softmax_j * vs_j is row-quantized to w8.  Both contractions
    read int8 cache bytes — the point of the optimization; the only f32
    arrays are [.., S] score/weight rows (1/hd of the cache).
    """
    b, s, hkv, hd = kc.shape
    hq = q.shape[2]
    g = hq // hkv
    inv_sqrt = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, hkv, g, hd)
    q8, qs = _quant_i8(qg)  # scale over hd -> [b,hkv,g]
    raw = jnp.einsum("bhgd,bshd->bhgs", q8.astype(jnp.int8), kc,
                     preferred_element_type=jnp.int32)
    scores = (
        raw.astype(jnp.float32)
        * qs[..., None]
        * ks.transpose(0, 2, 1)[:, :, None, :]
        * inv_sqrt
    )
    pos = jnp.arange(s)
    validm = pos[None, :] < jnp.broadcast_to(jnp.asarray(cache_len), (b,))[:, None]
    scores = jnp.where(validm[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    w = p * vs.transpose(0, 2, 1)[:, :, None, :]  # fold per-position V scales
    wmax = jnp.maximum(jnp.abs(w).max(-1, keepdims=True), 1e-9)
    w8 = jnp.clip(jnp.round(w / wmax * 127.0), -127, 127).astype(jnp.int8)
    acc = jnp.einsum("bhgs,bshd->bhgd", w8, vc, preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * (wmax / 127.0)
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def decode_step(params, cfg: TransformerConfig, caches, token: jnp.ndarray, pos: jnp.ndarray):
    """One decode step. token [B,1] int32; pos [] int32 (same for all rows).

    Returns (logits [B, V], new caches).
    """
    dt = cfg.dtypes
    x = jnp.take(params["embed"]["table"], token, axis=0).astype(dt.compute)
    x = constrain(x, "batch", None, None)

    new_caches = {}
    if cfg.n_groups > 0:
        def scan_fn(x, inp):
            gp, gc = inp
            new_gc = {}
            for i, kind in enumerate(cfg.pattern):
                x, c = _decode_layer(gp[f"p{i}"], x, gc[f"p{i}"], cfg, kind, pos)
                new_gc[f"p{i}"] = c
            return x, new_gc

        x, new_caches["groups"] = jax.lax.scan(
            scan_fn, x, (params["groups"], caches["groups"])
        )
    if cfg.n_rem:
        new_caches["rem"] = {}
        for i in range(cfg.n_rem):
            x, c = _decode_layer(
                params["rem"][f"p{i}"], x, caches["rem"][f"p{i}"], cfg, cfg.layer_kind(i), pos
            )
            new_caches["rem"][f"p{i}"] = c
    x = L.rmsnorm(params["final_norm"], x, dt)
    logits = jnp.einsum("bsd,dv->bsv", x, params["head"]["w"].astype(dt.compute))[:, 0]
    logits = constrain(logits, "batch", "vocab")
    return logits, new_caches


def prefill(params, cfg: TransformerConfig, tokens: jnp.ndarray):
    """Prefill forward: returns last-position logits (caches omitted — the
    serve engine re-runs layers to fill caches when needed; dry-run shapes
    only need the compute graph)."""
    logits, _ = forward(params, cfg, tokens)
    return logits[:, -1]
