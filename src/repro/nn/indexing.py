"""Safe row gather: negative indices -> zero rows.

JAX wraps negative indices (numpy semantics) even under ``mode='fill'`` —
only *positive* out-of-bounds indices hit the fill path.  Every "-1 means
padding" gather in the framework must therefore remap negatives to a positive
OOB sentinel first.  (Found the hard way: the Pallas kernel disagreed with a
wrap-buggy oracle; see tests/test_indexing.py.)
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["take_rows"]


def take_rows(table: jnp.ndarray, idx: jnp.ndarray, fill_value=0) -> jnp.ndarray:
    """table [N, ...], idx [...] int; idx < 0 or >= N -> fill_value rows."""
    n = table.shape[0]
    safe = jnp.where(idx >= 0, idx, n)
    return jnp.take(table, safe, axis=0, mode="fill", fill_value=fill_value)
