"""Substrate NN layers: dense/MLP/norms/RoPE/GQA attention (pure JAX).

Conventions:
  * params are nested dicts of arrays; init functions return trees of
    ``dist.partitioning.Param`` (value + logical dim names) that callers split.
  * compute dtype (default bf16) is separate from param dtype (default fp32);
    softmax / norms accumulate in fp32.
  * attention is flash-style chunked (two-level ``lax.scan`` with online
    softmax) so no [S, S] score tensor is ever materialized — the pure-XLA
    analogue of the Pallas kernel in ``repro.kernels.flash_attention``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.partitioning import Param, constrain

__all__ = [
    "Dtypes",
    "dense_init",
    "dense",
    "mlp_init",
    "mlp",
    "rmsnorm_init",
    "rmsnorm",
    "layernorm_init",
    "layernorm",
    "rope",
    "gqa_attention",
    "decode_attention",
    "embed_init",
]


@dataclasses.dataclass(frozen=True)
class Dtypes:
    param: Any = jnp.float32
    compute: Any = jnp.bfloat16


def _uniform_init(rng, shape, dtype, fan_in):
    bound = 1.0 / np.sqrt(max(fan_in, 1))
    return jax.random.uniform(rng, shape, dtype, -bound, bound)


def dense_init(rng, d_in, d_out, dt: Dtypes, axes=(None, None), bias=True):
    kw, kb = jax.random.split(rng)
    p = {"w": Param(_uniform_init(kw, (d_in, d_out), dt.param, d_in), axes)}
    if bias:
        p["b"] = Param(jnp.zeros((d_out,), dt.param), (axes[1],))
    return p


def dense(p, x, dt: Dtypes):
    y = x.astype(dt.compute) @ p["w"].astype(dt.compute)
    if "b" in p:
        y = y + p["b"].astype(dt.compute)
    return y


def mlp_init(rng, dims: Tuple[int, ...], dt: Dtypes, hidden_axis: Optional[str] = None):
    """Plain MLP tower (recsys style): dims = (in, h1, ..., out)."""
    keys = jax.random.split(rng, len(dims) - 1)
    return {
        f"l{i}": dense_init(k, dims[i], dims[i + 1], dt, axes=(None, hidden_axis if i < len(dims) - 2 else None))
        for i, k in enumerate(keys)
    }


def mlp(p, x, dt: Dtypes, act=jax.nn.relu, final_act=False):
    n = len(p)
    for i in range(n):
        x = dense(p[f"l{i}"], x, dt)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def rmsnorm_init(d, dt: Dtypes):
    return {"scale": Param(jnp.ones((d,), dt.param), (None,))}


def rmsnorm(p, x, dt: Dtypes, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt.compute)


def layernorm_init(d, dt: Dtypes):
    return {"scale": Param(jnp.ones((d,), dt.param), (None,)), "bias": Param(jnp.zeros((d,), dt.param), (None,))}


def layernorm(p, x, dt: Dtypes, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(dt.compute)


def embed_init(rng, vocab, d, dt: Dtypes, axes=("vocab", "embed")):
    return {"table": Param(jax.random.normal(rng, (vocab, d), dt.param) * 0.02, axes)}


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """Rotary embedding.  x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Flash-style chunked GQA attention (training / prefill)
# --------------------------------------------------------------------------


def _block_mask(q_idx, k_idx, *, causal: bool, window: Optional[int]):
    """[bq, bk] boolean mask for absolute positions q_idx x k_idx."""
    m = jnp.ones((q_idx.shape[0], k_idx.shape[0]), bool)
    if causal:
        m &= q_idx[:, None] >= k_idx[None, :]
    if window is not None:
        m &= (q_idx[:, None] - k_idx[None, :]) < window
    return m


def gqa_attention(
    q: jnp.ndarray,  # [B, S, Hq, hd]
    k: jnp.ndarray,  # [B, S, Hkv, hd]
    v: jnp.ndarray,  # [B, S, Hkv, hd]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 512,
    block_k: int = 512,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Grouped-query attention with online-softmax chunking (no [S,S] buffer).

    Equivalent to softmax(q k^T / sqrt(hd) + mask) v with kv heads repeated
    across query groups.  ``window`` adds a sliding-window constraint
    (Gemma-style local attention).
    """
    if use_pallas:
        from repro.kernels.flash_attention import ops as fa_ops

        return fa_ops.flash_attention(q, k, v, causal=causal, window=window)

    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    groups = hq // hkv
    scale = 1.0 / np.sqrt(hd)

    block_q = min(block_q, s)
    block_k = min(block_k, s)
    nq, nk = s // block_q, s // block_k
    assert nq * block_q == s and nk * block_k == s, "seq must divide blocks"

    # [B, Hkv, G, S, hd] query view grouped by kv head
    qg = q.reshape(b, s, hkv, groups, hd).transpose(0, 2, 3, 1, 4)
    kk = k.transpose(0, 2, 1, 3)  # [B, Hkv, S, hd]
    vv = v.transpose(0, 2, 1, 3)

    q_blocks = qg.reshape(b, hkv, groups, nq, block_q, hd).transpose(3, 0, 1, 2, 4, 5)
    k_blocks = kk.reshape(b, hkv, nk, block_k, hd).transpose(2, 0, 1, 3, 4)
    v_blocks = vv.reshape(b, hkv, nk, block_k, hd).transpose(2, 0, 1, 3, 4)

    def q_step(_, qi):
        qb, qidx = qi  # qb: [B, Hkv, G, bq, hd]
        q_pos = qidx * block_q + jnp.arange(block_q)

        def kv_step(carry, ki):
            m_prev, l_prev, acc = carry
            kb, vb, kidx = ki
            k_pos = kidx * block_k + jnp.arange(block_k)
            s_blk = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qb, kb, preferred_element_type=jnp.float32
            ) * scale
            mask = _block_mask(q_pos, k_pos, causal=causal, window=window)
            s_blk = jnp.where(mask[None, None, None], s_blk, -1e30)
            m_new = jnp.maximum(m_prev, s_blk.max(-1))
            p = jnp.exp(s_blk - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, groups, block_q), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, groups, block_q), jnp.float32)
        a0 = jnp.zeros((b, hkv, groups, block_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (k_blocks, v_blocks, jnp.arange(nk))
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, out_blocks = jax.lax.scan(q_step, None, (q_blocks, jnp.arange(nq)))
    # out_blocks: [nq, B, Hkv, G, bq, hd] -> [B, S, Hq, hd]
    out = out_blocks.transpose(1, 2, 3, 0, 4, 5).reshape(b, hkv, groups, s, hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, hq, hd)
    return out


def decode_attention(
    q: jnp.ndarray,  # [B, 1, Hq, hd]
    k_cache: jnp.ndarray,  # [B, S, Hkv, hd]
    v_cache: jnp.ndarray,  # [B, S, Hkv, hd]
    cache_len: jnp.ndarray,  # [] or [B] valid prefix length
    *,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Single-token decode attention against a KV cache.

    For windowed layers callers pass a ring-buffer cache of size ``window``;
    masking is by validity only.
    """
    b, s, hkv, hd = k_cache.shape
    hq = q.shape[2]
    groups = hq // hkv
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, hkv, groups, hd)
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", qg.astype(jnp.float32) * scale, k_cache.astype(jnp.float32)
    )
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.broadcast_to(jnp.asarray(cache_len), (b,))[:, None]
    if window is not None:
        lo = jnp.broadcast_to(jnp.asarray(cache_len), (b,))[:, None] - window
        valid &= pos[None, :] >= lo
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, hd).astype(q.dtype)
