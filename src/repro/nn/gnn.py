"""GatedGCN message passing via segment ops (JAX has no SpMM beyond BCOO —
edge-index scatter IS the system here), plus a real neighbor sampler.

GatedGCN (arXiv:1711.07553, benchmarking-gnns arXiv:2003.00982 form):

    e_ij' = e_ij + ReLU(Norm(A h_i + B h_j + C e_ij))
    h_i'  = h_i + ReLU(Norm(U h_i + sum_j eta_ij * (V h_j)))
    eta_ij = sigma(e_ij') / (sum_{j in N(i)} sigma(e_ij') + eps)

Norm is LayerNorm (BatchNorm in the original; LayerNorm avoids cross-device
batch statistics and is the common JAX adaptation — noted in DESIGN.md).
Graphs are edge lists (src, dst) with -1 padding; message passing is
``gather -> edge MLP -> segment_sum`` over destinations.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.partitioning import Param, constrain
from repro.nn.layers import Dtypes, dense, dense_init, layernorm, layernorm_init

__all__ = ["gatedgcn_layer_init", "gatedgcn_layer", "neighbor_sample"]


def gatedgcn_layer_init(rng, d: int, dt: Dtypes):
    ks = jax.random.split(rng, 5)
    return {
        "A": dense_init(ks[0], d, d, dt),
        "B": dense_init(ks[1], d, d, dt),
        "C": dense_init(ks[2], d, d, dt),
        "U": dense_init(ks[3], d, d, dt),
        "V": dense_init(ks[4], d, d, dt),
        "ln_h": layernorm_init(d, dt),
        "ln_e": layernorm_init(d, dt),
    }


def gatedgcn_layer(
    p,
    h: jnp.ndarray,  # [N, D] node features
    e: jnp.ndarray,  # [E, D] edge features
    src: jnp.ndarray,  # [E] int32 (-1 padding)
    dst: jnp.ndarray,  # [E] int32 (-1 padding)
    dt: Dtypes,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n = h.shape[0]
    valid = (src >= 0) & (dst >= 0)

    from repro.nn.indexing import take_rows

    h_src = take_rows(h, src)
    h_dst = take_rows(h, dst)

    e_new = dense(p["A"], h_dst, dt) + dense(p["B"], h_src, dt) + dense(p["C"], e, dt)
    e_new = constrain(e_new, "edge", None)
    e_out = e + jax.nn.relu(layernorm(p["ln_e"], e_new, dt))

    gate = jax.nn.sigmoid(e_new.astype(jnp.float32))
    gate = jnp.where(valid[:, None], gate, 0.0)
    msg = gate * dense(p["V"], h_src, dt).astype(jnp.float32)

    seg = jnp.where(valid, dst, n)  # padding -> dropped bucket
    agg = jax.ops.segment_sum(msg, seg, num_segments=n + 1)[:n]
    den = jax.ops.segment_sum(gate, seg, num_segments=n + 1)[:n]
    agg = agg / (den + 1e-6)

    h_new = dense(p["U"], h, dt) + agg.astype(dt.compute)
    h_out = h + jax.nn.relu(layernorm(p["ln_h"], h_new, dt))
    h_out = constrain(h_out, "node", None)
    return h_out, e_out


# ---------------------------------------------------------------------------
# Neighbor sampling (host-side, numpy) — required by the minibatch_lg shape.
# ---------------------------------------------------------------------------


def neighbor_sample(
    indptr: np.ndarray,  # CSR [N+1]
    indices: np.ndarray,  # CSR [nnz]
    seeds: np.ndarray,  # [B] seed node ids
    fanouts: Tuple[int, ...],  # e.g. (15, 10)
    rng: np.random.Generator,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Uniform k-hop neighbor sampling -> padded subgraph edge list.

    Returns (nodes [N_sub_max], src, dst, n_seed) where src/dst index into
    ``nodes`` (local ids), padded with -1 to the static worst-case size:
    N_sub_max = B * prod(1+f_i partials); E_max = B*f1 + B*f1*f2 + ...
    Seeds occupy nodes[:B]. Duplicates are kept (standard GraphSAGE practice)
    so shapes stay static.
    """
    b = len(seeds)
    frontier = np.asarray(seeds, dtype=np.int64)
    nodes = [frontier]
    srcs, dsts = [], []
    base = 0  # local offset of current frontier inside `nodes`
    for f in fanouts:
        deg = indptr[frontier + 1] - indptr[frontier]
        # sample f neighbors per frontier node (with replacement; deg==0 -> -1)
        u = rng.integers(0, np.maximum(deg, 1)[:, None], size=(len(frontier), f))
        pos = np.minimum(indptr[frontier][:, None] + u, len(indices) - 1)
        nbr = indices[pos]
        nbr = np.where(deg[:, None] > 0, nbr, -1)
        new_local = np.arange(nbr.size) + sum(len(x) for x in nodes)
        # edges: sampled neighbor (src) -> frontier node (dst)
        dst_local = np.repeat(np.arange(len(frontier)) + base, f)
        src_local = np.where(nbr.reshape(-1) >= 0, new_local, -1)
        srcs.append(src_local)
        dsts.append(np.where(src_local >= 0, dst_local, -1))
        base = sum(len(x) for x in nodes)
        frontier = np.maximum(nbr.reshape(-1), 0)
        nodes.append(frontier)
    all_nodes = np.concatenate(nodes)
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    return all_nodes.astype(np.int64), src, dst, b
