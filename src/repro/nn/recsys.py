"""Recsys interaction layers: FM, DIN target attention, DIEN (AU)GRU, MIND capsules.

All layers take embeddings that upstream code fetched through the paper's
``CachedEmbedding`` tier — the interaction math is cache-agnostic.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.partitioning import Param
from repro.nn.layers import Dtypes, dense, dense_init, mlp, mlp_init

__all__ = [
    "fm_interaction",
    "din_attention_init",
    "din_attention",
    "gru_init",
    "gru",
    "augru",
    "capsule_routing",
]


def fm_interaction(v: jnp.ndarray, use_pallas: bool = False) -> jnp.ndarray:
    """2-way FM pooling via the O(nk) sum-square trick (Rendle ICDM'10).

    v: [..., fields, dim] (embedding * feature value already folded in).
    Returns [...]: sum_{i<j} <v_i, v_j>.
    """
    if use_pallas:
        from repro.kernels.fm_interaction import ops as fm_ops

        return fm_ops.fm_interaction(v)
    s = v.sum(axis=-2)  # [..., dim]
    sq = (v * v).sum(axis=-2)
    return 0.5 * (s * s - sq).sum(axis=-1)


# ---------------------------------------------------------------------------
# DIN: target attention over user behaviour history (arXiv:1706.06978)
# ---------------------------------------------------------------------------


def din_attention_init(rng, dim: int, attn_units: Tuple[int, ...], dt: Dtypes):
    # input: [hist, target, hist-target, hist*target] -> 4*dim
    return mlp_init(rng, (4 * dim,) + tuple(attn_units) + (1,), dt)


def din_attention(
    p,
    hist: jnp.ndarray,  # [B, T, D] behaviour embeddings
    target: jnp.ndarray,  # [B, D] candidate item embedding
    mask: jnp.ndarray,  # [B, T] bool valid positions
    dt: Dtypes,
) -> jnp.ndarray:
    """Weighted-sum pooling with MLP-scored target attention -> [B, D]."""
    t = hist.shape[1]
    tgt = jnp.broadcast_to(target[:, None, :], hist.shape)
    feats = jnp.concatenate([hist, tgt, hist - tgt, hist * tgt], axis=-1)
    scores = mlp(p, feats, dt, act=jax.nn.sigmoid)[..., 0]  # [B, T]
    scores = jnp.where(mask, scores, -1e30)
    # DIN uses un-normalized sigmoid-ish weights in the paper; softmax variant is
    # the common open-source choice and is numerically safer.
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(mask, w, 0.0)
    return jnp.einsum("bt,btd->bd", w, hist)


# ---------------------------------------------------------------------------
# DIEN: GRU interest extraction + AUGRU interest evolution (arXiv:1809.03672)
# ---------------------------------------------------------------------------


def gru_init(rng, d_in: int, d_h: int, dt: Dtypes):
    k1, k2, k3 = jax.random.split(rng, 3)
    s = 1.0 / jnp.sqrt(jnp.asarray(d_h, jnp.float32))
    def m(k, i, o):
        return jax.random.uniform(k, (i, o), dt.param, -s, s)
    return {
        "wx": Param(m(k1, d_in, 3 * d_h), (None, None)),  # update/reset/cand
        "wh": Param(m(k2, d_h, 3 * d_h), (None, None)),
        "b": Param(jnp.zeros((3 * d_h,), dt.param), (None,)),
    }


def _gru_cell(p, h, x, att: Optional[jnp.ndarray], dt: Dtypes):
    d_h = h.shape[-1]
    gates = x.astype(dt.compute) @ p["wx"].astype(dt.compute) + h @ p["wh"].astype(dt.compute) + p[
        "b"
    ].astype(dt.compute)
    u = jax.nn.sigmoid(gates[..., :d_h])
    r = jax.nn.sigmoid(gates[..., d_h : 2 * d_h])
    # candidate uses reset-scaled h: recompute its slice with r*h
    cand = jnp.tanh(
        x.astype(dt.compute) @ p["wx"].astype(dt.compute)[:, 2 * d_h :]
        + (r * h) @ p["wh"].astype(dt.compute)[:, 2 * d_h :]
        + p["b"].astype(dt.compute)[2 * d_h :]
    )
    if att is not None:  # AUGRU: attention scales the update gate
        u = u * att[..., None]
    return (1.0 - u) * h + u * cand


def gru(p, xs: jnp.ndarray, dt: Dtypes, att: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """xs [B, T, D] -> hidden states [B, T, H]; ``att`` [B, T] turns it into AUGRU."""
    b, t, _ = xs.shape
    d_h = p["wh"].shape[0]
    h0 = jnp.zeros((b, d_h), dt.compute)

    def step(h, inp):
        x, a = inp
        h = _gru_cell(p, h, x, a, dt)
        return h, h

    att_seq = att.T if att is not None else jnp.ones((t, b), dt.compute)
    _, hs = jax.lax.scan(step, h0, (xs.transpose(1, 0, 2), att_seq))
    return hs.transpose(1, 0, 2)


def augru(p, xs, att, dt: Dtypes) -> jnp.ndarray:
    return gru(p, xs, dt, att=att)


# ---------------------------------------------------------------------------
# MIND: behaviour-to-interest dynamic (capsule) routing (arXiv:1904.08030)
# ---------------------------------------------------------------------------


def capsule_routing(
    hist: jnp.ndarray,  # [B, T, D] behaviour capsules
    mask: jnp.ndarray,  # [B, T]
    s_matrix: jnp.ndarray,  # [D, D] shared bilinear map
    n_interests: int,
    iters: int = 3,
    routing_init: Optional[jnp.ndarray] = None,  # [B, K, T] fixed random logits
) -> jnp.ndarray:
    """B2I dynamic routing -> interest capsules [B, K, D].

    MIND initializes routing logits randomly and keeps them fixed w.r.t.
    gradient (stop_gradient inside the loop, per the paper).
    """
    b, t, d = hist.shape
    u = jnp.einsum("btd,de->bte", hist, s_matrix)  # mapped behaviours
    if routing_init is None:
        routing_init = jnp.zeros((b, n_interests, t), u.dtype)
    logits = routing_init

    def squash(v):
        n2 = jnp.sum(v * v, axis=-1, keepdims=True)
        return (n2 / (1.0 + n2)) * v * jax.lax.rsqrt(n2 + 1e-9)

    caps = jnp.zeros((b, n_interests, d), u.dtype)
    for _ in range(iters):
        w = jax.nn.softmax(jnp.where(mask[:, None, :], logits, -1e30), axis=-1)
        caps = squash(jnp.einsum("bkt,btd->bkd", w, u))
        logits = logits + jnp.einsum("bkd,btd->bkt", jax.lax.stop_gradient(caps), u)
    return caps
