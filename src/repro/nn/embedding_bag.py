"""EmbeddingBag for JAX: ragged gather + segment-reduce (no torch analogue).

This is the *uncached* embedding path (used as the oracle/baseline and for
tables small enough to live wholly in HBM).  The cached path is
``repro.core.cached_embedding``; both share this module's bag semantics.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["embedding_bag", "one_hot_lookup"]


def one_hot_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """ids [..] -> [.., dim]; negative ids give zero rows."""
    from repro.nn.indexing import take_rows

    return take_rows(table, ids)


def embedding_bag(
    table: jnp.ndarray,  # [vocab, dim]
    flat_ids: jnp.ndarray,  # [N] (negative = padding)
    segment_ids: jnp.ndarray,  # [N] bag index per id, non-decreasing not required
    num_segments: int,
    combiner: str = "sum",
    weights: Optional[jnp.ndarray] = None,  # [N] per-sample weights
    use_pallas: bool = False,
) -> jnp.ndarray:
    """torch.nn.EmbeddingBag(sum|mean|max) built from gather + segment ops."""
    if use_pallas and combiner in ("sum", "mean") and weights is None:
        from repro.kernels.embedding_bag import ops as eb_ops

        return eb_ops.embedding_bag(table, flat_ids, segment_ids, num_segments, combiner)

    from repro.nn.indexing import take_rows

    rows = take_rows(table, flat_ids)
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    valid = flat_ids >= 0
    if combiner == "max":
        rows = jnp.where(valid[:, None], rows, -jnp.inf)
        out = jax.ops.segment_max(rows, segment_ids, num_segments=num_segments)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if combiner == "mean":
        cnt = jax.ops.segment_sum(valid.astype(out.dtype), segment_ids, num_segments=num_segments)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out
