"""FFN blocks: gated dense (SwiGLU) and top-k Mixture-of-Experts.

MoE dispatch is sort-based with a fixed capacity per expert (GShard-style):
tokens are ordered by assigned expert, positioned by a running offset, and
scattered into an [E, capacity, D] buffer; overflow tokens are dropped
(weighted combine makes the drop graceful).

``dp_groups`` (the §Perf lever for the MoE cells): with G=1 the dispatch is
GLOBAL — capacity counts every token in the batch and the buffer is a single
[E, T*k*cf/E, D] array, which at pod scale is terabytes and forces SPMD to
replicate/reduce it (the naive baseline).  With G = data-axis size, dispatch
is LOCAL to each batch shard: the buffer becomes [G, E, T/G*k*cf/E, D] with G
sharded over "data", so each device builds and computes only its own shard's
expert slots — the production layout (cf. MaxText/GShard).  Semantics change
only in where capacity overflow drops happen (per-shard instead of global).

Sharding regimes (DESIGN.md):
  * EP  (experts >= model-axis size, small d_ff — olmoe):  expert dim sharded
    over "experts" -> all-to-all dispatch on the model axis.
  * in-expert TP (few big experts — grok): d_ff sharded over "expert_mlp",
    expert dim replicated -> no all-to-all, dense-TP collective pattern.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.dist.partitioning import Param, constrain
from repro.nn.layers import Dtypes

__all__ = ["ffn_init", "ffn_apply", "moe_init", "moe_apply", "moe_capacity"]


def ffn_init(rng, d, ff, dt: Dtypes):
    kg, ku, kd = jax.random.split(rng, 3)
    s_in = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s_ff = 1.0 / jnp.sqrt(ff).astype(jnp.float32)
    return {
        "gate": Param(jax.random.normal(kg, (d, ff), dt.param) * s_in, ("embed", "mlp")),
        "up": Param(jax.random.normal(ku, (d, ff), dt.param) * s_in, ("embed", "mlp")),
        "down": Param(jax.random.normal(kd, (ff, d), dt.param) * s_ff, ("mlp", "embed")),
    }


def ffn_apply(p, x, dt: Dtypes):
    xc = x.astype(dt.compute)
    h = jax.nn.silu(xc @ p["gate"].astype(dt.compute)) * (xc @ p["up"].astype(dt.compute))
    h = constrain(h, "batch", "seq", "mlp")
    return h @ p["down"].astype(dt.compute)


def moe_capacity(n_tokens: int, n_experts: int, top_k: int, capacity_factor: float) -> int:
    cap = int(n_tokens * top_k * capacity_factor / n_experts)
    return max(8, -(-cap // 8) * 8)  # round up to 8


def moe_init(rng, d, ff, n_experts, dt: Dtypes):
    kr, kg, ku, kd = jax.random.split(rng, 4)
    s_in = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s_ff = 1.0 / jnp.sqrt(ff).astype(jnp.float32)
    return {
        "router": Param(jax.random.normal(kr, (d, n_experts), dt.param) * s_in, ("embed", None)),
        "gate": Param(
            jax.random.normal(kg, (n_experts, d, ff), dt.param) * s_in, ("experts", "embed", "expert_mlp")
        ),
        "up": Param(
            jax.random.normal(ku, (n_experts, d, ff), dt.param) * s_in, ("experts", "embed", "expert_mlp")
        ),
        "down": Param(
            jax.random.normal(kd, (n_experts, ff, d), dt.param) * s_ff, ("experts", "expert_mlp", "embed")
        ),
    }


def moe_apply(
    p,
    x: jnp.ndarray,  # [B, S, D]
    dt: Dtypes,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    dp_groups: int = 1,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output [B,S,D], aux load-balancing loss)."""
    b, s, d = x.shape
    e = p["router"].shape[-1]
    t = b * s
    g = max(1, dp_groups)
    assert t % g == 0, "tokens must divide dp_groups"
    tl = t // g  # tokens per dispatch group
    cap = moe_capacity(tl, e, top_k, capacity_factor)

    xt = x.reshape(g, tl, d).astype(dt.compute)
    xt = constrain(xt, "exp_dp", None, None)
    logits = jnp.einsum("gtd,de->gte", xt, p["router"].astype(dt.compute)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # [G, Tl, K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # Switch-style aux loss (per group, then averaged)
    one = jnp.zeros((g, e), jnp.float32)
    gidx = jnp.repeat(jnp.arange(g), tl * top_k)
    one = one.at[gidx, expert_idx.reshape(-1)].add(1.0) / (tl * top_k)
    aux = e * jnp.mean(jnp.sum(probs.mean(1) * one, axis=-1))

    # --- sort-based dispatch, independent per group --------------------------
    flat_e = expert_idx.reshape(g, tl * top_k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)  # [G, Tl*K]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e), side="left"))(sorted_e)
    pos_in_e = jnp.arange(tl * top_k)[None, :] - jnp.take_along_axis(starts, sorted_e, axis=-1)
    keep = pos_in_e < cap
    # linearized slot into a [G*E*cap] buffer (drop on overflow)
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)
    gofs = (jnp.arange(g) * (e * cap))[:, None]
    flat_slot = jnp.where(keep, slot + gofs, g * e * cap).reshape(-1)

    src_token = order // top_k + (jnp.arange(g) * tl)[:, None]  # global token idx
    xt_flat = xt.reshape(t, d)
    buf = jnp.zeros((g * e * cap, d), dt.compute).at[flat_slot].set(
        xt_flat[src_token.reshape(-1)], mode="drop"
    )
    buf = buf.reshape(g, e, cap, d)
    # "exp_dp" -> data shards the dispatch group axis; "experts" -> model (EP)
    buf = constrain(buf, "exp_dp", "experts", None, None)

    # --- expert FFN (batched over groups x experts) ---------------------------
    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", buf, p["gate"].astype(dt.compute))
    ) * jnp.einsum("gecd,edf->gecf", buf, p["up"].astype(dt.compute))
    h = constrain(h, "exp_dp", "experts", None, "expert_mlp")
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["down"].astype(dt.compute))
    out_buf = constrain(out_buf, "exp_dp", "experts", None, None)
    out_buf = out_buf.reshape(g * e * cap, d)

    # --- weighted combine -----------------------------------------------------
    gathered = jnp.take(out_buf, jnp.where(keep, slot + gofs, g * e * cap).reshape(-1),
                        axis=0, mode="fill", fill_value=0)  # [G*Tl*K, D]
    w = jnp.take_along_axis(gate_vals.reshape(g, tl * top_k), order, axis=-1)
    contrib = gathered * w.reshape(-1)[:, None].astype(dt.compute)
    out = jnp.zeros((t, d), dt.compute).at[src_token.reshape(-1)].add(contrib)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# shard_map MoE (§Perf olmoe it4 / grok_train it2): dispatch stays local to
# each device; the ONLY communication is one psum of the [T_local, D] output
# over the model axis per MoE layer.  Exploits x being replicated over the
# model axis (it is — activations are constrained (batch, seq, None)):
#   * EP   (E % n_model == 0): each model rank keeps its E/n experts, selects
#     the local-expert (token, k) pairs from the replicated routing, runs its
#     expert FFNs, scatters partial outputs, psums.
#   * in-expert TP (ff % n_model == 0): every rank runs ALL experts on a ff/n
#     slice; the down-projection contraction is completed by the same psum.
# ---------------------------------------------------------------------------


def moe_apply_shard_map(
    p,
    x: jnp.ndarray,  # [B, S, D]
    dt: Dtypes,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    data_axes: tuple = ("data",),
    model_axis: str = "model",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.partitioning import current_mesh

    mesh = current_mesh()
    assert mesh is not None, "moe_apply_shard_map needs an active mesh"
    n_model = mesh.shape[model_axis]
    b, s, d = x.shape
    e = p["router"].shape[-1]
    t = b * s
    xt = x.reshape(t, d).astype(dt.compute)

    # routing is cheap: compute it replicated, outside the shard_map
    logits = (xt @ p["router"].astype(dt.compute)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    gate_vals = (gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)).astype(dt.compute)
    frac = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (t * top_k)
    aux = e * jnp.sum(probs.mean(0) * frac)

    ep = e % n_model == 0
    ff = p["gate"].shape[-1]
    if not ep:
        assert ff % n_model == 0, "neither experts nor d_ff divide the model axis"

    dp = P(data_axes)
    wspec = P(model_axis, None, None) if ep else P(None, None, model_axis)
    wspec_down = P(model_axis, None, None) if ep else P(None, model_axis, None)

    def block(xt_l, gv_l, idx_l, gate_w, up_w, down_w):
        tl = xt_l.shape[0]
        e_l = gate_w.shape[0]
        r = jax.lax.axis_index(model_axis)
        flat_e = idx_l.reshape(-1)
        w = gv_l.reshape(-1)
        if ep:
            lo = r * e_l
            local = (flat_e >= lo) & (flat_e < lo + e_l)
            le = jnp.where(local, flat_e - lo, e_l)  # e_l == drop bucket
        else:
            local = jnp.ones_like(flat_e, bool)
            le = flat_e
        cap = moe_capacity(tl, e, top_k, capacity_factor)
        order = jnp.argsort(jnp.where(local, le, e_l), stable=True)
        se = jnp.where(local, le, e_l)[order]
        starts = jnp.searchsorted(se, jnp.arange(e_l), side="left")
        pos = jnp.arange(tl * top_k) - starts[jnp.minimum(se, e_l - 1)]
        keep = (se < e_l) & (pos < cap)
        slot = jnp.where(keep, se * cap + pos, e_l * cap)
        src = order // top_k
        buf = jnp.zeros((e_l * cap, xt_l.shape[1]), xt_l.dtype).at[slot].set(
            xt_l[src], mode="drop"
        ).reshape(e_l, cap, -1)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, gate_w)) * jnp.einsum(
            "ecd,edf->ecf", buf, up_w
        )
        outb = jnp.einsum("ecf,efd->ecd", h, down_w).reshape(e_l * cap, -1)
        gathered = jnp.take(outb, slot, axis=0, mode="fill", fill_value=0)
        contrib = gathered * w[order][:, None]
        out = jnp.zeros_like(xt_l).at[src].add(jnp.where(keep[:, None], contrib, 0))
        return jax.lax.psum(out, model_axis)

    fn = shard_map(
        block,
        mesh=mesh,
        in_specs=(dp, dp, dp, wspec, wspec, wspec_down),
        out_specs=dp,
        check_vma=False,
    )
    out = fn(
        xt, gate_vals, expert_idx,
        p["gate"].astype(dt.compute), p["up"].astype(dt.compute),
        p["down"].astype(dt.compute),
    )
    return out.reshape(b, s, d), aux
