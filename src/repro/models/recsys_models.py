"""Assigned recsys archs — DIN, DIEN, FM, MIND — sparse tables served through
the planner-driven ``EmbeddingCollection`` (keyed ``FeatureBatch`` in, keyed
embedding rows out).

Every model declares logical tables (items / cates / users / per-field) and
which features hit them; the default plan GROUPs all tables into one shared
cache arena — the paper's concatenated-table layout — while tests and
deployments may pass a ``PlacementPlanner`` budget to promote small tables
to DEVICE residency.

Shared batch schema (synthetic Amazon/Taobao/Criteo-like):
  DIN/DIEN: hist_items [B,T], hist_cates [B,T], hist_len [B], target_item [B],
            target_cate [B], user [B], label [B]
  MIND:     hist_items [B,T], hist_len [B], target_item [B], label [B]
  FM:       sparse [B, 39], label [B]

``retrieval_score`` (the retrieval_cand shape) scores one user against 10^6
candidates as a batched matmul against the *full* (slow-tier) table via
``collection.full_lookup`` — bulk scoring bypasses the cache bookkeeping by
design (the cache accelerates the per-request user-side lookups; candidate
scans read the authoritative tier).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collection as col
from repro.dist.partitioning import Param, constrain, split_params
from repro.models import common
from repro.nn import recsys as R
from repro.nn.layers import Dtypes, mlp, mlp_init
from repro.optim import optimizers as opt_lib

__all__ = ["FMConfig", "FMModel", "DINConfig", "DINModel", "DIENConfig", "DIENModel", "MINDConfig", "MINDModel"]

F32 = Dtypes(param=jnp.float32, compute=jnp.float32)


# ===========================================================================
# FM (Rendle ICDM'10): 39 sparse fields, embed_dim 10, 2-way interactions.
# Table payload is dim+1: columns [0:dim] factors, [dim] the linear weight —
# one cache tier moves both together.
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class FMConfig:
    vocab_sizes: Tuple[int, ...]  # 39 fields
    embed_dim: int = 10
    batch_size: int = 65536
    cache_ratio: float = 0.015
    max_unique_per_step: int = 0
    lr: float = 0.05
    use_pallas: bool = False
    emb_dtype: Any = jnp.float32
    protect_via_inverse: bool = True
    buffer_rows: int = 65536
    host_precision: str = "fp32"  # host-tier codec (see repro.store)
    arena_precision: str = "fp32"  # device-arena tail codec (see repro.store)
    arena_head_ratio: float = 0.25  # fp32 head share of a tiered arena
    use_pallas_plan: bool = False  # bounded-top-K fused cache planning
    chunk_rows: int = 0  # chunk-granularity host staging
    policy: Any = None  # core.Policy eviction policy; None -> FREQ_LFU


class FMModel(common.CollectionModelMixin):
    def __init__(self, cfg: FMConfig):
        self.cfg = cfg
        self.optimizer = opt_lib.sgd(cfg.lr)
        self.feature_names = tuple(f"f{i}" for i in range(len(cfg.vocab_sizes)))
        tables = [
            col.TableConfig(
                name=n,
                vocab=v,
                dim=cfg.embed_dim + 1,
                ids_per_step=cfg.batch_size,
                dtype=cfg.emb_dtype,
            )
            for n, v in zip(self.feature_names, cfg.vocab_sizes)
        ]
        self.collection = col.EmbeddingCollection.create(
            tables,
            cache_ratio=cfg.cache_ratio,
            max_unique_per_step=cfg.max_unique_per_step,
            protect_via_inverse=cfg.protect_via_inverse,
            buffer_rows=cfg.buffer_rows,
            host_precision=cfg.host_precision,
            arena_precision=cfg.arena_precision,
            arena_head_ratio=cfg.arena_head_ratio,
            use_pallas_plan=cfg.use_pallas_plan,
            chunk_rows=cfg.chunk_rows,
            policy=cfg.policy or col.Policy.FREQ_LFU,
        )

    def init(self, rng, counts: Optional[np.ndarray] = None):
        k_emb, k_b = jax.random.split(rng)
        params = {"bias": jnp.zeros((), jnp.float32)}
        counts_by_table = (
            self.collection.split_concat_counts(np.asarray(counts)) if counts is not None else None
        )
        emb = self.collection.init(k_emb, counts=counts_by_table)
        return {"params": params, "opt": self.optimizer.init(params), "emb": emb,
                "step": jnp.zeros((), jnp.int32)}

    def features(self, batch) -> col.FeatureBatch:
        names = self.feature_names[: batch["sparse"].shape[1]]
        return col.FeatureBatch.from_onehot(names, batch["sparse"])

    def flush(self, state):
        return common.flush_embeddings(self.collection, state)

    def fwd(self, params, rows: Dict[str, jnp.ndarray], batch):
        c = self.cfg
        names = self.feature_names[: batch["sparse"].shape[1]]
        stacked = jnp.stack([rows[n] for n in names], axis=1)  # [B, F, D+1]
        v, w = stacked[..., : c.embed_dim], stacked[..., c.embed_dim]
        logits = params["bias"] + w.sum(-1) + R.fm_interaction(v, use_pallas=c.use_pallas)
        return logits, {}

    # train_step + split pipeline stages come from CollectionModelMixin

    def serve_step(self, state, batch):
        emb_state, _, rows = self.collection.lookup(
            state["emb"], self.features(batch), writeback=False
        )
        logits, _ = self.fwd(state["params"], rows, batch)
        return logits, emb_state

    def retrieval_score(self, state, batch):
        """1 user's 38 context fields vs n_cand candidates in field 38."""
        c = self.cfg
        ctx = batch["sparse"]  # [1, 38] fields 0..37
        cands = batch["candidates"]  # [n_cand] local ids of field 38
        # user-side context rows via the cache tier (read-only)
        emb_state, _, rows = self.collection.lookup(
            state["emb"], self.features(batch), writeback=False
        )
        ctx_rows = jnp.stack(
            [rows[n][0] for n in self.feature_names[: ctx.shape[1]]], axis=0
        )  # [38, D+1]
        vc, wc = ctx_rows[:, : c.embed_dim], ctx_rows[:, c.embed_dim]
        # candidate rows: bulk scan of the slow tier (batched gather+dot, no loop)
        cand_rows = self.collection.full_lookup(emb_state, self.feature_names[-1], cands)
        vk, wk = cand_rows[:, : c.embed_dim], cand_rows[:, c.embed_dim]
        # FM score restricted to terms involving the candidate + context-only terms
        s_ctx = vc.sum(0)  # [D]
        ctx_pair = 0.5 * ((s_ctx * s_ctx).sum() - (vc * vc).sum())
        scores = state["params"]["bias"] + wc.sum() + ctx_pair + wk + vk @ s_ctx
        return scores, emb_state

    def input_specs(self, batch_size: int, n_candidates: int = 0):
        c = self.cfg
        if n_candidates:
            return {
                "sparse": jax.ShapeDtypeStruct((1, len(c.vocab_sizes) - 1), jnp.int32),
                "candidates": jax.ShapeDtypeStruct((n_candidates,), jnp.int32),
            }
        return {
            "sparse": jax.ShapeDtypeStruct((batch_size, len(c.vocab_sizes)), jnp.int32),
            "label": jax.ShapeDtypeStruct((batch_size,), jnp.float32),
        }


# ===========================================================================
# DIN (arXiv:1706.06978): target attention over behaviour history.
# Tables: items, categories, users (embed_dim 18 each) — hist and target
# features share the item/cate tables through the keyed-feature map.
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class DINConfig:
    n_items: int = 10_000_000
    n_cates: int = 1_000_000
    n_users: int = 1_000_000
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: Tuple[int, ...] = (80, 40)
    mlp: Tuple[int, ...] = (200, 80)
    batch_size: int = 65536
    cache_ratio: float = 0.015
    max_unique_per_step: int = 0
    lr: float = 0.05
    dtypes: Dtypes = F32
    host_precision: str = "fp32"  # host-tier codec (see repro.store)
    arena_precision: str = "fp32"  # device-arena tail codec (see repro.store)
    arena_head_ratio: float = 0.25  # fp32 head share of a tiered arena
    use_pallas_plan: bool = False  # bounded-top-K fused cache planning
    chunk_rows: int = 0  # chunk-granularity host staging
    policy: Any = None  # core.Policy eviction policy; None -> FREQ_LFU


class DINModel(common.CollectionModelMixin):
    def __init__(self, cfg: DINConfig):
        self.cfg = cfg
        self.optimizer = opt_lib.sgd(cfg.lr)
        b, t = cfg.batch_size, cfg.seq_len
        tables = [
            col.TableConfig("items", cfg.n_items, cfg.embed_dim, b * (t + 1),
                            feature_names=("hist_items", "target_item")),
            col.TableConfig("cates", cfg.n_cates, cfg.embed_dim, b * (t + 1),
                            feature_names=("hist_cates", "target_cate")),
            col.TableConfig("users", cfg.n_users, cfg.embed_dim, b,
                            feature_names=("user",)),
        ]
        self.collection = col.EmbeddingCollection.create(
            tables,
            cache_ratio=cfg.cache_ratio,
            max_unique_per_step=cfg.max_unique_per_step,
            host_precision=cfg.host_precision,
            arena_precision=cfg.arena_precision,
            arena_head_ratio=cfg.arena_head_ratio,
            use_pallas_plan=cfg.use_pallas_plan,
            chunk_rows=cfg.chunk_rows,
            policy=cfg.policy or col.Policy.FREQ_LFU,
        )

    @property
    def vocab_sizes(self):
        c = self.cfg
        return (c.n_items, c.n_cates, c.n_users)

    def init(self, rng, counts: Optional[np.ndarray] = None):
        c = self.cfg
        k_emb, k_attn, k_mlp = jax.random.split(rng, 3)
        d = c.embed_dim
        params, _ = split_params(
            {
                "attn": R.din_attention_init(k_attn, 2 * d, c.attn_mlp, c.dtypes),
                "mlp": mlp_init(k_mlp, (d + 2 * (2 * d),) + c.mlp + (1,), c.dtypes),
            }
        )
        counts_by_table = (
            self.collection.split_concat_counts(np.asarray(counts)) if counts is not None else None
        )
        emb = self.collection.init(k_emb, counts=counts_by_table)
        return {"params": params, "opt": self.optimizer.init(params), "emb": emb,
                "step": jnp.zeros((), jnp.int32)}

    def features(self, batch) -> col.FeatureBatch:
        t = self.cfg.seq_len
        hist_mask = jnp.arange(t)[None, :] < batch["hist_len"][:, None]
        ids = {
            "hist_items": jnp.where(hist_mask, batch["hist_items"], -1),
            "hist_cates": jnp.where(hist_mask, batch["hist_cates"], -1),
            "target_item": batch["target_item"],
            "target_cate": batch["target_cate"],
            "user": batch["user"],
        }
        return col.FeatureBatch(ids={k: v.astype(jnp.int32) for k, v in ids.items()})

    def flush(self, state):
        return common.flush_embeddings(self.collection, state)

    def fwd(self, params, rows: Dict[str, jnp.ndarray], batch):
        c = self.cfg
        t = c.seq_len
        hist = jnp.concatenate([rows["hist_items"], rows["hist_cates"]], axis=-1)  # [B,T,2D]
        target = jnp.concatenate([rows["target_item"], rows["target_cate"]], axis=-1)  # [B,2D]
        user = rows["user"]
        mask = jnp.arange(t)[None, :] < batch["hist_len"][:, None]
        pooled = R.din_attention(params["attn"], hist, target, mask, c.dtypes)  # [B,2D]
        x = jnp.concatenate([user, pooled, target], axis=-1)
        x = constrain(x, "batch", None)
        logits = mlp(params["mlp"], x, c.dtypes)[:, 0]
        return logits, {}

    # train_step + split pipeline stages come from CollectionModelMixin

    def serve_step(self, state, batch):
        emb_state, _, rows = self.collection.lookup(
            state["emb"], self.features(batch), writeback=False
        )
        logits, _ = self.fwd(state["params"], rows, batch)
        return logits, emb_state

    def retrieval_score(self, state, batch):
        """One user history vs n_cand candidate items (shared-user batched dot)."""
        c = self.cfg
        b1 = {k: v for k, v in batch.items() if k not in ("candidates", "candidate_cates")}
        b1.setdefault("target_item", jnp.zeros((1,), jnp.int32))
        b1.setdefault("target_cate", jnp.zeros((1,), jnp.int32))
        emb_state, _, rows = self.collection.lookup(
            state["emb"], self.features(b1), writeback=False
        )
        d, t = c.embed_dim, c.seq_len
        hist = jnp.concatenate([rows["hist_items"], rows["hist_cates"]], axis=-1)  # [1,T,2D]
        user = rows["user"]  # [1,D]
        mask = jnp.arange(t)[None, :] < batch["hist_len"][:, None]

        ti = self.collection.full_lookup(emb_state, "items", batch["candidates"])
        tc = self.collection.full_lookup(emb_state, "cates", batch["candidate_cates"])
        targets = jnp.concatenate([ti, tc], axis=-1)  # [n_cand, 2D]

        n = batch["candidates"].shape[0]
        histb = jnp.broadcast_to(hist, (n,) + hist.shape[1:])
        maskb = jnp.broadcast_to(mask, (n, t))
        pooled = R.din_attention(state["params"]["attn"], histb, targets, maskb, c.dtypes)
        userb = jnp.broadcast_to(user, (n, d))
        x = jnp.concatenate([userb, pooled, targets], axis=-1)
        scores = mlp(state["params"]["mlp"], x, c.dtypes)[:, 0]
        return scores, emb_state

    def input_specs(self, batch_size: int, n_candidates: int = 0):
        c = self.cfg
        base = {
            "hist_items": jax.ShapeDtypeStruct((batch_size, c.seq_len), jnp.int32),
            "hist_cates": jax.ShapeDtypeStruct((batch_size, c.seq_len), jnp.int32),
            "hist_len": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
            "target_item": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
            "target_cate": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
            "user": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
        }
        if n_candidates:
            base.pop("target_item"), base.pop("target_cate")
            base["candidates"] = jax.ShapeDtypeStruct((n_candidates,), jnp.int32)
            base["candidate_cates"] = jax.ShapeDtypeStruct((n_candidates,), jnp.int32)
            return base
        base["label"] = jax.ShapeDtypeStruct((batch_size,), jnp.float32)
        return base


# ===========================================================================
# DIEN (arXiv:1809.03672): GRU interest extraction + AUGRU evolution.
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class DIENConfig(DINConfig):
    gru_dim: int = 108


class DIENModel(DINModel):
    def __init__(self, cfg: DIENConfig):
        super().__init__(cfg)

    def init(self, rng, counts: Optional[np.ndarray] = None):
        c: DIENConfig = self.cfg  # type: ignore[assignment]
        k_emb, k_g1, k_g2, k_attn, k_mlp = jax.random.split(rng, 5)
        d = c.embed_dim
        params, _ = split_params(
            {
                "gru1": R.gru_init(k_g1, 2 * d, c.gru_dim, c.dtypes),
                "gru2": R.gru_init(k_g2, c.gru_dim, c.gru_dim, c.dtypes),
                "attn_proj": {
                    "w": Param(
                        jax.random.normal(k_attn, (2 * d, c.gru_dim), c.dtypes.param)
                        * (1.0 / np.sqrt(2 * d)),
                        (None, None),
                    )
                },
                "mlp": mlp_init(k_mlp, (d + 2 * d + c.gru_dim,) + c.mlp + (1,), c.dtypes),
            }
        )
        counts_by_table = (
            self.collection.split_concat_counts(np.asarray(counts)) if counts is not None else None
        )
        emb = self.collection.init(k_emb, counts=counts_by_table)
        return {"params": params, "opt": self.optimizer.init(params), "emb": emb,
                "step": jnp.zeros((), jnp.int32)}

    def fwd(self, params, rows: Dict[str, jnp.ndarray], batch):
        c: DIENConfig = self.cfg  # type: ignore[assignment]
        t = c.seq_len
        hist = jnp.concatenate([rows["hist_items"], rows["hist_cates"]], axis=-1)
        target = jnp.concatenate([rows["target_item"], rows["target_cate"]], axis=-1)
        user = rows["user"]
        mask = jnp.arange(t)[None, :] < batch["hist_len"][:, None]

        interest = R.gru(params["gru1"], hist, c.dtypes)  # [B,T,H]
        # attention of target on interest states
        tq = target @ params["attn_proj"]["w"].astype(c.dtypes.compute)  # [B,H]
        att = jnp.einsum("bh,bth->bt", tq, interest) / np.sqrt(c.gru_dim)
        att = jax.nn.softmax(jnp.where(mask, att, -1e30), axis=-1)
        att = jnp.where(mask, att, 0.0)
        final = R.augru(params["gru2"], interest, att, c.dtypes)[:, -1]  # [B,H]
        x = jnp.concatenate([user, target, final], axis=-1)
        logits = mlp(params["mlp"], x, c.dtypes)[:, 0]
        return logits, {}

    def retrieval_score(self, state, batch):
        """Bulk candidate scoring for DIEN.

        Serving-path adaptation (DESIGN.md): GRU1 interest extraction runs
        once (target-independent); candidates are scored by target attention
        over the interest states (the AUGRU evolution stage is skipped — a
        full per-candidate AUGRU over 10^6 candidates is a ranking-stage
        cost, not a retrieval-stage one).
        """
        c: DIENConfig = self.cfg  # type: ignore[assignment]
        t = c.seq_len
        b1 = {k: v for k, v in batch.items() if k not in ("candidates", "candidate_cates")}
        b1.setdefault("target_item", jnp.zeros((1,), jnp.int32))
        b1.setdefault("target_cate", jnp.zeros((1,), jnp.int32))
        emb_state, _, rows = self.collection.lookup(
            state["emb"], self.features(b1), writeback=False
        )
        hist = jnp.concatenate([rows["hist_items"], rows["hist_cates"]], axis=-1)
        mask = jnp.arange(t)[None, :] < batch["hist_len"][:, None]
        interest = R.gru(state["params"]["gru1"], hist, c.dtypes)[0]  # [T,H]

        ti = self.collection.full_lookup(emb_state, "items", batch["candidates"])
        tc = self.collection.full_lookup(emb_state, "cates", batch["candidate_cates"])
        targets = jnp.concatenate([ti, tc], axis=-1)  # [N, 2D]
        tq = targets @ state["params"]["attn_proj"]["w"].astype(c.dtypes.compute)  # [N,H]
        att = (tq @ interest.T) / np.sqrt(c.gru_dim)  # [N,T]
        att = jax.nn.softmax(jnp.where(mask[0][None, :], att, -1e30), axis=-1)
        pooled = att @ interest  # [N,H]
        scores = jnp.einsum("nh,nh->n", tq, pooled)
        return scores, emb_state


# ===========================================================================
# MIND (arXiv:1904.08030): multi-interest capsule routing.
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    n_items: int = 4_000_000
    n_users: int = 1_000_000
    embed_dim: int = 64
    seq_len: int = 100
    n_interests: int = 4
    capsule_iters: int = 3
    batch_size: int = 65536
    cache_ratio: float = 0.015
    max_unique_per_step: int = 0
    label_pow: float = 2.0  # label-aware attention sharpness
    lr: float = 0.05
    dtypes: Dtypes = F32
    host_precision: str = "fp32"  # host-tier codec (see repro.store)
    arena_precision: str = "fp32"  # device-arena tail codec (see repro.store)
    arena_head_ratio: float = 0.25  # fp32 head share of a tiered arena
    use_pallas_plan: bool = False  # bounded-top-K fused cache planning
    chunk_rows: int = 0  # chunk-granularity host staging
    policy: Any = None  # core.Policy eviction policy; None -> FREQ_LFU


class MINDModel(common.CollectionModelMixin):
    def __init__(self, cfg: MINDConfig):
        self.cfg = cfg
        self.optimizer = opt_lib.sgd(cfg.lr)
        b, t = cfg.batch_size, cfg.seq_len
        tables = [
            col.TableConfig("items", cfg.n_items, cfg.embed_dim, b * (t + 1),
                            feature_names=("hist_items", "target_item")),
            col.TableConfig("users", cfg.n_users, cfg.embed_dim, b,
                            feature_names=("user",)),
        ]
        self.collection = col.EmbeddingCollection.create(
            tables,
            cache_ratio=cfg.cache_ratio,
            max_unique_per_step=cfg.max_unique_per_step,
            host_precision=cfg.host_precision,
            arena_precision=cfg.arena_precision,
            arena_head_ratio=cfg.arena_head_ratio,
            use_pallas_plan=cfg.use_pallas_plan,
            chunk_rows=cfg.chunk_rows,
            policy=cfg.policy or col.Policy.FREQ_LFU,
        )

    @property
    def vocab_sizes(self):
        return (self.cfg.n_items, self.cfg.n_users)

    def init(self, rng, counts: Optional[np.ndarray] = None):
        c = self.cfg
        k_emb, k_s = jax.random.split(rng)
        params = {"s_matrix": jax.random.normal(k_s, (c.embed_dim, c.embed_dim), jnp.float32)
                  * (1.0 / np.sqrt(c.embed_dim))}
        counts_by_table = (
            self.collection.split_concat_counts(np.asarray(counts)) if counts is not None else None
        )
        emb = self.collection.init(k_emb, counts=counts_by_table)
        return {"params": params, "opt": self.optimizer.init(params), "emb": emb,
                "step": jnp.zeros((), jnp.int32)}

    def features(self, batch) -> col.FeatureBatch:
        t = self.cfg.seq_len
        mask = jnp.arange(t)[None, :] < batch["hist_len"][:, None]
        ids = {
            "hist_items": jnp.where(mask, batch["hist_items"], -1),
            "target_item": batch["target_item"],
            "user": batch["user"],
        }
        return col.FeatureBatch(ids={k: v.astype(jnp.int32) for k, v in ids.items()})

    def flush(self, state):
        return common.flush_embeddings(self.collection, state)

    def interests(self, params, hist, mask):
        c = self.cfg
        return R.capsule_routing(
            hist, mask, params["s_matrix"].astype(hist.dtype), c.n_interests, c.capsule_iters
        )  # [B,K,D]

    def fwd(self, params, rows: Dict[str, jnp.ndarray], batch):
        c = self.cfg
        t = c.seq_len
        hist, target, user = rows["hist_items"], rows["target_item"], rows["user"]
        mask = jnp.arange(t)[None, :] < batch["hist_len"][:, None]
        caps = self.interests(params, hist, mask)  # [B,K,D]
        caps = caps + user[:, None, :] * 0.0  # user id participates via ids only
        # label-aware attention: weight interests by target affinity^pow
        aff = jnp.einsum("bkd,bd->bk", caps, target)
        w = jax.nn.softmax(c.label_pow * aff, axis=-1)
        u = jnp.einsum("bk,bkd->bd", w, caps)
        logits = jnp.einsum("bd,bd->b", u, target)
        return logits, {}

    # train_step + split pipeline stages come from CollectionModelMixin

    def serve_step(self, state, batch):
        emb_state, _, rows = self.collection.lookup(
            state["emb"], self.features(batch), writeback=False
        )
        logits, _ = self.fwd(state["params"], rows, batch)
        return logits, emb_state

    def retrieval_score(self, state, batch):
        """Max-over-interests dot against 10^6 candidates (batched matmul)."""
        c = self.cfg
        b1 = dict(batch, target_item=jnp.zeros((1,), jnp.int32))
        b1.pop("candidates", None)
        emb_state, _, rows = self.collection.lookup(
            state["emb"], self.features(b1), writeback=False
        )
        mask = jnp.arange(c.seq_len)[None, :] < batch["hist_len"][:, None]
        caps = self.interests(state["params"], rows["hist_items"], mask)[0]  # [K,D]
        cand = self.collection.full_lookup(emb_state, "items", batch["candidates"])  # [N,D]
        scores = jnp.max(cand @ caps.T, axis=-1)  # [N]
        return scores, emb_state

    def input_specs(self, batch_size: int, n_candidates: int = 0):
        c = self.cfg
        base = {
            "hist_items": jax.ShapeDtypeStruct((batch_size, c.seq_len), jnp.int32),
            "hist_len": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
            "user": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
        }
        if n_candidates:
            base["candidates"] = jax.ShapeDtypeStruct((n_candidates,), jnp.int32)
            return base
        base["target_item"] = jax.ShapeDtypeStruct((batch_size,), jnp.int32)
        base["label"] = jax.ShapeDtypeStruct((batch_size,), jnp.float32)
        return base
