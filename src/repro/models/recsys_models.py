"""Assigned recsys archs — DIN, DIEN, FM, MIND — all with their (huge) sparse
tables served through the paper's frequency-aware cache.

Shared batch schema (synthetic Amazon/Taobao/Criteo-like):
  DIN/DIEN: hist_items [B,T], hist_cates [B,T], hist_len [B], target_item [B],
            target_cate [B], user [B], label [B]
  MIND:     hist_items [B,T], hist_len [B], target_item [B], label [B]
  FM:       sparse [B, 39], label [B]

``retrieval_score`` (the retrieval_cand shape) scores one user against 10^6
candidates as a batched matmul against the *full* (flushed) table — bulk
scoring bypasses the cache bookkeeping by design (the cache accelerates the
per-request user-side lookups; candidate scans read the authoritative tier).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cached_embedding as ce
from repro.core.policies import Policy
from repro.dist.partitioning import Param, constrain, split_params
from repro.models import common
from repro.nn import recsys as R
from repro.nn.layers import Dtypes, mlp, mlp_init
from repro.optim import optimizers as opt_lib

__all__ = ["FMConfig", "FMModel", "DINConfig", "DINModel", "DIENConfig", "DIENModel", "MINDConfig", "MINDModel"]

F32 = Dtypes(param=jnp.float32, compute=jnp.float32)


def _emb_cfg(vocab_sizes, dim, ids_per_step, cache_ratio, writeback=True, max_unique=0,
             policy=Policy.FREQ_LFU, dtype=jnp.float32, protect_via_inverse=True,
             buffer_rows=65536):
    return ce.CachedEmbeddingConfig(
        vocab_sizes=tuple(vocab_sizes),
        dim=dim,
        ids_per_step=ids_per_step,
        cache_ratio=cache_ratio,
        policy=policy,
        writeback=writeback,
        max_unique_per_step=max_unique,
        dtype=dtype,
        protect_via_inverse=protect_via_inverse,
        buffer_rows=buffer_rows,
    )


# ===========================================================================
# FM (Rendle ICDM'10): 39 sparse fields, embed_dim 10, 2-way interactions.
# Table payload is dim+1: columns [0:dim] factors, [dim] the linear weight —
# one cache tier moves both together.
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class FMConfig:
    vocab_sizes: Tuple[int, ...]  # 39 fields
    embed_dim: int = 10
    batch_size: int = 65536
    cache_ratio: float = 0.015
    max_unique_per_step: int = 0
    lr: float = 0.05
    use_pallas: bool = False
    emb_dtype: Any = jnp.float32
    protect_via_inverse: bool = True
    buffer_rows: int = 65536


class FMModel:
    def __init__(self, cfg: FMConfig):
        self.cfg = cfg
        self.optimizer = opt_lib.sgd(cfg.lr)

    def emb_cfg(self, batch_size=None, writeback=True):
        c = self.cfg
        b = batch_size or c.batch_size
        return _emb_cfg(
            c.vocab_sizes, c.embed_dim + 1, b * len(c.vocab_sizes), c.cache_ratio,
            writeback=writeback, max_unique=c.max_unique_per_step,
            dtype=c.emb_dtype, protect_via_inverse=c.protect_via_inverse,
            buffer_rows=c.buffer_rows,
        )

    def init(self, rng, counts: Optional[np.ndarray] = None):
        k_emb, k_b = jax.random.split(rng)
        params = {"bias": jnp.zeros((), jnp.float32)}
        emb = ce.init_state(k_emb, self.emb_cfg(), counts=counts)
        return {"params": params, "opt": self.optimizer.init(params), "emb": emb,
                "step": jnp.zeros((), jnp.int32)}

    def fwd(self, params, emb_rows, batch):
        c = self.cfg
        b, f = batch["sparse"].shape
        rows = emb_rows.reshape(b, f, c.embed_dim + 1)
        v, w = rows[..., : c.embed_dim], rows[..., c.embed_dim]
        logits = params["bias"] + w.sum(-1) + R.fm_interaction(v, use_pallas=c.use_pallas)
        return logits, {}

    def train_step(self, state, batch):
        step = common.EmbTrainStep(
            emb_cfg=self.emb_cfg(batch["sparse"].shape[0]),
            optimizer=self.optimizer,
            collect_ids=lambda bt: ce.globalize(state["emb"], bt["sparse"]).reshape(-1),
            fwd=self.fwd,
            emb_lr=self.cfg.lr,
        )
        return step(state, batch)

    def serve_step(self, state, batch):
        emb_cfg = self.emb_cfg(batch["sparse"].shape[0], writeback=False)
        ids = ce.globalize(state["emb"], batch["sparse"]).reshape(-1)
        emb_state, slots = ce.prepare_ids(emb_cfg, state["emb"], ids)
        rows = ce.gather_slots(emb_state, slots)
        logits, _ = self.fwd(state["params"], rows, batch)
        return logits, emb_state

    def retrieval_score(self, state, batch):
        """1 user's 38 context fields vs n_cand candidates in field 38."""
        c = self.cfg
        ctx = batch["sparse"]  # [1, 38] fields 0..37
        cands = batch["candidates"]  # [n_cand] local ids of field 38
        emb_cfg = self.emb_cfg(1, writeback=False)
        # user-side context rows via the cache tier
        gctx = (ctx.astype(jnp.int32) + state["emb"].offsets[:-1]).reshape(-1)
        pad = jnp.full((emb_cfg.ids_per_step - gctx.size,), -1, jnp.int32)
        emb_state, slots = ce.prepare_ids(emb_cfg, state["emb"], jnp.concatenate([gctx, pad]))
        ctx_rows = ce.gather_slots(emb_state, slots)[: gctx.size]
        vc, wc = ctx_rows[:, : c.embed_dim], ctx_rows[:, c.embed_dim]
        # candidate rows: bulk scan of the full table (batched gather+dot, no loop)
        rows_idx = emb_state.idx_map[cands + emb_state.offsets[-1]]
        cand_rows = jnp.take(emb_state.full["weight"], rows_idx, axis=0)
        vk, wk = cand_rows[:, : c.embed_dim], cand_rows[:, c.embed_dim]
        # FM score restricted to terms involving the candidate + context-only terms
        s_ctx = vc.sum(0)  # [D]
        ctx_pair = 0.5 * ((s_ctx * s_ctx).sum() - (vc * vc).sum())
        scores = state["params"]["bias"] + wc.sum() + ctx_pair + wk + vk @ s_ctx
        return scores, emb_state

    def input_specs(self, batch_size: int, n_candidates: int = 0):
        c = self.cfg
        if n_candidates:
            return {
                "sparse": jax.ShapeDtypeStruct((1, len(c.vocab_sizes) - 1), jnp.int32),
                "candidates": jax.ShapeDtypeStruct((n_candidates,), jnp.int32),
            }
        return {
            "sparse": jax.ShapeDtypeStruct((batch_size, len(c.vocab_sizes)), jnp.int32),
            "label": jax.ShapeDtypeStruct((batch_size,), jnp.float32),
        }


# ===========================================================================
# DIN (arXiv:1706.06978): target attention over behaviour history.
# Tables: items, categories, users (embed_dim 18 each).
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class DINConfig:
    n_items: int = 10_000_000
    n_cates: int = 1_000_000
    n_users: int = 1_000_000
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: Tuple[int, ...] = (80, 40)
    mlp: Tuple[int, ...] = (200, 80)
    batch_size: int = 65536
    cache_ratio: float = 0.015
    max_unique_per_step: int = 0
    lr: float = 0.05
    dtypes: Dtypes = F32


class DINModel:
    def __init__(self, cfg: DINConfig):
        self.cfg = cfg
        self.optimizer = opt_lib.sgd(cfg.lr)

    @property
    def vocab_sizes(self):
        c = self.cfg
        return (c.n_items, c.n_cates, c.n_users)

    def ids_per_batch(self, b):
        # hist items + hist cates + target item + target cate + user
        return b * (2 * self.cfg.seq_len + 3)

    def emb_cfg(self, batch_size=None, writeback=True):
        c = self.cfg
        b = batch_size or c.batch_size
        return _emb_cfg(self.vocab_sizes, c.embed_dim, self.ids_per_batch(b), c.cache_ratio,
                        writeback=writeback, max_unique=c.max_unique_per_step)

    def init(self, rng, counts: Optional[np.ndarray] = None):
        c = self.cfg
        k_emb, k_attn, k_mlp = jax.random.split(rng, 3)
        d = c.embed_dim
        params, _ = split_params(
            {
                "attn": R.din_attention_init(k_attn, 2 * d, c.attn_mlp, c.dtypes),
                "mlp": mlp_init(k_mlp, (d + 2 * (2 * d),) + c.mlp + (1,), c.dtypes),
            }
        )
        emb = ce.init_state(k_emb, self.emb_cfg(), counts=counts)
        return {"params": params, "opt": self.optimizer.init(params), "emb": emb,
                "step": jnp.zeros((), jnp.int32)}

    def collect_ids(self, emb_state, batch):
        off = emb_state.offsets
        b = batch["hist_items"].shape[0]
        hist_mask = jnp.arange(self.cfg.seq_len)[None, :] < batch["hist_len"][:, None]
        hi = jnp.where(hist_mask, batch["hist_items"] + off[0], -1)
        hc = jnp.where(hist_mask, batch["hist_cates"] + off[1], -1)
        ti = (batch["target_item"] + off[0])[:, None]
        tc = (batch["target_cate"] + off[1])[:, None]
        us = (batch["user"] + off[2])[:, None]
        return jnp.concatenate([hi, hc, ti, tc, us], axis=1).reshape(-1).astype(jnp.int32)

    def fwd(self, params, emb_rows, batch):
        c = self.cfg
        d, t = c.embed_dim, c.seq_len
        b = batch["hist_items"].shape[0]
        rows = emb_rows.reshape(b, 2 * t + 3, d)
        hist = jnp.concatenate([rows[:, :t], rows[:, t : 2 * t]], axis=-1)  # [B,T,2D]
        target = jnp.concatenate([rows[:, 2 * t], rows[:, 2 * t + 1]], axis=-1)  # [B,2D]
        user = rows[:, 2 * t + 2]
        mask = jnp.arange(t)[None, :] < batch["hist_len"][:, None]
        pooled = R.din_attention(params["attn"], hist, target, mask, c.dtypes)  # [B,2D]
        x = jnp.concatenate([user, pooled, target], axis=-1)
        x = constrain(x, "batch", None)
        logits = mlp(params["mlp"], x, c.dtypes)[:, 0]
        return logits, {}

    def train_step(self, state, batch):
        step = common.EmbTrainStep(
            emb_cfg=self.emb_cfg(batch["hist_items"].shape[0]),
            optimizer=self.optimizer,
            collect_ids=lambda bt: self.collect_ids(state["emb"], bt),
            fwd=self.fwd,
            emb_lr=self.cfg.lr,
        )
        return step(state, batch)

    def serve_step(self, state, batch):
        emb_cfg = self.emb_cfg(batch["hist_items"].shape[0], writeback=False)
        ids = self.collect_ids(state["emb"], batch)
        emb_state, slots = ce.prepare_ids(emb_cfg, state["emb"], ids)
        rows = ce.gather_slots(emb_state, slots)
        logits, _ = self.fwd(state["params"], rows, batch)
        return logits, emb_state

    def retrieval_score(self, state, batch):
        """One user history vs n_cand candidate items (shared-user batched dot)."""
        c = self.cfg
        emb_cfg = self.emb_cfg(1, writeback=False)
        b1 = {k: v for k, v in batch.items() if k not in ("candidates", "candidate_cates")}
        b1.setdefault("target_item", jnp.zeros((1,), jnp.int32))
        b1.setdefault("target_cate", jnp.zeros((1,), jnp.int32))
        ids = self.collect_ids(state["emb"], b1)
        emb_state, slots = ce.prepare_ids(emb_cfg, state["emb"], ids)
        rows = ce.gather_slots(emb_state, slots)
        d, t = c.embed_dim, c.seq_len
        rows = rows.reshape(1, 2 * t + 3, d)
        hist = jnp.concatenate([rows[:, :t], rows[:, t : 2 * t]], axis=-1)
        user = rows[:, 2 * t + 2]
        mask = jnp.arange(t)[None, :] < batch["hist_len"][:, None]

        cands = batch["candidates"]  # [n_cand] item ids; category = item's cate id array
        cand_cates = batch["candidate_cates"]
        rowsi = emb_state.idx_map[cands + emb_state.offsets[0]]
        rowsc = emb_state.idx_map[cand_cates + emb_state.offsets[1]]
        ti = jnp.take(emb_state.full["weight"], rowsi, axis=0)
        tc = jnp.take(emb_state.full["weight"], rowsc, axis=0)
        targets = jnp.concatenate([ti, tc], axis=-1)  # [n_cand, 2D]

        n = cands.shape[0]
        histb = jnp.broadcast_to(hist, (n,) + hist.shape[1:])
        maskb = jnp.broadcast_to(mask, (n, t))
        pooled = R.din_attention(state["params"]["attn"], histb, targets, maskb, c.dtypes)
        userb = jnp.broadcast_to(user, (n, d))
        x = jnp.concatenate([userb, pooled, targets], axis=-1)
        scores = mlp(state["params"]["mlp"], x, c.dtypes)[:, 0]
        return scores, emb_state

    def input_specs(self, batch_size: int, n_candidates: int = 0):
        c = self.cfg
        base = {
            "hist_items": jax.ShapeDtypeStruct((batch_size, c.seq_len), jnp.int32),
            "hist_cates": jax.ShapeDtypeStruct((batch_size, c.seq_len), jnp.int32),
            "hist_len": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
            "target_item": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
            "target_cate": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
            "user": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
        }
        if n_candidates:
            base.pop("target_item"), base.pop("target_cate")
            base["candidates"] = jax.ShapeDtypeStruct((n_candidates,), jnp.int32)
            base["candidate_cates"] = jax.ShapeDtypeStruct((n_candidates,), jnp.int32)
            return base
        base["label"] = jax.ShapeDtypeStruct((batch_size,), jnp.float32)
        return base


# ===========================================================================
# DIEN (arXiv:1809.03672): GRU interest extraction + AUGRU evolution.
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class DIENConfig(DINConfig):
    gru_dim: int = 108


class DIENModel(DINModel):
    def __init__(self, cfg: DIENConfig):
        super().__init__(cfg)

    def init(self, rng, counts: Optional[np.ndarray] = None):
        c: DIENConfig = self.cfg  # type: ignore[assignment]
        k_emb, k_g1, k_g2, k_attn, k_mlp = jax.random.split(rng, 5)
        d = c.embed_dim
        params, _ = split_params(
            {
                "gru1": R.gru_init(k_g1, 2 * d, c.gru_dim, c.dtypes),
                "gru2": R.gru_init(k_g2, c.gru_dim, c.gru_dim, c.dtypes),
                "attn_proj": {
                    "w": Param(
                        jax.random.normal(k_attn, (2 * d, c.gru_dim), c.dtypes.param)
                        * (1.0 / np.sqrt(2 * d)),
                        (None, None),
                    )
                },
                "mlp": mlp_init(k_mlp, (d + 2 * d + c.gru_dim,) + c.mlp + (1,), c.dtypes),
            }
        )
        emb = ce.init_state(k_emb, self.emb_cfg(), counts=counts)
        return {"params": params, "opt": self.optimizer.init(params), "emb": emb,
                "step": jnp.zeros((), jnp.int32)}

    def fwd(self, params, emb_rows, batch):
        c: DIENConfig = self.cfg  # type: ignore[assignment]
        d, t = c.embed_dim, c.seq_len
        b = batch["hist_items"].shape[0]
        rows = emb_rows.reshape(b, 2 * t + 3, d)
        hist = jnp.concatenate([rows[:, :t], rows[:, t : 2 * t]], axis=-1)
        target = jnp.concatenate([rows[:, 2 * t], rows[:, 2 * t + 1]], axis=-1)
        user = rows[:, 2 * t + 2]
        mask = jnp.arange(t)[None, :] < batch["hist_len"][:, None]

        interest = R.gru(params["gru1"], hist, c.dtypes)  # [B,T,H]
        # attention of target on interest states
        tq = target @ params["attn_proj"]["w"].astype(c.dtypes.compute)  # [B,H]
        att = jnp.einsum("bh,bth->bt", tq, interest) / np.sqrt(c.gru_dim)
        att = jax.nn.softmax(jnp.where(mask, att, -1e30), axis=-1)
        att = jnp.where(mask, att, 0.0)
        final = R.augru(params["gru2"], interest, att, c.dtypes)[:, -1]  # [B,H]
        x = jnp.concatenate([user, target, final], axis=-1)
        logits = mlp(params["mlp"], x, c.dtypes)[:, 0]
        return logits, {}

    def retrieval_score(self, state, batch):
        """Bulk candidate scoring for DIEN.

        Serving-path adaptation (DESIGN.md): GRU1 interest extraction runs
        once (target-independent); candidates are scored by target attention
        over the interest states (the AUGRU evolution stage is skipped — a
        full per-candidate AUGRU over 10^6 candidates is a ranking-stage
        cost, not a retrieval-stage one).
        """
        c: DIENConfig = self.cfg  # type: ignore[assignment]
        d, t = c.embed_dim, c.seq_len
        emb_cfg = self.emb_cfg(1, writeback=False)
        b1 = {k: v for k, v in batch.items() if k not in ("candidates", "candidate_cates")}
        b1.setdefault("target_item", jnp.zeros((1,), jnp.int32))
        b1.setdefault("target_cate", jnp.zeros((1,), jnp.int32))
        ids = self.collect_ids(state["emb"], b1)
        emb_state, slots = ce.prepare_ids(emb_cfg, state["emb"], ids)
        rows = ce.gather_slots(emb_state, slots).reshape(1, 2 * t + 3, d)
        hist = jnp.concatenate([rows[:, :t], rows[:, t : 2 * t]], axis=-1)
        mask = jnp.arange(t)[None, :] < batch["hist_len"][:, None]
        interest = R.gru(state["params"]["gru1"], hist, c.dtypes)[0]  # [T,H]

        rowsi = emb_state.idx_map[batch["candidates"] + emb_state.offsets[0]]
        rowsc = emb_state.idx_map[batch["candidate_cates"] + emb_state.offsets[1]]
        ti = jnp.take(emb_state.full["weight"], rowsi, axis=0)
        tc = jnp.take(emb_state.full["weight"], rowsc, axis=0)
        targets = jnp.concatenate([ti, tc], axis=-1)  # [N, 2D]
        tq = targets @ state["params"]["attn_proj"]["w"].astype(c.dtypes.compute)  # [N,H]
        att = (tq @ interest.T) / np.sqrt(c.gru_dim)  # [N,T]
        att = jax.nn.softmax(jnp.where(mask[0][None, :], att, -1e30), axis=-1)
        pooled = att @ interest  # [N,H]
        scores = jnp.einsum("nh,nh->n", tq, pooled)
        return scores, emb_state


# ===========================================================================
# MIND (arXiv:1904.08030): multi-interest capsule routing.
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    n_items: int = 4_000_000
    n_users: int = 1_000_000
    embed_dim: int = 64
    seq_len: int = 100
    n_interests: int = 4
    capsule_iters: int = 3
    batch_size: int = 65536
    cache_ratio: float = 0.015
    max_unique_per_step: int = 0
    label_pow: float = 2.0  # label-aware attention sharpness
    lr: float = 0.05
    dtypes: Dtypes = F32


class MINDModel:
    def __init__(self, cfg: MINDConfig):
        self.cfg = cfg
        self.optimizer = opt_lib.sgd(cfg.lr)

    @property
    def vocab_sizes(self):
        return (self.cfg.n_items, self.cfg.n_users)

    def ids_per_batch(self, b):
        return b * (self.cfg.seq_len + 2)  # hist + target + user

    def emb_cfg(self, batch_size=None, writeback=True):
        c = self.cfg
        b = batch_size or c.batch_size
        return _emb_cfg(self.vocab_sizes, c.embed_dim, self.ids_per_batch(b), c.cache_ratio,
                        writeback=writeback, max_unique=c.max_unique_per_step)

    def init(self, rng, counts: Optional[np.ndarray] = None):
        c = self.cfg
        k_emb, k_s = jax.random.split(rng)
        params = {"s_matrix": jax.random.normal(k_s, (c.embed_dim, c.embed_dim), jnp.float32)
                  * (1.0 / np.sqrt(c.embed_dim))}
        emb = ce.init_state(k_emb, self.emb_cfg(), counts=counts)
        return {"params": params, "opt": self.optimizer.init(params), "emb": emb,
                "step": jnp.zeros((), jnp.int32)}

    def collect_ids(self, emb_state, batch):
        off = emb_state.offsets
        t = self.cfg.seq_len
        mask = jnp.arange(t)[None, :] < batch["hist_len"][:, None]
        hi = jnp.where(mask, batch["hist_items"] + off[0], -1)
        ti = (batch["target_item"] + off[0])[:, None]
        us = (batch["user"] + off[1])[:, None]
        return jnp.concatenate([hi, ti, us], axis=1).reshape(-1).astype(jnp.int32)

    def interests(self, params, hist, mask):
        c = self.cfg
        return R.capsule_routing(
            hist, mask, params["s_matrix"].astype(hist.dtype), c.n_interests, c.capsule_iters
        )  # [B,K,D]

    def fwd(self, params, emb_rows, batch):
        c = self.cfg
        t, d = c.seq_len, c.embed_dim
        b = batch["hist_items"].shape[0]
        rows = emb_rows.reshape(b, t + 2, d)
        hist, target, user = rows[:, :t], rows[:, t], rows[:, t + 1]
        mask = jnp.arange(t)[None, :] < batch["hist_len"][:, None]
        caps = self.interests(params, hist, mask)  # [B,K,D]
        caps = caps + user[:, None, :] * 0.0  # user id participates via ids only
        # label-aware attention: weight interests by target affinity^pow
        aff = jnp.einsum("bkd,bd->bk", caps, target)
        w = jax.nn.softmax(c.label_pow * aff, axis=-1)
        u = jnp.einsum("bk,bkd->bd", w, caps)
        logits = jnp.einsum("bd,bd->b", u, target)
        return logits, {}

    def train_step(self, state, batch):
        step = common.EmbTrainStep(
            emb_cfg=self.emb_cfg(batch["hist_items"].shape[0]),
            optimizer=self.optimizer,
            collect_ids=lambda bt: self.collect_ids(state["emb"], bt),
            fwd=self.fwd,
            emb_lr=self.cfg.lr,
        )
        return step(state, batch)

    def serve_step(self, state, batch):
        emb_cfg = self.emb_cfg(batch["hist_items"].shape[0], writeback=False)
        ids = self.collect_ids(state["emb"], batch)
        emb_state, slots = ce.prepare_ids(emb_cfg, state["emb"], ids)
        rows = ce.gather_slots(emb_state, slots)
        logits, _ = self.fwd(state["params"], rows, batch)
        return logits, emb_state

    def retrieval_score(self, state, batch):
        """Max-over-interests dot against 10^6 candidates (batched matmul)."""
        c = self.cfg
        emb_cfg = self.emb_cfg(1, writeback=False)
        ids = self.collect_ids(
            state["emb"],
            dict(batch, target_item=jnp.zeros((1,), jnp.int32)),
        )
        emb_state, slots = ce.prepare_ids(emb_cfg, state["emb"], ids)
        rows = ce.gather_slots(emb_state, slots).reshape(1, c.seq_len + 2, c.embed_dim)
        hist = rows[:, : c.seq_len]
        mask = jnp.arange(c.seq_len)[None, :] < batch["hist_len"][:, None]
        caps = self.interests(state["params"], hist, mask)[0]  # [K,D]
        rowsi = emb_state.idx_map[batch["candidates"] + emb_state.offsets[0]]
        cand = jnp.take(emb_state.full["weight"], rowsi, axis=0)  # [N,D]
        scores = jnp.max(cand @ caps.T, axis=-1)  # [N]
        return scores, emb_state

    def input_specs(self, batch_size: int, n_candidates: int = 0):
        c = self.cfg
        base = {
            "hist_items": jax.ShapeDtypeStruct((batch_size, c.seq_len), jnp.int32),
            "hist_len": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
            "user": jax.ShapeDtypeStruct((batch_size,), jnp.int32),
        }
        if n_candidates:
            base["candidates"] = jax.ShapeDtypeStruct((n_candidates,), jnp.int32)
            return base
        base["target_item"] = jax.ShapeDtypeStruct((batch_size,), jnp.int32)
        base["label"] = jax.ShapeDtypeStruct((batch_size,), jnp.float32)
        return base
