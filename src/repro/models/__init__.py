from repro.models.dlrm import DLRM, DLRMConfig
from repro.models.gatedgcn import GatedGCNConfig, GatedGCNModel
from repro.models.lm import LMModel
from repro.models.recsys_models import (
    DIENConfig,
    DIENModel,
    DINConfig,
    DINModel,
    FMConfig,
    FMModel,
    MINDConfig,
    MINDModel,
)
