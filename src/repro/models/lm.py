"""LM-family model wrapper: train / prefill / decode steps over
``repro.nn.transformer`` with AdamW, grad clipping and optional gradient
compression for the DP reduction.

The paper's cache technique is inapplicable here (vocab tables fit in HBM —
DESIGN.md §Arch-applicability); these archs exercise the framework's
TP/FSDP/EP/long-context distribution paths.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common
from repro.nn import transformer as T
from repro.optim import optimizers as opt_lib
from repro.optim.compression import Compressor

__all__ = ["LMModel"]


class LMModel:
    def __init__(
        self,
        cfg: T.TransformerConfig,
        lr: float = 3e-4,
        clip_norm: float = 1.0,
        aux_weight: float = 0.01,
        compressor: str = "none",
    ):
        self.cfg = cfg
        self.clip_norm = clip_norm
        self.aux_weight = aux_weight
        self.optimizer = opt_lib.adamw(lr)
        self.compressor = Compressor(compressor)

    def init(self, rng) -> Dict[str, Any]:
        params, axes = T.init_lm(rng, self.cfg)
        state = {
            "params": params,
            "opt": self.optimizer.init(params),
            "step": jnp.zeros((), jnp.int32),
        }
        if self.compressor.codec == "int8":
            state["comp"] = self.compressor.init(params)
        self.param_axes = axes
        return state

    def loss_fn(self, params, batch):
        logits, aux = T.forward(params, self.cfg, batch["tokens"])
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(
            logits.astype(jnp.float32), batch["labels"][..., None], axis=-1
        )[..., 0]
        xent = jnp.mean(lse - ll)
        return xent + self.aux_weight * aux, (xent, aux)

    def train_step(self, state, batch):
        (loss, (xent, aux)), grads = jax.value_and_grad(self.loss_fn, has_aux=True)(
            state["params"], batch
        )
        grads, gnorm = opt_lib.clip_by_global_norm(grads, self.clip_norm)
        new_state = dict(state)
        if self.compressor.codec != "none":
            payload, sideband, comp_state = self.compressor.encode(grads, state.get("comp", ()))
            grads = self.compressor.decode(payload, sideband, grads)
            if self.compressor.codec == "int8":
                new_state["comp"] = comp_state
        params, opt_state = self.optimizer.update(grads, state["opt"], state["params"], state["step"])
        new_state.update(params=params, opt=opt_state, step=state["step"] + 1)
        return new_state, {"loss": loss, "xent": xent, "aux": aux, "grad_norm": gnorm}

    def prefill_step(self, state_params, batch):
        return T.prefill(state_params, self.cfg, batch["tokens"])

    def decode_fn(self, params, caches, token, pos):
        return T.decode_step(params, self.cfg, caches, token, pos)

    # ----- specs ------------------------------------------------------------
    def train_specs(self, batch: int, seq: int):
        return {
            "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }

    def prefill_specs(self, batch: int, seq: int):
        return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}

    def decode_specs(self, batch: int, kv_len: int):
        caches = jax.eval_shape(
            lambda: T.init_decode_caches(self.cfg, batch, kv_len)
        )
        return {
            "caches": caches,
            "token": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
