"""Shared model scaffolding: losses, metrics, the cached-embedding train-step
pattern (prepare -> diff gather -> synchronous row update).

``CollectionTrainStep`` is the collection-era pattern every recsys model
uses: a ``FeatureBatch`` goes through ``EmbeddingCollection.prepare`` outside
the grad closure, the loss differentiates w.r.t. ``collection.weights`` (the
fast tiers), and ``apply_grads`` performs the synchronous row update.
``EmbTrainStep`` is the legacy single-arena variant kept for the
``cached_embedding`` adapter path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.contracts import INT_COUNTERS, contract
from repro.core import cached_embedding as ce
from repro.core.collection import EmbeddingCollection, FeatureBatch
from repro.optim.optimizers import Optimizer

__all__ = [
    "bce_with_logits",
    "softmax_xent",
    "auc_proxy",
    "flush_embeddings",
    "EmbTrainStep",
    "CollectionTrainStep",
    "CollectionModelMixin",
]


def flush_embeddings(collection: "EmbeddingCollection", state: Dict[str, Any]) -> Dict[str, Any]:
    """The shared pre-checkpoint barrier: flush every cached slab under the
    ``emb`` key (models expose this as ``model.flush``)."""
    return dict(state, emb=collection.flush(state["emb"]))


def bce_with_logits(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    z = logits.astype(jnp.float32)
    y = labels.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def auc_proxy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Fast pairwise-ranking AUC estimate (exact when no score ties)."""
    s = logits.astype(jnp.float32).reshape(-1)
    y = labels.astype(jnp.float32).reshape(-1)
    order = jnp.argsort(s)
    ranks = jnp.zeros_like(s).at[order].set(jnp.arange(1, s.size + 1, dtype=jnp.float32))
    n_pos = jnp.sum(y)
    n_neg = y.size - n_pos
    auc = (jnp.sum(ranks * y) - n_pos * (n_pos + 1) / 2) / jnp.maximum(n_pos * n_neg, 1.0)
    return jnp.where((n_pos > 0) & (n_neg > 0), auc, 0.5)


@dataclasses.dataclass(frozen=True)
class EmbTrainStep:
    """Builds the jittable cached-embedding train step shared by all recsys archs.

    ``fwd(dense_params, emb_rows, batch) -> (logits, aux_dict)`` where
    ``emb_rows = gather(cached_weight, slots)`` happens inside so gradients
    reach the cached rows.
    """

    emb_cfg: ce.CachedEmbeddingConfig
    optimizer: Optimizer
    collect_ids: Callable[[Dict[str, jnp.ndarray]], jnp.ndarray]  # batch -> flat global ids
    fwd: Callable[..., Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]]
    loss: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray] = bce_with_logits
    emb_lr: float = 0.05

    def __call__(self, state: Dict[str, Any], batch: Dict[str, jnp.ndarray]):
        ids = self.collect_ids(batch)  # [ids_per_step] int32 global ids (-1 pad)
        emb_state, slots = ce.prepare_ids(self.emb_cfg, state["emb"], ids)

        def loss_fn(dense_params, cached_w):
            safe = jnp.where(slots >= 0, slots, cached_w.shape[0])  # negatives wrap
            rows = jnp.take(cached_w, safe, axis=0, mode="fill", fill_value=0)
            logits, aux = self.fwd(dense_params, rows, batch)
            return self.loss(logits, batch["label"]), (logits, aux)

        (loss_val, (logits, aux)), grads = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)(
            state["params"], emb_state.cache.cached_rows["weight"]
        )
        params, opt_state = self.optimizer.update(
            grads[0], state["opt"], state["params"], state["step"]
        )
        emb_state = ce.apply_row_grads(self.emb_cfg, emb_state, grads[1], self.emb_lr)
        metrics = {
            "loss": loss_val,
            "auc": auc_proxy(logits, batch["label"]),
            "hit_rate": emb_state.cache.hit_rate(),
            "cache_misses": emb_state.cache.misses,
            "uniq_overflows": emb_state.cache.uniq_overflows,
            **aux,
        }
        new_state = dict(state, params=params, opt=opt_state, emb=emb_state, step=state["step"] + 1)
        return new_state, metrics


@dataclasses.dataclass(frozen=True)
class CollectionTrainStep:
    """Jittable train step over an ``EmbeddingCollection``.

    ``features(batch) -> FeatureBatch`` replaces the hand-flattened
    ``collect_ids``; ``fwd(dense_params, rows, batch) -> (logits, aux)``
    receives the keyed gather output (feature name -> [.., dim] rows) so
    gradients reach the fast-tier weights of every slab — DEVICE tables and
    cached arenas alike.

    The step is exposed both fused (``__call__``) and split into the three
    pipeline stages (``plan_step`` / ``apply_step`` / ``compute_step``) so a
    pipelined trainer can dispatch step t+1's planning — which reads only ids
    and cache index state — while step t's dense compute is still running.
    ``__call__`` is exactly their composition, so the serial path stays the
    bit-exactness oracle for the pipelined one.
    """

    collection: EmbeddingCollection
    optimizer: Optimizer
    features: Callable[[Dict[str, jnp.ndarray]], FeatureBatch]
    fwd: Callable[..., Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]]
    loss: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray] = bce_with_logits
    emb_lr: float = 0.05

    def plan_step(
        self,
        state: Dict[str, Any],
        batch: Dict[str, jnp.ndarray],
        future_batches: Tuple[Dict[str, jnp.ndarray], ...] = (),
    ):
        """Weight-free planning half: dedup + slot assignment + movement plan
        for ``batch``, with ``future_batches``' ids merged as a lookahead
        window (their rows are prefetched and pinned; see
        ``EmbeddingCollection.plan_prepare``)."""
        fut = tuple(self.features(b) for b in future_batches)
        return self.collection.plan_prepare(state["emb"], self.features(batch), fb_future=fut)

    def apply_step(self, state: Dict[str, Any], plan) -> Dict[str, Any]:
        """Execute a plan's row movement (the only prepare half that touches
        weights — run it after the previous step's row update)."""
        return dict(state, emb=self.collection.apply_plan(state["emb"], plan))

    # max_sort_size admits the batch-sized ``auc_proxy`` argsort at the
    # analysis.smoke batch of 32, nothing capacity-sized.
    @contract(donates=("state",), int_counters=INT_COUNTERS, max_sort_size=64)
    def compute_step(
        self,
        state: Dict[str, Any],
        batch: Dict[str, jnp.ndarray],
        addresses: Dict[str, jnp.ndarray],
    ):
        """Dense fwd/bwd + optimizer + synchronous row update, given the
        addresses planned for ``batch`` (whose rows are already resident)."""
        fb = self.features(batch)
        emb_state = state["emb"]

        def loss_fn(dense_params, emb_weights):
            rows = self.collection.gather(emb_weights, addresses, fb)
            logits, aux = self.fwd(dense_params, rows, batch)
            return self.loss(logits, batch["label"]), (logits, aux)

        (loss_val, (logits, aux)), grads = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True
        )(state["params"], self.collection.weights(emb_state))
        params, opt_state = self.optimizer.update(
            grads[0], state["opt"], state["params"], state["step"]
        )
        emb_state = self.collection.apply_grads(emb_state, grads[1], self.emb_lr)
        metrics = {
            "loss": loss_val,
            "auc": auc_proxy(logits, batch["label"]),
            **self.collection.metrics(emb_state),
            **aux,
        }
        new_state = dict(state, params=params, opt=opt_state, emb=emb_state, step=state["step"] + 1)
        return new_state, metrics

    def __call__(self, state: Dict[str, Any], batch: Dict[str, jnp.ndarray]):
        plan = self.plan_step(state, batch)
        state = self.apply_step(state, plan)
        return self.compute_step(state, batch, plan.addresses)


class CollectionModelMixin:
    """The step surface shared by every model whose embeddings live in an
    ``EmbeddingCollection`` (expects ``self.collection`` / ``self.optimizer``
    / ``self.features`` / ``self.fwd`` and an embedding LR at ``cfg.lr``):
    the fused ``train_step`` plus the split pipeline stages ``plan_step`` /
    ``apply_step`` / ``compute_step`` consumed by ``PipelinedTrainer`` —
    planning is weight-free, so the trainer dispatches step t+1's plan while
    step t's dense compute runs."""

    @property
    def emb_lr(self) -> float:
        return self.cfg.lr

    def _train_step(self) -> CollectionTrainStep:
        return CollectionTrainStep(
            collection=self.collection,
            optimizer=self.optimizer,
            features=self.features,
            fwd=self.fwd,
            emb_lr=self.emb_lr,
        )

    def train_step(self, state, batch):
        return self._train_step()(state, batch)

    def plan_step(self, state, batch, future_batches=()):
        return self._train_step().plan_step(state, batch, future_batches)

    def apply_step(self, state, plan):
        return self._train_step().apply_step(state, plan)

    def compute_step(self, state, batch, addresses):
        return self._train_step().compute_step(state, batch, addresses)

    def refresh(self, state, cfg=None, writeback: bool = True):
        """Adaptive frequency refresh: re-rank the collection's cached slabs
        from their online decayed counters (``EmbeddingCollection.refresh``).
        Host-side and pure reindexing — call between steps (the trainers wire
        this as ``refresh_fn`` under ``TrainerConfig.refresh_interval``; serve
        passes ``writeback=False`` for its read-only cache states)."""
        new_emb, _ = self.collection.refresh(
            state["emb"], cfg, writeback=writeback
        )
        return dict(state, emb=new_emb)
