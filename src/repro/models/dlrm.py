"""DLRM (Naumov et al. 2019) — the paper's evaluation model, embeddings served
through the planner-driven ``EmbeddingCollection``.

Paper §5.1 configuration: embedding dim 128 for every table, bottom MLP
512-256-128 over 13 dense features, dot-product feature interaction, top MLP
1024-1024-512-256-1, SGD with constant LR.

Placement: with ``device_budget_bytes=None`` every sparse field is GROUPED
into one shared cache arena — the paper's original one-big-table layout, so
training curves are invariant to the cache ratio (tested parity property).
With a budget, ``PlacementPlanner`` promotes small/hot tables to DEVICE and
leaves the rest cached — the mixed-placement production layout.
``host_precision`` selects the host-tier storage codec of the cached slabs
(fp32 bit-exact / fp16 / row-wise int8 / auto) — see ``repro.store``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collection as col
from repro.dist.partitioning import constrain, split_params
from repro.models import common
from repro.nn.layers import Dtypes, mlp, mlp_init
from repro.optim import optimizers as opt_lib

__all__ = ["DLRMConfig", "DLRM"]


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    vocab_sizes: Tuple[int, ...]  # 26 sparse features (Criteo) / 13 (Avazu)
    n_dense: int = 13
    embed_dim: int = 128
    bottom_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256)
    batch_size: int = 16384
    cache_ratio: float = 0.015
    buffer_rows: int = 65536
    max_unique_per_step: int = 0
    lr: float = 1.0  # paper: 1.0 (Criteo), 5e-2 (Avazu)
    policy: Any = None  # core.Policy; None -> FREQ_LFU
    dtypes: Dtypes = Dtypes(param=jnp.float32, compute=jnp.float32)
    use_pallas: bool = False
    device_budget_bytes: Optional[int] = None  # None = paper single-arena mode
    # host-tier storage codec: "fp32" (bit-exact, default) | "fp16" | "int8"
    # (row-wise scale/zero-point) | "auto" (PrecisionPolicy picks per slab
    # from the frequency counts passed to init)
    host_precision: str = "fp32"
    # device-arena (fast-tier) codec: "fp32" keeps the raw bit-exact arena;
    # "fp16"/"int8" tier it — hot head stays fp32, the cold resident tail
    # stores encoded; "auto" lets PrecisionPolicy pick from head coverage.
    arena_precision: str = "fp32"
    arena_head_ratio: float = 0.25  # fp32 head share of a tiered arena
    # 0 = single-device collection; N >= 1 = hybrid parallel: cached slabs
    # shard over N model-axis shards (each with its own cache arena and
    # HostStore slice), dense params + DEVICE tables stay data-parallel.
    model_shards: int = 0
    # hybrid parallel only: K hottest ranks per cached slab live in a
    # replicated arena on every shard (0 = off, bit-identical to pre-
    # replication layout).
    replicate_top_k: int = 0
    # hybrid parallel only: codec for the routed row-leg of the exchange —
    # "fp32" (bit-exact, default) | "fp16" | "int8".
    exchange_codec: str = "fp32"
    # hybrid parallel only: static per-shard plan-width bound (0 = exact
    # full-width planning).  Bound too tight -> uniq_overflows trips the
    # trainer guard instead of silently dropping lanes.
    max_routed_per_shard: int = 0
    # cache hot path: bounded-top-K/fused planning kernels and chunked host
    # staging (see core.cache.CacheConfig; both bit-identical to defaults).
    use_pallas_plan: bool = False
    chunk_rows: int = 0

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)


class DLRM(common.CollectionModelMixin):
    def __init__(self, cfg: DLRMConfig):
        from repro.core.policies import Policy

        self.cfg = cfg
        f = cfg.n_sparse + 1  # embeddings + bottom-MLP output
        self.top_in = cfg.embed_dim + f * (f - 1) // 2
        self.optimizer = opt_lib.sgd(cfg.lr)
        self.feature_names = tuple(f"f{i}" for i in range(cfg.n_sparse))
        policy = cfg.policy or Policy.FREQ_LFU
        tables = [
            col.TableConfig(
                name=n,
                vocab=v,
                dim=cfg.embed_dim,
                ids_per_step=cfg.batch_size,
                cache_ratio=cfg.cache_ratio,
                policy=policy,
                buffer_rows=cfg.buffer_rows,
                # the config bound applies per table when the planner carves
                # solo CACHED slabs; the GROUPED arena uses the same value
                # collection-wide (passed to create below).
                max_unique_per_step=cfg.max_unique_per_step,
                dtype=cfg.dtypes.param,
            )
            for n, v in zip(self.feature_names, cfg.vocab_sizes)
        ]
        common_kw = dict(
            budget_bytes=cfg.device_budget_bytes,
            cache_ratio=cfg.cache_ratio,
            policy=policy,
            buffer_rows=cfg.buffer_rows,
            max_unique_per_step=cfg.max_unique_per_step,
            host_precision=cfg.host_precision,
            arena_precision=cfg.arena_precision,
            arena_head_ratio=cfg.arena_head_ratio,
            use_pallas_plan=cfg.use_pallas_plan,
            chunk_rows=cfg.chunk_rows,
        )
        if cfg.model_shards > 0:
            from repro.core.sharded import ShardedEmbeddingCollection

            self.collection = ShardedEmbeddingCollection.create(
                tables, num_shards=cfg.model_shards,
                replicate_top_k=cfg.replicate_top_k,
                exchange_codec=cfg.exchange_codec,
                max_routed_per_shard=cfg.max_routed_per_shard,
                **common_kw,
            )
        else:
            self.collection = col.EmbeddingCollection.create(tables, **common_kw)

    # ----- params ----------------------------------------------------------
    def init(self, rng: jax.Array, counts: Optional[np.ndarray] = None) -> Dict[str, Any]:
        cfg = self.cfg
        k_emb, k_bot, k_top = jax.random.split(rng, 3)
        params, _ = split_params(
            {
                "bottom": mlp_init(k_bot, (cfg.n_dense,) + cfg.bottom_mlp, cfg.dtypes),
                "top": mlp_init(k_top, (self.top_in,) + cfg.top_mlp + (1,), cfg.dtypes),
            }
        )
        counts_by_table = (
            self.collection.split_concat_counts(np.asarray(counts))
            if counts is not None
            else None
        )
        emb = self.collection.init(k_emb, counts=counts_by_table)
        return {
            "params": params,
            "opt": self.optimizer.init(params),
            "emb": emb,
            "step": jnp.zeros((), jnp.int32),
        }

    def features(self, batch) -> col.FeatureBatch:
        return col.FeatureBatch.from_onehot(self.feature_names, batch["sparse"])

    def flush(self, state):
        """Cache barrier (pre-checkpoint): slow tiers become authoritative."""
        return common.flush_embeddings(self.collection, state)

    # ----- forward ----------------------------------------------------------
    def interact(self, dense_vec: jnp.ndarray, emb: jnp.ndarray) -> jnp.ndarray:
        """Dot-product interaction: pairwise dots of [dense_vec] + embeddings."""
        b = dense_vec.shape[0]
        z = jnp.concatenate([dense_vec[:, None, :], emb], axis=1)  # [B, F+1, D]
        zz = jnp.einsum("bfd,bgd->bfg", z, z)
        f = z.shape[1]
        iu, ju = jnp.triu_indices(f, k=1)
        return zz[:, iu, ju]  # [B, F*(F-1)/2]

    def fwd(self, params, rows: Dict[str, jnp.ndarray], batch):
        cfg = self.cfg
        emb = jnp.stack([rows[n] for n in self.feature_names], axis=1)  # [B, F, D]
        emb = constrain(emb, "batch", None, None)
        dense_vec = mlp(params["bottom"], batch["dense"].astype(cfg.dtypes.compute), cfg.dtypes, final_act=True)
        x = jnp.concatenate([dense_vec, self.interact(dense_vec, emb)], axis=-1)
        logits = mlp(params["top"], x, cfg.dtypes)[:, 0]
        return logits, {}

    # ----- steps: train_step + the split pipeline stages (plan_step /
    # apply_step / compute_step) come from CollectionModelMixin --------------
    def serve_step(self, state, batch):
        """Inference: cache read path without writeback bookkeeping cost."""
        emb_state, _, rows = self.collection.lookup(
            state["emb"], self.features(batch), writeback=False
        )
        logits, _ = self.fwd(state["params"], rows, batch)
        return logits, emb_state

    # ----- specs -------------------------------------------------------------
    def input_specs(self, batch_size: int) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        return {
            "dense": jax.ShapeDtypeStruct((batch_size, cfg.n_dense), jnp.float32),
            "sparse": jax.ShapeDtypeStruct((batch_size, cfg.n_sparse), jnp.int32),
            "label": jax.ShapeDtypeStruct((batch_size,), jnp.float32),
        }
