"""DLRM (Naumov et al. 2019) — the paper's evaluation model, embeddings served
through the frequency-aware software cache.

Paper §5.1 configuration: embedding dim 128 for every table, bottom MLP
512-256-128 over 13 dense features, dot-product feature interaction, top MLP
1024-1024-512-256-1, SGD with constant LR.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cached_embedding as ce
from repro.dist.partitioning import constrain, split_params
from repro.models import common
from repro.nn.layers import Dtypes, mlp, mlp_init
from repro.optim import optimizers as opt_lib

__all__ = ["DLRMConfig", "DLRM"]


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    vocab_sizes: Tuple[int, ...]  # 26 sparse features (Criteo) / 13 (Avazu)
    n_dense: int = 13
    embed_dim: int = 128
    bottom_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256)
    batch_size: int = 16384
    cache_ratio: float = 0.015
    buffer_rows: int = 65536
    max_unique_per_step: int = 0
    lr: float = 1.0  # paper: 1.0 (Criteo), 5e-2 (Avazu)
    policy: Any = None  # core.Policy; None -> FREQ_LFU
    dtypes: Dtypes = Dtypes(param=jnp.float32, compute=jnp.float32)
    use_pallas: bool = False

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    def emb_cfg(self, batch_size: Optional[int] = None, writeback: bool = True):
        from repro.core.policies import Policy

        b = batch_size or self.batch_size
        return ce.CachedEmbeddingConfig(
            vocab_sizes=self.vocab_sizes,
            dim=self.embed_dim,
            ids_per_step=b * self.n_sparse,
            cache_ratio=self.cache_ratio,
            buffer_rows=self.buffer_rows,
            policy=self.policy or Policy.FREQ_LFU,
            writeback=writeback,
            dtype=self.dtypes.param,
            max_unique_per_step=self.max_unique_per_step,
        )


class DLRM:
    def __init__(self, cfg: DLRMConfig):
        self.cfg = cfg
        f = cfg.n_sparse + 1  # embeddings + bottom-MLP output
        self.top_in = cfg.embed_dim + f * (f - 1) // 2
        self.optimizer = opt_lib.sgd(cfg.lr)

    # ----- params ----------------------------------------------------------
    def init(self, rng: jax.Array, counts: Optional[np.ndarray] = None) -> Dict[str, Any]:
        cfg = self.cfg
        k_emb, k_bot, k_top = jax.random.split(rng, 3)
        params, _ = split_params(
            {
                "bottom": mlp_init(k_bot, (cfg.n_dense,) + cfg.bottom_mlp, cfg.dtypes),
                "top": mlp_init(k_top, (self.top_in,) + cfg.top_mlp + (1,), cfg.dtypes),
            }
        )
        emb = ce.init_state(k_emb, self.emb_cfg_train, counts=counts)
        return {
            "params": params,
            "opt": self.optimizer.init(params),
            "emb": emb,
            "step": jnp.zeros((), jnp.int32),
        }

    @property
    def emb_cfg_train(self):
        return self.cfg.emb_cfg()

    # ----- forward ----------------------------------------------------------
    def interact(self, dense_vec: jnp.ndarray, emb: jnp.ndarray) -> jnp.ndarray:
        """Dot-product interaction: pairwise dots of [dense_vec] + embeddings."""
        b = dense_vec.shape[0]
        z = jnp.concatenate([dense_vec[:, None, :], emb], axis=1)  # [B, F+1, D]
        zz = jnp.einsum("bfd,bgd->bfg", z, z)
        f = z.shape[1]
        iu, ju = jnp.triu_indices(f, k=1)
        return zz[:, iu, ju]  # [B, F*(F-1)/2]

    def fwd(self, params, emb_rows, batch):
        cfg = self.cfg
        b = batch["dense"].shape[0]
        emb = emb_rows.reshape(b, cfg.n_sparse, cfg.embed_dim)
        emb = constrain(emb, "batch", None, None)
        dense_vec = mlp(params["bottom"], batch["dense"].astype(cfg.dtypes.compute), cfg.dtypes, final_act=True)
        x = jnp.concatenate([dense_vec, self.interact(dense_vec, emb)], axis=-1)
        logits = mlp(params["top"], x, cfg.dtypes)[:, 0]
        return logits, {}

    # ----- steps -------------------------------------------------------------
    def collect_ids(self, batch):
        emb_state_offsets_needed = batch["sparse"]  # [B, F] local per-field ids
        return emb_state_offsets_needed  # translated in train_step via globalize

    def train_step(self, state, batch):
        cfg = self.cfg
        emb_cfg = self.emb_cfg_train
        step = common.EmbTrainStep(
            emb_cfg=emb_cfg,
            optimizer=self.optimizer,
            collect_ids=lambda b: ce.globalize(state["emb"], b["sparse"]).reshape(-1),
            fwd=self.fwd,
            emb_lr=cfg.lr,
        )
        return step(state, batch)

    def serve_step(self, state, batch):
        """Inference: cache read path without writeback bookkeeping cost."""
        emb_cfg = self.cfg.emb_cfg(batch_size=batch["sparse"].shape[0], writeback=False)
        emb_state, _, emb = ce.embed_onehot(emb_cfg, state["emb"], batch["sparse"])
        logits, _ = self.fwd(state["params"], emb.reshape(-1, self.cfg.embed_dim), batch)
        return logits, emb_state

    # ----- specs -------------------------------------------------------------
    def input_specs(self, batch_size: int) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        return {
            "dense": jax.ShapeDtypeStruct((batch_size, cfg.n_dense), jnp.float32),
            "sparse": jax.ShapeDtypeStruct((batch_size, cfg.n_sparse), jnp.int32),
            "label": jax.ShapeDtypeStruct((batch_size,), jnp.float32),
        }
