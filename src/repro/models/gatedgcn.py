"""GatedGCN (arXiv:2003.00982 config: 16 layers, d_hidden=70, gated aggregator).

Covers the four assigned graph regimes:
  full_graph_sm  — Cora-scale full-batch node classification
  minibatch_lg   — Reddit-scale sampled training (real neighbor sampler in
                   ``repro.data.graphs``; model consumes padded blocks)
  ogb_products   — full-batch large (2.4M nodes / 62M edges), edge-sharded
  molecule       — batched small graphs, graph-level regression

Layers are homogeneous -> scan over stacked layer params (one compiled body).
Optionally the (ogb-scale) learnable node-id embedding is served through the
paper's cache (``cache_node_embed``) — see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.partitioning import constrain, prepend_axis, split_params
from repro.models import common
from repro.nn import gnn as G
from repro.nn.layers import Dtypes, dense, dense_init
from repro.optim import optimizers as opt_lib

__all__ = ["GatedGCNConfig", "GatedGCNModel"]


@dataclasses.dataclass(frozen=True)
class GatedGCNConfig:
    d_feat: int
    n_classes: int
    n_layers: int = 16
    d_hidden: int = 70
    task: str = "node"  # node | graph
    lr: float = 1e-3
    dtypes: Dtypes = Dtypes(param=jnp.float32, compute=jnp.float32)


class GatedGCNModel:
    def __init__(self, cfg: GatedGCNConfig):
        self.cfg = cfg
        self.optimizer = opt_lib.adam(cfg.lr)

    def init(self, rng):
        cfg = self.cfg
        k_in, k_e, k_layers, k_out = jax.random.split(rng, 4)
        lkeys = jax.random.split(k_layers, cfg.n_layers)
        stacked = jax.vmap(lambda k: G.gatedgcn_layer_init(k, cfg.d_hidden, cfg.dtypes))(lkeys)
        params, _ = split_params(
            {
                "embed_h": dense_init(k_in, cfg.d_feat, cfg.d_hidden, cfg.dtypes),
                "embed_e": dense_init(k_e, 1, cfg.d_hidden, cfg.dtypes),
                "layers": prepend_axis(stacked, "layer_groups"),
                "readout": dense_init(k_out, cfg.d_hidden, cfg.n_classes, cfg.dtypes),
            }
        )
        return {
            "params": params,
            "opt": self.optimizer.init(params),
            "step": jnp.zeros((), jnp.int32),
        }

    def fwd(self, params, batch):
        cfg = self.cfg
        src, dst = batch["src"], batch["dst"]
        h = dense(params["embed_h"], batch["feat"].astype(cfg.dtypes.compute), cfg.dtypes)
        h = constrain(h, "node", None)
        e = dense(params["embed_e"], jnp.ones((src.shape[0], 1), cfg.dtypes.compute), cfg.dtypes)
        e = constrain(e, "edge", None)

        def body(carry, lp):
            h, e = carry
            h, e = G.gatedgcn_layer(lp, h, e, src, dst, cfg.dtypes)
            return (h, e), None

        (h, e), _ = jax.lax.scan(body, (h, e), params["layers"])

        if cfg.task == "graph":
            gid = batch["graph_id"]
            n_graphs = batch["label"].shape[0]
            valid = (batch["node_mask"] > 0).astype(h.dtype)[:, None]
            pooled = jax.ops.segment_sum(h * valid, gid, num_segments=n_graphs)
            cnt = jax.ops.segment_sum(valid, gid, num_segments=n_graphs)
            h = pooled / jnp.maximum(cnt, 1.0)
        logits = dense(params["readout"], h, cfg.dtypes)
        return logits

    def loss_fn(self, params, batch):
        cfg = self.cfg
        logits = self.fwd(params, batch)
        if cfg.task == "graph":
            # molecule regression head: use class-0 output as the scalar
            pred = logits[:, 0]
            return jnp.mean((pred - batch["label"].astype(jnp.float32)) ** 2), logits
        mask = batch["label_mask"].astype(jnp.float32)
        ll = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        picked = jnp.take_along_axis(ll, batch["label"][:, None].astype(jnp.int32), axis=-1)[:, 0]
        return -jnp.sum(picked * mask) / jnp.maximum(mask.sum(), 1.0), logits

    def train_step(self, state, batch):
        (loss, logits), grads = jax.value_and_grad(self.loss_fn, has_aux=True)(
            state["params"], batch
        )
        params, opt_state = self.optimizer.update(grads, state["opt"], state["params"], state["step"])
        new_state = dict(state, params=params, opt=opt_state, step=state["step"] + 1)
        return new_state, {"loss": loss}

    def serve_step(self, state, batch):
        return self.fwd(state["params"], batch), None

    def input_specs(
        self, n_nodes: int, n_edges: int, n_targets: int = 0, n_graphs: int = 0
    ) -> Dict[str, jax.ShapeDtypeStruct]:
        cfg = self.cfg
        specs = {
            "feat": jax.ShapeDtypeStruct((n_nodes, cfg.d_feat), jnp.float32),
            "src": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
            "dst": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
        }
        if cfg.task == "graph":
            specs["graph_id"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
            specs["node_mask"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
            specs["label"] = jax.ShapeDtypeStruct((n_graphs,), jnp.float32)
        else:
            specs["label"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
            specs["label_mask"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
        return specs
