"""Incremental re-ranking refresh: the adaptive half of the frequency module.

The paper's FREQ_LFU rank is frozen at init, so when the hot set drifts the
cache keeps protecting yesterday's hot rows (the regime runtime re-tiering
targets — Ren et al., ML-guided memory optimization for DLRM inference on
tiered memory).  This module closes the loop: every N steps a host-side pass
reads the online decayed counters (:class:`repro.core.freq.FreqTracker`,
updated in-jit by ``cache.plan_prepare``), re-ranks, and applies a BOUNDED
incremental permutation — at most ``max_swaps`` rank pairs, and only pairs
that cross the cache-capacity boundary (a swap that stays inside the hot or
the cold region cannot change any eviction outcome under FREQ_LFU, so it is
pure churn and never emitted).

A refresh is *pure reindexing*: ranks are names, not values.  Each swap

  1. writes the pair's resident rows (if any) back to the slow tier at their
     OLD rank positions (the dirty resident copy is authoritative — with a
     quantized host store this is the one codec round trip a refresh costs,
     which is why refresh purity is bitwise for fp32 and codec-noise-bounded
     for fp16/int8);
  2. invalidates their residency (``slot_to_row``/``row_to_slot`` -> -1; the
     rows simply re-fault on next use — empty slots evict first, so the freed
     slots are the next victims anyway);
  3. swaps the slow-tier payload+sideband rows and the tracker slices, and
     remaps ``idx_map`` through the rank permutation.

Model outputs are bitwise unchanged across the call (fp32): every raw id
still resolves — through the new ``idx_map`` and the permuted slow tier — to
exactly the value it resolved to before.  What changes is the FUTURE: the
promoted rows now live at hot ranks, so FREQ_LFU stops thrash-evicting them.

Sharded collections use the same plan; physical rows live at fixed
``(owner shard, local row)`` homes keyed by rank (``rank_owner``/
``rank_local`` never change), so a swap moves slow-tier row CONTENT between
the two ranks' homes — a cross-shard row exchange when the homes differ,
metered by ``RefreshConfig.exchange_budget`` (pairs beyond the budget are
deferred to the next refresh; same-shard pairs are always applied).  With one
shard the homes are the ranks themselves and the pass is bit-identical to
the unsharded one.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import INT_COUNTERS, contract
from repro.core import freq as freq_lib
from repro.core import transmitter
from repro.store import HostStore

__all__ = [
    "RefreshConfig",
    "RefreshReport",
    "plan_swaps",
    "refresh_cached_slab",
    "refresh_sharded_slab",
]


@dataclasses.dataclass(frozen=True)
class RefreshConfig:
    """Knobs of one refresh pass (per slab)."""

    max_swaps: int = 256  # bounded top-K rank pairs per slab per refresh
    min_gain: float = 0.0  # extra decayed mass a cold row must carry over the
    # hot row it displaces (hysteresis against boundary flapping; the
    # comparison is already strict, so 0.0 only suppresses exact ties)
    exchange_budget: Optional[int] = None  # sharded: max slow-tier rows moved
    # ACROSS shards per refresh (2 per cross-shard pair); None = unbounded,
    # 0 = same-shard swaps only.  Unsharded slabs ignore it.
    rebalance_threshold: Optional[float] = None  # sharded: when the LIVE
    # routed-traffic imbalance (max/mean of per-shard decayed tracker mass)
    # exceeds this after the swap pass, re-run ``assign_devices`` on the live
    # scores and re-home every rank (``_apply_rebalance``).  None = homes
    # stay where init placed them (the historical behavior).


@dataclasses.dataclass
class RefreshReport:
    """Host-side summary of one collection-wide refresh pass (per slab)."""

    swaps: Dict[str, int] = dataclasses.field(default_factory=dict)
    rows_moved: Dict[str, int] = dataclasses.field(default_factory=dict)
    cross_shard_rows: Dict[str, int] = dataclasses.field(default_factory=dict)
    deferred_swaps: Dict[str, int] = dataclasses.field(default_factory=dict)
    rebalance_moves: Dict[str, int] = dataclasses.field(default_factory=dict)
    rebalance_imbalance: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, slab: str, stats: Dict[str, int]) -> None:
        self.swaps[slab] = stats["swaps"]
        self.rows_moved[slab] = stats["rows_moved"]
        self.cross_shard_rows[slab] = stats.get("cross_shard_rows", 0)
        self.deferred_swaps[slab] = stats.get("deferred_swaps", 0)
        self.rebalance_moves[slab] = stats.get("rebalance_moves", 0)
        self.rebalance_imbalance[slab] = stats.get("rebalance_imbalance", 1.0)

    @property
    def total_swaps(self) -> int:
        return sum(self.swaps.values())

    @property
    def total_rows_moved(self) -> int:
        return sum(self.rows_moved.values())


def plan_swaps(
    scores: np.ndarray,
    hot: np.ndarray,
    max_swaps: int,
    min_gain: float = 0.0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Pick the bounded set of capacity-boundary rank swaps.

    ``scores`` are the decayed access masses in CURRENT rank order and
    ``hot`` marks the ranks inside the cache-capacity (warm-set) boundary.
    Pairs the coldest hot ranks against the hottest cold ranks, hottest
    mismatch first, and keeps a pair only while the cold row's mass exceeds
    the hot row's by more than ``min_gain`` — gains are non-increasing along
    the pairing, so the kept set is a prefix.  Deterministic: stable sorts
    with rank tie-breaks (every host derives the identical plan, the same
    requirement ``build_freq_stats`` meets).

    Returns ``(a, b)``: demoted hot ranks and promoted cold ranks, pairwise.
    """
    hot = np.asarray(hot, bool)
    hot_idx = np.nonzero(hot)[0]
    cold_idx = np.nonzero(~hot)[0]
    k = min(int(max_swaps), hot_idx.size, cold_idx.size)
    if k <= 0:
        return np.empty((0,), np.int64), np.empty((0,), np.int64)
    s = np.asarray(scores, np.float64)
    # coldest hot ranks first; score ties -> larger rank first (the row the
    # old ranking already believed colder)
    order_h = np.lexsort((-hot_idx, s[hot_idx]))
    # hottest cold ranks first; score ties -> smaller rank first
    order_c = np.lexsort((cold_idx, -s[cold_idx]))
    a = hot_idx[order_h[:k]]
    b = cold_idx[order_c[:k]]
    keep = s[b] > s[a] + min_gain
    n = int(np.argmax(~keep)) if not keep.all() else k  # first rejected pair
    return a[:n].astype(np.int64), b[:n].astype(np.int64)


def _permute_rows(tree: Any, to: jnp.ndarray, frm: jnp.ndarray) -> Any:
    """Scatter-swap: row ``to[i]`` of every leaf takes row ``frm[i]``'s
    content (O(swaps) rows touched, not O(vocab)); OOB ``to`` lanes drop."""
    def perm(leaf):
        return leaf.at[to].set(leaf[frm], mode="drop")

    return jax.tree_util.tree_map(perm, tree)


def _permute_store(full: Any, to: jnp.ndarray, frm: jnp.ndarray) -> Any:
    """Permute slow-tier rows.  A ``HostStore`` permutes payload AND sideband
    ENCODED — no decode/re-encode, so the move itself is bit-exact for every
    codec; raw pytrees permute in place."""
    if isinstance(full, HostStore):
        return HostStore(
            data=_permute_rows(full.data, to, frm),
            sideband=_permute_rows(full.sideband, to, frm),
            codec=full.codec,
            out_dtype=full.out_dtype,
        )
    return _permute_rows(full, to, frm)


# ---------------------------------------------------------------------------
# unsharded slab surgery
# ---------------------------------------------------------------------------


@contract(int_counters=INT_COUNTERS)
@functools.partial(jax.jit, static_argnames=("buffer_rows", "writeback"))
def _apply_swaps(
    full: Any,
    cache: Any,
    idx_map: jnp.ndarray,
    a: jnp.ndarray,  # int32 [K] demoted hot ranks (-1 padding)
    b: jnp.ndarray,  # int32 [K] promoted cold ranks (-1 padding)
    valid: jnp.ndarray,  # bool [K]
    *,
    buffer_rows: int,
    writeback: bool,
):
    """Jitted state surgery for one swap set (padded to a static K so a slab
    compiles once): write back, invalidate, permute, remap.  Returns
    ``(full', cache', idx_map')``."""
    vocab = cache.row_to_slot.shape[0]
    capacity = cache.slot_to_row.shape[0]
    involved = jnp.concatenate([a, b])
    inv_valid = jnp.concatenate([valid, valid])
    # 1) write the pairs' dirty resident rows back at their OLD ranks
    slots = cache.row_to_slot.at[
        jnp.where(inv_valid, involved, 0)
    ].get(mode="fill", fill_value=-1)
    slots = jnp.where(inv_valid, slots, -1)
    active = slots >= 0
    if writeback:
        full = transmitter.move_rows(
            cache.cached_rows, full, slots, involved, active,
            buffer_rows=buffer_rows,
        )
    # 2) invalidate residency (the rows re-fault at their new ranks)
    slot_to_row = cache.slot_to_row.at[
        jnp.where(active, slots, capacity)
    ].set(-1, mode="drop")
    row_to_slot = cache.row_to_slot.at[
        jnp.where(inv_valid, involved, vocab)
    ].set(-1, mode="drop")
    # 3) swap slow-tier rows + tracker slices; remap idx_map through P
    to = jnp.where(inv_valid, involved, vocab)
    frm = jnp.where(inv_valid, jnp.concatenate([b, a]), 0)
    full = _permute_store(full, to, frm)
    tr = cache.tracker
    tr = dataclasses.replace(
        tr,
        score=_permute_rows(tr.score, to, frm),
        last_touch=_permute_rows(tr.last_touch, to, frm),
        refresh_swaps=tr.refresh_swaps + jnp.sum(valid).astype(jnp.int32),
        refresh_rows=tr.refresh_rows + jnp.sum(inv_valid).astype(jnp.int32),
    )
    perm = jnp.arange(vocab, dtype=jnp.int32)
    perm = perm.at[jnp.where(valid, a, vocab)].set(
        b.astype(jnp.int32), mode="drop"
    )
    perm = perm.at[jnp.where(valid, b, vocab)].set(
        a.astype(jnp.int32), mode="drop"
    )
    idx_map = perm[idx_map]
    cache = dataclasses.replace(
        cache, slot_to_row=slot_to_row, row_to_slot=row_to_slot, tracker=tr
    )
    return full, cache, idx_map


def _pad_pairs(a: np.ndarray, b: np.ndarray, k: int):
    """Pad a swap set to the static length ``k`` (-1 / False padding)."""
    valid = np.zeros((k,), bool)
    valid[: a.size] = True
    ap = np.full((k,), -1, np.int32)
    bp = np.full((k,), -1, np.int32)
    ap[: a.size] = a
    bp[: b.size] = b
    return jnp.asarray(ap), jnp.asarray(bp), jnp.asarray(valid)


def refresh_cached_slab(
    ccfg, slab, cfg: RefreshConfig, writeback: bool = True
) -> Tuple[Any, Dict[str, int]]:
    """One refresh pass over an unsharded ``collection.CachedSlab``.

    ``ccfg`` is the slab's ``cache.CacheConfig`` (half-life + buffer size;
    geometry comes from the STATE, as everywhere in ``core.cache``).  The
    swap planning runs host-side on device_get'd counters; the state surgery
    is one jitted call on swap arrays padded to ``cfg.max_swaps`` (compiled
    once per slab geometry).  With ``writeback=False`` (read-only serve
    states) resident rows are clean, so the write-back step is skipped and
    only the invalidate+permute runs.  Returns ``(slab', stats)``; a no-swap
    pass returns the slab unchanged.
    """
    cache = slab.cache
    capacity = int(cache.slot_to_row.shape[0])
    vocab = int(cache.row_to_slot.shape[0])
    step = int(jax.device_get(cache.step))
    tr = cache.tracker
    scores = freq_lib.decayed_scores(
        jax.device_get(tr.score), jax.device_get(tr.last_touch), step,
        ccfg.freq_half_life,
    )
    hot = np.arange(vocab) < capacity
    a, b = plan_swaps(scores, hot, cfg.max_swaps, cfg.min_gain)
    if a.size == 0:
        return slab, {"swaps": 0, "rows_moved": 0}
    ap, bp, valid = _pad_pairs(a, b, int(cfg.max_swaps))
    full, new_cache, idx_map = _apply_swaps(
        slab.full, cache, slab.idx_map, ap, bp, valid,
        buffer_rows=ccfg.buffer_rows, writeback=writeback,
    )
    new_slab = dataclasses.replace(
        slab, full=full, cache=new_cache, idx_map=idx_map
    )
    return new_slab, {"swaps": int(a.size), "rows_moved": int(2 * a.size)}


# ---------------------------------------------------------------------------
# sharded slab surgery
# ---------------------------------------------------------------------------


def _flat_view(full: Any) -> Any:
    """Shard-stacked slow tier ([S, vs, ...] leaves) as a flat [S*vs, ...]
    tree/store, so flat home ``owner * vs + local`` addresses rows."""
    def rs(v):
        return v.reshape((-1,) + v.shape[2:])

    if isinstance(full, HostStore):
        return HostStore(
            data={k: rs(v) for k, v in full.data.items()},
            sideband={k: rs(v) for k, v in full.sideband.items()},
            codec=full.codec,
            out_dtype=full.out_dtype,
        )
    return jax.tree_util.tree_map(rs, full)


def _restack_like(flat: Any, like: Any) -> Any:
    """Inverse of :func:`_flat_view`: reshape a flat tree/store back to the
    shard-stacked leaf shapes of ``like``."""
    if isinstance(flat, HostStore):
        return HostStore(
            data={k: v.reshape(like.data[k].shape) for k, v in flat.data.items()},
            sideband={
                k: v.reshape(like.sideband[k].shape) for k, v in flat.sideband.items()
            },
            codec=flat.codec,
            out_dtype=flat.out_dtype,
        )
    return jax.tree_util.tree_map(lambda v, l: v.reshape(l.shape), flat, like)


def _read_flat_rows(full: Any, idx: jnp.ndarray) -> jnp.ndarray:
    """Decoded ``weight`` rows at flat homes ``idx`` (-1 lanes -> zero rows)
    of a stacked slow tier."""
    flat = _flat_view(full)
    if isinstance(flat, HostStore):
        return transmitter._gather_store_rows(flat, idx)["weight"]
    return transmitter.gather_rows(flat, idx)["weight"]


@contract(int_counters=INT_COUNTERS)
@functools.partial(jax.jit, static_argnames=("buffer_rows", "writeback"))
def _apply_swaps_sharded(
    full: Any,
    cache: Any,
    idx_map: jnp.ndarray,
    rep: Any,  # sharded.RepArena (or None): the slab's replicated hot head
    rows_img: jnp.ndarray,  # int32 [S, 2K] involved local rows (-1 off-shard)
    pa: jnp.ndarray,  # int32 [K] flat home of each demoted rank (-1 pad)
    pb: jnp.ndarray,  # int32 [K] flat home of each promoted rank (-1 pad)
    a: jnp.ndarray,  # int32 [K] demoted ranks (-1 pad)
    b: jnp.ndarray,  # int32 [K] promoted ranks (-1 pad)
    valid: jnp.ndarray,  # bool [K]
    swaps_ps: jnp.ndarray,  # int32 [S] per-shard swap shares (telemetry)
    rows_ps: jnp.ndarray,  # int32 [S] per-shard moved-row shares
    *,
    buffer_rows: int,
    writeback: bool,
):
    """Jitted sharded surgery (padded to static K; compiled once per slab):
    per-shard write-back + invalidate under ``vmap``, then the flat content
    exchange between the swapped ranks' fixed homes.

    Replicated boundary: a demoted rank ``a < K`` lives in the replicated
    arena, whose row (SGD-updated every step) and tracker slice are the
    authoritative copies — any per-shard cache copy of its home never
    diverges from init.  Before the home exchange the arena row + tracker
    slice are pushed into the rank's home (so the exchange carries them to
    the promoted rank's cold home); after it, the arena pulls the promoted
    content back from the now-swapped home.  The arena and per-shard plan
    clocks tick together, so raw (score, last_touch) interchange is exact."""
    S, vs = cache.row_to_slot.shape
    cap = cache.slot_to_row.shape[1]
    K = int(rep.rows.shape[0]) if rep is not None else 0
    vocab = idx_map.shape[0]

    def shard_surgery(full_s, cache_s, rows_s):
        slots = cache_s.row_to_slot.at[
            jnp.where(rows_s >= 0, rows_s, 0)
        ].get(mode="fill", fill_value=-1)
        slots = jnp.where(rows_s >= 0, slots, -1)
        act = slots >= 0
        if writeback:
            full_s = transmitter.move_rows(
                cache_s.cached_rows, full_s, slots, rows_s, act,
                buffer_rows=buffer_rows,
            )
        row_to_slot = cache_s.row_to_slot.at[
            jnp.where(rows_s >= 0, rows_s, vs)
        ].set(-1, mode="drop")
        slot_to_row = cache_s.slot_to_row.at[
            jnp.where(act, slots, cap)
        ].set(-1, mode="drop")
        return full_s, dataclasses.replace(
            cache_s, row_to_slot=row_to_slot, slot_to_row=slot_to_row
        )

    full, cache = jax.vmap(shard_surgery)(full, cache, rows_img)

    def fput(leaf2d, idx, vals):
        fl = leaf2d.reshape((-1,) + leaf2d.shape[2:])
        return fl.at[idx].set(vals, mode="drop").reshape(leaf2d.shape)

    if K:
        # demoted replicated ranks: push the arena's authoritative row +
        # tracker slice into the rank's home (overwrites any never-diverged
        # cache writeback above) so the generic exchange carries them.
        am = valid & (a >= 0) & (a < K)
        src = jnp.where(am, a, K)
        dst = jnp.where(am, pa, S * vs)
        if writeback:
            rows_push = jnp.take(rep.rows, src, axis=0, mode="fill", fill_value=0)
            flatf = transmitter.write_rows(
                {"weight": rows_push}, _flat_view(full), dst, am,
                buffer_rows=buffer_rows,
            )
            full = _restack_like(flatf, full)
        tr0 = cache.tracker
        cache = dataclasses.replace(
            cache,
            tracker=dataclasses.replace(
                tr0,
                score=fput(tr0.score, dst,
                           jnp.take(rep.score, src, mode="fill", fill_value=0)),
                last_touch=fput(tr0.last_touch, dst,
                                jnp.take(rep.last_touch, src, mode="fill",
                                         fill_value=0)),
            ),
        )

    # swap slow-tier content between the two ranks' flat homes
    vv = jnp.concatenate([valid, valid])
    to = jnp.where(vv, jnp.concatenate([pa, pb]), S * vs)
    frm = jnp.where(vv, jnp.concatenate([pb, pa]), 0)

    def flat_perm(leaf):
        flatl = leaf.reshape((-1,) + leaf.shape[2:])
        flatl = flatl.at[to].set(flatl[frm], mode="drop")
        return flatl.reshape(leaf.shape)

    if isinstance(full, HostStore):
        full = HostStore(
            data={k: flat_perm(v) for k, v in full.data.items()},
            sideband={k: flat_perm(v) for k, v in full.sideband.items()},
            codec=full.codec,
            out_dtype=full.out_dtype,
        )
    else:
        full = jax.tree_util.tree_map(flat_perm, full)
    tr = cache.tracker
    tr = dataclasses.replace(
        tr,
        score=flat_perm(tr.score),
        last_touch=flat_perm(tr.last_touch),
        refresh_swaps=tr.refresh_swaps + swaps_ps,
        refresh_rows=tr.refresh_rows + rows_ps,
    )
    cache = dataclasses.replace(cache, tracker=tr)

    if K:
        # pull the promoted content back into the arena: after the exchange,
        # home of rank a holds the promoted raw id's row + tracker slice.
        idxp = jnp.where(am, pa, -1)
        rows_new = _read_flat_rows(full, idxp)
        arena_dst = jnp.where(am, a, K)
        flsc = tr.score.reshape(-1)
        fllt = tr.last_touch.reshape(-1)
        safe = jnp.where(am, pa, 0)
        rep = dataclasses.replace(
            rep,
            rows=rep.rows.at[arena_dst].set(
                rows_new.astype(rep.rows.dtype), mode="drop"
            ),
            score=rep.score.at[arena_dst].set(flsc[safe], mode="drop"),
            last_touch=rep.last_touch.at[arena_dst].set(fllt[safe], mode="drop"),
        )

    perm = jnp.arange(vocab, dtype=jnp.int32)
    perm = perm.at[jnp.where(valid, a, vocab)].set(
        b.astype(jnp.int32), mode="drop"
    )
    perm = perm.at[jnp.where(valid, b, vocab)].set(
        a.astype(jnp.int32), mode="drop"
    )
    idx_map = perm[idx_map]
    return full, cache, idx_map, rep


def refresh_sharded_slab(
    ccfg, slab, cfg: RefreshConfig, writeback: bool = True
) -> Tuple[Any, Dict[str, int]]:
    """One refresh pass over a ``sharded.ShardedSlab``.

    Rank homes (``rank_owner``/``rank_local``) are FIXED — a swap exchanges
    slow-tier row content between the two ranks' physical homes, so the
    balance ``assign_devices`` computed for the hot positions is inherited by
    whichever rows are hot now.  Pairs whose homes sit on different shards
    are cross-shard row exchanges, metered by ``cfg.exchange_budget`` (kept
    pairs stay a prefix of the gain ordering among same-shard pairs plus the
    budget-affordable cross-shard ones).  With ``num_shards == 1`` every
    quantity reduces to the unsharded pass bit-for-bit.
    """
    cache = slab.cache
    rep = getattr(slab, "rep", None)
    K = int(rep.rows.shape[0]) if rep is not None else 0
    S, vs = cache.row_to_slot.shape
    cap = int(cache.slot_to_row.shape[1])
    steps = np.asarray(jax.device_get(cache.step))  # [S]; equal across shards
    tr = cache.tracker
    local_scores = freq_lib.decayed_scores(
        jax.device_get(tr.score), jax.device_get(tr.last_touch),
        steps[:, None], ccfg.freq_half_life,
    )  # [S, vs]
    owner = np.asarray(jax.device_get(slab.rank_owner), np.int64)
    local = np.asarray(jax.device_get(slab.rank_local), np.int64)
    vocab = owner.shape[0]
    scores = local_scores[owner, local]  # [vocab], rank order
    if K:
        # replicated ranks bypass the per-shard plans, so their signal lives
        # in the arena tracker (same plan clock as the per-shard caches).
        scores[:K] = freq_lib.decayed_scores(
            jax.device_get(rep.score), jax.device_get(rep.last_touch),
            float(jax.device_get(rep.step)), ccfg.freq_half_life,
        )
    # hot = inside the per-shard warm boundary OR in the replicated arena —
    # the swap set crosses the replicated boundary like the capacity one.
    hot = (local < cap) | (np.arange(vocab) < K)
    a, b = plan_swaps(scores, hot, cfg.max_swaps, cfg.min_gain)
    if a.size and cfg.exchange_budget is not None:
        cross = owner[a] != owner[b]
        keep = ~cross | (np.cumsum(cross) * 2 <= cfg.exchange_budget)
        deferred = int((~keep).sum())
        a, b = a[keep], b[keep]
    else:
        deferred = 0
    if a.size == 0:
        return slab, {"swaps": 0, "rows_moved": 0, "cross_shard_rows": 0,
                      "deferred_swaps": deferred}

    k = int(cfg.max_swaps)
    involved = np.concatenate([a, b])
    # per-shard image of the involved ranks' local rows (-1 off-shard/pad)
    rows_img = np.full((S, 2 * k), -1, np.int32)
    rows_img[owner[involved], np.arange(involved.size)] = local[involved]
    # flat homes, padded to the static K
    pa = np.full((k,), -1, np.int32)
    pb = np.full((k,), -1, np.int32)
    pa[: a.size] = owner[a] * vs + local[a]
    pb[: b.size] = owner[b] * vs + local[b]
    ap, bp, valid = _pad_pairs(a, b, k)
    # per-shard counter shares: swaps by the demoted (hot) rank's home, rows
    # by each changed home — both sum to the collection-wide totals.
    swaps_ps = np.bincount(owner[a], minlength=S).astype(np.int32)
    rows_ps = np.bincount(owner[involved], minlength=S).astype(np.int32)
    full, new_cache, idx_map, new_rep = _apply_swaps_sharded(
        slab.full, cache, slab.idx_map, rep, jnp.asarray(rows_img),
        jnp.asarray(pa), jnp.asarray(pb), ap, bp, valid,
        jnp.asarray(swaps_ps), jnp.asarray(rows_ps),
        buffer_rows=ccfg.buffer_rows, writeback=writeback,
    )
    kw = {"rep": new_rep} if rep is not None else {}
    new_slab = dataclasses.replace(
        slab, full=full, cache=new_cache, idx_map=idx_map, **kw
    )
    cross_rows = int(2 * np.sum(owner[a] != owner[b]))
    return new_slab, {
        "swaps": int(a.size),
        "rows_moved": int(involved.size),
        "cross_shard_rows": cross_rows,
        "deferred_swaps": deferred,
    }


# ---------------------------------------------------------------------------
# traffic-aware re-homing (sharded re-balance)
# ---------------------------------------------------------------------------


@contract(int_counters=INT_COUNTERS)
@functools.partial(jax.jit, static_argnames=("buffer_rows", "writeback"))
def _apply_rebalance(
    full: Any,
    cache: Any,
    src_for_dest: jnp.ndarray,  # int32 [S*vs] new flat home -> old flat home
    *,
    buffer_rows: int,
    writeback: bool,
):
    """Jitted re-home surgery for one sharded slab: write every resident row
    back (the dirty cache copy is authoritative), drop all residency, then
    permute the slow tier + tracker flat rows old-home -> new-home.

    ``src_for_dest`` is a full [S*vs] gather map (identity on positions that
    stay put or are padding).  Moving ENCODED payload + sideband keeps the
    move itself bit-exact for every codec; rank identities (``idx_map``) are
    untouched — this is re-homing, not re-ranking — so lookups through the
    caller-installed new ``rank_owner``/``rank_local`` resolve to exactly the
    pre-rebalance values (codec round trip for dirty rows, as everywhere).
    The caller re-warms the emptied per-shard caches afterwards."""
    cap = cache.slot_to_row.shape[1]

    def shard_flush(full_s, cache_s):
        slots = jnp.arange(cap, dtype=jnp.int32)
        rows = cache_s.slot_to_row
        act = rows >= 0
        if writeback:
            full_s = transmitter.move_rows(
                cache_s.cached_rows, full_s, slots, rows, act,
                buffer_rows=buffer_rows,
            )
        return full_s, dataclasses.replace(
            cache_s,
            slot_to_row=jnp.full_like(cache_s.slot_to_row, -1),
            row_to_slot=jnp.full_like(cache_s.row_to_slot, -1),
        )

    full, cache = jax.vmap(shard_flush)(full, cache)

    def flat_perm(leaf):
        flatl = leaf.reshape((-1,) + leaf.shape[2:])
        return flatl[src_for_dest].reshape(leaf.shape)

    if isinstance(full, HostStore):
        full = HostStore(
            data={k: flat_perm(v) for k, v in full.data.items()},
            sideband={k: flat_perm(v) for k, v in full.sideband.items()},
            codec=full.codec,
            out_dtype=full.out_dtype,
        )
    else:
        full = jax.tree_util.tree_map(flat_perm, full)
    tr = cache.tracker
    tr = dataclasses.replace(
        tr, score=flat_perm(tr.score), last_touch=flat_perm(tr.last_touch)
    )
    cache = dataclasses.replace(cache, tracker=tr)
    return full, cache
