"""The dynamic module (paper §4.3, Algorithm 1) as a functional, jittable JAX op.

State layout (all device-resident, mirroring the paper's "green boxes"):

  cached_rows   pytree; each leaf [capacity, ...]   the CUDA-Cached-Weight analogue
                (leaf 0 is the weight; extra leaves carry per-row optimizer state)
  slot_to_row   int32 [capacity]   freq-ranked row held by each slot (-1 = empty)
                (the paper's ``cached_idx_map``)
  row_to_slot   int32 [vocab]      inverse map (-1 = not cached); 4 B/row ~= 0.8 %
                overhead of a dim-128 fp32 table, same trade the paper makes
  last_used / use_count  int32 [capacity]  only read by non-paper policies
  counters      hit/miss/transfer telemetry (int64 scalars)

Shapes are static: each ``prepare`` call ingests a fixed-size padded id vector,
takes a fixed-size ``unique``, and drives the bounded-buffer transmitter for a
fixed number of rounds — the compile-time promotion of the paper's "strictly
limit the buffer size / complete the transfer multiple times".

Invariant (tested property): after ``prepare``, every id of the batch maps to
a resident slot, and lookups through the cache are bit-identical to lookups
into an uncached table — the cache is pure data movement, which is why the
paper's accuracy matches the baseline.

Host tier: ``full_rows`` may be either a raw pytree (leaves [vocab, ...]) or
a :class:`repro.store.HostStore` — the mixed-precision host-side container.
``apply_plan`` / ``flush`` / ``warmup`` only ever touch it through the
transmitter, which is codec-aware: loads dequantize the staging block on
arrival, evictions/flushes quantize before the block crosses the link.  With
the fp32 codec the store path is bit-identical to the raw-pytree path; with
fp16/int8 the cache invariant weakens from bit-exact to codec-roundtrip-exact
(resident rows are still authoritative full-precision copies).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.contracts import INT_COUNTERS, contract
from repro.core import freq as freq_lib
from repro.core import transmitter
from repro.core.policies import Policy, eviction_key
from repro.kernels.cache_ops import ops as cache_ops
from repro.store.arena import ArenaStore

__all__ = [
    "CacheConfig",
    "CacheState",
    "CachePlan",
    "init_cache",
    "plan_prepare",
    "apply_plan",
    "prepare",
    "lookup_slots",
    "flush",
    "warmup",
]

_EMPTY = jnp.array(-1, jnp.int32)
_BIG = jnp.iinfo(jnp.int32).max // 2


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    vocab: int  # total rows of the (concatenated, freq-ordered) table
    capacity: int  # cached rows (= cache_ratio * vocab)
    ids_per_step: int  # static size of the flattened id vector per prepare()
    buffer_rows: int = 65536  # transmitter staging-block rows per round
    policy: Policy = Policy.FREQ_LFU
    writeback: bool = True  # False for inference (cache rows stay clean)
    protect_via_inverse: bool = True  # beyond-paper DEFAULT: O(K) scatter via
    # the inverse map instead of the paper's isin for the eviction "backlist"
    # (bit-identical; XLA lowers the isin as a [C x K] outer compare — the
    # entire memory roofline term of every recsys cell. False = paper-faithful
    # ablation. See EXPERIMENTS.md §Perf fm.)
    max_unique_per_step: int = 0  # 0 = worst case (= ids_per_step); smaller
    # values bound the per-step unique buffer (the same philosophy as the
    # paper's strict buffer limit).  Overflow — more distinct rows in a batch
    # than the bound — is counted in ``state.uniq_overflows`` and must stay 0
    # for exactness (the trainer asserts this; tests property-check it).
    arena_precision: str = "fp32"  # device-arena tail codec: "fp32" keeps the
    # raw pre-tiering dict (bit-identical); "fp16"/"int8" store the arena as a
    # frequency-tiered ``store.ArenaStore`` — fp32 head for the hottest slots,
    # encoded tail for the colder residents.  ("auto" is resolved to one of
    # these by the collection's PrecisionPolicy before a CacheConfig exists.)
    arena_head_ratio: float = 0.25  # fraction of capacity kept fp32 when tiered
    freq_half_life: int = 1024  # PLAN CALLS for a row's decayed access
    # counter (and the rolling hit-rate window) to halve — the adaptive
    # frequency engine's memory length.  The tracker clock is ``state.step``,
    # which advances once per ``plan_prepare``: in the serial trainer that is
    # one trainer step, but under group scheduling (pipeline_depth = k) only
    # group leaders plan, so the decay timescale stretches to k trainer steps
    # per tick — divide the half-life by the depth if you tune it to a drift
    # timescale measured in steps (same clock caveat as the hits/misses
    # sampling documented in ``plan_prepare``).  Tracking is always on (two
    # O(K) scatters per plan); the counters only influence behavior when a
    # ``core.refresh`` pass is invoked, so untouched runs stay bit-identical.
    use_pallas_plan: bool = False  # route planning through the bounded-top-K
    # + fused-dedup kernels (kernels/cache_ops): no capacity-sized sort
    # anywhere in plan_prepare.  Bit-identical to the default route (property
    # tested); False keeps the historical XLA route as the exactness oracle.
    chunk_rows: int = 0  # slow-tier staging granularity: 0 moves scattered
    # rows (historical path); > 0 groups each transmitter round's rows into
    # contiguous ``chunk_rows``-row chunks so host<->device traffic issues as
    # few large copies (the paper's chunk-based manager).  Bit-identical
    # either way; values that do not divide the vocab fall back to rows.

    def __post_init__(self):
        if self.capacity < self.unique_size:
            raise ValueError(
                f"cache capacity {self.capacity} must hold one batch's unique rows "
                f"(<= {self.unique_size})"
            )
        if self.arena_precision not in ("fp32", "fp16", "int8"):
            raise ValueError(
                f"arena_precision must be fp32/fp16/int8 at the cache level "
                f"(auto resolves above), got {self.arena_precision!r}"
            )
        if not (0.0 < self.arena_head_ratio <= 1.0):
            raise ValueError(f"arena_head_ratio must be in (0, 1], got {self.arena_head_ratio}")
        if self.chunk_rows < 0:
            raise ValueError(f"chunk_rows must be >= 0, got {self.chunk_rows}")

    @property
    def unique_size(self) -> int:
        # number of distinct rows a step may touch
        k = min(self.ids_per_step, self.vocab)
        if self.max_unique_per_step:
            k = min(k, self.max_unique_per_step)
        return k

    @property
    def head_capacity(self) -> int:
        """Slots kept fp32 when the arena is tiered (all of them for fp32)."""
        if self.arena_precision == "fp32":
            return self.capacity
        return min(self.capacity, max(1, int(round(self.arena_head_ratio * self.capacity))))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CacheState:
    cached_rows: Any  # pytree, leaves [capacity, ...]
    slot_to_row: jnp.ndarray  # int32 [capacity]
    row_to_slot: jnp.ndarray  # int32 [vocab]
    last_used: jnp.ndarray  # int32 [capacity]
    use_count: jnp.ndarray  # int32 [capacity]
    step: jnp.ndarray  # int32 []
    hits: jnp.ndarray  # int32 [] id-level hits (telemetry; x64 is off)
    misses: jnp.ndarray  # int32 [] unique-row misses (= rows moved host->device)
    evictions: jnp.ndarray  # int32 [] rows written back device->host
    uniq_overflows: jnp.ndarray  # int32 [] steps whose distinct rows > unique_size
    tier_promotions: jnp.ndarray  # int32 [] rows loaded INTO the fp32 head tier
    tier_demotions: jnp.ndarray  # int32 [] resident rows displaced OUT of it
    # (both always 0 for a raw fp32 arena — every slot is the head then)
    tracker: freq_lib.FreqTracker  # online decayed per-row counters (core.freq)

    def hit_rate(self) -> jnp.ndarray:
        tot = self.hits + self.misses
        return jnp.where(tot > 0, self.hits / jnp.maximum(tot, 1), 0.0)


def init_cache(cfg: CacheConfig, row_tree_example: Any) -> CacheState:
    """Empty cache; ``row_tree_example`` gives per-row leaf shapes/dtypes.

    ``row_tree_example`` leaves have shape [..row dims..]; cached leaves get a
    leading ``capacity`` dim.
    """
    def z(leaf):
        return jnp.zeros((cfg.capacity,) + tuple(leaf.shape), leaf.dtype)

    cached_rows = jax.tree_util.tree_map(z, row_tree_example)
    if cfg.arena_precision != "fp32":
        # frequency-tiered arena: fp32 head + encoded tail.  Zeros encode to
        # zeros under both codecs, so the empty tiered arena decodes exactly
        # like the empty raw arena.
        cached_rows = ArenaStore.create(cached_rows, cfg.head_capacity, cfg.arena_precision)
    return CacheState(
        cached_rows=cached_rows,
        slot_to_row=jnp.full((cfg.capacity,), -1, jnp.int32),
        row_to_slot=jnp.full((cfg.vocab,), -1, jnp.int32),
        last_used=jnp.zeros((cfg.capacity,), jnp.int32),
        use_count=jnp.zeros((cfg.capacity,), jnp.int32),
        step=jnp.zeros((), jnp.int32),
        hits=jnp.zeros((), jnp.int32),
        misses=jnp.zeros((), jnp.int32),
        evictions=jnp.zeros((), jnp.int32),
        uniq_overflows=jnp.zeros((), jnp.int32),
        tier_promotions=jnp.zeros((), jnp.int32),
        tier_demotions=jnp.zeros((), jnp.int32),
        tracker=freq_lib.init_tracker(cfg.vocab),
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CachePlan:
    """The weight-free half of Algorithm 1: a movement program plus the
    post-apply index image.

    ``plan_prepare`` computes it from (index state, ids) alone — no weights
    are touched, so a plan for step t+1 can be built while step t's dense
    compute is still running.  ``apply_plan`` executes the row movement and
    installs the index image; ``prepare`` composes the two and is bit-exact
    with the former fused implementation.
    """

    # movement program (static length = unique_size [+ lookahead uniques])
    miss_rows: jnp.ndarray  # int32 [kv] freq-ranked rows to load (-1 inactive)
    victim_slots: jnp.ndarray  # int32 [kv] destination slots
    victim_rows: jnp.ndarray  # int32 [kv] rows being displaced (-1 = empty)
    load_active: jnp.ndarray  # bool [kv]
    evict_active: jnp.ndarray  # bool [kv] displaced rows needing write-back
    # post-apply index image (everything in CacheState except cached_rows)
    slot_to_row: jnp.ndarray
    row_to_slot: jnp.ndarray
    last_used: jnp.ndarray
    use_count: jnp.ndarray
    step: jnp.ndarray
    hits: jnp.ndarray
    misses: jnp.ndarray
    evictions: jnp.ndarray
    uniq_overflows: jnp.ndarray
    tier_promotions: jnp.ndarray
    tier_demotions: jnp.ndarray
    tracker: freq_lib.FreqTracker  # post-plan decayed-counter image
    # per-lane resident slot for the CURRENT batch (-1 padding)
    slots: jnp.ndarray


# max_sort_size quotes the analysis.smoke geometry (ids_per_step=16): planning
# declares bounded-top-K, so only O(unique)-sized sorts are admissible.  The
# smoke config routes through ``use_pallas_plan`` (ROADMAP item 3: bounded
# top-K victim selection + fused prepare, kernels/cache_ops), which holds the
# bound; the ``use_pallas_plan=False`` oracle route keeps the full-capacity
# eviction argsort and is covered by bit-identity property tests instead.
@contract(max_sort_size=64, int_counters=INT_COUNTERS)
def plan_prepare(
    cfg: CacheConfig,
    state: CacheState,
    rows: jnp.ndarray,
    future_rows: Optional[jnp.ndarray] = None,
) -> CachePlan:
    """Pure planning half of ``prepare``: dedup, victim selection, movement
    plan and index bookkeeping — callable on ids alone, no weights touched.

    ``future_rows`` (optional, int32 [F], -1 padding) merges a lookahead
    window of future-batch rows into the admission decision: rows needed at
    step t+k are scheduled for load *now* (before they miss) and slots
    holding soon-needed rows are pinned against eviction — the exact-lookahead
    analogue of the paper's frequency protection.  Current-batch rows always
    win: if capacity is short, future loads are dropped first and pinned
    future slots may be reclaimed, but rows of the current batch are never
    evicted (exactness is unconditional).

    Pin lifetime (audited invariant, tested in test_pipeline.py): a pin is
    PLAN-LOCAL.  Nothing in ``CacheState`` records it — the eviction-key
    demotion exists only inside this call, recomputed from the window the
    caller passes.  If a pipelined group is abandoned mid-group (early stop,
    producer error), the next ``plan_prepare`` with a fresh window simply
    does not re-pin the stale rows: they compete under the normal policy key
    (for LRU they age from their load step like any other resident row, for
    FREQ_LFU the pin never influenced the key beyond the planning call) and
    ``flush`` writes them back like any resident row.  No unpin step exists
    because no pin state persists.
    """
    k = cfg.unique_size
    # geometry comes from the STATE (a serve-time cfg may quote a smaller
    # capacity than the state it operates on — guards must use real sizes)
    capacity = state.slot_to_row.shape[0]
    vocab = state.row_to_slot.shape[0]
    valid = rows >= 0
    int_max = jnp.iinfo(jnp.int32).max

    # --- id-level hit telemetry (before any movement) ----------------------
    pre_slots = state.row_to_slot.at[jnp.where(valid, rows, 0)].get(mode="fill", fill_value=-1)
    id_hits = jnp.sum((pre_slots >= 0) & valid)

    # --- unique needed rows (fixed size k, padded with -1 at the end) ------
    # jnp.unique sorts ascending; map padding to +inf-like sentinel then back.
    big_rows = jnp.where(valid, rows, int_max)
    if cfg.use_pallas_plan:
        # fused dedup -> residency probe -> miss compaction: ONE sort total
        # (the overflow count shares the dedup's sorted buffer instead of
        # paying a second full sort) — bit-identical to the route below.
        img = cache_ops.plan_image_impl(big_rows, state.row_to_slot, k)
        uniq_sorted = img.uniq_sorted
        uniq_valid = img.uniq_valid
        uniq = img.uniq
        overflow = (img.n_distinct > k).astype(jnp.int32)
        uniq_slots = img.uniq_slots
        miss = img.miss
        n_miss = img.n_miss
    else:
        uniq = jnp.unique(big_rows, size=k, fill_value=int_max)
        uniq_valid = uniq != int_max
        uniq_sorted = uniq  # ascending, sentinel-padded — reused for membership
        uniq = jnp.where(uniq_valid, uniq, -1)

        # overflow detection: did the batch contain more distinct rows than k?
        # (jnp.unique(size=k) silently keeps the k smallest — count the truth.)
        srt = jnp.sort(big_rows)
        n_distinct_valid = jnp.sum(
            (jnp.diff(srt) != 0) & (srt[1:] != int_max)
        ) + (srt[0] != int_max).astype(jnp.int32)
        overflow = (n_distinct_valid > k).astype(jnp.int32)

        uniq_slots = state.row_to_slot.at[jnp.where(uniq_valid, uniq, 0)].get(mode="fill", fill_value=-1)
        miss = (uniq_slots < 0) & uniq_valid
        n_miss = jnp.sum(miss)

    # --- lookahead merge: unique FUTURE rows not already needed now --------
    if future_rows is not None and future_rows.shape[0] == 0:
        future_rows = None
    kf = 0
    if future_rows is not None:
        kf = min(int(future_rows.shape[0]), vocab)
        fbig = jnp.where(future_rows >= 0, future_rows, int_max)
        if cfg.use_pallas_plan:
            fut_uniq, _ = cache_ops.dedup_impl(fbig, kf, int_max)
        else:
            fut_uniq = jnp.unique(fbig, size=kf, fill_value=int_max)
        # membership in the current batch's unique set via the sorted buffer
        pos = jnp.clip(jnp.searchsorted(uniq_sorted, fut_uniq), 0, k - 1)
        in_now = uniq_sorted[pos] == fut_uniq
        fut_valid = (fut_uniq != int_max) & ~in_now
        fut_uniq = jnp.where(fut_valid, fut_uniq, -1)
        fut_slots = state.row_to_slot.at[jnp.where(fut_valid, fut_uniq, 0)].get(
            mode="fill", fill_value=-1
        )
        fut_miss = (fut_slots < 0) & fut_valid
        n_fut_miss = jnp.sum(fut_miss)

    # --- online frequency tracking (adaptive engine input) ------------------
    # The decayed counters ride the dedup this function already paid for:
    # current uniques count 1 touch, lookahead uniques count 1 touch (under
    # group scheduling each batch appears exactly once across the group's
    # merged plans, so per-batch mass is neither lost nor double-counted).
    # Purely additive state — no planning decision below reads it.
    step = state.step + 1
    tracker = freq_lib.tracker_touch(
        state.tracker, uniq, uniq_valid, step, cfg.freq_half_life
    )
    if kf:
        tracker = freq_lib.tracker_touch(
            tracker, fut_uniq, fut_valid, step, cfg.freq_half_life
        )
    tracker = freq_lib.tracker_observe(tracker, id_hits, n_miss, cfg.freq_half_life)

    # --- victim selection (Algorithm 1 lines 15-26) ------------------------
    # "backlist": rows needed now must not be evicted; rows needed in the
    # lookahead window are pinned one tier above (reclaimed only if the
    # current batch needs the space).
    if cfg.protect_via_inverse:
        # a slot needs protection iff it currently holds a needed (hit) row;
        # we already know those slots from the inverse map: O(K) scatter.
        hit_slots = jnp.where((uniq_slots >= 0) & uniq_valid, uniq_slots, capacity)
        protected = (
            jnp.zeros((capacity,), bool).at[hit_slots].set(True, mode="drop")
        )
    else:
        protected = jnp.isin(state.slot_to_row, jnp.where(uniq_valid, uniq, -7)) & (
            state.slot_to_row >= 0
        )
    key = eviction_key(cfg.policy, state.slot_to_row, state.last_used, state.use_count)
    key = jnp.where(state.slot_to_row < 0, _BIG, key)  # empty slots evict first
    if kf:
        if cfg.protect_via_inverse:
            fut_hit = jnp.where((fut_slots >= 0) & fut_valid, fut_slots, capacity)
            pinned = jnp.zeros((capacity,), bool).at[fut_hit].set(True, mode="drop")
        else:
            pinned = jnp.isin(
                state.slot_to_row, jnp.where(fut_valid, fut_uniq, -7)
            ) & (state.slot_to_row >= 0)
        key = jnp.where(pinned, -(_BIG // 2), key)  # soon-needed: evict late
    key = jnp.where(protected, -_BIG, key)  # needed-now slots evict last
    # a step can never load more rows than there are slots
    kv = min(k + kf, capacity)
    if cfg.use_pallas_plan:
        # bounded top-K: 32-round streaming threshold descent + kv-sized sort
        # (bit-identical to the full argsort slice, including tie order)
        victim_slots = cache_ops.victim_topk_impl(key, kv)
    else:
        order = jnp.argsort(key, descending=True)
        victim_slots = order[:kv].astype(jnp.int32)

    lane = jnp.arange(kv)
    if kf:
        # mandatory current-batch misses first, then as many future misses as
        # fit without reclaiming any pinned/protected slot.
        n_prot = jnp.sum(protected) + jnp.sum(pinned & ~protected)
        n_fut_load = jnp.clip(capacity - n_prot - n_miss, 0, n_fut_miss)
        n_loads = n_miss + n_fut_load
        active = lane < n_loads
        if cfg.use_pallas_plan:
            # cumsum-compact both miss runs and lane-select the merge — the
            # priority argsorts below, without sorting (lanes past the two
            # runs are never active, so the -1 padding never surfaces).
            now_c = img.miss_rows
            fut_c = cache_ops.compact_front_impl(fut_miss, fut_uniq, kf)
            cand = cache_ops.merge_candidates_impl(now_c, n_miss, fut_c, kv)
            miss_rows = jnp.where(active, cand, -1)
        else:
            perm_now = jnp.argsort(jnp.where(miss, 0, 1), stable=True)
            perm_fut = jnp.argsort(jnp.where(fut_miss, 0, 1), stable=True)
            cand_rows = jnp.concatenate([uniq[perm_now], fut_uniq[perm_fut]])
            cand_pri = jnp.concatenate(
                [
                    jnp.where(jnp.arange(k) < n_miss, 0, 2),
                    jnp.where(jnp.arange(kf) < n_fut_miss, 1, 2),
                ]
            )
            perm = jnp.argsort(cand_pri, stable=True)
            miss_rows = jnp.where(active, cand_rows[perm][:kv], -1)
    else:
        n_loads = n_miss
        active = lane < n_loads  # one victim per actual miss
        # --- compact miss rows to the front ---------------------------------
        if cfg.use_pallas_plan:
            miss_rows = jnp.where(active, img.miss_rows[:kv], -1)
        else:
            perm = jnp.argsort(jnp.where(miss, 0, 1), stable=True)
            miss_rows = jnp.where(active, uniq[perm][:kv], -1)

    victim_rows = state.slot_to_row[victim_slots]
    evict_active = active & (victim_rows >= 0)

    # --- precision-tier movement telemetry ---------------------------------
    # For a tiered arena, slots below head_capacity are the fp32 head: a load
    # landing there promotes the row to full precision; displacing a resident
    # row from there demotes it (it re-faults into whichever tier its new
    # rank's slot occupies).  The container type is static pytree metadata,
    # so this branch specializes at trace time (vmap included); raw fp32
    # arenas keep both counters pinned at zero.
    if isinstance(state.cached_rows, ArenaStore):
        head_cap = state.cached_rows.head_capacity
        in_head = victim_slots < head_cap
        n_promote = jnp.sum(active & in_head).astype(jnp.int32)
        n_demote = jnp.sum(evict_active & in_head).astype(jnp.int32)
    else:
        n_promote = jnp.zeros((), jnp.int32)
        n_demote = jnp.zeros((), jnp.int32)
    row_to_slot = state.row_to_slot.at[jnp.where(evict_active, victim_rows, vocab)].set(
        -1, mode="drop"
    )
    slot_to_row = state.slot_to_row.at[jnp.where(active, victim_slots, capacity)].set(
        jnp.where(active, miss_rows, -1), mode="drop"
    )
    row_to_slot = row_to_slot.at[jnp.where(active, miss_rows, vocab)].set(
        jnp.where(active, victim_slots, -1), mode="drop"
    )

    # --- recency / runtime-frequency bookkeeping ----------------------------
    touched_slots = row_to_slot.at[jnp.where(uniq_valid, uniq, 0)].get(mode="fill", fill_value=-1)
    touch = jnp.where(uniq_valid, touched_slots, capacity)
    last_used = state.last_used.at[touch].set(step, mode="drop")
    use_count = state.use_count.at[touch].add(1, mode="drop")
    # loaded rows start fresh
    fresh = jnp.where(active, victim_slots, capacity)
    use_count = use_count.at[fresh].set(1, mode="drop")
    if kf:
        # prefetched rows count as just-arrived so recency policies don't
        # evict them before their step comes up.
        last_used = last_used.at[jnp.where(active, victim_slots, capacity)].set(
            step, mode="drop"
        )

    # NB: negative indices WRAP in jax even with mode='fill'; mask explicitly.
    slots = jnp.where(
        valid, row_to_slot.at[jnp.where(valid, rows, 0)].get(mode="fill", fill_value=-1), -1
    )
    return CachePlan(
        miss_rows=miss_rows,
        victim_slots=victim_slots,
        victim_rows=victim_rows,
        load_active=active,
        evict_active=evict_active,
        slot_to_row=slot_to_row,
        row_to_slot=row_to_slot,
        last_used=last_used,
        use_count=use_count,
        step=step,
        # misses counts DEMAND misses only — a prefetched future row is not a
        # miss, so hit-rate telemetry keeps its meaning and shows the prefetch
        # benefit; transmitter traffic is visible via evictions + the movement
        # plan itself.  NB: hits/misses are recorded for the rows passed as
        # the CURRENT batch; under group scheduling (pipeline_depth > 1) only
        # group leaders run a plan, so telemetry samples 1/k of the traffic.
        hits=state.hits + id_hits.astype(jnp.int32),
        misses=state.misses + n_miss.astype(jnp.int32),
        evictions=state.evictions + jnp.sum(evict_active).astype(jnp.int32),
        uniq_overflows=state.uniq_overflows + overflow,
        tier_promotions=state.tier_promotions + n_promote,
        tier_demotions=state.tier_demotions + n_demote,
        tracker=tracker,
        slots=slots,
    )


@contract(donates=("full_rows", "state"), int_counters=INT_COUNTERS, max_sort_size=0)
def apply_plan(
    cfg: CacheConfig, full_rows: Any, state: CacheState, plan: CachePlan
) -> Tuple[Any, CacheState]:
    """Execute a ``CachePlan``: write back displaced rows, load missed rows,
    install the index image.  The only half that touches weights — in the
    pipelined trainer it runs after the previous step's row update so evicted
    rows carry their freshest values."""
    # chunk granularity applies to the SLOW-tier side only (the full table):
    # writebacks scatter into it, loads gather from it.  The cache side stays
    # row-granular — its slots are a permutation with no useful locality.
    if cfg.writeback:
        full_rows = transmitter.move_rows(
            state.cached_rows,
            full_rows,
            plan.victim_slots,
            plan.victim_rows,
            plan.evict_active,
            buffer_rows=cfg.buffer_rows,
            dst_chunk_rows=cfg.chunk_rows,
        )
    cached_rows = transmitter.move_rows(
        full_rows,
        state.cached_rows,
        plan.miss_rows,
        plan.victim_slots,
        plan.load_active,
        buffer_rows=cfg.buffer_rows,
        src_chunk_rows=cfg.chunk_rows,
    )
    new_state = CacheState(
        cached_rows=cached_rows,
        slot_to_row=plan.slot_to_row,
        row_to_slot=plan.row_to_slot,
        last_used=plan.last_used,
        use_count=plan.use_count,
        step=plan.step,
        hits=plan.hits,
        misses=plan.misses,
        evictions=plan.evictions,
        uniq_overflows=plan.uniq_overflows,
        tier_promotions=plan.tier_promotions,
        tier_demotions=plan.tier_demotions,
        tracker=plan.tracker,
    )
    return full_rows, new_state


def prepare(
    cfg: CacheConfig,
    full_rows: Any,
    state: CacheState,
    rows: jnp.ndarray,
    future_rows: Optional[jnp.ndarray] = None,
) -> Tuple[Any, CacheState, jnp.ndarray]:
    """Algorithm 1 ``PrepareCache``: make every row of ``rows`` resident.

    Args:
      full_rows: the full (freq-ordered) table — a raw pytree with leaves
        [vocab, ...] or a ``repro.store.HostStore`` holding the same leaves
        encoded (misses are dequantized on load, evictions quantized on
        writeback, inside the transmitter rounds).
      rows: int32 [ids_per_step] freq-ranked row per id (-1 padding). Callers
        translate raw ids through ``idx_map`` first.
      future_rows: optional lookahead window of future-batch rows (see
        ``plan_prepare``) — prefetched alongside the current batch's misses.

    Returns (full_rows', state', slots) where ``slots`` maps each input lane to
    its resident cache slot (-1 for padding lanes).
    """
    plan = plan_prepare(cfg, state, rows, future_rows=future_rows)
    full_rows, new_state = apply_plan(cfg, full_rows, state, plan)
    return full_rows, new_state, plan.slots


def lookup_slots(state: CacheState, slots: jnp.ndarray, leaf: str | int = 0) -> jnp.ndarray:
    """Gather cached rows by slot; -1 (padding) lanes return zero rows.

    On a tiered arena the gather is decode-on-read: head lanes come back
    bit-exact, tail lanes dequantized — same zero-fill convention."""
    cached = state.cached_rows
    if isinstance(cached, ArenaStore):
        keys = sorted(set(cached.head) | set(cached.raw))
        key = keys[leaf] if isinstance(leaf, int) else leaf
        return cached.gather_slots(slots)[key]
    leaves = jax.tree_util.tree_leaves(cached)
    w = leaves[leaf] if isinstance(leaf, int) else cached[leaf]
    safe = jnp.where(slots >= 0, slots, w.shape[0])  # negatives would wrap
    return jnp.take(w, safe, axis=0, mode="fill", fill_value=0)


@contract(donates=("full_rows",), int_counters=INT_COUNTERS, max_sort_size=0)
def flush(cfg: CacheConfig, full_rows: Any, state: CacheState) -> Tuple[Any, CacheState]:
    """Write every resident row back to the full table (checkpoint barrier).

    After ``flush`` the full table is authoritative; the cache stays warm
    (rows remain resident and clean).
    """
    # geometry from the STATE, like ``prepare``: a serve-time cfg may quote a
    # different capacity/vocab than the state it operates on.
    capacity = state.slot_to_row.shape[0]
    slots = jnp.arange(capacity, dtype=jnp.int32)
    rows = state.slot_to_row
    active = rows >= 0
    full_rows = transmitter.move_rows(
        state.cached_rows,
        full_rows,
        slots,
        rows,
        active,
        buffer_rows=cfg.buffer_rows,
        dst_chunk_rows=cfg.chunk_rows,
    )
    return full_rows, state


@contract(donates=("state",), int_counters=INT_COUNTERS, max_sort_size=0)
def warmup(
    cfg: CacheConfig, full_rows: Any, state: CacheState
) -> Tuple[Any, CacheState]:
    """Paper §4.3 cache warm-up: pre-fill with the hottest (lowest-rank) rows."""
    # geometry from the STATE (see ``prepare``/``flush``): cfg capacity/vocab
    # may be stale relative to the arrays being warmed.
    capacity = state.slot_to_row.shape[0]
    vocab = state.row_to_slot.shape[0]
    n = min(capacity, vocab)
    rows = jnp.arange(capacity, dtype=jnp.int32)
    active = rows < n
    rows = jnp.where(active, rows, -1)
    slots = jnp.arange(capacity, dtype=jnp.int32)
    cached_rows = transmitter.move_rows(
        full_rows,
        state.cached_rows,
        rows,
        slots,
        active,
        buffer_rows=cfg.buffer_rows,
        src_chunk_rows=cfg.chunk_rows,
    )
    slot_to_row = jnp.where(active, rows, -1).astype(jnp.int32)
    row_to_slot = state.row_to_slot.at[jnp.where(active, rows, vocab)].set(
        jnp.where(active, slots, -1), mode="drop"
    )
    return full_rows, dataclasses.replace(
        state,
        cached_rows=cached_rows,
        slot_to_row=slot_to_row,
        row_to_slot=row_to_slot,
    )
