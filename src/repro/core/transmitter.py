"""The data transmitter (paper §4.3): bounded-buffer, blocked row movement.

The paper packs scattered embedding rows into contiguous blocks on the source
device, ships the block across the slow link (PCI-e there; host<->HBM DMA on a
TPU host), and scatters on the target — with a strictly limited buffer, so a
big transfer completes in multiple rounds.

JAX/XLA adaptation: shapes must be static, so the transmitter has a fixed
per-round budget ``buffer_rows`` and always executes ``ceil(K / buffer_rows)``
rounds over the (padded) index arrays.  Inactive lanes use out-of-bounds
indices with ``mode='drop'`` / ``mode='fill'`` so they are hardware no-ops.
The pack -> move -> scatter structure is kept explicit (``pack`` is a gather
into a contiguous [buffer_rows, ...] staging block — exactly the paper's
buffer) so that on TPU the staging block is what crosses the host/device
boundary.

Rows are pytrees: every leaf has a leading "row" dimension; auxiliary per-row
state (e.g. row-wise Adagrad accumulators) moves together with the weights.

Codec-aware movement: either side of ``move_rows`` may be a
:class:`repro.store.HostStore` (the mixed-precision host tier).  The pack
stage then gathers the *encoded* payload + sideband into the staging block —
that is what crosses the slow link, so an int8 store moves ~4x fewer bytes
per round — and the decode (load) / encode (writeback) runs on the block at
the device end of the link.  With the fp32 codec the store is raw arrays and
the path is bit-identical to the plain-pytree one.

The DEVICE side may likewise be a :class:`repro.store.ArenaStore` (the
frequency-tiered arena): gathers decode-on-read (head slots bit-exact, tail
slots dequantized) and scatters encode tail lanes on arrival.  When a host
store loads into an arena of the SAME codec, the tail lanes take the host
payload + sideband verbatim — the encoded block that crossed the link lands
in the tail tier without a decode/re-encode round trip (the head lanes still
decode, since the head stores fp32).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.store.arena import ArenaStore
from repro.store.host_store import HostStore

__all__ = ["move_rows", "write_rows", "gather_rows", "scatter_rows", "num_rounds"]


def num_rounds(k: int, buffer_rows: int) -> int:
    return -(-k // buffer_rows)


# ---------------------------------------------------------------------------
# chunk-granularity staging (paper's chunk-based manager, arXiv 2208.05321)
# ---------------------------------------------------------------------------
#
# Instead of gathering/scattering K scattered rows on the slow tier, the
# chunked path groups the round's rows by their CONTIGUOUS chunk of
# ``chunk_rows`` rows, dedups the chunk ids (one buffer-sized sort per
# round — never a table-sized one), and moves whole chunks: loads gather at
# most ``buffer_rows`` unique chunks and pick rows out of the staged block;
# writebacks read-modify-write the touched chunks.  On a host<->device link
# this turns K row-sized DMAs into a few large contiguous ones; values are
# bit-identical to the row-granular path (tested).


def _chunk_plan(
    idx: jnp.ndarray, chunk: int, n_chunks: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-round chunk schedule: dedup'd chunk ids (``n_chunks`` = OOB pad)
    plus each lane's flat position ``pos_in_dedup * chunk + offset`` into
    the staged [B, chunk, ...] block (-1 for inactive lanes)."""
    big = jnp.iinfo(jnp.int32).max
    b = idx.shape[0]
    cid = jnp.where(idx >= 0, idx // chunk, big)
    srt = jnp.sort(cid)  # buffer-sized, bounded by the round
    first = jnp.concatenate([jnp.ones((1,), bool), jnp.diff(srt) != 0]) & (srt != big)
    pos = jnp.cumsum(first.astype(jnp.int32)) - 1
    uniq_c = jnp.full((b,), n_chunks, jnp.int32).at[
        jnp.where(first, pos, b)
    ].set(srt.astype(jnp.int32), mode="drop")
    lane_pos = jnp.clip(
        jnp.searchsorted(uniq_c, jnp.where(idx >= 0, cid, 0).astype(jnp.int32)),
        0,
        b - 1,
    ).astype(jnp.int32)
    flat = jnp.where(idx >= 0, lane_pos * chunk + idx % chunk, -1)
    return uniq_c, flat


def _chunkable(tree: Any, chunk: int) -> bool:
    """Chunking needs every leaf's row count to divide evenly (the reshaped
    [rows/chunk, chunk, ...] view); otherwise fall back to row granularity."""
    if chunk <= 0:
        return False
    leaves = jax.tree_util.tree_leaves(tree)
    return bool(leaves) and all(leaf.shape[0] % chunk == 0 for leaf in leaves)


def _gather_rows_chunked(tree: Any, idx: jnp.ndarray, chunk: int) -> Any:
    """Chunked pack: gather the round's unique chunks, then pick each lane's
    row out of the staged block.  Inactive lanes (-1) produce zero rows —
    same convention as :func:`gather_rows`."""
    b = idx.shape[0]

    def g(leaf):
        nc = leaf.shape[0] // chunk
        uniq_c, flat = _chunk_plan(idx, chunk, nc)
        view = leaf.reshape((nc, chunk) + leaf.shape[1:])
        staged = jnp.take(view, uniq_c, axis=0, mode="fill", fill_value=0)
        rows = staged.reshape((b * chunk,) + leaf.shape[1:])
        safe = jnp.where(flat >= 0, flat, b * chunk)
        return jnp.take(rows, safe, axis=0, mode="fill", fill_value=0)

    return jax.tree_util.tree_map(g, tree)


def _scatter_rows_chunked(
    tree: Any, idx: jnp.ndarray, block: Any, active: jnp.ndarray, chunk: int
) -> Any:
    """Chunked unpack: read-modify-write the touched chunks — gather them,
    overwrite the block's rows at their in-chunk offsets, scatter the chunks
    back.  Untouched rows of a touched chunk keep their gathered values, so
    the result is bit-identical to the row-granular scatter."""
    b = idx.shape[0]
    idx_eff = jnp.where(active, idx, -1)

    def s(leaf, blk):
        nc = leaf.shape[0] // chunk
        uniq_c, flat = _chunk_plan(idx_eff, chunk, nc)
        view = leaf.reshape((nc, chunk) + leaf.shape[1:])
        staged = jnp.take(view, uniq_c, axis=0, mode="fill", fill_value=0)
        rows = staged.reshape((b * chunk,) + leaf.shape[1:])
        rows = rows.at[jnp.where(flat >= 0, flat, b * chunk)].set(blk, mode="drop")
        staged = rows.reshape((b, chunk) + leaf.shape[1:])
        new_view = view.at[uniq_c].set(staged, mode="drop")  # pad = OOB, dropped
        return new_view.reshape(leaf.shape)

    return jax.tree_util.tree_map(s, tree, block)


def _gather_store_rows_chunked(store: HostStore, idx: jnp.ndarray, chunk: int) -> Any:
    """Chunked pack from a host store: the chunks that cross the link are the
    ENCODED payload + sideband (chunking composes with the wire codec)."""
    block = _gather_rows_chunked(store.data, idx, chunk)
    side = _gather_rows_chunked(store.sideband, idx, chunk)
    return store.decode_block(block, side)


def _scatter_store_rows_chunked(
    store: HostStore, idx: jnp.ndarray, block: Any, active: jnp.ndarray, chunk: int
) -> HostStore:
    """Chunked unpack into a host store: encode on the device side, then RMW
    whole payload/sideband chunks on the host side."""
    data_blk, side_blk = store.encode_block(block)
    data = _scatter_rows_chunked(store.data, idx, data_blk, active, chunk)
    sideband = (
        _scatter_rows_chunked(store.sideband, idx, side_blk, active, chunk)
        if store.sideband
        else store.sideband
    )
    return HostStore(
        data=data, sideband=sideband, codec=store.codec, out_dtype=store.out_dtype
    )


def gather_rows(tree: Any, idx: jnp.ndarray) -> Any:
    """Pack: gather rows ``idx`` of every leaf into a contiguous block.

    Out-of-bounds / negative indices produce zero rows (``mode='fill'``).
    """
    def g(leaf):
        safe = jnp.where(idx >= 0, idx, leaf.shape[0])  # negatives would wrap
        return jnp.take(leaf, safe, axis=0, mode="fill", fill_value=0)

    return jax.tree_util.tree_map(g, tree)


def scatter_rows(tree: Any, idx: jnp.ndarray, block: Any, active: jnp.ndarray) -> Any:
    """Unpack: scatter ``block`` rows into ``tree`` at ``idx`` where ``active``.

    Inactive lanes are redirected out of bounds and dropped.
    """
    n = jax.tree_util.tree_leaves(tree)[0].shape[0]
    safe_idx = jnp.where(active, idx, n)  # n == OOB -> dropped

    def s(leaf, blk):
        return leaf.at[safe_idx].set(blk, mode="drop")

    return jax.tree_util.tree_map(s, tree, block)


def _gather_store_rows(store: HostStore, idx: jnp.ndarray) -> Any:
    """Pack from a host store: the staging block is the ENCODED payload +
    sideband (this is what crosses the link), decoded only on arrival."""
    block = gather_rows(store.data, idx)
    side = gather_rows(store.sideband, idx)
    return store.decode_block(block, side)


def _scatter_store_rows(
    store: HostStore, idx: jnp.ndarray, block: Any, active: jnp.ndarray
) -> HostStore:
    """Unpack into a host store: quantize-on-writeback — the block is encoded
    on the device side, then payload + sideband cross the link and scatter."""
    data_blk, side_blk = store.encode_block(block)
    data = scatter_rows(store.data, idx, data_blk, active)
    sideband = (  # sideband-free codecs (fp32/fp16) carry an empty dict
        scatter_rows(store.sideband, idx, side_blk, active) if store.sideband else store.sideband
    )
    return HostStore(
        data=data, sideband=sideband, codec=store.codec, out_dtype=store.out_dtype
    )


def move_rows(
    src_tree: Any,
    dst_tree: Any,
    src_idx: jnp.ndarray,
    dst_idx: jnp.ndarray,
    active: jnp.ndarray,
    *,
    buffer_rows: int,
    src_chunk_rows: int = 0,
    dst_chunk_rows: int = 0,
) -> Any:
    """Move rows ``src_idx`` of ``src_tree`` to positions ``dst_idx`` of ``dst_tree``.

    ``active`` masks real lanes; all arrays have static length K.  The move is
    performed in ``ceil(K/buffer_rows)`` rounds through a [buffer_rows, ...]
    staging block.  Returns the updated ``dst_tree``.  Designed to be called
    from inside a jitted step (it is pure; no own jit so the caller fuses it).

    Either side may be a ``HostStore``: loads gather the encoded staging
    block and decode it at the device end; writebacks encode the block
    before it crosses, then scatter payload + sideband into the store.  The
    device side may be an ``ArenaStore`` (tiered arena) — see module
    docstring for the encoded host->tail fast path.

    ``src_chunk_rows`` / ``dst_chunk_rows`` (0 = off) switch the named side
    to chunk-granularity staging: the round's rows are grouped into
    contiguous ``chunk_rows``-row chunks and whole chunks cross the link
    (loads pick rows out of the staged chunks; writebacks read-modify-write
    them).  Callers set the knob on their SLOW-TIER side only.  Values are
    bit-identical to the row-granular path; chunking silently falls back to
    rows when a leaf's row count does not divide the chunk size.  The
    host->tail verbatim fast path is row-granular (the staged chunks are
    decoded at the device end), so it is bypassed under a chunked source.
    """
    k = src_idx.shape[0]
    buffer_rows = min(buffer_rows, k)
    rounds = num_rounds(k, buffer_rows)
    pad = rounds * buffer_rows - k
    if pad:
        src_idx = jnp.concatenate([src_idx, jnp.full((pad,), -1, src_idx.dtype)])
        dst_idx = jnp.concatenate([dst_idx, jnp.full((pad,), -1, dst_idx.dtype)])
        active = jnp.concatenate([active, jnp.zeros((pad,), bool)])
    src_store = src_tree.data if isinstance(src_tree, HostStore) else src_tree
    chunk_src = (
        src_chunk_rows
        if not isinstance(src_tree, ArenaStore) and _chunkable(src_store, src_chunk_rows)
        else 0
    )
    dst_store = dst_tree.data if isinstance(dst_tree, HostStore) else dst_tree
    chunk_dst = (
        dst_chunk_rows
        if not isinstance(dst_tree, ArenaStore) and _chunkable(dst_store, dst_chunk_rows)
        else 0
    )

    def body(r, dst):
        s = r * buffer_rows
        si = jax.lax.dynamic_slice_in_dim(src_idx, s, buffer_rows)
        di = jax.lax.dynamic_slice_in_dim(dst_idx, s, buffer_rows)
        ac = jax.lax.dynamic_slice_in_dim(active, s, buffer_rows)
        si = jnp.where(ac, si, -1)
        enc_payload: Optional[Any] = None
        enc_side: Optional[Any] = None
        if isinstance(src_tree, HostStore):  # pack + decode-on-load
            if chunk_src:
                block = _gather_store_rows_chunked(src_tree, si, chunk_src)
            else:
                # keep the encoded block around: if the destination is a
                # tiered arena of the same codec, tail lanes take it
                # verbatim below.
                enc_payload = gather_rows(src_tree.data, si)
                enc_side = gather_rows(src_tree.sideband, si)
                block = src_tree.decode_block(enc_payload, enc_side)
        elif isinstance(src_tree, ArenaStore):  # pack + decode-on-read
            block = src_tree.gather_slots(si)
        elif chunk_src:
            block = _gather_rows_chunked(src_tree, si, chunk_src)
        else:
            block = gather_rows(src_tree, si)  # pack (staging buffer)
        if isinstance(dst, HostStore):  # encode-on-writeback + unpack
            if chunk_dst:
                return _scatter_store_rows_chunked(dst, di, block, ac, chunk_dst)
            return _scatter_store_rows(dst, di, block, ac)
        if isinstance(dst, ArenaStore):  # tiered unpack (tail encodes)
            payload_blk = side_blk = None
            if enc_payload is not None and isinstance(src_tree, HostStore) \
                    and src_tree.codec == dst.codec:
                payload_blk = {
                    k_: enc_payload[k_]
                    for k_ in dst.tail
                    if k_ in enc_payload and src_tree.is_encoded(k_)
                }
                side_blk = {
                    k_: enc_side[k_] for k_ in dst.sideband if k_ in enc_side
                }
            return dst.scatter_slots(
                di, block, ac, payload_block=payload_blk, side_block=side_blk
            )
        if chunk_dst:
            return _scatter_rows_chunked(dst, di, block, ac, chunk_dst)
        return scatter_rows(dst, di, block, ac)  # move + unpack

    if rounds == 1:
        return body(0, dst_tree)
    return jax.lax.fori_loop(0, rounds, body, dst_tree)


def write_rows(
    rows: Any,
    dst_tree: Any,
    dst_idx: jnp.ndarray,
    active: jnp.ndarray,
    *,
    buffer_rows: int,
    dst_chunk_rows: int = 0,
) -> Any:
    """Scatter an explicit block of ``rows`` (row i -> ``dst_idx[i]``) into
    ``dst_tree`` through the same bounded staging buffer as :func:`move_rows`
    — encode-on-writeback applies when the destination is a ``HostStore``.
    Used by the sharded collection to push its replicated arena back to the
    rows' slow-tier homes (flush, refresh demotions)."""
    k = dst_idx.shape[0]
    src_idx = jnp.arange(k, dtype=dst_idx.dtype)
    return move_rows(
        rows, dst_tree, src_idx, dst_idx, active,
        buffer_rows=buffer_rows, dst_chunk_rows=dst_chunk_rows,
    )
