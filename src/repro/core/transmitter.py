"""The data transmitter (paper §4.3): bounded-buffer, blocked row movement.

The paper packs scattered embedding rows into contiguous blocks on the source
device, ships the block across the slow link (PCI-e there; host<->HBM DMA on a
TPU host), and scatters on the target — with a strictly limited buffer, so a
big transfer completes in multiple rounds.

JAX/XLA adaptation: shapes must be static, so the transmitter has a fixed
per-round budget ``buffer_rows`` and always executes ``ceil(K / buffer_rows)``
rounds over the (padded) index arrays.  Inactive lanes use out-of-bounds
indices with ``mode='drop'`` / ``mode='fill'`` so they are hardware no-ops.
The pack -> move -> scatter structure is kept explicit (``pack`` is a gather
into a contiguous [buffer_rows, ...] staging block — exactly the paper's
buffer) so that on TPU the staging block is what crosses the host/device
boundary.

Rows are pytrees: every leaf has a leading "row" dimension; auxiliary per-row
state (e.g. row-wise Adagrad accumulators) moves together with the weights.

Codec-aware movement: either side of ``move_rows`` may be a
:class:`repro.store.HostStore` (the mixed-precision host tier).  The pack
stage then gathers the *encoded* payload + sideband into the staging block —
that is what crosses the slow link, so an int8 store moves ~4x fewer bytes
per round — and the decode (load) / encode (writeback) runs on the block at
the device end of the link.  With the fp32 codec the store is raw arrays and
the path is bit-identical to the plain-pytree one.

The DEVICE side may likewise be a :class:`repro.store.ArenaStore` (the
frequency-tiered arena): gathers decode-on-read (head slots bit-exact, tail
slots dequantized) and scatters encode tail lanes on arrival.  When a host
store loads into an arena of the SAME codec, the tail lanes take the host
payload + sideband verbatim — the encoded block that crossed the link lands
in the tail tier without a decode/re-encode round trip (the head lanes still
decode, since the head stores fp32).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.store.arena import ArenaStore
from repro.store.host_store import HostStore

__all__ = ["move_rows", "write_rows", "gather_rows", "scatter_rows", "num_rounds"]


def num_rounds(k: int, buffer_rows: int) -> int:
    return -(-k // buffer_rows)


def gather_rows(tree: Any, idx: jnp.ndarray) -> Any:
    """Pack: gather rows ``idx`` of every leaf into a contiguous block.

    Out-of-bounds / negative indices produce zero rows (``mode='fill'``).
    """
    def g(leaf):
        safe = jnp.where(idx >= 0, idx, leaf.shape[0])  # negatives would wrap
        return jnp.take(leaf, safe, axis=0, mode="fill", fill_value=0)

    return jax.tree_util.tree_map(g, tree)


def scatter_rows(tree: Any, idx: jnp.ndarray, block: Any, active: jnp.ndarray) -> Any:
    """Unpack: scatter ``block`` rows into ``tree`` at ``idx`` where ``active``.

    Inactive lanes are redirected out of bounds and dropped.
    """
    n = jax.tree_util.tree_leaves(tree)[0].shape[0]
    safe_idx = jnp.where(active, idx, n)  # n == OOB -> dropped

    def s(leaf, blk):
        return leaf.at[safe_idx].set(blk, mode="drop")

    return jax.tree_util.tree_map(s, tree, block)


def _gather_store_rows(store: HostStore, idx: jnp.ndarray) -> Any:
    """Pack from a host store: the staging block is the ENCODED payload +
    sideband (this is what crosses the link), decoded only on arrival."""
    block = gather_rows(store.data, idx)
    side = gather_rows(store.sideband, idx)
    return store.decode_block(block, side)


def _scatter_store_rows(
    store: HostStore, idx: jnp.ndarray, block: Any, active: jnp.ndarray
) -> HostStore:
    """Unpack into a host store: quantize-on-writeback — the block is encoded
    on the device side, then payload + sideband cross the link and scatter."""
    data_blk, side_blk = store.encode_block(block)
    data = scatter_rows(store.data, idx, data_blk, active)
    sideband = (  # sideband-free codecs (fp32/fp16) carry an empty dict
        scatter_rows(store.sideband, idx, side_blk, active) if store.sideband else store.sideband
    )
    return HostStore(
        data=data, sideband=sideband, codec=store.codec, out_dtype=store.out_dtype
    )


def move_rows(
    src_tree: Any,
    dst_tree: Any,
    src_idx: jnp.ndarray,
    dst_idx: jnp.ndarray,
    active: jnp.ndarray,
    *,
    buffer_rows: int,
) -> Any:
    """Move rows ``src_idx`` of ``src_tree`` to positions ``dst_idx`` of ``dst_tree``.

    ``active`` masks real lanes; all arrays have static length K.  The move is
    performed in ``ceil(K/buffer_rows)`` rounds through a [buffer_rows, ...]
    staging block.  Returns the updated ``dst_tree``.  Designed to be called
    from inside a jitted step (it is pure; no own jit so the caller fuses it).

    Either side may be a ``HostStore``: loads gather the encoded staging
    block and decode it at the device end; writebacks encode the block
    before it crosses, then scatter payload + sideband into the store.  The
    device side may be an ``ArenaStore`` (tiered arena) — see module
    docstring for the encoded host->tail fast path.
    """
    k = src_idx.shape[0]
    buffer_rows = min(buffer_rows, k)
    rounds = num_rounds(k, buffer_rows)
    pad = rounds * buffer_rows - k
    if pad:
        src_idx = jnp.concatenate([src_idx, jnp.full((pad,), -1, src_idx.dtype)])
        dst_idx = jnp.concatenate([dst_idx, jnp.full((pad,), -1, dst_idx.dtype)])
        active = jnp.concatenate([active, jnp.zeros((pad,), bool)])

    def body(r, dst):
        s = r * buffer_rows
        si = jax.lax.dynamic_slice_in_dim(src_idx, s, buffer_rows)
        di = jax.lax.dynamic_slice_in_dim(dst_idx, s, buffer_rows)
        ac = jax.lax.dynamic_slice_in_dim(active, s, buffer_rows)
        si = jnp.where(ac, si, -1)
        enc_payload: Optional[Any] = None
        enc_side: Optional[Any] = None
        if isinstance(src_tree, HostStore):  # pack + decode-on-load
            # keep the encoded block around: if the destination is a tiered
            # arena of the same codec, tail lanes take it verbatim below.
            enc_payload = gather_rows(src_tree.data, si)
            enc_side = gather_rows(src_tree.sideband, si)
            block = src_tree.decode_block(enc_payload, enc_side)
        elif isinstance(src_tree, ArenaStore):  # pack + decode-on-read
            block = src_tree.gather_slots(si)
        else:
            block = gather_rows(src_tree, si)  # pack (staging buffer)
        if isinstance(dst, HostStore):  # encode-on-writeback + unpack
            return _scatter_store_rows(dst, di, block, ac)
        if isinstance(dst, ArenaStore):  # tiered unpack (tail encodes)
            payload_blk = side_blk = None
            if isinstance(src_tree, HostStore) and src_tree.codec == dst.codec:
                payload_blk = {
                    k_: enc_payload[k_]
                    for k_ in dst.tail
                    if k_ in enc_payload and src_tree.is_encoded(k_)
                }
                side_blk = {
                    k_: enc_side[k_] for k_ in dst.sideband if k_ in enc_side
                }
            return dst.scatter_slots(
                di, block, ac, payload_block=payload_blk, side_block=side_blk
            )
        return scatter_rows(dst, di, block, ac)  # move + unpack

    if rounds == 1:
        return body(0, dst_tree)
    return jax.lax.fori_loop(0, rounds, body, dst_tree)


def write_rows(
    rows: Any,
    dst_tree: Any,
    dst_idx: jnp.ndarray,
    active: jnp.ndarray,
    *,
    buffer_rows: int,
) -> Any:
    """Scatter an explicit block of ``rows`` (row i -> ``dst_idx[i]``) into
    ``dst_tree`` through the same bounded staging buffer as :func:`move_rows`
    — encode-on-writeback applies when the destination is a ``HostStore``.
    Used by the sharded collection to push its replicated arena back to the
    rows' slow-tier homes (flush, refresh demotions)."""
    k = dst_idx.shape[0]
    src_idx = jnp.arange(k, dtype=dst_idx.dtype)
    return move_rows(rows, dst_tree, src_idx, dst_idx, active, buffer_rows=buffer_rows)
