"""CachedEmbedding — the paper's one-big-table design as a thin adapter.

All per-field tables are concatenated into one big frequency-ordered table
(paper §5.1) and served through the two-tier software cache — i.e. exactly
the all-GROUPED special case of ``repro.core.collection``: one shared cache
arena over every table.  Since the collection refactor this module is a thin
single-arena adapter over the ``collection.cached_slab_*`` ops (one slab,
raw-global ids); it stays as the stable single-table API and the oracle for
the bit-exactness property tests.  New code should use
``collection.EmbeddingCollection``, which adds per-table placement plans.

The module is functional: a ``CachedEmbeddingState`` pytree is threaded
through the train step.

Training protocol (synchronous updates, paper §2.2.3):

    state, slots = prepare_ids(cfg, state, raw_ids)        # non-diff bookkeeping
    emb = gather(state.cache.cached_rows["weight"], slots) # diff wrt cached weight
    ... loss/backprop produces d(cached_weight) ...
    state = apply_row_grads(cfg, state, grad_cached, lr)   # update *cached* copy

Rows are authoritative while resident; eviction (inside ``prepare_ids``) and
``flush_state`` (checkpoint barrier) write them back to the full table.  The
cache is exact — a pure data-movement layer — so training curves match the
uncached baseline bit-for-bit up to float reordering (tested property).

Sharding (paper §4.4 hybrid parallel): column-wise 1-D tensor parallel — the
embedding dim of both tiers is sharded over the ``model`` mesh axis, index
arrays are replicated (every data rank derives identical bookkeeping), and the
lookup output is resharded batch-wise, which XLA SPMD realizes as the paper's
all-to-all.  ``shard_specs`` returns the PartitionSpec pytree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cache as cache_lib
from repro.core import collection as coll_lib
from repro.core import freq as freq_lib
from repro.core.policies import Policy
from repro.store import HostStore, get_codec

__all__ = [
    "CachedEmbeddingConfig",
    "CachedEmbeddingState",
    "init_state",
    "prepare_ids",
    "gather_slots",
    "embed_onehot",
    "embed_bag",
    "apply_row_grads",
    "flush_state",
    "shard_specs",
    "device_bytes",
]


@dataclasses.dataclass(frozen=True)
class CachedEmbeddingConfig:
    vocab_sizes: Tuple[int, ...]  # per-field vocab sizes (concatenated)
    dim: int
    ids_per_step: int  # static flattened id count per prepare call
    cache_ratio: float = 0.015  # paper default 1.5 %
    buffer_rows: int = 65536
    policy: Policy = Policy.FREQ_LFU
    writeback: bool = True
    dtype: Any = jnp.float32
    rowwise_adagrad: bool = False  # carry per-row accumulator through the cache
    max_unique_per_step: int = 0  # 0 = worst case; see CacheConfig
    protect_via_inverse: bool = True  # see CacheConfig (paper isin = False)
    host_precision: str = "fp32"  # host-tier codec: fp32 (bit-exact) | fp16 | int8
    freq_half_life: int = 1024  # online frequency tracker decay (CacheConfig)
    use_pallas_plan: bool = False  # bounded-top-K fused planning (CacheConfig)
    chunk_rows: int = 0  # chunk-granularity host staging (CacheConfig)

    @property
    def vocab(self) -> int:
        return int(sum(self.vocab_sizes))

    @property
    def unique_size(self) -> int:
        k = min(self.ids_per_step, self.vocab)
        if self.max_unique_per_step:
            k = min(k, self.max_unique_per_step)
        return k

    @property
    def capacity(self) -> int:
        cap = max(int(self.cache_ratio * self.vocab), self.unique_size)
        return min(cap, self.vocab)

    def cache_config(self) -> cache_lib.CacheConfig:
        return cache_lib.CacheConfig(
            vocab=self.vocab,
            capacity=self.capacity,
            ids_per_step=self.ids_per_step,
            buffer_rows=self.buffer_rows,
            policy=self.policy,
            writeback=self.writeback,
            max_unique_per_step=self.max_unique_per_step,
            protect_via_inverse=self.protect_via_inverse,
            freq_half_life=self.freq_half_life,
            use_pallas_plan=self.use_pallas_plan,
            chunk_rows=self.chunk_rows,
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CachedEmbeddingState:
    # slow tier: a repro.store.HostStore of {"weight": [vocab, dim],
    # ("accum": [vocab])?} — fp32 codec = raw arrays (pre-store behavior)
    full: Any
    cache: cache_lib.CacheState
    idx_map: jnp.ndarray  # int32 [vocab] raw id -> freq-ranked row
    offsets: jnp.ndarray  # int32 [fields] per-field base offset

    def slab(self) -> coll_lib.CachedSlab:
        """View this state as the collection's single cached-arena slab."""
        return coll_lib.CachedSlab(full=self.full, cache=self.cache, idx_map=self.idx_map)

    def with_slab(self, slab: coll_lib.CachedSlab) -> "CachedEmbeddingState":
        return dataclasses.replace(
            self, full=slab.full, cache=slab.cache, idx_map=slab.idx_map
        )


def init_state(
    rng: jax.Array,
    cfg: CachedEmbeddingConfig,
    counts: Optional[np.ndarray] = None,
    warm: bool = True,
) -> CachedEmbeddingState:
    """Build the static module (freq-ordered full table + idx_map) and an
    empty (optionally warmed-up) cache."""
    vocab, dim = cfg.vocab, cfg.dim
    scale = 1.0 / np.sqrt(dim)
    weight = jax.random.uniform(rng, (vocab, dim), cfg.dtype, -scale, scale)
    if counts is not None:
        stats = freq_lib.build_freq_stats(counts)
        idx_map = jnp.asarray(stats.idx_map)
        # weight rows are freshly random; ordering is only logical, no permute needed,
        # but idx_map must still be a real permutation so lookups land right.
    else:
        idx_map = jnp.arange(vocab, dtype=jnp.int32)
    full = {"weight": weight}
    row_example = {"weight": jax.ShapeDtypeStruct((dim,), cfg.dtype)}
    if cfg.rowwise_adagrad:
        full["accum"] = jnp.zeros((vocab,), jnp.float32)
        row_example["accum"] = jax.ShapeDtypeStruct((), jnp.float32)
    row_example = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), row_example, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
    )
    state = cache_lib.init_cache(cfg.cache_config(), row_example)
    offsets = jnp.asarray(freq_lib.concat_table_offsets(cfg.vocab_sizes).astype(np.int32))
    store = HostStore.create(full, codec=cfg.host_precision)
    st = CachedEmbeddingState(full=store, cache=state, idx_map=idx_map, offsets=offsets)
    if warm:
        st = st.with_slab(coll_lib.cached_slab_warmup(cfg.cache_config(), st.slab()))
    return st


def globalize(state: CachedEmbeddingState, field_ids: jnp.ndarray) -> jnp.ndarray:
    """[.., fields] local ids -> global concatenated-table ids."""
    return (field_ids.astype(jnp.int32) + state.offsets).astype(jnp.int32)


def prepare_ids(
    cfg: CachedEmbeddingConfig, state: CachedEmbeddingState, raw_ids: jnp.ndarray
) -> Tuple[CachedEmbeddingState, jnp.ndarray]:
    """Make all rows for ``raw_ids`` resident; return per-id cache slots.

    ``raw_ids``: int32 [ids_per_step] global ids, -1 = padding.  Non-
    differentiable bookkeeping (Algorithm 1) — call outside the grad closure.
    """
    slab, slots = coll_lib.cached_slab_prepare(cfg.cache_config(), state.slab(), raw_ids)
    return state.with_slab(slab), slots


def gather_slots(state: CachedEmbeddingState, slots: jnp.ndarray) -> jnp.ndarray:
    """Differentiable gather from the cached weight (padding -> zero rows)."""
    return coll_lib.cached_slab_gather(state.slab(), slots)


def embed_onehot(
    cfg: CachedEmbeddingConfig, state: CachedEmbeddingState, field_ids: jnp.ndarray
) -> Tuple[CachedEmbeddingState, jnp.ndarray, jnp.ndarray]:
    """One id per field (Criteo-style): [batch, fields] -> [batch, fields, dim].

    Returns (state', slots, embeddings); keep ``slots`` to scatter gradients.
    """
    b, f = field_ids.shape
    gids = globalize(state, field_ids).reshape(-1)
    state, slots = prepare_ids(cfg, state, gids)
    emb = gather_slots(state, slots).reshape(b, f, cfg.dim)
    return state, slots, emb


def embed_bag(
    cfg: CachedEmbeddingConfig,
    state: CachedEmbeddingState,
    flat_ids: jnp.ndarray,
    segment_ids: jnp.ndarray,
    num_segments: int,
    combiner: str = "sum",
) -> Tuple[CachedEmbeddingState, jnp.ndarray, jnp.ndarray]:
    """EmbeddingBag over ragged multi-hot bags (padding ids < 0 contribute 0).

    JAX has no native EmbeddingBag; this is gather + ``jax.ops.segment_sum``
    through the cache tier.
    """
    state, slots = prepare_ids(cfg, state, flat_ids)
    rows = gather_slots(state, slots)
    pooled = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if combiner == "mean":
        cnt = jax.ops.segment_sum((flat_ids >= 0).astype(rows.dtype), segment_ids, num_segments)
        pooled = pooled / jnp.maximum(cnt, 1.0)[:, None]
    return state, slots, pooled


def apply_row_grads(
    cfg: CachedEmbeddingConfig,
    state: CachedEmbeddingState,
    grad_cached_weight: jnp.ndarray,
    lr: float | jnp.ndarray,
) -> CachedEmbeddingState:
    """Synchronous update of the *cached* rows (SGD or row-wise Adagrad).

    The full-table copy is updated lazily at eviction/flush — the paper's
    synchronous scheme: resident rows are authoritative.
    """
    cached = dict(state.cache.cached_rows)
    if cfg.rowwise_adagrad:
        g2 = jnp.mean(grad_cached_weight.astype(jnp.float32) ** 2, axis=-1)
        accum = cached["accum"] + g2
        scale = lr / (jnp.sqrt(accum) + 1e-10)
        cached["weight"] = cached["weight"] - (scale[:, None] * grad_cached_weight).astype(
            cached["weight"].dtype
        )
        cached["accum"] = accum
    else:
        cached["weight"] = cached["weight"] - (lr * grad_cached_weight).astype(
            cached["weight"].dtype
        )
    new_cache = dataclasses.replace(state.cache, cached_rows=cached)
    return dataclasses.replace(state, cache=new_cache)


def flush_state(cfg: CachedEmbeddingConfig, state: CachedEmbeddingState) -> CachedEmbeddingState:
    """Checkpoint barrier: write all resident rows back to the full table."""
    return state.with_slab(coll_lib.cached_slab_flush(cfg.cache_config(), state.slab()))


def dense_reference_lookup(state: CachedEmbeddingState, field_ids: jnp.ndarray) -> jnp.ndarray:
    """Oracle: bypass the cache, read the flushed full table (tests only;
    decoded when the slow tier is quantized)."""
    gids = globalize(state, field_ids)
    rows = state.idx_map[gids]
    return coll_lib._read_full_rows(state.full, rows)


def shard_specs(
    cfg: CachedEmbeddingConfig, mode: str = "column", model_axis: str = "model"
):
    """PartitionSpec pytree for the cache state.

    mode:
      * "column"     — the paper's column-wise 1-D TP: embedding dim of both
        tiers sharded over ``model_axis`` (requires dim % tp == 0).
      * "row"        — full (slow-tier) table row-sharded over ``model_axis``;
        cached tier replicated.  Used when dim is too small to split (DIN/FM,
        dims 10-18 — DESIGN.md §Arch-applicability).
      * "replicated" — everything replicated (tests / tiny tables).
    """
    from jax.sharding import PartitionSpec as P

    if mode == "column":
        full_w = cached_w = P(None, model_axis)
        side_w = P(None, None)  # per-row sideband cannot split the dim
    elif mode == "row":
        full_w, cached_w = P(model_axis, None), P(None, None)
        side_w = P(model_axis, None)
    else:
        full_w = cached_w = side_w = P(None, None)
    full_like = {"weight": jax.ShapeDtypeStruct((cfg.vocab, cfg.dim), cfg.dtype)}
    full = {"weight": full_w}
    cached = {"weight": cached_w}
    if cfg.rowwise_adagrad:
        full_like["accum"] = jax.ShapeDtypeStruct((cfg.vocab,), jnp.float32)
        full["accum"] = P(model_axis) if mode == "row" else P(None)
        cached["accum"] = P(None)
    return CachedEmbeddingState(
        full=HostStore.spec_like(full_like, full, side_w, codec=cfg.host_precision),
        cache=cache_lib.CacheState(
            cached_rows=cached,
            slot_to_row=P(None),
            row_to_slot=P(None),
            last_used=P(None),
            use_count=P(None),
            step=P(),
            hits=P(),
            misses=P(),
            evictions=P(),
            uniq_overflows=P(),
            tier_promotions=P(),
            tier_demotions=P(),
            tracker=freq_lib.tracker_spec(P),
        ),
        idx_map=P(None),
        offsets=P(None),
    )


def device_bytes(cfg: CachedEmbeddingConfig) -> dict:
    """Fast-tier vs slow-tier footprint (paper Figs. 7/8 memory accounting;
    the slow tier is charged at its encoded, host-precision size)."""
    itemsize = jnp.dtype(cfg.dtype).itemsize
    fast = cfg.capacity * cfg.dim * itemsize  # cached weight
    fast += cfg.capacity * 4 * 3  # slot_to_row, last_used, use_count
    # row_to_slot + idx_map + frequency-tracker score/last_touch (on device)
    fast += cfg.vocab * 4 * 4
    slow = cfg.vocab * get_codec(cfg.host_precision).row_bytes((cfg.dim,), cfg.dtype)
    if cfg.rowwise_adagrad:
        fast += cfg.capacity * 4
        slow += cfg.vocab * 4  # accumulators stay raw fp32 (per-row scalars)
    return {"fast_tier_bytes": fast, "slow_tier_bytes": slow}
