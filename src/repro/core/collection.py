"""Planner-driven multi-table embeddings behind one keyed-feature API.

The paper manages ONE concatenated, frequency-ordered table through a single
software cache.  Production DLRMs hold dozens of tables whose size and skew
differ by orders of magnitude; per-table statistical placement across memory
tiers beats any one-size-fits-all policy (RecShard, arXiv 2201.10095), and
per-table tiering composes with cache-backed embeddings (arXiv 2010.11305).
This module generalizes the paper's design to that setting:

  * ``TableConfig``      — one logical table (vocab, dim, per-table cache
                           knobs, optional placement override).
  * ``FeatureBatch``     — keyed ids (feature name -> id array, -1 = padding),
                           the KJT analogue; ``from_onehot`` / ``from_bags``
                           constructors replace hand-flattened id vectors.
  * ``PlacementPlanner`` — takes the tables, optional frequency stats, and a
                           device-memory budget; assigns each table DEVICE
                           (fully resident, no cache bookkeeping), CACHED
                           (the paper's two-tier cache, per-table ratio and
                           policy), or GROUPED (many small tables share one
                           cache arena — the paper's original layout is the
                           all-GROUPED special case).
  * ``EmbeddingCollection`` — owns N tables under a plan and exposes the
                           collection-level surface shared by train and
                           serve: ``init`` / ``prepare`` / ``weights`` /
                           ``gather`` / ``pool`` / ``apply_grads`` /
                           ``flush`` / ``shard_specs`` / ``device_bytes``.

Everything rides on the existing machinery: ``core.cache`` (Algorithm 1),
``core.freq`` (static frequency module), ``core.transmitter``.  The cache
remains pure data movement, so a mixed-placement collection is bit-identical
to a dense reference lookup (tested property).

Training protocol (mirrors ``cached_embedding``, per collection):

    emb_state, slots = coll.prepare(emb_state, fb)       # non-diff bookkeeping
    def loss_fn(params, emb_w):                          # emb_w = coll.weights(state)
        rows = coll.gather(emb_w, slots, fb)             # diff wrt emb_w
        ...
    grads wrt (params, emb_w) ...
    emb_state = coll.apply_grads(emb_state, grads_emb, lr)
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import INT_COUNTERS, contract
from repro.core import cache as cache_lib
from repro.core import freq as freq_lib
from repro.core import refresh as refresh_lib
from repro.core.policies import Policy
from repro.obs.hub import ExactCounter
from repro.store import (
    ArenaStore,
    HostStore,
    PrecisionPolicy,
    SlabGeometry,
    get_codec,
    tiered_arena_bytes,
)

__all__ = [
    "Placement",
    "TableConfig",
    "FeatureBatch",
    "TablePlacement",
    "PlacementPlan",
    "PlacementPlanner",
    "ShardAssignment",
    "EmbeddingCollection",
    "DeviceSlab",
    "CachedSlab",
    "CollectionState",
    "CollectionPlan",
    "exact_metric_bytes",
    "ExactCounterTotals",
]

SHARED_ARENA = "__shared__"

# The exact-counter contract of a ``metrics()`` dict: every per-slab
# cumulative counter (and its static per-unit byte size) that
# ``repro.obs.hub.MetricsHub.observe_embedding_metrics`` reconstructs
# host-side must leave jit as int32/uint32 — a float cast anywhere in between
# silently reintroduces the 2^24 resolution drift the pattern exists to kill.
METRICS_INT_COUNTERS: Tuple[str, ...] = (
    r"\['slab_(hits|misses|refresh_swaps|refresh_rows"
    r"|tier_promotions|tier_demotions)'\]",
    r"\['host_(moved_rows|row_bytes)'\]",
    r"\['exchange_(routed_lanes|lane_bytes|id_lane_bytes|row_lane_bytes"
    r"|per_shard_lanes)'\]",
    r"\['(cache_misses|cache_evictions|uniq_overflows|refresh_swaps"
    r"|refresh_rows_moved)'\]$",
)


class Placement(enum.Enum):
    DEVICE = "device"  # full table resident on device, no cache bookkeeping
    CACHED = "cached"  # paper two-tier cache, table's own ratio/policy
    GROUPED = "grouped"  # shares the collection-wide cache arena


@dataclasses.dataclass(frozen=True)
class TableConfig:
    """One logical embedding table.

    ``feature_names`` lists the FeatureBatch keys served by this table
    (several features may share a table: e.g. ``hist_items`` and
    ``target_item`` both hit the items table); defaults to ``(name,)``.
    ``ids_per_step`` is the static number of id lanes the table's features
    contribute per step — it sizes the per-step unique buffer and the
    minimum cache capacity, exactly like the paper's strict buffer limit.
    """

    name: str
    vocab: int
    dim: int
    ids_per_step: int
    feature_names: Tuple[str, ...] = ()
    cache_ratio: float = 0.015  # paper default 1.5 %
    policy: Policy = Policy.FREQ_LFU
    buffer_rows: int = 65536
    max_unique_per_step: int = 0
    protect_via_inverse: bool = True
    dtype: Any = jnp.float32
    placement: Optional[Placement] = None  # planner override
    # host-tier storage codec for this table when CACHED: "fp32" (bit-exact
    # default), "fp16", "int8" (row-wise scale/zero-point), or "auto"
    # (PrecisionPolicy picks from frequency coverage at init).  None defers
    # to the planner / collection-wide setting.  DEVICE tables have no host
    # tier; GROUPED tables share the arena's codec.
    host_precision: Optional[str] = None
    # device-arena tail codec for this table when CACHED: "fp32" (raw arena,
    # bit-identical default), "fp16"/"int8" (frequency-tiered ArenaStore — an
    # fp32 head over the hottest slots, encoded tail for colder residents), or
    # "auto" (PrecisionPolicy.choose_arena picks from the head's share of
    # resident traffic at init).  None defers to the planner / collection-wide
    # setting.  DEVICE tables have no arena; GROUPED tables share the arena's.
    arena_precision: Optional[str] = None
    # decay half-life (steps) of the online frequency tracker — how fast the
    # adaptive engine forgets old traffic; match it to the expected drift
    # timescale (a refresh can only promote a newly-hot row once its fresh
    # mass outweighs the old hot set's decayed mass).  GROUPED tables use
    # the arena's value.
    freq_half_life: int = 1024
    # cache hot-path routing (see CacheConfig): bounded-top-K/fused planning
    # kernels and chunk-granularity host staging.  GROUPED tables use the
    # arena's values.
    use_pallas_plan: bool = False
    chunk_rows: int = 0

    @property
    def features(self) -> Tuple[str, ...]:
        return self.feature_names or (self.name,)

    @property
    def full_bytes(self) -> int:
        return self.vocab * self.dim * jnp.dtype(self.dtype).itemsize

    def unique_size(self, ids_per_step: Optional[int] = None) -> int:
        k = min(ids_per_step or self.ids_per_step, self.vocab)
        if self.max_unique_per_step:
            k = min(k, self.max_unique_per_step)
        return k


# ---------------------------------------------------------------------------
# FeatureBatch — the keyed-ids input type (KJT analogue)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FeatureBatch:
    """Keyed feature ids: name -> int32 id array (any shape, -1 = padding).

    For pooled ("bag") features, ``segments[name]`` assigns each flat lane to
    an output row (``num_segments`` rows total); ``EmbeddingCollection.pool``
    runs the segment reduction after the cached gather.
    """

    ids: Dict[str, jnp.ndarray]
    segments: Dict[str, jnp.ndarray] = dataclasses.field(default_factory=dict)
    num_segments: int = dataclasses.field(default=0, metadata=dict(static=True))

    @classmethod
    def from_onehot(cls, names: Sequence[str], id_matrix: jnp.ndarray) -> "FeatureBatch":
        """Criteo-style [batch, fields] matrix -> one [batch] feature per name."""
        assert id_matrix.ndim == 2 and id_matrix.shape[1] == len(names)
        return cls(ids={n: id_matrix[:, j].astype(jnp.int32) for j, n in enumerate(names)})

    @classmethod
    def from_bags(
        cls,
        bags: Mapping[str, Tuple[jnp.ndarray, jnp.ndarray]],
        num_segments: int,
        extra_onehot: Optional[Mapping[str, jnp.ndarray]] = None,
    ) -> "FeatureBatch":
        """Ragged multi-hot bags: name -> (flat_ids, segment_ids)."""
        ids = {n: flat.astype(jnp.int32) for n, (flat, _) in bags.items()}
        segments = {n: seg.astype(jnp.int32) for n, (_, seg) in bags.items()}
        if extra_onehot:
            ids.update({n: v.astype(jnp.int32) for n, v in extra_onehot.items()})
        return cls(ids=ids, segments=segments, num_segments=num_segments)

    @property
    def features(self) -> Tuple[str, ...]:
        return tuple(self.ids)


# ---------------------------------------------------------------------------
# placement plan + planner
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TablePlacement:
    placement: Placement
    # effective ratio for CACHED/GROUPED tables; None = use the table's own.
    # 0.0 is meaningful (planner shrunk to the exactness floor), hence Optional.
    cache_ratio: Optional[float] = None
    # host-tier codec ("fp32"/"fp16"/"int8"/"auto"); None = table's own / fp32
    host_precision: Optional[str] = None
    # device-arena tail codec ("fp32"/"fp16"/"int8"/"auto"); None = table's own
    arena_precision: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ArenaConfig:
    """Knobs of the shared GROUPED cache arena."""

    cache_ratio: float = 0.015
    policy: Policy = Policy.FREQ_LFU
    buffer_rows: int = 65536
    max_unique_per_step: int = 0
    protect_via_inverse: bool = True
    host_precision: str = "fp32"  # the arena's host-tier codec (shared table)
    arena_precision: str = "fp32"  # the arena's device-tail codec (tiered arena)
    arena_head_ratio: float = 0.25  # fp32 head fraction when the arena is tiered
    freq_half_life: int = 1024  # online-tracker decay (see TableConfig)
    use_pallas_plan: bool = False  # bounded-top-K fused planning (CacheConfig)
    chunk_rows: int = 0  # chunk-granularity host staging (CacheConfig)


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    placements: Dict[str, TablePlacement]
    arena: ArenaConfig = ArenaConfig()
    budget_bytes: Optional[int] = None

    def placement(self, name: str) -> Placement:
        return self.placements[name].placement

    @classmethod
    def single_arena(
        cls,
        tables: Sequence[TableConfig],
        cache_ratio: float = 0.015,
        policy: Policy = Policy.FREQ_LFU,
        buffer_rows: int = 65536,
        max_unique_per_step: int = 0,
        protect_via_inverse: bool = True,
        host_precision: str = "fp32",
        arena_precision: str = "fp32",
        arena_head_ratio: float = 0.25,
        freq_half_life: int = 1024,
        use_pallas_plan: bool = False,
        chunk_rows: int = 0,
    ) -> "PlacementPlan":
        """The paper's layout: every table GROUPED into one shared cache."""
        return cls(
            placements={
                t.name: TablePlacement(
                    Placement.GROUPED,
                    cache_ratio,
                    host_precision=host_precision,
                    arena_precision=arena_precision,
                )
                for t in tables
            },
            arena=ArenaConfig(
                cache_ratio=cache_ratio,
                policy=policy,
                buffer_rows=buffer_rows,
                max_unique_per_step=max_unique_per_step,
                protect_via_inverse=protect_via_inverse,
                host_precision=host_precision,
                arena_precision=arena_precision,
                arena_head_ratio=arena_head_ratio,
                freq_half_life=freq_half_life,
                use_pallas_plan=use_pallas_plan,
                chunk_rows=chunk_rows,
            ),
            budget_bytes=None,
        )

    def summary(self) -> Dict[str, str]:
        out = {}
        for n, p in self.placements.items():
            s = f"{p.placement.value}"
            if p.placement is not Placement.DEVICE:
                s += f"@{p.cache_ratio:.4f}" if p.cache_ratio is not None else ""
                hp = p.host_precision or "fp32"
                if hp != "fp32":
                    s += f":{hp}"  # host-tier codec (bytes saved vs fp32)
                ap = p.arena_precision or "fp32"
                if ap != "fp32":
                    s += f"/arena:{ap}"  # device-arena tail codec (tiered)
            out[n] = s
        return out


@dataclasses.dataclass(frozen=True)
class ShardAssignment:
    """Frequency-driven device assignment of one cached slab's rows.

    Maps every frequency-ranked row of a slab to a ``model``-axis shard so
    the expected hot-row traffic is balanced across devices (RecShard,
    arXiv 2201.10095: the statistics a placement pass needs are exactly the
    frequency counts the planner already collects).  ``owner``/``local`` are
    host-side numpy; the sharded collection places them on device next to
    ``idx_map`` so id routing is one extra gather.
    """

    num_shards: int
    owner: np.ndarray  # int32 [vocab] freq rank -> owning shard
    local: np.ndarray  # int32 [vocab] freq rank -> row index on the owner
    shard_rows: np.ndarray  # int64 [S] real rows per shard (pads excluded)
    shard_load: np.ndarray  # float64 [S] expected ROUTED traffic per shard
    # hot-row replication head: ranks < replicate_top_k live in a small arena
    # replicated on every shard, so their lookups never enter the id/row
    # exchange.  They still get (owner, local) slow-tier homes — appended
    # AFTER the routed ranks, so they land at each shard's coldest local
    # positions and never occupy warm cache slots — and carry zero routed
    # load (``shard_load``/``imbalance`` meter only what actually routes).
    replicate_top_k: int = 0

    @property
    def rows_per_shard(self) -> int:
        """Uniform local vocab (stacked [S, rows_per_shard, ...] layout);
        shards with fewer real rows pad with never-referenced zero rows."""
        return -(-int(self.owner.shape[0]) // self.num_shards)

    def imbalance(self) -> float:
        """max/mean expected routed traffic across shards (1.0 = even)."""
        mean = float(np.mean(self.shard_load))
        return float(np.max(self.shard_load)) / mean if mean > 0 else 1.0


class PlacementPlanner:
    """Assign each table a memory tier under an explicit device-byte budget.

    Heuristic (RecShard-flavoured, deterministic):
      1. honor explicit ``TableConfig.placement`` overrides;
      2. greedily promote the remaining tables to DEVICE, hottest-per-byte
         first (access frequency per byte when counts are given, smallest
         table first otherwise), while the full table fits the remaining
         budget — small hot tables stop paying any cache bookkeeping;
      3. tables with vocab below ``group_below_rows`` share the GROUPED
         arena (one cache, one set of index arrays, amortized bookkeeping);
      4. everything else is CACHED with its own ratio/policy; if the summed
         fast tiers overflow the remaining budget, ratios are scaled down
         uniformly, floored at one batch's unique rows (exactness floor).

    Host precision: the planner also stamps each CACHED/GROUPED table's
    host-tier codec (``TablePlacement.host_precision``): the table's own
    ``TableConfig.host_precision`` wins, then the planner-wide
    ``host_precision`` default.  ``"auto"`` defers the choice to
    ``repro.store.PrecisionPolicy`` at ``EmbeddingCollection.init`` time,
    when frequency counts are available.
    """

    def __init__(
        self,
        budget_bytes: int,
        group_below_rows: int = 0,
        arena: Optional[ArenaConfig] = None,
        host_precision: Optional[str] = None,
        arena_precision: Optional[str] = None,
        arena_head_ratio: float = 0.25,
    ):
        self.budget_bytes = int(budget_bytes)
        self.group_below_rows = int(group_below_rows)
        self.arena = arena if arena is not None else ArenaConfig()
        self.host_precision = host_precision
        self.arena_precision = arena_precision
        self.arena_head_ratio = float(arena_head_ratio)

    @staticmethod
    def _tiered_weight_bytes(
        capacity: int, dim: int, dtype, arena_precision: Optional[str], head_ratio: float
    ) -> int:
        """Weight-leaf footprint of one arena at ``arena_precision`` — fp32
        head + encoded tail payload + tail sideband (the sideband bytes are
        part of the budget: they are device-resident like the payload).
        "auto" is budgeted at the policy's no-stats pick, matching what init
        resolves when no counts arrive."""
        ap = arena_precision or "fp32"
        if ap == "auto":
            ap = PrecisionPolicy().no_stats
        if ap == "fp32":
            head = capacity
        else:
            head = min(capacity, max(1, int(round(head_ratio * capacity))))
        return tiered_arena_bytes(capacity, head, dim, dtype, ap)

    def _table_arena_precision(self, t: TableConfig) -> Optional[str]:
        return t.arena_precision or self.arena_precision

    def _fast_bytes(self, t: TableConfig, ratio: float) -> int:
        """Device footprint of one CACHED table at ``ratio`` (weights + per-slot
        bookkeeping + the vocab-sized index arrays + the online frequency
        tracker's decayed counters)."""
        cap = min(max(int(ratio * t.vocab), t.unique_size()), t.vocab)
        w = self._tiered_weight_bytes(
            cap, t.dim, t.dtype, self._table_arena_precision(t), self.arena_head_ratio
        )
        # vocab-sized: row_to_slot + idx_map + tracker score + last_touch
        return w + cap * 4 * 3 + t.vocab * 4 * 4

    def _arena_bytes(self, grouped: Sequence[TableConfig]) -> int:
        if not grouped:
            return 0
        gvocab = sum(t.vocab for t in grouped)
        gids = sum(t.ids_per_step for t in grouped)
        gcap = min(max(int(self.arena.cache_ratio * gvocab), min(gids, gvocab)), gvocab)
        w = self._tiered_weight_bytes(
            gcap,
            grouped[0].dim,
            grouped[0].dtype,
            self.arena_precision or self.arena.arena_precision,
            self.arena.arena_head_ratio,
        )
        return w + gcap * 4 * 3 + gvocab * 4 * 4

    def plan(
        self,
        tables: Sequence[TableConfig],
        counts: Optional[Mapping[str, np.ndarray]] = None,
    ) -> PlacementPlan:
        placements: Dict[str, TablePlacement] = {}
        device_bytes = 0

        undecided: List[TableConfig] = []
        grouped: List[TableConfig] = []
        solo: List[TableConfig] = []
        for t in tables:
            if t.placement is Placement.DEVICE:
                placements[t.name] = TablePlacement(Placement.DEVICE)
                device_bytes += t.full_bytes
            elif t.placement is Placement.GROUPED:
                grouped.append(t)
            elif t.placement is Placement.CACHED:
                solo.append(t)
            elif t.vocab < self.group_below_rows:
                grouped.append(t)  # many tiny tables share the arena by policy
            else:
                undecided.append(t)

        def heat_per_byte(t: TableConfig) -> float:
            if counts is not None and t.name in counts:
                return float(np.sum(counts[t.name])) / max(t.full_bytes, 1)
            return 1.0 / max(t.full_bytes, 1)  # no stats: smallest first

        # greedy DEVICE promotion, hottest-per-byte first.  A promotion is
        # only taken if the rest of the plan stays feasible in the worst case
        # (every remaining cached table shrunk to its exactness floor).
        undecided.sort(key=lambda t: (-heat_per_byte(t), t.name))
        for i, t in enumerate(undecided):
            rest = undecided[i + 1 :] + solo
            floor_rest = sum(self._fast_bytes(r, 0.0) for r in rest)
            cost = device_bytes + t.full_bytes + floor_rest + self._arena_bytes(grouped)
            if cost <= self.budget_bytes:
                placements[t.name] = TablePlacement(Placement.DEVICE)
                device_bytes += t.full_bytes
            else:
                solo.append(t)

        def host_prec(t: TableConfig) -> Optional[str]:
            return t.host_precision or self.host_precision

        # the planner-wide defaults also govern the shared arena (the arena's
        # own fields keep their fp32 defaults otherwise); the returned plan's
        # ArenaConfig carries the resolved codecs so the collection's arena
        # slab agrees with the GROUPED placements.
        arena = dataclasses.replace(
            self.arena,
            host_precision=self.host_precision or self.arena.host_precision,
            arena_precision=self.arena_precision or self.arena.arena_precision,
        )
        for t in grouped:
            placements[t.name] = TablePlacement(
                Placement.GROUPED,
                arena.cache_ratio,
                host_precision=arena.host_precision,
                arena_precision=arena.arena_precision,
            )

        # fit solo cache ratios into what is left (index arrays included)
        remaining = self.budget_bytes - device_bytes - self._arena_bytes(grouped)
        want = sum(self._fast_bytes(t, t.cache_ratio) for t in solo)
        scale = 1.0
        if solo and want > remaining:
            floor = sum(self._fast_bytes(t, 0.0) for t in solo)
            if floor > remaining:
                raise ValueError(
                    f"budget {self.budget_bytes} cannot hold even one batch's unique "
                    f"rows per cached table (need >= {self.budget_bytes - remaining + floor})"
                )
            # weight bytes scale ~linearly with ratio; solve for the shrink
            scale = max(0.0, (remaining - floor) / max(want - floor, 1))
        for t in solo:
            placements[t.name] = TablePlacement(
                Placement.CACHED,
                t.cache_ratio * scale,
                host_precision=host_prec(t),
                arena_precision=self._table_arena_precision(t),
            )

        return PlacementPlan(
            placements=placements, arena=arena, budget_bytes=self.budget_bytes
        )

    @staticmethod
    def assign_devices(
        vocab: int,
        num_shards: int,
        counts_ranked: Optional[np.ndarray] = None,
        replicate_top_k: int = 0,
    ) -> ShardAssignment:
        """Device-assignment pass: spread a slab's frequency-ranked rows over
        ``num_shards`` model-axis shards, balancing expected hot-row traffic.

        ``counts_ranked`` is the slab's access counts in frequency-rank order
        (descending at init time — ``FreqStats.counts[inv_map]``; the live
        re-balance pass feeds ``FreqTracker`` decayed scores, which need not
        be monotone in rank).  Greedy longest-processing-time: routed ranks
        are taken hottest first and each goes to the least-loaded shard that
        still has room (every shard holds at most ``ceil(vocab/S)`` rows so
        the stacked state stays uniform).  Without counts the pass
        degenerates to round-robin over ranks — under a Zipfian ordering that
        is already near-optimal traffic balance.  Deterministic: ties break
        by (rows held, shard index), so every host derives the identical
        assignment (a requirement, like ``build_freq_stats`` stability).

        ``replicate_top_k`` marks ranks ``< K`` as replicated: they carry no
        routed load (their lookups are served from the per-shard replicated
        arena, never the exchange) and their slow-tier homes are appended
        *after* all routed ranks, onto the least-filled shards — i.e. at each
        shard's coldest local positions, outside the warm cache prefix.  With
        ``replicate_top_k=0`` the pass is bit-identical to the historical
        assignment.
        """
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        S = int(num_shards)
        vocab = int(vocab)
        K = min(max(int(replicate_top_k), 0), vocab)
        cap = -(-vocab // S)
        routed = np.arange(K, vocab, dtype=np.int64)
        c = None
        if counts_ranked is not None:
            c = np.asarray(counts_ranked, np.float64)
            if c.shape[0] != vocab:
                raise ValueError(f"counts_ranked has {c.shape[0]} entries, want {vocab}")
        owner = np.empty((vocab,), np.int32)
        local = np.empty((vocab,), np.int32)
        if c is None or S == 1:
            # round-robin over routed ranks, replicated homes appended last
            # (K=0 reduces to owner=rank%S, local=rank//S exactly).
            seq = np.concatenate([routed, np.arange(K, dtype=np.int64)])
            pos = np.arange(vocab, dtype=np.int64)
            owner[seq] = (pos % S).astype(np.int32)
            local[seq] = (pos // S).astype(np.int32)
        else:
            import heapq

            # LPT wants hottest-first; live re-balance scores are unsorted,
            # so order routed ranks by descending mass (stable -> identity
            # for the already-descending init-time counts).
            hot_first = routed[np.argsort(-c[routed], kind="stable")]
            sizes = np.zeros((S,), np.int64)
            heap = [(0.0, 0, s) for s in range(S)]  # (load, rows held, shard)
            for r in hot_first:
                ld, size, s = heapq.heappop(heap)
                owner[r] = s
                local[r] = size
                sizes[s] = size + 1
                if size + 1 < cap:  # full shards leave the heap for good
                    heapq.heappush(heap, (ld + c[r], size + 1, s))
            # replicated head: zero routed load, so placement only levels row
            # counts — append to the least-filled shards with room.
            rep_heap = [(int(sizes[s]), s) for s in range(S)]
            heapq.heapify(rep_heap)
            for r in range(K):
                size, s = heapq.heappop(rep_heap)
                owner[r] = s
                local[r] = size
                if size + 1 < cap:
                    heapq.heappush(rep_heap, (size + 1, s))
        load = np.zeros((S,), np.float64)
        if routed.size:
            if c is not None:
                np.add.at(load, owner[routed], c[routed])
            else:
                np.add.at(load, owner[routed], 1.0)
        shard_rows = np.bincount(owner, minlength=S).astype(np.int64)
        return ShardAssignment(
            num_shards=S, owner=owner, local=local, shard_rows=shard_rows,
            shard_load=load, replicate_top_k=K,
        )


# ---------------------------------------------------------------------------
# state pytrees
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeviceSlab:
    """A fully-resident table: just the weight, no cache bookkeeping."""

    weight: jnp.ndarray  # [vocab, dim]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CachedSlab:
    """A two-tier cached arena (one table, or the shared GROUPED group)."""

    # slow tier: a repro.store.HostStore holding {"weight": [vocab, dim], ...}
    # encoded by the slab's host codec (fp32 = raw, bit-identical to the
    # pre-store pytree).  Raw dicts are still accepted anywhere the slab is
    # consumed (the transmitter handles both), but ``init`` always builds a
    # store.
    full: Any
    cache: cache_lib.CacheState
    idx_map: jnp.ndarray  # int32 [vocab] raw id -> freq-ranked row


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CollectionState:
    slabs: Dict[str, Any]  # name -> DeviceSlab | CachedSlab


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CollectionPlan:
    """The weight-free half of ``prepare`` for a whole collection.

    Built by ``EmbeddingCollection.plan_prepare`` from ids alone (per-slab
    ``cache.CachePlan``s plus the per-feature addresses); executed by
    ``EmbeddingCollection.apply_plan``.  Because planning never reads weights,
    the plan for step t+1 can be computed while step t's dense compute runs —
    the pipelined trainer's whole trick.

    When a lookahead window was merged, ``future_addresses[j]`` holds the
    planned addresses of ``fb_future[j]``'s lanes and ``future_unresident``
    counts future lanes whose row will NOT be resident after apply (loads
    dropped or pins reclaimed under capacity pressure).  A trainer that runs
    whole groups off one merged plan must see ``future_unresident == 0``;
    the current batch's addresses are unconditionally valid either way.
    """

    slab_plans: Dict[str, cache_lib.CachePlan]
    addresses: Dict[str, jnp.ndarray]  # feature -> slots / row ids (-1 pad)
    future_addresses: Tuple[Dict[str, jnp.ndarray], ...] = ()
    future_unresident: jnp.ndarray = dataclasses.field(
        default_factory=lambda: jnp.zeros((), jnp.int32)
    )
    writeback: bool = dataclasses.field(default=True, metadata=dict(static=True))


# --- slab-level ops (the single-arena core; ``cached_embedding`` adapts
#     its one-big-table API onto exactly these) ------------------------------


def _translate(slab: CachedSlab, raw_ids: jnp.ndarray) -> jnp.ndarray:
    """Slab-global raw ids (-1 pad) -> freq-ranked rows (-1 pad)."""
    valid = raw_ids >= 0
    rows = slab.idx_map.at[jnp.where(valid, raw_ids, 0)].get(mode="fill", fill_value=-1)
    return jnp.where(valid, rows, -1)


def _read_full_rows(full: Any, rows: jnp.ndarray) -> jnp.ndarray:
    """Gather weight rows from a slow tier — decoded when it is a HostStore,
    raw otherwise; negative lanes give zero rows (oracle/bulk read path)."""
    if isinstance(full, HostStore):
        return full.decode_rows(rows)["weight"]
    w = full["weight"]
    safe = jnp.where(rows >= 0, rows, w.shape[0])
    return jnp.take(w, safe, axis=0, mode="fill", fill_value=0)


def cached_slab_plan(
    ccfg: cache_lib.CacheConfig,
    slab: CachedSlab,
    raw_ids: jnp.ndarray,
    raw_future: Optional[jnp.ndarray] = None,
) -> cache_lib.CachePlan:
    """Planning half of ``cached_slab_prepare``: ids in, movement plan out —
    no weights touched (see ``cache.plan_prepare``)."""
    fut = None if raw_future is None else _translate(slab, raw_future)
    return cache_lib.plan_prepare(ccfg, slab.cache, _translate(slab, raw_ids), future_rows=fut)


def cached_slab_apply(
    ccfg: cache_lib.CacheConfig, slab: CachedSlab, plan: cache_lib.CachePlan
) -> CachedSlab:
    """Apply half: execute the planned row movement on this slab's weights."""
    full, cache_state = cache_lib.apply_plan(ccfg, slab.full, slab.cache, plan)
    return dataclasses.replace(slab, full=full, cache=cache_state)


def cached_slab_prepare(
    ccfg: cache_lib.CacheConfig, slab: CachedSlab, raw_ids: jnp.ndarray
) -> Tuple[CachedSlab, jnp.ndarray]:
    """Make all rows for ``raw_ids`` (slab-global, -1 pad) resident."""
    plan = cached_slab_plan(ccfg, slab, raw_ids)
    return cached_slab_apply(ccfg, slab, plan), plan.slots


def cached_slab_gather(slab: CachedSlab, slots: jnp.ndarray) -> jnp.ndarray:
    """Differentiable gather from the cached weight (padding -> zero rows)."""
    return cache_lib.lookup_slots(slab.cache, slots, leaf="weight")


def cached_slab_flush(ccfg: cache_lib.CacheConfig, slab: CachedSlab) -> CachedSlab:
    full, cache_state = cache_lib.flush(ccfg, slab.full, slab.cache)
    return dataclasses.replace(slab, full=full, cache=cache_state)


def cached_slab_warmup(ccfg: cache_lib.CacheConfig, slab: CachedSlab) -> CachedSlab:
    full, cache_state = cache_lib.warmup(ccfg, slab.full, slab.cache)
    return dataclasses.replace(slab, full=full, cache=cache_state)


# ---------------------------------------------------------------------------
# the collection
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _CachedSlabSpec:
    """Static geometry of one cached slab (solo table or shared arena)."""

    tables: Tuple[TableConfig, ...]
    cache_ratio: float
    policy: Policy
    buffer_rows: int
    max_unique_per_step: int
    protect_via_inverse: bool
    host_precision: str = "fp32"  # requested codec; "auto" resolves at init
    arena_precision: str = "fp32"  # device-arena tail codec; "auto" -> init
    arena_head_ratio: float = 0.25  # fp32 head fraction of a tiered arena
    freq_half_life: int = 1024  # online-tracker decay (adaptive engine)
    use_pallas_plan: bool = False  # bounded-top-K fused planning (CacheConfig)
    chunk_rows: int = 0  # chunk-granularity host staging (CacheConfig)

    @property
    def vocab(self) -> int:
        return sum(t.vocab for t in self.tables)

    @property
    def dim(self) -> int:
        return self.tables[0].dim

    @property
    def dtype(self):
        return self.tables[0].dtype

    @property
    def ids_per_step(self) -> int:
        return sum(t.ids_per_step for t in self.tables)

    @property
    def offsets(self) -> np.ndarray:
        return freq_lib.concat_table_offsets([t.vocab for t in self.tables])

    def unique_size(self, ids_per_step: Optional[int] = None) -> int:
        k = min(ids_per_step or self.ids_per_step, self.vocab)
        if self.max_unique_per_step:
            k = min(k, self.max_unique_per_step)
        return k

    @property
    def capacity(self) -> int:
        cap = max(int(self.cache_ratio * self.vocab), self.unique_size())
        return min(cap, self.vocab)

    @property
    def head_capacity(self) -> int:
        """fp32 slots of the (possibly tiered) arena — mirrors
        ``CacheConfig.head_capacity`` so planner/policy math agrees with the
        cache's own split."""
        if self.arena_precision == "fp32":
            return self.capacity
        return min(self.capacity, max(1, int(round(self.arena_head_ratio * self.capacity))))

    def cache_config(self, ids_per_step: Optional[int] = None, writeback: bool = True):
        # NB: capacity is fixed at construction; a batch whose unique buffer
        # exceeds it fails CacheConfig's own guard with an actionable error
        # (more uniques than slots cannot all be resident at once).  Serve
        # batches larger than ``ids_per_step`` are fine as long as
        # ``max_unique_per_step`` (or the vocab) bounds their uniques.
        return cache_lib.CacheConfig(
            vocab=self.vocab,
            capacity=self.capacity,
            ids_per_step=ids_per_step or self.ids_per_step,
            buffer_rows=self.buffer_rows,
            policy=self.policy,
            writeback=writeback,
            max_unique_per_step=self.max_unique_per_step,
            protect_via_inverse=self.protect_via_inverse,
            # a still-unresolved "auto" budgets/structures like the policy's
            # no-stats default; ``EmbeddingCollection.init`` replaces the spec
            # with the counts-resolved codec before any state exists.
            arena_precision=(
                PrecisionPolicy().no_stats
                if self.arena_precision == "auto"
                else self.arena_precision
            ),
            arena_head_ratio=self.arena_head_ratio,
            freq_half_life=self.freq_half_life,
            use_pallas_plan=self.use_pallas_plan,
            chunk_rows=self.chunk_rows,
        )


class EmbeddingCollection:
    """N tables under one placement plan, behind one keyed-feature surface."""

    def __init__(self, tables: Sequence[TableConfig], plan: PlacementPlan):
        names = [t.name for t in tables]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate table names: {names}")
        missing = [n for n in names if n not in plan.placements]
        if missing:
            raise ValueError(f"plan is missing placements for tables: {missing}")
        self.tables: Dict[str, TableConfig] = {t.name: t for t in tables}
        self.plan = plan

        # feature -> owning table
        self.feature_to_table: Dict[str, str] = {}
        for t in tables:
            for f in t.features:
                if f in self.feature_to_table:
                    raise ValueError(f"feature {f!r} claimed by two tables")
                self.feature_to_table[f] = t.name

        # slab layout: DEVICE/CACHED tables get their own slab; GROUPED share one
        self.device_slabs: Dict[str, TableConfig] = {}
        self.cached_slabs: Dict[str, _CachedSlabSpec] = {}
        grouped: List[TableConfig] = []
        for t in tables:
            p = plan.placements[t.name]
            if p.placement is Placement.DEVICE:
                self.device_slabs[t.name] = t
            elif p.placement is Placement.CACHED:
                self.cached_slabs[t.name] = _CachedSlabSpec(
                    tables=(t,),
                    cache_ratio=t.cache_ratio if p.cache_ratio is None else p.cache_ratio,
                    policy=t.policy,
                    buffer_rows=t.buffer_rows,
                    max_unique_per_step=t.max_unique_per_step,
                    protect_via_inverse=t.protect_via_inverse,
                    host_precision=p.host_precision or t.host_precision or "fp32",
                    arena_precision=p.arena_precision or t.arena_precision or "fp32",
                    freq_half_life=t.freq_half_life,
                    use_pallas_plan=t.use_pallas_plan,
                    chunk_rows=t.chunk_rows,
                )
            else:
                grouped.append(t)
        if grouped:
            dims = {(t.dim, jnp.dtype(t.dtype).name) for t in grouped}
            if len(dims) != 1:
                raise ValueError(f"GROUPED tables must share (dim, dtype); got {dims}")
            a = plan.arena
            self.cached_slabs[SHARED_ARENA] = _CachedSlabSpec(
                tables=tuple(grouped),
                cache_ratio=a.cache_ratio,
                policy=a.policy,
                buffer_rows=a.buffer_rows,
                max_unique_per_step=a.max_unique_per_step,
                protect_via_inverse=a.protect_via_inverse,
                host_precision=a.host_precision,
                arena_precision=a.arena_precision,
                arena_head_ratio=a.arena_head_ratio,
                freq_half_life=a.freq_half_life,
                use_pallas_plan=a.use_pallas_plan,
                chunk_rows=a.chunk_rows,
            )
        # resolved host codec per cached slab ("auto" is re-resolved by init,
        # which needs the frequency counts; shard_specs/device_bytes read this)
        self.host_precision: Dict[str, str] = {
            sname: spec.host_precision for sname, spec in self.cached_slabs.items()
        }
        # resolved device-arena tail codec per cached slab (same protocol:
        # "auto" re-resolves at init, when frequency counts are available)
        self.arena_precision: Dict[str, str] = {
            sname: spec.arena_precision for sname, spec in self.cached_slabs.items()
        }
        self.precision_policy = PrecisionPolicy()

        # table -> (slab, offset of the table inside the slab's concat vocab)
        self.table_slab: Dict[str, Tuple[str, int]] = {}
        for name in self.device_slabs:
            self.table_slab[name] = (name, 0)
        for sname, spec in self.cached_slabs.items():
            offs = spec.offsets
            for t, off in zip(spec.tables, offs):
                self.table_slab[t.name] = (sname, int(off))

    # ----- construction -----------------------------------------------------

    @classmethod
    def create(
        cls,
        tables: Sequence[TableConfig],
        budget_bytes: Optional[int] = None,
        counts: Optional[Mapping[str, np.ndarray]] = None,
        planner: Optional[PlacementPlanner] = None,
        **arena_kw,
    ) -> "EmbeddingCollection":
        """Plan + build.  Without a budget this is the paper's layout (one
        shared cache arena over all tables).  ``host_precision=`` (in
        ``arena_kw``) selects the host-tier codec collection-wide:
        "fp32"/"fp16"/"int8"/"auto"."""
        if planner is None and budget_bytes is None:
            return cls(tables, PlacementPlan.single_arena(tables, **arena_kw))
        planner = planner or PlacementPlanner(
            budget_bytes,
            arena=ArenaConfig(**arena_kw),
            host_precision=arena_kw.get("host_precision"),
            arena_precision=arena_kw.get("arena_precision"),
            arena_head_ratio=arena_kw.get("arena_head_ratio", 0.25),
        )
        return cls(tables, planner.plan(tables, counts=counts))

    # ----- init -------------------------------------------------------------

    def split_concat_counts(self, counts: np.ndarray) -> Dict[str, np.ndarray]:
        """Split a concatenated-vocab count vector (table declaration order)
        into the per-table dict ``init`` expects."""
        out, off = {}, 0
        for t in self.tables.values():
            out[t.name] = np.asarray(counts[off : off + t.vocab])
            off += t.vocab
        assert off == counts.shape[0], "counts length != total vocab"
        return out

    def init(
        self,
        rng: jax.Array,
        counts: Optional[Mapping[str, np.ndarray]] = None,
        warm: bool = True,
        host_precision: Optional[str] = None,
        arena_precision: Optional[str] = None,
    ) -> CollectionState:
        """Build the collection state.  ``host_precision`` overrides every
        cached slab's host-tier codec for this state ("fp32"/"fp16"/"int8"/
        "auto"); "auto" asks ``PrecisionPolicy`` to pick per slab from the
        frequency counts (fp16 when no counts are given).  The resolved
        choice is recorded in ``self.host_precision`` so ``shard_specs`` and
        ``device_bytes`` stay structurally consistent with the state.

        ``arena_precision`` does the same for the DEVICE arena's tail codec:
        "fp32" keeps the raw pre-tiering arena dict (bit-identical), "fp16"/
        "int8" build a frequency-tiered ``ArenaStore``, and "auto" asks
        ``PrecisionPolicy.choose_arena`` whether the fp32 head absorbs enough
        resident traffic to quantize the tail.  The resolved codec is written
        back into ``self.cached_slabs``/``self.arena_precision`` so every
        later ``cache_config()`` (prepare/refresh/flush/shard_specs) agrees
        with the state's arena container."""
        slabs: Dict[str, Any] = {}
        keys = jax.random.split(rng, len(self.device_slabs) + len(self.cached_slabs))
        kit = iter(keys)
        for name, t in self.device_slabs.items():
            scale = 1.0 / np.sqrt(t.dim)
            slabs[name] = DeviceSlab(
                weight=jax.random.uniform(next(kit), (t.vocab, t.dim), t.dtype, -scale, scale)
            )
        for sname, spec in self.cached_slabs.items():
            scale = 1.0 / np.sqrt(spec.dim)
            weight = jax.random.uniform(
                next(kit), (spec.vocab, spec.dim), spec.dtype, -scale, scale
            )
            slab_counts = None
            if counts is not None:
                slab_counts = np.concatenate(
                    [
                        np.asarray(
                            counts.get(t.name, np.zeros((t.vocab,), np.int64)), np.int64
                        )
                        for t in spec.tables
                    ]
                )
                idx_map = jnp.asarray(freq_lib.build_freq_stats(slab_counts).idx_map)
            else:
                idx_map = jnp.arange(spec.vocab, dtype=jnp.int32)
            geom = SlabGeometry(
                name=sname,
                vocab=spec.vocab,
                dim=spec.dim,
                capacity=spec.capacity,
                dtype_itemsize=jnp.dtype(spec.dtype).itemsize,
            )
            codec = host_precision or spec.host_precision
            if codec == "auto":
                codec = self.precision_policy.choose(geom, counts=slab_counts)
            else:
                get_codec(codec)  # fail fast on typos
            self.host_precision[sname] = codec
            arena_codec = arena_precision or spec.arena_precision
            if arena_codec == "auto":
                arena_codec = self.precision_policy.choose_arena(
                    geom, spec.head_capacity, counts=slab_counts
                )
            else:
                get_codec(arena_codec)  # fail fast on typos
            if arena_codec != spec.arena_precision:
                # write the resolution back so every later cache_config()
                # (prepare / refresh / flush / shard_specs) builds the same
                # arena container this state carries.
                spec = dataclasses.replace(spec, arena_precision=arena_codec)
                self.cached_slabs[sname] = spec
            self.arena_precision[sname] = arena_codec
            slab = CachedSlab(
                full=HostStore.create({"weight": weight}, codec=codec),
                cache=cache_lib.init_cache(
                    spec.cache_config(), {"weight": jnp.zeros((spec.dim,), spec.dtype)}
                ),
                idx_map=idx_map,
            )
            if warm:
                slab = cached_slab_warmup(spec.cache_config(), slab)
            slabs[sname] = slab
        return CollectionState(slabs=slabs)

    # ----- the non-diff bookkeeping pass ------------------------------------

    def _check_features(self, *fbs: FeatureBatch) -> None:
        for b in fbs:
            for f in b.features:
                if f not in self.feature_to_table:
                    raise KeyError(
                        f"unknown feature {f!r}; known: {sorted(self.feature_to_table)}"
                    )

    def _slab_lanes(self, fb: FeatureBatch, sname: str) -> List[Tuple[str, int]]:
        """Static (feature, flat lane count) list this slab serves, in a
        deterministic order (slab table order, then FeatureBatch order)."""
        spec = self.cached_slabs[sname]
        member = {t.name for t in spec.tables}
        out = []
        for f in fb.features:
            if self.feature_to_table.get(f) in member:
                out.append((f, int(np.prod(fb.ids[f].shape))))
        return out

    def _slab_raw(self, fb: FeatureBatch, sname: str) -> Optional[jnp.ndarray]:
        """Flat offset-translated id vector of this slab's lanes in ``fb``
        (slab-lane order); None when the batch has no lanes for the slab."""
        lanes = self._slab_lanes(fb, sname)
        if not lanes:
            return None
        parts = []
        for f, _ in lanes:
            ids = fb.ids[f].reshape(-1).astype(jnp.int32)
            off = self.table_slab[self.feature_to_table[f]][1]
            parts.append(jnp.where(ids >= 0, ids + off, -1))
        return jnp.concatenate(parts) if len(parts) > 1 else parts[0]

    def plan_prepare(
        self,
        state: CollectionState,
        fb: FeatureBatch,
        fb_future: Sequence[FeatureBatch] = (),
        writeback: bool = True,
    ) -> CollectionPlan:
        """Planning half of ``prepare``: dedup, slot assignment and the row
        movement plan, computed from ids and index state alone — no weights
        are read, so this can run while the previous step's dense compute is
        still in flight (the pipelined trainer dispatches it there).

        ``fb_future`` is a lookahead window of future batches: their ids are
        merged into the admission decision so rows needed at step t+k are
        scheduled for load now, and slots holding soon-needed rows are pinned
        against eviction (see ``cache.plan_prepare``).  The plan also carries
        each future batch's addresses (from the post-apply index image) plus a
        ``future_unresident`` count so a group-scheduled trainer can run the
        whole window off one merged plan — amortizing the bookkeeping k-fold —
        after checking that nothing was dropped under capacity pressure.
        """
        self._check_features(fb, *fb_future)
        addresses: Dict[str, jnp.ndarray] = {}
        future_addresses: List[Dict[str, jnp.ndarray]] = [{} for _ in fb_future]
        future_unresident = jnp.zeros((), jnp.int32)

        # DEVICE tables: the address IS the (local) row id.
        for j, b in enumerate((fb, *fb_future)):
            out = addresses if j == 0 else future_addresses[j - 1]
            for f in b.features:
                if self.feature_to_table[f] in self.device_slabs:
                    out[f] = b.ids[f].astype(jnp.int32)

        # cached slabs: concatenate this batch's lanes, one plan per slab.
        slab_plans: Dict[str, cache_lib.CachePlan] = {}
        for sname, spec in self.cached_slabs.items():
            raw = self._slab_raw(fb, sname)
            slab = state.slabs[sname]
            fut_raws = [self._slab_raw(b, sname) for b in fb_future]
            if raw is None:
                # a slab touched only by the window is not prefetched (every
                # batch of a homogeneous stream touches the same slabs; its
                # own step will fault the rows in exactly) — but its window
                # lanes are then NOT resident, so a group-scheduled trainer
                # must see them in the guard instead of a missing address.
                for raw_j in fut_raws:
                    if raw_j is not None:
                        future_unresident = future_unresident + jnp.sum(
                            raw_j >= 0
                        ).astype(jnp.int32)
                continue
            # translate once per future batch; the merged plan input and the
            # per-batch address lookups reuse the same translated rows
            rows_fut = [None if p is None else _translate(slab, p) for p in fut_raws]
            fut_parts = [r for r in rows_fut if r is not None]
            future_rows = jnp.concatenate(fut_parts) if fut_parts else None
            ccfg = spec.cache_config(ids_per_step=int(raw.shape[0]), writeback=writeback)
            plan = cache_lib.plan_prepare(
                ccfg, slab.cache, _translate(slab, raw), future_rows=future_rows
            )
            slab_plans[sname] = plan
            pos = 0
            for f, n in self._slab_lanes(fb, sname):
                addresses[f] = plan.slots[pos : pos + n].reshape(fb.ids[f].shape)
                pos += n
            # future lanes: addresses from the post-apply index image; count
            # lanes whose row will not be resident (dropped under pressure)
            for j, (b, rows_j) in enumerate(zip(fb_future, rows_fut)):
                if rows_j is None:
                    continue
                slots_j = plan.row_to_slot.at[jnp.where(rows_j >= 0, rows_j, 0)].get(
                    mode="fill", fill_value=-1
                )
                slots_j = jnp.where(rows_j >= 0, slots_j, -1)
                future_unresident = future_unresident + jnp.sum(
                    (rows_j >= 0) & (slots_j < 0)
                ).astype(jnp.int32)
                pos = 0
                for f, n in self._slab_lanes(b, sname):
                    future_addresses[j][f] = slots_j[pos : pos + n].reshape(b.ids[f].shape)
                    pos += n
        return CollectionPlan(
            slab_plans=slab_plans,
            addresses=addresses,
            future_addresses=tuple(future_addresses),
            future_unresident=future_unresident,
            writeback=writeback,
        )

    def apply_plan(self, state: CollectionState, plan: CollectionPlan) -> CollectionState:
        """Apply half of ``prepare``: execute each slab's planned row movement
        (the only part that touches weights — in the pipelined trainer it runs
        after the previous step's row update so evictions write back fresh
        values) and install the index images."""
        slabs = dict(state.slabs)
        for sname, p in plan.slab_plans.items():
            spec = self.cached_slabs[sname]
            ccfg = spec.cache_config(writeback=plan.writeback)
            slabs[sname] = cached_slab_apply(ccfg, slabs[sname], p)
        return CollectionState(slabs=slabs)

    def prepare(
        self, state: CollectionState, fb: FeatureBatch, writeback: bool = True
    ) -> Tuple[CollectionState, Dict[str, jnp.ndarray]]:
        """Make every requested row resident; return per-feature addresses.

        Addresses are cache slots for cached tables and plain row indices for
        DEVICE tables (-1 marks padding lanes in both).  Non-differentiable —
        call outside the grad closure (Algorithm 1 bookkeeping).  Equivalent
        to ``apply_plan(state, plan_prepare(state, fb))`` — bit-exact with the pre-split
        implementation.
        """
        p = self.plan_prepare(state, fb, writeback=writeback)
        return self.apply_plan(state, p), p.addresses

    def prepare_lookahead(
        self,
        state: CollectionState,
        fb_now: FeatureBatch,
        fb_future: Sequence[FeatureBatch],
        writeback: bool = True,
    ) -> Tuple[CollectionState, Dict[str, jnp.ndarray]]:
        """``prepare`` with a lookahead window: rows needed by ``fb_future``
        are fetched before they miss and pinned against eviction until their
        step comes up.  Exactness for ``fb_now`` is unconditional (future
        loads are dropped first under capacity pressure)."""
        p = self.plan_prepare(state, fb_now, fb_future=tuple(fb_future), writeback=writeback)
        return self.apply_plan(state, p), p.addresses

    # ----- differentiable read path -----------------------------------------

    def weights(self, state: CollectionState) -> Dict[str, jnp.ndarray]:
        """The trainable fast-tier weights, keyed by slab — differentiate the
        loss w.r.t. this dict and feed the grads to ``apply_grads``.

        A tiered arena returns its full DECODED [capacity, dim] view: the
        forward/backward run in fp32 against the dequantized rows, and
        ``apply_grads`` re-encodes the updated tail — the straight-through
        scheme of arXiv 2010.11305 (gradients flow as if the arena were
        full-precision; storage noise enters only through the decode)."""
        out = {}
        for name in self.device_slabs:
            out[name] = state.slabs[name].weight
        for sname in self.cached_slabs:
            cached = state.slabs[sname].cache.cached_rows
            if isinstance(cached, ArenaStore):
                out[sname] = cached.decode_leaf("weight")
            else:
                out[sname] = cached["weight"]
        return out

    @contract(max_sort_size=0)
    def gather(
        self,
        weights: Mapping[str, jnp.ndarray],
        addresses: Mapping[str, jnp.ndarray],
        fb: FeatureBatch,
    ) -> Dict[str, jnp.ndarray]:
        """Pure gather: feature -> rows of shape ``ids.shape + (dim,)``.

        A function of ``weights`` only, so gradients flow to the cached rows
        (or the DEVICE table) and nowhere else.
        """
        out = {}
        for f in fb.features:
            sname = self.table_slab[self.feature_to_table[f]][0]
            w = weights[sname]
            addr = addresses[f]
            flat = addr.reshape(-1)
            safe = jnp.where(flat >= 0, flat, w.shape[0])
            rows = jnp.take(w, safe, axis=0, mode="fill", fill_value=0)
            out[f] = rows.reshape(addr.shape + (w.shape[-1],))
        return out

    def pool(
        self,
        rows: Mapping[str, jnp.ndarray],
        fb: FeatureBatch,
        combiner: str = "sum",
        *,
        weights: Optional[Mapping[str, jnp.ndarray]] = None,
        addresses: Optional[Mapping[str, jnp.ndarray]] = None,
        use_pallas: bool = False,
        max_bag: int = 0,
    ) -> Dict[str, jnp.ndarray]:
        """Segment-reduce bag features ([lanes, dim] -> [num_segments, dim]);
        one-hot features pass through.

        With ``use_pallas`` (and ``weights`` + ``addresses`` from the same
        step), bag features skip the materialized per-lane ``rows`` entirely:
        the Pallas embedding-bag kernel runs a fused gather+segment-sum
        straight off the fast-tier slab, with the cache-slot addresses as its
        ids (-1 lanes are padding).  Differentiable w.r.t. ``weights`` via the
        kernel's custom VJP; the ``jnp.take``/``segment_sum`` route below
        stays as the bit-exactness reference.
        """
        out = dict(rows)
        if use_pallas and (weights is None or addresses is None):
            raise ValueError("use_pallas pooling needs weights= and addresses=")
        for f, seg in fb.segments.items():
            if use_pallas:
                from repro.kernels.embedding_bag import ops as eb_ops

                sname = self.table_slab[self.feature_to_table[f]][0]
                out[f] = eb_ops.embedding_bag(
                    weights[sname],
                    addresses[f].reshape(-1),
                    seg,
                    fb.num_segments,
                    combiner=combiner,
                    max_bag=max_bag,
                )
                continue
            pooled = jax.ops.segment_sum(rows[f], seg, num_segments=fb.num_segments)
            if combiner == "mean":
                cnt = jax.ops.segment_sum(
                    (fb.ids[f] >= 0).astype(pooled.dtype), seg, num_segments=fb.num_segments
                )
                pooled = pooled / jnp.maximum(cnt, 1.0)[:, None]
            out[f] = pooled
        return out

    def lookup(
        self, state: CollectionState, fb: FeatureBatch, writeback: bool = True
    ) -> Tuple[CollectionState, Dict[str, jnp.ndarray], Dict[str, jnp.ndarray]]:
        """Convenience prepare+gather: (state', addresses, feature -> rows)."""
        state, addresses = self.prepare(state, fb, writeback=writeback)
        rows = self.gather(self.weights(state), addresses, fb)
        return state, addresses, rows

    # ----- updates ----------------------------------------------------------

    @contract(donates=("state",), int_counters=INT_COUNTERS, max_sort_size=0)
    def apply_grads(
        self,
        state: CollectionState,
        grads: Mapping[str, jnp.ndarray],
        lr,
    ) -> CollectionState:
        """Synchronous SGD on the fast tier (the paper §2.2.3 scheme: resident
        rows are authoritative; the slow tier catches up at eviction/flush)."""
        slabs = dict(state.slabs)
        for name in self.device_slabs:
            slab = slabs[name]
            slabs[name] = dataclasses.replace(
                slab, weight=(slab.weight - lr * grads[name]).astype(slab.weight.dtype)
            )
        for sname in self.cached_slabs:
            slab = slabs[sname]
            cached = slab.cache.cached_rows
            if isinstance(cached, ArenaStore):
                # quantization-aware SGD: step on the decoded view, then store
                # head rows raw and re-encode the tail with a fresh per-row
                # master scale (the sideband).  Rows with zero gradient
                # re-encode to the identical payload (stable projection), so
                # untouched residents never drift.
                w = cached.decode_leaf("weight")
                w = (w - lr * grads[sname]).astype(w.dtype)
                cached = cached.replace_leaf("weight", w)
            else:
                cached = dict(cached)
                cached["weight"] = (cached["weight"] - lr * grads[sname]).astype(
                    cached["weight"].dtype
                )
            slabs[sname] = dataclasses.replace(
                slab, cache=dataclasses.replace(slab.cache, cached_rows=cached)
            )
        return CollectionState(slabs=slabs)

    def flush(self, state: CollectionState) -> CollectionState:
        """Checkpoint barrier: every cached slab writes residents back."""
        slabs = dict(state.slabs)
        for sname, spec in self.cached_slabs.items():
            slabs[sname] = cached_slab_flush(spec.cache_config(), slabs[sname])
        return CollectionState(slabs=slabs)

    # ----- adaptive frequency refresh ---------------------------------------

    def refresh(
        self,
        state: CollectionState,
        cfg: Optional[refresh_lib.RefreshConfig] = None,
        writeback: bool = True,
    ) -> Tuple[CollectionState, refresh_lib.RefreshReport]:
        """Re-rank every cached slab from its online decayed counters and
        apply the bounded incremental permutation (``core.refresh``).

        Host-side, OUTSIDE any jitted step; run it only when no planned
        addresses are outstanding (the trainers call it between steps /
        pipeline groups, the serve engine between batches).  Pure reindexing:
        ``full_lookup``/``dense_reference``/``lookup`` return bitwise the
        same values immediately before and after the call for fp32 host
        stores (codec-noise-bounded for fp16/int8, whose swapped dirty rows
        pay one quantize round trip on the write-back).  Pass
        ``writeback=False`` for read-only (serve) states, whose resident rows
        are clean.  Returns the refreshed state plus a ``RefreshReport``; the
        same counts accumulate in-state (``metrics()``: ``refresh_swaps`` /
        ``refresh_rows_moved``).
        """
        cfg = cfg or refresh_lib.RefreshConfig()
        slabs = dict(state.slabs)
        report = refresh_lib.RefreshReport()
        for sname, spec in self.cached_slabs.items():
            slabs[sname], stats = refresh_lib.refresh_cached_slab(
                spec.cache_config(writeback=writeback), slabs[sname], cfg,
                writeback=writeback,
            )
            report.add(sname, stats)
        return CollectionState(slabs=slabs), report

    def collect_counts_stream(
        self, stream, max_batches: Optional[int] = None
    ) -> Dict[str, np.ndarray]:
        """``freq.collect_counts_stream`` with this collection's feature ->
        table routing and vocab sizes filled in: per-table counts straight
        off a ``Prefetcher`` / ``FeatureBatch`` iterator, ready for
        ``init(counts=...)``."""
        return freq_lib.collect_counts_stream(
            stream,
            self.feature_to_table,
            {t.name: t.vocab for t in self.tables.values()},
            max_batches=max_batches,
        )

    # ----- oracles / bulk reads ---------------------------------------------

    def full_lookup(
        self, state: CollectionState, table: str, local_ids: jnp.ndarray
    ) -> jnp.ndarray:
        """Bulk read from the authoritative (slow) tier of one table —
        retrieval-style candidate scans bypass cache bookkeeping by design."""
        sname, off = self.table_slab[table]
        if sname in self.device_slabs:
            w = state.slabs[sname].weight
            safe = jnp.where(local_ids >= 0, local_ids, w.shape[0])
            return jnp.take(w, safe, axis=0, mode="fill", fill_value=0)
        slab = state.slabs[sname]
        valid = local_ids >= 0
        rows = slab.idx_map.at[jnp.where(valid, local_ids + off, 0)].get(
            mode="fill", fill_value=-1
        )
        return _read_full_rows(slab.full, jnp.where(valid, rows, -1))

    def dense_reference(
        self, state: CollectionState, fb: FeatureBatch
    ) -> Dict[str, jnp.ndarray]:
        """Oracle lookup reading only authoritative tiers (flush first so the
        slow tier is current) — the bit-exactness reference for tests (with a
        quantized host store the slow tier is codec-roundtrip-exact: what was
        flushed is what the oracle decodes)."""
        out = {}
        for f in fb.features:
            tname = self.feature_to_table[f]
            sname, off = self.table_slab[tname]
            ids = fb.ids[f]
            flat = ids.reshape(-1)
            if sname in self.device_slabs:
                w = state.slabs[sname].weight
                safe = jnp.where(flat >= 0, flat, w.shape[0])
                rows = jnp.take(w, safe, axis=0, mode="fill", fill_value=0)
            else:
                slab = state.slabs[sname]
                r = slab.idx_map.at[
                    jnp.where(flat >= 0, flat + off, 0)
                ].get(mode="fill", fill_value=-1)
                rows = _read_full_rows(slab.full, jnp.where(flat >= 0, r, -1))
            out[f] = rows.reshape(ids.shape + (rows.shape[-1],))
        return out

    # ----- telemetry / accounting -------------------------------------------

    # jit-adjacent: traced inside every compute_step — the int-counter
    # contract pins the per-slab counter families the obs hub reconstructs,
    # and max_sort_size=0 asserts metric collection never adds a sort.
    @contract(int_counters=METRICS_INT_COUNTERS, max_sort_size=0)
    def metrics(
        self, state: CollectionState, writeback: bool = True
    ) -> Dict[str, jnp.ndarray]:
        """Cache telemetry aggregated over cached slabs (DEVICE tables have
        no bookkeeping, hence no misses by construction).  ``host_wire_bytes``
        is the cumulative host<->device traffic estimate: demand misses
        (loads) plus — when the caller runs the cache with writeback —
        evictions (writebacks), each costing the slab's *encoded* row size,
        the quantity the mixed-precision store shrinks.  Pass
        ``writeback=False`` for read-only (serve) states, whose evicted rows
        are dropped and never cross the link.

        Two representations are returned: ``host_wire_bytes`` is a float32
        scalar (in-jit convenience — float32 loses integer resolution past
        2^24, so it DRIFTS on long runs), while ``host_moved_rows`` /
        ``host_row_bytes`` are per-slab int32 counters + static encoded row
        sizes from which :func:`exact_metric_bytes` reconstructs the exact
        cumulative byte count host-side (what the trainer records)."""
        hits = misses = evictions = overflows = 0
        win_h = win_m = jnp.zeros((), jnp.float32)
        ref_swaps = ref_rows = jnp.zeros((), jnp.int32)
        wire = jnp.zeros((), jnp.float32)
        moved_rows: Dict[str, jnp.ndarray] = {}
        row_bytes_map: Dict[str, jnp.ndarray] = {}
        slab_hits: Dict[str, jnp.ndarray] = {}
        slab_misses: Dict[str, jnp.ndarray] = {}
        slab_ref_swaps: Dict[str, jnp.ndarray] = {}
        slab_ref_rows: Dict[str, jnp.ndarray] = {}
        slab_tier_promotions: Dict[str, jnp.ndarray] = {}
        slab_tier_demotions: Dict[str, jnp.ndarray] = {}
        for sname, spec in self.cached_slabs.items():
            c = state.slabs[sname].cache
            hits = hits + jnp.sum(c.hits)
            misses = misses + jnp.sum(c.misses)
            evictions = evictions + jnp.sum(c.evictions)
            overflows = overflows + jnp.sum(c.uniq_overflows)
            slab_hits[sname] = jnp.sum(c.hits).astype(jnp.int32)
            slab_misses[sname] = jnp.sum(c.misses).astype(jnp.int32)
            win_h = win_h + jnp.sum(c.tracker.win_hits)
            win_m = win_m + jnp.sum(c.tracker.win_misses)
            ref_swaps = ref_swaps + jnp.sum(c.tracker.refresh_swaps)
            ref_rows = ref_rows + jnp.sum(c.tracker.refresh_rows)
            slab_ref_swaps[sname] = jnp.sum(c.tracker.refresh_swaps).astype(jnp.int32)
            slab_ref_rows[sname] = jnp.sum(c.tracker.refresh_rows).astype(jnp.int32)
            # precision-boundary crossings (jnp.sum folds the sharded [S]
            # per-shard counters into one cumulative int32, like hits/misses)
            slab_tier_promotions[sname] = jnp.sum(c.tier_promotions).astype(jnp.int32)
            slab_tier_demotions[sname] = jnp.sum(c.tier_demotions).astype(jnp.int32)
            full = state.slabs[sname].full
            row_bytes = (
                full.row_wire_bytes(batch_dims=full.data["weight"].ndim - 1)
                if isinstance(full, HostStore)
                else spec.dim * jnp.dtype(spec.dtype).itemsize
            )
            moved = c.misses + c.evictions if writeback else c.misses
            moved_rows[sname] = jnp.sum(moved).astype(jnp.int32)
            row_bytes_map[sname] = jnp.asarray(row_bytes, jnp.int32)
            wire = wire + jnp.sum(moved).astype(jnp.float32) * row_bytes
        tot = hits + misses
        win_tot = win_h + win_m
        return {
            "hit_rate": jnp.where(tot > 0, hits / jnp.maximum(tot, 1), 0.0),
            # drift telemetry: the exponentially-windowed hit rate reacts to a
            # hot-set shift within ~one half-life, long before the cumulative
            # rate moves; refresh_* count the adaptive engine's rank churn
            # (swapped pairs) and slow-tier rows it permuted.
            "window_hit_rate": jnp.where(
                win_tot > 0, win_h / jnp.maximum(win_tot, 1e-9), 0.0
            ),
            "refresh_swaps": ref_swaps,
            "refresh_rows_moved": ref_rows,
            "cache_misses": jnp.asarray(misses),
            "cache_evictions": jnp.asarray(evictions),
            "uniq_overflows": jnp.asarray(overflows),
            "host_wire_bytes": wire,
            "host_moved_rows": moved_rows,
            "host_row_bytes": row_bytes_map,
            # per-slab cumulative int32 counters: wrap-free exact totals are
            # reconstructed host-side (``repro.obs.hub``) — the int32 scalars
            # above wrap past 2^31 on long runs.
            "slab_hits": slab_hits,
            "slab_misses": slab_misses,
            "slab_refresh_swaps": slab_ref_swaps,
            "slab_refresh_rows": slab_ref_rows,
            "slab_tier_promotions": slab_tier_promotions,
            "slab_tier_demotions": slab_tier_demotions,
        }

    def _slab_codec(self, sname: str) -> str:
        """Resolved host codec of one cached slab ("auto" before init falls
        back to the policy's no-stats default for accounting purposes)."""
        name = self.host_precision[sname]
        return self.precision_policy.no_stats if name == "auto" else name

    def _slab_arena_codec(self, sname: str) -> str:
        """Resolved device-arena tail codec (same "auto" fallback protocol
        as ``_slab_codec``)."""
        name = self.arena_precision[sname]
        return self.precision_policy.no_stats if name == "auto" else name

    def device_bytes(self) -> Dict[str, int]:
        """Device-resident vs host-tier footprint under the plan (per-slab
        breakdown included; the planner's budget bounds ``device_total``).
        The slow tier is accounted at its *encoded* size; ``host_bytes_saved``
        is what the host-precision codecs shaved off the fp32 layout, and
        ``arena_bytes_saved`` what the tiered arena shaved off the device
        side (a tiered slab's weight bytes = fp32 head + encoded tail payload
        + tail sideband, all device-resident)."""
        per_slab: Dict[str, int] = {}
        slow = slow_fp32 = 0
        fast_fp32 = fast_actual = 0
        for name, t in self.device_slabs.items():
            per_slab[name] = t.full_bytes
        for sname, spec in self.cached_slabs.items():
            item = jnp.dtype(spec.dtype).itemsize
            arena_codec = self._slab_arena_codec(sname)
            head = spec.capacity if arena_codec == "fp32" else spec.head_capacity
            w = tiered_arena_bytes(spec.capacity, head, spec.dim, spec.dtype, arena_codec)
            fast = w
            fast += spec.capacity * 4 * 3  # slot_to_row, last_used, use_count
            # row_to_slot + idx_map + tracker (score + last_touch)
            fast += spec.vocab * 4 * 4
            per_slab[sname] = fast
            fast_actual += w
            fast_fp32 += spec.capacity * spec.dim * item
            codec = get_codec(self._slab_codec(sname))
            slow += spec.vocab * codec.row_bytes((spec.dim,), spec.dtype)
            slow_fp32 += spec.vocab * spec.dim * item
        return {
            "device_total": sum(per_slab.values()),
            "slow_tier_bytes": slow,
            "host_bytes_saved": slow_fp32 - slow,
            "arena_bytes_saved": fast_fp32 - fast_actual,
            "per_slab": per_slab,
            "budget_bytes": self.plan.budget_bytes,
        }

    # ----- sharding ----------------------------------------------------------

    def shard_specs(self, mode: str = "column", model_axis: str = "model"):
        """PartitionSpec pytree matching ``CollectionState`` (see
        ``cached_embedding.shard_specs`` for the mode semantics).  The slow
        tier's specs mirror the slab's resolved ``HostStore`` layout — with
        an "auto" precision, call after ``init`` so the resolved codec (and
        hence the sideband structure) matches the state."""
        from jax.sharding import PartitionSpec as P

        if mode == "column":
            full_w = cached_w = dev_w = P(None, model_axis)
            side_w = P(None, None)  # per-row sideband cannot split the dim
        elif mode == "row":
            full_w, cached_w = P(model_axis, None), P(None, None)
            dev_w = P(model_axis, None)
            side_w = P(model_axis, None)  # sideband rows travel with the table
        else:
            full_w = cached_w = dev_w = side_w = P(None, None)

        slabs: Dict[str, Any] = {}
        for name in self.device_slabs:
            slabs[name] = DeviceSlab(weight=dev_w)
        for sname, spec in self.cached_slabs.items():
            like = {"weight": jax.ShapeDtypeStruct((spec.vocab, spec.dim), spec.dtype)}
            arena_codec = self._slab_arena_codec(sname)
            if arena_codec == "fp32":
                cached_rows: Any = {"weight": cached_w}
            else:
                # tiered arena: head/tail carry the cached-weight spec; the
                # [slots, 2] sideband rides with the CACHE (replicated in row
                # mode, and its (scale, zp) axis can never split the model
                # axis), hence P(None, None) rather than the host side_w.
                cached_rows = ArenaStore.spec_like(
                    {
                        "weight": jax.ShapeDtypeStruct(
                            (spec.capacity, spec.dim), spec.dtype
                        )
                    },
                    cached_w,
                    P(None, None),
                    codec=arena_codec,
                )
            slabs[sname] = CachedSlab(
                full=HostStore.spec_like(
                    like, {"weight": full_w}, side_w, codec=self._slab_codec(sname)
                ),
                cache=cache_lib.CacheState(
                    cached_rows=cached_rows,
                    slot_to_row=P(None),
                    row_to_slot=P(None),
                    last_used=P(None),
                    use_count=P(None),
                    step=P(),
                    hits=P(),
                    misses=P(),
                    evictions=P(),
                    uniq_overflows=P(),
                    tier_promotions=P(),
                    tier_demotions=P(),
                    tracker=freq_lib.tracker_spec(P),
                ),
                idx_map=P(None),
            )
        return CollectionState(slabs=slabs)


def exact_metric_bytes(
    metrics: Mapping[str, Any], counts_key: str, bytes_key: str
) -> Optional[int]:
    """Exact cumulative byte counter from a metrics dict, as a Python int.

    ``metrics[counts_key]`` holds per-slab int32 cumulative counts and
    ``metrics[bytes_key]`` the matching per-unit byte sizes (both emitted by
    ``EmbeddingCollection.metrics``); their products are summed in Python
    integer arithmetic, so — unlike the float32 ``host_wire_bytes`` scalar,
    which loses integer resolution past 2^24 — the result is exact for the
    whole int32 range of the counters.  Returns None when the keys are absent
    (legacy metrics dicts)."""
    if counts_key not in metrics or bytes_key not in metrics:
        return None
    counts = jax.device_get(metrics[counts_key])
    unit = jax.device_get(metrics[bytes_key])
    return sum(int(counts[k]) * int(unit[k]) for k in counts)


class ExactCounterTotals(ExactCounter):
    """Back-compat spelling of :class:`repro.obs.hub.ExactCounter`.

    The wrap-safe modulo-2^32 delta accumulation this class introduced (PR5)
    now lives in the observability hub — ONE implementation shared by the
    trainer, the serve engine, and the benchmarks instead of a copy per call
    site.  Kept as an alias so pre-hub callers (``update(per_slab)``)
    keep working unchanged."""

    def update(self, per_slab: Mapping[str, Any]) -> int:
        return self.observe(per_slab)
