"""Core: the paper's frequency-aware two-tier software cache."""
from repro.core.cache import CacheConfig, CacheState, init_cache, prepare, flush, warmup
from repro.core.cached_embedding import (
    CachedEmbeddingConfig,
    CachedEmbeddingState,
    init_state,
    prepare_ids,
    embed_onehot,
    embed_bag,
    apply_row_grads,
    flush_state,
)
from repro.core.collection import (
    EmbeddingCollection,
    FeatureBatch,
    Placement,
    PlacementPlan,
    PlacementPlanner,
    ShardAssignment,
    TableConfig,
    exact_metric_bytes,
)
from repro.core.freq import FreqStats, build_freq_stats, collect_counts, coverage
from repro.core.policies import Policy
from repro.core.sharded import ShardedEmbeddingCollection
