"""Frequency module: the paper's static pass (§4.2) plus an online tracker.

Static half — collects id-frequency statistics of the target dataset *before*
training, reorders the embedding table rows from most- to least-frequent, and
builds ``idx_map`` (raw id -> frequency-ranked row index).  With rows ordered
this way, LFU eviction degenerates to "evict the largest row index" (paper
§4.3), which is a single masked argsort on device.  These functions are
host-side / numpy (they run once, before training); the resulting arrays are
placed on device and consumed by ``core.cache``.

Online half — :class:`FreqTracker`, a device-resident pytree of per-ranked-row
exponentially-decayed access counters.  ``core.cache.plan_prepare`` updates it
in-jit from the ids it already deduplicates (two O(K) gathers + scatters per
step — near-zero marginal cost, vmap-safe so the sharded collection tracks per
shard for free).  Decay is LAZY: a row's stored score is exact as of its
``last_touch`` step, and :func:`decayed_scores` normalizes all rows to a
common step when ``core.refresh`` re-ranks.  The tracker also keeps an
exponentially-windowed hit/miss pair (the rolling-window hit rate that makes
hot-set drift visible long before the cumulative rate moves) and the
cumulative refresh telemetry (rank churn / rows moved) that
``EmbeddingCollection.metrics`` reports.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FreqStats",
    "FreqTracker",
    "collect_counts",
    "collect_counts_sampled",
    "collect_counts_stream",
    "build_freq_stats",
    "concat_table_offsets",
    "coverage",
    "init_tracker",
    "tracker_spec",
    "tracker_touch",
    "tracker_observe",
    "decayed_scores",
    "decay_to",
]


@dataclasses.dataclass(frozen=True)
class FreqStats:
    """Output of the static module.

    Attributes:
      idx_map:    int32 [vocab]  raw id -> frequency-ranked row (rank 0 = hottest).
      inv_map:    int32 [vocab]  frequency-ranked row -> raw id (the reorder perm).
      counts:     int64 [vocab]  raw-id occurrence counts (as collected).
      vocab:      total number of rows across all (concatenated) tables.
    """

    idx_map: np.ndarray
    inv_map: np.ndarray
    counts: np.ndarray
    vocab: int

    def reorder_rows(self, weight: np.ndarray) -> np.ndarray:
        """Reorder a [vocab, dim] table so row r holds the r-th most frequent id."""
        assert weight.shape[0] == self.vocab
        return weight[self.inv_map]

    def top_fraction_coverage(self, frac: float) -> float:
        """Fraction of total accesses covered by the top-``frac`` hottest ids."""
        k = max(1, int(round(frac * self.vocab)))
        sorted_counts = self.counts[self.inv_map]  # descending
        tot = sorted_counts.sum()
        return float(sorted_counts[:k].sum() / max(tot, 1))


def collect_counts(id_batches: Iterable[np.ndarray], vocab: int) -> np.ndarray:
    """Scan the dataset once and count id occurrences (paper: 'simply scan')."""
    counts = np.zeros((vocab,), dtype=np.int64)
    for ids in id_batches:
        np.add.at(counts, ids.reshape(-1).astype(np.int64), 1)
    return counts


def collect_counts_sampled(
    id_batches: Iterable[np.ndarray],
    vocab: int,
    sample_rate: float,
    seed: int = 0,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Sampled counting for very large datasets (paper cites [Adnan et al. 2021]).

    Keeps each batch with probability ``sample_rate``; unbiased up to scaling,
    and ranking (all the cache needs) is preserved in expectation.

    Pass an explicit ``rng`` (or a ``seed``) to make the sample — and with it
    every downstream consumer of the counts, like the ``auto``
    host-precision policy's coverage estimate — deterministic across hosts
    and reruns (every data rank must derive identical placement/precision).
    """
    if rng is None:
        rng = np.random.default_rng(seed)
    counts = np.zeros((vocab,), dtype=np.int64)
    for ids in id_batches:
        if rng.random() <= sample_rate:
            np.add.at(counts, ids.reshape(-1).astype(np.int64), 1)
    return counts


def build_freq_stats(counts: np.ndarray) -> FreqStats:
    """Build the reorder permutation and idx_map from raw counts.

    ``inv_map`` sorts ids by descending count (stable, so ties keep raw order —
    deterministic across hosts, which matters because every data rank must
    derive the *identical* cache bookkeeping).
    """
    vocab = int(counts.shape[0])
    # stable descending sort: sort ascending on negated counts.
    inv_map = np.argsort(-counts, kind="stable").astype(np.int32)
    idx_map = np.empty_like(inv_map)
    idx_map[inv_map] = np.arange(vocab, dtype=np.int32)
    return FreqStats(idx_map=idx_map, inv_map=inv_map, counts=counts.astype(np.int64), vocab=vocab)


def concat_table_offsets(vocab_sizes: Sequence[int]) -> np.ndarray:
    """Offsets for concatenating per-field tables into one big table (paper §5.1).

    Raw (field f, local id i) maps to global id ``offsets[f] + i``.
    """
    return np.concatenate([[0], np.cumsum(np.asarray(vocab_sizes, dtype=np.int64))[:-1]]).astype(
        np.int64
    )


def coverage(counts: np.ndarray, top_fracs: Sequence[float]) -> dict:
    """Paper Fig. 2 statistic: access share of the top-x%% hottest ids."""
    stats = build_freq_stats(counts)
    return {f: stats.top_fraction_coverage(f) for f in top_fracs}


def collect_counts_stream(
    stream: Iterable,
    feature_to_table: Mapping[str, str],
    vocab_sizes: Mapping[str, int],
    max_batches: Optional[int] = None,
) -> Dict[str, np.ndarray]:
    """Collect per-table id counts straight off a stream of keyed batches.

    Unlike :func:`collect_counts`, nothing is materialized: ``stream`` may be
    a ``data.pipeline.Prefetcher`` (yielding ``(step, batch)`` pairs) or any
    iterator of ``FeatureBatch``-like objects (anything with an ``.ids``
    mapping) or plain ``{feature: id array}`` dicts.  The stream ends by the
    Prefetcher end-of-stream contract: the producer raises ``StopIteration``
    and iteration stops cleanly (``max_batches`` bounds the scan for infinite
    streams; producer errors re-raise here, in stream order).

    ``feature_to_table`` routes each feature's ids to its owning table's
    count vector (several features may share a table); features absent from
    the mapping (labels, dense fields) are skipped.  Negative ids (padding)
    are ignored.  Returns the ``{table: int64 [vocab]}`` dict that
    ``EmbeddingCollection.init(counts=...)`` expects.
    """
    counts = {t: np.zeros((v,), np.int64) for t, v in vocab_sizes.items()}
    n = 0
    for item in stream:
        if max_batches is not None and n >= max_batches:
            break
        batch = item[1] if isinstance(item, tuple) else item
        ids = getattr(batch, "ids", batch)
        for f, arr in ids.items():
            table = feature_to_table.get(f)
            if table is None:
                continue
            a = np.asarray(arr).reshape(-1).astype(np.int64)
            a = a[a >= 0]
            np.add.at(counts[table], a, 1)
        n += 1
    return counts


# ---------------------------------------------------------------------------
# online tracker (device-resident, updated in-jit by ``cache.plan_prepare``)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FreqTracker:
    """Per-ranked-row exponentially-decayed access counters + drift telemetry.

    ``score[r]`` is the decayed access mass of frequency-ranked row ``r`` as
    of step ``last_touch[r]`` (lazy decay: untouched rows pay nothing per
    step; readers normalize via :func:`decayed_scores`).  ``win_hits`` /
    ``win_misses`` are the same-decay rolling window over the cache's
    id-hit / unique-miss telemetry.  ``refresh_swaps`` / ``refresh_rows`` are
    cumulative counters stamped host-side by ``core.refresh`` (rank pairs
    swapped, host rows permuted) so drift telemetry flows through the normal
    in-jit ``metrics()`` path.  Leaves vmap over a leading shard axis; in the
    sharded collection the per-shard counters sum exactly (refresh stamps
    per-shard shares).
    """

    score: jnp.ndarray  # float32 [vocab] decayed mass, exact at last_touch
    last_touch: jnp.ndarray  # int32 [vocab] step of the last update
    win_hits: jnp.ndarray  # float32 [] decayed id-hit window
    win_misses: jnp.ndarray  # float32 [] decayed unique-miss window
    refresh_swaps: jnp.ndarray  # int32 [] cumulative swapped rank pairs
    refresh_rows: jnp.ndarray  # int32 [] cumulative host rows moved by refresh


def init_tracker(vocab: int) -> FreqTracker:
    return FreqTracker(
        score=jnp.zeros((vocab,), jnp.float32),
        last_touch=jnp.zeros((vocab,), jnp.int32),
        win_hits=jnp.zeros((), jnp.float32),
        win_misses=jnp.zeros((), jnp.float32),
        refresh_swaps=jnp.zeros((), jnp.int32),
        refresh_rows=jnp.zeros((), jnp.int32),
    )


def tracker_spec(P, axis: Optional[str] = None) -> FreqTracker:
    """PartitionSpec mirror of :func:`init_tracker` for ``shard_specs`` trees
    — the ONE place that must track the dataclass's leaf set.  ``axis=None``
    replicates (unsharded collections); a mesh-axis name shards the leading
    per-shard dim of every leaf (stacked sharded collections)."""
    if axis is None:
        return FreqTracker(
            score=P(None), last_touch=P(None),
            win_hits=P(), win_misses=P(),
            refresh_swaps=P(), refresh_rows=P(),
        )
    return FreqTracker(
        score=P(axis, None), last_touch=P(axis, None),
        win_hits=P(axis), win_misses=P(axis),
        refresh_swaps=P(axis), refresh_rows=P(axis),
    )


def tracker_touch(
    tracker: FreqTracker,
    rows: jnp.ndarray,
    valid: jnp.ndarray,
    step: jnp.ndarray,
    half_life: int,
) -> FreqTracker:
    """O(K) in-jit decayed-counter bump for one DEDUPED row set.

    ``rows`` must be unique among its valid lanes (the ``jnp.unique`` output
    ``plan_prepare`` already holds) — the scatter writes one value per row.
    Each touched row's stored score is first decayed from its own
    ``last_touch`` to ``step`` (lazy decay), then incremented by 1.
    """
    vocab = tracker.score.shape[0]
    safe = jnp.where(valid, rows, 0)
    prev = tracker.score[safe]
    last = tracker.last_touch[safe]
    dt = jnp.maximum(step - last, 0).astype(jnp.float32)
    bumped = prev * jnp.exp2(-dt / half_life) + 1.0
    dest = jnp.where(valid, rows, vocab)  # invalid lanes dropped OOB
    return dataclasses.replace(
        tracker,
        score=tracker.score.at[dest].set(bumped, mode="drop"),
        last_touch=tracker.last_touch.at[dest].set(step, mode="drop"),
    )


def tracker_observe(
    tracker: FreqTracker,
    hits: jnp.ndarray,
    misses: jnp.ndarray,
    half_life: int,
) -> FreqTracker:
    """Fold one plan's hit/miss telemetry into the rolling window."""
    d = jnp.float32(2.0 ** (-1.0 / half_life))
    return dataclasses.replace(
        tracker,
        win_hits=tracker.win_hits * d + hits.astype(jnp.float32),
        win_misses=tracker.win_misses * d + misses.astype(jnp.float32),
    )


def decay_to(
    score: jnp.ndarray, last_touch: jnp.ndarray, step: jnp.ndarray, half_life: int
) -> jnp.ndarray:
    """In-jit float32 twin of :func:`decayed_scores`: normalize lazy-decayed
    masses to a common ``step``.  Broadcasts, so one call handles both the
    flat replicated-arena tracker and the stacked per-shard tracker (pass
    ``step[:, None]`` there).  Used by the live ``shard_imbalance`` metric
    and the replicated-arena bookkeeping in ``core.sharded``."""
    dt = jnp.maximum(step - last_touch, 0).astype(jnp.float32)
    return score * jnp.exp2(-dt / half_life)


def decayed_scores(
    score: Any, last_touch: Any, step: int, half_life: int
) -> np.ndarray:
    """Host-side normalization: every row's decayed mass AS OF ``step``.

    ``core.refresh`` calls this on device_get'd tracker leaves before ranking;
    float64 so the comparison that picks swap pairs is not re-quantized.
    """
    s = np.asarray(score, np.float64)
    lt = np.asarray(last_touch, np.float64)
    return s * np.exp2(-np.maximum(step - lt, 0.0) / half_life)
