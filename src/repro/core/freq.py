"""Static frequency module (paper §4.2).

Collects id-frequency statistics of the target dataset *before* training,
reorders the embedding table rows from most- to least-frequent, and builds
``idx_map`` (raw id -> frequency-ranked row index).  With rows ordered this
way, LFU eviction degenerates to "evict the largest row index" (paper §4.3),
which is a single masked argsort on device.

All functions here are host-side / numpy (they run once, before training);
the resulting arrays are placed on device and consumed by ``core.cache``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional, Sequence

import numpy as np

__all__ = [
    "FreqStats",
    "collect_counts",
    "collect_counts_sampled",
    "build_freq_stats",
    "concat_table_offsets",
    "coverage",
]


@dataclasses.dataclass(frozen=True)
class FreqStats:
    """Output of the static module.

    Attributes:
      idx_map:    int32 [vocab]  raw id -> frequency-ranked row (rank 0 = hottest).
      inv_map:    int32 [vocab]  frequency-ranked row -> raw id (the reorder perm).
      counts:     int64 [vocab]  raw-id occurrence counts (as collected).
      vocab:      total number of rows across all (concatenated) tables.
    """

    idx_map: np.ndarray
    inv_map: np.ndarray
    counts: np.ndarray
    vocab: int

    def reorder_rows(self, weight: np.ndarray) -> np.ndarray:
        """Reorder a [vocab, dim] table so row r holds the r-th most frequent id."""
        assert weight.shape[0] == self.vocab
        return weight[self.inv_map]

    def top_fraction_coverage(self, frac: float) -> float:
        """Fraction of total accesses covered by the top-``frac`` hottest ids."""
        k = max(1, int(round(frac * self.vocab)))
        sorted_counts = self.counts[self.inv_map]  # descending
        tot = sorted_counts.sum()
        return float(sorted_counts[:k].sum() / max(tot, 1))


def collect_counts(id_batches: Iterable[np.ndarray], vocab: int) -> np.ndarray:
    """Scan the dataset once and count id occurrences (paper: 'simply scan')."""
    counts = np.zeros((vocab,), dtype=np.int64)
    for ids in id_batches:
        np.add.at(counts, ids.reshape(-1).astype(np.int64), 1)
    return counts


def collect_counts_sampled(
    id_batches: Iterable[np.ndarray],
    vocab: int,
    sample_rate: float,
    seed: int = 0,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Sampled counting for very large datasets (paper cites [Adnan et al. 2021]).

    Keeps each batch with probability ``sample_rate``; unbiased up to scaling,
    and ranking (all the cache needs) is preserved in expectation.

    Pass an explicit ``rng`` (or a ``seed``) to make the sample — and with it
    every downstream consumer of the counts, like the ``auto``
    host-precision policy's coverage estimate — deterministic across hosts
    and reruns (every data rank must derive identical placement/precision).
    """
    if rng is None:
        rng = np.random.default_rng(seed)
    counts = np.zeros((vocab,), dtype=np.int64)
    for ids in id_batches:
        if rng.random() <= sample_rate:
            np.add.at(counts, ids.reshape(-1).astype(np.int64), 1)
    return counts


def build_freq_stats(counts: np.ndarray) -> FreqStats:
    """Build the reorder permutation and idx_map from raw counts.

    ``inv_map`` sorts ids by descending count (stable, so ties keep raw order —
    deterministic across hosts, which matters because every data rank must
    derive the *identical* cache bookkeeping).
    """
    vocab = int(counts.shape[0])
    # stable descending sort: sort ascending on negated counts.
    inv_map = np.argsort(-counts, kind="stable").astype(np.int32)
    idx_map = np.empty_like(inv_map)
    idx_map[inv_map] = np.arange(vocab, dtype=np.int32)
    return FreqStats(idx_map=idx_map, inv_map=inv_map, counts=counts.astype(np.int64), vocab=vocab)


def concat_table_offsets(vocab_sizes: Sequence[int]) -> np.ndarray:
    """Offsets for concatenating per-field tables into one big table (paper §5.1).

    Raw (field f, local id i) maps to global id ``offsets[f] + i``.
    """
    return np.concatenate([[0], np.cumsum(np.asarray(vocab_sizes, dtype=np.int64))[:-1]]).astype(
        np.int64
    )


def coverage(counts: np.ndarray, top_fracs: Sequence[float]) -> dict:
    """Paper Fig. 2 statistic: access share of the top-x%% hottest ids."""
    stats = build_freq_stats(counts)
    return {f: stats.top_fraction_coverage(f) for f in top_fracs}
