"""Eviction policies.

The paper's policy is FREQ_LFU: rows are statically ordered by dataset
frequency, so "least frequently used" == "largest row index" — eviction is a
single masked argsort, no runtime counters (paper §4.3).

For ablation (and as the TorchRec-UVM stand-in baseline) we also provide
recency (LRU / UVM row paging) and a runtime-counter LFU.  All policies share
one code path in ``core.cache``: they only differ in the per-slot eviction
*key* (higher key = evicted earlier).  Empty slots always evict first and
slots holding rows needed by the current batch never evict (Algorithm 1's
"backlist").
"""
from __future__ import annotations

import enum

import jax.numpy as jnp

__all__ = ["Policy", "eviction_key"]

_BIG = jnp.iinfo(jnp.int32).max // 2


class Policy(enum.Enum):
    FREQ_LFU = "freq_lfu"  # the paper: static frequency rank (row index)
    LRU = "lru"  # least-recently-used (runtime recency)
    RUNTIME_LFU = "runtime_lfu"  # classical LFU with runtime counters
    UVM_ROW = "uvm_row"  # TorchRec-UVM stand-in: LRU keys + row-granular transfer


def eviction_key(
    policy: Policy,
    slot_to_row: jnp.ndarray,
    last_used: jnp.ndarray,
    use_count: jnp.ndarray,
) -> jnp.ndarray:
    """Per-slot eviction key; argsort(key, descending) gives the victim order."""
    if policy is Policy.FREQ_LFU:
        # rows are frequency-ranked: larger row index == less frequent.
        return slot_to_row.astype(jnp.int32)
    if policy in (Policy.LRU, Policy.UVM_ROW):
        return -(last_used.astype(jnp.int32))  # oldest access first
    if policy is Policy.RUNTIME_LFU:
        return -(use_count.astype(jnp.int32))  # fewest uses first
    raise ValueError(policy)
