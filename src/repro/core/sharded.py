"""Hybrid-parallel sharded ``EmbeddingCollection`` over a device mesh.

The paper scales its cache "to multiple GPUs in combination with the widely
used hybrid parallel training approaches": dense/MLP parameters replicate and
train data-parallel over the ``data`` mesh axis, while the cached embedding
slabs — too big to replicate — shard over a ``model`` axis, each shard owning
its own frequency-aware cache arena and its own slice of the host-tier
``HostStore``.  This module is that layer, built on the PR 1-3 stack:

  * ``PlacementPlanner.assign_devices`` (the RecShard-style pass in
    ``core.collection``) maps every frequency-ranked row of a cached slab to
    a shard, balancing expected hot-row traffic from the same ``FreqStats``
    counts that drive ``host_precision="auto"``.
  * ``ShardedSlab`` stacks the per-shard state along a leading ``[S, ...]``
    axis (uniform shapes; short shards pad with never-referenced zero rows).
    Sharding that axis over the mesh's ``model`` axis puts shard ``s``'s
    cache arena, index image and host-store slice on device ``s`` — the
    per-shard cache ops run under ``jax.vmap``, so XLA partitions them
    device-local with no cross-shard traffic.
  * ``plan_prepare`` bucketizes each batch's ids by owning shard (the
    id all-to-all: a ``[S, lanes]`` routed-id image, each row of which lands
    on its shard) and runs one cache plan per shard; ``gather`` reads the
    combined ``owner * capacity + slot`` address space off the stacked fast
    tier (the row all-to-all return path — on a sharded mesh XLA lowers the
    cross-shard gather to the collective).
  * DEVICE-placed tables stay replicated (they are dense-sized by
    definition), training data-parallel like the MLPs.

Exactness is unchanged: the cache remains pure data movement per shard, so a
sharded collection's lookups still bit-match the dense reference, and the
training loss trajectory matches the single-device collection (bit-exact for
fp32, codec-roundtrip-exact for lossy host codecs).  A 1-shard collection is
bit-identical to the unsharded one by construction (tested).

Worst-case sizing: a batch's lanes may all land on one shard, so each
per-shard cache keeps the full lane budget as its unique floor — capacity is
``max(ratio * vocab_s, min(ids_per_step, vocab_s))`` per shard.  Bound it
with ``TableConfig.max_unique_per_step`` exactly as on one device.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import INT_COUNTERS, contract
from repro.core import cache as cache_lib
from repro.core import freq as freq_lib
from repro.core import refresh as refresh_lib
from repro.core.collection import (
    ArenaConfig,
    CollectionState,
    DeviceSlab,
    EmbeddingCollection,
    FeatureBatch,
    PlacementPlan,
    PlacementPlanner,
    ShardAssignment,
    TableConfig,
    _CachedSlabSpec,
    _read_full_rows,
)
from repro.store import HostStore, SlabGeometry, get_codec

__all__ = [
    "ShardedSlab",
    "ShardedCollectionPlan",
    "ShardedEmbeddingCollection",
    "flat_store",
]


def flat_store(store: HostStore) -> HostStore:
    """View a shard-stacked store ([S, vocab_s, ...] leaves) as one flat
    [S * vocab_s, ...] store — flat row ``owner * vocab_s + local`` is the
    rank's slot, which is how oracles and checkpoint validators address it."""
    def rs(v):
        return v.reshape((-1,) + v.shape[2:])

    return HostStore(
        data={k: rs(v) for k, v in store.data.items()},
        sideband={k: rs(v) for k, v in store.sideband.items()},
        codec=store.codec,
        out_dtype=store.out_dtype,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardedSlab:
    """One cached slab sharded over the model axis (leading dim = shard)."""

    full: Any  # HostStore, leaves [S, rows_per_shard, ...] (encoded)
    cache: cache_lib.CacheState  # every leaf [S, ...] (per-shard arena)
    idx_map: jnp.ndarray  # int32 [vocab] raw id -> freq rank (replicated)
    rank_owner: jnp.ndarray  # int32 [vocab] rank -> owning shard (replicated)
    rank_local: jnp.ndarray  # int32 [vocab] rank -> local row (replicated)
    routed_lanes: jnp.ndarray  # int32 [S] cumulative id lanes routed per shard


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardedCollectionPlan:
    """``CollectionPlan`` analogue with per-shard cache plans.

    ``slab_plans`` leaves carry a leading [S] shard dim; ``addresses`` are
    COMBINED addresses (``owner * shard_capacity + slot``, -1 padding) into
    the flattened stacked fast tier, so the downstream gather/pool/grad path
    is shape-identical to the unsharded one.  ``routed`` counts this step's
    valid id lanes per shard (the id all-to-all payload).  Field names match
    ``CollectionPlan`` where the trainer reads them (``addresses``,
    ``future_addresses``, ``future_unresident`` — a scalar, summed over
    shards, so ``PipelinedTrainer`` needs no sharding awareness).
    """

    slab_plans: Dict[str, cache_lib.CachePlan]
    routed: Dict[str, jnp.ndarray]
    addresses: Dict[str, jnp.ndarray]
    future_addresses: Tuple[Dict[str, jnp.ndarray], ...] = ()
    future_unresident: jnp.ndarray = dataclasses.field(
        default_factory=lambda: jnp.zeros((), jnp.int32)
    )
    writeback: bool = dataclasses.field(default=True, metadata=dict(static=True))


class ShardedEmbeddingCollection(EmbeddingCollection):
    """``EmbeddingCollection`` with cached slabs sharded over a model axis.

    Same keyed-feature surface (``init`` / ``plan_prepare`` / ``apply_plan``
    / ``prepare`` / ``weights`` / ``gather`` / ``pool`` / ``apply_grads`` /
    ``flush`` / ``metrics`` / ``device_bytes`` / ``shard_specs``), so models
    and both trainers consume it unchanged.  ``num_shards`` is the size of
    the mesh's ``model`` axis; on a single device the stacked state simply
    lives on that device (useful for tests — the math is mesh-agnostic).
    """

    def __init__(
        self,
        tables: Sequence[TableConfig],
        plan: PlacementPlan,
        num_shards: int,
        model_axis: str = "model",
    ):
        super().__init__(tables, plan)
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.num_shards = int(num_shards)
        self.model_axis = model_axis
        # per-slab frequency-driven device assignment; populated by ``init``
        # (it needs the counts) and mirrored host-side for telemetry.
        self.assignments: Dict[str, ShardAssignment] = {}

    @classmethod
    def create(
        cls,
        tables: Sequence[TableConfig],
        num_shards: int = 1,
        budget_bytes: Optional[int] = None,
        counts: Optional[Mapping[str, np.ndarray]] = None,
        planner: Optional[PlacementPlanner] = None,
        model_axis: str = "model",
        **arena_kw,
    ) -> "ShardedEmbeddingCollection":
        """Plan + build, like ``EmbeddingCollection.create`` plus the shard
        count.  ``budget_bytes`` is the PER-DEVICE budget (each shard holds
        1/S of every cached slab plus the replicated DEVICE tables)."""
        if planner is None and budget_bytes is None:
            return cls(tables, PlacementPlan.single_arena(tables, **arena_kw),
                       num_shards, model_axis)
        planner = planner or PlacementPlanner(
            budget_bytes,
            arena=ArenaConfig(**arena_kw),
            host_precision=arena_kw.get("host_precision"),
        )
        return cls(tables, planner.plan(tables, counts=counts), num_shards, model_axis)

    # ----- per-shard geometry ----------------------------------------------

    def rows_per_shard(self, spec: _CachedSlabSpec) -> int:
        return -(-spec.vocab // self.num_shards)

    def shard_capacity(self, spec: _CachedSlabSpec) -> int:
        """Per-shard cache capacity: the slab ratio applied to the local
        vocab, floored at one batch's unique rows (worst-case skew: every
        lane of a batch may land on one shard)."""
        vs = self.rows_per_shard(spec)
        k = min(spec.ids_per_step, vs)
        if spec.max_unique_per_step:
            k = min(k, spec.max_unique_per_step)
        return min(max(int(spec.cache_ratio * vs), k), vs)

    def shard_cache_config(
        self,
        spec: _CachedSlabSpec,
        ids_per_step: Optional[int] = None,
        writeback: bool = True,
    ) -> cache_lib.CacheConfig:
        return cache_lib.CacheConfig(
            vocab=self.rows_per_shard(spec),
            capacity=self.shard_capacity(spec),
            ids_per_step=ids_per_step or spec.ids_per_step,
            buffer_rows=spec.buffer_rows,
            policy=spec.policy,
            writeback=writeback,
            max_unique_per_step=spec.max_unique_per_step,
            protect_via_inverse=spec.protect_via_inverse,
            freq_half_life=spec.freq_half_life,
        )

    # ----- init -------------------------------------------------------------

    def init(
        self,
        rng: jax.Array,
        counts: Optional[Mapping[str, np.ndarray]] = None,
        warm: bool = True,
        host_precision: Optional[str] = None,
    ) -> CollectionState:
        """Build the sharded state.  Weight draws mirror the unsharded
        ``init`` key-for-key, so the sharded collection starts from the exact
        same logical table as the single-device reference — the basis of the
        loss-trajectory parity property."""
        S = self.num_shards
        slabs: Dict[str, Any] = {}
        keys = jax.random.split(rng, len(self.device_slabs) + len(self.cached_slabs))
        kit = iter(keys)
        for name, t in self.device_slabs.items():
            scale = 1.0 / np.sqrt(t.dim)
            slabs[name] = DeviceSlab(
                weight=jax.random.uniform(next(kit), (t.vocab, t.dim), t.dtype, -scale, scale)
            )
        for sname, spec in self.cached_slabs.items():
            scale = 1.0 / np.sqrt(spec.dim)
            weight = jax.random.uniform(
                next(kit), (spec.vocab, spec.dim), spec.dtype, -scale, scale
            )
            slab_counts = None
            counts_ranked = None
            if counts is not None:
                slab_counts = np.concatenate(
                    [
                        np.asarray(
                            counts.get(t.name, np.zeros((t.vocab,), np.int64)), np.int64
                        )
                        for t in spec.tables
                    ]
                )
                stats = freq_lib.build_freq_stats(slab_counts)
                idx_map = jnp.asarray(stats.idx_map)
                counts_ranked = stats.counts[stats.inv_map]  # descending
            else:
                idx_map = jnp.arange(spec.vocab, dtype=jnp.int32)
            assign = PlacementPlanner.assign_devices(spec.vocab, S, counts_ranked)
            self.assignments[sname] = assign
            codec = host_precision or spec.host_precision
            if codec == "auto":
                codec = self.precision_policy.choose(
                    SlabGeometry(
                        name=sname,
                        vocab=spec.vocab,
                        dim=spec.dim,
                        capacity=S * self.shard_capacity(spec),
                        dtype_itemsize=jnp.dtype(spec.dtype).itemsize,
                    ),
                    counts=slab_counts,
                )
            else:
                get_codec(codec)  # fail fast on typos
            self.host_precision[sname] = codec
            vs = self.rows_per_shard(spec)
            # scatter rank r's row to flat slot owner[r]*vs + local[r]; pad
            # rows (flat slots no rank maps to) stay zero and are never read.
            dest = jnp.asarray(
                assign.owner.astype(np.int64) * vs + assign.local.astype(np.int64),
                jnp.int32,
            )
            flat = jnp.zeros((S * vs, spec.dim), spec.dtype).at[dest].set(weight)
            store = HostStore.create({"weight": flat}, codec=codec)
            full = HostStore(
                data={k: v.reshape((S, vs) + v.shape[1:]) for k, v in store.data.items()},
                sideband={
                    k: v.reshape((S, vs) + v.shape[1:]) for k, v in store.sideband.items()
                },
                codec=store.codec,
                out_dtype=store.out_dtype,
            )
            ccfg = self.shard_cache_config(spec)
            cache0 = cache_lib.init_cache(
                ccfg, {"weight": jnp.zeros((spec.dim,), spec.dtype)}
            )
            cache = jax.tree_util.tree_map(
                lambda l: jnp.repeat(l[None], S, axis=0), cache0
            )
            if warm:
                full, cache = jax.vmap(
                    lambda f, c: cache_lib.warmup(ccfg, f, c)
                )(full, cache)
            slabs[sname] = ShardedSlab(
                full=full,
                cache=cache,
                idx_map=idx_map,
                rank_owner=jnp.asarray(assign.owner),
                rank_local=jnp.asarray(assign.local),
                routed_lanes=jnp.zeros((S,), jnp.int32),
            )
        return CollectionState(slabs=slabs)

    # ----- id routing (the bucketize / all-to-all image) --------------------

    def _route(
        self, slab: ShardedSlab, raw: jnp.ndarray
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Slab-global raw ids (-1 pad) -> (owning shard, local row), both -1
        on padding lanes — the routing table of the id exchange."""
        valid = raw >= 0
        rank = slab.idx_map.at[jnp.where(valid, raw, 0)].get(mode="fill", fill_value=-1)
        rank = jnp.where(valid, rank, -1)
        ok = rank >= 0
        owner = slab.rank_owner.at[jnp.where(ok, rank, 0)].get(mode="fill", fill_value=-1)
        local = slab.rank_local.at[jnp.where(ok, rank, 0)].get(mode="fill", fill_value=-1)
        return jnp.where(ok, owner, -1), jnp.where(ok, local, -1)

    def _bucketize(
        self, owner: jnp.ndarray, local: jnp.ndarray
    ) -> jnp.ndarray:
        """[lanes] routing -> [S, lanes] per-shard local-row image: shard s's
        row keeps only the lanes it owns (-1 elsewhere).  Sharding the
        leading axis over ``model`` makes this the id all-to-all payload."""
        sids = jnp.arange(self.num_shards, dtype=jnp.int32)[:, None]
        return jnp.where(
            (owner[None, :] == sids) & (local[None, :] >= 0), local[None, :], -1
        ).astype(jnp.int32)

    @staticmethod
    def _combine_slots(per_shard_slots: jnp.ndarray, cap: int) -> jnp.ndarray:
        """[S, lanes] per-shard slots (-1 off-shard) -> [lanes] combined
        addresses ``owner * cap + slot`` (-1 pad).  Each valid lane is
        resident on exactly one shard, so an integer sum of the shifted
        one-hot encodings is exact — this is the return half of the
        exchange, folded into address arithmetic."""
        S = per_shard_slots.shape[0]
        enc = jnp.where(
            per_shard_slots >= 0,
            jnp.arange(S, dtype=jnp.int32)[:, None] * cap + per_shard_slots + 1,
            0,
        )
        return jnp.sum(enc, axis=0) - 1

    def _lookup_combined(
        self,
        row_to_slot: jnp.ndarray,  # [S, vocab_s] index image
        owner: jnp.ndarray,
        local: jnp.ndarray,
        cap: int,
    ) -> jnp.ndarray:
        """Combined address of each (owner, local) lane under an index image
        (-1 when not resident on its owner or a padding lane)."""
        enc = jnp.zeros(owner.shape, jnp.int32)
        for s in range(self.num_shards):  # S is small and static
            rs = row_to_slot[s]
            slot = rs.at[jnp.where(owner == s, local, 0)].get(mode="fill", fill_value=-1)
            enc = enc + jnp.where((owner == s) & (slot >= 0), s * cap + slot + 1, 0)
        return enc - 1

    # ----- the non-diff bookkeeping pass ------------------------------------

    # bounded-top-K declaration mirrors ``cache.plan_prepare`` (the vmapped
    # per-shard plan inherits its full-capacity eviction argsort — same
    # known-issue baseline entry until ROADMAP item 3).
    @contract(max_sort_size=64, int_counters=INT_COUNTERS)
    def plan_prepare(
        self,
        state: CollectionState,
        fb: FeatureBatch,
        fb_future: Sequence[FeatureBatch] = (),
        writeback: bool = True,
    ) -> ShardedCollectionPlan:
        """Sharded planning half: translate ids, bucketize them by owning
        shard, and run one weight-free cache plan per shard (vmapped over the
        stacked state — on a mesh each shard plans on its own device).
        Lookahead windows merge per shard exactly like the unsharded path;
        ``future_unresident`` sums over shards so the pipelined trainer's
        group guard is sharding-agnostic."""
        self._check_features(fb, *fb_future)
        addresses: Dict[str, jnp.ndarray] = {}
        future_addresses: List[Dict[str, jnp.ndarray]] = [{} for _ in fb_future]
        future_unresident = jnp.zeros((), jnp.int32)

        for j, b in enumerate((fb, *fb_future)):
            out = addresses if j == 0 else future_addresses[j - 1]
            for f in b.features:
                if self.feature_to_table[f] in self.device_slabs:
                    out[f] = b.ids[f].astype(jnp.int32)

        slab_plans: Dict[str, cache_lib.CachePlan] = {}
        routed: Dict[str, jnp.ndarray] = {}
        for sname, spec in self.cached_slabs.items():
            raw = self._slab_raw(fb, sname)
            slab = state.slabs[sname]
            fut_raws = [self._slab_raw(b, sname) for b in fb_future]
            if raw is None:
                # slab touched only by the window: not prefetched (see the
                # unsharded path) — surface its lanes in the guard instead.
                for raw_j in fut_raws:
                    if raw_j is not None:
                        future_unresident = future_unresident + jnp.sum(
                            raw_j >= 0
                        ).astype(jnp.int32)
                continue
            cap = self.shard_capacity(spec)
            owner, local = self._route(slab, raw)
            rows_sh = self._bucketize(owner, local)  # [S, lanes]
            routes_fut = [
                None if p is None else self._route(slab, p) for p in fut_raws
            ]
            fut_parts = [
                self._bucketize(o, l) for o, l in (r for r in routes_fut if r is not None)
            ]
            fut_sh = jnp.concatenate(fut_parts, axis=1) if fut_parts else None
            ccfg = self.shard_cache_config(
                spec, ids_per_step=int(raw.shape[0]), writeback=writeback
            )
            if fut_sh is None:
                plan = jax.vmap(
                    lambda st_, r_: cache_lib.plan_prepare(ccfg, st_, r_)
                )(slab.cache, rows_sh)
            else:
                plan = jax.vmap(
                    lambda st_, r_, f_: cache_lib.plan_prepare(
                        ccfg, st_, r_, future_rows=f_
                    )
                )(slab.cache, rows_sh, fut_sh)
            slab_plans[sname] = plan
            routed[sname] = jnp.sum(rows_sh >= 0, axis=1).astype(jnp.int32)
            combined = self._combine_slots(plan.slots, cap)
            pos = 0
            for f, n in self._slab_lanes(fb, sname):
                addresses[f] = combined[pos : pos + n].reshape(fb.ids[f].shape)
                pos += n
            for j, (b, route_j) in enumerate(zip(fb_future, routes_fut)):
                if route_j is None:
                    continue
                o_j, l_j = route_j
                slots_j = self._lookup_combined(plan.row_to_slot, o_j, l_j, cap)
                future_unresident = future_unresident + jnp.sum(
                    (l_j >= 0) & (slots_j < 0)
                ).astype(jnp.int32)
                pos = 0
                for f, n in self._slab_lanes(b, sname):
                    future_addresses[j][f] = slots_j[pos : pos + n].reshape(
                        b.ids[f].shape
                    )
                    pos += n
        return ShardedCollectionPlan(
            slab_plans=slab_plans,
            routed=routed,
            addresses=addresses,
            future_addresses=tuple(future_addresses),
            future_unresident=future_unresident,
            writeback=writeback,
        )

    @contract(donates=("state",), int_counters=INT_COUNTERS, max_sort_size=0)
    def apply_plan(
        self, state: CollectionState, plan: ShardedCollectionPlan
    ) -> CollectionState:
        """Execute every shard's planned row movement (vmapped: each shard
        moves rows between ITS host-store slice and ITS cache arena — no
        cross-shard traffic) and accumulate the exchange telemetry."""
        slabs = dict(state.slabs)
        for sname, p in plan.slab_plans.items():
            spec = self.cached_slabs[sname]
            ccfg = self.shard_cache_config(spec, writeback=plan.writeback)
            slab = slabs[sname]
            full, cache = jax.vmap(
                lambda f, c, pp: cache_lib.apply_plan(ccfg, f, c, pp)
            )(slab.full, slab.cache, p)
            slabs[sname] = dataclasses.replace(
                slab,
                full=full,
                cache=cache,
                routed_lanes=slab.routed_lanes + plan.routed[sname],
            )
        return CollectionState(slabs=slabs)

    # ----- differentiable read path -----------------------------------------

    # the exchange path: on a mesh this flatten + parent gather lowers to the
    # row all-to-all, so its contract covers the cross-shard wire too.
    @contract(max_sort_size=0)
    def gather(
        self,
        weights: Mapping[str, jnp.ndarray],
        addresses: Mapping[str, jnp.ndarray],
        fb: FeatureBatch,
    ) -> Dict[str, jnp.ndarray]:
        """Gather through the combined address space: the stacked [S, cap,
        dim] fast tier flattens to [S*cap, dim] and the parent gather serves
        every lane off it — on a sharded mesh this lowers to the row
        all-to-all (each lane's row crosses from its owner shard).  Gradients
        flow back through the same map, landing on the owning shard's slot."""
        weights = {
            k: (v.reshape((-1,) + v.shape[2:]) if k in self.cached_slabs else v)
            for k, v in weights.items()
        }
        return super().gather(weights, addresses, fb)

    def pool(self, rows, fb, combiner="sum", *, weights=None, addresses=None,
             use_pallas=False, max_bag=0):
        if use_pallas and weights is not None:
            weights = {
                k: (v.reshape((-1,) + v.shape[2:]) if k in self.cached_slabs else v)
                for k, v in weights.items()
            }
        return super().pool(rows, fb, combiner, weights=weights,
                            addresses=addresses, use_pallas=use_pallas,
                            max_bag=max_bag)

    # weights / apply_grads are inherited: the stacked [S, cap, dim] cached
    # leaf updates elementwise exactly like the flat one.

    def flush(self, state: CollectionState) -> CollectionState:
        slabs = dict(state.slabs)
        for sname, spec in self.cached_slabs.items():
            ccfg = self.shard_cache_config(spec)
            slab = slabs[sname]
            full, cache = jax.vmap(lambda f, c: cache_lib.flush(ccfg, f, c))(
                slab.full, slab.cache
            )
            slabs[sname] = dataclasses.replace(slab, full=full, cache=cache)
        return CollectionState(slabs=slabs)

    # ----- adaptive frequency refresh ---------------------------------------

    def refresh(
        self,
        state: CollectionState,
        cfg: Optional[refresh_lib.RefreshConfig] = None,
        writeback: bool = True,
    ) -> Tuple[CollectionState, refresh_lib.RefreshReport]:
        """Sharded re-ranking refresh (see ``EmbeddingCollection.refresh``).

        The incremental permutation is planned GLOBALLY from the merged
        per-shard decayed counters, then applied as content exchanges between
        the swapped ranks' fixed ``(owner, local)`` homes — the traffic
        balance ``assign_devices`` placed on the hot homes is inherited by
        the newly-hot rows.  Cross-shard exchanges are metered by
        ``cfg.exchange_budget`` (rows per refresh; excess pairs defer to the
        next pass).  With one shard the pass is bit-identical to the
        unsharded refresh."""
        cfg = cfg or refresh_lib.RefreshConfig()
        slabs = dict(state.slabs)
        report = refresh_lib.RefreshReport()
        for sname, spec in self.cached_slabs.items():
            slabs[sname], stats = refresh_lib.refresh_sharded_slab(
                self.shard_cache_config(spec, writeback=writeback),
                slabs[sname], cfg, writeback=writeback,
            )
            report.add(sname, stats)
        return CollectionState(slabs=slabs), report

    # ----- oracles / bulk reads ---------------------------------------------

    def _rank_rows(self, slab: ShardedSlab, rank: jnp.ndarray) -> jnp.ndarray:
        """Decoded slow-tier rows for freq ranks (-1 lanes -> zero rows)."""
        vs = slab.full.data["weight"].shape[1]
        ok = rank >= 0
        owner = slab.rank_owner.at[jnp.where(ok, rank, 0)].get(mode="fill", fill_value=-1)
        local = slab.rank_local.at[jnp.where(ok, rank, 0)].get(mode="fill", fill_value=-1)
        flat = jnp.where(ok & (owner >= 0), owner * vs + local, -1)
        return _read_full_rows(flat_store(slab.full), flat)

    def full_lookup(
        self, state: CollectionState, table: str, local_ids: jnp.ndarray
    ) -> jnp.ndarray:
        sname, off = self.table_slab[table]
        if sname in self.device_slabs:
            return super().full_lookup(state, table, local_ids)
        slab = state.slabs[sname]
        valid = local_ids >= 0
        rank = slab.idx_map.at[jnp.where(valid, local_ids + off, 0)].get(
            mode="fill", fill_value=-1
        )
        return self._rank_rows(slab, jnp.where(valid, rank, -1))

    def dense_reference(
        self, state: CollectionState, fb: FeatureBatch
    ) -> Dict[str, jnp.ndarray]:
        out = {}
        for f in fb.features:
            tname = self.feature_to_table[f]
            sname, off = self.table_slab[tname]
            ids = fb.ids[f]
            flat = ids.reshape(-1)
            if sname in self.device_slabs:
                w = state.slabs[sname].weight
                safe = jnp.where(flat >= 0, flat, w.shape[0])
                rows = jnp.take(w, safe, axis=0, mode="fill", fill_value=0)
            else:
                slab = state.slabs[sname]
                r = slab.idx_map.at[jnp.where(flat >= 0, flat + off, 0)].get(
                    mode="fill", fill_value=-1
                )
                rows = self._rank_rows(slab, jnp.where(flat >= 0, r, -1))
            out[f] = rows.reshape(ids.shape + (rows.shape[-1],))
        return out

    # ----- telemetry / accounting -------------------------------------------

    def metrics(
        self, state: CollectionState, writeback: bool = True
    ) -> Dict[str, jnp.ndarray]:
        """Unsharded telemetry (counters sum over shards) plus the exchange
        accounting: ``exchange_routed_lanes`` / ``exchange_lane_bytes`` are
        per-slab cumulative id lanes routed through the bucketize exchange
        and the per-lane payload (4 B id out + one fast-tier row back) —
        exact bytes via ``exact_metric_bytes``; ``exchange_bytes`` is the
        float32 convenience total and ``shard_imbalance`` the max/mean routed
        load across shards (1.0 = perfectly balanced).  Of the payload, an
        expected (S-1)/S fraction crosses devices on an S-shard mesh.

        Telemetry caveat (same as hits/misses): under pipelined group
        scheduling only group leaders run a plan, so routed lanes sample the
        leaders' batches."""
        out = super().metrics(state, writeback=writeback)
        lanes: Dict[str, jnp.ndarray] = {}
        lane_bytes: Dict[str, jnp.ndarray] = {}
        xbytes = jnp.zeros((), jnp.float32)
        per_shard = jnp.zeros((self.num_shards,), jnp.int32)
        for sname, spec in self.cached_slabs.items():
            slab = state.slabs[sname]
            n = jnp.sum(slab.routed_lanes)
            lanes[sname] = n.astype(jnp.int32)
            b = 4 + spec.dim * jnp.dtype(spec.dtype).itemsize
            lane_bytes[sname] = jnp.asarray(b, jnp.int32)
            xbytes = xbytes + n.astype(jnp.float32) * b
            per_shard = per_shard + slab.routed_lanes
        tot = jnp.sum(per_shard)
        mean = tot.astype(jnp.float32) / self.num_shards
        out["exchange_routed_lanes"] = lanes
        out["exchange_lane_bytes"] = lane_bytes
        out["exchange_bytes"] = xbytes
        out["shard_imbalance"] = jnp.where(
            tot > 0, jnp.max(per_shard).astype(jnp.float32) / jnp.maximum(mean, 1e-9), 1.0
        )
        return out

    def device_bytes(self) -> Dict[str, int]:
        """Footprint under the sharded layout.  ``device_total`` counts one
        REPLICA of the replicated arrays (DEVICE tables, id routing maps)
        plus the summed stacked arrays; ``device_per_shard`` is what one mesh
        device actually holds — the budget-relevant number."""
        S = self.num_shards
        per_slab: Dict[str, int] = {}
        replicated = 0
        stacked = 0
        slow = slow_fp32 = 0
        for name, t in self.device_slabs.items():
            per_slab[name] = t.full_bytes
            replicated += t.full_bytes
        for sname, spec in self.cached_slabs.items():
            item = jnp.dtype(spec.dtype).itemsize
            vs = self.rows_per_shard(spec)
            cap = self.shard_capacity(spec)
            # per shard: arena + slot bookkeeping + row_to_slot + tracker
            stack = S * (cap * spec.dim * item + cap * 4 * 3 + vs * 4 * 3)
            rep = spec.vocab * 4 * 3  # idx_map + rank_owner + rank_local
            per_slab[sname] = stack + rep
            stacked += stack
            replicated += rep
            codec = get_codec(self._slab_codec(sname))
            slow += S * vs * codec.row_bytes((spec.dim,), spec.dtype)
            slow_fp32 += S * vs * spec.dim * item
        return {
            "device_total": replicated + stacked,
            "device_per_shard": replicated + stacked // max(S, 1),
            "slow_tier_bytes": slow,
            "host_bytes_saved": slow_fp32 - slow,
            "per_slab": per_slab,
            "budget_bytes": self.plan.budget_bytes,
        }

    # ----- sharding ----------------------------------------------------------

    def shard_specs(self, mode: str = "shard", model_axis: Optional[str] = None):
        """PartitionSpec pytree matching the sharded ``CollectionState``:
        every stacked leaf splits its leading shard dim over the mesh's
        ``model`` axis, the id-routing maps and DEVICE tables replicate
        (DEVICE tables train data-parallel with the MLPs).  ``mode`` is
        accepted for drop-in compatibility with the unsharded signature but
        the layout is fixed by the shard structure."""
        from jax.sharding import PartitionSpec as P

        axis = model_axis or self.model_axis
        slabs: Dict[str, Any] = {}
        for name in self.device_slabs:
            slabs[name] = DeviceSlab(weight=P(None, None))
        for sname, spec in self.cached_slabs.items():
            like = {"weight": jax.ShapeDtypeStruct((spec.vocab, spec.dim), spec.dtype)}
            slabs[sname] = ShardedSlab(
                full=HostStore.spec_like(
                    like,
                    {"weight": P(axis, None, None)},
                    P(axis, None, None),
                    codec=self._slab_codec(sname),
                ),
                cache=cache_lib.CacheState(
                    cached_rows={"weight": P(axis, None, None)},
                    slot_to_row=P(axis, None),
                    row_to_slot=P(axis, None),
                    last_used=P(axis, None),
                    use_count=P(axis, None),
                    step=P(axis),
                    hits=P(axis),
                    misses=P(axis),
                    evictions=P(axis),
                    uniq_overflows=P(axis),
                    tracker=freq_lib.tracker_spec(P, axis=axis),
                ),
                idx_map=P(None),
                rank_owner=P(None),
                rank_local=P(None),
                routed_lanes=P(axis),
            )
        return CollectionState(slabs=slabs)
