"""Hybrid-parallel sharded ``EmbeddingCollection`` over a device mesh.

The paper scales its cache "to multiple GPUs in combination with the widely
used hybrid parallel training approaches": dense/MLP parameters replicate and
train data-parallel over the ``data`` mesh axis, while the cached embedding
slabs — too big to replicate — shard over a ``model`` axis, each shard owning
its own frequency-aware cache arena and its own slice of the host-tier
``HostStore``.  This module is that layer, built on the PR 1-3 stack:

  * ``PlacementPlanner.assign_devices`` (the RecShard-style pass in
    ``core.collection``) maps every frequency-ranked row of a cached slab to
    a shard, balancing expected hot-row traffic from the same ``FreqStats``
    counts that drive ``host_precision="auto"``.
  * ``ShardedSlab`` stacks the per-shard state along a leading ``[S, ...]``
    axis (uniform shapes; short shards pad with never-referenced zero rows).
    Sharding that axis over the mesh's ``model`` axis puts shard ``s``'s
    cache arena, index image and host-store slice on device ``s`` — the
    per-shard cache ops run under ``jax.vmap``, so XLA partitions them
    device-local with no cross-shard traffic.
  * ``plan_prepare`` bucketizes each batch's ids by owning shard (the
    id all-to-all: a ``[S, lanes]`` routed-id image, each row of which lands
    on its shard) and runs one cache plan per shard; ``gather`` reads the
    combined ``owner * capacity + slot`` address space off the stacked fast
    tier (the row all-to-all return path — on a sharded mesh XLA lowers the
    cross-shard gather to the collective).
  * DEVICE-placed tables stay replicated (they are dense-sized by
    definition), training data-parallel like the MLPs.

Exactness is unchanged: the cache remains pure data movement per shard, so a
sharded collection's lookups still bit-match the dense reference, and the
training loss trajectory matches the single-device collection (bit-exact for
fp32, codec-roundtrip-exact for lossy host codecs).  A 1-shard collection is
bit-identical to the unsharded one by construction (tested).

Worst-case sizing: a batch's lanes may all land on one shard, so each
per-shard cache keeps the full lane budget as its unique floor — capacity is
``max(ratio * vocab_s, min(ids_per_step, vocab_s))`` per shard.  Bound it
with ``TableConfig.max_unique_per_step`` exactly as on one device.

Scaling the exchange (the three fronts that keep throughput monotone in S):

  * **Hot-row replication** (``replicate_top_k``): the K hottest ranks live
    in a small :class:`RepArena` replicated on every shard.  Their lookups
    resolve to arena addresses (``S * cap + rank``) and never enter the
    id/row all-to-all; their summed-lane gradients reach the replicated leaf
    through GSPMD's automatic all-reduce (the data-axis sum, plus a
    model-axis ``psum`` whenever the compiler shards the lane dimension), so
    every shard applies the identical SGD update.  ``refresh`` promotes and
    demotes across the replicated boundary exactly like the capacity one.
  * **Exchange compression**: batch ids are deduplicated BEFORE the
    bucketize, so a shard never receives the same id twice per plan (and the
    vmapped per-shard unique sorts shrink to the dedup width); with
    ``exchange_codec`` the row-leg return path travels encoded (fp16/int8 +
    sideband, the PR3 wire codecs) and decodes at the consumer, with a
    straight-through gradient into the fp32 arenas.
  * **Traffic-aware re-balance** (``RefreshConfig.rebalance_threshold``):
    ``refresh`` re-runs ``assign_devices`` on the live ``FreqTracker``
    decayed scores when the observed routed imbalance drifts past the
    threshold, re-homing ranks so shard load tracks the live hot set.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import INT_COUNTERS, contract
from repro.core import cache as cache_lib
from repro.core import freq as freq_lib
from repro.core import refresh as refresh_lib
from repro.core import transmitter
from repro.core.collection import (
    METRICS_INT_COUNTERS,
    ArenaConfig,
    CollectionState,
    DeviceSlab,
    EmbeddingCollection,
    FeatureBatch,
    PlacementPlan,
    PlacementPlanner,
    ShardAssignment,
    TableConfig,
    _CachedSlabSpec,
    _read_full_rows,
)
from repro.dist import partitioning as dist_part
from repro.kernels.cache_ops import ops as cache_ops
from repro.store import (
    ArenaStore,
    HostStore,
    PrecisionPolicy,
    SlabGeometry,
    get_codec,
    tiered_arena_bytes,
)

__all__ = [
    "RepArena",
    "ShardedSlab",
    "ShardedCollectionPlan",
    "ShardedEmbeddingCollection",
    "flat_store",
]

# sentinel for invalid lanes in the dedup'd rank buffer: sorts after every
# real rank (vocab is far below int32 max), so ``jnp.unique`` packs real
# ranks first and padding last.
_PAD_RANK = jnp.iinfo(jnp.int32).max


def flat_store(store: HostStore) -> HostStore:
    """View a shard-stacked store ([S, vocab_s, ...] leaves) as one flat
    [S * vocab_s, ...] store — flat row ``owner * vocab_s + local`` is the
    rank's slot, which is how oracles and checkpoint validators address it."""
    def rs(v):
        return v.reshape((-1,) + v.shape[2:])

    return HostStore(
        data={k: rs(v) for k, v in store.data.items()},
        sideband={k: rs(v) for k, v in store.sideband.items()},
        codec=store.codec,
        out_dtype=store.out_dtype,
    )


def _stack_store(store: HostStore, S: int, vs: int) -> HostStore:
    """Inverse of :func:`flat_store`: re-stack a flat [S*vs, ...] store into
    the [S, vs, ...] shard-stacked layout."""
    def rs(v):
        return v.reshape((S, vs) + v.shape[1:])

    return HostStore(
        data={k: rs(v) for k, v in store.data.items()},
        sideband={k: rs(v) for k, v in store.sideband.items()},
        codec=store.codec,
        out_dtype=store.out_dtype,
    )


def _shard_lane_idx(owner: jnp.ndarray, slot: jnp.ndarray, S: int, cap: int):
    """[L] per-lane (owner, slot) -> [S, L] per-shard take indices: shard s
    keeps its own lanes' slots and fills everyone else's with the
    out-of-range sentinel ``cap`` (-> zero row).  Each valid lane is owned by
    exactly ONE shard, so summing the per-shard takes is an exact select —
    and it is the form GSPMD partitions as the row all-to-all: every shard
    does an O(L) LOCAL take, instead of the all-gather of the whole stacked
    arena that a flat ``jnp.take`` on the [S*cap] view lowers to (that
    all-gather is what made the gather cost per shard scale with S)."""
    sids = jnp.arange(S, dtype=jnp.int32)[:, None]
    return jnp.where(owner[None, :] == sids, slot[None, :], cap)


def _partitioned_take(w: jnp.ndarray, owner: jnp.ndarray, slot: jnp.ndarray):
    """Raw (fp32) routed row-leg: [S, cap, dim] stacked arena + per-lane
    routing -> [L, dim] rows, as shard-local takes summed across the shard
    axis (see ``_shard_lane_idx``).  Lanes with ``owner`` outside [0, S)
    come back as exact zero rows — the padding-lane convention."""
    S, cap = w.shape[0], w.shape[1]
    idx = _shard_lane_idx(owner, slot, S, cap)
    part = jax.vmap(
        lambda w_, i_: jnp.take(w_, i_, axis=0, mode="fill", fill_value=0)
    )(w, idx)
    return jnp.sum(part, axis=0)


def _encoded_exchange(
    codec, w: jnp.ndarray, owner: jnp.ndarray, slot: jnp.ndarray
) -> jnp.ndarray:
    """The compressed row-leg of the exchange: each producer shard encodes
    ITS arena slice, per-lane payload + sideband cross the wire (that is the
    traffic ``metrics`` accounts), and the consumer decodes once.  Same
    partitioned shape as ``_partitioned_take`` — per-shard local takes of
    the ENCODED payload summed across shards (exact: one owner per lane,
    zero fill elsewhere, and every codec decodes zero payload + zero
    sideband to the zero row).  Straight-through gradient: the backward pass
    is the plain gather transpose (per-shard scatter-add into the fp32
    arena), identical to the uncompressed path, so training updates
    full-precision rows while only the forward value carries codec noise —
    the PR3 host-tier semantics, applied to the wire."""
    S, cap = w.shape[0], w.shape[1]
    shape = w.shape
    out_dtype = w.dtype

    @jax.custom_vjp
    def take_enc(w_):
        payload, side = jax.vmap(codec.encode)(w_)
        idx = _shard_lane_idx(owner, slot, S, cap)
        tk = jax.vmap(
            lambda x_, i_: jnp.take(x_, i_, axis=0, mode="fill", fill_value=0)
        )
        p = jnp.sum(tk(payload, idx), axis=0, dtype=payload.dtype)
        s_ = None
        if side is not None:
            s_ = jnp.sum(tk(side, idx), axis=0, dtype=side.dtype)
        return codec.decode(p, s_, out_dtype)

    def fwd(w_):
        return take_enc(w_), None

    def bwd(_, ct):
        own = jnp.where((owner >= 0) & (owner < S), owner, S)  # OOB -> drop
        return (
            jnp.zeros(shape, ct.dtype).at[own, slot].add(ct, mode="drop"),
        )

    take_enc.defvjp(fwd, bwd)
    return take_enc(w)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RepArena:
    """The replicated hot head of one sharded slab (``replicate_top_k``).

    ``rows[r]`` is the fp32-authoritative fast-tier row of frequency rank
    ``r < K`` — replicated on every shard (no leading [S] dim; its
    PartitionSpec replicates, and under jit GSPMD inserts the gradient
    all-reduce that keeps the copies identical, like the data-parallel
    MLPs).  Replicated lanes bypass the per-shard cache plans, so the arena
    keeps its own lazy-decay tracker slice (same formula and plan clock as
    ``FreqTracker``) — without it the hot head would go dark to ``refresh``
    and the re-balance trigger.  ``K = 0`` gives zero-length leaves and a
    behavior bit-identical to the pre-replication collection."""

    rows: jnp.ndarray  # [K, dim] replicated fast-tier rows
    score: jnp.ndarray  # float32 [K] decayed mass, exact at last_touch
    last_touch: jnp.ndarray  # int32 [K] plan step of the last touch
    step: jnp.ndarray  # int32 [] plan clock (ticks with ``apply_plan``)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardedSlab:
    """One cached slab sharded over the model axis (leading dim = shard)."""

    full: Any  # HostStore, leaves [S, rows_per_shard, ...] (encoded)
    cache: cache_lib.CacheState  # every leaf [S, ...] (per-shard arena)
    idx_map: jnp.ndarray  # int32 [vocab] raw id -> freq rank (replicated)
    rank_owner: jnp.ndarray  # int32 [vocab] rank -> owning shard (replicated)
    rank_local: jnp.ndarray  # int32 [vocab] rank -> local row (replicated)
    routed_lanes: jnp.ndarray  # int32 [S] cumulative id lanes routed per shard
    rep: RepArena  # replicated hot head (zero-length leaves when K = 0)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardedCollectionPlan:
    """``CollectionPlan`` analogue with per-shard cache plans.

    ``slab_plans`` leaves carry a leading [S] shard dim; ``addresses`` are
    COMBINED addresses (``owner * shard_capacity + slot``, -1 padding) into
    the flattened stacked fast tier, so the downstream gather/pool/grad path
    is shape-identical to the unsharded one.  ``routed`` counts this step's
    valid id lanes per shard (the id all-to-all payload).  Field names match
    ``CollectionPlan`` where the trainer reads them (``addresses``,
    ``future_addresses``, ``future_unresident`` — a scalar, summed over
    shards, so ``PipelinedTrainer`` needs no sharding awareness).
    """

    slab_plans: Dict[str, cache_lib.CachePlan]
    routed: Dict[str, jnp.ndarray]
    addresses: Dict[str, jnp.ndarray]
    # per-slab dedup'd rank buffer of this step's batch (int32, -1 padding) —
    # ``apply_plan`` folds the replicated head's touches into the arena
    # tracker from it (the per-shard plans never see replicated lanes).
    uniq_ranks: Dict[str, jnp.ndarray] = dataclasses.field(default_factory=dict)
    future_addresses: Tuple[Dict[str, jnp.ndarray], ...] = ()
    future_unresident: jnp.ndarray = dataclasses.field(
        default_factory=lambda: jnp.zeros((), jnp.int32)
    )
    writeback: bool = dataclasses.field(default=True, metadata=dict(static=True))


class ShardedEmbeddingCollection(EmbeddingCollection):
    """``EmbeddingCollection`` with cached slabs sharded over a model axis.

    Same keyed-feature surface (``init`` / ``plan_prepare`` / ``apply_plan``
    / ``prepare`` / ``weights`` / ``gather`` / ``pool`` / ``apply_grads`` /
    ``flush`` / ``metrics`` / ``device_bytes`` / ``shard_specs``), so models
    and both trainers consume it unchanged.  ``num_shards`` is the size of
    the mesh's ``model`` axis; on a single device the stacked state simply
    lives on that device (useful for tests — the math is mesh-agnostic).
    """

    def __init__(
        self,
        tables: Sequence[TableConfig],
        plan: PlacementPlan,
        num_shards: int,
        model_axis: str = "model",
        replicate_top_k: int = 0,
        exchange_codec: Optional[str] = None,
        max_routed_per_shard: int = 0,
    ):
        super().__init__(tables, plan)
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.num_shards = int(num_shards)
        self.model_axis = model_axis
        # static per-shard plan width bound: 0 (default) keeps the exact
        # full-width [S, U] bucketize image; > 0 compacts routed lanes to a
        # dense [S, W] image so the vmapped per-shard plans stop scaling with
        # the dedup buffer.  Lanes past the bound are counted into
        # ``uniq_overflows`` and trip the trainer's exactness guard.
        self.max_routed_per_shard = max(int(max_routed_per_shard), 0)
        # hot-row replication head size (per cached slab, clamped to vocab)
        self.replicate_top_k = max(int(replicate_top_k), 0)
        # wire codec of the row-leg exchange; None / "fp32" = raw rows (the
        # bit-exact default — fp32's encode/decode is identity, so it is
        # folded into the plain-gather path rather than paying the custom-vjp
        # detour for nothing).
        if exchange_codec in (None, "fp32"):
            self.exchange_codec: Optional[str] = None
        else:
            get_codec(exchange_codec)  # fail fast on typos
            self.exchange_codec = exchange_codec
        # per-slab frequency-driven device assignment; populated by ``init``
        # (it needs the counts), updated by re-balance passes, and mirrored
        # host-side for telemetry.
        self.assignments: Dict[str, ShardAssignment] = {}

    @classmethod
    def create(
        cls,
        tables: Sequence[TableConfig],
        num_shards: int = 1,
        budget_bytes: Optional[int] = None,
        counts: Optional[Mapping[str, np.ndarray]] = None,
        planner: Optional[PlacementPlanner] = None,
        model_axis: str = "model",
        replicate_top_k: int = 0,
        exchange_codec: Optional[str] = None,
        max_routed_per_shard: int = 0,
        **arena_kw,
    ) -> "ShardedEmbeddingCollection":
        """Plan + build, like ``EmbeddingCollection.create`` plus the shard
        count.  ``budget_bytes`` is the PER-DEVICE budget (each shard holds
        1/S of every cached slab plus the replicated DEVICE tables)."""
        if planner is None and budget_bytes is None:
            return cls(tables, PlacementPlan.single_arena(tables, **arena_kw),
                       num_shards, model_axis, replicate_top_k, exchange_codec,
                       max_routed_per_shard)
        planner = planner or PlacementPlanner(
            budget_bytes,
            arena=ArenaConfig(**arena_kw),
            host_precision=arena_kw.get("host_precision"),
            arena_precision=arena_kw.get("arena_precision"),
            arena_head_ratio=arena_kw.get("arena_head_ratio", 0.25),
        )
        return cls(tables, planner.plan(tables, counts=counts), num_shards,
                   model_axis, replicate_top_k, exchange_codec,
                   max_routed_per_shard)

    # ----- per-shard geometry ----------------------------------------------

    def rows_per_shard(self, spec: _CachedSlabSpec) -> int:
        return -(-spec.vocab // self.num_shards)

    def shard_capacity(self, spec: _CachedSlabSpec) -> int:
        """Per-shard cache capacity: the slab ratio applied to the local
        vocab, floored at one batch's unique rows (worst-case skew: every
        lane of a batch may land on one shard).  With a
        ``max_routed_per_shard`` bound the worst case is the bound itself
        (lanes past it trip the ``uniq_overflows`` guard), so the floor
        shrinks with it — this is what keeps per-shard plan cost (eviction
        sort, movement lists, index images) proportional to 1/S instead of
        pinning every shard at full-batch width.  Capacity never changes
        lookup VALUES (writeback keeps cached rows equal to the slow tier),
        so shrinking the floor preserves bit-exactness."""
        vs = self.rows_per_shard(spec)
        k = min(spec.ids_per_step, vs)
        if self.max_routed_per_shard:
            k = min(k, self.max_routed_per_shard)
        if spec.max_unique_per_step:
            k = min(k, spec.max_unique_per_step)
        return min(max(int(spec.cache_ratio * vs), k), vs)

    def shard_cache_config(
        self,
        spec: _CachedSlabSpec,
        ids_per_step: Optional[int] = None,
        writeback: bool = True,
    ) -> cache_lib.CacheConfig:
        ids = ids_per_step or spec.ids_per_step
        if self.max_routed_per_shard:
            # a shard never sees more than the routed-lane bound per step
            # (plan_prepare compacts to it and counts the excess into
            # ``uniq_overflows``), so the per-shard id width shrinks with it
            ids = min(ids, self.max_routed_per_shard)
        return cache_lib.CacheConfig(
            vocab=self.rows_per_shard(spec),
            capacity=self.shard_capacity(spec),
            ids_per_step=ids,
            buffer_rows=spec.buffer_rows,
            policy=spec.policy,
            writeback=writeback,
            max_unique_per_step=spec.max_unique_per_step,
            protect_via_inverse=spec.protect_via_inverse,
            freq_half_life=spec.freq_half_life,
            use_pallas_plan=spec.use_pallas_plan,
            chunk_rows=spec.chunk_rows,
            # each shard's arena tiers at the same head ratio; an unresolved
            # "auto" (config built before ``init``) budgets at the policy's
            # no-stats pick, exactly like the unsharded ``cache_config``.
            arena_precision=(
                PrecisionPolicy().no_stats
                if spec.arena_precision == "auto"
                else spec.arena_precision
            ),
            arena_head_ratio=spec.arena_head_ratio,
        )

    # ----- init -------------------------------------------------------------

    def init(
        self,
        rng: jax.Array,
        counts: Optional[Mapping[str, np.ndarray]] = None,
        warm: bool = True,
        host_precision: Optional[str] = None,
        arena_precision: Optional[str] = None,
    ) -> CollectionState:
        """Build the sharded state.  Weight draws mirror the unsharded
        ``init`` key-for-key, so the sharded collection starts from the exact
        same logical table as the single-device reference — the basis of the
        loss-trajectory parity property."""
        S = self.num_shards
        slabs: Dict[str, Any] = {}
        keys = jax.random.split(rng, len(self.device_slabs) + len(self.cached_slabs))
        kit = iter(keys)
        for name, t in self.device_slabs.items():
            scale = 1.0 / np.sqrt(t.dim)
            slabs[name] = DeviceSlab(
                weight=jax.random.uniform(next(kit), (t.vocab, t.dim), t.dtype, -scale, scale)
            )
        for sname, spec in self.cached_slabs.items():
            scale = 1.0 / np.sqrt(spec.dim)
            weight = jax.random.uniform(
                next(kit), (spec.vocab, spec.dim), spec.dtype, -scale, scale
            )
            slab_counts = None
            counts_ranked = None
            if counts is not None:
                slab_counts = np.concatenate(
                    [
                        np.asarray(
                            counts.get(t.name, np.zeros((t.vocab,), np.int64)), np.int64
                        )
                        for t in spec.tables
                    ]
                )
                stats = freq_lib.build_freq_stats(slab_counts)
                idx_map = jnp.asarray(stats.idx_map)
                counts_ranked = stats.counts[stats.inv_map]  # descending
            else:
                idx_map = jnp.arange(spec.vocab, dtype=jnp.int32)
            K = min(self.replicate_top_k, spec.vocab)
            assign = PlacementPlanner.assign_devices(
                spec.vocab, S, counts_ranked, replicate_top_k=K
            )
            self.assignments[sname] = assign
            codec = host_precision or spec.host_precision
            if codec == "auto":
                codec = self.precision_policy.choose(
                    SlabGeometry(
                        name=sname,
                        vocab=spec.vocab,
                        dim=spec.dim,
                        capacity=S * self.shard_capacity(spec),
                        dtype_itemsize=jnp.dtype(spec.dtype).itemsize,
                    ),
                    counts=slab_counts,
                )
            else:
                get_codec(codec)  # fail fast on typos
            self.host_precision[sname] = codec
            # arena (fast-tier) precision mirrors the host resolution: "auto"
            # picks from the GLOBAL resident geometry (S * shard capacity /
            # head) — coverage is a property of the logical slab, not of one
            # shard's slice.  The resolved codec is written back into the
            # spec so every later ``shard_cache_config`` agrees with the
            # state structure built below.
            arena_codec = arena_precision or spec.arena_precision
            if arena_codec == "auto":
                cap_s = self.shard_capacity(spec)
                head_s = min(cap_s, max(1, int(round(spec.arena_head_ratio * cap_s))))
                arena_codec = self.precision_policy.choose_arena(
                    SlabGeometry(
                        name=sname,
                        vocab=spec.vocab,
                        dim=spec.dim,
                        capacity=S * cap_s,
                        dtype_itemsize=jnp.dtype(spec.dtype).itemsize,
                    ),
                    S * head_s,
                    counts=slab_counts,
                )
            else:
                get_codec(arena_codec)  # fail fast on typos
            if arena_codec != spec.arena_precision:
                spec = dataclasses.replace(spec, arena_precision=arena_codec)
                self.cached_slabs[sname] = spec
            self.arena_precision[sname] = arena_codec
            vs = self.rows_per_shard(spec)
            # scatter rank r's row to flat slot owner[r]*vs + local[r]; pad
            # rows (flat slots no rank maps to) stay zero and are never read.
            dest = jnp.asarray(
                assign.owner.astype(np.int64) * vs + assign.local.astype(np.int64),
                jnp.int32,
            )
            flat = jnp.zeros((S * vs, spec.dim), spec.dtype).at[dest].set(weight)
            store = HostStore.create({"weight": flat}, codec=codec)
            full = HostStore(
                data={k: v.reshape((S, vs) + v.shape[1:]) for k, v in store.data.items()},
                sideband={
                    k: v.reshape((S, vs) + v.shape[1:]) for k, v in store.sideband.items()
                },
                codec=store.codec,
                out_dtype=store.out_dtype,
            )
            ccfg = self.shard_cache_config(spec)
            cache0 = cache_lib.init_cache(
                ccfg, {"weight": jnp.zeros((spec.dim,), spec.dtype)}
            )
            cache = jax.tree_util.tree_map(
                lambda l: jnp.repeat(l[None], S, axis=0), cache0
            )
            if warm:
                full, cache = jax.vmap(
                    lambda f, c: cache_lib.warmup(ccfg, f, c)
                )(full, cache)
            # replicated hot head: rank r's content is weight[r] (the same
            # rank-content convention the flat scatter above follows), so the
            # arena starts bit-identical to the ranks' slow-tier homes.
            rep = RepArena(
                rows=weight[:K],
                score=jnp.zeros((K,), jnp.float32),
                last_touch=jnp.zeros((K,), jnp.int32),
                step=jnp.zeros((), jnp.int32),
            )
            slabs[sname] = ShardedSlab(
                full=full,
                cache=cache,
                idx_map=idx_map,
                rank_owner=jnp.asarray(assign.owner),
                rank_local=jnp.asarray(assign.local),
                routed_lanes=jnp.zeros((S,), jnp.int32),
                rep=rep,
            )
        return CollectionState(slabs=slabs)

    # ----- id routing (the bucketize / all-to-all image) --------------------

    def _rank_ids(self, slab: ShardedSlab, raw: jnp.ndarray) -> jnp.ndarray:
        """Slab-global raw ids (-1 pad) -> frequency ranks (-1 pad)."""
        valid = raw >= 0
        rank = slab.idx_map.at[jnp.where(valid, raw, 0)].get(mode="fill", fill_value=-1)
        return jnp.where(valid, rank, -1)

    def _route(
        self, slab: ShardedSlab, rank: jnp.ndarray
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Frequency ranks (-1 pad) -> (owning shard, local row), both -1 on
        padding lanes AND on replicated lanes (``rank < K``) — replicated
        ranks are served from the per-shard arena and never enter the id
        exchange, which is the whole point of the head."""
        K = slab.rep.rows.shape[0]
        ok = rank >= K
        owner = slab.rank_owner.at[jnp.where(ok, rank, 0)].get(mode="fill", fill_value=-1)
        local = slab.rank_local.at[jnp.where(ok, rank, 0)].get(mode="fill", fill_value=-1)
        return jnp.where(ok, owner, -1), jnp.where(ok, local, -1)

    @staticmethod
    def _dedup(
        rank: jnp.ndarray, vocab: int, fused: bool = False
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Dedup ranks ahead of the bucketize: [L] ranks (-1 pad) ->
        ``(uniq, pos)`` where ``uniq`` is the [U = min(L, vocab)] ascending
        unique buffer (``_PAD_RANK`` padding) and ``pos[i]`` locates lane
        i's rank in it.  A shard then receives each id at most ONCE per plan
        — duplicate lanes (within or across a slab's features) collapse to
        one exchange lane and one cache-plan lane.

        ``fused=True`` swaps ``jnp.unique`` for the one-sort dedup in
        ``kernels/cache_ops`` (bit-identical; ``_PAD_RANK`` is the max
        sentinel it collapses padding into)."""
        u = min(int(rank.shape[0]), int(vocab))
        key = jnp.where(rank >= 0, rank, _PAD_RANK)
        if fused:
            uniq, _ = cache_ops.dedup_impl(key, u, _PAD_RANK)
        else:
            uniq = jnp.unique(key, size=u, fill_value=_PAD_RANK)
        pos = jnp.minimum(jnp.searchsorted(uniq, key), u - 1).astype(jnp.int32)
        return uniq.astype(jnp.int32), pos

    def _bucketize(
        self, owner: jnp.ndarray, local: jnp.ndarray, fused: bool = False
    ) -> jnp.ndarray:
        """[lanes] routing -> [S, lanes] per-shard local-row image: shard s's
        row keeps only the lanes it owns (-1 elsewhere).  Sharding the
        leading axis over ``model`` makes this the id all-to-all payload.
        ``fused=True`` routes through ``kernels/cache_ops`` (a per-shard-row
        Pallas pass on accelerators; same where-image on CPU)."""
        if fused:
            return cache_ops.bucketize_impl(owner, local, self.num_shards)
        sids = jnp.arange(self.num_shards, dtype=jnp.int32)[:, None]
        return jnp.where(
            (owner[None, :] == sids) & (local[None, :] >= 0), local[None, :], -1
        ).astype(jnp.int32)

    def _compact_lanes(
        self, owner: jnp.ndarray, local: jnp.ndarray, width: int
    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Dense [S, width] per-shard lane image (vs ``_bucketize``'s sparse
        [S, U] one): ONE stable argsort by owner groups every shard's lanes
        contiguously, so the vmapped per-shard plans chew ``width`` lanes
        instead of U — the term that made planning cost scale with S.

        Returns ``(rows, src, overflow)``: per-shard local rows (-1 pad),
        the source index of each compact lane in the dedup'd array (-1 pad;
        the scatter map that rebuilds combined addresses), and the per-shard
        count of lanes DROPPED because a shard drew more than ``width``
        unique rows.  Dropped lanes would silently read zero rows, so the
        caller must surface overflow through ``uniq_overflows`` (the trainer
        raises on it — same exactness contract as the unique-buffer bound)."""
        u = owner.shape[0]
        S = self.num_shards
        key = jnp.where(local >= 0, owner, S)  # pad/replicated -> sentinel S
        perm = jnp.argsort(key)  # stable: keeps dedup order within a shard
        sk = jnp.take(key, perm)
        starts = jnp.searchsorted(sk, jnp.arange(S + 1, dtype=sk.dtype))
        counts = (starts[1:] - starts[:-1]).astype(jnp.int32)
        j = jnp.arange(width, dtype=jnp.int32)[None, :]
        ok = j < jnp.minimum(counts, width)[:, None]
        pos = jnp.clip(starts[:S, None] + j, 0, u - 1)
        src = jnp.where(ok, jnp.take(perm, pos), -1).astype(jnp.int32)
        rows = jnp.where(
            ok, jnp.take(local, jnp.where(ok, src, 0)), -1
        ).astype(jnp.int32)
        overflow = jnp.maximum(counts - width, 0)
        return rows, src, overflow

    def _lane_width(self, u: int) -> Optional[int]:
        """Static compact-image width, or None for the full-width path (the
        historical, bound-free layout)."""
        w = self.max_routed_per_shard
        if w <= 0 or w >= u:
            return None
        return w

    @staticmethod
    def _combine_slots(per_shard_slots: jnp.ndarray, cap: int) -> jnp.ndarray:
        """[S, lanes] per-shard slots (-1 off-shard) -> [lanes] combined
        addresses ``owner * cap + slot`` (-1 pad).  Each valid lane is
        resident on exactly one shard, so an integer sum of the shifted
        one-hot encodings is exact — this is the return half of the
        exchange, folded into address arithmetic."""
        S = per_shard_slots.shape[0]
        enc = jnp.where(
            per_shard_slots >= 0,
            jnp.arange(S, dtype=jnp.int32)[:, None] * cap + per_shard_slots + 1,
            0,
        )
        return jnp.sum(enc, axis=0) - 1

    def _lookup_combined(
        self,
        row_to_slot: jnp.ndarray,  # [S, vocab_s] index image
        owner: jnp.ndarray,
        local: jnp.ndarray,
        cap: int,
    ) -> jnp.ndarray:
        """Combined address of each (owner, local) lane under an index image
        (-1 when not resident on its owner or a padding lane)."""
        enc = jnp.zeros(owner.shape, jnp.int32)
        for s in range(self.num_shards):  # S is small and static
            rs = row_to_slot[s]
            slot = rs.at[jnp.where(owner == s, local, 0)].get(mode="fill", fill_value=-1)
            enc = enc + jnp.where((owner == s) & (slot >= 0), s * cap + slot + 1, 0)
        return enc - 1

    # ----- the non-diff bookkeeping pass ------------------------------------

    # bounded-top-K declaration mirrors ``cache.plan_prepare``: with
    # ``use_pallas_plan`` the vmapped per-shard plans and the router dedup/
    # bucketize route through kernels/cache_ops (ROADMAP item 3), so no
    # capacity-sized sort survives; the oracle route keeps the historical
    # argsort and is covered by bit-identity tests instead.
    @contract(max_sort_size=64, int_counters=INT_COUNTERS)
    def plan_prepare(
        self,
        state: CollectionState,
        fb: FeatureBatch,
        fb_future: Sequence[FeatureBatch] = (),
        writeback: bool = True,
    ) -> ShardedCollectionPlan:
        """Sharded planning half: translate ids, bucketize them by owning
        shard, and run one weight-free cache plan per shard (vmapped over the
        stacked state — on a mesh each shard plans on its own device).
        Lookahead windows merge per shard exactly like the unsharded path;
        ``future_unresident`` sums over shards so the pipelined trainer's
        group guard is sharding-agnostic."""
        self._check_features(fb, *fb_future)
        addresses: Dict[str, jnp.ndarray] = {}
        future_addresses: List[Dict[str, jnp.ndarray]] = [{} for _ in fb_future]
        future_unresident = jnp.zeros((), jnp.int32)

        for j, b in enumerate((fb, *fb_future)):
            out = addresses if j == 0 else future_addresses[j - 1]
            for f in b.features:
                if self.feature_to_table[f] in self.device_slabs:
                    out[f] = b.ids[f].astype(jnp.int32)

        slab_plans: Dict[str, cache_lib.CachePlan] = {}
        routed: Dict[str, jnp.ndarray] = {}
        uniq_ranks: Dict[str, jnp.ndarray] = {}
        for sname, spec in self.cached_slabs.items():
            raw = self._slab_raw(fb, sname)
            slab = state.slabs[sname]
            fut_raws = [self._slab_raw(b, sname) for b in fb_future]
            if raw is None:
                # slab touched only by the window: not prefetched (see the
                # unsharded path) — surface its lanes in the guard instead.
                for raw_j in fut_raws:
                    if raw_j is not None:
                        future_unresident = future_unresident + jnp.sum(
                            raw_j >= 0
                        ).astype(jnp.int32)
                continue
            cap = self.shard_capacity(spec)
            K = slab.rep.rows.shape[0]
            ncomb = self.num_shards * cap  # arena addresses live past this
            rank = self._rank_ids(slab, raw)
            fused = spec.use_pallas_plan
            uniq, pos = self._dedup(rank, spec.vocab, fused=fused)  # [U], [lanes]
            owner_u, local_u = self._route(slab, uniq)
            width = self._lane_width(int(uniq.shape[0]))
            if width is None:
                rows_sh = self._bucketize(owner_u, local_u, fused=fused)  # [S, U]
                src_sh = lane_over = None
            else:
                # bounded dense image: the vmapped per-shard plans run at
                # ``width`` lanes instead of U — the term that made plan cost
                # scale with S.  Dropped lanes are counted loudly below.
                rows_sh, src_sh, lane_over = self._compact_lanes(
                    owner_u, local_u, width
                )
            # pin the per-shard image split over the shard axis: it is built
            # from REPLICATED dedup output, and without the constraint GSPMD
            # is free to keep the whole vmapped plan replicated — every
            # device then plans all S shards and plan cost scales with S.
            rows_sh = dist_part.constrain(rows_sh, "shard", None)
            fut_ranks = [
                None if p is None else self._rank_ids(slab, p) for p in fut_raws
            ]
            fut_parts = [r for r in fut_ranks if r is not None]
            if fut_parts:
                # the window merges into ONE dedup'd image (the per-shard
                # plan only needs the union of pinned rows)
                fuq, _ = self._dedup(
                    jnp.concatenate(fut_parts), spec.vocab, fused=fused
                )
                fo, fl = self._route(slab, fuq)
                if width is None:
                    fut_sh = self._bucketize(fo, fl, fused=fused)
                else:
                    # a dropped future lane only loses its prefetch pin; the
                    # pipelined group guard still counts it unresident, so
                    # the bound is safe (not silent) on the window leg.
                    fut_sh, _, _ = self._compact_lanes(fo, fl, width)
                fut_sh = dist_part.constrain(fut_sh, "shard", None)
            else:
                fut_sh = None
            ccfg = self.shard_cache_config(
                spec, ids_per_step=int(rows_sh.shape[1]), writeback=writeback
            )
            if fut_sh is None:
                plan = jax.vmap(
                    lambda st_, r_: cache_lib.plan_prepare(ccfg, st_, r_)
                )(slab.cache, rows_sh)
            else:
                plan = jax.vmap(
                    lambda st_, r_, f_: cache_lib.plan_prepare(
                        ccfg, st_, r_, future_rows=f_
                    )
                )(slab.cache, rows_sh, fut_sh)
            if width is not None:
                # a dropped lane would silently gather a zero row — count it
                # into the same exactness guard as the unique-buffer bound.
                plan = dataclasses.replace(
                    plan, uniq_overflows=plan.uniq_overflows + lane_over
                )
            slab_plans[sname] = plan
            routed[sname] = jnp.sum(rows_sh >= 0, axis=1).astype(jnp.int32)
            uniq_ranks[sname] = jnp.where(uniq < _PAD_RANK, uniq, -1)
            if width is None:
                combined_u = self._combine_slots(plan.slots, cap)  # [U]
            else:
                # scatter the compact [S, W] slots back to dedup'd lane
                # order: each uniq lane lives in at most one compact cell, so
                # a one-hot-shifted scatter-add is exact (see _combine_slots)
                u_n = int(uniq.shape[0])
                sids = jnp.arange(self.num_shards, dtype=jnp.int32)[:, None]
                enc = jnp.where(
                    (src_sh >= 0) & (plan.slots >= 0),
                    sids * cap + plan.slots + 1,
                    0,
                )
                dest = jnp.where(src_sh >= 0, src_sh, u_n).reshape(-1)
                combined_u = (
                    jnp.zeros((u_n,), jnp.int32)
                    .at[dest]
                    .add(enc.reshape(-1), mode="drop")
                    - 1
                )
            if K:
                # replicated lanes: always-resident arena addresses appended
                # after the routed combined space (the _PAD_RANK sentinel is
                # >= K, so padding lanes fall through untouched)
                combined_u = jnp.where(uniq < K, ncomb + uniq, combined_u)
            lane_addr = jnp.where(rank >= 0, jnp.take(combined_u, pos), -1)
            off = 0
            for f, n in self._slab_lanes(fb, sname):
                addresses[f] = lane_addr[off : off + n].reshape(fb.ids[f].shape)
                off += n
            for j, (b, rank_j) in enumerate(zip(fb_future, fut_ranks)):
                if rank_j is None:
                    continue
                o_j, l_j = self._route(slab, rank_j)
                slots_j = self._lookup_combined(plan.row_to_slot, o_j, l_j, cap)
                if K:
                    slots_j = jnp.where(
                        (rank_j >= 0) & (rank_j < K), ncomb + rank_j, slots_j
                    )
                # replicated lanes never count as unresident: their l_j is -1
                # and their addresses are arena-resident by construction.
                future_unresident = future_unresident + jnp.sum(
                    (l_j >= 0) & (slots_j < 0)
                ).astype(jnp.int32)
                off = 0
                for f, n in self._slab_lanes(b, sname):
                    future_addresses[j][f] = slots_j[off : off + n].reshape(
                        b.ids[f].shape
                    )
                    off += n
        return ShardedCollectionPlan(
            slab_plans=slab_plans,
            routed=routed,
            addresses=addresses,
            uniq_ranks=uniq_ranks,
            future_addresses=tuple(future_addresses),
            future_unresident=future_unresident,
            writeback=writeback,
        )

    @contract(donates=("state",), int_counters=INT_COUNTERS, max_sort_size=0)
    def apply_plan(
        self, state: CollectionState, plan: ShardedCollectionPlan
    ) -> CollectionState:
        """Execute every shard's planned row movement (vmapped: each shard
        moves rows between ITS host-store slice and ITS cache arena — no
        cross-shard traffic) and accumulate the exchange telemetry."""
        slabs = dict(state.slabs)
        for sname, p in plan.slab_plans.items():
            spec = self.cached_slabs[sname]
            ccfg = self.shard_cache_config(spec, writeback=plan.writeback)
            slab = slabs[sname]
            full, cache = jax.vmap(
                lambda f, c, pp: cache_lib.apply_plan(ccfg, f, c, pp)
            )(slab.full, slab.cache, p)
            rep = slab.rep
            step = rep.step + 1  # ticks with the per-shard plan clocks
            u = plan.uniq_ranks.get(sname)
            if rep.rows.shape[0] and u is not None:
                # fold the head's touches into the arena tracker (the
                # per-shard plans never see replicated lanes) — the same
                # lazy-decay bump as ``freq.tracker_touch``.  The dedup'd
                # rank buffer is ascending with -1 padding at the tail, so
                # every arena lane (rank < K) lives in its first K entries —
                # slice there instead of scanning the full lane width.
                K = rep.rows.shape[0]
                u = u[: min(K, u.shape[0])]
                m = (u >= 0) & (u < K)
                safe = jnp.where(m, u, 0)
                bumped = freq_lib.decay_to(
                    rep.score[safe], rep.last_touch[safe], step,
                    spec.freq_half_life,
                ) + 1.0
                dest = jnp.where(m, u, K)
                rep = dataclasses.replace(
                    rep,
                    # pinned replicated (see the apply_grads constraint)
                    score=dist_part.constrain(
                        rep.score.at[dest].set(bumped, mode="drop")
                    ),
                    last_touch=dist_part.constrain(
                        rep.last_touch.at[dest].set(step, mode="drop")
                    ),
                    step=step,
                )
            else:
                rep = dataclasses.replace(rep, step=step)
            slabs[sname] = dataclasses.replace(
                slab,
                full=full,
                cache=cache,
                routed_lanes=slab.routed_lanes + plan.routed[sname],
                rep=rep,
            )
        return CollectionState(slabs=slabs)

    # ----- differentiable read path -----------------------------------------

    # the exchange path: on a mesh this flatten + gather lowers to the row
    # all-to-all, so its contract covers the cross-shard wire too.
    @contract(max_sort_size=0)
    def gather(
        self,
        weights: Mapping[str, jnp.ndarray],
        addresses: Mapping[str, jnp.ndarray],
        fb: FeatureBatch,
    ) -> Dict[str, jnp.ndarray]:
        """Gather through the combined address space: each lane's combined
        address splits back into (owner, slot) and the routed leg is served
        as PER-SHARD LOCAL takes summed over the shard axis
        (:func:`_partitioned_take`) — the form GSPMD partitions as the row
        all-to-all; flattening the stacked arena and taking from the [S*cap]
        view instead lowers to an all-gather of the whole arena on every
        shard, which is what made gather cost scale with S.  Arena lanes
        (combined address >= S*cap) stay shard-local.  With
        ``exchange_codec`` the routed leg crosses ENCODED and decodes at the
        consumer (:func:`_encoded_exchange`); arena lanes never touch the
        wire, so they are always served raw.  Gradients flow back through
        the same maps, landing on the owning shard's slot / the replicated
        ``<slab>::rep`` leaf."""
        codec = get_codec(self.exchange_codec) if self.exchange_codec else None
        out = {}
        for f in fb.features:
            sname = self.table_slab[self.feature_to_table[f]][0]
            w = weights[sname]
            addr = addresses[f]
            flat = addr.reshape(-1)
            if sname not in self.cached_slabs:
                safe = jnp.where(flat >= 0, flat, w.shape[0])
                rows = jnp.take(w, safe, axis=0, mode="fill", fill_value=0)
            else:
                cap = w.shape[1]
                ncomb = w.shape[0] * cap
                rep = weights.get(sname + "::rep")
                K = rep.shape[0] if rep is not None else 0
                routed = (flat >= 0) & (flat < ncomb)
                owner = jnp.where(routed, flat // cap, self.num_shards)
                slot = jnp.where(routed, flat % cap, 0)
                if codec is None:
                    rows = _partitioned_take(w, owner, slot)
                else:
                    rows = _encoded_exchange(codec, w, owner, slot)
                if K:
                    # arena lanes stay shard-local and raw: overlay them on
                    # the routed leg (which returned zero rows for them)
                    loc = jnp.take(
                        rep, jnp.where(flat >= ncomb, flat - ncomb, K),
                        axis=0, mode="fill", fill_value=0,
                    )
                    rows = jnp.where((flat >= ncomb)[:, None], loc, rows)
            out[f] = rows.reshape(addr.shape + (rows.shape[-1],))
        return out

    def pool(self, rows, fb, combiner="sum", *, weights=None, addresses=None,
             use_pallas=False, max_bag=0):
        # the Pallas kernel reads the raw fp32 fast tier (arena concatenated
        # past the routed block); the exchange codec only shapes the
        # jnp.take route, which stays the exactness reference.
        if use_pallas and weights is not None:
            fused = {}
            for k, v in weights.items():
                if k.endswith("::rep"):
                    continue
                if k in self.cached_slabs:
                    v = v.reshape((-1,) + v.shape[2:])
                    rep = weights.get(k + "::rep")
                    if rep is not None and rep.shape[0]:
                        v = jnp.concatenate([v, rep], axis=0)
                fused[k] = v
            weights = fused
        return super().pool(rows, fb, combiner, weights=weights,
                            addresses=addresses, use_pallas=use_pallas,
                            max_bag=max_bag)

    def weights(self, state: CollectionState) -> Dict[str, jnp.ndarray]:
        """Parent surface plus one ``<slab>::rep`` leaf per replicated arena
        (omitted when K = 0, keeping the grads pytree — and with it the fp32
        trajectory — bit-identical to the pre-replication collection)."""
        out = super().weights(state)
        for sname in self.cached_slabs:
            rep = state.slabs[sname].rep
            if rep.rows.shape[0]:
                out[sname + "::rep"] = rep.rows
        return out

    @contract(donates=("state",), int_counters=INT_COUNTERS, max_sort_size=0)
    def apply_grads(
        self,
        state: CollectionState,
        grads: Mapping[str, jnp.ndarray],
        lr,
    ) -> CollectionState:
        """Parent SGD on the per-shard fast tiers, plus the replicated-slice
        update: a ``<slab>::rep`` grad is the SUM of its lanes' cotangents
        across the whole (data-parallel) batch — under jit on a mesh GSPMD
        materializes that sum as the all-reduce over the data axis plus a
        ``model``-axis ``psum`` wherever it sharded the lane dimension — so
        every shard applies the identical update and the arena copies never
        diverge (same mechanism that keeps the replicated MLPs in sync)."""
        state = super().apply_grads(state, grads, lr)
        slabs = dict(state.slabs)
        for sname in self.cached_slabs:
            g = grads.get(sname + "::rep")
            if g is None:
                continue
            slab = slabs[sname]
            rows = (slab.rep.rows - lr * g).astype(slab.rep.rows.dtype)
            # pin the arena replicated on the way out: without the constraint
            # GSPMD is free to shard the updated leaf over the mesh, and the
            # next step's in_shardings (replicated, see ``shard_specs``) then
            # reject the committed state.  Identity off-mesh.
            rows = dist_part.constrain(rows)
            slabs[sname] = dataclasses.replace(
                slab, rep=dataclasses.replace(slab.rep, rows=rows)
            )
        return CollectionState(slabs=slabs)

    def flush(self, state: CollectionState) -> CollectionState:
        slabs = dict(state.slabs)
        for sname, spec in self.cached_slabs.items():
            ccfg = self.shard_cache_config(spec)
            slab = slabs[sname]
            full, cache = jax.vmap(lambda f, c: cache_lib.flush(ccfg, f, c))(
                slab.full, slab.cache
            )
            K = slab.rep.rows.shape[0]
            if K:
                # the arena is authoritative for ranks < K: write it back to
                # the ranks' slow-tier homes AFTER the per-shard flush (a
                # never-planned warm copy of a replicated home may still sit
                # in some shard's arena; the rep row must win).
                vs = self.rows_per_shard(spec)
                homes = (
                    slab.rank_owner[:K] * vs + slab.rank_local[:K]
                ).astype(jnp.int32)
                flat = transmitter.write_rows(
                    {"weight": slab.rep.rows}, flat_store(full), homes,
                    jnp.ones((K,), bool), buffer_rows=spec.buffer_rows,
                )
                full = _stack_store(flat, self.num_shards, vs)
            slabs[sname] = dataclasses.replace(slab, full=full, cache=cache)
        return CollectionState(slabs=slabs)

    # ----- adaptive frequency refresh ---------------------------------------

    def refresh(
        self,
        state: CollectionState,
        cfg: Optional[refresh_lib.RefreshConfig] = None,
        writeback: bool = True,
    ) -> Tuple[CollectionState, refresh_lib.RefreshReport]:
        """Sharded re-ranking refresh (see ``EmbeddingCollection.refresh``).

        The incremental permutation is planned GLOBALLY from the merged
        per-shard decayed counters, then applied as content exchanges between
        the swapped ranks' fixed ``(owner, local)`` homes — the traffic
        balance ``assign_devices`` placed on the hot homes is inherited by
        the newly-hot rows.  Cross-shard exchanges are metered by
        ``cfg.exchange_budget`` (rows per refresh; excess pairs defer to the
        next pass).  With one shard the pass is bit-identical to the
        unsharded refresh."""
        cfg = cfg or refresh_lib.RefreshConfig()
        slabs = dict(state.slabs)
        report = refresh_lib.RefreshReport()
        for sname, spec in self.cached_slabs.items():
            slabs[sname], stats = refresh_lib.refresh_sharded_slab(
                self.shard_cache_config(spec, writeback=writeback),
                slabs[sname], cfg, writeback=writeback,
            )
            if cfg.rebalance_threshold is not None:
                slabs[sname], rstats = self._maybe_rebalance(
                    sname, spec, slabs[sname], cfg, writeback
                )
                stats = {**stats, **rstats}
            report.add(sname, stats)
        return CollectionState(slabs=slabs), report

    def _maybe_rebalance(
        self,
        sname: str,
        spec: _CachedSlabSpec,
        slab: ShardedSlab,
        cfg: refresh_lib.RefreshConfig,
        writeback: bool,
    ) -> Tuple[ShardedSlab, Dict[str, Any]]:
        """Traffic-aware re-homing (tentpole front c): measure the LIVE
        routed imbalance from the per-shard trackers' decayed scores; when it
        exceeds ``cfg.rebalance_threshold``, re-run ``assign_devices`` on the
        live scores and permute every rank's slow-tier home to its new
        ``(owner, local)`` — pure data movement (the encoded payload moves
        bit-exact), so the fp32 loss trajectory is unchanged while future
        exchange traffic follows the refreshed placement.  Planning is
        host-side numpy like init-time placement."""
        S = self.num_shards
        vs = self.rows_per_shard(spec)
        K = int(slab.rep.rows.shape[0])
        owner = np.asarray(jax.device_get(slab.rank_owner))
        local = np.asarray(jax.device_get(slab.rank_local))
        tr = slab.cache.tracker
        steps = np.asarray(jax.device_get(slab.cache.step), np.float64)
        local_scores = freq_lib.decayed_scores(
            np.asarray(jax.device_get(tr.score)),
            np.asarray(jax.device_get(tr.last_touch)),
            steps[:, None],
            spec.freq_half_life,
        )
        scores = local_scores[owner, local]
        scores[:K] = 0.0  # replicated ranks carry no routed traffic
        load = np.zeros((S,), np.float64)
        np.add.at(load, owner[K:], scores[K:])
        mean = float(load.mean())
        imb = float(load.max() / mean) if mean > 0 else 1.0
        stats: Dict[str, Any] = {"rebalance_moves": 0, "rebalance_imbalance": imb}
        if imb <= float(cfg.rebalance_threshold):
            return slab, stats
        assign = PlacementPlanner.assign_devices(
            spec.vocab, S, scores, replicate_top_k=K
        )
        new_flat = assign.owner.astype(np.int64) * vs + assign.local.astype(np.int64)
        old_flat = owner.astype(np.int64) * vs + local
        moved = int(np.sum(new_flat != old_flat))
        if not moved:
            return slab, stats
        # gather map: the leaf row that must land at each new flat home.
        src_for_dest = np.arange(S * vs, dtype=np.int64)
        src_for_dest[new_flat] = old_flat
        full, cache = refresh_lib._apply_rebalance(
            slab.full, slab.cache,
            jnp.asarray(src_for_dest, jnp.int32),
            buffer_rows=spec.buffer_rows, writeback=writeback,
        )
        ccfg = self.shard_cache_config(spec, writeback=writeback)
        full, cache = jax.vmap(lambda f, c: cache_lib.warmup(ccfg, f, c))(
            full, cache
        )
        self.assignments[sname] = assign
        stats["rebalance_moves"] = moved
        stats["rebalance_imbalance"] = imb
        return (
            dataclasses.replace(
                slab, full=full, cache=cache,
                rank_owner=jnp.asarray(assign.owner, jnp.int32),
                rank_local=jnp.asarray(assign.local, jnp.int32),
            ),
            stats,
        )

    # ----- oracles / bulk reads ---------------------------------------------

    def _rank_rows(self, slab: ShardedSlab, rank: jnp.ndarray) -> jnp.ndarray:
        """Decoded slow-tier rows for freq ranks (-1 lanes -> zero rows).
        Replicated ranks (< K) read the arena directly — it is authoritative
        (the slow-tier home only re-syncs at flush/refresh)."""
        vs = slab.full.data["weight"].shape[1]
        ok = rank >= 0
        owner = slab.rank_owner.at[jnp.where(ok, rank, 0)].get(mode="fill", fill_value=-1)
        local = slab.rank_local.at[jnp.where(ok, rank, 0)].get(mode="fill", fill_value=-1)
        flat = jnp.where(ok & (owner >= 0), owner * vs + local, -1)
        rows = _read_full_rows(flat_store(slab.full), flat)
        K = slab.rep.rows.shape[0]
        if K:
            in_rep = ok & (rank < K)
            rep_rows = jnp.take(
                slab.rep.rows, jnp.where(in_rep, rank, K),
                axis=0, mode="fill", fill_value=0,
            )
            rows = jnp.where(in_rep[:, None], rep_rows, rows)
        return rows

    def full_lookup(
        self, state: CollectionState, table: str, local_ids: jnp.ndarray
    ) -> jnp.ndarray:
        sname, off = self.table_slab[table]
        if sname in self.device_slabs:
            return super().full_lookup(state, table, local_ids)
        slab = state.slabs[sname]
        valid = local_ids >= 0
        rank = slab.idx_map.at[jnp.where(valid, local_ids + off, 0)].get(
            mode="fill", fill_value=-1
        )
        return self._rank_rows(slab, jnp.where(valid, rank, -1))

    def dense_reference(
        self, state: CollectionState, fb: FeatureBatch
    ) -> Dict[str, jnp.ndarray]:
        out = {}
        for f in fb.features:
            tname = self.feature_to_table[f]
            sname, off = self.table_slab[tname]
            ids = fb.ids[f]
            flat = ids.reshape(-1)
            if sname in self.device_slabs:
                w = state.slabs[sname].weight
                safe = jnp.where(flat >= 0, flat, w.shape[0])
                rows = jnp.take(w, safe, axis=0, mode="fill", fill_value=0)
            else:
                slab = state.slabs[sname]
                r = slab.idx_map.at[jnp.where(flat >= 0, flat + off, 0)].get(
                    mode="fill", fill_value=-1
                )
                rows = self._rank_rows(slab, jnp.where(flat >= 0, r, -1))
            out[f] = rows.reshape(ids.shape + (rows.shape[-1],))
        return out

    # ----- telemetry / accounting -------------------------------------------

    # jit-adjacent: traced inside every sharded compute_step — the int-counter
    # contract pins the exchange/refresh counter families the obs hub
    # reconstructs, and max_sort_size=0 asserts telemetry never adds a sort.
    @contract(int_counters=METRICS_INT_COUNTERS, max_sort_size=0)
    def metrics(
        self, state: CollectionState, writeback: bool = True
    ) -> Dict[str, jnp.ndarray]:
        """Unsharded telemetry (counters sum over shards) plus the exchange
        accounting: ``exchange_routed_lanes`` / ``exchange_lane_bytes`` are
        per-slab cumulative id lanes routed through the bucketize exchange
        and the per-lane payload (4 B id out + one fast-tier row back; the
        row leg prices at the exchange codec's encoded width) — exact bytes
        via ``exact_metric_bytes``.  The two legs are also split out —
        ``exchange_id_lane_bytes`` / ``exchange_row_lane_bytes`` per slab and
        ``exchange_id_bytes`` / ``exchange_row_bytes`` float32 totals — and
        ``exchange_per_shard_lanes`` is the [S] routed-lane histogram summed
        over slabs.  Of the payload an expected (S-1)/S fraction crosses
        devices on an S-shard mesh.

        ``shard_imbalance`` is LIVE: max/mean of the per-shard decayed
        frequency mass (``freq.decay_to`` over the trackers at the current
        step), so it follows traffic drift instead of freezing at the
        init-time placement counts.  The cumulative-lane variant survives as
        ``shard_imbalance_routed``.

        Telemetry caveat (same as hits/misses): under pipelined group
        scheduling only group leaders run a plan, so routed lanes sample the
        leaders' batches."""
        out = super().metrics(state, writeback=writeback)
        lanes: Dict[str, jnp.ndarray] = {}
        lane_bytes: Dict[str, jnp.ndarray] = {}
        id_lane_bytes: Dict[str, jnp.ndarray] = {}
        row_lane_bytes: Dict[str, jnp.ndarray] = {}
        id_bytes = jnp.zeros((), jnp.float32)
        row_bytes = jnp.zeros((), jnp.float32)
        per_shard = jnp.zeros((self.num_shards,), jnp.int32)
        live = jnp.zeros((self.num_shards,), jnp.float32)
        for sname, spec in self.cached_slabs.items():
            slab = state.slabs[sname]
            n = jnp.sum(slab.routed_lanes)
            lanes[sname] = n.astype(jnp.int32)
            if self.exchange_codec:
                rb = int(get_codec(self.exchange_codec).row_bytes(
                    (spec.dim,), spec.dtype
                ))
            else:
                rb = spec.dim * jnp.dtype(spec.dtype).itemsize
            lane_bytes[sname] = jnp.asarray(4 + rb, jnp.int32)
            id_lane_bytes[sname] = jnp.asarray(4, jnp.int32)
            row_lane_bytes[sname] = jnp.asarray(rb, jnp.int32)
            id_bytes = id_bytes + n.astype(jnp.float32) * 4
            row_bytes = row_bytes + n.astype(jnp.float32) * rb
            per_shard = per_shard + slab.routed_lanes
            tr = slab.cache.tracker
            live = live + jnp.sum(
                freq_lib.decay_to(
                    tr.score, tr.last_touch, slab.cache.step[:, None],
                    spec.freq_half_life,
                ),
                axis=1,
            )
        tot = jnp.sum(per_shard)
        mean = tot.astype(jnp.float32) / self.num_shards
        tot_live = jnp.sum(live)
        out["exchange_routed_lanes"] = lanes
        out["exchange_lane_bytes"] = lane_bytes
        out["exchange_id_lane_bytes"] = id_lane_bytes
        out["exchange_row_lane_bytes"] = row_lane_bytes
        out["exchange_id_bytes"] = id_bytes
        out["exchange_row_bytes"] = row_bytes
        out["exchange_bytes"] = id_bytes + row_bytes
        out["exchange_per_shard_lanes"] = per_shard
        out["shard_imbalance"] = jnp.where(
            tot_live > 0,
            jnp.max(live) / jnp.maximum(tot_live / self.num_shards, 1e-9),
            1.0,
        )
        out["shard_imbalance_routed"] = jnp.where(
            tot > 0, jnp.max(per_shard).astype(jnp.float32) / jnp.maximum(mean, 1e-9), 1.0
        )
        return out

    def device_bytes(self) -> Dict[str, int]:
        """Footprint under the sharded layout.  ``device_total`` counts one
        REPLICA of the shared read-only arrays (DEVICE tables, id routing
        maps) plus the summed stacked arrays plus S copies of every
        replicated hot-row arena — each mesh device materializes its own
        ``ShardedSlab.rep``, so charging it once under-counted real HBM by
        ``(S-1) * rep_arena`` bytes.  ``device_per_shard`` is what one mesh
        device actually holds — the budget-relevant number.  Tiered arenas
        (``arena_precision`` != fp32) charge the encoded tail + sideband via
        :func:`tiered_arena_bytes`; ``arena_bytes_saved`` is the fast-tier
        HBM the tiering freed versus an all-fp32 arena."""
        S = self.num_shards
        per_slab: Dict[str, int] = {}
        replicated = 0
        stacked = 0
        rep_arenas = 0
        slow = slow_fp32 = 0
        fast_fp32 = fast_actual = 0
        for name, t in self.device_slabs.items():
            per_slab[name] = t.full_bytes
            replicated += t.full_bytes
        for sname, spec in self.cached_slabs.items():
            item = jnp.dtype(spec.dtype).itemsize
            vs = self.rows_per_shard(spec)
            cap = self.shard_capacity(spec)
            ccfg = self.shard_cache_config(spec)
            w = tiered_arena_bytes(
                cap, ccfg.head_capacity, spec.dim, spec.dtype,
                ccfg.arena_precision,
            )
            fast_fp32 += S * cap * spec.dim * item
            fast_actual += S * w
            # per shard: arena (+ sideband) + slot bookkeeping + row_to_slot
            # + tracker
            stack = S * (w + cap * 4 * 3 + vs * 4 * 3)
            rep = spec.vocab * 4 * 3  # idx_map + rank_owner + rank_local
            K = min(self.replicate_top_k, spec.vocab)
            # replicated arena: rows + its tracker (score, last_touch) + step
            # — PER DEVICE (every shard holds a full copy; fp32 by design)
            rep_arena = K * (spec.dim * item + 4 + 4) + 4
            per_slab[sname] = stack + rep + S * rep_arena
            stacked += stack
            replicated += rep
            rep_arenas += rep_arena
            codec = get_codec(self._slab_codec(sname))
            slow += S * vs * codec.row_bytes((spec.dim,), spec.dtype)
            slow_fp32 += S * vs * spec.dim * item
        return {
            "device_total": replicated + stacked + S * rep_arenas,
            "device_per_shard": replicated + rep_arenas + stacked // max(S, 1),
            "slow_tier_bytes": slow,
            "host_bytes_saved": slow_fp32 - slow,
            "arena_bytes_saved": fast_fp32 - fast_actual,
            "per_slab": per_slab,
            "budget_bytes": self.plan.budget_bytes,
        }

    # ----- sharding ----------------------------------------------------------

    def shard_specs(self, mode: str = "shard", model_axis: Optional[str] = None):
        """PartitionSpec pytree matching the sharded ``CollectionState``:
        every stacked leaf splits its leading shard dim over the mesh's
        ``model`` axis, the id-routing maps and DEVICE tables replicate
        (DEVICE tables train data-parallel with the MLPs).  ``mode`` is
        accepted for drop-in compatibility with the unsharded signature but
        the layout is fixed by the shard structure."""
        from jax.sharding import PartitionSpec as P

        axis = model_axis or self.model_axis
        slabs: Dict[str, Any] = {}
        for name in self.device_slabs:
            slabs[name] = DeviceSlab(weight=P(None, None))
        for sname, spec in self.cached_slabs.items():
            like = {"weight": jax.ShapeDtypeStruct((spec.vocab, spec.dim), spec.dtype)}
            arena_codec = self._slab_arena_codec(sname)
            if arena_codec == "fp32":
                cached_rows: Any = {"weight": P(axis, None, None)}
            else:
                # tiered arena: every tier's leaves carry the leading [S]
                # shard dim, sideband included (it is per-shard cache state,
                # unlike the host-store sideband which follows the row split)
                cap = self.shard_capacity(spec)
                cached_rows = ArenaStore.spec_like(
                    {"weight": jax.ShapeDtypeStruct((cap, spec.dim), spec.dtype)},
                    P(axis, None, None),
                    P(axis, None, None),
                    codec=arena_codec,
                )
            slabs[sname] = ShardedSlab(
                full=HostStore.spec_like(
                    like,
                    {"weight": P(axis, None, None)},
                    P(axis, None, None),
                    codec=self._slab_codec(sname),
                ),
                cache=cache_lib.CacheState(
                    cached_rows=cached_rows,
                    slot_to_row=P(axis, None),
                    row_to_slot=P(axis, None),
                    last_used=P(axis, None),
                    use_count=P(axis, None),
                    step=P(axis),
                    hits=P(axis),
                    misses=P(axis),
                    evictions=P(axis),
                    uniq_overflows=P(axis),
                    tier_promotions=P(axis),
                    tier_demotions=P(axis),
                    tracker=freq_lib.tracker_spec(P, axis=axis),
                ),
                idx_map=P(None),
                rank_owner=P(None),
                rank_local=P(None),
                routed_lanes=P(axis),
                rep=RepArena(
                    rows=P(None, None),  # replicated on every shard
                    score=P(None),
                    last_touch=P(None),
                    step=P(),
                ),
            )
        return CollectionState(slabs=slabs)
