"""``ArenaStore`` — the frequency-tiered device cache arena (fast tier).

The device arena historically stored every resident row fp32.  The same HBM
budget stretches 2-4x further when only the *hot head* of the arena keeps
full precision and the colder resident tail stores encoded (fp16 or row-wise
int8 with a sideband scale leaf) — "Mixed-Precision Embedding Using a Cache"
(arXiv 2010.11305), applied on-device instead of host-side.  An
``ArenaStore`` is that container:

  * slots ``[0, head_capacity)`` — the fp32 head: raw leaves, bit-exact, the
    tier SGD updates touch directly.
  * slots ``[head_capacity, capacity)`` — the encoded tail: payload leaves in
    the codec's storage dtype plus a per-row ``sideband`` leaf (int8's
    [tail, 2] (scale, zero_point); empty for fp16).

The slot partition is what ties precision to frequency WITHOUT any extra
bookkeeping: ``warmup`` fills slot i with frequency rank i, FREQ_LFU's
eviction key is the resident rank itself, and ``plan_prepare`` compacts miss
rows in ascending-rank order — so hot rows gravitate to low slots (the head)
and cold residents to high slots (the tail) by the same mechanics that
already move rows across the capacity boundary.  ``core.refresh`` swaps
cross the precision boundary for free: a swapped row is invalidated and
re-faults into whichever tier its new rank's slot lives in.

Layout convention: encoded leaves are per-row vectors ``[..., slots, dim]``
(the cache's ``{"weight": [capacity, dim]}`` shape); leaves the codec does
not transform (per-row scalars, integer leaves) stay raw at full capacity in
``raw``.  All ops treat the slot axis as axis 0 of the unbatched view, so
they compose with ``jax.vmap`` over a leading shard axis exactly like the
raw-dict arena (the sharded collection's stacked ``[S, capacity, dim]``
leaves).  Whole-leaf ``decode_leaf`` / ``replace_leaf`` accept stacked
arrays directly — encode flattens the leading batch dims first, because the
int8 codec's per-row reduction would otherwise collapse the shard axis into
one scale.

Like ``HostStore``, the codec name is static pytree metadata, so jit
specializes per codec and checkpoint restore validates the layout (leaf
shape/dtype mismatch = arena-precision mismatch, a loud failure).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cache_ops import ops as cache_ops
from repro.store.codec import Codec, get_codec

__all__ = ["ArenaStore", "tiered_arena_bytes"]


def tiered_arena_bytes(
    capacity: int,
    head_capacity: int,
    dim: int,
    dtype,
    codec: str,
) -> int:
    """Static device footprint of one tiered weight leaf: fp32 head rows +
    encoded tail payload + tail sideband.  ``codec="fp32"`` reproduces the
    raw-arena accounting exactly (head == capacity, no tail)."""
    item = jnp.dtype(dtype).itemsize
    if codec == "fp32":
        return capacity * dim * item
    c = get_codec(codec)
    head = min(max(int(head_capacity), 0), int(capacity))
    tail = int(capacity) - head
    return head * dim * item + tail * c.row_bytes((dim,), dtype)


def _row_mask(mask: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a per-lane bool mask over a block's trailing row dims."""
    return mask.reshape(mask.shape + (1,) * (rows.ndim - mask.ndim))


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ArenaStore:
    """Tiered fast-tier container (see module docstring).

    ``head``/``tail``/``sideband``/``raw`` are flat ``Dict[str, array]``
    pytrees; ``codec``/``out_dtype`` ride as static metadata (like
    ``HostStore``), so ``isinstance`` dispatch in the transmitter stays a
    trace-time decision, including under ``jax.vmap``."""

    head: Dict[str, jnp.ndarray]  # [head_capacity, ...] raw (fp32) rows
    tail: Dict[str, jnp.ndarray]  # [capacity - head_capacity, ...] payload
    sideband: Dict[str, jnp.ndarray]  # per-row codec metadata for tail rows
    raw: Dict[str, jnp.ndarray]  # untransformed leaves, full [capacity, ...]
    codec: str = dataclasses.field(default="fp16", metadata=dict(static=True))
    out_dtype: str = dataclasses.field(default="float32", metadata=dict(static=True))

    # ----- construction -----------------------------------------------------

    @staticmethod
    def _tiers(codec: Codec, leaf) -> bool:
        """Encoded leaves are per-row VECTORS exactly ([slots, dim]): wider
        per-row shapes and scalars stay raw (sideband bookkeeping would cost
        more than it saves — the ``HostStore.encodes`` trade, tightened to
        the arena's known leaf layout)."""
        return codec.encodes(leaf) and len(leaf.shape) == 2

    @classmethod
    def create(
        cls,
        full_tree: Dict[str, jnp.ndarray],
        head_capacity: int,
        codec: str,
    ) -> "ArenaStore":
        """Split a raw ``[capacity, ...]`` arena dict into head + encoded tail."""
        c = get_codec(codec)
        if codec == "fp32":
            raise ValueError(
                "ArenaStore is the tiered container; an fp32 arena stays a raw "
                "dict (bit-identical pre-tiering layout)"
            )
        dts = {
            str(jnp.dtype(v.dtype)) for v in full_tree.values() if cls._tiers(c, v)
        }
        if len(dts) > 1:
            raise ValueError(
                f"ArenaStore decodes all tail leaves to one dtype, got {sorted(dts)}"
            )
        if not dts:
            raise ValueError("ArenaStore needs at least one per-row vector leaf")
        out_dtype = dts.pop()
        head: Dict[str, jnp.ndarray] = {}
        tail: Dict[str, jnp.ndarray] = {}
        sideband: Dict[str, jnp.ndarray] = {}
        raw: Dict[str, jnp.ndarray] = {}
        for k, leaf in full_tree.items():
            if cls._tiers(c, leaf):
                h = min(max(int(head_capacity), 0), int(leaf.shape[0]))
                head[k] = leaf[:h]
                payload, side = c.encode(leaf[h:])
                tail[k] = payload
                if side is not None:
                    sideband[k] = side
            else:
                raw[k] = leaf
        return cls(
            head=head, tail=tail, sideband=sideband, raw=raw,
            codec=codec, out_dtype=out_dtype,
        )

    @classmethod
    def spec_like(
        cls,
        full_like: Dict[str, Any],
        leaf_spec: Any,
        side_spec: Any,
        codec: str,
    ) -> "ArenaStore":
        """PartitionSpec mirror of ``create``: head/tail entries carry
        ``leaf_spec``, sideband entries ``side_spec``, exactly where arrays
        would sit — the shard-spec source of truth (``HostStore.spec_like``
        pattern)."""
        c = get_codec(codec)
        head: Dict[str, Any] = {}
        tail: Dict[str, Any] = {}
        sideband: Dict[str, Any] = {}
        raw: Dict[str, Any] = {}
        dts = {
            str(jnp.dtype(v.dtype)) for v in full_like.values() if cls._tiers(c, v)
        }
        out_dtype = dts.pop() if dts else "float32"
        for k, leaf in full_like.items():
            if cls._tiers(c, leaf):
                head[k] = leaf_spec
                tail[k] = leaf_spec
                if c.sideband_row_shape() is not None:
                    sideband[k] = side_spec
            else:
                raw[k] = leaf_spec
        return cls(
            head=head, tail=tail, sideband=sideband, raw=raw,
            codec=codec, out_dtype=out_dtype,
        )

    # ----- geometry ---------------------------------------------------------

    @property
    def head_capacity(self) -> int:
        """Slots below this index are fp32; derived from leaf shapes so it is
        correct on the unbatched view inside ``vmap`` and on stacked leaves
        alike (slot axis = second-to-last of a [..., slots, dim] leaf)."""
        return int(next(iter(self.head.values())).shape[-2])

    @property
    def capacity(self) -> int:
        return self.head_capacity + int(next(iter(self.tail.values())).shape[-2])

    @property
    def _codec(self) -> Codec:
        return get_codec(self.codec)

    @property
    def _out(self):
        return jnp.dtype(self.out_dtype)

    # ----- slot ops (the transmitter's gather/scatter surface) --------------

    def gather_slots(self, slots: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        """Decoded rows at ``slots`` (int32 [K]); negative/OOB lanes give zero
        rows — the ``transmitter.gather_rows`` convention.  Head lanes are
        bit-exact reads; tail lanes decode payload + sideband."""
        c = self._codec
        out: Dict[str, jnp.ndarray] = {}
        for k, hleaf in self.head.items():
            # fused gather+decode (kernels/cache_ops): Pallas lowers the
            # per-lane head-or-tail pick + in-register decode on accelerators;
            # the reference route is the exact historical take/decode/select.
            out[k] = cache_ops.arena_gather_impl(
                hleaf,
                self.tail[k],
                self.sideband.get(k),
                slots,
                self.codec,
                c.decode,
                self._out,
            )
        for k, leaf in self.raw.items():
            safe = jnp.where(slots >= 0, slots, leaf.shape[0])
            out[k] = jnp.take(leaf, safe, axis=0, mode="fill", fill_value=0)
        return out

    def scatter_slots(
        self,
        slots: jnp.ndarray,
        block: Dict[str, jnp.ndarray],
        active: jnp.ndarray,
        payload_block: Optional[Dict[str, jnp.ndarray]] = None,
        side_block: Optional[Dict[str, jnp.ndarray]] = None,
    ) -> "ArenaStore":
        """Scatter a full-precision ``block`` into ``slots`` where ``active``:
        head lanes land raw, tail lanes encode first.  When the source was a
        host store of the SAME codec, ``payload_block``/``side_block`` carry
        its already-encoded rows and tail lanes take them verbatim — the
        host->device load lands encoded with no decode/re-encode round trip
        (payload-stable: the device tail holds the host tier's exact bits)."""
        c = self._codec
        H = self.head_capacity
        in_tail = slots >= H
        head = dict(self.head)
        tail = dict(self.tail)
        sideband = dict(self.sideband)
        raw = dict(self.raw)
        for k, hleaf in self.head.items():
            idx_h = jnp.where(active & ~in_tail, slots, hleaf.shape[0])
            head[k] = hleaf.at[idx_h].set(
                block[k].astype(hleaf.dtype), mode="drop"
            )
            if payload_block is not None and k in payload_block:
                payload, side = payload_block[k], (
                    side_block.get(k) if side_block else None
                )
            else:
                payload, side = c.encode(block[k])
            tleaf = self.tail[k]
            idx_t = jnp.where(active & in_tail, slots - H, tleaf.shape[0])
            tail[k] = tleaf.at[idx_t].set(payload.astype(tleaf.dtype), mode="drop")
            if k in self.sideband:
                sideband[k] = self.sideband[k].at[idx_t].set(
                    side.astype(self.sideband[k].dtype), mode="drop"
                )
        n = self.capacity
        for k, leaf in self.raw.items():
            idx = jnp.where(active, slots, n)
            raw[k] = leaf.at[idx].set(block[k], mode="drop")
        return dataclasses.replace(
            self, head=head, tail=tail, sideband=sideband, raw=raw
        )

    # ----- whole-leaf views (weights() / apply_grads surface) ---------------

    def decode_leaf(self, key: str) -> jnp.ndarray:
        """The full ``[..., capacity, dim]`` decoded view of one leaf — what
        ``weights()`` hands the differentiable gather.  Works on stacked
        shard leaves unchanged (the codec decode broadcasts leading dims)."""
        if key in self.raw:
            return self.raw[key]
        tail = self._codec.decode(self.tail[key], self.sideband.get(key), self._out)
        return jnp.concatenate(
            [self.head[key].astype(self._out), tail], axis=-2
        )

    def _encode_rows(self, x: jnp.ndarray) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
        """Per-row encode of a possibly-stacked ``[..., rows, dim]`` array:
        flatten the leading batch dims first — the int8 codec reduces over
        every non-leading axis, so encoding ``[S, rows, dim]`` directly would
        produce one scale per SHARD instead of per row."""
        batch = x.shape[:-2]
        flat = x.reshape((-1,) + x.shape[-1:]) if batch else x
        payload, side = self._codec.encode(flat)
        if batch:
            payload = payload.reshape(x.shape)
            if side is not None:
                side = side.reshape(batch + x.shape[-2:-1] + side.shape[-1:])
        return payload, side

    def replace_leaf(self, key: str, full: jnp.ndarray) -> "ArenaStore":
        """New store with leaf ``key`` set from a full decoded array: the
        head slice lands raw (bit-exact SGD on hot rows), the tail slice
        re-encodes with a fresh per-row master scale (the sideband).  Rows
        the update left untouched re-encode to the identical payload (the
        codec's stable-projection property), so a zero gradient is a no-op
        in both tiers."""
        if key in self.raw:
            return dataclasses.replace(self, raw={**self.raw, key: full})
        H = self.head_capacity
        head_part = full[..., :H, :].astype(self.head[key].dtype)
        payload, side = self._encode_rows(full[..., H:, :])
        sideband = dict(self.sideband)
        if side is not None and key in self.sideband:
            sideband[key] = side.astype(self.sideband[key].dtype)
        return dataclasses.replace(
            self,
            head={**self.head, key: head_part},
            tail={**self.tail, key: payload.astype(self.tail[key].dtype)},
            sideband=sideband,
        )

    # ----- accounting -------------------------------------------------------

    def device_bytes(self) -> int:
        """Actual device footprint of the container (all tiers + sideband)."""
        n = 0
        for leaf in (
            list(self.head.values()) + list(self.tail.values())
            + list(self.sideband.values()) + list(self.raw.values())
        ):
            n += int(np.prod(leaf.shape, dtype=np.int64)) * jnp.dtype(leaf.dtype).itemsize
        return n

    def fp32_equiv_bytes(self) -> int:
        """The raw-arena footprint of the same resident set (head == capacity)."""
        n = 0
        for k in self.head:
            row = int(np.prod(self.head[k].shape[-1:], dtype=np.int64))
            batch = int(np.prod(self.head[k].shape[:-2], dtype=np.int64))
            n += batch * self.capacity * row * self._out.itemsize
        for leaf in self.raw.values():
            n += int(np.prod(leaf.shape, dtype=np.int64)) * jnp.dtype(leaf.dtype).itemsize
        return n
