"""``HostStore`` — the host-tier (slow, full-table) representation.

A ``HostStore`` replaces the raw fp32 ``full_rows`` pytree under the cache:
it holds each leaf *encoded* by one :mod:`repro.store.codec` codec (payload
dict + per-row sideband dict) and exposes row-block ``encode_rows`` /
``decode_rows`` so the transmitter can quantize-on-writeback and
dequantize-on-load inside its pack -> move -> scatter rounds.  The staging
block crosses the host<->device link *encoded* — for int8 that is ~4x fewer
bytes per cache miss — while the cached working set stays full precision
(the mixed-precision-cache design of arXiv 2010.11305).

``data`` must be a flat ``Dict[str, jnp.ndarray]`` (the shape every slab's
``full`` tree already has: ``{"weight": [vocab, dim], ("accum": [vocab])?}``).
Leaves the codec does not transform (per-row scalars like optimizer
accumulators, integer leaves) are stored raw and pass through untouched.

The fp32 codec stores raw arrays, so a ``HostStore("fp32")`` is bit-identical
to the pre-store pytree in every operation — existing callers migrate with
zero numeric risk.  ``store[key]`` / ``store[key] = v`` index straight into
``data`` (for fp32 that is the old raw-leaf access; quantized readers must
use ``decode_rows`` / ``decode_leaf``).

The codec name rides on the pytree as static metadata, so jit specializes
per codec and checkpoint restore can validate it (leaf dtype/shape mismatch
= codec mismatch).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.store.codec import Codec, get_codec

__all__ = ["HostStore"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HostStore:
    """Encoded full-table container: ``data`` payload leaves [vocab, ...] in
    the codec's storage dtype, ``sideband`` per-row codec metadata (e.g.
    int8's [vocab, 2] (scale, zero_point)), empty for sideband-free codecs."""

    data: Dict[str, jnp.ndarray]
    sideband: Dict[str, jnp.ndarray]
    codec: str = dataclasses.field(default="fp32", metadata=dict(static=True))
    out_dtype: str = dataclasses.field(default="float32", metadata=dict(static=True))

    # ----- construction -----------------------------------------------------

    @staticmethod
    def _out_dtype(codec: "Codec", full_tree: Dict[str, Any]) -> str:
        """The single decode-target dtype of the tree's encoded leaves.

        One store decodes to ONE dtype, so all codec-eligible leaves must
        share their source dtype — reject mixed trees instead of silently
        decoding the minority leaf to the wrong type."""
        dts = {str(jnp.dtype(v.dtype)) for v in full_tree.values() if codec.encodes(v)}
        if len(dts) > 1:
            raise ValueError(
                f"HostStore encodes all leaves to one decode dtype, but the "
                f"tree mixes {sorted(dts)} — split the table into one store "
                f"per dtype"
            )
        return dts.pop() if dts else "float32"

    @classmethod
    def create(cls, full_tree: Dict[str, jnp.ndarray], codec: str = "fp32") -> "HostStore":
        """Encode a raw full-table dict into a store (one codec per store)."""
        c = get_codec(codec)
        data: Dict[str, jnp.ndarray] = {}
        sideband: Dict[str, jnp.ndarray] = {}
        out_dtype = cls._out_dtype(c, full_tree)
        for k, leaf in full_tree.items():
            if c.encodes(leaf):
                payload, side = c.encode(leaf)
                data[k] = payload
                if side is not None:
                    sideband[k] = side
            else:
                data[k] = leaf
        return cls(data=data, sideband=sideband, codec=codec, out_dtype=out_dtype)

    @classmethod
    def like(cls, full_like: Dict[str, Any], codec: str = "fp32") -> "HostStore":
        """Structure-only store from shape/dtype examples (specs, eval_shape)."""
        c = get_codec(codec)
        data: Dict[str, Any] = {}
        sideband: Dict[str, Any] = {}
        out_dtype = cls._out_dtype(c, full_like)
        for k, leaf in full_like.items():
            if c.encodes(leaf):
                data[k] = jax.ShapeDtypeStruct(leaf.shape, c.payload_dtype(leaf.dtype))
                srow = c.sideband_row_shape()
                if srow is not None:
                    sideband[k] = jax.ShapeDtypeStruct((leaf.shape[0],) + srow, jnp.float32)
            else:
                data[k] = jax.ShapeDtypeStruct(leaf.shape, leaf.dtype)
        return cls(data=data, sideband=sideband, codec=codec, out_dtype=out_dtype)

    @classmethod
    def spec_like(
        cls,
        full_like: Dict[str, Any],
        leaf_specs: Dict[str, Any],
        side_spec: Any,
        codec: str = "fp32",
    ) -> "HostStore":
        """PartitionSpec mirror of ``create(full_like, codec)``: a store whose
        ``data`` holds ``leaf_specs`` and whose sideband entries (``side_spec``
        per quantized leaf) appear exactly where ``create`` would put arrays —
        the single source of truth for shard-spec trees that must match a
        real store's structure."""
        c = get_codec(codec)
        out_dtype = cls._out_dtype(c, full_like)
        sideband = {
            k: side_spec
            for k, leaf in full_like.items()
            if c.encodes(leaf) and c.sideband_row_shape() is not None
        }
        return cls(
            data=dict(leaf_specs), sideband=sideband, codec=c.name, out_dtype=out_dtype
        )

    # ----- raw-leaf access (fp32 compatibility surface) ---------------------

    def __getitem__(self, key: str) -> jnp.ndarray:
        """The stored payload leaf — for fp32 stores this is the raw array
        (the pre-store access idiom); quantized readers want ``decode_leaf``."""
        return self.data[key]

    def __setitem__(self, key: str, value) -> None:
        self.data[key] = value

    def __contains__(self, key: str) -> bool:
        return key in self.data

    # ----- codec plumbing ---------------------------------------------------

    @property
    def _codec(self) -> Codec:
        return get_codec(self.codec)

    @property
    def _out(self):
        return jnp.dtype(self.out_dtype)

    def is_encoded(self, key: str) -> bool:
        """True when ``data[key]`` is stored in the codec's low-precision
        form (self-describing: payload dtype differs from the decode target,
        or a sideband entry exists)."""
        if self.codec == "fp32":
            return False
        if key in self.sideband:
            return True
        return jnp.dtype(self.data[key].dtype) != self._out and jnp.issubdtype(
            self._out, jnp.floating
        )

    # ----- block transforms (what the transmitter calls per round) ----------

    def decode_block(
        self, block: Dict[str, jnp.ndarray], side: Dict[str, jnp.ndarray]
    ) -> Dict[str, jnp.ndarray]:
        """Decode a gathered staging block back to full precision."""
        c = self._codec
        return {
            k: c.decode(v, side.get(k), self._out) if self.is_encoded(k) else v
            for k, v in block.items()
        }

    def encode_block(
        self, block: Dict[str, jnp.ndarray]
    ) -> Tuple[Dict[str, jnp.ndarray], Dict[str, jnp.ndarray]]:
        """Encode a full-precision staging block for the trip to host."""
        c = self._codec
        data: Dict[str, jnp.ndarray] = {}
        side: Dict[str, jnp.ndarray] = {}
        for k, v in block.items():
            if self.is_encoded(k):
                payload, s = c.encode(v)
                data[k] = payload
                if s is not None:
                    side[k] = s
            else:
                data[k] = v
        return data, side

    # ----- row reads (oracles / bulk scans) ---------------------------------

    def decode_rows(self, idx: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        """Gather rows ``idx`` of every leaf, decoded; negative/OOB lanes
        give zero rows (the ``transmitter.gather_rows`` convention)."""
        block: Dict[str, jnp.ndarray] = {}
        side: Dict[str, jnp.ndarray] = {}
        for k, leaf in self.data.items():
            safe = jnp.where(idx >= 0, idx, leaf.shape[0])
            block[k] = jnp.take(leaf, safe, axis=0, mode="fill", fill_value=0)
            if k in self.sideband:
                side[k] = jnp.take(
                    self.sideband[k], safe, axis=0, mode="fill", fill_value=0
                )
        return self.decode_block(block, side)

    def decode_leaf(self, key: str) -> jnp.ndarray:
        """The whole leaf, decoded (oracle/bulk use; fp32 = zero-cost)."""
        if not self.is_encoded(key):
            return self.data[key]
        return self._codec.decode(self.data[key], self.sideband.get(key), self._out)

    # ----- accounting -------------------------------------------------------

    def row_wire_bytes(self, batch_dims: int = 1) -> int:
        """Encoded bytes per row across all leaves — what one transmitter
        lane moves over the host link (load or writeback).  ``batch_dims``
        counts the leading non-row dims: 1 for a plain [vocab, ...] store,
        2 for a shard-stacked [S, vocab_s, ...] one."""
        total = 0
        for k, leaf in self.data.items():
            if self.is_encoded(k):
                total += self._codec.row_bytes(tuple(leaf.shape[batch_dims:]), self._out)
            else:
                total += int(
                    np.prod(leaf.shape[batch_dims:], dtype=np.int64)
                ) * jnp.dtype(leaf.dtype).itemsize
        return total

    def host_bytes(self) -> int:
        """Total host-tier footprint (payload + sideband)."""
        n = 0
        for leaf in list(self.data.values()) + list(self.sideband.values()):
            n += int(np.prod(leaf.shape, dtype=np.int64)) * jnp.dtype(leaf.dtype).itemsize
        return n

    def fp32_equiv_bytes(self) -> int:
        """What the same table would cost stored raw (the pre-store layout)."""
        n = 0
        for k, leaf in self.data.items():
            item = jnp.dtype(self._out if self.is_encoded(k) else leaf.dtype).itemsize
            n += int(np.prod(leaf.shape, dtype=np.int64)) * item
        return n

    def bytes_saved(self) -> int:
        return self.fp32_equiv_bytes() - self.host_bytes()
