"""Mixed-precision host-tier embedding storage (the tier under the cache).

``HostStore`` holds the full (host-resident) table encoded by a ``Codec``
(fp32 passthrough / fp16 / row-wise int8); ``PrecisionPolicy`` picks a codec
per table from frequency statistics and a host-byte budget.  The transmitter
is codec-aware, so staging blocks cross the host<->device link encoded.
"""
from repro.store.arena import ArenaStore, tiered_arena_bytes
from repro.store.codec import CODECS, Codec, Fp16Codec, Fp32Codec, Int8Codec, get_codec
from repro.store.host_store import HostStore
from repro.store.policy import PrecisionPolicy, SlabGeometry

__all__ = [
    "ArenaStore",
    "CODECS",
    "Codec",
    "Fp32Codec",
    "Fp16Codec",
    "Int8Codec",
    "get_codec",
    "HostStore",
    "PrecisionPolicy",
    "SlabGeometry",
    "tiered_arena_bytes",
]
