"""Row codecs for the host-tier embedding store (mixed-precision host memory).

The cache's premise is that the device holds ~1.5 % of the table while the
host holds everything — so host capacity and host<->device bandwidth are both
set by the *host-side* representation.  "Mixed-Precision Embedding Using a
Cache" (arXiv 2010.11305) shows the cold, host-resident majority of rows
tolerates low precision as long as the hot cached working set stays full
precision.  A ``Codec`` is that storage transform, applied per row block:

  * ``fp32`` — bit-exact passthrough (the pre-store behavior; zero risk).
  * ``fp16`` — 2x: cast on encode, upcast on decode.  Round-trip through the
    projection is idempotent (fp16 values are exactly representable in fp32).
  * ``int8`` — ~4x: row-wise affine quantization with a per-row
    (scale, zero_point) fp32 sideband — the row-wise version of the
    per-tensor scheme in ``optim/compression.py``.  The encode convention
    maps each row's min/max exactly onto q = -127/+127, so a
    decode -> encode round trip of an untouched row reproduces the same
    int8 payload (the projection is stable; tested property).

Codecs are pure jnp functions usable inside jit; ``encode``/``decode``
operate on row blocks (leading row dim), so the transmitter can encode or
decode its staging buffer per round — the block that crosses the host link
is the *encoded* one, which is the bandwidth win.

A leaf is only quantized when ``encodes(leaf)`` holds: floating dtype and a
per-row vector (ndim >= 2).  Per-row *scalar* leaves (e.g. row-wise Adagrad
accumulators, shape [vocab]) stay raw — a per-row sideband would cost more
than the scalar it compresses.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["Codec", "Fp32Codec", "Fp16Codec", "Int8Codec", "get_codec", "CODECS"]

_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class Codec:
    """Base codec: bit-exact passthrough (the ``fp32`` codec)."""

    name: str = "fp32"

    # -- which leaves this codec transforms ---------------------------------
    def encodes(self, leaf) -> bool:
        """Only per-row float vectors are re-coded; everything else is raw."""
        return jnp.issubdtype(leaf.dtype, jnp.floating) and len(leaf.shape) >= 2

    # -- block transforms (leading dim = rows) ------------------------------
    def encode(self, rows: jnp.ndarray) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
        """[n, ...] float rows -> (payload, sideband or None)."""
        return rows, None

    def decode(
        self, payload: jnp.ndarray, sideband: Optional[jnp.ndarray], out_dtype
    ) -> jnp.ndarray:
        return payload

    # -- static accounting ---------------------------------------------------
    def payload_dtype(self, orig_dtype):
        return orig_dtype

    def sideband_row_shape(self) -> Optional[Tuple[int, ...]]:
        """Per-row sideband shape, or None when the codec needs none."""
        return None

    def row_bytes(self, row_shape: Tuple[int, ...], orig_dtype) -> int:
        """Encoded bytes per row (payload + sideband) — what crosses the link."""
        n = int(np.prod(row_shape)) if row_shape else 1
        b = n * jnp.dtype(self.payload_dtype(orig_dtype)).itemsize
        side = self.sideband_row_shape()
        if side is not None:
            b += int(np.prod(side, dtype=np.int64)) * 4  # sideband is fp32
        return b


@dataclasses.dataclass(frozen=True)
class Fp32Codec(Codec):
    name: str = "fp32"


@dataclasses.dataclass(frozen=True)
class Fp16Codec(Codec):
    name: str = "fp16"

    def encode(self, rows: jnp.ndarray) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
        return rows.astype(jnp.float16), None

    def decode(self, payload, sideband, out_dtype) -> jnp.ndarray:
        return payload.astype(out_dtype)

    def payload_dtype(self, orig_dtype):
        return jnp.float16


@dataclasses.dataclass(frozen=True)
class Int8Codec(Codec):
    """Row-wise affine int8: q = round((x - zp) / scale) in [-127, 127].

    Sideband is [n, 2] fp32 = (scale, zero_point) per row, with
    ``scale = (max - min) / 254`` and ``zp = (max + min) / 2`` so the row
    endpoints land exactly on q = +-127.  A decoded row's endpoints are
    therefore re-encoded to the identical grid, making evict -> reload of an
    untouched row payload-stable (no quantization drift across cycles).
    """

    name: str = "int8"

    def encode(self, rows: jnp.ndarray) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
        x = rows.astype(jnp.float32)
        red = tuple(range(1, x.ndim))
        mn = jnp.min(x, axis=red)
        mx = jnp.max(x, axis=red)
        scale = jnp.maximum(mx - mn, _EPS) / 254.0
        zp = 0.5 * (mx + mn)
        bshape = (-1,) + (1,) * (x.ndim - 1)
        q = jnp.clip(
            jnp.round((x - zp.reshape(bshape)) / scale.reshape(bshape)), -127, 127
        ).astype(jnp.int8)
        return q, jnp.stack([scale, zp], axis=-1)

    def decode(self, payload, sideband, out_dtype) -> jnp.ndarray:
        # sideband is [...batch, 2]; payload may carry extra trailing row dims
        # (e.g. a [B, F, dim] oracle gather) — broadcast scale/zp over them.
        extra = payload.ndim - (sideband.ndim - 1)
        bshape = sideband.shape[:-1] + (1,) * extra
        scale = sideband[..., 0].reshape(bshape)
        zp = sideband[..., 1].reshape(bshape)
        return (payload.astype(jnp.float32) * scale + zp).astype(out_dtype)

    def payload_dtype(self, orig_dtype):
        return jnp.int8

    def sideband_row_shape(self) -> Optional[Tuple[int, ...]]:
        return (2,)


CODECS: Dict[str, Codec] = {
    "fp32": Fp32Codec(),
    "fp16": Fp16Codec(),
    "int8": Int8Codec(),
}


def get_codec(name: str) -> Codec:
    try:
        return CODECS[name]
    except KeyError:
        raise ValueError(f"unknown host-store codec {name!r}; known: {sorted(CODECS)}") from None
