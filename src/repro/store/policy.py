"""``PrecisionPolicy`` — frequency-driven host-precision assignment.

Which codec a table's host tier can afford is a statistical question, and it
is the same statistic the cache already computes (``core/freq.py``): when the
cache's capacity fraction covers most accesses, the host copy is effectively
*cold storage* — decoded rows are rare, quantization noise rarely enters the
training path, and aggressive int8 is safe.  When coverage is poor, the host
tier is on the hot path and deserves fp16 or fp32.  ML-guided tiering for
DLRM inference (arXiv 2511.08568) motivates exactly this frequency-driven
tier/precision assignment.

The policy is deterministic: coverage thresholds pick a codec per slab, and
an optional host-byte budget demotes the coldest slabs first (fp32 -> fp16
-> int8) until the encoded total fits.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.store.codec import get_codec

__all__ = ["SlabGeometry", "PrecisionPolicy"]

_LADDER = ("fp32", "fp16", "int8")  # demotion order under a host budget


@dataclasses.dataclass(frozen=True)
class SlabGeometry:
    """The static facts the policy needs about one slab's host tier."""

    name: str
    vocab: int
    dim: int
    capacity: int  # cached rows (the fast tier)
    dtype_itemsize: int = 4


def _host_bytes(g: SlabGeometry, codec_name: str) -> int:
    import jax.numpy as jnp

    c = get_codec(codec_name)
    dt = {4: jnp.float32, 2: jnp.float16}.get(g.dtype_itemsize, jnp.float32)
    return g.vocab * c.row_bytes((g.dim,), dt)


def _coverage(counts: Optional[np.ndarray], capacity: int) -> Optional[float]:
    """Access share of the ``capacity`` hottest ids (paper Fig. 2 statistic)."""
    if counts is None:
        return None
    counts = np.asarray(counts, dtype=np.float64)
    tot = counts.sum()
    if tot <= 0:
        return None
    top = np.sort(counts)[::-1][: max(int(capacity), 1)]
    return float(top.sum() / tot)


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Pick a host codec per slab from cache coverage + a host-byte budget.

    ``choose`` (one slab) applies the coverage thresholds; ``assign`` (a
    collection) additionally enforces ``host_budget_bytes`` by demoting the
    coldest slabs one precision step at a time.  Without counts the policy
    falls back to ``no_stats`` (fp16 by default: 2x savings, ~1e-3 relative
    error — safe for cold rows without any evidence of skew).
    """

    int8_coverage: float = 0.75  # cache absorbs >= 75 % of accesses -> int8
    fp16_coverage: float = 0.40
    no_stats: str = "fp16"
    host_budget_bytes: Optional[int] = None

    def choose(self, geom: SlabGeometry, counts: Optional[np.ndarray] = None) -> str:
        cov = _coverage(counts, geom.capacity)
        if cov is None:
            return self.no_stats
        if cov >= self.int8_coverage:
            return "int8"
        if cov >= self.fp16_coverage:
            return "fp16"
        return "fp32"

    def choose_arena(
        self,
        geom: SlabGeometry,
        head_capacity: int,
        counts: Optional[np.ndarray] = None,
    ) -> str:
        """Pick the *device tail* codec for a tiered arena (``arena_precision
        ="auto"``).  Same thresholds as ``choose``, but the statistic is the
        head's share of the accesses that land in the arena at all: among the
        ``capacity`` hottest ids, how much traffic do the ``head_capacity``
        hottest absorb?  When the fp32 head soaks up most resident reads, the
        encoded tail is effectively device-side cold storage and int8 is
        safe; when resident traffic is flat, keep the tail at fp16/fp32."""
        if counts is None:
            return self.no_stats
        counts = np.asarray(counts, dtype=np.float64)
        resident = np.sort(counts)[::-1][: max(int(geom.capacity), 1)]
        tot = resident.sum()
        if tot <= 0:
            return self.no_stats
        cov = float(resident[: max(int(head_capacity), 1)].sum() / tot)
        if cov >= self.int8_coverage:
            return "int8"
        if cov >= self.fp16_coverage:
            return "fp16"
        return "fp32"

    def assign(
        self,
        slabs: Sequence[SlabGeometry],
        counts: Optional[Mapping[str, np.ndarray]] = None,
        host_budget_bytes: Optional[int] = None,
    ) -> Dict[str, str]:
        """Codec per slab; deterministic, budget-aware.

        Demotion order under a budget: HIGHEST cache coverage first (the
        cache absorbs those slabs' accesses, so their host tier is the
        coldest storage and quantizes most safely — the same rationale as
        ``choose``'s thresholds), unknown-coverage slabs last, ties broken by
        name so every host derives the identical assignment; one rung of
        ``fp32 -> fp16 -> int8`` at a time.
        """
        budget = host_budget_bytes or self.host_budget_bytes
        out: Dict[str, Tuple[str, float]] = {}
        for g in slabs:
            c = counts.get(g.name) if counts else None
            cov = _coverage(c, g.capacity)
            out[g.name] = (self.choose(g, c), -1.0 if cov is None else cov)
        if budget is not None:
            geoms = {g.name: g for g in slabs}
            # best-covered (coldest host tier) slabs demote first; the -1.0
            # unknown-coverage sentinel sorts last (treated as hot)
            order = sorted(out, key=lambda n: (-out[n][1], n))
            while sum(_host_bytes(geoms[n], out[n][0]) for n in out) > budget:
                for n in order:
                    codec = out[n][0]
                    i = _LADDER.index(codec)
                    if i + 1 < len(_LADDER):
                        out[n] = (_LADDER[i + 1], out[n][1])
                        break
                else:  # everything already int8; budget is infeasible
                    need = sum(_host_bytes(geoms[n], out[n][0]) for n in out)
                    raise ValueError(
                        f"host budget {budget} B cannot hold the table set even "
                        f"at int8 (needs >= {need} B)"
                    )
        return {n: c for n, (c, _) in out.items()}
