"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/*.json."""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"

HEADERS = (
    "| cell | kind | compute s | memory s | collective s | dominant | frac of roofline | "
    "MODEL/HLO flops | HBM GB/dev | what would move the dominant term |"
)


def bottleneck_note(rec, arch, shape):
    dom = rec["dominant"]
    if dom == "memory_s":
        if rec["kind"] in ("decode",):
            return "KV-cache bytes: int8 KV / seq-sharded cache (see §Perf grok)"
        if arch in ("fm", "din", "dien", "mind", "dlrm-criteo", "dlrm-avazu"):
            return "cache bookkeeping sorts + row moves: bf16 tier, O(K) backlist (§Perf fm)"
        if arch == "gatedgcn":
            return "edge gather/scatter traffic: fuse message+aggregate, cache locality ordering"
        return "activation traffic: larger per-device batch or deeper fusion"
    if dom == "collective_s":
        return "all-gather/reduce volume: overlap, gradient compression, 2D sharding"
    return "compute-bound: near roofline; increase arithmetic intensity only"


def render(single_only=True, path=None):
    from repro.launch.model_flops import model_flops

    data = json.loads((pathlib.Path(path) if path else RESULTS / "dryrun.json").read_text())
    lines_single, lines_multi, skipped = [], [], []
    for key in sorted(data):
        rec = data[key]
        arch, shape, mesh = key.split("/")
        if rec.get("skipped"):
            if mesh == "single":
                skipped.append(f"| {arch}/{shape} | skipped — {rec['reason']} |")
            continue
        if "error" in rec:
            continue
        n_dev = rec["n_devices"]
        try:
            mf = model_flops(arch, shape) / n_dev
        except Exception:
            mf = 0.0
        ratio = mf / max(rec["flops_per_device"], 1.0)
        hbm_gb = rec["memory"]["peak_estimate_bytes"] / 1e9
        row = (
            f"| {arch}/{shape} | {rec['kind']} | {rec['compute_s']:.2e} | {rec['memory_s']:.2e} "
            f"| {rec['collective_s']:.2e} | {rec['dominant'].replace('_s','')} "
            f"| {rec['roofline_fraction']:.3f} | {ratio:.2f} | {hbm_gb:.1f} "
            f"| {bottleneck_note(rec, arch, shape)} |"
        )
        (lines_single if mesh == "single" else lines_multi).append(row)
    return lines_single, lines_multi, skipped


def dryrun_summary(path=None):
    data = json.loads((pathlib.Path(path) if path else RESULTS / "dryrun.json").read_text())
    rows = []
    for key in sorted(data):
        rec = data[key]
        if rec.get("skipped") or "error" in rec:
            continue
        m = rec["memory"]
        colls = ", ".join(f"{k}:{v['wire_bytes']/1e9:.2f}GB" for k, v in rec.get("collectives", {}).items())
        rows.append(
            f"| {key} | {m['argument_bytes']/1e9:.2f} | {m['temp_bytes']/1e9:.2f} | "
            f"{m['peak_estimate_bytes']/1e9:.2f} | {rec['flops_per_device']:.2e} | {colls or '—'} |"
        )
    return rows


if __name__ == "__main__":
    s, m, sk = render()
    print("\n".join(s))
    print("\nskipped:")
    print("\n".join(sk))
