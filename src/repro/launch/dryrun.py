import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective analysis for §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b --shape long_500k

Results stream into results/dryrun.json (incremental; completed cells are
skipped on re-run unless --force).
"""
import argparse
import json
import pathlib
import time
import traceback

import jax

from repro.configs import REGISTRY
import repro.dist.partitioning as dist
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"


def shardings_for(mesh, spec_tree):
    from jax.sharding import NamedSharding, PartitionSpec

    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def run_cell(arch_name: str, shape: str, multi_pod: bool, extra_tag: str = "",
             cell_override=None):
    """Lower + compile one cell; returns the roofline record."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    arch = REGISTRY[arch_name]
    cell = cell_override or arch.build_cell(shape, mesh.axis_names)
    if cell is None:
        return {"skipped": True, "reason": "shape inapplicable (see DESIGN.md)"}

    t0 = time.time()
    with dist.axis_rules(mesh, cell.rules):
        in_sh = shardings_for(mesh, cell.in_specs)
        fn = jax.jit(cell.step_fn, in_shardings=in_sh, donate_argnums=cell.donate)
        lowered = fn.lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    rec = roofline.analyze_compiled(compiled)
    rec.update(
        arch=arch_name, shape=shape, kind=cell.kind,
        mesh="2x16x16" if multi_pod else "16x16",
        n_devices=int(mesh.size),
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        note=cell.note + extra_tag,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(RESULTS / "dryrun.json"))
    args = ap.parse_args()

    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    archs = list(REGISTRY) if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for name in archs:
        arch = REGISTRY[name]
        shapes = arch.shapes if args.shape == "all" else [
            s for s in args.shape.split(",") if s in arch.shapes
        ]
        for shape in shapes:
            for mp in meshes:
                key = f"{name}/{shape}/{'multi' if mp else 'single'}"
                if key in results and not args.force and "error" not in results[key]:
                    print(f"[skip] {key}")
                    continue
                print(f"[run ] {key} ...", flush=True)
                t0 = time.time()
                try:
                    rec = run_cell(name, shape, mp)
                    results[key] = rec
                    if rec.get("skipped"):
                        print(f"[skip] {key}: {rec['reason']}")
                    else:
                        print(
                            f"[ ok ] {key} compile={rec['compile_s']}s "
                            f"flops/dev={rec['flops_per_device']:.3e} "
                            f"dominant={rec['dominant']} "
                            f"frac={rec['roofline_fraction']:.3f}"
                        )
                except Exception as e:
                    traceback.print_exc()
                    results[key] = {"error": f"{type(e).__name__}: {e}", "elapsed_s": time.time() - t0}
                    failures.append(key)
                out_path.write_text(json.dumps(results, indent=1, default=str))
    print(f"\ndone. {len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
