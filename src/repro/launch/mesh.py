"""Production meshes.

Single pod:  (data=16, model=16)          = 256 chips (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16)   = 512 chips

Functions, not module constants, so importing never touches jax device state.
The ``pod`` axis composes with ``data`` for batch/FSDP sharding, so the same
rule tables scale to N pods — DCN traffic is whatever lands on the ``pod``
axis (gradient all-reduce, optionally compressed via optim.compression).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "make_hybrid_mesh"]


def _make(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; Auto is the default there,
    # so on older jax the plain call is equivalent.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (benchmarks use 1..8-device slices)."""
    return _make(tuple(shape), tuple(axes))


def make_hybrid_mesh(model_shards: int, n_devices: int | None = None):
    """The hybrid-parallel (data, model) mesh for a sharded collection.

    ``model`` gets exactly ``model_shards`` devices (the shard count of a
    ``ShardedEmbeddingCollection`` must equal the model-axis size so the
    stacked state splits one shard per device); the remaining factor becomes
    ``data`` for batch/dense parallelism.  ``n_devices`` defaults to every
    local device and must be divisible by ``model_shards``.
    """
    n = n_devices if n_devices is not None else len(jax.devices())
    if model_shards < 1 or n % model_shards:
        raise ValueError(
            f"{n} devices not divisible into model={model_shards} shards"
        )
    return _make((n // model_shards, model_shards), ("data", "model"))
