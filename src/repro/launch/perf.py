import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""§Perf hillclimbing driver: named variants of the three chosen cells,
each re-lowered + re-analyzed on the single-pod mesh, streamed to
results/perf.json.

  PYTHONPATH=src python -m repro.launch.perf --exp olmoe --variant it1
  PYTHONPATH=src python -m repro.launch.perf --exp all
"""
import argparse
import dataclasses
import json
import pathlib
import time

import jax

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"


# ---------------------------------------------------------------------------
# variant builders: () -> Cell
# ---------------------------------------------------------------------------


def _olmoe_cell(rule_overrides=None, cfg_overrides=None, rules_kw=None):
    from repro.configs import olmoe_1b_7b as O
    from repro.configs.base import lm_cell
    from repro.configs.lm_common import lm_rules
    from repro.models.lm import LMModel

    cfg = dataclasses.replace(O.CONFIG, **(cfg_overrides or {}))
    rules = lm_rules(("data", "model"), "train", moe="ep", **(rules_kw or {}))
    rules.update(rule_overrides or {})
    return lm_cell("olmoe-1b-7b", "train_4k", LMModel(cfg), cfg, "train", 256, 4096, rules)


def _grok_train_cell(rule_overrides=None, cfg_overrides=None):
    from repro.configs import grok_1_314b as G
    from repro.configs.base import lm_cell
    from repro.configs.lm_common import lm_rules
    from repro.models.lm import LMModel

    cfg = dataclasses.replace(G.CONFIG, **(cfg_overrides or {}))
    rules = lm_rules(("data", "model"), "train", moe="tp", tp_kv_param=False)
    rules.update(rule_overrides or {})
    return lm_cell("grok-1-314b", "train_4k", LMModel(cfg), cfg, "train", 256, 4096, rules)


def _grok_decode_cell(rule_overrides=None, cfg_overrides=None):
    from repro.configs import grok_1_314b as G
    from repro.configs.base import lm_cell
    from repro.configs.lm_common import lm_rules
    from repro.models.lm import LMModel

    cfg = dataclasses.replace(G.CONFIG, **(cfg_overrides or {}))
    rules = lm_rules(("data", "model"), "decode", moe="tp", tp_kv_param=False)
    rules.update(rule_overrides or {})
    return lm_cell("grok-1-314b", "decode_32k", LMModel(cfg), cfg, "decode", 128, 32768, rules)


def _fm_cell(cfg_overrides=None, emb_mode="row"):
    import dataclasses as dc

    from repro.configs import fm as F
    from repro.configs import shapes as S
    from jax.sharding import PartitionSpec as P
    from repro.configs.base import recsys_cell
    from repro.models.recsys_models import FMModel

    cfg = dc.replace(F.CONFIG, **(cfg_overrides or {}))
    model = FMModel(cfg)
    specs = model.input_specs(cfg.batch_size)
    in_specs = {"sparse": P(("data",), None), "label": P(("data",))}
    return recsys_cell("fm", "train_batch", model, "train", specs, in_specs,
                       emb_mode, {"batch": ("data",), "seq": None})


EXPERIMENTS = {
    # most collective-bound baseline cell
    "olmoe": {
        "it1_local_dispatch": lambda: _olmoe_cell(
            rule_overrides={"exp_dp": ("data",)},
            cfg_overrides={"moe_dp_groups": 16}),
        "it2_local_dispatch_cf1": lambda: _olmoe_cell(
            rule_overrides={"exp_dp": ("data",)},
            cfg_overrides={"moe_dp_groups": 16, "capacity_factor": 1.0}),
        "it3_local_no_fsdp": lambda: _olmoe_cell(
            rule_overrides={"exp_dp": ("data",)},
            cfg_overrides={"moe_dp_groups": 16}, rules_kw={"fsdp": False}),
        "it4_shard_map": lambda: _olmoe_cell(
            cfg_overrides={"moe_impl": "shard_map"}),
        "it5_shard_map_no_fsdp": lambda: _olmoe_cell(
            cfg_overrides={"moe_impl": "shard_map"}, rules_kw={"fsdp": False}),
    },
    # bonus: the same lever on the heaviest collective cell (grok train)
    "grok_train": {
        "it1_local_dispatch": lambda: _grok_train_cell(
            rule_overrides={"exp_dp": ("data",)},
            cfg_overrides={"moe_dp_groups": 16}),
        "it2_shard_map": lambda: _grok_train_cell(
            cfg_overrides={"moe_impl": "shard_map"}),
    },
    # worst-roofline-fraction family (memory-bound decode)
    "grok": {
        "it1_int8_kv": lambda: _grok_decode_cell(cfg_overrides={"kv_cache_int8": True}),
        "it2_seq_shard_cache": lambda: _grok_decode_cell(
            rule_overrides={"kv_seq": "model", "kv_heads_eff": None},
            cfg_overrides={"kv_repeat": 1}),
        "it3_int8_plus_seqshard": lambda: _grok_decode_cell(
            rule_overrides={"kv_seq": "model", "kv_heads_eff": None},
            cfg_overrides={"kv_repeat": 1, "kv_cache_int8": True}),
    },
    # most paper-representative cell (cached-embedding train step)
    "fm": {
        "it1_bf16_table": lambda: _fm_cell(
            cfg_overrides={"emb_dtype": __import__("jax.numpy", fromlist=["bfloat16"]).bfloat16}),
        "it2_inverse_protect": lambda: _fm_cell(
            cfg_overrides={"protect_via_inverse": True}),
        "it3_tight_unique": lambda: _fm_cell(
            cfg_overrides={"max_unique_per_step": 1 << 20}),
        "it4_combined": lambda: _fm_cell(
            cfg_overrides={
                "emb_dtype": __import__("jax.numpy", fromlist=["bfloat16"]).bfloat16,
                "protect_via_inverse": True,
                "max_unique_per_step": 1 << 20,
            }),
    },
}


def run_variant(exp: str, name: str, builder):
    import repro.dist.partitioning as dist
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.launch import roofline
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=False)
    cell = builder()
    t0 = time.time()
    with dist.axis_rules(mesh, cell.rules):
        in_sh = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), cell.in_specs,
            is_leaf=lambda x: isinstance(x, PartitionSpec))
        compiled = jax.jit(cell.step_fn, in_shardings=in_sh,
                           donate_argnums=cell.donate).lower(*cell.args).compile()
    rec = roofline.analyze_compiled(compiled)
    rec.update(experiment=exp, variant=name, compile_s=round(time.time() - t0, 1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default="all")
    ap.add_argument("--variant", default="all")
    ap.add_argument("--out", default=str(RESULTS / "perf.json"))
    args = ap.parse_args()
    out_path = pathlib.Path(args.out)
    results = json.loads(out_path.read_text()) if out_path.exists() else {}
    for exp, variants in EXPERIMENTS.items():
        if args.exp != "all" and args.exp != exp:
            continue
        for name, builder in variants.items():
            if args.variant != "all" and args.variant not in name:
                continue
            key = f"{exp}/{name}"
            print(f"[run] {key}", flush=True)
            try:
                rec = run_variant(exp, name, builder)
                results[key] = rec
                print(f"[ ok] {key}: compute={rec['compute_s']:.3e} "
                      f"memory={rec['memory_s']:.3e} coll={rec['collective_s']:.3e} "
                      f"dominant={rec['dominant']} frac={rec['roofline_fraction']:.3f}")
            except Exception as e:
                import traceback
                traceback.print_exc()
                results[key] = {"error": str(e)}
            out_path.write_text(json.dumps(results, indent=1, default=str))


if __name__ == "__main__":
    main()
