"""Analytic MODEL_FLOPS per (arch x shape) — the *useful* flops a perfect
implementation would execute, used for the §Roofline ratio
MODEL_FLOPS / HLO_FLOPs (catches remat recompute, dispatch waste, padding).

Conventions: train = 3x forward (bwd = 2x fwd; remat overhead is exactly what
the ratio should expose, so it is NOT included here); prefill/serve = 1x
forward; decode = one-token forward incl. attention reads over the KV cache.
Causal attention scores count the triangle (x0.5).  All values are GLOBAL
flops; divide by chips for per-device.
"""
from __future__ import annotations

from typing import Dict

from repro.configs import REGISTRY
from repro.configs.lm_common import SHAPE_DEFS as LM_SHAPES


def _lm_fwd_flops(cfg, tokens: int, seq: int, decode: bool = False) -> float:
    d, hd = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    L = cfg.n_layers
    proj = 2 * d * (hq * hd + 2 * hkv * hd) + 2 * hq * hd * d  # qkv + o
    if cfg.ffn == "moe":
        ffn = 2 * 3 * d * cfg.d_ff * cfg.top_k + 2 * d * cfg.n_experts
    else:
        ffn = 2 * 3 * d * cfg.d_ff
    # attention context per token
    n_local = sum(1 for k in (cfg.pattern * L)[:L] if k == "local")
    n_global = L - n_local
    if decode:
        ctx_g, ctx_l = seq, min(cfg.window, seq)
        attn_per_layer_g = 4 * ctx_g * hq * hd
        attn_per_layer_l = 4 * ctx_l * hq * hd
    else:
        attn_per_layer_g = 4 * seq * hq * hd * 0.5
        attn_per_layer_l = 4 * min(cfg.window, seq) * hq * hd * 0.75
    attn = n_global * attn_per_layer_g + n_local * attn_per_layer_l
    vocab = 2 * d * cfg.vocab
    return tokens * (L * (proj + ffn) + attn + vocab)


def _gnn_fwd_flops(n_nodes: int, n_edges: int, d: int, layers: int, d_feat: int) -> float:
    dense = 5 * 2 * n_nodes * d * d  # A,B,C,U,V
    edges = 12 * n_edges * d  # gate, messages, normalization
    return layers * (dense + edges) + 2 * n_nodes * d_feat * d


def _gru_flops(tokens: int, seq: int, d_in: int, d_h: int) -> float:
    return tokens * seq * 2 * 3 * (d_in * d_h + d_h * d_h)


def model_flops(arch: str, shape: str) -> float:
    """GLOBAL useful flops for the cell (0.0 = not modelled)."""
    a = REGISTRY[arch]
    if a.family == "lm":
        import importlib

        mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
        cfg = mod.CONFIG
        kind, batch, seq = LM_SHAPES[shape]
        if kind == "train":
            return 3 * _lm_fwd_flops(cfg, batch * seq, seq)
        if kind == "prefill":
            return _lm_fwd_flops(cfg, batch * seq, seq)
        return _lm_fwd_flops(cfg, batch, seq, decode=True)

    if arch == "gatedgcn":
        from repro.configs.gatedgcn import SHAPE_CFG

        kind, n, e, d_feat, n_cls, task, _ = SHAPE_CFG[shape]
        return 3 * _gnn_fwd_flops(n, e, 70, 16, d_feat)

    if arch.startswith("dlrm"):
        import importlib

        mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
        c = mod.CONFIG
        f1 = len(c.vocab_sizes) + 1
        bot = 2 * sum(a * b for a, b in zip((c.n_dense,) + c.bottom_mlp[:-1], c.bottom_mlp))
        inter = 2 * f1 * f1 * c.embed_dim
        top_in = c.embed_dim + f1 * (f1 - 1) // 2
        top = 2 * sum(a * b for a, b in zip((top_in,) + c.top_mlp, c.top_mlp + (1,)))
        return 3 * c.batch_size * (bot + inter + top)

    # recsys
    from repro.configs import din as din_mod, dien as dien_mod, fm as fm_mod, mind as mind_mod
    from repro.configs.shapes import N_CANDIDATES, RECSYS_DEFS

    kind, batch = RECSYS_DEFS[shape]
    n = N_CANDIDATES if kind == "retrieval" else batch
    mult = 3 if kind == "train" else 1

    if arch == "fm":
        c = fm_mod.CONFIG
        f, d = len(c.vocab_sizes), c.embed_dim
        return mult * n * (4 * f * d)
    if arch in ("din", "dien"):
        c = din_mod.CONFIG if arch == "din" else dien_mod.CONFIG
        d, t = c.embed_dim, c.seq_len
        attn_in = 8 * d
        attn = t * 2 * (attn_in * 80 + 80 * 40 + 40)
        mlp = 2 * (5 * d * 200 + 200 * 80 + 80)
        if arch == "dien":
            gru = _gru_flops(1, t, 2 * d, c.gru_dim) + _gru_flops(1, t, c.gru_dim, c.gru_dim)
            per = gru + attn + mlp
            if kind == "retrieval":
                per = _gru_flops(1, t, 2 * d, c.gru_dim) / n + t * 2 * c.gru_dim * 2  # shared GRU
        else:
            per = attn + mlp
        return mult * n * per
    if arch == "mind":
        c = mind_mod.CONFIG
        d, t, k = c.embed_dim, c.seq_len, c.n_interests
        caps = 2 * t * d * d + c.capsule_iters * (2 * k * t * d * 2)
        if kind == "retrieval":
            return caps + n * 2 * k * d
        return mult * n * (caps + 2 * k * d)
    return 0.0


def all_model_flops() -> Dict[str, float]:
    out = {}
    for name, arch in REGISTRY.items():
        for shape in arch.shapes:
            try:
                out[f"{name}/{shape}"] = model_flops(name, shape)
            except Exception:
                out[f"{name}/{shape}"] = 0.0
    return out
