"""Roofline accounting from post-optimization HLO text (per-device program).

Why not ``compiled.cost_analysis()``:
  * XLA counts every ``while`` body ONCE (verified in tests) — scanned models
    (layer-group scans, chunked attention, the transmitter's bounded-buffer
    loop) are undercounted by their trip counts;
  * "bytes accessed" charges gathers/scatters the FULL operand, overcounting
    cache/embedding programs (the paper's core!) by orders of magnitude.

This analyzer walks the computation graph:
  * ``while`` bodies x ``known_trip_count`` (XLA annotates it; default 1);
  * per-instruction byte model at fusion granularity (one HBM round trip per
    buffer — the TPU cost model): fusions charge result + params, EXCEPT
    params consumed only by ``gather`` (charged at touched-rows size) and
    scatter-rooted fusions (result charged at 3x updates, read-modify-write);
  * flops: dot = 2 * out * K (contracting dims); CPU-backend oneDNN matmul
    custom-calls estimated via K = sqrt(lhs*rhs/out / batch); elementwise =
    output elements; sort = n log n;
  * collectives: ring-model wire bytes by kind and group size, x trip count.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_TRIP_RE = re.compile(r"known_trip_count[^\d]*(\d+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*(.*?)\s*\{\s*$")
# result type: either a (tuple type ...) — may contain /*index=N*/ comments
# but never nested parens — or a single scalar/array type token.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]{},]+))\s+([\w\-]+)\((.*)$"
)
_MATMUL_TARGETS = ("matmul", "dot", "gemm", "conv")


def _parse_shapes(text: str) -> List[Tuple[str, List[int]]]:
    return [
        (dt, [int(d) for d in dims.split(",") if d])
        for dt, dims in _SHAPE_RE.findall(text)
        if dt in _DTYPE_BYTES
    ]


def _bytes_of_type(text: str) -> int:
    return sum(
        _DTYPE_BYTES[dt] * math.prod(dims) for dt, dims in _parse_shapes(text)
    )


def _elems_of_type(text: str) -> int:
    return sum(math.prod(dims) for _, dims in _parse_shapes(text))


def _split_top(s: str) -> List[str]:
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        parts.append(tail)
    return parts


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    operands: List[str]  # operand instruction/param names (no %)
    rest: str  # text after the operand list (attributes)


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]  # param name -> type
    param_order: List[str]
    instrs: List[Instr]
    types: Dict[str, str]  # every defined name -> result type
    root: Optional[str] = None


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in hlo.splitlines():
        h = _HEADER_RE.match(line)
        if h:
            is_entry, name, params_text, _ = h.groups()
            params: Dict[str, str] = {}
            order: List[str] = []
            for p in _split_top(params_text):
                m = re.match(r"%?([\w.\-]+)\s*:\s*(.*)", p)
                if m:
                    params[m.group(1)] = m.group(2)
                    order.append(m.group(1))
            cur = Computation(name, params, order, [], dict(params))
            comps[name] = cur
            if is_entry:
                entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, op, tail = m.groups()
        # split operand list from attributes
        depth, end = 1, len(tail)
        for i, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        opnds_text, rest = tail[:end], tail[end + 1:]
        operands = []
        for part in _split_top(opnds_text):
            mm = re.search(r"%([\w.\-]+)\s*$", part)
            if mm:
                operands.append(mm.group(1))
        ins = Instr(name, rtype, op, operands, rest)
        cur.instrs.append(ins)
        cur.types[name] = rtype
        if "ROOT" in line:
            cur.root = name
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.wire_bytes += o.wire_bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f, self.wire_bytes * f,
                    {k: v * f for k, v in self.coll.items()})


def _group_size(text: str) -> int:
    m = _GROUPS_IOTA_RE.search(text)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(text)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 2


def _wire(kind: str, out_bytes: float, g: int) -> float:
    if kind == "all-gather":
        return out_bytes * (g - 1) / max(g, 1)
    if kind == "all-reduce":
        return 2 * out_bytes * (g - 1) / max(g, 1)
    if kind == "reduce-scatter":
        return out_bytes * (g - 1)
    if kind == "all-to-all":
        return out_bytes * (g - 1) / max(g, 1)
    return out_bytes  # collective-permute


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out = _elems_of_type(ins.result_type)
    m = _CONTRACT.search(ins.rest)
    lhs_type = comp.types.get(ins.operands[0], "") if ins.operands else ""
    lhs = _parse_shapes(lhs_type)
    if m and lhs:
        k = 1
        for d in (int(x) for x in m.group(1).split(",") if x):
            dims = lhs[0][1]
            if d < len(dims):
                k *= dims[d]
        return 2.0 * out * k
    return 2.0 * out


def _matmul_custom_flops(ins: Instr, comp: Computation) -> float:
    out = max(_elems_of_type(ins.result_type), 1)
    shp = []
    for o in ins.operands[:2]:
        s = _parse_shapes(comp.types.get(o, ""))
        shp.append(math.prod(s[0][1]) if s else 1)
    if len(shp) < 2:
        return 2.0 * out
    lhs_e, rhs_e = max(shp[0], 1), max(shp[1], 1)
    # batch detection: shared leading dims across all three
    out_dims = _parse_shapes(ins.result_type)
    od = out_dims[0][1] if out_dims else []
    lhs_dims = _parse_shapes(comp.types.get(ins.operands[0], ""))
    ld = lhs_dims[0][1] if lhs_dims else []
    b = 1
    for i in range(min(len(od), len(ld)) - 2):
        if od[i] == ld[i]:
            b *= od[i]
        else:
            break
    k2 = lhs_e * rhs_e / max(out, 1) / max(b, 1)
    return 2.0 * out * math.sqrt(max(k2, 1.0))


class Analyzer:
    def __init__(self, comps: Dict[str, Computation]):
        self.comps = comps
        self.memo: Dict[str, Cost] = {}

    def computation_cost(self, name: str) -> Cost:
        if name in self.memo:
            return self.memo[name]
        self.memo[name] = Cost()  # cycle guard
        comp = self.comps.get(name)
        if comp is None:
            return Cost()
        total = Cost()
        for ins in comp.instrs:
            total += self.instr_cost(ins, comp)
        self.memo[name] = total
        return total

    # -- fusion internals ---------------------------------------------------
    def _fusion_param_usage(self, fname: str):
        """param index -> ('gather', touched_bytes) | ('scatter',) | ('dense',)."""
        comp = self.comps.get(fname)
        if comp is None:
            return {}, False
        usage: Dict[str, List[Tuple[str, Instr]]] = {p: [] for p in comp.params}
        for ins in comp.instrs:
            for i, o in enumerate(ins.operands):
                if o in usage:
                    usage[o].append((ins.op, ins, i) if False else (ins.op, ins))
        # does a scatter/dynamic-update-slice feed the root?
        root_scatterish = False
        if comp.root:
            seen = {comp.root}
            frontier = [comp.root]
            while frontier:
                n = frontier.pop()
                ins = next((i for i in comp.instrs if i.name == n), None)
                if ins is None:
                    continue
                if ins.op in ("scatter", "dynamic-update-slice", "select-and-scatter"):
                    root_scatterish = True
                    break
                if ins.op in ("bitcast", "tuple", "copy", "transpose", "reshape", "get-tuple-element"):
                    for o in ins.operands:
                        if o not in seen:
                            seen.add(o)
                            frontier.append(o)
        return usage, root_scatterish

    def _fusion_cost(self, ins: Instr, comp: Computation) -> Cost:
        c = Cost()
        called = _CALLS_RE.search(ins.rest)
        fname = called.group(1) if called else None
        fcomp = self.comps.get(fname) if fname else None
        out_bytes = _bytes_of_type(ins.result_type)

        if fcomp is None:
            c.bytes += out_bytes + self._operand_bytes(ins, comp)
            c.flops += _elems_of_type(ins.result_type)
            return c

        usage, root_scatterish = self._fusion_param_usage(fname)

        # inner flops (+ nested control flow, e.g. while inside a call)
        scatter_updates = 0
        for fin in fcomp.instrs:
            if fin.op == "dot":
                c.flops += _dot_flops(fin, fcomp)
            elif fin.op == "custom-call" and any(t in fin.rest for t in _MATMUL_TARGETS):
                c.flops += _matmul_custom_flops(fin, fcomp)
            elif fin.op == "while":
                c += self._while_cost(fin)
            elif fin.op in ("scatter", "dynamic-update-slice", "select-and-scatter"):
                upd = fin.operands[2] if fin.op == "scatter" and len(fin.operands) > 2 else (
                    fin.operands[1] if len(fin.operands) > 1 else None
                )
                if upd:
                    scatter_updates += _bytes_of_type(fcomp.types.get(upd, ""))
            else:
                c.flops += _elems_of_type(fin.result_type)

        # result write
        if root_scatterish:
            c.bytes += 3 * max(scatter_updates, 1)  # RMW of touched rows
        else:
            c.bytes += out_bytes

        # param reads.  Row-granular accesses — gather and dynamic-slice with
        # the param as the sliced operand — charge touched bytes; scatter /
        # dynamic-update-slice writes into the param are covered by the RMW
        # result charge.  A param consumed ONLY by such ops (XLA lowers a
        # donated scatter to a rolled while loop whose body slices one row and
        # dynamic-update-slices it back) must not be charged its full size.
        for pname in fcomp.param_order:
            ptype = fcomp.params[pname]
            uses = usage.get(pname, [])
            reads = [
                (op, u)
                for op, u in uses
                if op in ("gather", "dynamic-slice") and u.operands and u.operands[0] == pname
            ]
            writes = [
                (op, u)
                for op, u in uses
                if op in ("scatter", "dynamic-update-slice", "select-and-scatter")
                and u.operands
                and u.operands[0] == pname
            ]
            if uses and len(reads) + len(writes) == len(uses):
                touched = sum(_bytes_of_type(u.result_type) for _, u in reads)
                c.bytes += min(touched, _bytes_of_type(ptype))
            else:
                c.bytes += _bytes_of_type(ptype)
        return c

    def _operand_bytes(self, ins: Instr, comp: Computation) -> int:
        return sum(_bytes_of_type(comp.types.get(o, "")) for o in ins.operands)

    def _while_cost(self, ins: Instr) -> Cost:
        trips = 1
        tm = _TRIP_RE.search(ins.rest)
        if tm:
            trips = int(tm.group(1))
        c = Cost()
        b = _BODY_RE.search(ins.rest)
        if b:
            c += self.computation_cost(b.group(1)).scaled(trips)
        cond = _COND_RE.search(ins.rest)
        if cond:
            c += self.computation_cost(cond.group(1)).scaled(trips)
        return c

    def instr_cost(self, ins: Instr, comp: Computation) -> Cost:
        op = ins.op
        c = Cost()
        if op in ("parameter", "constant", "tuple", "get-tuple-element", "bitcast",
                  "after-all", "partition-id", "replica-id", "copy-start", "copy-done"):
            return c
        if op == "while":
            return self._while_cost(ins)
        if op == "call":
            m = _TOAPPLY_RE.search(ins.rest) or _CALLS_RE.search(ins.rest)
            if m:
                c += self.computation_cost(m.group(1))
            return c
        if op == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}", ins.rest)
            if branches:
                costs = [self.computation_cost(b.strip().lstrip("%"))
                         for b in branches[0].split(",")]
                if costs:
                    c += max(costs, key=lambda x: x.flops + x.bytes)
            return c
        if op == "fusion":
            return self._fusion_cost(ins, comp)

        out_bytes = _bytes_of_type(ins.result_type)
        out_elems = _elems_of_type(ins.result_type)
        kind = op.replace("-start", "")
        if kind in _COLL_KINDS:
            g = _group_size(ins.rest)
            w = _wire(kind, out_bytes, g)
            c.wire_bytes += w
            c.coll[kind] = c.coll.get(kind, 0.0) + w
            c.bytes += 2 * out_bytes
            return c
        if op.endswith("-done") or op.endswith("-update"):
            return c
        if op == "dot":
            c.flops += _dot_flops(ins, comp)
            c.bytes += out_bytes + self._operand_bytes(ins, comp)
            return c
        if op == "custom-call":
            if any(t in ins.rest for t in _MATMUL_TARGETS):
                c.flops += _matmul_custom_flops(ins, comp)
            c.bytes += out_bytes + self._operand_bytes(ins, comp)
            return c
        if op == "gather":
            idx = _bytes_of_type(comp.types.get(ins.operands[1], "")) if len(ins.operands) > 1 else 0
            c.bytes += 2 * out_bytes + idx
            return c
        if op in ("scatter", "select-and-scatter"):
            upd = _bytes_of_type(comp.types.get(ins.operands[2], "")) if len(ins.operands) > 2 else out_bytes
            idx = _bytes_of_type(comp.types.get(ins.operands[1], "")) if len(ins.operands) > 1 else 0
            c.bytes += 3 * upd + idx
            c.flops += _elems_of_type(comp.types.get(ins.operands[2], "")) if len(ins.operands) > 2 else 0
            return c
        if op == "dynamic-slice":
            c.bytes += 2 * out_bytes
            return c
        if op == "dynamic-update-slice":
            upd = _bytes_of_type(comp.types.get(ins.operands[1], "")) if len(ins.operands) > 1 else out_bytes
            c.bytes += 3 * upd
            return c
        if op == "sort":
            n = max(out_elems, 2)
            c.flops += n * math.log2(n)
            c.bytes += 2 * (out_bytes + self._operand_bytes(ins, comp))
            return c
        if op in ("reduce", "reduce-window", "map", "select-and-scatter"):
            c.flops += self._operand_bytes(ins, comp) // 4 + out_elems
            c.bytes += out_bytes + self._operand_bytes(ins, comp)
            return c
        c.flops += out_elems
        c.bytes += out_bytes + self._operand_bytes(ins, comp)
        return c


def analyze_hlo(hlo_text: str) -> Cost:
    comps, entry = parse_computations(hlo_text)
    if entry is None:
        return Cost()
    # only the entry's reachable graph is charged; fusion computations are
    # accounted at their call sites.
    return Analyzer(comps).computation_cost(entry)
