"""Training launcher: ``--arch <id>`` selects a registry architecture and runs
the fault-tolerant trainer on synthetic data (CPU-scale shapes by default;
the production mesh path is exercised by ``launch.dryrun``).

  PYTHONPATH=src python -m repro.launch.train --arch dlrm-criteo --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch din --steps 20
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import graphs, synth
from repro.train.trainer import PipelinedTrainer, Trainer, TrainerConfig


def cache_policy(name):
    """CLI string -> ``core.Policy`` (None passes the model default through)."""
    from repro.core.policies import Policy

    return Policy(name) if name else None


def _recsys_runner(arch: str, batch: int, host_precision: str = "fp32",
                   model_shards: int = 0, policy=None,
                   replicate_top_k: int = 0, exchange_codec: str = "fp32",
                   max_routed_per_shard: int = 0,
                   arena_precision: str = "fp32",
                   use_pallas_plan: bool = False, chunk_rows: int = 0):
    if model_shards and not arch.startswith("dlrm"):
        raise SystemExit(f"--model-shards is wired for dlrm archs; {arch} "
                         f"builds an unsharded collection")
    if (replicate_top_k or exchange_codec != "fp32"
            or max_routed_per_shard) and not model_shards:
        raise SystemExit("--replicate-top-k / --exchange-codec / "
                         "--max-routed-per-shard shape the sharded exchange; "
                         "they need --model-shards >= 1")
    if arch.startswith("dlrm"):
        from repro.models.dlrm import DLRM, DLRMConfig

        cfg = DLRMConfig(vocab_sizes=(100_000, 50_000, 20_000), embed_dim=32,
                         batch_size=batch, cache_ratio=0.02, lr=0.3,
                         bottom_mlp=(64, 32), top_mlp=(64,),
                         host_precision=host_precision,
                         arena_precision=arena_precision,
                         use_pallas_plan=use_pallas_plan, chunk_rows=chunk_rows,
                         model_shards=model_shards, policy=policy,
                         replicate_top_k=replicate_top_k,
                         exchange_codec=exchange_codec,
                         max_routed_per_shard=max_routed_per_shard)
        model = DLRM(cfg)
        spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)
        make = lambda s: {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, batch, 0, s).items()}
    elif arch == "fm":
        from repro.models.recsys_models import FMConfig, FMModel

        cfg = FMConfig(vocab_sizes=(100_000,) * 6, embed_dim=10, batch_size=batch,
                       cache_ratio=0.02, host_precision=host_precision,
                       arena_precision=arena_precision, policy=policy,
                       use_pallas_plan=use_pallas_plan, chunk_rows=chunk_rows)
        model = FMModel(cfg)
        spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes)
        make = lambda s: {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, batch, 0, s).items()}
    elif arch in ("din", "dien", "mind"):
        from repro.models.recsys_models import (DIENConfig, DIENModel, DINConfig,
                                                DINModel, MINDConfig, MINDModel)

        if arch == "mind":
            cfg = MINDConfig(n_items=200_000, n_users=20_000, embed_dim=32,
                             seq_len=50, batch_size=batch, cache_ratio=0.05,
                             host_precision=host_precision,
                             arena_precision=arena_precision, policy=policy,
                             use_pallas_plan=use_pallas_plan,
                             chunk_rows=chunk_rows)
            model = MINDModel(cfg)
            make = lambda s: {k: jnp.asarray(v) for k, v in synth.recsys_batch(
                cfg.n_items, cfg.n_users, cfg.seq_len, batch, 0, s).items()}
        else:
            kw = dict(n_items=200_000, n_cates=20_000, n_users=20_000, embed_dim=18,
                      seq_len=50, batch_size=batch, cache_ratio=0.05,
                      host_precision=host_precision,
                      arena_precision=arena_precision, policy=policy,
                      use_pallas_plan=use_pallas_plan, chunk_rows=chunk_rows)
            cfg = DINConfig(**kw) if arch == "din" else DIENConfig(gru_dim=36, **kw)
            model = (DINModel if arch == "din" else DIENModel)(cfg)
            make = lambda s: {k: jnp.asarray(v) for k, v in synth.recsys_batch(
                cfg.n_items, cfg.n_users, cfg.seq_len, batch, 0, s, n_cates=cfg.n_cates).items()}
    else:
        raise ValueError(arch)

    return model, make, model.flush


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--pipeline-depth", type=int, default=0,
                    help="0 = serial; k >= 1 = pipelined groups of k steps per "
                         "merged cache plan (collection-backed archs only)")
    ap.add_argument("--host-precision", default="fp32",
                    choices=["fp32", "fp16", "int8", "auto"],
                    help="host-tier embedding storage codec: fp32 = bit-exact "
                         "pre-store behavior; fp16/int8 shrink host bytes and "
                         "host<->device traffic; auto = PrecisionPolicy from "
                         "frequency stats (recsys archs only)")
    ap.add_argument("--arena-precision", default="fp32",
                    choices=["fp32", "fp16", "int8", "auto"],
                    help="device-arena (fast-tier) codec: fp32 = raw bit-exact "
                         "arena (pre-tiering behavior); fp16/int8 tier the "
                         "arena — the hot head stays fp32, the cold resident "
                         "tail stores encoded, stretching the same HBM over "
                         "2-4x more resident rows; auto = PrecisionPolicy "
                         "from head coverage (recsys archs only)")
    ap.add_argument("--model-shards", type=int, default=0,
                    help="0 = single-device collection; N >= 1 = hybrid "
                         "parallel: cached embedding slabs shard over N "
                         "model-axis shards, each with its own cache arena "
                         "and HostStore slice (dlrm archs; run under a mesh "
                         "whose model axis has N devices, or on one device "
                         "for functional testing)")
    ap.add_argument("--replicate-top-k", type=int, default=0,
                    help="hybrid parallel: K hottest ranks per cached slab "
                         "live in a replicated arena on every shard — their "
                         "lanes skip the all-to-all entirely (0 = off, "
                         "bit-identical layout to pre-replication)")
    ap.add_argument("--exchange-codec", default="fp32",
                    choices=["fp32", "fp16", "int8"],
                    help="hybrid parallel: codec for the routed row-leg of "
                         "the shard exchange; fp32 = bit-exact, fp16/int8 "
                         "shrink the cross-shard wire 2x/~4x")
    ap.add_argument("--max-routed-per-shard", type=int, default=0,
                    help="hybrid parallel: static per-shard plan-width bound "
                         "(0 = full-width planning).  Bounds the per-shard "
                         "cache-plan cost so planning stops scaling with the "
                         "shard count; too tight a bound raises through the "
                         "uniq_overflows guard instead of dropping lanes")
    ap.add_argument("--use-pallas-plan", action="store_true",
                    help="route cache planning through the bounded-top-K / "
                         "fused-prepare kernels (kernels/cache_ops): no "
                         "capacity-sized sort in the plan hot path.  "
                         "Bit-identical to the default route; Pallas lowers "
                         "on TPU/GPU, XLA references elsewhere (recsys archs "
                         "only)")
    ap.add_argument("--chunk-rows", type=int, default=0,
                    help="0 = scattered-row host staging (default); N = "
                         "stage host<->device embedding traffic in "
                         "contiguous N-row chunks (the paper's chunk-based "
                         "cache manager).  Bit-identical either way; values "
                         "that do not divide the table fall back to rows "
                         "(recsys archs only)")
    ap.add_argument("--cache-policy", default=None,
                    choices=["freq_lfu", "lru", "runtime_lfu", "uvm_row"],
                    help="cache eviction policy (core.policies.Policy): "
                         "freq_lfu = the paper's static frequency rank "
                         "(default), lru / uvm_row = recency, runtime_lfu = "
                         "online counters (recsys archs only)")
    ap.add_argument("--refresh-interval", type=int, default=0,
                    help="0 = static frequency ranking (the paper); N = "
                         "adaptive frequency engine: re-rank cached slabs "
                         "from online decayed counters every N steps "
                         "(pipelined runs refresh at group boundaries).  "
                         "Pure reindexing: fp32 losses are bit-identical "
                         "with or without it (recsys archs only)")
    ap.add_argument("--obs-dir", default=None,
                    help="observability output directory: streams per-step "
                         "JSONL (exact counters, loss, stage spans, the "
                         "step-time histogram) to <dir>/train.jsonl and a "
                         "Chrome trace to <dir>/train.trace.json; render "
                         "with `python -m repro.obs.report <dir>/train.jsonl`")
    ap.add_argument("--obs-annotate", action="store_true",
                    help="also enter jax.profiler.TraceAnnotation per stage "
                         "span so device-timeline captures carry the same "
                         "stage names")
    ap.add_argument("--history-limit", type=int, default=0,
                    help="0 = keep full in-memory history (legacy); N = keep "
                         "only the last N step records in memory (the full "
                         "stream is on disk when --obs-dir is set)")
    args = ap.parse_args()

    if args.arch == "gatedgcn":
        from repro.models.gatedgcn import GatedGCNConfig, GatedGCNModel

        model = GatedGCNModel(GatedGCNConfig(d_feat=32, n_classes=8, n_layers=8, d_hidden=32))
        indptr, indices, _ = graphs.random_graph_csr(20_000, 100_000, 0)
        feats = np.random.default_rng(0).normal(size=(20_000, 32)).astype(np.float32)
        labels = (feats[:, 0] > 0).astype(np.int32)
        make = lambda s: {k: jnp.asarray(v) for k, v in graphs.sampled_batch(
            indptr, indices, feats, labels, 256, (10, 5), 0, s).items()}
        flush = None
    elif args.arch in ("grok-1-314b", "olmoe-1b-7b", "gemma3-27b", "smollm-360m", "internlm2-20b"):
        import importlib

        from repro.models.lm import LMModel

        mod = importlib.import_module(f"repro.configs.{args.arch.replace('-', '_')}")
        model = LMModel(mod.SMOKE, lr=1e-3)  # reduced config for CPU training
        make = lambda s: {k: jnp.asarray(v) for k, v in synth.seq_batch(
            mod.SMOKE.vocab, 8, 64, 0, s).items()}
        flush = None
    else:
        model, make, flush = _recsys_runner(args.arch, args.batch,
                                            args.host_precision, args.model_shards,
                                            policy=cache_policy(args.cache_policy),
                                            replicate_top_k=args.replicate_top_k,
                                            exchange_codec=args.exchange_codec,
                                            max_routed_per_shard=args.max_routed_per_shard,
                                            arena_precision=args.arena_precision,
                                            use_pallas_plan=args.use_pallas_plan,
                                            chunk_rows=args.chunk_rows)

    if args.cache_policy and not hasattr(model, "collection"):
        raise SystemExit(f"--cache-policy needs a collection-backed arch; "
                         f"{args.arch} has no embedding cache")
    refresh_fn = None
    if args.refresh_interval:
        if not hasattr(model, "refresh"):
            raise SystemExit(f"--refresh-interval needs a collection-backed "
                             f"arch; {args.arch} has no cached slabs to re-rank")
        refresh_fn = model.refresh
    tc = TrainerConfig(max_steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=25,
                       pipeline_depth=args.pipeline_depth,
                       refresh_interval=args.refresh_interval or None,
                       obs_dir=args.obs_dir, obs_annotate=args.obs_annotate,
                       history_limit=args.history_limit or None)
    kw = dict(
        init_fn=lambda: model.init(jax.random.PRNGKey(0)),
        make_batch=make,
        flush_fn=flush,
        refresh_fn=refresh_fn,
        on_straggler=lambda s, dt: print(f"[straggler] step {s}: {dt*1e3:.0f} ms"),
    )
    if args.pipeline_depth > 0:
        if not hasattr(model, "plan_step"):
            raise SystemExit(f"--pipeline-depth needs a collection-backed arch; "
                             f"{args.arch} has no split plan/compute step")
        # compute/apply consume the state they are passed, so donating arg 0
        # lets XLA update the cache arena in place instead of double-buffering
        # it.  plan_fn must NOT donate: planning reads the same state the
        # overlapped compute is still using.
        trainer = PipelinedTrainer(
            tc,
            plan_fn=jax.jit(model.plan_step),
            compute_fn=jax.jit(model.compute_step, donate_argnums=(0,)),
            apply_fn=jax.jit(model.apply_step, donate_argnums=(0,)),
            **kw,
        )
    else:
        trainer = Trainer(
            tc, step_fn=jax.jit(model.train_step, donate_argnums=(0,)), **kw
        )
    trainer.run()
    h = trainer.history
    # history may be trimmed to a tail (--history-limit): report real steps
    print(f"\narch={args.arch} steps={h[-1]['step'] + 1} "
          f"loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f}")
    if "hit_rate" in h[-1]:
        print(f"cache hit rate: {h[-1]['hit_rate']:.1%}")
    if args.refresh_interval and "refresh_swaps" in h[-1]:
        print(f"adaptive refresh: {h[-1]['refresh_swaps']:.0f} rank swaps, "
              f"{h[-1]['refresh_rows_moved']:.0f} slow-tier rows moved, "
              f"window hit rate {h[-1].get('window_hit_rate', 0.0):.1%}")
    if hasattr(model, "collection"):
        db = model.collection.device_bytes()
        print(f"host tier ({args.host_precision}): {db['slow_tier_bytes']/1e6:.1f} MB "
              f"(saved {db['host_bytes_saved']/1e6:.1f} MB vs fp32)")
        if args.arena_precision != "fp32":
            print(f"arena tier ({args.arena_precision}): saved "
                  f"{db.get('arena_bytes_saved', 0)/1e6:.2f} MB HBM vs fp32")
        if "host_wire_bytes" in h[-1]:
            print(f"host<->device traffic: {h[-1]['host_wire_bytes']/1e6:.1f} MB total")
        if args.model_shards:
            imb = h[-1].get("shard_imbalance", 1.0)
            print(f"hybrid parallel: {args.model_shards} shards, "
                  f"exchange {h[-1].get('exchange_bytes', 0)/1e6:.1f} MB total "
                  f"(ids {h[-1].get('exchange_id_bytes', 0)/1e6:.1f} MB + rows "
                  f"{h[-1].get('exchange_row_bytes', 0)/1e6:.1f} MB "
                  f"[{args.exchange_codec}], top-{args.replicate_top_k} "
                  f"replicated), live imbalance {imb:.2f}x")
    if args.obs_dir:
        print(f"observability: {trainer.hub.jsonl_path} "
              f"(render: python -m repro.obs.report {trainer.hub.jsonl_path}) "
              f"| chrome trace: {trainer.trace_path}")


if __name__ == "__main__":
    main()
