"""Serving launcher: batched scoring with the cache in read-only mode.

  PYTHONPATH=src python -m repro.launch.serve --arch mind --requests 2000
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.data import synth
from repro.serve.engine import ServeEngine


def main():
    from repro.launch.train import cache_policy

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mind", choices=["mind", "din", "dlrm-criteo"])
    ap.add_argument("--requests", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--arena-precision", default="fp32",
                    choices=["fp32", "fp16", "int8", "auto"],
                    help="device-arena (fast-tier) codec: fp32 = raw bit-exact "
                         "arena; fp16/int8 tier it (fp32 hot head + encoded "
                         "resident tail) so the same HBM holds 2-4x more "
                         "resident rows; auto = PrecisionPolicy pick")
    ap.add_argument("--cache-policy", default=None,
                    choices=["freq_lfu", "lru", "runtime_lfu", "uvm_row"],
                    help="cache eviction policy (core.policies.Policy); "
                         "default = the model's (freq_lfu)")
    ap.add_argument("--refresh-interval", type=int, default=0,
                    help="0 = static ranking; N = adaptive frequency engine: "
                         "re-rank the read-only cache from online decayed "
                         "counters every N scored batches (pure reindexing — "
                         "scores unchanged, hit rate adapts to traffic)")
    ap.add_argument("--obs-dir", default=None,
                    help="observability output directory: streams per-batch "
                         "JSONL (exact counters, the latency histogram, span "
                         "aggregates) to <dir>/serve.jsonl and a Chrome "
                         "trace to <dir>/serve.trace.json; render with "
                         "`python -m repro.obs.report <dir>/serve.jsonl`")
    args = ap.parse_args()
    policy = cache_policy(args.cache_policy)

    if args.arch == "mind":
        from repro.models.recsys_models import MINDConfig, MINDModel

        cfg = MINDConfig(n_items=200_000, n_users=20_000, embed_dim=32, seq_len=50,
                         batch_size=args.batch, cache_ratio=0.05,
                         arena_precision=args.arena_precision, policy=policy)
        model = MINDModel(cfg)
        pad = {"hist_items": np.zeros((cfg.seq_len,), np.int32),
               "hist_len": np.zeros((), np.int32), "user": np.zeros((), np.int32),
               "target_item": np.zeros((), np.int32), "label": np.zeros((), np.float32)}
        mk = lambda s: synth.recsys_batch(cfg.n_items, cfg.n_users, cfg.seq_len,
                                          args.batch, 1, s)
    elif args.arch == "din":
        from repro.models.recsys_models import DINConfig, DINModel

        cfg = DINConfig(n_items=200_000, n_cates=20_000, n_users=20_000, embed_dim=18,
                        seq_len=50, batch_size=args.batch, cache_ratio=0.05,
                        arena_precision=args.arena_precision, policy=policy)
        model = DINModel(cfg)
        pad = {k: np.zeros(s, np.int32) for k, s in (
            ("hist_items", (cfg.seq_len,)), ("hist_cates", (cfg.seq_len,)),
            ("hist_len", ()), ("target_item", ()), ("target_cate", ()), ("user", ()))}
        pad["label"] = np.zeros((), np.float32)
        mk = lambda s: synth.recsys_batch(cfg.n_items, cfg.n_users, cfg.seq_len,
                                          args.batch, 1, s, n_cates=cfg.n_cates)
    else:
        from repro.models.dlrm import DLRM, DLRMConfig

        cfg = DLRMConfig(vocab_sizes=(100_000, 50_000), embed_dim=32, batch_size=args.batch,
                         cache_ratio=0.05, bottom_mlp=(64, 32), top_mlp=(64,),
                         arena_precision=args.arena_precision, policy=policy)
        model = DLRM(cfg)
        pad = {"dense": np.zeros((13,), np.float32), "sparse": np.zeros((2,), np.int32),
               "label": np.zeros((), np.float32)}
        spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)
        mk = lambda s: synth.sparse_batch(spec, args.batch, 1, s)

    state = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(
        model.serve_step, state, batch_size=args.batch, pad_example=pad,
        state_stats_fn=lambda s: model.collection.metrics(s["emb"], writeback=False),
        # read-only cache: resident rows are clean, the re-rank skips writebacks
        refresh_fn=(lambda s: model.refresh(s, writeback=False))
        if args.refresh_interval else None,
        refresh_every=args.refresh_interval or None,
        obs_dir=args.obs_dir,
    )
    n = 0
    step = 0
    while n < args.requests:
        b = mk(step)
        engine.score(b)
        n += args.batch
        step += 1
    summary = engine.summary()
    engine.close()
    print("stats:", summary)
    print(f"cache hit rate: {summary['hit_rate']:.1%} | "
          f"host<->device traffic: {summary['host_wire_bytes']/1e6:.2f} MB")
    if args.obs_dir:
        print(f"observability: {engine.hub.jsonl_path} "
              f"(render: python -m repro.obs.report {engine.hub.jsonl_path}) "
              f"| chrome trace: {engine.trace_path}")


if __name__ == "__main__":
    main()
