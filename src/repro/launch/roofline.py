"""Roofline-term extraction from compiled dry-run artifacts.

Terms (seconds, per device == per chip; cost_analysis of an SPMD executable
reports the per-device program):

  compute    = HLO_FLOPs / PEAK_FLOPS
  memory     = HLO_bytes / HBM_BW
  collective = wire_bytes / LINK_BW

wire_bytes is parsed from the post-SPMD HLO: for each collective op we take
its result (and group size) and apply ring-transfer accounting:

  all-gather        result * (g-1)/g
  all-reduce        2 * result * (g-1)/g
  reduce-scatter    result * (g-1)          (operand = result * g)
  all-to-all        result * (g-1)/g
  collective-permute  result

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

import numpy as np

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
LINK_BW = 50e9  # bytes/s / ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    op: str
    count: int
    result_bytes: int
    wire_bytes: float


def parse_collectives(hlo_text: str) -> List[CollectiveStats]:
    """Aggregate collective ops in (post-SPMD) HLO text."""
    agg: Dict[str, CollectiveStats] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        tuple_types, single_type, op = m.groups()
        rb = _tensor_bytes(tuple_types if tuple_types is not None else single_type)
        if "-done(" in line:  # async pair: count only the -start
            continue
        g = _group_size(line)
        if op == "all-gather":
            wire = rb * (g - 1) / max(g, 1)
        elif op == "all-reduce":
            wire = 2 * rb * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            wire = rb * (g - 1)
        elif op == "all-to-all":
            wire = rb * (g - 1) / max(g, 1)
        else:  # collective-permute
            wire = rb
        key = op
        if key not in agg:
            agg[key] = CollectiveStats(op, 0, 0, 0.0)
        agg[key].count += 1
        agg[key].result_bytes += rb
        agg[key].wire_bytes += wire
    return list(agg.values())


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 2


def roofline_terms(
    flops_per_device: float,
    bytes_per_device: float,
    wire_bytes_per_device: float,
) -> Dict[str, float]:
    compute = flops_per_device / PEAK_FLOPS
    memory = bytes_per_device / HBM_BW
    collective = wire_bytes_per_device / LINK_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    terms["dominant"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    )
    terms["bound_s"] = max(compute, memory, collective)
    terms["roofline_fraction"] = compute / max(terms["bound_s"], 1e-30)
    return terms


def analyze_compiled(compiled, lowered_text: Optional[str] = None) -> Dict[str, object]:
    """Full per-cell record from a compiled executable.

    Primary terms come from ``hlo_analyzer`` (while-trip-count-exact,
    gather/scatter touched-rows byte model); XLA's own ``cost_analysis`` is
    kept under ``xla_raw`` as a diagnostic (it counts loop bodies once and
    charges gathers the full operand — see the analyzer docstring).
    """
    from repro.launch import hlo_analyzer

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # jax<=0.4.x returns [dict]
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    text = lowered_text if lowered_text is not None else compiled.as_text()
    c = hlo_analyzer.analyze_hlo(text)
    flops, bytes_, wire = float(c.flops), float(c.bytes), float(c.wire_bytes)
    rec = {
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "wire_bytes_per_device": wire,
        "collectives": {k: {"wire_bytes": v} for k, v in sorted(c.coll.items())},
        "xla_raw": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
            "host_argument_bytes": mem.host_argument_size_in_bytes,
            "host_temp_bytes": mem.host_temp_size_in_bytes,
        },
        **roofline_terms(flops, bytes_, wire),
    }
    return rec
