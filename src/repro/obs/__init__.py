"""Unified observability layer: metrics hub, stage tracing, exact histograms.

The measurement substrate the ROADMAP items report through: a typed
:class:`~repro.obs.hub.MetricsHub` (exact wrap-safe counters, JSONL sink,
snapshot/delta), a :class:`~repro.obs.tracing.Tracer` (named stage spans,
Chrome-trace export, optional ``jax.profiler`` annotation), and
:class:`~repro.obs.hist.FixedHistogram` (deterministic log-bucket latency
percentiles).  ``python -m repro.obs.report <run.jsonl>`` renders a run.
"""
from repro.obs.hist import FixedHistogram, log_bounds
from repro.obs.hub import ExactCounter, Gauge, MetricsHub
from repro.obs.tracing import NULL_TRACER, Tracer

__all__ = [
    "ExactCounter",
    "FixedHistogram",
    "Gauge",
    "MetricsHub",
    "NULL_TRACER",
    "Tracer",
    "log_bounds",
]
