"""Deterministic fixed-bucket latency histograms.

The serving tier used to estimate percentiles from a seeded reservoir
(Vitter's Algorithm R): O(1) memory, but a reservoir is a *sample* — the tail
is under-weighted by construction (a p999 event has a 0.1% chance of being in
any given slot), and the estimate depends on the arrival order of samples.
A fixed-bucket histogram with log-spaced bounds fixes both at the same O(1)
memory: every observation is COUNTED (exact integer counts, nothing is ever
dropped or displaced), and a quantile query returns the smallest bucket
upper bound covering the requested rank — a deterministic, order-independent
*guaranteed upper bound* on the true quantile, with relative error bounded by
the bucket ratio (``10^(1/per_decade)``, ~26% at the default 10 buckets per
decade — tight enough to tell 1 ms from 10 ms from 100 ms, which is what a
latency SLO needs).

Pure stdlib on purpose (``bisect`` + lists): the histogram is serialized into
the observability JSONL stream and must round-trip byte-identically.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Dict, List, Mapping

__all__ = ["FixedHistogram", "log_bounds"]


def log_bounds(lo: float, hi: float, per_decade: int = 10) -> tuple:
    """Log-spaced bucket upper bounds from ``lo`` to >= ``hi``.

    Deterministic: bounds are computed as ``lo * 10**(k/per_decade)`` for
    integer ``k``, so two processes building the same (lo, hi, per_decade)
    get bit-identical floats."""
    if lo <= 0 or hi <= lo:
        raise ValueError(f"need 0 < lo < hi, got lo={lo} hi={hi}")
    out: List[float] = []
    k = 0
    while True:
        b = lo * 10.0 ** (k / per_decade)
        out.append(b)
        if b >= hi:
            break
        k += 1
    return tuple(out)


# default latency range: 10 us .. 100 s, 10 buckets/decade (71 buckets).
_DEFAULT_LATENCY_BOUNDS = log_bounds(1e-5, 100.0, per_decade=10)


@dataclasses.dataclass
class FixedHistogram:
    """Exact-count histogram over fixed ascending bucket upper bounds.

    Bucket ``i`` covers ``(bounds[i-1], bounds[i]]`` (bucket 0 covers
    ``(-inf, bounds[0]]``); ``counts[len(bounds)]`` is the overflow bucket
    for observations past the last bound.  ``min``/``max``/``sum`` are kept
    exactly so the overflow bucket can still report its true maximum.
    """

    bounds: tuple
    counts: List[int] = dataclasses.field(default_factory=list)
    count: int = 0
    sum: float = 0.0
    min: float = 0.0
    max: float = 0.0

    def __post_init__(self):
        self.bounds = tuple(float(b) for b in self.bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError("bounds must be strictly ascending")
        if not self.counts:
            self.counts = [0] * (len(self.bounds) + 1)
        elif len(self.counts) != len(self.bounds) + 1:
            raise ValueError(
                f"counts must have len(bounds)+1 = {len(self.bounds) + 1} "
                f"entries, got {len(self.counts)}"
            )

    @classmethod
    def latency(cls) -> "FixedHistogram":
        """The canonical latency histogram (seconds, 10 us .. 100 s)."""
        return cls(bounds=_DEFAULT_LATENCY_BOUNDS)

    def observe(self, x: float) -> None:
        x = float(x)
        i = bisect.bisect_left(self.bounds, x)  # first bound >= x; overflow past end
        self.counts[i] += 1
        if self.count == 0:
            self.min = self.max = x
        else:
            self.min = min(self.min, x)
            self.max = max(self.max, x)
        self.count += 1
        self.sum += x

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Deterministic upper bound on the ``q``-quantile (q in [0, 1]).

        Returns the upper bound of the bucket containing the
        ``ceil(q * count)``-th smallest observation — the true quantile is
        <= the returned value and > the bucket's lower edge.  The overflow
        bucket reports the exact observed maximum.  0.0 when empty."""
        if self.count == 0:
            return 0.0
        # ceil(q * count), nudged so binary-inexact q (0.999 * 1000 ->
        # 999.0000000000001) does not round the rank up a whole sample
        rank = max(1, min(self.count, math.ceil(q * self.count - 1e-9)))
        acc = 0
        for i, c in enumerate(self.counts):
            acc += c
            if acc >= rank:
                if i >= len(self.bounds):  # overflow bucket
                    return self.max
                # never report past the observed max (single-sample exactness)
                return min(self.bounds[i], self.max)
        return self.max

    def percentiles(self) -> Dict[str, float]:
        return {
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
            "p999": self.quantile(0.999),
        }

    def merge(self, other: "FixedHistogram") -> "FixedHistogram":
        """Exact merge of two histograms over identical bounds (shard/replica
        aggregation) — counts add, extrema combine."""
        if self.bounds != other.bounds:
            raise ValueError("cannot merge histograms with different bounds")
        out = FixedHistogram(
            bounds=self.bounds,
            counts=[a + b for a, b in zip(self.counts, other.counts)],
            count=self.count + other.count,
            sum=self.sum + other.sum,
        )
        if self.count and other.count:
            out.min, out.max = min(self.min, other.min), max(self.max, other.max)
        elif self.count:
            out.min, out.max = self.min, self.max
        else:
            out.min, out.max = other.min, other.max
        return out

    def to_dict(self) -> Dict[str, object]:
        return {
            "bounds": list(self.bounds),
            "counts": list(self.counts),
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "FixedHistogram":
        return cls(
            bounds=tuple(d["bounds"]),
            counts=list(d["counts"]),
            count=int(d["count"]),
            sum=float(d["sum"]),
            min=float(d["min"]),
            max=float(d["max"]),
        )
