"""Run-summary renderer for observability JSONL streams.

  PYTHONPATH=src python -m repro.obs.report /tmp/obs/run.jsonl
  PYTHONPATH=src python -m repro.obs.report /tmp/obs/serve.jsonl --json

Reads the records a :class:`repro.obs.hub.MetricsHub` sink wrote — ``meta``,
``step`` (one per trainer step), ``serve_batch``, ``spans``, ``hist``,
``summary`` — and renders the run: loss and hit-rate trajectories
(sparklines), bytes/step for the host link and the shard exchange, the
per-stage span breakdown, and the latency percentile table.  ``--json``
emits the computed summary as machine-readable JSON instead (what CI
asserts on).  Pure stdlib: the report must render on a box without jax.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Sequence

from repro.obs.hist import FixedHistogram

__all__ = ["load_records", "summarize", "render", "main"]

_SPARK = "▁▂▃▄▅▆▇█"


def sparkline(values: Sequence[float], width: int = 48) -> str:
    """Down-sampled unicode sparkline (empty string for no data)."""
    vals = [float(v) for v in values]
    if not vals:
        return ""
    if len(vals) > width:  # mean-pool into `width` buckets
        n = len(vals)
        vals = [
            sum(vals[i * n // width : (i + 1) * n // width])
            / max(1, (i + 1) * n // width - i * n // width)
            for i in range(width)
        ]
    lo, hi = min(vals), max(vals)
    span = hi - lo
    if span <= 0:
        return _SPARK[0] * len(vals)
    return "".join(_SPARK[int((v - lo) / span * (len(_SPARK) - 1))] for v in vals)


def load_records(path: str) -> List[Dict[str, Any]]:
    out = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise SystemExit(f"{path}:{i + 1}: not a JSONL record: {e}") from e
    return out


def _series(steps: List[Dict[str, Any]], key: str) -> List[float]:
    return [float(r[key]) for r in steps if key in r]


def _per_step(cumulative: List[float]) -> List[float]:
    """Per-step deltas of a cumulative series (first entry counts from 0)."""
    out, prev = [], 0.0
    for v in cumulative:
        out.append(v - prev)
        prev = v
    return out


def summarize(records: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Fold a record stream into the report's data model."""
    by_kind: Dict[str, List[Dict[str, Any]]] = {}
    for r in records:
        by_kind.setdefault(r.get("kind", "?"), []).append(r)

    out: Dict[str, Any] = {}
    meta = by_kind.get("meta", [])
    if meta:
        out["run"] = meta[0].get("run", "?")

    steps = sorted(by_kind.get("step", []), key=lambda r: r.get("step", 0))
    if steps:
        losses = _series(steps, "loss")
        hit = _series(steps, "hit_rate_exact") or _series(steps, "hit_rate")
        times = [
            float(r["wall"]["time_s"])
            for r in steps
            if isinstance(r.get("wall"), dict) and "time_s" in r["wall"]
        ]
        s: Dict[str, Any] = {
            "n_steps": len(steps),
            "first_step": steps[0].get("step"),
            "last_step": steps[-1].get("step"),
        }
        if losses:
            s["loss_first"], s["loss_last"] = losses[0], losses[-1]
            s["loss_series"] = losses
        if hit:
            s["hit_rate_last"] = hit[-1]
            s["hit_rate_series"] = hit
        if times:
            s["step_time_mean_s"] = sum(times) / len(times)
        for key in ("host_wire_bytes", "exchange_bytes", "exchange_id_bytes",
                    "exchange_row_bytes"):
            series = _series(steps, key)
            if series:
                s[f"{key}_total"] = int(series[-1])
                s[f"{key}_per_step"] = series[-1] / max(len(series), 1)
        for key in ("cache_hits", "cache_misses", "refresh_swaps_exact",
                    "refresh_rows_moved_exact"):
            series = _series(steps, key)
            if series:
                s[f"{key}_total"] = int(series[-1])
        out["train"] = s

    batches = by_kind.get("serve_batch", [])
    if batches:
        out["serve"] = {
            "n_batches": len(batches),
            "requests": int(batches[-1].get("requests", 0)),
        }

    spans = by_kind.get("spans", [])
    if spans:
        last = spans[-1]
        stages = (last.get("wall") or {}).get("stages", {})
        total = sum(v.get("total_s", 0.0) for v in stages.values()) or 1.0
        out["stages"] = {
            name: {
                "count": v.get("count", 0),
                "total_s": v.get("total_s", 0.0),
                "mean_ms": v.get("mean_ms", 0.0),
                "share": v.get("total_s", 0.0) / total,
            }
            for name, v in sorted(stages.items())
        }

    hists = {}
    for r in by_kind.get("hist", []):
        payload = (r.get("wall") or {}).get("hist")
        if payload is None:
            continue
        h = FixedHistogram.from_dict(payload)
        hists[r.get("name", "?")] = {
            "count": h.count,
            "mean_ms": 1e3 * h.mean,
            **{k: 1e3 * v for k, v in h.percentiles().items()},
            "max_ms": 1e3 * h.max,
        }
    if hists:
        out["latency"] = hists

    summaries = by_kind.get("summary", [])
    if summaries:
        out["counters"] = summaries[-1].get("counters", {})
    return out


def _fmt_bytes(n: float) -> str:
    for unit, div in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if abs(n) >= div:
            return f"{n / div:.2f} {unit}"
    return f"{n:.0f} B"


def render(summary: Dict[str, Any]) -> str:
    lines: List[str] = []
    lines.append(f"run: {summary.get('run', '?')}")

    t = summary.get("train")
    if t:
        lines.append(
            f"steps: {t['n_steps']} ({t.get('first_step')}..{t.get('last_step')})"
        )
        if "loss_first" in t:
            lines.append(
                f"loss: {t['loss_first']:.4f} -> {t['loss_last']:.4f}  "
                f"{sparkline(t.get('loss_series', []))}"
            )
        if "hit_rate_last" in t:
            lines.append(
                f"hit rate: {t['hit_rate_last']:.1%}  "
                f"{sparkline(t.get('hit_rate_series', []))}"
            )
        if "step_time_mean_s" in t:
            lines.append(f"step time: mean {t['step_time_mean_s'] * 1e3:.2f} ms")
        if "host_wire_bytes_total" in t:
            lines.append(
                f"host link: {_fmt_bytes(t['host_wire_bytes_total'])} total, "
                f"{_fmt_bytes(t['host_wire_bytes_per_step'])}/step"
            )
        if "exchange_bytes_total" in t:
            extra = ""
            if "exchange_id_bytes_total" in t:
                extra = (
                    f" (ids {_fmt_bytes(t['exchange_id_bytes_total'])}"
                    f" + rows {_fmt_bytes(t.get('exchange_row_bytes_total', 0))})"
                )
            lines.append(
                f"shard exchange: {_fmt_bytes(t['exchange_bytes_total'])} total, "
                f"{_fmt_bytes(t['exchange_bytes_per_step'])}/step{extra}"
            )
        if "cache_hits_total" in t:
            lines.append(
                f"cache: {t['cache_hits_total']} hits / "
                f"{t.get('cache_misses_total', 0)} misses (exact)"
            )
        if "refresh_swaps_exact_total" in t:
            lines.append(
                f"refresh: {t['refresh_swaps_exact_total']} swaps, "
                f"{t.get('refresh_rows_moved_exact_total', 0)} rows moved"
            )

    sv = summary.get("serve")
    if sv:
        lines.append(f"serve: {sv['n_batches']} batches, {sv['requests']} requests")

    stages = summary.get("stages")
    if stages:
        lines.append("")
        lines.append("stage breakdown (host wall-clock spans):")
        lines.append(f"  {'stage':<14}{'count':>8}{'total ms':>12}{'mean ms':>10}{'share':>8}")
        for name, v in stages.items():
            lines.append(
                f"  {name:<14}{v['count']:>8}{v['total_s'] * 1e3:>12.1f}"
                f"{v['mean_ms']:>10.2f}{v['share']:>8.1%}"
            )

    lat = summary.get("latency")
    if lat:
        lines.append("")
        lines.append("latency (fixed-bucket histogram bounds, ms):")
        lines.append(
            f"  {'name':<18}{'count':>8}{'mean':>9}{'p50':>9}{'p95':>9}"
            f"{'p99':>9}{'p999':>9}{'max':>9}"
        )
        for name, v in sorted(lat.items()):
            lines.append(
                f"  {name:<18}{v['count']:>8}{v['mean_ms']:>9.2f}{v['p50']:>9.2f}"
                f"{v['p95']:>9.2f}{v['p99']:>9.2f}{v['p999']:>9.2f}{v['max_ms']:>9.2f}"
            )
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report", description=__doc__
    )
    ap.add_argument("jsonl", help="run JSONL written by a MetricsHub sink")
    ap.add_argument("--json", action="store_true",
                    help="emit the computed summary as JSON (CI mode)")
    args = ap.parse_args(argv)
    records = load_records(args.jsonl)
    if not records:
        raise SystemExit(f"{args.jsonl}: no records")
    summary = summarize(records)
    if args.json:
        print(json.dumps(summary, indent=2, sort_keys=True, default=str))
    else:
        print(render(summary))
    return 0


if __name__ == "__main__":
    sys.exit(main())
