"""Structured metrics hub: typed instruments, exact-int accumulation, JSONL.

Every int32 counter the cache threads through jit — hits, misses, routed
exchange lanes, host rows moved, refresh swaps — is CUMULATIVE device state
that (a) wraps past 2^31 on long runs (x64 is off) and (b) only becomes a
trustworthy Python int through modulo-2^32 delta accumulation host-side.
Before this module that wrap-safe pattern lived in three places
(``Trainer._post_step``, ``ServeEngine.summary``, and ad-hoc
``exact_metric_bytes`` call sites in the benchmarks); :class:`ExactCounter`
is the one implementation, and :meth:`MetricsHub.observe_embedding_metrics`
is the ONE place that knows which families a ``collection.metrics`` dict
carries and how each reconstructs (per-slab counts, optionally priced by a
static per-unit byte size).

The hub also owns the run's JSONL sink.  Records are written with sorted
keys and every wall-clock-dependent field (timestamps, step durations, span
times) quarantined under the reserved ``"wall"`` key, so two identical runs
emit BYTE-IDENTICAL files modulo that one subtree — determinism you can test
(``tests/test_obs.py`` does), which turns telemetry diffs into regression
signals instead of noise.

Dependency-light on purpose: stdlib + jax only (``jax.device_get`` to fetch
counter leaves).  ``core``/``train``/``serve`` import this module, never the
reverse, so the hub can sit under all of them.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Any, Dict, IO, Mapping, Optional, Union

import jax

from repro.obs.hist import FixedHistogram

__all__ = ["ExactCounter", "Gauge", "MetricsHub"]

_WRAP = 1 << 32


def _as_int_map(value: Any) -> Dict[str, int]:
    """Normalize a cumulative observation — scalar, array scalar, or per-key
    mapping of either — to ``{key: int}`` (single scalars key as "")."""
    if isinstance(value, Mapping):
        fetched = jax.device_get(dict(value))
        return {k: int(v) for k, v in fetched.items()}
    return {"": int(jax.device_get(value))}


class ExactCounter:
    """Wrap-free exact totals over cumulative int32 device counters.

    Two ways to feed it:

    * :meth:`add` — a direct host-side increment (already an exact int).
    * :meth:`observe` — an observation of a CUMULATIVE device counter (or a
      per-slab mapping of them).  The per-interval delta is recovered modulo
      2^32 — exact whenever fewer than 2^31 events happen between
      observations, which one step can never exceed — and summed in Python
      integers.  With ``unit`` (an int, or a per-key mapping of ints), each
      key's delta is multiplied by its unit BEFORE summing, so byte totals
      (rows x encoded row size) are wrap-safe too — unlike the legacy
      ``exact_metric_bytes`` one-shot product, which inherits the int32 wrap
      of the count it reads.

    Idempotent under repeated observation of the same values (delta 0), so
    summaries may call it freely.  Totals count from the first observation's
    raw value — exact for fresh states; a state restored with an
    already-wrapped counter under-reports only the pre-restore portion.
    """

    def __init__(self, name: str = ""):
        self.name = name
        self._prev: Dict[str, int] = {}
        self._total = 0

    def add(self, n: int) -> int:
        self._total += int(n)
        return self._total

    def observe(
        self,
        cumulative: Any,
        unit: Optional[Union[int, Mapping[str, Any]]] = None,
    ) -> int:
        cur = _as_int_map(cumulative)
        units: Optional[Dict[str, int]] = None
        if unit is not None:
            units = (
                _as_int_map(unit)
                if isinstance(unit, Mapping)
                else {k: int(unit) for k in cur}
            )
        for k, v in cur.items():
            delta = (v - self._prev.get(k, 0)) % _WRAP
            self._prev[k] = v
            self._total += delta * (units[k] if units is not None else 1)
        return self._total

    @property
    def value(self) -> int:
        return self._total

    # back-compat spelling used by the pre-hub pattern
    total = value


class Gauge:
    """Last-value instrument (floats: hit rate, imbalance, loss)."""

    def __init__(self, name: str = ""):
        self.name = name
        self.value: float = 0.0

    def set(self, v: float) -> float:
        self.value = float(v)
        return self.value


# -- the one registry of cumulative families in a collection metrics dict ---
#
# (record_key, counts_key, unit_key) — counts_key holds per-slab cumulative
# int32 counts; unit_key (None = 1) holds the matching static per-unit byte
# sizes.  Everything the trainer/serve summaries report as exact ints flows
# through this table and nowhere else.
_CUMULATIVE_FAMILIES = (
    ("cache_hits", "slab_hits", None),
    ("cache_misses", "slab_misses", None),
    ("host_moved_rows", "host_moved_rows", None),
    ("host_wire_bytes", "host_moved_rows", "host_row_bytes"),
    ("exchange_routed_lanes", "exchange_routed_lanes", None),
    ("exchange_bytes", "exchange_routed_lanes", "exchange_lane_bytes"),
    ("exchange_id_bytes", "exchange_routed_lanes", "exchange_id_lane_bytes"),
    ("exchange_row_bytes", "exchange_routed_lanes", "exchange_row_lane_bytes"),
    ("refresh_swaps_exact", "slab_refresh_swaps", None),
    ("refresh_rows_moved_exact", "slab_refresh_rows", None),
    ("slab_tier_promotions", "slab_tier_promotions", None),
    ("slab_tier_demotions", "slab_tier_demotions", None),
)


class MetricsHub:
    """Typed counter/gauge/histogram registry + per-run JSONL sink.

    ``run_dir=None`` gives a sink-less hub: instruments still accumulate
    (the trainer always routes its exact counters through one), ``log`` is a
    no-op.  With a directory, records stream to ``<run_dir>/<run>.jsonl``
    and ``close()`` finalizes the file.

    Snapshot/delta semantics: :meth:`snapshot` captures every instrument's
    current value; :meth:`delta` subtracts a previous snapshot's counters —
    how a serve summary reports per-interval rates off the same hub a
    trainer fills.
    """

    def __init__(
        self,
        run_dir: Optional[str] = None,
        run: str = "run",
        timestamps: bool = True,
    ):
        self.run = run
        self.timestamps = timestamps
        self.jsonl_path: Optional[str] = None
        self._sink: Optional[IO[str]] = None
        self._counters: Dict[str, ExactCounter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, FixedHistogram] = {}
        if run_dir is not None:
            os.makedirs(run_dir, exist_ok=True)
            self.jsonl_path = os.path.join(run_dir, f"{run}.jsonl")
            self._sink = open(self.jsonl_path, "w")
            self.log("meta", {"run": run, "argv": list(sys.argv[1:])})

    # -- typed instruments ---------------------------------------------------

    def counter(self, name: str) -> ExactCounter:
        if name not in self._counters:
            self._counters[name] = ExactCounter(name)
        return self._counters[name]

    def gauge(self, name: str) -> Gauge:
        if name not in self._gauges:
            self._gauges[name] = Gauge(name)
        return self._gauges[name]

    def histogram(
        self, name: str, bounds: Optional[tuple] = None
    ) -> FixedHistogram:
        if name not in self._hists:
            self._hists[name] = (
                FixedHistogram(bounds=bounds)
                if bounds is not None
                else FixedHistogram.latency()
            )
        return self._hists[name]

    # -- the ONE cumulative-counter reconstruction point ---------------------

    def observe_embedding_metrics(self, metrics: Mapping[str, Any]) -> Dict[str, int]:
        """Feed one observation of a ``collection.metrics`` dict; returns the
        exact-int record for the families present (wrap-safe Python ints).

        This replaces the per-call-site ``ExactCounterTotals`` pairs and
        ``exact_metric_bytes`` calls the trainer, the serve engine, and the
        benchmarks each hand-rolled: add a counter family to
        ``_CUMULATIVE_FAMILIES`` and every consumer reports it.  Derived
        ``hit_rate_exact`` rides along whenever both hit families exist.

        One ``jax.device_get`` for the whole observation: the per-slab
        counter leaves are fetched as a single tree, not one sync per leaf.
        """
        wanted = {
            key
            for _, counts_key, unit_key in _CUMULATIVE_FAMILIES
            for key in (counts_key, unit_key)
            if key is not None and key in metrics
        }
        fetched = jax.device_get(
            {
                k: dict(metrics[k]) if isinstance(metrics[k], Mapping) else metrics[k]
                for k in wanted
            }
        )
        out: Dict[str, int] = {}
        for record_key, counts_key, unit_key in _CUMULATIVE_FAMILIES:
            if counts_key not in fetched:
                continue
            if unit_key is not None and unit_key not in fetched:
                continue
            unit = fetched[unit_key] if unit_key is not None else None
            out[record_key] = self.counter(record_key).observe(
                fetched[counts_key], unit=unit
            )
        if "cache_hits" in out and "cache_misses" in out:
            h, m = out["cache_hits"], out["cache_misses"]
            out["hit_rate_exact"] = h / max(h + m, 1)
        return out

    # -- JSONL sink ----------------------------------------------------------

    def log(
        self,
        kind: str,
        payload: Mapping[str, Any],
        wall: Optional[Mapping[str, Any]] = None,
    ) -> None:
        """Append one record.  ``payload`` must be deterministic run-to-run;
        anything wall-clock-dependent goes in ``wall`` (plus the record
        timestamp when enabled) — the quarantine that keeps identical runs
        byte-identical modulo the ``"wall"`` subtree."""
        if self._sink is None:
            return
        rec: Dict[str, Any] = {"kind": kind, **payload}
        w = dict(wall) if wall else {}
        if self.timestamps:
            w["ts"] = time.time()
        if w:
            rec["wall"] = w
        self._sink.write(json.dumps(rec, sort_keys=True) + "\n")
        self._sink.flush()

    def log_hist(self, name: str, hist: Optional[FixedHistogram] = None) -> None:
        """Write a named histogram record.  Latency counts are wall-clock
        dependent, so the whole payload sits under ``wall``."""
        h = hist if hist is not None else self._hists.get(name)
        if h is None:
            return
        self.log("hist", {"name": name}, wall={"hist": h.to_dict()})

    def log_spans(self, tracer) -> None:
        """Write the tracer's stage aggregate: span names and counts are
        deterministic (the schedule is), durations are wall-clock."""
        summary = tracer.stage_summary()
        self.log(
            "spans",
            {"counts": {k: v["count"] for k, v in summary.items()}},
            wall={"stages": summary},
        )

    # -- snapshot / delta ----------------------------------------------------

    def snapshot(self) -> Dict[str, Any]:
        return {
            "counters": {k: c.value for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value for k, g in sorted(self._gauges.items())},
            "hists": {k: h.to_dict() for k, h in sorted(self._hists.items())},
        }

    def delta(self, prev: Mapping[str, Any]) -> Dict[str, int]:
        """Counter movement since a previous :meth:`snapshot`."""
        base = prev.get("counters", {})
        return {
            k: c.value - int(base.get(k, 0))
            for k, c in sorted(self._counters.items())
        }

    def close(self) -> None:
        if self._sink is not None:
            self.log("summary", {"counters": self.snapshot()["counters"]})
            self._sink.close()
            self._sink = None

    def __enter__(self) -> "MetricsHub":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
