"""Host-side span tracing for the pipelined stages.

The pipelined trainer's whole value proposition is *overlap* — plan t+1
dispatched under compute t — and BagPipe's lesson (arXiv 2202.12429) is that
those wins are only real if you can see which stage hides which latency.
``Tracer`` records named wall-clock spans at the stage boundaries the Python
loop actually controls (plan / compute / apply / refresh / host-transfer /
checkpoint / score) and exports them as Chrome-trace JSON, so a run renders
directly in ``chrome://tracing`` / Perfetto with one row per thread and the
group structure visible.

Two caveats, by design:

* JAX dispatch is asynchronous — a span around ``compute_fn(...)`` measures
  *dispatch* time unless something blocks inside it.  The blocking point is
  explicit in the trainer (the once-per-step loss fetch is its own
  ``host-transfer`` span), so the span profile shows where the Python loop
  spends wall-clock, which is exactly the quantity the pipeline overlaps.
* device-side timing needs the real profiler: with ``annotate=True`` every
  span also enters a ``jax.profiler.TraceAnnotation``, so the same stage
  names appear on the device timeline when a ``jax.profiler.trace`` capture
  is taken around the run.

Raw events are capped (``max_events``, default 100k) so a week-long serve
loop cannot grow without bound — aggregate stats (count / total per name)
stay exact past the cap.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Dict, List

__all__ = ["Tracer", "NULL_TRACER"]


class Tracer:
    """Named wall-clock spans with Chrome-trace export.

    Thread-safe: the serve engine's replica workers and the trainer's
    prefetch thread may all record spans; events carry the recording
    thread's id so the Chrome trace renders one row per thread.
    """

    def __init__(self, annotate: bool = False, max_events: int = 100_000):
        self.annotate = annotate
        self.max_events = max_events
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._dropped = 0
        # exact aggregates, never capped: name -> [count, total_seconds]
        self._agg: Dict[str, List[float]] = {}

    @contextlib.contextmanager
    def span(self, name: str, **attrs: Any):
        """Record one ``name`` span around the body (optionally annotating
        the device timeline via ``jax.profiler.TraceAnnotation``)."""
        ann = contextlib.nullcontext()
        if self.annotate:
            import jax.profiler  # deferred: tracing stays importable sans jax

            ann = jax.profiler.TraceAnnotation(name)
        start = time.perf_counter()
        with ann:
            try:
                yield
            finally:
                dur = time.perf_counter() - start
                self._record(name, start - self._t0, dur, attrs)

    def _record(self, name: str, ts: float, dur: float, attrs: Dict) -> None:
        with self._lock:
            agg = self._agg.setdefault(name, [0, 0.0])
            agg[0] += 1
            agg[1] += dur
            if len(self._events) >= self.max_events:
                self._dropped += 1
                return
            ev = {"name": name, "ts": ts, "dur": dur,
                  "tid": threading.get_ident()}
            if attrs:
                ev["args"] = dict(attrs)
            self._events.append(ev)

    # -- aggregates ----------------------------------------------------------

    def stage_summary(self) -> Dict[str, Dict[str, float]]:
        """Exact per-stage aggregates: ``{name: {count, total_s, mean_ms}}``
        (counts survive the raw-event cap)."""
        with self._lock:
            return {
                name: {
                    "count": int(c),
                    "total_s": t,
                    "mean_ms": 1e3 * t / c if c else 0.0,
                }
                for name, (c, t) in sorted(self._agg.items())
            }

    @property
    def dropped_events(self) -> int:
        return self._dropped

    # -- export --------------------------------------------------------------

    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome-trace/Perfetto JSON object (``ph: "X"`` complete events,
        microsecond timestamps relative to tracer start)."""
        with self._lock:
            events = [
                {
                    "name": ev["name"],
                    "ph": "X",
                    "ts": round(ev["ts"] * 1e6, 3),
                    "dur": round(ev["dur"] * 1e6, 3),
                    "pid": os.getpid(),
                    "tid": ev["tid"],
                    **({"args": ev["args"]} if "args" in ev else {}),
                }
                for ev in self._events
            ]
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> str:
        """Write the Chrome-trace JSON to ``path`` (atomic rename so a
        crashed run never leaves a half-written trace); returns the path."""
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(self.chrome_trace(), f)
        os.replace(tmp, path)
        return path


class _NullTracer(Tracer):
    """Zero-overhead stand-in when observability is off: ``span`` returns a
    shared nullcontext, records nothing."""

    def __init__(self):
        super().__init__(annotate=False, max_events=0)
        self._null = contextlib.nullcontext()

    def span(self, name: str, **attrs: Any):  # noqa: ARG002 - interface parity
        return self._null


NULL_TRACER: Tracer = _NullTracer()
