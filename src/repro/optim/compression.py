"""Gradient compression for the data-parallel reduction, with error feedback.

At 1000+ nodes the DP gradient all-reduce is the dominant inter-pod
collective.  We provide two codecs:

  * ``bf16``  — 2x: cast to bfloat16 before the reduction (no feedback needed
    in practice, but we keep it for bit-accounting).
  * ``int8``  — 4x: per-tensor symmetric int8 with a float scale, plus error
    feedback (residual accumulation) so the quantization noise is unbiased
    over steps [Seide et al. 2014; Karimireddy et al. 2019].

Usage inside a jitted train step::

    comp = Compressor("int8")
    cstate = comp.init(grads_like)
    grads_q, cstate = comp.encode(grads, cstate)   # before psum / pmean
    grads_q = jax.lax.pmean(grads_q, "data")        # or rely on pjit's implicit reduce
    grads   = comp.decode(grads_q)

Under pjit the reduction is implicit; ``encode`` still shrinks the bytes that
cross the wire because the all-reduce then runs on the low-precision dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Compressor"]


@dataclasses.dataclass(frozen=True)
class Compressor:
    codec: str = "none"  # none | bf16 | int8

    def init(self, grads_like: Any) -> Any:
        if self.codec != "int8":
            return ()
        return jax.tree_util.tree_map(lambda g: jnp.zeros_like(g, jnp.float32), grads_like)

    def encode(self, grads: Any, state: Any) -> Tuple[Any, Any, Any]:
        """Returns (payload, sideband, new_state).

        ``payload`` is what crosses the wire (low precision); ``sideband``
        carries per-tensor scales (tiny, fp32).
        """
        if self.codec == "none":
            return grads, (), state
        if self.codec == "bf16":
            return jax.tree_util.tree_map(lambda g: g.astype(jnp.bfloat16), grads), (), state

        # int8 with error feedback
        def enc(g, e):
            gf = g.astype(jnp.float32) + e
            scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
            new_e = gf - q.astype(jnp.float32) * scale
            return q, scale, new_e

        enc_tree = jax.tree_util.tree_map(enc, grads, state)
        is3 = lambda x: isinstance(x, tuple) and len(x) == 3
        payload = jax.tree_util.tree_map(lambda t: t[0], enc_tree, is_leaf=is3)
        scales = jax.tree_util.tree_map(lambda t: t[1], enc_tree, is_leaf=is3)
        new_state = jax.tree_util.tree_map(lambda t: t[2], enc_tree, is_leaf=is3)
        return payload, scales, new_state

    def decode(self, payload: Any, sideband: Any, target_like: Any) -> Any:
        if self.codec == "none":
            return payload
        if self.codec == "bf16":
            return jax.tree_util.tree_map(
                lambda q, t: q.astype(t.dtype), payload, target_like
            )
        return jax.tree_util.tree_map(
            lambda q, s, t: (q.astype(jnp.float32) * s).astype(t.dtype),
            payload,
            sideband,
            target_like,
        )

    def wire_bytes(self, grads: Any) -> int:
        per = {"none": 4, "bf16": 2, "int8": 1}[self.codec]
        return sum(x.size * per for x in jax.tree_util.tree_leaves(grads))
