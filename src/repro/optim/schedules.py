"""LR schedules (callables of the int step)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "linear_warmup_cosine", "inverse_sqrt"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup_cosine(peak: float, warmup_steps: int, total_steps: int, floor: float = 0.0):
    def f(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup_steps, warm, cos)

    return f


def inverse_sqrt(peak: float, warmup_steps: int):
    def f(step):
        s = jnp.maximum(step.astype(jnp.float32), 1.0)
        return peak * jnp.minimum(s / max(warmup_steps, 1), jnp.sqrt(warmup_steps / s))

    return f
