"""Optimizers (optax-free): SGD, Adam(W), Adagrad, row-wise Adagrad.

API: ``opt = sgd(lr=...)``; ``state = opt.init(params)``;
``params, state = opt.update(grads, state, params, step)``.
LR may be a float or a schedule ``f(step) -> float``.

Row-wise Adagrad (one accumulator per embedding row) is the standard
industrial choice for huge tables; for *cached* tables the accumulator
travels with the row through ``repro.core`` (see
``CachedEmbeddingConfig.rowwise_adagrad``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Union

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "adam", "adamw", "adagrad", "clip_by_global_norm", "global_norm"]

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


def _lr_at(lr: Schedule, step) -> jnp.ndarray:
    return jnp.asarray(lr(step) if callable(lr) else lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params, step) -> (params, state)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    n = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), n


def sgd(lr: Schedule, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)
        if momentum == 0.0:
            new_params = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32) - lr_t * g.astype(jnp.float32)).astype(p.dtype),
                params,
                grads,
            )
            return new_params, state
        new_m = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads
        )
        new_params = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - lr_t * m).astype(p.dtype), params, new_m
        )
        return new_params, new_m

    return Optimizer(init, update)


def adam(
    lr: Schedule, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, weight_decay: float = 0.0
) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
        }

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * gf * gf
            u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), m2, v2

        flat = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree_util.tree_map(lambda x: x[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda x: x[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda x: x[2], flat, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def adamw(lr: Schedule, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)


def adagrad(lr: Schedule, eps: float = 1e-10) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params, step):
        lr_t = _lr_at(lr, step)
        new_acc = jax.tree_util.tree_map(
            lambda a, g: a + jnp.square(g.astype(jnp.float32)), state, grads
        )
        new_params = jax.tree_util.tree_map(
            lambda p, g, a: (
                p.astype(jnp.float32) - lr_t * g.astype(jnp.float32) / (jnp.sqrt(a) + eps)
            ).astype(p.dtype),
            params,
            grads,
            new_acc,
        )
        return new_params, new_acc

    return Optimizer(init, update)
