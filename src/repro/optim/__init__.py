from repro.optim.optimizers import (
    Optimizer,
    adagrad,
    adam,
    adamw,
    clip_by_global_norm,
    global_norm,
    sgd,
)
from repro.optim.compression import Compressor
from repro.optim import schedules
