"""Fault-tolerant checkpointing: atomic, async, topology-agnostic.

Layout::

    <dir>/step_000123/
        manifest.json       # step, leaf index (path -> file, shape, dtype)
        000_params.weight.npy ...
    <dir>/LATEST            # text file naming the newest complete checkpoint

Guarantees:
  * atomicity — writes land in ``step_N.tmp`` and are renamed only after the
    manifest is fsynced; a crash mid-save leaves the previous checkpoint
    intact and a garbage ``.tmp`` that restore ignores.
  * topology-agnostic restore — leaves are saved as full logical arrays
    (device_get gathers shards); ``restore`` returns numpy, and the caller
    re-shards with whatever mesh is active (elastic rescaling = restart on a
    different mesh).
  * async — ``save_async`` snapshots to host synchronously (cheap) and
    serializes on a background thread so the train loop is not blocked.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "Checkpointer"]

_SEP = "/"


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        out.append((key, leaf))
    return out, treedef


def save(directory: str | os.PathLike, step: int, tree: Any, keep: int = 3) -> pathlib.Path:
    """Blocking atomic save of a (possibly device-resident) pytree."""
    host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)
    return _write(pathlib.Path(directory), step, host_tree, keep)


def _write(directory: pathlib.Path, step: int, host_tree: Any, keep: int) -> pathlib.Path:
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:09d}"
    tmp = directory / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, _ = _flatten(host_tree)
    index = []
    for i, (key, leaf) in enumerate(leaves):
        fname = f"{i:04d}.npy"
        np.save(tmp / fname, np.asarray(leaf), allow_pickle=False)
        index.append({"key": key, "file": fname, "shape": list(np.shape(leaf)),
                      "dtype": str(np.asarray(leaf).dtype)})
    manifest = {"step": step, "leaves": index}
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish
    (directory / "LATEST.tmp").write_text(final.name)
    (directory / "LATEST.tmp").rename(directory / "LATEST")
    _gc(directory, keep)
    return final


def _gc(directory: pathlib.Path, keep: int):
    ckpts = sorted(d for d in directory.glob("step_*") if d.is_dir() and not d.name.endswith(".tmp"))
    for d in ckpts[:-keep]:
        shutil.rmtree(d, ignore_errors=True)


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    directory = pathlib.Path(directory)
    marker = directory / "LATEST"
    if marker.exists():
        name = marker.read_text().strip()
        if (directory / name / "manifest.json").exists():
            return int(name.split("_")[1])
    # fall back to scanning (LATEST may be missing after a crash)
    best = None
    for d in sorted(directory.glob("step_*")):
        if d.is_dir() and (d / "manifest.json").exists():
            best = int(d.name.split("_")[1])
    return best


def _leaf_meta(like: Any) -> Tuple[Tuple[int, ...], Optional[np.dtype]]:
    """(shape, dtype) of a template leaf; dtype None when undeterminable."""
    shape = tuple(getattr(like, "shape", np.shape(like)))
    dt = getattr(like, "dtype", None)
    try:
        return shape, np.dtype(dt) if dt is not None else np.asarray(like).dtype
    except TypeError:
        return shape, None


def restore(directory: str | os.PathLike, tree_like: Any, step: Optional[int] = None) -> Tuple[Any, int]:
    """Load a checkpoint into the structure of ``tree_like`` (numpy leaves).

    The caller re-shards (``jax.device_put`` with the current mesh) — this is
    what makes restarts elastic across topologies.

    Every leaf is validated against ``tree_like``'s shape/dtype before it is
    accepted: a silent mismatch would hand back a corrupt tree (the classic
    case being a host-store codec change — int8 payloads restored into an
    fp32 store — which must fail loudly, not train on garbage).
    """
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = directory / f"step_{step:09d}"
    manifest = json.loads((d / "manifest.json").read_text())
    by_key = {e["key"]: e for e in manifest["leaves"]}
    leaves, treedef = _flatten(tree_like)
    out = []
    for key, like in leaves:
        e = by_key.get(key)
        if e is None:
            raise ValueError(
                f"checkpoint {d} has no leaf {key!r} — the on-disk state was "
                f"saved with a different structure than the restore template"
            )
        shape, dtype = _leaf_meta(like)
        disk_shape, disk_dtype = tuple(e["shape"]), np.dtype(e["dtype"])
        if disk_shape != shape or (dtype is not None and disk_dtype != dtype):
            is_store = ".full." in key or ".sideband" in key
            # keystr renders ArenaStore fields as .cached_rows.head['w'] /
            # .cached_rows.tail['w'] / .cached_rows.sideband['w']
            is_arena = ".cached_rows." in key and (
                ".head" in key or ".tail" in key or ".sideband" in key
            )
            if is_arena:
                hint = (
                    "  The leaf belongs to a tiered device arena: the "
                    "checkpoint was saved under a different arena_precision "
                    "(or arena_head_ratio) than the restore template expects "
                    "— restore with the setting it was saved with, then "
                    "convert explicitly (pre-tiering checkpoints restore only "
                    "under arena_precision='fp32')."
                )
            elif is_store:
                hint = (
                    "  The leaf belongs to a host store: the checkpoint was "
                    "saved under a different host-precision codec than the "
                    "restore template expects — restore with the codec it was "
                    "saved with (matching host_precision), then convert "
                    "explicitly."
                )
            else:
                hint = ""
            raise ValueError(
                f"checkpoint leaf {key!r} mismatch: on disk "
                f"{disk_shape}/{disk_dtype}, template expects {shape}/{dtype}."
                + hint
            )
        arr = np.load(d / e["file"], allow_pickle=False)
        out.append(arr)
    surplus = sorted(set(by_key) - {k for k, _ in leaves})
    if surplus:
        raise ValueError(
            f"checkpoint {d} holds {len(surplus)} leaves the restore template "
            f"does not (e.g. {surplus[:3]}) — restoring would silently drop "
            f"state; rebuild the template with the structure it was saved with"
        )
    return jax.tree_util.tree_unflatten(treedef, out), manifest["step"]


class Checkpointer:
    """Async checkpoint manager with bounded in-flight saves."""

    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any):
        self.wait()  # at most one in flight; snapshot synchronously
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                _write(self.directory, step, host_tree, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def restore_latest(self, tree_like: Any):
        return restore(self.directory, tree_like)
