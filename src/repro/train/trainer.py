"""Training loop with the fault-tolerance features required at pod scale:

  * checkpoint/restart — async atomic checkpoints every N steps; on start the
    loop auto-resumes from the newest complete checkpoint (crash-safe), and
    because restore returns logical arrays, a restart may use a different
    mesh (elastic rescale) — shardings are re-applied here.
  * cached-embedding consistency — models with software-cache tiers get
    ``flush_fn`` called before every checkpoint so the slow tiers are
    authoritative (the caches stay warm); collection-era models pass
    ``model.flush`` (an ``EmbeddingCollection.flush`` over every cached
    slab), single-arena models wrap ``cached_embedding.flush_state``.
  * straggler detection — per-step wall times feed an EWMA + deviation
    monitor; steps slower than ``straggler_factor`` x the smoothed time fire
    ``on_straggler`` (log/report/abort — pluggable; on a real pod this wires
    into the coordinator's slow-host eviction).
  * overlap — host batch generation runs in a Prefetcher thread, and JAX
    async dispatch keeps device compute ahead of the Python loop; the
    cache-prepare stage of step t+1 can overlap step t's dense compute when
    the model exposes a split step (``prepare_fn``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.data.pipeline import Prefetcher
from repro.train import checkpoint as ckpt_lib

__all__ = ["TrainerConfig", "Trainer", "StragglerDetector"]


@dataclasses.dataclass
class StragglerDetector:
    """EWMA step-time monitor; flags abnormal steps (slow host / bad chip)."""

    factor: float = 3.0
    alpha: float = 0.1
    warmup: int = 5
    ewma: float = 0.0
    count: int = 0
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        self.count += 1
        if self.count <= self.warmup:
            self.ewma = dt if self.ewma == 0 else (1 - self.alpha) * self.ewma + self.alpha * dt
            return False
        slow = dt > self.factor * max(self.ewma, 1e-9)
        if slow:
            self.flagged += 1
        else:  # stragglers don't poison the mean
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


@dataclasses.dataclass
class TrainerConfig:
    max_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    ckpt_keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    prefetch_depth: int = 2
    assert_no_uniq_overflow: bool = True


class Trainer:
    def __init__(
        self,
        cfg: TrainerConfig,
        init_fn: Callable[[], Any],  # () -> state
        step_fn: Callable[[Any, Dict], Any],  # (state, batch) -> (state, metrics); jitted
        make_batch: Callable[[int], Dict],  # step -> host batch
        flush_fn: Optional[Callable[[Any], Any]] = None,  # cache barrier pre-ckpt
        on_straggler: Optional[Callable[[int, float], None]] = None,
        shard_fn: Optional[Callable[[Any], Any]] = None,  # re-shard after restore
    ):
        self.cfg = cfg
        self.init_fn = init_fn
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.flush_fn = flush_fn
        self.on_straggler = on_straggler
        self.shard_fn = shard_fn
        self.detector = StragglerDetector(factor=cfg.straggler_factor)
        self.checkpointer = (
            ckpt_lib.Checkpointer(cfg.ckpt_dir, keep=cfg.ckpt_keep) if cfg.ckpt_dir else None
        )
        self.history: List[Dict[str, float]] = []

    # -- state bootstrap -----------------------------------------------------
    def _bootstrap(self):
        state = self.init_fn()
        start = 0
        if self.checkpointer is not None:
            try:
                restored, start = self.checkpointer.restore_latest(state)
                state = restored
                if self.shard_fn is not None:
                    state = self.shard_fn(state)  # elastic: new mesh, same logical state
            except FileNotFoundError:
                pass
        return state, start

    def run(self) -> Any:
        cfg = self.cfg
        state, start = self._bootstrap()
        if start >= cfg.max_steps:
            return state
        prefetch = Prefetcher(self.make_batch, start_step=start, depth=cfg.prefetch_depth)
        try:
            for step_i, batch in prefetch:
                if step_i >= cfg.max_steps:
                    break
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                # block on one scalar so step time is real, rest stays async
                loss = float(jax.device_get(metrics["loss"]))
                dt = time.perf_counter() - t0
                if self.detector.observe(dt) and self.on_straggler:
                    self.on_straggler(step_i, dt)
                if cfg.assert_no_uniq_overflow and "uniq_overflows" in metrics:
                    n_over = int(jax.device_get(metrics["uniq_overflows"]))
                    if n_over:
                        raise RuntimeError(
                            f"cache unique-buffer overflow at step {step_i}: raise "
                            f"max_unique_per_step (per-table TableConfig bound, or the "
                            f"arena bound for GROUPED tables — exactness violated otherwise)"
                        )
                rec = {"step": step_i, "loss": loss, "time_s": dt}
                for k in ("auc", "hit_rate", "cache_evictions", "grad_norm", "xent"):
                    if k in metrics:
                        rec[k] = float(jax.device_get(metrics[k]))
                self.history.append(rec)
                last = step_i + 1 >= cfg.max_steps
                if self.checkpointer and (
                    (step_i + 1) % cfg.ckpt_every == 0 or last
                ):
                    to_save = state
                    if self.flush_fn is not None:
                        to_save = self.flush_fn(state)
                        state = to_save  # flushed state stays valid to train on
                    self.checkpointer.save_async(step_i + 1, to_save)
            if self.checkpointer:
                self.checkpointer.wait()
        finally:
            prefetch.close()
        return state
