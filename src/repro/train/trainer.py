"""Training loop with the fault-tolerance features required at pod scale:

  * checkpoint/restart — async atomic checkpoints every N steps; on start the
    loop auto-resumes from the newest complete checkpoint (crash-safe), and
    because restore returns logical arrays, a restart may use a different
    mesh (elastic rescale) — shardings are re-applied here.
  * cached-embedding consistency — models with software-cache tiers get
    ``flush_fn`` called before every checkpoint so the slow tiers are
    authoritative (the caches stay warm); collection-era models pass
    ``model.flush`` (an ``EmbeddingCollection.flush`` over every cached
    slab), single-arena models wrap ``cached_embedding.flush_state``.
  * straggler detection — per-step wall times feed an EWMA + deviation
    monitor; steps slower than ``straggler_factor`` x the smoothed time fire
    ``on_straggler`` (log/report/abort — pluggable; on a real pod this wires
    into the coordinator's slow-host eviction).
  * overlap — host batch generation runs in a Prefetcher thread, and JAX
    async dispatch keeps device compute ahead of the Python loop; with
    ``TrainerConfig.pipeline_depth > 0`` the ``PipelinedTrainer`` runs the
    cache-prepare stage of step t+1 overlapped with step t's dense compute:
    planning (dedup + slot assignment + movement plan) reads only ids and
    cache index state, so it is dispatched before the trainer blocks on step
    t's loss, and the Prefetcher's lookahead window lets it prefetch rows
    needed k steps ahead (BagPipe, arXiv 2202.12429).  The serial ``Trainer``
    remains the bit-exactness oracle: both paths produce identical losses.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.data.pipeline import Prefetcher
from repro.obs import NULL_TRACER, MetricsHub, Tracer
from repro.train import checkpoint as ckpt_lib

__all__ = ["TrainerConfig", "Trainer", "PipelinedTrainer", "StragglerDetector"]


@dataclasses.dataclass
class StragglerDetector:
    """EWMA step-time monitor; flags abnormal steps (slow host / bad chip)."""

    factor: float = 3.0
    alpha: float = 0.1
    warmup: int = 5
    ewma: float = 0.0
    count: int = 0
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        self.count += 1
        if self.count <= self.warmup:
            self.ewma = dt if self.ewma == 0 else (1 - self.alpha) * self.ewma + self.alpha * dt
            return False
        slow = dt > self.factor * max(self.ewma, 1e-9)
        if slow:
            self.flagged += 1
        else:  # stragglers don't poison the mean
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


@dataclasses.dataclass
class TrainerConfig:
    max_steps: int
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    ckpt_keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    prefetch_depth: int = 2
    assert_no_uniq_overflow: bool = True
    # 0 = serial (one fused step_fn per step).  k >= 1 enables the pipelined
    # path (``PipelinedTrainer``): step t+1's cache plan is dispatched while
    # step t's dense compute runs, with the ids of the next k batches merged
    # into each plan so rows needed at t+k are prefetched before they miss.
    pipeline_depth: int = 0
    # None = static frequency ranking (the paper).  N = run the adaptive
    # re-ranking refresh (``refresh_fn``, usually ``model.refresh``) every N
    # steps — the serial trainer refreshes exactly on the cadence; the
    # pipelined trainer refreshes at the first GROUP BOUNDARY at or past each
    # multiple of N (a merged plan's addresses must never straddle a refresh).
    # Refresh is pure reindexing, so fp32 losses are bit-identical either way.
    refresh_interval: Optional[int] = None
    # -- observability -------------------------------------------------------
    # None keeps the hub sink-less: exact counters still accumulate, nothing
    # is written and span tracing is the zero-cost NULL_TRACER.  With a
    # directory, per-step records, the span aggregate, and the step-time
    # histogram stream to <obs_dir>/<obs_run>.jsonl and a Chrome trace is
    # exported at exit (render with ``python -m repro.obs.report``).
    obs_dir: Optional[str] = None
    obs_run: str = "train"
    # forward spans into jax.profiler.TraceAnnotation so the same stage names
    # label the device timeline under a ``jax.profiler.trace`` capture
    obs_annotate: bool = False
    # None = unbounded in-memory history (legacy behavior).  N = keep only
    # the last N records in memory; with obs_dir set the full stream is on
    # disk anyway, so long runs stop accumulating O(steps) host memory.
    history_limit: Optional[int] = None


class Trainer:
    def __init__(
        self,
        cfg: TrainerConfig,
        init_fn: Callable[[], Any],  # () -> state
        step_fn: Callable[[Any, Dict], Any],  # (state, batch) -> (state, metrics); jitted
        make_batch: Callable[[int], Dict],  # step -> host batch
        flush_fn: Optional[Callable[[Any], Any]] = None,  # cache barrier pre-ckpt
        on_straggler: Optional[Callable[[int, float], None]] = None,
        shard_fn: Optional[Callable[[Any], Any]] = None,  # re-shard after restore
        refresh_fn: Optional[Callable[[Any], Any]] = None,  # adaptive re-rank
        # ^ host-side pure-reindexing pass (``model.refresh``), run every
        #   ``cfg.refresh_interval`` steps (pipelined: at group boundaries)
    ):
        self.cfg = cfg
        self.init_fn = init_fn
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.flush_fn = flush_fn
        self.on_straggler = on_straggler
        self.shard_fn = shard_fn
        self.refresh_fn = refresh_fn
        self.detector = StragglerDetector(factor=cfg.straggler_factor)
        self.checkpointer = (
            ckpt_lib.Checkpointer(cfg.ckpt_dir, keep=cfg.ckpt_keep) if cfg.ckpt_dir else None
        )
        self.history: List[Dict[str, float]] = []
        # ONE wrap-safe reconstruction point for every cumulative in-jit
        # int32 counter (hits/misses, host rows/bytes, exchange lanes/bytes,
        # refresh swaps): the hub accumulates exact Python-int totals even
        # with no sink; with cfg.obs_dir it also streams the run's JSONL.
        self.hub = MetricsHub(run_dir=cfg.obs_dir, run=cfg.obs_run)
        self.tracer = (
            Tracer(annotate=cfg.obs_annotate)
            if (cfg.obs_dir or cfg.obs_annotate)
            else NULL_TRACER
        )
        self.trace_path: Optional[str] = None

    # -- state bootstrap -----------------------------------------------------
    def _bootstrap(self):
        state = self.init_fn()
        start = 0
        if self.checkpointer is not None:
            try:
                restored, start = self.checkpointer.restore_latest(state)
                state = restored
                if self.shard_fn is not None:
                    state = self.shard_fn(state)  # elastic: new mesh, same logical state
            except FileNotFoundError:
                pass
        return state, start

    # -- shared per-step bookkeeping (both execution paths) ------------------
    def _post_step(self, step_i: int, state: Any, metrics: Dict, t0: float) -> Any:
        """Block on the loss scalar, record history, run the straggler /
        overflow monitors and the checkpoint cadence; returns the (possibly
        flushed) state."""
        cfg = self.cfg
        # block on one scalar so step time is real, rest stays async; this
        # fetch is the step's ONE deliberate device->host sync point (its own
        # span so the wall-clock profile shows where the loop blocks)
        with self.tracer.span("host-transfer"):
            loss = float(jax.device_get(metrics["loss"]))
        dt = time.perf_counter() - t0
        if self.detector.observe(dt) and self.on_straggler:
            self.on_straggler(step_i, dt)
        if cfg.assert_no_uniq_overflow and "uniq_overflows" in metrics:
            n_over = int(jax.device_get(metrics["uniq_overflows"]))
            if n_over:
                raise RuntimeError(
                    f"cache unique-buffer overflow at step {step_i}: raise "
                    f"max_unique_per_step (per-table TableConfig bound, or the "
                    f"arena bound for GROUPED tables — exactness violated otherwise)"
                )
        rec: Dict[str, Any] = {"step": step_i, "loss": loss, "time_s": dt}
        float_keys = [
            k
            for k in ("auc", "hit_rate", "cache_evictions", "grad_norm",
                      "xent", "shard_imbalance", "window_hit_rate",
                      "refresh_swaps", "refresh_rows_moved")
            if k in metrics
        ]
        if float_keys:  # one fetch for all float telemetry, not one per key
            fetched = jax.device_get({k: metrics[k] for k in float_keys})
            rec.update({k: float(v) for k, v in fetched.items()})
        # every cumulative int32 counter family in the metrics dict —
        # hits/misses, host rows and ENCODED wire bytes, exchange lanes and
        # id/row-leg bytes, refresh swaps — reconstructs to exact wrap-free
        # Python ints through the hub (the one family table lives in
        # repro.obs.hub; hit_rate_exact rides along when both hit families
        # are present).  A float32 accumulator loses integer resolution past
        # 2^24 and the in-jit int32 counters wrap past 2^31; neither survives
        # a long run, which is why everything routes through the hub.
        rec.update(self.hub.observe_embedding_metrics(metrics))
        if "host_wire_bytes" not in rec and "host_wire_bytes" in metrics:
            # legacy metrics dicts carry only the float32 scalar fallback
            rec["host_wire_bytes"] = float(jax.device_get(metrics["host_wire_bytes"]))
        self.hub.histogram("step_time_s").observe(dt)
        self.hub.log(
            "step",
            {k: v for k, v in rec.items() if k != "time_s"},
            wall={"time_s": dt},
        )
        self.history.append(rec)
        if cfg.history_limit is not None and len(self.history) > cfg.history_limit:
            # tests index and slice history, so it stays a plain list; trim
            # the head in place to bound host memory on long runs
            del self.history[: len(self.history) - cfg.history_limit]
        last = step_i + 1 >= cfg.max_steps
        if self.checkpointer and ((step_i + 1) % cfg.ckpt_every == 0 or last):
            with self.tracer.span("checkpoint"):
                to_save = state
                if self.flush_fn is not None:
                    to_save = self.flush_fn(state)
                    state = to_save  # flushed state stays valid to train on
                self.checkpointer.save_async(step_i + 1, to_save)
        return state

    def _finish_obs(self) -> None:
        """Flush the run's observability artifacts — the step-time histogram,
        the span aggregate, the counter summary, and the Chrome trace.
        Idempotent and called from the run loop's ``finally`` so a crashed
        run still leaves a renderable JSONL."""
        self.hub.log_hist("step_time_s")
        self.hub.log_spans(self.tracer)
        if self.cfg.obs_dir:
            self.trace_path = self.tracer.export_chrome_trace(
                os.path.join(self.cfg.obs_dir, f"{self.cfg.obs_run}.trace.json")
            )
        self.hub.close()

    def run(self) -> Any:
        cfg = self.cfg
        state, start = self._bootstrap()
        if start >= cfg.max_steps:
            self._finish_obs()
            return state
        prefetch = Prefetcher(self.make_batch, start_step=start, depth=cfg.prefetch_depth)
        try:
            for step_i, batch in prefetch:
                if step_i >= cfg.max_steps:
                    break
                t0 = time.perf_counter()
                with self.tracer.span("step"):
                    state, metrics = self.step_fn(state, batch)
                state = self._post_step(step_i, state, metrics, t0)
                if (
                    self.refresh_fn is not None
                    and cfg.refresh_interval
                    and (step_i + 1) % cfg.refresh_interval == 0
                    and step_i + 1 < cfg.max_steps
                ):
                    with self.tracer.span("refresh"):
                        state = self.refresh_fn(state)
            if self.checkpointer:
                self.checkpointer.wait()
        finally:
            prefetch.close()
            self._finish_obs()
        return state


class PipelinedTrainer(Trainer):
    """Two-stage pipelined execution with lookahead cache prefetch.

    The fused step is split into the model's three stages:

      ``plan_fn(state, batch, future_batches) -> plan``   weight-free: dedup,
          slot assignment, movement plan; merges the lookahead window's ids so
          rows needed k steps ahead load early and are pinned until used.
      ``compute_fn(state, batch, addresses) -> (state, metrics)``   dense
          fwd/bwd + optimizer + synchronous row update.
      ``apply_fn(state, plan) -> state``   executes the planned row movement.

    Steps run in GROUPS of ``pipeline_depth``: one merged plan admits the
    whole group's rows (addresses for every member come from the same plan),
    so the per-step bookkeeping — dedup, victim argsort, transmitter rounds —
    is paid once per group instead of once per step.  The next group's plan is
    dispatched at the FIRST compute of the current group, before the trainer
    blocks on any loss: planning reads only ids and cache index arrays (which
    the compute step passes through untouched), so a multi-stream runtime is
    free to overlap it with the dense work, and the prepare stage leaves the
    loss-to-loss critical path either way.  Its row movement is applied after
    the group's last row update, so evictions write back fresh values.

    ``pipeline_depth=1`` is the pure BagPipe pipeline (plan t+1 under compute
    t); larger depths add the amortization.  Because planning never reads
    weights and compute never reads the index arrays, any depth is
    loss-bit-identical to the serial ``Trainer`` (tested property) when the
    host tier stores fp32.  With a lossy host codec (fp16/int8 ``HostStore``)
    the schedules agree only to codec noise: lookahead pinning keeps a
    soon-needed row resident where the serial schedule would evict
    (quantize) and reload (dequantize) it, so the pipelined path sees
    strictly FEWER quantization round trips — same parity tolerance, not
    bitwise equality.

    The exact ids of future batches come from ``Prefetcher.lookahead`` — the
    BagPipe observation that training data is read ahead anyway, so there is
    nothing speculative about prefetching embedding rows.  Running a group off
    one plan needs the union of its unique rows to fit the cache: the trainer
    checks the plan's ``future_unresident`` counter and fails fast with the
    remedy (raise the cache ratio or lower ``pipeline_depth``) instead of
    silently gathering zeros.

    Telemetry caveat: cache hit/miss counters are recorded by the plan, so
    under group scheduling they sample only the group leaders' batches (1/k
    of the traffic); losses and transfer correctness are unaffected.
    """

    def __init__(
        self,
        cfg: TrainerConfig,
        init_fn: Callable[[], Any],
        plan_fn: Callable[[Any, Dict, tuple], Any],  # jitted (state, batch, window)
        compute_fn: Callable[[Any, Dict, Any], Any],  # jitted (state, batch, addresses)
        apply_fn: Callable[[Any, Any], Any],  # jitted (state, plan)
        make_batch: Callable[[int], Dict],
        flush_fn: Optional[Callable[[Any], Any]] = None,
        on_straggler: Optional[Callable[[int, float], None]] = None,
        shard_fn: Optional[Callable[[Any], Any]] = None,
        refresh_fn: Optional[Callable[[Any], Any]] = None,
    ):
        super().__init__(
            cfg,
            init_fn,
            step_fn=None,
            make_batch=make_batch,
            flush_fn=flush_fn,
            on_straggler=on_straggler,
            shard_fn=shard_fn,
            refresh_fn=refresh_fn,
        )
        self.plan_fn = plan_fn
        self.compute_fn = compute_fn
        self.apply_fn = apply_fn

    @staticmethod
    def _take(prefetch, n: int) -> list:
        """Up to ``n`` (step, batch) pairs; a short list means the stream
        ended (mirrors ``Prefetcher.lookahead``'s contract)."""
        out = []
        for _ in range(n):
            try:
                out.append(next(prefetch))
            except StopIteration:
                break
        return out

    def _check_window(self, plan, group) -> None:
        """A group runs off one merged plan only if every member's rows made
        residency — fail fast with the remedy otherwise."""
        if len(group) <= 1:
            return
        n = int(jax.device_get(plan.future_unresident))
        if n:
            raise RuntimeError(
                f"pipelined group of {len(group)} steps needs all its unique rows "
                f"resident at once, but {n} lookahead lanes were dropped under "
                f"capacity pressure: raise the cache ratio or lower "
                f"TrainerConfig.pipeline_depth"
            )

    def run(self) -> Any:
        cfg = self.cfg
        depth = max(1, cfg.pipeline_depth)
        state, start = self._bootstrap()
        if start >= cfg.max_steps:
            self._finish_obs()
            return state
        prefetch = Prefetcher(
            self.make_batch, start_step=start, depth=max(cfg.prefetch_depth, depth)
        )
        try:
            group = self._take(prefetch, min(depth, cfg.max_steps - start))
            if not group:  # stream ended before the first step
                return state
            # prologue: the first group has no shadow to plan under
            with self.tracer.span("plan"):
                plan = self.plan_fn(
                    state, group[0][1], tuple(b for _, b in group[1:])
                )
            self._check_window(plan, group)
            with self.tracer.span("apply"):
                state = self.apply_fn(state, plan)
            addrs = (plan.addresses,) + tuple(plan.future_addresses)
            refresh_on = self.refresh_fn is not None and cfg.refresh_interval
            # align the cadence to ABSOLUTE step numbers so a checkpoint
            # restore resumes the same refresh schedule (the serial trainer's
            # modulo check is restore-aligned by construction)
            next_refresh_at = (
                (start // cfg.refresh_interval + 1) * cfg.refresh_interval
                if refresh_on
                else None
            )
            while group:
                next_plan = None
                last_step = group[-1][0]
                n_next = min(depth, cfg.max_steps - (last_step + 1))
                # refresh only at GROUP BOUNDARIES: a merged plan's addresses
                # are computed against one index image, so a group must never
                # straddle the re-rank.  When a refresh falls due inside this
                # group, the next group's plan is NOT dispatched early — it is
                # planned after the refresh, from the refreshed index state
                # (one serial prepare per refresh_interval steps).
                refresh_now = (
                    refresh_on
                    and last_step + 1 >= next_refresh_at
                    and n_next > 0
                )
                for j, (step_i, batch) in enumerate(group):
                    t0 = time.perf_counter()
                    if j == 0 and n_next > 0 and not refresh_now:
                        # dispatch the NEXT group's merged plan before blocking
                        # on any of this group's losses — planning reads only
                        # ids + index state, so it overlaps the dense compute.
                        # A short peek means the STREAM ENDED (the lookahead
                        # contract): the final group shrinks to what is left
                        # rather than planning batches that will never come.
                        peek = prefetch.lookahead(n_next)
                        n_next = len(peek)
                        if peek:
                            with self.tracer.span("plan"):
                                next_plan = self.plan_fn(
                                    state, peek[0][1],
                                    tuple(b for _, b in peek[1:]),
                                )
                    with self.tracer.span("compute"):
                        state, metrics = self.compute_fn(state, batch, addrs[j])
                    if j == len(group) - 1 and next_plan is not None:
                        # movement runs after the group's last row update:
                        # evictions write back the freshest values
                        with self.tracer.span("apply"):
                            state = self.apply_fn(state, next_plan)
                    state = self._post_step(step_i, state, metrics, t0)
                if refresh_now:
                    with self.tracer.span("refresh"):
                        state = self.refresh_fn(state)
                    done = last_step + 1
                    next_refresh_at = (
                        done // cfg.refresh_interval + 1
                    ) * cfg.refresh_interval
                    peek = prefetch.lookahead(n_next)
                    n_next = len(peek)
                    if peek:
                        with self.tracer.span("plan"):
                            next_plan = self.plan_fn(
                                state, peek[0][1], tuple(b for _, b in peek[1:])
                            )
                        with self.tracer.span("apply"):
                            state = self.apply_fn(state, next_plan)
                if next_plan is None:
                    break
                group = self._take(prefetch, n_next)
                self._check_window(next_plan, group)
                addrs = (next_plan.addresses,) + tuple(next_plan.future_addresses)
            if self.checkpointer:
                self.checkpointer.wait()
        finally:
            prefetch.close()
            self._finish_obs()
        return state
