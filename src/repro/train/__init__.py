from repro.train.checkpoint import Checkpointer, latest_step, restore, save
from repro.train.trainer import StragglerDetector, Trainer, TrainerConfig
