"""Synthetic graphs (power-law degree), CSR utilities, sampled-block batches."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

from repro.nn.gnn import neighbor_sample

__all__ = ["random_graph_csr", "full_graph_batch", "sampled_batch", "molecule_batch"]


def random_graph_csr(n_nodes: int, n_edges: int, seed: int = 0):
    """Power-law-ish random graph as CSR (duplicates allowed, like real logs)."""
    rng = np.random.default_rng(seed)
    # preferential-attachment-flavoured endpoints
    dst = (rng.pareto(1.5, n_edges) * n_nodes / 20).astype(np.int64) % n_nodes
    src = rng.integers(0, n_nodes, n_edges)
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, dst + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, src.astype(np.int64), (src.astype(np.int32), dst.astype(np.int32))


def full_graph_batch(
    n_nodes: int, n_edges: int, d_feat: int, n_classes: int, seed: int = 0
) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    _, _, (src, dst) = random_graph_csr(n_nodes, n_edges, seed)
    feat = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    # labels correlated with neighborhood mean feature sign
    label = (feat[:, 0] > 0).astype(np.int32) % n_classes
    mask = (rng.random(n_nodes) < 0.5).astype(np.int32)
    return {"feat": feat, "src": src, "dst": dst, "label": label, "label_mask": mask}


def sampled_batch(
    indptr: np.ndarray,
    indices: np.ndarray,
    feats: np.ndarray,
    labels: np.ndarray,
    batch_nodes: int,
    fanouts: Tuple[int, ...],
    seed: int,
    step: int,
) -> Dict[str, np.ndarray]:
    """Neighbor-sampled block for minibatch training (static shapes)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    n = len(indptr) - 1
    seeds = rng.integers(0, n, batch_nodes)
    nodes, src, dst, n_seed = neighbor_sample(indptr, indices, seeds, fanouts, rng)
    feat = feats[nodes]
    label = np.zeros(len(nodes), np.int32)
    label[:n_seed] = labels[seeds]
    mask = np.zeros(len(nodes), np.int32)
    mask[:n_seed] = 1
    return {"feat": feat.astype(np.float32), "src": src, "dst": dst, "label": label, "label_mask": mask}


def molecule_batch(
    n_graphs: int, max_nodes: int, max_edges: int, d_feat: int, seed: int, step: int
) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    tot_n, tot_e = n_graphs * max_nodes, n_graphs * max_edges
    feat = rng.normal(size=(tot_n, d_feat)).astype(np.float32)
    graph_id = np.repeat(np.arange(n_graphs, dtype=np.int32), max_nodes)
    node_mask = np.ones(tot_n, np.int32)
    src = np.zeros(tot_e, np.int32)
    dst = np.zeros(tot_e, np.int32)
    for g in range(n_graphs):
        nn = rng.integers(max_nodes // 2, max_nodes + 1)
        ne = rng.integers(max_edges // 2, max_edges + 1)
        s = rng.integers(0, nn, ne) + g * max_nodes
        d = rng.integers(0, nn, ne) + g * max_nodes
        src[g * max_edges : g * max_edges + ne] = s
        dst[g * max_edges : g * max_edges + ne] = d
        src[g * max_edges + ne : (g + 1) * max_edges] = -1
        dst[g * max_edges + ne : (g + 1) * max_edges] = -1
        node_mask[g * max_nodes + nn : (g + 1) * max_nodes] = 0
    label = rng.normal(size=n_graphs).astype(np.float32)
    return {
        "feat": feat,
        "src": src,
        "dst": dst,
        "graph_id": graph_id,
        "node_mask": node_mask,
        "label": label,
    }
