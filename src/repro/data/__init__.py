from repro.data.pipeline import Prefetcher
from repro.data import graphs, synth
