"""Synthetic datasets with the paper's access-skew characteristics.

Criteo/Avazu-like sparse streams use Zipf-distributed ids (paper Fig. 2: top
0.14% / 0.012% of ids cover ~90% of accesses — our generator's skew exponent
is calibrated so the benchmark reproduces that coverage curve), plus label
models that make AUROC move during training so accuracy-parity experiments
are meaningful.  Everything is step-seeded: batch ``i`` is a pure function of
(seed, i), which is what makes checkpoint-resume exact.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "ZipfSparseSpec",
    "DriftingZipfSpec",
    "sparse_batch",
    "drifting_sparse_batch",
    "seq_batch",
    "recsys_batch",
    "count_stream",
]


@dataclasses.dataclass(frozen=True)
class ZipfSparseSpec:
    vocab_sizes: Tuple[int, ...]
    zipf_a: float = 1.2  # calibrated: ~90% of accesses to top <1% of ids
    n_dense: int = 0


def _zipf_ids(rng: np.random.Generator, vocab: int, size, a: float) -> np.ndarray:
    """Zipf over [0, vocab): ranked id r has p ~ (r+1)^-a (id == popularity rank)."""
    # inverse-CDF sampling on the truncated zipf
    u = rng.random(size)
    # approximate inverse of normalized harmonic CDF via exponent transform:
    if a == 1.0:
        ids = np.exp(u * np.log(vocab)) - 1.0
    else:
        h = (vocab ** (1.0 - a) - 1.0) / (1.0 - a)
        ids = ((u * h * (1.0 - a)) + 1.0) ** (1.0 / (1.0 - a)) - 1.0
    return np.clip(ids.astype(np.int64), 0, vocab - 1)


def sparse_batch(
    spec: ZipfSparseSpec,
    batch: int,
    seed: int,
    step: int,
    id_shift: Optional[np.ndarray] = None,
) -> Dict[str, np.ndarray]:
    """Criteo-style batch: one id per field + dense features + clicky label.

    ``id_shift`` (optional int64 [fields]) rotates each field's id space by a
    per-field offset AFTER popularity sampling and BEFORE the label model —
    the popularity RANKING moves but the skew shape doesn't, which is how
    :func:`drifting_sparse_batch` models hot-set drift.  ``None`` is
    bit-identical to the historical generator (same rng draw order)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    f = len(spec.vocab_sizes)
    sparse = np.stack(
        [_zipf_ids(rng, v, batch, spec.zipf_a) for v in spec.vocab_sizes], axis=1
    ).astype(np.int32)
    if id_shift is not None:
        vocabs = np.asarray(spec.vocab_sizes, dtype=np.int64)
        sparse = ((sparse.astype(np.int64) + id_shift) % vocabs).astype(np.int32)
    out: Dict[str, np.ndarray] = {"sparse": sparse}
    if spec.n_dense:
        out["dense"] = rng.normal(size=(batch, spec.n_dense)).astype(np.float32)
    # label depends on a hidden linear function of (hashed) ids so AUROC is learnable
    h = ((sparse * np.arange(1, f + 1)) % 97).sum(1) / (97.0 * f)
    noise = rng.normal(scale=0.3, size=batch)
    out["label"] = ((h + noise) > 0.5).astype(np.float32)
    return out


@dataclasses.dataclass(frozen=True)
class DriftingZipfSpec:
    """A Zipf sparse stream whose HOT SET moves: every ``drift_every`` steps
    the popularity ranking rotates by ``shift_fraction`` of each vocab (phase
    ``p`` maps sampled popularity-rank ``r`` to id ``(r + p * shift) % vocab``).

    The skew shape (coverage curve) is phase-invariant — only WHICH ids are
    hot changes, making this the canonical stress case for the static
    frequency module (its FREQ_LFU rank goes stale at every phase change) and
    the recovery case for the adaptive refresh engine.  Still step-seeded:
    batch ``i`` is a pure function of (seed, i), so checkpoint-resume stays
    exact and every data rank derives the same stream.
    """

    base: ZipfSparseSpec
    drift_every: int = 200  # steps per popularity phase
    shift_fraction: float = 0.37  # hot-set rotation per phase (per vocab);
    # irrational-ish so successive phases' hot sets don't re-align quickly

    def shifts(self, step: int) -> np.ndarray:
        """Per-field id rotation of the phase containing ``step``."""
        phase = step // self.drift_every
        vocabs = np.asarray(self.base.vocab_sizes, dtype=np.int64)
        per_phase = np.maximum(
            (self.shift_fraction * vocabs).astype(np.int64), 1
        )
        return (phase * per_phase) % vocabs


def drifting_sparse_batch(
    spec: DriftingZipfSpec, batch: int, seed: int, step: int
) -> Dict[str, np.ndarray]:
    """``sparse_batch`` under hot-set drift: same skew, rotating hot ids.

    Phase 0 (``step < drift_every``) is bit-identical to the un-drifted
    generator, so frequency stats collected there are honestly stale — not
    merely wrong — after the first phase change."""
    return sparse_batch(spec.base, batch, seed, step, id_shift=spec.shifts(step))


def recsys_batch(
    n_items: int,
    n_users: int,
    seq_len: int,
    batch: int,
    seed: int,
    step: int,
    n_cates: Optional[int] = None,
    zipf_a: float = 1.2,
) -> Dict[str, np.ndarray]:
    """DIN/DIEN/MIND-style behaviour batch with zipf-popular items."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    hist = _zipf_ids(rng, n_items, (batch, seq_len), zipf_a).astype(np.int32)
    hist_len = rng.integers(5, seq_len + 1, size=batch).astype(np.int32)
    target = _zipf_ids(rng, n_items, batch, zipf_a).astype(np.int32)
    user = rng.integers(0, n_users, size=batch).astype(np.int32)
    # label: does target "match" the user's dominant history bucket?
    aff = (hist % 17 == (target % 17)[:, None]).mean(1)
    label = (aff + rng.normal(scale=0.2, size=batch) > 0.12).astype(np.float32)
    out = {
        "hist_items": hist,
        "hist_len": hist_len,
        "target_item": target,
        "user": user,
        "label": label,
    }
    if n_cates is not None:
        out["hist_cates"] = (hist % n_cates).astype(np.int32)
        out["target_cate"] = (target % n_cates).astype(np.int32)
    return out


def seq_batch(vocab: int, batch: int, seq: int, seed: int, step: int) -> Dict[str, np.ndarray]:
    """LM token stream (markov-ish so loss decreases)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    toks = rng.integers(0, vocab, size=(batch, seq + 1), dtype=np.int64)
    # make it predictable: next token often (prev*7+3) % vocab
    for t in range(1, seq + 1):
        m = rng.random(batch) < 0.7
        toks[m, t] = (toks[m, t - 1] * 7 + 3) % vocab
    return {"tokens": toks[:, :-1].astype(np.int32), "labels": toks[:, 1:].astype(np.int32)}


def count_stream(spec: ZipfSparseSpec, batch: int, n_steps: int, seed: int):
    """Iterator of id matrices for frequency collection (paper §4.2 'scan')."""
    offsets = np.concatenate([[0], np.cumsum(spec.vocab_sizes)[:-1]])
    for i in range(n_steps):
        b = sparse_batch(spec, batch, seed, i)
        yield (b["sparse"].astype(np.int64) + offsets).reshape(-1)
