"""Host data pipeline: background prefetch + exact checkpoint-resume.

Batches are pure functions of (seed, step) so resuming at step N after a
restart replays the identical stream on any topology — a requirement for
elastic rescaling (DESIGN.md §5).  A small thread pool prefetches ``depth``
batches ahead so host-side generation (incl. neighbor sampling) overlaps
device compute, complementing JAX's async dispatch.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np

__all__ = ["Prefetcher"]


class Prefetcher:
    """Wrap ``make_batch(step) -> dict`` with background prefetch from ``start_step``."""

    def __init__(self, make_batch: Callable[[int], Dict], start_step: int = 0, depth: int = 2):
        self.make_batch = make_batch
        self.depth = depth
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._next
        while not self._stop.is_set():
            try:
                batch = self.make_batch(step)
            except Exception as e:  # surface in consumer
                self._q.put(e)
                return
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        item = self._q.get()
        if isinstance(item, Exception):
            raise item
        return item  # (step, batch)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
