"""Host data pipeline: background prefetch + exact checkpoint-resume.

Batches are pure functions of (seed, step) so resuming at step N after a
restart replays the identical stream on any topology — a requirement for
elastic rescaling (DESIGN.md §5).  A worker thread prefetches ``depth``
batches ahead so host-side generation (incl. neighbor sampling) overlaps
device compute, complementing JAX's async dispatch.

Because batches are generated ahead of consumption anyway, the exact ids of
FUTURE batches are known before their step runs (the BagPipe observation,
arXiv 2202.12429): ``lookahead(k)`` exposes the next k batches without
consuming them, which is what lets the pipelined trainer plan step t+1's
cache movement — and prefetch rows needed at t+k — while step t's dense
compute is still in flight.
"""
from __future__ import annotations

import collections
import threading
from typing import Callable, Dict, Iterator, List, Tuple

__all__ = ["Prefetcher"]


class Prefetcher:
    """Wrap ``make_batch(step) -> dict`` with background prefetch from ``start_step``.

    Iteration yields ``(step, batch)`` in order; ``lookahead(k)`` peeks the
    batches the next k ``__next__`` calls would return, blocking until the
    worker has generated them.  ``close()`` stops and *joins* the worker (a
    drain-only shutdown races with a worker that refills after the drain,
    leaking a blocked daemon thread per trainer run).

    End of stream: ``make_batch`` may raise ``StopIteration`` to end a finite
    stream.  Already-buffered batches stay consumable; ``__next__`` then ends
    iteration cleanly and ``lookahead`` returns only what remains.  Any other
    exception is an ERROR and re-raises in the consumer, in stream order.
    """

    def __init__(self, make_batch: Callable[[int], Dict], start_step: int = 0, depth: int = 2):
        self.make_batch = make_batch
        self.depth = max(1, depth)
        self._buf: "collections.deque" = collections.deque()
        self._cv = threading.Condition()
        self._err: Exception | None = None
        self._done = False  # producer raised StopIteration (clean end)
        self._stop = False
        self._start = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._start
        while True:
            with self._cv:
                while len(self._buf) >= self.depth and not self._stop:
                    self._cv.wait()
                if self._stop:
                    return
            try:
                batch = self.make_batch(step)
            except StopIteration:  # clean end of a finite stream
                with self._cv:
                    self._done = True
                    self._cv.notify_all()
                return
            except Exception as e:  # surface in consumer, in stream order
                with self._cv:
                    self._err = e
                    self._cv.notify_all()
                return
            with self._cv:
                if self._stop:
                    return
                self._buf.append((step, batch))
                self._cv.notify_all()
            step += 1

    @property
    def exhausted(self) -> bool:
        """True once the producer has cleanly ended the stream (batches may
        still be buffered — iteration drains them before stopping)."""
        with self._cv:
            return self._done

    def __iter__(self) -> Iterator:
        return self

    def __next__(self) -> Tuple[int, Dict]:
        with self._cv:
            while (
                not self._buf and self._err is None and not self._done and not self._stop
            ):
                self._cv.wait()
            if self._buf:
                item = self._buf.popleft()
                self._cv.notify_all()  # free a slot for the worker
                return item
            if self._err is not None:
                raise self._err
            raise StopIteration  # stream ended or prefetcher closed

    def lookahead(self, k: int) -> List[Tuple[int, Dict]]:
        """Peek the next ``k`` (step, batch) pairs without consuming them.

        Contract (the pipelined trainer's group scheduler relies on it):

          * "not yet produced" BLOCKS — the call waits for the worker, it
            never returns a short list just because generation is behind;
          * "stream ended" returns the SHORT list of whatever remains
            (possibly empty) — a result shorter than ``k`` always means the
            producer finished, so the caller shrinks its final group instead
            of treating a mid-epoch stall as "no future ids";
          * a producer ERROR raises here once fewer than ``k`` batches remain
            (already-buffered batches stay consumable through ``__next__``);
          * peeking a CLOSED prefetcher raises ``RuntimeError`` — the old
            behavior (silent short list) was indistinguishable from end of
            stream.

        Requires ``k <= depth`` (the buffer can never hold more).
        """
        if k <= 0:
            return []
        if k > self.depth:
            raise ValueError(f"lookahead({k}) exceeds prefetch depth {self.depth}")
        with self._cv:
            while (
                len(self._buf) < k
                and self._err is None
                and not self._done
                and not self._stop
            ):
                self._cv.wait()
            if len(self._buf) < k:
                if self._err is not None:
                    raise self._err
                # a cleanly-ended stream keeps its short-list contract even
                # after close(); only an un-ended (cancelled) stream raises
                if self._stop and not self._done:
                    raise RuntimeError("lookahead on a closed Prefetcher")
            return [self._buf[i] for i in range(min(k, len(self._buf)))]

    def close(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        # bounded join: the worker is a daemon, so if it is wedged inside a
        # blocking make_batch we must not hang the caller (often a `finally:`
        # with the real exception in flight) — it dies with the process.
        self._thread.join(timeout=10.0)
        with self._cv:
            self._buf.clear()
