"""Distribution helpers: logical-axis partitioning (``repro.dist.partitioning``)."""
