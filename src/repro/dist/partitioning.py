"""Logical-axis partitioning: named parameter dims resolved to mesh axes.

The scheme (Flax/T5X-style, dependency-free): init functions annotate every
parameter with *logical* dim names by wrapping the value in a ``Param``
(a pytree node whose aux data is the names, so it survives ``jax.vmap`` /
``jax.eval_shape``).  ``split_params`` separates the value tree from the
axes tree; a per-launch *rule table* (``axis_rules``) maps logical names to
mesh axes, turning the axes tree into ``PartitionSpec``s
(``specs_for_axes``) and making in-graph constraints (``constrain``)
resolve against the active mesh.

Nothing here talks to a specific model: models speak logical names
("embed", "heads", "batch", ...), launch code owns the mesh and the rules.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "Param",
    "axis_rules",
    "current_mesh",
    "current_rules",
    "resolve",
    "spec",
    "specs_for_axes",
    "constrain",
    "split_params",
    "prepend_axis",
    "hybrid_rules",
]

AxisName = Optional[str]
MeshAxes = Union[None, str, Tuple[str, ...]]


class Param:
    """A parameter value tagged with logical dim names.

    ``value`` is the array (or ShapeDtypeStruct under ``eval_shape``);
    ``axes`` has one logical name (or None) per dim.  Registered as a pytree
    node with ``axes`` as aux data, so transformations map over ``value``
    while the annotation rides along unchanged — ``jax.vmap`` over an init
    function yields stacked Params (callers then ``prepend_axis`` the new
    leading dim).
    """

    __slots__ = ("value", "axes")

    def __init__(self, value: Any, axes: Sequence[AxisName]):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Param({self.value!r}, axes={self.axes})"


def _param_flatten(p: Param):
    return (p.value,), p.axes


def _param_unflatten(axes, children):
    return Param(children[0], axes)


jax.tree_util.register_pytree_node(Param, _param_flatten, _param_unflatten)


# ---------------------------------------------------------------------------
# active mesh + rule table (thread-local so parallel launches don't collide)
# ---------------------------------------------------------------------------

_SCOPE = threading.local()


def current_mesh():
    """The mesh of the innermost ``axis_rules`` scope (None outside one)."""
    return getattr(_SCOPE, "mesh", None)


def current_rules() -> Dict[str, MeshAxes]:
    return getattr(_SCOPE, "rules", None) or {}


@contextlib.contextmanager
def axis_rules(mesh, rules: Optional[Dict[str, MeshAxes]]):
    """Scope a (mesh, logical-name -> mesh-axes) rule table.

    ``mesh`` may be None (spec resolution only, e.g. building PartitionSpec
    trees host-side); ``constrain`` is a no-op without a mesh.
    """
    prev_mesh = getattr(_SCOPE, "mesh", None)
    prev_rules = getattr(_SCOPE, "rules", None)
    _SCOPE.mesh = mesh
    _SCOPE.rules = dict(rules or {})
    try:
        yield
    finally:
        _SCOPE.mesh = prev_mesh
        _SCOPE.rules = prev_rules


def resolve(name: AxisName) -> MeshAxes:
    """Logical name -> mesh axes under the current rules (unknown -> None)."""
    if name is None:
        return None
    return current_rules().get(name)


def spec(*names: AxisName) -> P:
    """PartitionSpec for logical dim names under the current rules."""
    return P(*(resolve(n) for n in names))


def _is_axes_leaf(x: Any) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)


def specs_for_axes(axes_tree: Any) -> Any:
    """Map an axes tree (from ``split_params``) to PartitionSpecs."""
    return jax.tree_util.tree_map(
        lambda axes: spec(*axes), axes_tree, is_leaf=_is_axes_leaf
    )


def constrain(x: jax.Array, *names: AxisName) -> jax.Array:
    """In-graph sharding constraint by logical names; identity without an
    active mesh/rule scope (single-device tests, host-side code)."""
    mesh = current_mesh()
    rules = current_rules()
    if mesh is None or not rules:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec(*names)))


def _default_axes(leaf: Any) -> Tuple[AxisName, ...]:
    shape = getattr(leaf, "shape", None)
    return (None,) * len(shape) if shape is not None else ()


def split_params(tree: Any) -> Tuple[Any, Any]:
    """Split a Param tree into (values, axes) trees of identical structure.

    Non-Param leaves pass through with all-None (replicated) axes, so trees
    can mix annotated and plain parameters.
    """
    is_leaf = lambda x: isinstance(x, Param)
    values = jax.tree_util.tree_map(
        lambda x: x.value if isinstance(x, Param) else x, tree, is_leaf=is_leaf
    )
    axes = jax.tree_util.tree_map(
        lambda x: x.axes if isinstance(x, Param) else _default_axes(x),
        tree,
        is_leaf=is_leaf,
    )
    return values, axes


def hybrid_rules(
    data_axis: str = "data", model_axis: str = "model"
) -> Dict[str, MeshAxes]:
    """The rule table of the hybrid-parallel recsys layout: the batch shards
    over ``data`` (dense params replicate and train data-parallel), embedding
    shards — the leading dim of a ``ShardedEmbeddingCollection``'s stacked
    slabs — split over ``model``.  Models speak the logical names ("batch",
    "shard"); launch code binds them to whatever mesh it built."""
    return {"batch": (data_axis,), "shard": (model_axis,)}


def prepend_axis(tree: Any, name: AxisName) -> Any:
    """Prepend a logical name to every Param's axes (stacked/vmapped trees)."""
    return jax.tree_util.tree_map(
        lambda x: Param(x.value, (name,) + x.axes) if isinstance(x, Param) else x,
        tree,
        is_leaf=lambda x: isinstance(x, Param),
    )
