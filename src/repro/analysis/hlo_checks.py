"""Post-lowering contract checks on compiled HLO text.

What only the compiled program can prove (reusing
``launch.hlo_analyzer``'s HLO parser — same regexes, same ``Computation``
walk):

* **donation** — lower each entry WITH its contract's donation
  (``SmokeCase.donate_argnums``) and verify XLA actually aliased the large
  input buffers into the outputs (``input_output_alias`` in the module
  header).  Declared-but-not-elided donation means the arena/HostStore
  payload is double-buffered — the exact failure the paper's memory budget
  cannot absorb.
* **f64** — no f64/c128 buffer survives optimization (a jaxpr-level cast can
  be folded away; one that reaches HLO is real).
* **host-call** — no host callback custom-calls / infeed / outfeed in the
  optimized program (oneDNN/matmul custom-calls are fine and expected on
  CPU).
"""
from __future__ import annotations

import re
from typing import List, Tuple

import jax

from repro.analysis.contracts import Contract, Violation
from repro.analysis.smoke import SmokeCase
from repro.launch.hlo_analyzer import _bytes_of_type, parse_computations

__all__ = ["check_case_hlo", "parse_input_output_alias", "compiled_text"]

_ALIAS_PAIR_RE = re.compile(r"\{[\d,\s]*\}:\s*\((\d+),\s*\{[\d,\s]*\}")
_HOST_CALL_RE = re.compile(
    r'custom_call_target="([^"]*(?:callback|host|infeed|outfeed)[^"]*)"', re.I
)


def compiled_text(case: SmokeCase, donate: bool = False) -> str:
    donate_argnums = case.donate_argnums if donate else ()
    return (
        jax.jit(case.fn, donate_argnums=donate_argnums)
        .lower(*case.args)
        .compile()
        .as_text()
    )


def parse_input_output_alias(hlo: str) -> List[int]:
    """Donated-parameter numbers aliased into outputs, from the module
    header's ``input_output_alias={ {out}: (param, {path}, kind), ... }``."""
    start = hlo.find("input_output_alias=")
    if start < 0:
        return []
    # brace-matched scan over the alias map (entries contain nested braces)
    i = hlo.find("{", start)
    depth, j = 0, i
    while j < len(hlo):
        if hlo[j] == "{":
            depth += 1
        elif hlo[j] == "}":
            depth -= 1
            if depth == 0:
                break
        j += 1
    body = hlo[i : j + 1]
    return [int(m.group(1)) for m in _ALIAS_PAIR_RE.finditer(body)]


def _entry_param_bytes(hlo: str) -> List[int]:
    comps, entry = parse_computations(hlo)
    if entry is None or entry not in comps:
        return []
    comp = comps[entry]
    return [_bytes_of_type(comp.params[p]) for p in comp.param_order]


def _donated_leaf_bytes(case: SmokeCase) -> List[int]:
    leaves = []
    for i in case.donate_argnums:
        for leaf in jax.tree_util.tree_leaves(case.args[i]):
            leaves.append(int(leaf.size) * leaf.dtype.itemsize)
    return leaves


def check_donation(case: SmokeCase, c: Contract, hlo: str) -> List[Violation]:
    if not c.donates or not case.donate_argnums:
        return []
    aliased = parse_input_output_alias(hlo)
    if not aliased:
        return [
            Violation(
                "donation",
                c.name,
                f"contract donates {c.donates} but compiled module has no "
                "input_output_alias — every donated buffer is double-buffered",
            )
        ]
    sizes = _entry_param_bytes(hlo)
    aliased_bytes = sum(sizes[p] for p in aliased if p < len(sizes))
    biggest = max(_donated_leaf_bytes(case), default=0)
    if aliased_bytes < biggest:
        return [
            Violation(
                "donation",
                c.name,
                f"aliased only {aliased_bytes} B of donated inputs; largest "
                f"donated leaf is {biggest} B — the arena payload did not "
                "elide",
            )
        ]
    return []


def check_f64_hlo(case: SmokeCase, c: Contract, hlo: str) -> List[Violation]:
    if not c.no_f64:
        return []
    comps, _ = parse_computations(hlo)
    out = []
    for comp in comps.values():
        for instr in comp.instrs:
            if "f64[" in instr.result_type or "c128[" in instr.result_type:
                out.append(
                    Violation(
                        "f64",
                        c.name,
                        f"HLO '{instr.op}' in {comp.name} produces "
                        f"{instr.result_type}",
                    )
                )
    return out


def check_host_calls(case: SmokeCase, c: Contract, hlo: str) -> List[Violation]:
    if not c.no_host_transfer:
        return []
    out = [
        Violation("host-transfer", c.name, f"HLO host custom-call '{m.group(1)}'")
        for m in _HOST_CALL_RE.finditer(hlo)
    ]
    for op in ("infeed(", "outfeed("):
        if op in hlo:
            out.append(
                Violation("host-transfer", c.name, f"HLO {op.rstrip('(')} op")
            )
    return out


def check_case_hlo(case: SmokeCase, c: Contract) -> List[Violation]:
    """All HLO-level checks for one entry (one compile, with the contract's
    donation applied so the aliasing decision is the one production sees)."""
    try:
        hlo = compiled_text(case, donate=bool(case.donate_argnums))
    except Exception as e:
        return [Violation("lower-error", c.name, f"{type(e).__name__}: {e}")]
    out: List[Violation] = []
    out += check_donation(case, c, hlo)
    out += check_f64_hlo(case, c, hlo)
    out += check_host_calls(case, c, hlo)
    return out
