"""Canonical smoke shapes: one tiny, trace-only case per registered entry.

Importing this module imports every covered subsystem (populating the
contract registry) and builds a :class:`SmokeCase` for each entry point.
Everything stays CHEAP and trace-compatible:

* fixture states are built by the real ``init`` paths at toy geometry
  (vocab ~256, dim 8, batch 32) — a few KB of device zeros;
* plan/step *outputs* needed as inputs downstream are materialized as zeros
  from ``jax.eval_shape`` structures, never by executing an entry body;
* the analyzer itself only ever calls ``jax.make_jaxpr`` / ``jit().lower()``
  on ``case.fn`` — no entry point is executed.

``advance`` encodes one abstract state-threading step for the
stable-signature check: ``jax.eval_shape(advance, *args)`` must reproduce the
argument avals exactly (shape, dtype AND weak_type), otherwise the entry
would retrace at step t+1 — the silent pipeline killer.

The geometry constants below are the reference point for every
``max_sort_size`` quoted in a ``@contract`` — change them together.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.contracts import registry
from repro.core import cache as cache_lib
from repro.core import refresh as refresh_lib
from repro.core.collection import EmbeddingCollection, FeatureBatch, TableConfig
from repro.core.sharded import RepArena, ShardedEmbeddingCollection
from repro.kernels.cache_ops import ops as co_ops
from repro.kernels.embedding_bag import ops as eb_ops
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.fm_interaction import ops as fm_ops
from repro.models.dlrm import DLRM, DLRMConfig

__all__ = ["SmokeCase", "build_cases", "GEOMETRY"]

# -- canonical geometry (quoted by @contract max_sort_size bounds) ----------
GEOMETRY = dict(
    vocab=256, capacity=128, dim=8, ids=16, buffer_rows=64,
    batch=32, tables=(192, 96), shards=2, swap_k=8, rep_k=16, routed_w=48,
)


@dataclasses.dataclass
class SmokeCase:
    """One traceable entry point: ``fn(*args)`` with statics already bound.

    ``donate_argnums`` are positions in ``args`` realizing the contract's
    ``donates`` declaration (the HLO pass lowers with them).  ``advance`` is
    the abstract step-t -> step-t+1 argument map (None = signature check
    degenerates to re-abstractifying ``args``, still catching weak types).
    """

    name: str
    fn: Callable
    args: Tuple[Any, ...]
    advance: Optional[Callable] = None
    donate_argnums: Tuple[int, ...] = ()


def _zeros_like_shape(tree: Any) -> Any:
    """Materialize a ``jax.eval_shape`` output structure as device zeros."""
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), tree
    )


# -- cache ------------------------------------------------------------------


def _cache_cases() -> Dict[str, SmokeCase]:
    g = GEOMETRY
    # int8 tiered arena ON so the traces cover the ArenaStore lanes (fp32
    # head + encoded tail scatter/gather, sideband, tier counters); the raw
    # fp32 arena path stays traced via the compute_step case below.
    # use_pallas_plan ON: planning traces the bounded-top-K / fused-dedup
    # route (kernels/cache_ops), which is what makes the max_sort_size=64
    # declaration hold with an EMPTY baseline — the oracle route keeps the
    # full-capacity argsort and is covered by bit-identity tests instead.
    cfg = cache_lib.CacheConfig(
        vocab=g["vocab"], capacity=g["capacity"], ids_per_step=g["ids"],
        buffer_rows=g["buffer_rows"], arena_precision="int8",
        use_pallas_plan=True,
    )
    row_ex = {"weight": jnp.zeros((g["dim"],), jnp.float32)}
    state = cache_lib.init_cache(cfg, row_ex)
    full = {"weight": jnp.zeros((g["vocab"], g["dim"]), jnp.float32)}
    rows = jnp.arange(g["ids"], dtype=jnp.int32)

    plan_fn = functools.partial(cache_lib.plan_prepare, cfg)
    plan0 = _zeros_like_shape(jax.eval_shape(plan_fn, state, rows))

    def plan_advance(s, r):
        p = plan_fn(s, r)
        _, s2 = cache_lib.apply_plan(cfg, full, s, p)
        return (s2, r)

    def apply_advance(f, s, p):
        f2, s2 = cache_lib.apply_plan(cfg, f, s, p)
        return (f2, s2, plan_fn(s2, rows))

    def flush_advance(f, s):
        return cache_lib.flush(cfg, f, s)

    def warmup_advance(f, s):
        return cache_lib.warmup(cfg, f, s)

    m = cache_lib.plan_prepare.__module__
    return {
        f"{m}.plan_prepare": SmokeCase(
            f"{m}.plan_prepare", plan_fn, (state, rows), plan_advance
        ),
        f"{m}.apply_plan": SmokeCase(
            f"{m}.apply_plan",
            functools.partial(cache_lib.apply_plan, cfg),
            (full, state, plan0),
            apply_advance,
            donate_argnums=(0, 1),
        ),
        f"{m}.flush": SmokeCase(
            f"{m}.flush",
            functools.partial(cache_lib.flush, cfg),
            (full, state),
            flush_advance,
            donate_argnums=(0,),
        ),
        f"{m}.warmup": SmokeCase(
            f"{m}.warmup",
            functools.partial(cache_lib.warmup, cfg),
            (full, state),
            warmup_advance,
            donate_argnums=(1,),
        ),
    }


# -- collection (unsharded + sharded) ---------------------------------------


def _toy_tables() -> Tuple[TableConfig, ...]:
    g = GEOMETRY
    return tuple(
        TableConfig(
            name=f"f{i}", vocab=v, dim=g["dim"], ids_per_step=g["batch"],
            cache_ratio=0.5, buffer_rows=g["buffer_rows"],
        )
        for i, v in enumerate(g["tables"])
    )


def _toy_fb() -> FeatureBatch:
    g = GEOMETRY
    names = tuple(f"f{i}" for i in range(len(g["tables"])))
    return FeatureBatch.from_onehot(
        names, jnp.zeros((g["batch"], len(names)), jnp.int32)
    )


def _collection_cases() -> Dict[str, SmokeCase]:
    g = GEOMETRY
    # fp16 tiered arena here (int8 is traced by the cache/sharded cases) so
    # both tail codecs cross the analyzer.
    coll = EmbeddingCollection.create(
        _toy_tables(), cache_ratio=0.5, buffer_rows=g["buffer_rows"],
        arena_precision="fp16",
    )
    state = coll.init(jax.random.PRNGKey(0))
    fb = _toy_fb()
    plan0 = _zeros_like_shape(jax.eval_shape(coll.plan_prepare, state, fb))
    weights = coll.weights(state)
    grads0 = _zeros_like_shape(jax.eval_shape(lambda w: w, weights))

    def grads_advance(s, grd):
        return (coll.apply_grads(s, grd, 0.05), grd)

    m = "repro.core.collection.EmbeddingCollection"
    return {
        f"{m}.gather": SmokeCase(
            f"{m}.gather", coll.gather, (weights, plan0.addresses, fb)
        ),
        f"{m}.apply_grads": SmokeCase(
            f"{m}.apply_grads",
            lambda s, grd: coll.apply_grads(s, grd, 0.05),
            (state, grads0),
            grads_advance,
            donate_argnums=(0,),
        ),
        f"{m}.metrics": SmokeCase(
            f"{m}.metrics", lambda s: coll.metrics(s), (state,)
        ),
    }


def _sharded_cases() -> Dict[str, SmokeCase]:
    g = GEOMETRY
    # replication + exchange codec + bounded plan width + int8 tiered arena
    # ON so the traces cover the arena lanes, the tracker mirror, the encoded
    # row-leg, the ::rep SGD branch, the compact-image scatter (routed_w <
    # the 64-lane dedup width, so plan_prepare takes the compaction path),
    # and the vmapped ArenaStore encode/decode lanes.
    # use_pallas_plan ON for the same reason as _cache_cases: the router
    # dedup and the vmapped per-shard plans trace the bounded-top-K route,
    # holding max_sort_size=64 with no baseline entry.
    scoll = ShardedEmbeddingCollection.create(
        _toy_tables(), num_shards=g["shards"], cache_ratio=0.5,
        buffer_rows=g["buffer_rows"], replicate_top_k=g["rep_k"],
        exchange_codec="fp16", max_routed_per_shard=g["routed_w"],
        arena_precision="int8", use_pallas_plan=True,
    )
    state = scoll.init(jax.random.PRNGKey(1))
    fb = _toy_fb()
    plan0 = _zeros_like_shape(jax.eval_shape(scoll.plan_prepare, state, fb))
    weights = scoll.weights(state)
    grads0 = _zeros_like_shape(jax.eval_shape(lambda w: w, weights))

    def plan_advance(s, f):
        p = scoll.plan_prepare(s, f)
        return (scoll.apply_plan(s, p), f)

    def apply_advance(s, p):
        s2 = scoll.apply_plan(s, p)
        return (s2, scoll.plan_prepare(s2, fb))

    def grads_advance(s, grd):
        return (scoll.apply_grads(s, grd, 0.05), grd)

    m = "repro.core.sharded.ShardedEmbeddingCollection"
    return {
        f"{m}.plan_prepare": SmokeCase(
            f"{m}.plan_prepare", scoll.plan_prepare, (state, fb), plan_advance
        ),
        f"{m}.apply_plan": SmokeCase(
            f"{m}.apply_plan", scoll.apply_plan, (state, plan0),
            apply_advance, donate_argnums=(0,),
        ),
        f"{m}.gather": SmokeCase(
            f"{m}.gather", scoll.gather, (weights, plan0.addresses, fb)
        ),
        f"{m}.apply_grads": SmokeCase(
            f"{m}.apply_grads",
            lambda s, grd: scoll.apply_grads(s, grd, 0.05),
            (state, grads0),
            grads_advance,
            donate_argnums=(0,),
        ),
        f"{m}.metrics": SmokeCase(
            f"{m}.metrics", lambda s: scoll.metrics(s), (state,)
        ),
    }


# -- trainer compute step ---------------------------------------------------


def _compute_step_case() -> Dict[str, SmokeCase]:
    g = GEOMETRY
    model = DLRM(
        DLRMConfig(
            vocab_sizes=g["tables"], n_dense=4, embed_dim=g["dim"],
            bottom_mlp=(16, g["dim"]), top_mlp=(16,), batch_size=g["batch"],
            cache_ratio=0.5, buffer_rows=g["buffer_rows"],
        )
    )
    state = model.init(jax.random.PRNGKey(2))
    batch = {
        "dense": jnp.zeros((g["batch"], 4), jnp.float32),
        "sparse": jnp.zeros((g["batch"], len(g["tables"])), jnp.int32),
        "label": jnp.zeros((g["batch"],), jnp.float32),
    }
    addr0 = _zeros_like_shape(
        jax.eval_shape(model.plan_step, state, batch).addresses
    )

    def advance(s, b, a):
        s2, _ = model.compute_step(s, b, a)
        return (s2, b, a)

    key = "repro.models.common.CollectionTrainStep.compute_step"
    return {
        key: SmokeCase(
            key, model.compute_step, (state, batch, addr0), advance,
            donate_argnums=(0,),
        )
    }


# -- refresh slab surgery ---------------------------------------------------


def _refresh_cases() -> Dict[str, SmokeCase]:
    g = GEOMETRY
    k = g["swap_k"]
    # int8 tiered arena so the slab-surgery traces cross the precision
    # boundary (swap invalidation over ArenaStore head+tail leaves).
    cfg = cache_lib.CacheConfig(
        vocab=g["vocab"], capacity=g["capacity"], ids_per_step=g["ids"],
        buffer_rows=g["buffer_rows"], arena_precision="int8",
    )
    row_ex = {"weight": jnp.zeros((g["dim"],), jnp.float32)}
    cache0 = cache_lib.init_cache(cfg, row_ex)
    full = {"weight": jnp.zeros((g["vocab"], g["dim"]), jnp.float32)}
    idx_map = jnp.arange(g["vocab"], dtype=jnp.int32)
    pairs = jnp.full((k,), -1, jnp.int32)
    valid = jnp.zeros((k,), bool)

    fn_1 = functools.partial(
        refresh_lib._apply_swaps, buffer_rows=g["buffer_rows"], writeback=True
    )

    # sharded: leaves gain a leading shard dim; idx_map stays flat [vocab].
    s = g["shards"]
    vs = g["vocab"] // s
    scfg = dataclasses.replace(cfg, vocab=vs, capacity=g["capacity"] // s)
    cache_s = jax.tree_util.tree_map(
        lambda x: jnp.stack([x] * s), cache_lib.init_cache(scfg, row_ex)
    )
    full_s = {"weight": jnp.zeros((s, vs, g["dim"]), jnp.float32)}
    rows_img = jnp.full((s, 2 * k), -1, jnp.int32)
    per_shard = jnp.zeros((s,), jnp.int32)
    rep = RepArena(
        rows=jnp.zeros((g["rep_k"], g["dim"]), jnp.float32),
        score=jnp.zeros((g["rep_k"],), jnp.float32),
        last_touch=jnp.zeros((g["rep_k"],), jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )
    fn_s = functools.partial(
        refresh_lib._apply_swaps_sharded,
        buffer_rows=g["buffer_rows"], writeback=True,
    )
    src_perm = jnp.arange(s * vs, dtype=jnp.int32)
    fn_rb = functools.partial(
        refresh_lib._apply_rebalance,
        buffer_rows=g["buffer_rows"], writeback=True,
    )

    m = "repro.core.refresh"
    return {
        f"{m}._apply_swaps": SmokeCase(
            f"{m}._apply_swaps",
            fn_1,
            (full, cache0, idx_map, pairs, pairs, valid),
            lambda f, c, im, a, b, v: fn_1(f, c, im, a, b, v) + (a, b, v),
        ),
        f"{m}._apply_swaps_sharded": SmokeCase(
            f"{m}._apply_swaps_sharded",
            fn_s,
            (full_s, cache_s, idx_map, rep, rows_img, pairs, pairs, pairs,
             pairs, valid, per_shard, per_shard),
            lambda f, c, im, r, *rest: fn_s(f, c, im, r, *rest) + rest,
        ),
        f"{m}._apply_rebalance": SmokeCase(
            f"{m}._apply_rebalance",
            fn_rb,
            (full_s, cache_s, src_perm),
            lambda f, c, sp: fn_rb(f, c, sp) + (sp,),
        ),
    }


# -- Pallas kernel ops ------------------------------------------------------


def _kernel_cases() -> Dict[str, SmokeCase]:
    g = GEOMETRY
    table = jnp.zeros((64, g["dim"]), jnp.float32)
    flat_ids = jnp.zeros((g["batch"],), jnp.int32)
    seg = jnp.zeros((g["batch"],), jnp.int32)
    v = jnp.zeros((g["batch"] // 2, 4, g["dim"]), jnp.float32)
    q = jnp.zeros((2, 16, 2, g["dim"]), jnp.float32)
    return {
        "repro.kernels.embedding_bag.ops.embedding_bag": SmokeCase(
            "repro.kernels.embedding_bag.ops.embedding_bag",
            lambda t, i, sg: eb_ops.embedding_bag(
                t, i, sg, num_segments=8, combiner="sum", max_bag=4
            ),
            (table, flat_ids, seg),
        ),
        "repro.kernels.fm_interaction.ops.fm_interaction": SmokeCase(
            "repro.kernels.fm_interaction.ops.fm_interaction",
            fm_ops.fm_interaction, (v,),
        ),
        "repro.kernels.flash_attention.ops.flash_attention": SmokeCase(
            "repro.kernels.flash_attention.ops.flash_attention",
            fa_ops.flash_attention, (q, q, q),
        ),
        # cache hot-path ops: key sized to the cache capacity, lane counts to
        # the unique buffer — the max_sort_size=64 contracts quote exactly
        # these shapes (only the kv/u-sized epilogue sorts may appear).
        "repro.kernels.cache_ops.ops.victim_topk": SmokeCase(
            "repro.kernels.cache_ops.ops.victim_topk",
            lambda k: co_ops.victim_topk(k, kv=g["ids"]),
            (jnp.zeros((g["capacity"],), jnp.int32),),
        ),
        "repro.kernels.cache_ops.ops.plan_image": SmokeCase(
            "repro.kernels.cache_ops.ops.plan_image",
            lambda r, m: co_ops.plan_image(r, m, k=g["ids"]),
            (
                jnp.zeros((4 * g["ids"],), jnp.int32),
                jnp.full((g["vocab"],), -1, jnp.int32),
            ),
        ),
        "repro.kernels.cache_ops.ops.shard_bucketize": SmokeCase(
            "repro.kernels.cache_ops.ops.shard_bucketize",
            lambda r, ro, rl: co_ops.shard_bucketize(
                r, ro, rl, rep_k=g["rep_k"], num_shards=g["shards"],
                u=g["routed_w"],
            ),
            (
                jnp.zeros((g["routed_w"],), jnp.int32),
                jnp.zeros((g["tables"][0],), jnp.int32),
                jnp.zeros((g["tables"][0],), jnp.int32),
            ),
        ),
        "repro.kernels.cache_ops.ops.arena_gather": SmokeCase(
            "repro.kernels.cache_ops.ops.arena_gather",
            lambda h, t, sb, sl: co_ops.arena_gather(
                h, t, sb, sl, codec="int8", out_dtype="float32"
            ),
            (
                jnp.zeros((32, g["dim"]), jnp.float32),
                jnp.zeros((96, g["dim"]), jnp.int8),
                jnp.zeros((96, 2), jnp.float32),
                jnp.zeros((g["ids"],), jnp.int32),
            ),
        ),
        "repro.kernels.cache_ops.ops.chunked_move": SmokeCase(
            "repro.kernels.cache_ops.ops.chunked_move",
            lambda s, d, si, di, ac: co_ops.chunked_move(
                s, d, si, di, ac, buffer_rows=g["buffer_rows"],
                src_chunk_rows=8,
            ),
            (
                {"weight": jnp.zeros((g["vocab"], g["dim"]), jnp.float32)},
                {"weight": jnp.zeros((g["capacity"], g["dim"]), jnp.float32)},
                jnp.zeros((g["ids"],), jnp.int32),
                jnp.zeros((g["ids"],), jnp.int32),
                jnp.zeros((g["ids"],), bool),
            ),
        ),
    }


def build_cases() -> Dict[str, SmokeCase]:
    """All smoke cases, keyed by registry name.  ``run`` cross-checks this
    against :func:`repro.analysis.contracts.registry` — a registered entry
    with no smoke case is itself a violation (the analyzer must trace every
    entry point)."""
    cases: Dict[str, SmokeCase] = {}
    for part in (
        _cache_cases(), _collection_cases(), _sharded_cases(),
        _compute_step_case(), _refresh_cases(), _kernel_cases(),
    ):
        cases.update(part)
    return cases


def registered_without_smoke() -> Tuple[str, ...]:
    return tuple(sorted(set(registry()) - set(build_cases())))
