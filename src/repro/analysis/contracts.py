"""Declarative hot-path contract registry.

A jit entry point declares its invariants at the definition site::

    @contract(no_host_transfer=True, donates=("state",), max_sort_size=64)
    def plan_prepare(cfg, state, rows, ...): ...

``@contract`` does NOT wrap the function — zero runtime overhead, no jit
interference — it records a :class:`Contract` in the module-level registry
keyed by ``module.qualname`` and (best effort) tags the callable with
``__contract__``.  The analyzer (``repro.analysis.run``) imports the covered
modules, walks the registry, and traces each entry at the canonical smoke
shapes defined in ``repro.analysis.smoke``.

This module is dependency-light on purpose (stdlib only): ``core``/
``kernels`` import it, never the reverse, so registration can never create an
import cycle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

__all__ = ["Contract", "Violation", "contract", "registry", "INT_COUNTERS"]

# The exact-counter contract (PR4/PR5): every telemetry/index leaf the cache
# threads through jit stays int32/uint32 — matched against output tree paths
# (``jax.tree_util.keystr``; registered-dataclass fields render as ``.name``).
INT_COUNTERS: Tuple[str, ...] = (
    r"\.(step|hits|misses|evictions|uniq_overflows|last_used|use_count"
    r"|slot_to_row|row_to_slot|last_touch|refresh_swaps|refresh_rows"
    r"|routed_lanes|tier_promotions|tier_demotions)$",
)


@dataclasses.dataclass(frozen=True)
class Contract:
    """Machine-checked invariants of one jit entry point.

    ``max_sort_size`` is quoted at the canonical smoke shapes of
    ``analysis.smoke`` (it bounds the largest sort/argsort operand the traced
    body may contain there) — an entry declaring bounded-top-K sets it to a
    small multiple of its per-step unique count, so a full-capacity argsort
    trips the check.  ``int_counters`` are regexes matched against output
    tree paths (``jax.tree_util.keystr``); matching leaves must stay
    int32/uint32 (the exact-counter contract).  ``donates`` names arguments
    the caller is expected to donate; the HLO pass lowers with that donation
    and verifies XLA actually aliased the large buffers (no double-buffered
    arena).
    """

    name: str  # "module.qualname" registry key
    no_host_transfer: bool = True
    no_f64: bool = True
    donates: Tuple[str, ...] = ()
    int_counters: Tuple[str, ...] = ()
    max_sort_size: Optional[int] = None
    stable_signature: bool = True


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding, from any pass.  ``(check, entry)`` is the baseline key —
    ``detail`` may drift between jax versions without invalidating a
    known-issue entry."""

    check: str  # "host-transfer" | "f64" | "int-counter" | "sort-bound" | ...
    entry: str  # registry key, or "path:line" for AST findings
    detail: str

    @property
    def key(self) -> str:
        return f"{self.check}::{self.entry}"

    def to_dict(self) -> Dict[str, str]:
        return {"check": self.check, "entry": self.entry, "detail": self.detail}


_REGISTRY: Dict[str, Tuple[Callable, Contract]] = {}


def registry() -> Dict[str, Tuple[Callable, Contract]]:
    """Snapshot of every registered entry point: key -> (callable, contract).
    Populated as covered modules are imported (``analysis.smoke`` imports
    them all)."""
    return dict(_REGISTRY)


def contract(
    *,
    no_host_transfer: bool = True,
    no_f64: bool = True,
    donates: Tuple[str, ...] = (),
    int_counters: Tuple[str, ...] = (),
    max_sort_size: Optional[int] = None,
    stable_signature: bool = True,
    name: Optional[str] = None,
) -> Callable:
    """Register the decorated callable's hot-path contract (see module doc).

    Stack ABOVE ``jax.jit`` so the registry holds the jitted callable.  For
    methods the registry key is ``module.Class.method``; ``name`` overrides
    when the qualname would be ambiguous (lambdas, factories).
    """

    def deco(fn: Callable) -> Callable:
        qual = name or f"{fn.__module__}.{getattr(fn, '__qualname__', fn.__name__)}"
        c = Contract(
            name=qual,
            no_host_transfer=no_host_transfer,
            no_f64=no_f64,
            donates=tuple(donates),
            int_counters=tuple(int_counters),
            max_sort_size=max_sort_size,
            stable_signature=stable_signature,
        )
        _REGISTRY[qual] = (fn, c)
        try:
            fn.__contract__ = c
        except (AttributeError, TypeError):  # C++ jit wrappers may refuse
            pass
        return fn

    return deco
