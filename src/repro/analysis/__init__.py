"""Static contract analysis for the hot path (no execution, trace-only).

The paper's end-to-end claim rests on the cache staying a pure on-device
data-movement layer: one hidden host sync, silent retrace, or missed buffer
donation in ``plan_prepare``/``apply_plan`` erases the win — and a pipelined
trainer keeps producing correct losses while the overlap is silently gone.
This package machine-checks those contracts before every ROADMAP churn:

* ``contracts``    — the ``@contract`` registry jit entry points declare on
* ``smoke``        — canonical tiny shapes each entry is traced at
* ``jaxpr_checks`` — trace-level invariants (``jax.make_jaxpr``)
* ``hlo_checks``   — post-lowering invariants (compiled HLO text, reusing
                     ``launch.hlo_analyzer``'s parser)
* ``ast_lint``     — JAX-aware AST pass over ``src/`` for what ruff can't see
* ``run``          — CLI / CI gate: ``python -m repro.analysis.run [--json]``
"""
from repro.analysis.contracts import Contract, Violation, contract, registry

__all__ = ["Contract", "Violation", "contract", "registry"]
