"""JAX-aware AST lint: what ruff cannot see because it is JAX semantics.

Four rules, applied to *jit-context* functions — functions decorated with
``jax.jit`` / ``functools.partial(jax.jit, ...)`` / ``@contract``, anything
nested inside one, plus any (module, qualname) the caller passes in
``extra_jit`` (``run`` feeds the contract registry, so undecorated methods
like ``EmbeddingCollection.gather`` are linted as jit bodies too):

* ``ast-host-sync``   — ``.item()`` / ``.block_until_ready()`` on anything,
  and ``float()`` / ``int()`` / ``np.asarray()`` / ``np.array()`` /
  ``jax.device_get()`` applied to a traced parameter: each is a synchronous
  device->host round trip per step.
* ``ast-tracer-branch`` — Python ``if``/``while`` on an expression that
  references a traced parameter by bare name (a ``ConcretizationTypeError``
  at best; at worst a silently shape-specialized branch).  Attribute access
  (``cfg.writeback``, ``x.shape``), ``isinstance``/``len`` calls and
  ``is None`` tests are static and excluded.
* ``ast-unregistered-dataclass`` — a ``@dataclasses.dataclass`` holding
  ``jnp.ndarray`` / ``jax.Array`` fields without
  ``jax.tree_util.register_dataclass`` (or a ``register_pytree_node`` call):
  it silently becomes a static leaf and retraces on every value change.
* ``ast-state-mutation`` — in-place mutation of a parameter
  (``state.x = ...``, ``state["k"] = ...``, augmented assigns): functional
  pytree state must be rebuilt, not mutated; locals (``d = dict(state); ...``)
  are fine.

One additional rule applies to the HOST-side metric-collection modules
(``repro.train.trainer``, ``repro.serve.engine``, ``repro.obs.*``) rather
than jit bodies:

* ``ast-obs-host-sync`` — an explicit sync primitive (``jax.device_get`` /
  ``.item()`` / ``.block_until_ready()``) outside the documented
  once-per-step sync points.  The observability layer's overhead contract is
  ONE deliberate block per step (the trainer's loss fetch in ``_post_step``;
  the serve response fetch in ``score``); a stray sync anywhere else in
  those modules silently serializes JAX's async dispatch pipeline.

Parameters annotated as plain Python scalars (``int``/``bool``/``str``/
``float``), ``*Config`` types, or named ``self``/``cls``/``cfg``/``config``
are treated as static and never count as traced.  A line containing
``jaxlint: ok`` suppresses findings on it.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.contracts import Violation

__all__ = ["lint_source", "lint_file", "lint_tree"]

_STATIC_PARAM_NAMES = {"self", "cls", "cfg", "config", "ccfg", "scfg"}
_STATIC_ANNOTATIONS = {"int", "bool", "str", "float", "bytes"}
_ARRAY_ANNOTATIONS = ("jnp.ndarray", "jax.Array", "jnp.array", "chex.Array")
_STATIC_CALLS = {"isinstance", "len", "getattr", "hasattr", "callable", "type"}
_SUPPRESS = "jaxlint: ok"


def _unparse(node: Optional[ast.AST]) -> str:
    if node is None:
        return ""
    try:
        return ast.unparse(node)
    except Exception:
        return ""


def _is_jit_decorator(dec: ast.AST) -> bool:
    """jax.jit / jit / partial(jax.jit, ...) / functools.partial(jax.jit, ...)
    / contract(...)."""
    s = _unparse(dec)
    head = s.split("(", 1)[0]
    if head in ("jax.jit", "jit", "contract") or head.endswith(
        (".jit", ".contract")
    ):
        return True
    return "partial(" in s and "jit" in s.split("partial(", 1)[1]


def _traced_params(fn: ast.AST) -> Set[str]:
    args = fn.args
    names: Set[str] = set()
    for a in list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs):
        if a.arg in _STATIC_PARAM_NAMES:
            continue
        ann = _unparse(a.annotation)
        if ann in _STATIC_ANNOTATIONS or ann.endswith("Config"):
            continue
        names.add(a.arg)
    return names


class _TracerRefFinder(ast.NodeVisitor):
    """Bare-name references to traced params, skipping static contexts."""

    def __init__(self, traced: Set[str]):
        self.traced = traced
        self.hits: List[str] = []

    def visit_Attribute(self, node: ast.Attribute) -> None:
        return  # cfg.writeback / state.step / x.shape: static or indirect

    def visit_Call(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Name) and node.func.id in _STATIC_CALLS:
            return
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare) -> None:
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return  # `x is None` guards are static
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if node.id in self.traced:
            self.hits.append(node.id)


def _tracer_refs(node: ast.AST, traced: Set[str]) -> List[str]:
    f = _TracerRefFinder(traced)
    f.visit(node)
    return f.hits


def _root_name(node: ast.AST) -> Optional[str]:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


@dataclasses.dataclass
class _Ctx:
    path: str
    lines: Sequence[str]
    module: str
    extra_jit: Set[str]
    out: List[Violation]

    def add(self, check: str, node: ast.AST, detail: str) -> None:
        line = node.lineno
        if 0 < line <= len(self.lines) and _SUPPRESS in self.lines[line - 1]:
            return
        self.out.append(Violation(check, f"{self.path}:{line}", detail))


def _lint_fn_body(fn: ast.AST, ctx: _Ctx, traced: Set[str]) -> None:
    for node in ast.walk(fn):
        # nested defs are handled by the outer walk (they inherit jit ctx
        # through _walk_defs); don't double-visit their bodies here.
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr in (
                "item",
                "block_until_ready",
            ):
                ctx.add(
                    "ast-host-sync", node,
                    f".{f.attr}() forces a host sync inside a jit body",
                )
            elif isinstance(f, ast.Name) and f.id in ("float", "int"):
                refs = [r for a in node.args for r in _tracer_refs(a, traced)]
                if refs:
                    ctx.add(
                        "ast-host-sync", node,
                        f"{f.id}() on traced value '{refs[0]}' concretizes "
                        "(host sync / trace error)",
                    )
            elif isinstance(f, ast.Attribute):
                call = _unparse(f)
                if call in ("np.asarray", "np.array", "numpy.asarray",
                            "numpy.array", "jax.device_get"):
                    refs = [
                        r for a in node.args for r in _tracer_refs(a, traced)
                    ]
                    if refs:
                        ctx.add(
                            "ast-host-sync", node,
                            f"{call}() on traced value '{refs[0]}' pulls it "
                            "to host",
                        )
        elif isinstance(node, (ast.If, ast.While)):
            refs = _tracer_refs(node.test, traced)
            if refs:
                kind = "if" if isinstance(node, ast.If) else "while"
                ctx.add(
                    "ast-tracer-branch", node,
                    f"Python `{kind}` on traced value '{refs[0]}' — use "
                    "jnp.where / lax.cond",
                )
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (
                node.targets if isinstance(node, ast.Assign) else [node.target]
            )
            for t in targets:
                if isinstance(t, (ast.Attribute, ast.Subscript)):
                    root = _root_name(t)
                    if root in traced:
                        ctx.add(
                            "ast-state-mutation", node,
                            f"in-place mutation of traced parameter '{root}' "
                            "— rebuild the pytree instead",
                        )


def _walk_defs(
    node: ast.AST, ctx: _Ctx, qual: str, in_jit: bool
) -> None:
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
            q = f"{qual}.{child.name}" if qual else child.name
            jit_here = (
                in_jit
                or any(_is_jit_decorator(d) for d in child.decorator_list)
                or f"{ctx.module}.{q}" in ctx.extra_jit
            )
            if jit_here:
                _lint_fn_body(child, ctx, _traced_params(child))
            _walk_defs(child, ctx, q, jit_here)
        elif isinstance(child, ast.ClassDef):
            _check_dataclass(child, ctx)
            q = f"{qual}.{child.name}" if qual else child.name
            _walk_defs(child, ctx, q, in_jit)
        else:
            _walk_defs(child, ctx, qual, in_jit)


def _check_dataclass(cls: ast.ClassDef, ctx: _Ctx) -> None:
    decs = [_unparse(d) for d in cls.decorator_list]
    is_dc = any("dataclass" in d for d in decs)
    registered = any("register" in d for d in decs)
    if not is_dc or registered:
        return
    def _array_field(ann: str) -> bool:
        # Callable[..., jnp.ndarray] fields hold functions, not array leaves
        return any(a in ann for a in _ARRAY_ANNOTATIONS) and "Callable" not in ann

    array_fields = [
        stmt.target.id
        for stmt in cls.body
        if isinstance(stmt, ast.AnnAssign)
        and isinstance(stmt.target, ast.Name)
        and _array_field(_unparse(stmt.annotation))
    ]
    if not array_fields:
        return
    # a module-level register_pytree_node(Cls, ...) call also counts
    src = "\n".join(ctx.lines)
    if f"register_pytree_node({cls.name}" in src or (
        f"register_dataclass({cls.name}" in src
    ):
        return
    ctx.add(
        "ast-unregistered-dataclass", cls,
        f"dataclass '{cls.name}' holds array fields {array_fields} but is "
        "not registered as a pytree (jax.tree_util.register_dataclass)",
    )


# -- obs host-sync discipline ------------------------------------------------
#
# Metric collection must not add device->host round trips: everything the
# hub records per step rides the step's one deliberate blocking fetch.  In
# these modules, sync primitives may only appear inside the named functions.
_OBS_SYNC_MODULES = ("repro.train.trainer", "repro.serve.engine", "repro.obs")
_OBS_SYNC_OK = {
    "_post_step",       # trainer: the once-per-step blocking point
    "_check_window",    # pipelined trainer: per-GROUP residency fail-fast
    "summary",          # on-demand reporting, not per-step
    "score",            # serve: the response IS the fetch
    "observe",          # ExactCounter: cumulative-counter reconstruction
    "observe_embedding_metrics",  # MetricsHub: the one batched family fetch
    "_as_int_map",      # ExactCounter normalization helper
}


def _lint_obs_sync(tree: ast.AST, ctx: _Ctx) -> None:
    def walk(node: ast.AST, fname: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                walk(child, child.name)
                continue
            if isinstance(child, ast.Call):
                f = child.func
                sync = None
                if isinstance(f, ast.Attribute) and f.attr in (
                    "item", "block_until_ready",
                ):
                    sync = f".{f.attr}()"
                elif _unparse(f) in ("jax.device_get", "device_get"):
                    sync = "jax.device_get()"
                if sync is not None and fname not in _OBS_SYNC_OK:
                    ctx.add(
                        "ast-obs-host-sync", child,
                        f"{sync} in '{fname}' — metric collection must not "
                        "add device->host syncs outside the documented "
                        "once-per-step points "
                        f"({', '.join(sorted(_OBS_SYNC_OK))})",
                    )
            walk(child, fname)

    walk(tree, "<module>")


def _module_name(path: Path, root: Path) -> str:
    try:
        rel = path.resolve().relative_to(root.resolve())
    except ValueError:
        rel = path
    parts = list(rel.with_suffix("").parts)
    if parts and parts[0] == "src":
        parts = parts[1:]
    return ".".join(parts)


def lint_source(
    source: str,
    path: str = "<string>",
    module: str = "",
    extra_jit: Iterable[str] = (),
) -> List[Violation]:
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Violation("ast-parse-error", f"{path}:{e.lineno}", str(e))]
    ctx = _Ctx(
        path=path,
        lines=source.splitlines(),
        module=module,
        extra_jit=set(extra_jit),
        out=[],
    )
    _walk_defs(tree, ctx, "", in_jit=False)
    if any(
        module == m or module.startswith(m + ".") for m in _OBS_SYNC_MODULES
    ):
        _lint_obs_sync(tree, ctx)
    return ctx.out


def lint_file(
    path: Path, root: Path, extra_jit: Iterable[str] = ()
) -> List[Violation]:
    rel = str(path.relative_to(root)) if path.is_relative_to(root) else str(path)
    return lint_source(
        path.read_text(),
        path=rel,
        module=_module_name(path, root),
        extra_jit=extra_jit,
    )


def lint_tree(
    root: Path, extra_jit: Iterable[str] = ()
) -> Tuple[List[Violation], int]:
    """Lint every ``.py`` under ``root/src``; returns (violations, n_files)."""
    extra = set(extra_jit)
    out: List[Violation] = []
    files = sorted((root / "src").rglob("*.py"))
    for f in files:
        out.extend(lint_file(f, root, extra))
    return out, len(files)
