"""CLI / CI gate: ``python -m repro.analysis.run [--json] [--strict]``.

Runs the three passes over every registered jit entry point and the source
tree, diffs the findings against the known-issue baseline
(``analysis/baseline.json``), and exits non-zero on anything new:

* exit 0 — clean (every finding is baselined)
* exit 1 — NEW violations (not in the baseline)
* exit 2 — ``--strict`` only: STALE baseline entries (listed but no longer
  firing — the fix landed, delete the line so it cannot mask a regression)

``--json`` prints the full machine-readable report on stdout; the human
format prints one line per finding.  ``--skip-hlo`` skips the compile-based
pass (a few seconds per entry) for fast local iteration; CI always runs
everything.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any, Dict, List, Sequence

from repro.analysis import ast_lint, hlo_checks, jaxpr_checks
from repro.analysis.contracts import Violation, registry

_DEFAULT_BASELINE = Path(__file__).with_name("baseline.json")


def load_baseline(path: Path) -> List[Dict[str, str]]:
    if not path.exists():
        return []
    return json.loads(path.read_text()).get("known_issues", [])


def analyze(
    root: Path,
    passes: Sequence[str] = ("jaxpr", "hlo", "ast"),
) -> Dict[str, Any]:
    """Run the requested passes; returns the raw (un-baselined) report."""
    from repro.analysis import smoke  # imports all covered modules

    reg = registry()
    cases = smoke.build_cases()
    violations: List[Violation] = []

    for name in sorted(set(reg) - set(cases)):
        violations.append(
            Violation(
                "no-smoke", name,
                "registered entry point has no smoke case — the analyzer "
                "cannot trace it (add one in analysis/smoke.py)",
            )
        )
    for name in sorted(set(reg) & set(cases)):
        _, c = reg[name]
        case = cases[name]
        if "jaxpr" in passes:
            violations.extend(jaxpr_checks.check_case(case, c))
        if "hlo" in passes:
            violations.extend(hlo_checks.check_case_hlo(case, c))

    n_files = 0
    if "ast" in passes:
        ast_violations, n_files = ast_lint.lint_tree(root, set(reg))
        violations.extend(ast_violations)

    return {
        "entries": sorted(reg),
        "passes": list(passes),
        "ast_files": n_files,
        "violations": violations,
    }


def apply_baseline(
    report: Dict[str, Any], baseline: List[Dict[str, str]]
) -> Dict[str, Any]:
    known = {(b["check"], b["entry"]): b for b in baseline}
    new, suppressed, fired = [], [], set()
    for v in report["violations"]:
        k = (v.check, v.entry)
        if k in known:
            fired.add(k)
            suppressed.append(v)
        else:
            new.append(v)
    stale = [known[k] for k in sorted(set(known) - fired)]
    return {
        "entries": report["entries"],
        "passes": report["passes"],
        "ast_files": report["ast_files"],
        "new": [v.to_dict() for v in new],
        "baselined": [
            dict(v.to_dict(), rationale=known[(v.check, v.entry)]["rationale"])
            for v in suppressed
        ],
        "stale_baseline": stale,
        "ok": not new,
    }


def main(argv: Sequence[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.run", description=__doc__
    )
    p.add_argument("--json", action="store_true", help="machine-readable report")
    p.add_argument(
        "--strict", action="store_true",
        help="also fail (exit 2) on stale baseline entries",
    )
    p.add_argument("--baseline", type=Path, default=_DEFAULT_BASELINE)
    p.add_argument(
        "--root", type=Path, default=Path.cwd(),
        help="repo root (for the AST pass over src/)",
    )
    p.add_argument(
        "--skip-hlo", action="store_true",
        help="skip the compile-based HLO pass (faster local runs)",
    )
    args = p.parse_args(argv)

    passes = ("jaxpr", "ast") if args.skip_hlo else ("jaxpr", "hlo", "ast")
    report = apply_baseline(
        analyze(args.root, passes), load_baseline(args.baseline)
    )

    if args.json:
        print(json.dumps(report, indent=2))
    else:
        print(
            f"analyzed {len(report['entries'])} jit entry points, "
            f"{report['ast_files']} source files ({', '.join(report['passes'])})"
        )
        for v in report["new"]:
            print(f"  NEW   {v['check']:26s} {v['entry']}: {v['detail']}")
        for v in report["baselined"]:
            print(f"  known {v['check']:26s} {v['entry']} ({v['rationale']})")
        for b in report["stale_baseline"]:
            print(
                f"  STALE baseline entry {b['check']}::{b['entry']} no longer "
                "fires — delete it from baseline.json"
            )
        verdict = "OK" if report["ok"] else "FAIL"
        print(f"{verdict}: {len(report['new'])} new, "
              f"{len(report['baselined'])} baselined, "
              f"{len(report['stale_baseline'])} stale")

    if not report["ok"]:
        return 1
    if args.strict and report["stale_baseline"]:
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
