"""Trace-level contract checks (``jax.make_jaxpr`` — nothing executes).

Four invariants per registered entry, driven by its :class:`Contract`:

* **host-transfer** — no ``device_put`` / callback / infeed primitive
  anywhere in the traced body (recursing into sub-jaxprs: pjit, scan, while,
  cond, vmap, custom_vjp, pallas_call).  One of these inside the hot path is
  a synchronous host round-trip per step.
* **f64** — no f64/c128 result and no ``convert_element_type`` to them
  (x64 creep doubles the wire bytes of every host<->device row move).
* **int-counter** — output leaves whose tree path matches the contract's
  ``int_counters`` regexes stay int32/uint32 (the exact-counter contract:
  PR4's telemetry totals and PR5's tracker clock both wrap, never round).
* **sort-bound** — largest ``sort`` operand (along its sort dimension) must
  not exceed ``max_sort_size`` at the smoke shapes; entries declaring
  bounded-top-K set a small bound so a full-capacity argsort fails.

Plus the **retrace** check: abstractly advance the entry's arguments one step
(``SmokeCase.advance`` under ``jax.eval_shape``) and require identical avals
— shape, dtype and weak_type — at step t and t+1.  Any difference means jit
recompiles every step, which silently destroys pipeline overlap.
"""
from __future__ import annotations

import re
from typing import Any, Iterator, List, Tuple

import jax
import numpy as np
from jax.api_util import shaped_abstractify

from repro.analysis.contracts import Contract, Violation
from repro.analysis.smoke import SmokeCase

__all__ = [
    "check_case",
    "check_signature_stability",
    "iter_eqns",
    "HOST_TRANSFER_PRIMITIVES",
]

HOST_TRANSFER_PRIMITIVES = frozenset(
    {
        "device_put",
        "pure_callback",
        "io_callback",
        "debug_callback",
        "callback",
        "infeed",
        "outfeed",
        "host_callback",
        "copy_to_host",
    }
)

_F64 = (np.dtype("float64"), np.dtype("complex128"))
_INT_OK = (np.dtype("int32"), np.dtype("uint32"))


def _sub_jaxprs(params: dict) -> Iterator[Any]:
    for v in params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for x in vals:
            if isinstance(x, jax.core.ClosedJaxpr):
                yield x.jaxpr
            elif isinstance(x, jax.core.Jaxpr):
                yield x


def iter_eqns(jaxpr: Any) -> Iterator[Any]:
    """Every equation of ``jaxpr`` and (recursively) of its sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def _trace(case: SmokeCase) -> Any:
    return jax.make_jaxpr(case.fn)(*case.args).jaxpr


def _aval_of(var: Any):
    return getattr(var, "aval", None)


def check_host_transfer(case: SmokeCase, c: Contract) -> List[Violation]:
    if not c.no_host_transfer:
        return []
    out = []
    for eqn in iter_eqns(_trace(case)):
        if eqn.primitive.name not in HOST_TRANSFER_PRIMITIVES:
            continue
        # device_put of a scalar LITERAL is trace-time constant placement
        # (e.g. ``jnp.unique(..., fill_value=<int>)``) — XLA folds it; only a
        # device_put of a traced/captured value is a real mid-graph transfer.
        if eqn.primitive.name == "device_put" and all(
            isinstance(v, jax.core.Literal) for v in eqn.invars
        ):
            continue
        out.append(
            Violation(
                "host-transfer",
                c.name,
                f"primitive '{eqn.primitive.name}' in traced body",
            )
        )
    return out


def check_f64(case: SmokeCase, c: Contract) -> List[Violation]:
    if not c.no_f64:
        return []
    out = []
    for eqn in iter_eqns(_trace(case)):
        new_dtype = eqn.params.get("new_dtype")
        if (
            eqn.primitive.name == "convert_element_type"
            and new_dtype is not None
            and np.dtype(new_dtype) in _F64
        ):
            out.append(
                Violation("f64", c.name, f"convert_element_type to {new_dtype}")
            )
            continue
        for var in eqn.outvars:
            aval = _aval_of(var)
            if aval is not None and getattr(aval, "dtype", None) in _F64:
                out.append(
                    Violation(
                        "f64",
                        c.name,
                        f"'{eqn.primitive.name}' produces {aval.dtype}",
                    )
                )
                break
    return out


def check_int_counters(case: SmokeCase, c: Contract) -> List[Violation]:
    if not c.int_counters:
        return []
    out_tree = jax.eval_shape(case.fn, *case.args)
    leaves = jax.tree_util.tree_flatten_with_path(out_tree)[0]
    out = []
    for path, leaf in leaves:
        ps = jax.tree_util.keystr(path)
        for pat in c.int_counters:
            if re.search(pat, ps) and np.dtype(leaf.dtype) not in _INT_OK:
                out.append(
                    Violation(
                        "int-counter",
                        c.name,
                        f"output leaf '{ps}' is {leaf.dtype}, not int32/uint32",
                    )
                )
                break
    return out


def check_sort_bound(case: SmokeCase, c: Contract) -> List[Violation]:
    if c.max_sort_size is None:
        return []
    out = []
    for eqn in iter_eqns(_trace(case)):
        if eqn.primitive.name != "sort":
            continue
        dim = eqn.params.get("dimension", -1)
        sizes = [
            _aval_of(v).shape[dim]
            for v in eqn.invars
            if _aval_of(v) is not None and getattr(_aval_of(v), "shape", ())
        ]
        size = max(sizes, default=0)
        if size > c.max_sort_size:
            out.append(
                Violation(
                    "sort-bound",
                    c.name,
                    f"sort over {size} elements exceeds declared "
                    f"max_sort_size={c.max_sort_size} at smoke shapes",
                )
            )
    return out


def _sig(tree: Any) -> Tuple[Any, List[Tuple[str, Tuple]]]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    sig = []
    for path, leaf in leaves:
        aval = shaped_abstractify(leaf)
        sig.append(
            (
                jax.tree_util.keystr(path),
                (tuple(aval.shape), str(aval.dtype), bool(aval.weak_type)),
            )
        )
    return treedef, sig


def check_signature_stability(case: SmokeCase, c: Contract) -> List[Violation]:
    """Re-abstract the entry's args at step t and t+1; any aval difference
    (incl. weak_type) means a per-step retrace."""
    if not c.stable_signature or case.advance is None:
        return []
    td0, sig0 = _sig(case.args)
    nxt = jax.eval_shape(lambda *a: case.advance(*a), *case.args)
    td1, sig1 = _sig(nxt)
    if td0 != td1:
        return [
            Violation(
                "retrace", c.name,
                "argument tree structure changes between step t and t+1",
            )
        ]
    out = []
    for (p0, a0), (_, a1) in zip(sig0, sig1):
        if a0 != a1:
            out.append(
                Violation(
                    "retrace",
                    c.name,
                    f"arg leaf '{p0}' aval drifts {a0} -> {a1} "
                    "(shape, dtype, weak_type)",
                )
            )
    return out


def check_case(case: SmokeCase, c: Contract) -> List[Violation]:
    """All jaxpr-level checks for one entry."""
    out: List[Violation] = []
    try:
        out += check_host_transfer(case, c)
        out += check_f64(case, c)
        out += check_int_counters(case, c)
        out += check_sort_bound(case, c)
        out += check_signature_stability(case, c)
    except Exception as e:  # a case that cannot even trace is itself a finding
        out.append(Violation("trace-error", c.name, f"{type(e).__name__}: {e}"))
    return out
