"""Optimizers, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import optimizers as O
from repro.optim import schedules
from repro.optim.compression import Compressor


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


@pytest.mark.parametrize("make", [
    lambda: O.sgd(0.1), lambda: O.sgd(0.05, momentum=0.9),
    lambda: O.adam(0.2), lambda: O.adamw(0.2, weight_decay=0.0),
    lambda: O.adagrad(0.9),
])
def test_optimizers_converge_on_quadratic(make):
    opt = make()
    params = {"w": jnp.zeros((4,)), "b": jnp.zeros((2,))}
    state = opt.init(params)
    for i in range(200):
        grads = jax.grad(quad_loss)(params)
        params, state = opt.update(grads, state, params, jnp.int32(i))
    assert float(quad_loss(params)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, n = O.clip_by_global_norm(g, 1.0)
    assert float(n) == pytest.approx(20.0)
    assert float(O.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.full((4,), 0.01)}
    same, _ = O.clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 0.01, rtol=1e-6)


def test_schedules():
    c = schedules.constant(0.5)
    assert float(c(jnp.int32(100))) == 0.5
    w = schedules.linear_warmup_cosine(1.0, 10, 100)
    assert float(w(jnp.int32(5))) == pytest.approx(0.5)
    assert float(w(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)
    s = schedules.inverse_sqrt(1.0, 100)
    assert float(s(jnp.int32(100))) == pytest.approx(1.0)
    assert float(s(jnp.int32(400))) == pytest.approx(0.5)


@pytest.mark.parametrize("codec,factor", [("none", 4), ("bf16", 2), ("int8", 1)])
def test_compressor_wire_bytes(codec, factor):
    comp = Compressor(codec)
    g = {"a": jnp.zeros((100,), jnp.float32)}
    assert comp.wire_bytes(g) == 100 * factor


def test_int8_error_feedback_convergence():
    """Quantization noise must not stall convergence (error feedback)."""
    comp = Compressor("int8")
    opt = O.sgd(0.05)
    params = {"w": jnp.zeros((8,))}
    opt_state = opt.init(params)
    comp_state = comp.init(params)
    for i in range(300):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))(params)
        payload, sideband, comp_state = comp.encode(grads, comp_state)
        grads_q = comp.decode(payload, sideband, grads)
        params, opt_state = opt.update(grads_q, opt_state, params, jnp.int32(i))
    np.testing.assert_allclose(np.asarray(params["w"]), 1.0, atol=1e-2)


def test_int8_roundtrip_bounded_error():
    comp = Compressor("int8")
    g = {"a": jnp.asarray(np.random.default_rng(0).normal(size=(256,)).astype(np.float32))}
    st = comp.init(g)
    payload, sideband, st = comp.encode(g, st)
    assert payload["a"].dtype == jnp.int8
    back = comp.decode(payload, sideband, g)
    scale = float(jnp.abs(g["a"]).max()) / 127
    assert float(jnp.abs(back["a"] - g["a"]).max()) <= scale * 0.5 + 1e-6
