"""Pipelined execution engine: plan/apply split, lookahead admission,
pipelined-vs-serial bit-identity, and the Prefetcher lookahead view."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collection as col
from repro.data.pipeline import Prefetcher


def _arena(state):
    return state.slabs[col.SHARED_ARENA]


def _resident(state, raw_id):
    slab = _arena(state)
    row = int(slab.idx_map[raw_id])
    return int(slab.cache.row_to_slot[row]) >= 0


def _fb(ids):
    return col.FeatureBatch(ids={"t": jnp.asarray(ids, jnp.int32)})


def _coll(vocab=100, cache_ratio=0.12, ids=4, **kw):
    tables = [col.TableConfig("t", vocab=vocab, dim=4, ids_per_step=ids, **kw)]
    return col.EmbeddingCollection.create(tables, cache_ratio=cache_ratio)


# --------------------------------------------------------------------------
# plan/apply split
# --------------------------------------------------------------------------


def test_prepare_equals_plan_then_apply():
    coll = _coll()
    s1 = coll.init(jax.random.PRNGKey(0))
    s2 = coll.init(jax.random.PRNGKey(0))
    for step in range(6):
        fb = _fb([step * 3, step * 3 + 1, 90 - step, -1])
        s1, a1 = coll.prepare(s1, fb)
        p = coll.plan_prepare(s2, fb)
        s2 = coll.apply_plan(s2, p)
        np.testing.assert_array_equal(np.asarray(a1["t"]), np.asarray(p.addresses["t"]))
        jax.tree_util.tree_map(
            lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)), s1, s2
        )


def test_plan_reads_no_weights():
    """The planning half must be a function of ids + index state only: zeroing
    every weight changes nothing in the plan."""
    coll = _coll()
    state = coll.init(jax.random.PRNGKey(0))
    fb, fut = _fb([5, 6, 7, 8]), _fb([40, 41, 42, 43])
    zeroed = jax.tree_util.tree_map(
        lambda x: jnp.zeros_like(x) if jnp.issubdtype(x.dtype, jnp.floating) else x, state
    )
    p1 = coll.plan_prepare(state, fb, fb_future=(fut,))
    p2 = coll.plan_prepare(zeroed, fb, fb_future=(fut,))
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x), np.asarray(y)), p1, p2
    )


# --------------------------------------------------------------------------
# lookahead admission (satellite: resident by t+k, never evicted in between)
# --------------------------------------------------------------------------


def test_lookahead_row_resident_by_its_step_and_never_evicted():
    # capacity 12; each step brings 4 fresh rows, so eviction pressure is real
    coll = _coll(vocab=100, cache_ratio=0.12)
    state = coll.init(jax.random.PRNGKey(0))  # warm: rows 0..11 resident
    batches = [[0, 1, 2, 3], [20, 21, 22, 23], [30, 31, 32, 33], [40, 41, 42, 43],
               [50, 51, 52, 53]]
    depth = 2  # window: the next 2 batches' ids merge into each plan
    target = 30  # needed at t=2; must be prefetched at t=0 and pinned at t=1

    residency = []
    for t in range(3):
        fb_now = _fb(batches[t])
        fb_future = [_fb(b) for b in batches[t + 1 : t + 1 + depth]]
        state, addr = coll.prepare_lookahead(state, fb_now, fb_future)
        residency.append(_resident(state, target))
        if t == 2:
            # the target batch's rows were all prefetched: no new loads beyond
            # its own lookahead window's, and the target row is a hit
            assert all(int(a) >= 0 for a in np.asarray(addr["t"]))
        # exactness every step, lookahead or not
        rows = coll.gather(coll.weights(state), addr, fb_now)
        ref = coll.dense_reference(coll.flush(state), fb_now)
        np.testing.assert_array_equal(np.asarray(rows["t"]), np.asarray(ref["t"]))
    assert residency == [True, True, True], residency


def test_lookahead_current_batch_wins_under_capacity_pressure():
    """When the window's rows don't fit, future loads are dropped — the
    current batch stays exact and never overflows the victim budget."""
    coll = _coll(vocab=100, cache_ratio=0.06, ids=6)  # capacity 6 = one batch
    state = coll.init(jax.random.PRNGKey(0))
    fb_now = _fb([10, 11, 12, 13, 14, 15])
    fb_future = [_fb([20, 21, 22, 23, 24, 25])]
    state, addr = coll.prepare_lookahead(state, fb_now, fb_future)
    # every current row resident + exact
    assert all(int(a) >= 0 for a in np.asarray(addr["t"]))
    rows = coll.gather(coll.weights(state), addr, fb_now)
    ref = coll.dense_reference(coll.flush(state), fb_now)
    np.testing.assert_array_equal(np.asarray(rows["t"]), np.asarray(ref["t"]))


def test_future_only_slab_counts_as_unresident_not_keyerror():
    """A cached slab touched only by the window is not prefetched — the plan
    must report its lanes in future_unresident (the group trainer's fail-fast)
    rather than silently omitting their addresses."""
    tables = [
        col.TableConfig("a", vocab=64, dim=4, ids_per_step=4,
                        placement=col.Placement.CACHED, cache_ratio=0.5),
        col.TableConfig("b", vocab=64, dim=4, ids_per_step=4,
                        placement=col.Placement.CACHED, cache_ratio=0.5),
    ]
    coll = col.EmbeddingCollection(tables, col.PlacementPlanner(10**9).plan(tables))
    state = coll.init(jax.random.PRNGKey(0))
    fb_now = col.FeatureBatch(ids={"a": jnp.asarray([1, 2, 3, -1], jnp.int32)})
    fb_fut = col.FeatureBatch(ids={"a": jnp.asarray([4, 5, -1, -1], jnp.int32),
                                   "b": jnp.asarray([7, 8, 9, -1], jnp.int32)})
    p = coll.plan_prepare(state, fb_now, fb_future=(fb_fut,))
    assert int(p.future_unresident) == 3  # b's three valid lanes
    assert "a" in p.future_addresses[0] and "b" not in p.future_addresses[0]


def test_pallas_bag_grad_respects_max_bag_truncation():
    """Forward truncates bags at max_bag; the custom VJP must use the same
    lane mask (no gradient into dropped rows, mean divided by kept count)."""
    from repro.kernels.embedding_bag import ops as eb_ops

    table = jnp.arange(40, dtype=jnp.float32).reshape(10, 4)
    flat = jnp.asarray([0, 1, 2, 3, 4, 5], jnp.int32)  # one bag of 6 lanes
    seg = jnp.zeros(6, jnp.int32)
    for combiner in ("sum", "mean"):
        def loss(w, combiner=combiner):
            return jnp.sum(
                eb_ops.embedding_bag(w, flat, seg, 1, combiner=combiner, max_bag=4) ** 2
            )
        g = jax.grad(loss)(table)
        assert bool((np.asarray(g)[4:6] == 0).all()), combiner  # dropped lanes
        # numeric check against a jnp oracle over the kept lanes only
        def ref(w, combiner=combiner):
            rows = jnp.take(w, flat[:4], axis=0)
            out = rows.sum(0) / (4.0 if combiner == "mean" else 1.0)
            return jnp.sum(out**2)
        np.testing.assert_allclose(np.asarray(g), np.asarray(jax.grad(ref)(table)),
                                   rtol=1e-5)


def test_overflow_accounting_under_merged_lookahead_ids():
    """uniq_overflows counts CURRENT-batch overflow only: a lookahead window
    far beyond max_unique_per_step must not trip the exactness guard."""
    tables = [col.TableConfig("t", vocab=100, dim=4, ids_per_step=8,
                              max_unique_per_step=8, cache_ratio=0.3,
                              placement=col.Placement.CACHED)]
    coll = col.EmbeddingCollection(tables, col.PlacementPlanner(10**9).plan(tables))
    state = coll.init(jax.random.PRNGKey(0))
    fb_now = _fb([1, 1, 2, 2, 3, 3, 4, 4])  # 4 distinct <= 8: fine
    fb_future = [_fb(list(range(20, 28))), _fb(list(range(40, 48)))]  # 16 more distinct
    state, _ = coll.prepare_lookahead(state, fb_now, fb_future)
    assert int(coll.metrics(state)["uniq_overflows"]) == 0
    # a genuinely overflowing CURRENT batch still counts exactly once
    fb_over = _fb(list(range(80, 92)))  # 12 distinct > max_unique_per_step=8
    tables12 = [col.TableConfig("t", vocab=100, dim=4, ids_per_step=12,
                                max_unique_per_step=8, cache_ratio=0.3,
                                placement=col.Placement.CACHED)]
    coll12 = col.EmbeddingCollection(tables12, col.PlacementPlanner(10**9).plan(tables12))
    st12 = coll12.init(jax.random.PRNGKey(0))
    st12, _ = coll12.prepare_lookahead(st12, fb_over, [_fb(list(range(8)) + [-1] * 4)])
    assert int(coll12.metrics(st12)["uniq_overflows"]) == 1


# --------------------------------------------------------------------------
# pipelined trainer == serial trainer, bit for bit
# --------------------------------------------------------------------------


@pytest.mark.parametrize("pipeline_depth", [1, 3])
def test_pipelined_trainer_loss_bit_identical_to_serial(pipeline_depth):
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig
    from repro.train.trainer import PipelinedTrainer, Trainer, TrainerConfig

    cfg = DLRMConfig(vocab_sizes=(4096, 256, 64), embed_dim=8, batch_size=16,
                     cache_ratio=0.25, lr=0.1, bottom_mlp=(16, 8), top_mlp=(16,))
    spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)

    def make_batch(step):
        return {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, 16, 0, step).items()}

    model = DLRM(cfg)
    serial = Trainer(TrainerConfig(max_steps=6),
                     init_fn=lambda: model.init(jax.random.PRNGKey(0)),
                     step_fn=jax.jit(model.train_step),
                     make_batch=make_batch, flush_fn=model.flush)
    serial.run()

    model2 = DLRM(cfg)
    piped = PipelinedTrainer(
        TrainerConfig(max_steps=6, pipeline_depth=pipeline_depth),
        init_fn=lambda: model2.init(jax.random.PRNGKey(0)),
        plan_fn=jax.jit(model2.plan_step),
        compute_fn=jax.jit(model2.compute_step),
        apply_fn=jax.jit(model2.apply_step),
        make_batch=make_batch, flush_fn=model2.flush)
    piped.run()

    assert [h["loss"] for h in serial.history] == [h["loss"] for h in piped.history]
    assert [h["auc"] for h in serial.history] == [h["auc"] for h in piped.history]
    assert [h["step"] for h in serial.history] == [h["step"] for h in piped.history]


# --------------------------------------------------------------------------
# fused Pallas gather+pool parity (forward AND gradient)
# --------------------------------------------------------------------------


def test_pool_pallas_fused_matches_reference_and_grads():
    tables = [col.TableConfig("t", vocab=50, dim=4, ids_per_step=12, cache_ratio=0.5)]
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.5)
    state = coll.init(jax.random.PRNGKey(0))
    flat = jnp.asarray([1, 2, 3, -1, 4, 5, 6, 7, -1, -1, 8, 9], jnp.int32)
    seg = jnp.asarray([0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2], jnp.int32)
    fb = col.FeatureBatch.from_bags({"t": (flat, seg)}, num_segments=3)
    state, addr = coll.prepare(state, fb)
    w = coll.weights(state)

    for combiner in ("sum", "mean"):
        rows = coll.gather(w, addr, fb)
        ref = coll.pool(rows, fb, combiner)["t"]
        fused = coll.pool({}, fb, combiner, weights=w, addresses=addr, use_pallas=True)["t"]
        np.testing.assert_allclose(np.asarray(ref), np.asarray(fused), rtol=1e-6)

        g_ref = jax.grad(lambda w, combiner=combiner: jnp.sum(
            coll.pool(coll.gather(w, addr, fb), fb, combiner)["t"] ** 2))(w)
        g_fus = jax.grad(lambda w, combiner=combiner: jnp.sum(
            coll.pool({}, fb, combiner, weights=w, addresses=addr, use_pallas=True)["t"] ** 2))(w)
        for k in g_ref:
            np.testing.assert_allclose(np.asarray(g_ref[k]), np.asarray(g_fus[k]), rtol=1e-5)


# --------------------------------------------------------------------------
# Prefetcher lookahead view + join-on-close (satellite)
# --------------------------------------------------------------------------


def test_prefetcher_lookahead_peeks_without_consuming():
    pf = Prefetcher(lambda s: {"x": np.asarray([s])}, start_step=0, depth=4)
    try:
        step, batch = next(pf)
        assert (step, int(batch["x"][0])) == (0, 0)
        peek = pf.lookahead(3)
        assert [s for s, _ in peek] == [1, 2, 3]
        peek2 = pf.lookahead(3)  # idempotent: nothing consumed
        assert [s for s, _ in peek2] == [1, 2, 3]
        assert next(pf)[0] == 1  # stream order unchanged
        with pytest.raises(ValueError):
            pf.lookahead(5)  # beyond buffer depth
    finally:
        pf.close()


def test_prefetcher_close_joins_worker_thread():
    before = threading.active_count()
    pf = Prefetcher(lambda s: {"x": np.asarray([s])}, depth=2)
    next(pf)
    pf.close()
    assert not pf._thread.is_alive()
    assert threading.active_count() <= before


def test_prefetcher_surfaces_producer_error_in_order():
    def make(step):
        if step == 2:
            raise RuntimeError("boom")
        return {"x": np.asarray([step])}

    pf = Prefetcher(make, depth=2)
    try:
        assert next(pf)[0] == 0
        assert next(pf)[0] == 1
        with pytest.raises(RuntimeError, match="boom"):
            next(pf)
    finally:
        pf.close()
    # lookahead must surface the producer error too, not return a short peek
    pf2 = Prefetcher(make, depth=3)
    try:
        assert next(pf2)[0] == 0
        with pytest.raises(RuntimeError, match="boom"):
            pf2.lookahead(3)  # only step 1 exists before the error
        assert next(pf2)[0] == 1  # buffered good batch stays consumable
    finally:
        pf2.close()


# --------------------------------------------------------------------------
# Prefetcher end-of-stream contract (satellite): "stream ended" is a short
# list, "not yet produced" blocks, "closed" raises
# --------------------------------------------------------------------------


def _finite(n):
    def make(step):
        if step >= n:
            raise StopIteration
        return {"x": np.asarray([step])}
    return make


def test_prefetcher_lookahead_short_list_means_stream_ended():
    pf = Prefetcher(_finite(3), depth=4)
    try:
        assert next(pf)[0] == 0
        peek = pf.lookahead(4)  # only steps 1, 2 remain
        assert [s for s, _ in peek] == [1, 2]
        assert pf.exhausted
        assert next(pf)[0] == 1
        assert next(pf)[0] == 2
        with pytest.raises(StopIteration):
            next(pf)
        assert pf.lookahead(2) == []  # ended and drained: empty, not a hang
    finally:
        pf.close()
    # a cleanly-ended stream keeps the short-list contract after close() too
    # (only cancelling an un-ended stream turns lookahead into an error)
    assert pf.lookahead(2) == []


def test_prefetcher_iteration_ends_cleanly_on_finite_stream():
    pf = Prefetcher(_finite(4), depth=2)
    try:
        assert [s for s, _ in pf] == [0, 1, 2, 3]  # for-loop just terminates
        assert pf.exhausted
    finally:
        pf.close()


def test_prefetcher_lookahead_on_closed_raises():
    pf = Prefetcher(lambda s: {"x": np.asarray([s])}, depth=2)
    next(pf)
    pf.close()
    with pytest.raises(RuntimeError, match="closed"):
        pf.lookahead(1)


def test_pipelined_trainer_handles_stream_ending_mid_group():
    """A finite stream shorter than max_steps must end the pipelined run
    cleanly — the final group shrinks to the remaining batches and the losses
    still bit-match the serial trainer over the same stream."""
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig
    from repro.train.trainer import PipelinedTrainer, Trainer, TrainerConfig

    cfg = DLRMConfig(vocab_sizes=(1024, 128), embed_dim=8, batch_size=16,
                     cache_ratio=0.25, lr=0.1, bottom_mlp=(16, 8), top_mlp=(16,))
    spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)
    n_stream = 5  # ends mid-group at depth 3 (groups of 3 + a short tail of 2)

    def make_batch(step):
        if step >= n_stream:
            raise StopIteration
        return {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, 16, 0, step).items()}

    model = DLRM(cfg)
    serial = Trainer(TrainerConfig(max_steps=50),
                     init_fn=lambda: model.init(jax.random.PRNGKey(0)),
                     step_fn=jax.jit(model.train_step),
                     make_batch=make_batch, flush_fn=model.flush)
    serial.run()
    assert len(serial.history) == n_stream

    model2 = DLRM(cfg)
    piped = PipelinedTrainer(
        TrainerConfig(max_steps=50, pipeline_depth=3),
        init_fn=lambda: model2.init(jax.random.PRNGKey(0)),
        plan_fn=jax.jit(model2.plan_step),
        compute_fn=jax.jit(model2.compute_step),
        apply_fn=jax.jit(model2.apply_step),
        make_batch=make_batch, flush_fn=model2.flush)
    piped.run()
    assert len(piped.history) == n_stream
    assert [h["loss"] for h in serial.history] == [h["loss"] for h in piped.history]


# --------------------------------------------------------------------------
# lookahead pin leakage (satellite): pins are plan-local — an abandoned
# group's prefetched rows are fully reclaimable by the very next plan
# --------------------------------------------------------------------------


def test_abandoned_group_pins_are_cleared_by_next_plan():
    """Pin a lookahead window, abandon the group (its batch never runs), then
    present a batch whose uniques fill the whole cache: every slot — the
    stale-pinned ones included — must be reclaimed, and the new batch stays
    exact.  A persistent pin would leave its row resident and break this."""
    coll = _coll(vocab=100, cache_ratio=0.06, ids=6)  # capacity 6 = one batch
    state = coll.init(jax.random.PRNGKey(0))  # warm: rows 0..5 resident
    # group leader plans with a future window; 20..22 load and pin
    state, addr = coll.prepare_lookahead(
        state, _fb([0, 1, 2, -1, -1, -1]), [_fb([20, 21, 22, -1, -1, -1])]
    )
    assert all(_resident(state, r) for r in (20, 21, 22))
    # the group is abandoned HERE: batch [20, 21, 22] never runs.
    # next plan needs all 6 slots -> previously-pinned rows must be evictable
    fb = _fb([30, 31, 32, 33, 34, 35])
    state, addr = coll.prepare(state, fb)
    assert all(int(a) >= 0 for a in np.asarray(addr["t"]))
    assert not any(_resident(state, r) for r in (20, 21, 22))
    rows = coll.gather(coll.weights(state), addr, fb)
    ref = coll.dense_reference(coll.flush(state), fb)
    np.testing.assert_array_equal(np.asarray(rows["t"]), np.asarray(ref["t"]))


@pytest.mark.parametrize("policy", ["lru", "runtime_lfu"])
def test_stale_prefetch_not_above_normal_tier_for_runtime_policies(policy):
    """Under recency/counter policies a prefetched-then-abandoned row must
    compete like any resident row (it aged from its load step) — later-used
    rows outrank it, so it is evicted first under pressure."""
    from repro.core.policies import Policy

    pol = Policy(policy)
    coll = _coll(vocab=100, cache_ratio=0.08, ids=4, policy=pol)  # capacity 8
    state = coll.init(jax.random.PRNGKey(0))
    # t0: leader plans with window -> 20, 21 prefetched; group abandoned
    state, _ = coll.prepare_lookahead(
        state, _fb([0, 1, -1, -1]), [_fb([20, 21, -1, -1])]
    )
    # t1..t2: other rows get USED (their recency/use counters pass the stale
    # prefetch, whose pin no plan renews)
    for ids in ([2, 3, 4, 5], [2, 3, 4, 5]):
        state, _ = coll.prepare(state, _fb(ids))
    # pressure: 4 fresh rows need slots; the stale prefetched pair must be
    # among the victims before any of the recently-used rows
    state, addr = coll.prepare(state, _fb([40, 41, 42, 43]))
    assert all(int(a) >= 0 for a in np.asarray(addr["t"]))
    assert not _resident(state, 20) and not _resident(state, 21)
    assert _resident(state, 2) and _resident(state, 3)
