"""HLO roofline analyzer: trip-count-exact flops, touched-rows byte model.

These tests also document WHY the analyzer exists: XLA's cost_analysis counts
while bodies once and charges gathers their full operand.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analyzer import analyze_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_body_multiplied_by_trip_count():
    w = jnp.ones((8, 128, 128))

    def f(x):
        y, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)
        return y.sum()

    comp = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    c = analyze_hlo(comp.as_text())
    expect = 8 * 2 * 128**3
    assert 0.8 * expect < c.flops < 1.3 * expect
    # and XLA's own analysis indeed counts the body once (the motivation);
    # cost_analysis() returns a per-device list on newer jax.
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < 0.3 * expect


def test_gather_charges_touched_rows_not_table():
    t = jnp.ones((1_000_000, 64))
    idx = jnp.arange(1000, dtype=jnp.int32)
    comp = _compile(lambda t, i: jnp.take(t, i, axis=0), t, idx)
    c = analyze_hlo(comp.as_text())
    touched = 2 * 1000 * 64 * 4 + 1000 * 4
    assert c.bytes < 4 * touched  # not 256 MB
    assert c.bytes >= 0.5 * touched


def test_donated_scatter_charges_updates():
    t = jnp.ones((1_000_000, 64))
    idx = jnp.arange(1000, dtype=jnp.int32)
    u = jnp.ones((1000, 64))
    comp = jax.jit(lambda t, i, u: t.at[i].set(u), donate_argnums=(0,)).lower(t, idx, u).compile()
    c = analyze_hlo(comp.as_text())
    assert c.bytes < 8e6  # not 0.5 GB


def test_matmul_flops_including_onednn_custom_call():
    comp = _compile(lambda a, b: a @ b, jnp.ones((256, 512)), jnp.ones((512, 128)))
    c = analyze_hlo(comp.as_text())
    expect = 2 * 256 * 512 * 128
    assert 0.9 * expect < c.flops < 1.2 * expect


def test_batched_einsum_flops():
    comp = _compile(
        lambda a, b: jnp.einsum("bik,bkj->bij", a, b),
        jnp.ones((4, 64, 32), jnp.bfloat16), jnp.ones((4, 32, 16), jnp.bfloat16),
    )
    c = analyze_hlo(comp.as_text())
    expect = 2 * 4 * 64 * 32 * 16
    assert 0.8 * expect < c.flops < 1.5 * expect
