"""HLO roofline analyzer: trip-count-exact flops, touched-rows byte model.

These tests also document WHY the analyzer exists: XLA's cost_analysis counts
while bodies once and charges gathers their full operand.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analyzer import analyze_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_body_multiplied_by_trip_count():
    w = jnp.ones((8, 128, 128))

    def f(x):
        y, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)
        return y.sum()

    comp = _compile(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    c = analyze_hlo(comp.as_text())
    expect = 8 * 2 * 128**3
    assert 0.8 * expect < c.flops < 1.3 * expect
    # and XLA's own analysis indeed counts the body once (the motivation);
    # cost_analysis() returns a per-device list on newer jax.
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < 0.3 * expect


def test_gather_charges_touched_rows_not_table():
    t = jnp.ones((1_000_000, 64))
    idx = jnp.arange(1000, dtype=jnp.int32)
    comp = _compile(lambda t, i: jnp.take(t, i, axis=0), t, idx)
    c = analyze_hlo(comp.as_text())
    touched = 2 * 1000 * 64 * 4 + 1000 * 4
    assert c.bytes < 4 * touched  # not 256 MB
    assert c.bytes >= 0.5 * touched


def test_donated_scatter_charges_updates():
    t = jnp.ones((1_000_000, 64))
    idx = jnp.arange(1000, dtype=jnp.int32)
    u = jnp.ones((1000, 64))
    comp = jax.jit(lambda t, i, u: t.at[i].set(u), donate_argnums=(0,)).lower(t, idx, u).compile()
    c = analyze_hlo(comp.as_text())
    assert c.bytes < 8e6  # not 0.5 GB


def test_matmul_flops_including_onednn_custom_call():
    comp = _compile(lambda a, b: a @ b, jnp.ones((256, 512)), jnp.ones((512, 128)))
    c = analyze_hlo(comp.as_text())
    expect = 2 * 256 * 512 * 128
    assert 0.9 * expect < c.flops < 1.2 * expect


def test_batched_einsum_flops():
    comp = _compile(
        lambda a, b: jnp.einsum("bik,bkj->bij", a, b),
        jnp.ones((4, 64, 32), jnp.bfloat16), jnp.ones((4, 32, 16), jnp.bfloat16),
    )
    c = analyze_hlo(comp.as_text())
    expect = 2 * 4 * 64 * 32 * 16
    assert 0.8 * expect < c.flops < 1.5 * expect


# -- edge cases: the HLO shapes that broke (or nearly broke) the parser ------


def test_rolled_while_loop_scatter_stays_touched_rows():
    """XLA lowers a donated per-row update loop to a rolled `while` whose body
    dynamic-slices one row and dynamic-update-slices it back.  The donated
    table param is consumed only through that loop — it must be charged at
    touched-rows size, not once-per-trip x full table (16 MB x 64 trips)."""
    t = jnp.ones((65536, 64))

    def f(t, u):
        def body(i, acc):
            return acc.at[i * 7].set(u[i])

        return jax.lax.fori_loop(0, 64, body, t)

    comp = jax.jit(f, donate_argnums=(0,)).lower(t, jnp.ones((64, 64))).compile()
    c = analyze_hlo(comp.as_text())
    # loose: well under one full-table sweep (16.7 MB); the real traffic is
    # 64 rows in + RMW out, a few hundred KB
    assert c.bytes < t.size * t.dtype.itemsize
    assert c.bytes > 0


def test_cost_analysis_list_return_is_normalized_by_tests():
    """jax >= 0.4.30 returns cost_analysis() as a per-device list; older
    versions return a bare dict.  The normalization idiom used across this
    suite must accept both."""
    comp = _compile(lambda a, b: a @ b, jnp.ones((64, 64)), jnp.ones((64, 64)))
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert isinstance(ca, dict) and "flops" in ca
    assert ca["flops"] > 0


def test_multi_computation_module_parses_every_computation():
    """scan + cond + custom_vjp in one program: the module text carries many
    non-entry computations (while body/condition, branch computations, fused
    subgraphs).  parse_computations must find them all and identify ENTRY."""
    from repro.launch.hlo_analyzer import parse_computations

    @jax.custom_vjp
    def sq(x):
        return x * x

    sq.defvjp(lambda x: (x * x, x), lambda x, g: (2.0 * x * g,))

    def loss(x, w):
        def step(c, wi):
            c = jax.lax.cond(c.sum() > 0, lambda v: v @ wi, lambda v: v - 1.0, c)
            return c, None

        y, _ = jax.lax.scan(step, sq(x), w)
        return y.sum()

    comp = _compile(jax.grad(loss), jnp.ones((16, 16)), jnp.ones((4, 16, 16)))
    hlo = comp.as_text()
    comps, entry = parse_computations(hlo)
    assert entry is not None and entry in comps
    assert len(comps) > 1, "while/cond bodies must parse as separate computations"
    # every instruction name defined in a computation has a parsed type
    for c in comps.values():
        for ins in c.instrs:
            assert ins.name in c.types
    # and the analyzer still walks it end-to-end with sane totals
    r = analyze_hlo(hlo)
    assert r.flops > 0 and r.bytes > 0


def test_empty_and_headerless_text_do_not_crash():
    from repro.launch.hlo_analyzer import parse_computations

    comps, entry = parse_computations("")
    assert comps == {} and entry is None
    c = analyze_hlo("not hlo at all\n")
    assert c.flops == 0 and c.bytes == 0
