"""XLA substrate layers: chunked GQA attention vs dense ref; MoE vs dense."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.partitioning import split_params
from repro.kernels.flash_attention.ref import attention_ref
from repro.nn import moe as M
from repro.nn.layers import Dtypes, decode_attention, gqa_attention

F32 = Dtypes(param=jnp.float32, compute=jnp.float32)


@pytest.mark.parametrize("hq,hkv,window", [(4, 2, None), (4, 4, 8), (8, 1, None), (6, 2, 16)])
def test_chunked_gqa_matches_dense(hq, hkv, window):
    rng = np.random.default_rng(hq)
    b, s, d = 2, 64, 16
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
    out = gqa_attention(q, k, v, causal=True, window=window, block_q=16, block_k=16)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        True, window,
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_dense_last_position():
    rng = np.random.default_rng(0)
    b, s, hq, hkv, d = 2, 32, 4, 2, 16
    q_all = jnp.asarray(rng.normal(size=(b, s, hq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)).astype(np.float32))
    full = attention_ref(
        q_all.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        True, None,
    ).transpose(0, 2, 1, 3)
    dec = decode_attention(q_all[:, -1:], k, v, jnp.int32(s))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]), rtol=2e-5, atol=2e-5)


def test_moe_equals_dense_at_full_capacity():
    dt = F32
    p, _ = split_params(M.moe_init(jax.random.PRNGKey(0), 16, 32, 8, dt))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    out, _ = M.moe_apply(p, x, dt, top_k=8, capacity_factor=8.0)
    xt = x.reshape(-1, 16)
    probs = jax.nn.softmax(xt @ p["router"], -1)
    ref = jnp.zeros_like(xt)
    for e in range(8):
        h = jax.nn.silu(xt @ p["gate"][e]) * (xt @ p["up"][e])
        ref += probs[:, e:e + 1] * (h @ p["down"][e])
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 16)), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_moe_capacity_drop_is_graceful():
    dt = F32
    p, _ = split_params(M.moe_init(jax.random.PRNGKey(0), 8, 16, 4, dt))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 8))
    out_full, _ = M.moe_apply(p, x, dt, top_k=2, capacity_factor=4.0)
    out_tight, _ = M.moe_apply(p, x, dt, top_k=2, capacity_factor=0.5)
    assert bool(jnp.isfinite(out_tight).all())
    # tight capacity drops some tokens but output stays in a sane range
    assert float(jnp.abs(out_tight).max()) <= float(jnp.abs(out_full).max()) * 2 + 1.0


def test_moe_grads_finite_under_drop():
    dt = F32
    p, _ = split_params(M.moe_init(jax.random.PRNGKey(0), 8, 16, 4, dt))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 8))

    def loss(p_):
        out, aux = M.moe_apply(p_, x, dt, top_k=2, capacity_factor=0.5)
        return (out ** 2).mean() + 0.01 * aux

    g = jax.grad(loss)(p)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree_util.tree_leaves(g))
