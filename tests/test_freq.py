"""Static frequency module: reorder maps, coverage stats, sampling."""
import numpy as np

from repro.core import freq


def test_idx_map_is_permutation():
    counts = np.array([5, 1, 9, 0, 3])
    st = freq.build_freq_stats(counts)
    assert sorted(st.idx_map.tolist()) == list(range(5))
    assert sorted(st.inv_map.tolist()) == list(range(5))
    # rank 0 = hottest id (2), rank order matches descending counts
    assert st.inv_map[0] == 2 and st.inv_map[1] == 0
    # inverse relationship
    np.testing.assert_array_equal(st.idx_map[st.inv_map], np.arange(5))


def test_stable_ties_are_deterministic():
    counts = np.array([3, 3, 3, 3])
    st = freq.build_freq_stats(counts)
    np.testing.assert_array_equal(st.inv_map, np.arange(4))  # stable: raw order


def test_collect_counts_and_coverage():
    rng = np.random.default_rng(0)
    batches = [(rng.zipf(1.5, 100) % 50) for _ in range(20)]
    counts = freq.collect_counts(iter(batches), 50)
    assert counts.sum() == 2000
    cov = freq.coverage(counts, [0.1, 0.5, 1.0])
    assert 0 < cov[0.1] <= cov[0.5] <= cov[1.0] == 1.0
    assert cov[0.1] > 0.5  # zipf skew: top-10% of ids >> 10% of traffic


def test_sampled_counts_preserve_head_ranking():
    rng = np.random.default_rng(1)
    batches = [(rng.zipf(1.3, 1000) % 100) for _ in range(100)]
    full = freq.collect_counts(iter(batches), 100)
    samp = freq.collect_counts_sampled(iter(batches), 100, sample_rate=0.3, seed=0)
    top_full = set(freq.build_freq_stats(full).inv_map[:5].tolist())
    top_samp = set(freq.build_freq_stats(samp).inv_map[:5].tolist())
    assert len(top_full & top_samp) >= 4  # head agrees

def test_reorder_rows():
    counts = np.array([1, 5, 3])
    st = freq.build_freq_stats(counts)
    w = np.arange(6).reshape(3, 2)
    rw = st.reorder_rows(w)
    np.testing.assert_array_equal(rw[0], w[1])  # hottest first


def test_collect_counts_stream_routes_features_to_tables():
    stream = [
        {"f_a": np.array([0, 1, 1, -1]), "f_b": np.array([2, 2])},
        {"f_a": np.array([[1, 3], [3, -1]])},  # any shape; padding skipped
        {"label": np.array([1.0])},  # unmapped fields ignored
    ]
    got = freq.collect_counts_stream(
        iter(stream), {"f_a": "ta", "f_b": "tb"}, {"ta": 5, "tb": 4}
    )
    np.testing.assert_array_equal(got["ta"], [1, 3, 0, 2, 0])
    np.testing.assert_array_equal(got["tb"], [0, 0, 2, 0])
    # max_batches bounds the scan
    got1 = freq.collect_counts_stream(
        iter(stream), {"f_a": "ta", "f_b": "tb"}, {"ta": 5, "tb": 4}, max_batches=1
    )
    assert got1["ta"].sum() == 3 and got1["tb"].sum() == 2


def test_tracker_lazy_decay_normalization():
    import jax.numpy as jnp

    tr = freq.init_tracker(4)
    # touch rows {0, 2} at step 1, row {0} again at step 3
    tr = freq.tracker_touch(
        tr, jnp.array([0, 2]), jnp.array([True, True]), jnp.int32(1), half_life=2
    )
    tr = freq.tracker_touch(
        tr, jnp.array([0, -1]), jnp.array([True, False]), jnp.int32(3), half_life=2
    )
    got = freq.decayed_scores(np.asarray(tr.score), np.asarray(tr.last_touch), 3, 2)
    # row 0: 1 @step1 decayed 2 steps (x 1/2) + 1 = 1.5; row 2: 1 @step1 -> 0.5
    np.testing.assert_allclose(got, [1.5, 0.0, 0.5, 0.0], rtol=1e-6)
