"""Static frequency module: reorder maps, coverage stats, sampling."""
import numpy as np

from repro.core import freq


def test_idx_map_is_permutation():
    counts = np.array([5, 1, 9, 0, 3])
    st = freq.build_freq_stats(counts)
    assert sorted(st.idx_map.tolist()) == list(range(5))
    assert sorted(st.inv_map.tolist()) == list(range(5))
    # rank 0 = hottest id (2), rank order matches descending counts
    assert st.inv_map[0] == 2 and st.inv_map[1] == 0
    # inverse relationship
    np.testing.assert_array_equal(st.idx_map[st.inv_map], np.arange(5))


def test_stable_ties_are_deterministic():
    counts = np.array([3, 3, 3, 3])
    st = freq.build_freq_stats(counts)
    np.testing.assert_array_equal(st.inv_map, np.arange(4))  # stable: raw order


def test_collect_counts_and_coverage():
    rng = np.random.default_rng(0)
    batches = [(rng.zipf(1.5, 100) % 50) for _ in range(20)]
    counts = freq.collect_counts(iter(batches), 50)
    assert counts.sum() == 2000
    cov = freq.coverage(counts, [0.1, 0.5, 1.0])
    assert 0 < cov[0.1] <= cov[0.5] <= cov[1.0] == 1.0
    assert cov[0.1] > 0.5  # zipf skew: top-10% of ids >> 10% of traffic


def test_sampled_counts_preserve_head_ranking():
    rng = np.random.default_rng(1)
    batches = [(rng.zipf(1.3, 1000) % 100) for _ in range(100)]
    full = freq.collect_counts(iter(batches), 100)
    samp = freq.collect_counts_sampled(iter(batches), 100, sample_rate=0.3, seed=0)
    top_full = set(freq.build_freq_stats(full).inv_map[:5].tolist())
    top_samp = set(freq.build_freq_stats(samp).inv_map[:5].tolist())
    assert len(top_full & top_samp) >= 4  # head agrees

def test_reorder_rows():
    counts = np.array([1, 5, 3])
    st = freq.build_freq_stats(counts)
    w = np.arange(6).reshape(3, 2)
    rw = st.reorder_rows(w)
    np.testing.assert_array_equal(rw[0], w[1])  # hottest first
