"""Observability layer: wrap-safe exact counters, deterministic JSONL,
fixed-bucket histograms, span tracing, and the report CLI."""
import json
import threading

import jax
import jax.numpy as jnp
import pytest

from repro.obs import (
    NULL_TRACER,
    ExactCounter,
    FixedHistogram,
    MetricsHub,
    Tracer,
    log_bounds,
)
from repro.obs import report as report_mod

# --------------------------------------------------------------------------
# ExactCounter: wrap safety past 2^31 for every counter family
# --------------------------------------------------------------------------


def _wrapped(x: int) -> jnp.ndarray:
    """The int32 value a cumulative device counter holds after x events."""
    return jnp.asarray((x + 2**31) % 2**32 - 2**31, jnp.int32)


def test_exact_counter_survives_int32_wrap():
    c = ExactCounter()
    c.observe(_wrapped(2**31 - 100))
    c.observe(_wrapped(2**31 + 90))  # wrapped negative on device
    assert c.value == 2**31 + 90  # exact Python int, no wrap


def test_exact_counter_per_slab_and_idempotent():
    c = ExactCounter()
    c.observe({"a": _wrapped(5), "b": _wrapped(7)})
    c.observe({"a": _wrapped(5), "b": _wrapped(7)})  # summaries re-observe
    assert c.value == 12
    c.observe({"a": _wrapped(2**31 + 5), "b": _wrapped(7)})
    assert c.value == 2**31 + 12


def test_exact_counter_unit_weighted_bytes_are_wrap_safe():
    # bytes = rows x static row size must survive the ROW counter wrapping —
    # the legacy one-shot product (exact_metric_bytes) inherits the wrap.
    c = ExactCounter()
    c.observe({"s": _wrapped(2**31 - 10)}, unit={"s": jnp.asarray(128)})
    c.observe({"s": _wrapped(2**31 + 10)}, unit={"s": jnp.asarray(128)})
    assert c.value == (2**31 + 10) * 128


@pytest.mark.parametrize(
    "counts_key,unit_key,record_key",
    [
        ("slab_hits", None, "cache_hits"),
        ("slab_misses", None, "cache_misses"),
        ("host_moved_rows", "host_row_bytes", "host_wire_bytes"),
        ("exchange_routed_lanes", None, "exchange_routed_lanes"),
        ("exchange_routed_lanes", "exchange_lane_bytes", "exchange_bytes"),
        ("exchange_routed_lanes", "exchange_id_lane_bytes", "exchange_id_bytes"),
        ("exchange_routed_lanes", "exchange_row_lane_bytes", "exchange_row_bytes"),
        ("slab_refresh_swaps", None, "refresh_swaps_exact"),
        ("slab_refresh_rows", None, "refresh_rows_moved_exact"),
    ],
)
def test_every_hub_family_is_wrap_safe_past_2_31(counts_key, unit_key, record_key):
    """Each counter family routed through MetricsHub reconstructs exactly
    across an int32 wrap of its in-jit cumulative counter."""
    hub = MetricsHub()
    unit = 8
    m1 = {counts_key: {"s": _wrapped(2**31 - 3)}}
    m2 = {counts_key: {"s": _wrapped(2**31 + 3)}}
    if unit_key is not None:
        m1[unit_key] = {"s": jnp.asarray(unit, jnp.int32)}
        m2[unit_key] = {"s": jnp.asarray(unit, jnp.int32)}
    hub.observe_embedding_metrics(m1)
    out = hub.observe_embedding_metrics(m2)
    expect = (2**31 + 3) * (unit if unit_key is not None else 1)
    assert out[record_key] == expect
    assert isinstance(out[record_key], int)


def test_hub_derives_exact_hit_rate():
    hub = MetricsHub()
    out = hub.observe_embedding_metrics(
        {"slab_hits": {"s": _wrapped(30)}, "slab_misses": {"s": _wrapped(10)}}
    )
    assert out["hit_rate_exact"] == 0.75


# --------------------------------------------------------------------------
# FixedHistogram
# --------------------------------------------------------------------------


def test_log_bounds_cover_range_deterministically():
    b = log_bounds(1e-5, 100.0, per_decade=10)
    assert b[0] == 1e-5 and b[-1] >= 100.0
    assert b == log_bounds(1e-5, 100.0, per_decade=10)
    assert list(b) == sorted(b)


def test_histogram_quantiles_are_guaranteed_upper_bounds():
    h = FixedHistogram.latency()
    vals = [1e-3] * 900 + [1e-2] * 90 + [1e-1] * 9 + [1.0]
    for v in vals:
        h.observe(v)
    assert h.count == 1000
    s = sorted(vals)
    for q in (0.5, 0.95, 0.99, 0.999):
        true_q = s[max(0, int(q * len(s)) - 1)]
        assert h.quantile(q) >= true_q  # never under-reports
        assert h.quantile(q) <= true_q * 10 ** (1 / 10) + 1e-12  # bucket err
    assert h.quantile(1.0) == 1.0  # the max lands exactly on its sample


def test_histogram_order_independent_and_overflow_reports_max():
    vals = [5e-3, 2.0, 1e-4, 500.0, 5e-3]  # 500 s is past the last bound
    h1, h2 = FixedHistogram.latency(), FixedHistogram.latency()
    for v in vals:
        h1.observe(v)
    for v in reversed(vals):
        h2.observe(v)
    d1, d2 = h1.to_dict(), h2.to_dict()
    s1, s2 = d1.pop("sum"), d2.pop("sum")
    assert d1 == d2  # counts/extrema are exactly order-independent
    assert s1 == pytest.approx(s2)  # float sum only to addition re-ordering
    assert h1.quantile(1.0) == 500.0  # overflow bucket: exact max
    assert h1.counts[-1] == 1


def test_histogram_merge_and_roundtrip():
    a, b = FixedHistogram.latency(), FixedHistogram.latency()
    for v in (1e-3, 2e-3):
        a.observe(v)
    b.observe(0.5)
    m = a.merge(b)
    assert m.count == 3 and m.min == 1e-3 and m.max == 0.5
    assert FixedHistogram.from_dict(m.to_dict()).to_dict() == m.to_dict()
    with pytest.raises(ValueError):
        a.merge(FixedHistogram(bounds=(1.0, 2.0)))


# --------------------------------------------------------------------------
# Tracer
# --------------------------------------------------------------------------


def test_tracer_spans_aggregate_and_export_chrome_trace(tmp_path):
    tr = Tracer()
    for _ in range(3):
        with tr.span("plan"):
            pass
    with tr.span("compute", step=7):
        pass
    agg = tr.stage_summary()
    assert agg["plan"]["count"] == 3 and agg["compute"]["count"] == 1
    assert agg["plan"]["total_s"] >= 0
    path = tr.export_chrome_trace(str(tmp_path / "t.trace.json"))
    doc = json.loads((tmp_path / "t.trace.json").read_text())
    assert path.endswith("t.trace.json")
    assert len(doc["traceEvents"]) == 4
    ev = {e["name"] for e in doc["traceEvents"]}
    assert ev == {"plan", "compute"}
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in doc["traceEvents"])
    args = [e.get("args") for e in doc["traceEvents"] if e["name"] == "compute"]
    assert args == [{"step": 7}]


def test_tracer_event_cap_keeps_aggregates_exact():
    tr = Tracer(max_events=5)
    for _ in range(20):
        with tr.span("s"):
            pass
    assert tr.stage_summary()["s"]["count"] == 20  # exact past the cap
    assert tr.dropped_events == 15
    assert len(tr.chrome_trace()["traceEvents"]) == 5


def test_tracer_is_thread_safe():
    tr = Tracer()

    def work():
        for _ in range(50):
            with tr.span("w"):
                pass

    ts = [threading.Thread(target=work) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert tr.stage_summary()["w"]["count"] == 200


def test_null_tracer_records_nothing():
    with NULL_TRACER.span("x"):
        pass
    assert NULL_TRACER.stage_summary() == {}


# --------------------------------------------------------------------------
# MetricsHub sink: JSONL determinism
# --------------------------------------------------------------------------


def _strip_wall(line: str) -> dict:
    rec = json.loads(line)
    rec.pop("wall", None)
    return rec


def _run_hub(run_dir) -> str:
    hub = MetricsHub(run_dir=str(run_dir), run="r", timestamps=True)
    for step in range(3):
        out = hub.observe_embedding_metrics(
            {"slab_hits": {"s": _wrapped(10 * (step + 1))},
             "slab_misses": {"s": _wrapped(2 * (step + 1))}}
        )
        hub.histogram("step_time_s").observe(1e-3 * (step + 1))
        hub.log("step", {"step": step, **out}, wall={"time_s": 1e-3})
    tr = Tracer()
    with tr.span("compute"):
        pass
    hub.log_hist("step_time_s")
    hub.log_spans(tr)
    hub.close()
    return hub.jsonl_path


def test_jsonl_streams_are_byte_identical_modulo_wall(tmp_path):
    """Two identical runs emit byte-identical JSONL once the reserved `wall`
    subtree (timestamps, durations) is dropped — telemetry diffs become
    regression signals."""
    p1 = _run_hub(tmp_path / "a")
    p2 = _run_hub(tmp_path / "b")
    l1 = open(p1).read().splitlines()
    l2 = open(p2).read().splitlines()
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        assert _strip_wall(a) == _strip_wall(b)
    # every record's deterministic part serializes with sorted keys: the
    # stripped record re-serialized matches the on-disk prefix ordering
    for line in l1:
        rec = json.loads(line)
        assert json.dumps(rec, sort_keys=True) == line


def test_jsonl_without_timestamps_is_fully_byte_identical(tmp_path):
    def run(d):
        hub = MetricsHub(run_dir=str(d), run="r", timestamps=False)
        hub.log("step", {"step": 0, "loss": 0.5})
        hub.log_hist("h", FixedHistogram(bounds=(1.0, 2.0)))
        hub.close()
        return open(hub.jsonl_path).read()

    a = run(tmp_path / "a")
    b = run(tmp_path / "b")
    # histogram payloads sit under `wall`; with no observations and no
    # timestamps the full files match byte for byte
    assert a == b


def test_hub_sinkless_mode_accumulates_without_files(tmp_path):
    hub = MetricsHub()  # no run_dir
    hub.counter("c").add(3)
    hub.log("step", {"step": 0})
    assert hub.jsonl_path is None
    assert hub.snapshot()["counters"]["c"] == 3
    hub.close()
    assert list(tmp_path.iterdir()) == []


def test_hub_snapshot_delta():
    hub = MetricsHub()
    hub.counter("x").add(10)
    snap = hub.snapshot()
    hub.counter("x").add(5)
    assert hub.delta(snap) == {"x": 5}


# --------------------------------------------------------------------------
# report CLI
# --------------------------------------------------------------------------


def test_report_cli_renders_and_json(tmp_path, capsys):
    path = _run_hub(tmp_path)
    assert report_mod.main([path]) == 0
    text = capsys.readouterr().out
    assert "cache: 30 hits / 6 misses (exact)" in text
    assert "compute" in text and "step_time_s" in text
    assert report_mod.main([path, "--json"]) == 0
    summary = json.loads(capsys.readouterr().out)
    assert summary["train"]["n_steps"] == 3
    assert summary["train"]["cache_hits_total"] == 30
    assert summary["counters"]["cache_hits"] == 30
    assert summary["latency"]["step_time_s"]["count"] == 3


# --------------------------------------------------------------------------
# trainer integration: history bounding + step records
# --------------------------------------------------------------------------


def _toy_trainer(tmp_path=None, **cfg_kw):
    from repro.train.trainer import Trainer, TrainerConfig

    def step_fn(state, batch):
        s = state + 1
        return s, {"loss": jnp.asarray(0.5, jnp.float32)}

    return Trainer(
        TrainerConfig(max_steps=6, **cfg_kw),
        init_fn=lambda: jnp.zeros((), jnp.int32),
        step_fn=jax.jit(step_fn),
        make_batch=lambda s: {"x": s},
    )


def test_trainer_history_limit_bounds_memory(tmp_path):
    tr = _toy_trainer(obs_dir=str(tmp_path), history_limit=2)
    tr.run()
    assert len(tr.history) == 2  # only the tail stays in memory
    assert [r["step"] for r in tr.history] == [4, 5]
    # ...while the full stream is on disk
    records = report_mod.load_records(tr.hub.jsonl_path)
    steps = [r for r in records if r.get("kind") == "step"]
    assert [r["step"] for r in steps] == [0, 1, 2, 3, 4, 5]
    assert all("time_s" in r["wall"] for r in steps)  # wall-clock quarantined
    kinds = [r.get("kind") for r in records]
    assert kinds[0] == "meta" and "hist" in kinds and "spans" in kinds
    assert kinds[-1] == "summary"
    assert tr.trace_path and json.load(open(tr.trace_path))["traceEvents"]


def test_trainer_default_history_unbounded():
    tr = _toy_trainer()
    tr.run()
    assert [r["step"] for r in tr.history] == [0, 1, 2, 3, 4, 5]
    assert tr.hub.jsonl_path is None  # no obs dir -> no files
    assert tr.tracer is NULL_TRACER
