"""Mixed-precision device arena: fp32-head/encoded-tail tiering exactness,
precision-boundary crossings (churn + refresh), per-device byte accounting,
planner sideband budgeting, checkpoint loudness, and counter plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as cache_lib
from repro.core import collection as col
from repro.core.refresh import RefreshConfig
from repro.core.sharded import ShardedEmbeddingCollection
from repro.obs import MetricsHub
from repro.store import ArenaStore, get_codec, tiered_arena_bytes
from repro.train import checkpoint as ckpt


def _tables(dim=8, ids=16):
    return [
        col.TableConfig("big", vocab=512, dim=dim, ids_per_step=ids, cache_ratio=0.1),
        col.TableConfig("small", vocab=96, dim=dim, ids_per_step=ids, cache_ratio=0.3),
    ]


def _fb(tables, n, seed):
    rng = np.random.default_rng(seed)
    return col.FeatureBatch(ids={
        t.name: jnp.asarray(rng.integers(-1, t.vocab, n).astype(np.int32))
        for t in tables
    })


def _counts(tables, seed=1):
    rng = np.random.default_rng(seed)
    return {t.name: rng.integers(0, 50, t.vocab) for t in tables}


def _warm_state(coll, tables, steps=10, seed0=100):
    state = coll.init(jax.random.PRNGKey(0), counts=_counts(tables))
    step = jax.jit(lambda s, f: coll.lookup(s, f))
    for i in range(steps):
        state, _, _ = step(state, _fb(tables, 16, seed0 + i))
    return state


# --------------------------------------------------------------------------
# layout: fp32 keeps the raw arena, tiered builds an ArenaStore
# --------------------------------------------------------------------------


def test_fp32_default_is_bit_identical_to_explicit_fp32():
    """arena_precision='fp32' (and omitting it) must keep the exact pre-
    tiering state: same treedef (raw dict arena, no ArenaStore), bitwise
    equal leaves along a lookup stream."""
    tables = _tables()
    a = col.EmbeddingCollection.create(tables, cache_ratio=0.1)
    b = col.EmbeddingCollection.create(tables, cache_ratio=0.1,
                                       arena_precision="fp32")
    sa, sb = _warm_state(a, tables), _warm_state(b, tables)
    assert isinstance(sa.slabs[col.SHARED_ARENA].cache.cached_rows, dict)
    assert (jax.tree_util.tree_structure(sa)
            == jax.tree_util.tree_structure(sb))
    for la, lb in zip(jax.tree_util.tree_leaves(sa), jax.tree_util.tree_leaves(sb)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


@pytest.mark.parametrize("precision", ["fp16", "int8"])
def test_tiered_state_builds_arena_store(precision):
    tables = _tables()
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.1,
                                          arena_precision=precision,
                                          arena_head_ratio=0.25)
    state = coll.init(jax.random.PRNGKey(0))
    spec = coll.cached_slabs[col.SHARED_ARENA]
    arena = state.slabs[col.SHARED_ARENA].cache.cached_rows
    assert isinstance(arena, ArenaStore)
    assert arena.head_capacity == spec.head_capacity
    assert arena.head["weight"].shape[-2] == spec.head_capacity
    assert arena.tail["weight"].shape[-2] == spec.capacity - spec.head_capacity
    assert arena.head["weight"].dtype == jnp.float32
    db = coll.device_bytes()
    assert db["arena_bytes_saved"] > 0
    assert db["device_total"] + db["arena_bytes_saved"] == (
        col.EmbeddingCollection.create(tables, cache_ratio=0.1).device_bytes()
        ["device_total"]
    )


# --------------------------------------------------------------------------
# exactness: post-flush lookups == the dense oracle at every precision
# --------------------------------------------------------------------------


@pytest.mark.parametrize("precision", ["fp16", "int8"])
def test_post_flush_lookup_matches_dense_reference(precision):
    """The paper's consistency contract under tiering: flush makes the slow
    tier authoritative, and the oracle then agrees with through-cache
    lookups EXACTLY when both decode in the same execution mode.  (Under
    ``jax.jit`` XLA may FMA-fuse the tail decode's multiply-add, shifting
    fp32 results by 1 ulp vs the eager flush — bounded below, not exact.)"""
    tables = _tables()
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.1,
                                          arena_precision=precision)
    state = coll.init(jax.random.PRNGKey(0), counts=_counts(tables))
    jstate = state
    jstep = jax.jit(lambda s, f: coll.lookup(s, f))
    for i in range(10):
        fb = _fb(tables, 16, 500 + i)
        state, _, rows = coll.lookup(state, fb)
        ref = coll.dense_reference(coll.flush(state), fb)
        jstate, _, jrows = jstep(jstate, fb)
        for f in fb.features:
            np.testing.assert_array_equal(np.asarray(rows[f]), np.asarray(ref[f]))
            np.testing.assert_allclose(
                np.asarray(jrows[f]), np.asarray(ref[f]), rtol=0, atol=1e-6
            )


# --------------------------------------------------------------------------
# precision-boundary crossings
# --------------------------------------------------------------------------


def test_cache_churn_counts_promotions_and_demotions():
    """Evicting a head slot demotes; loading into a head slot promotes —
    full-arena churn (every slot evicted) must tick both counters.  LRU so
    recency makes the head slots stale and evictable (FREQ_LFU's static
    rank key would protect the rank-0/1 head rows forever)."""
    from repro.core.policies import Policy

    cfg = cache_lib.CacheConfig(
        vocab=32, capacity=8, ids_per_step=4, buffer_rows=8,
        arena_precision="int8", arena_head_ratio=0.25, policy=Policy.LRU,
    )
    assert cfg.head_capacity == 2
    st = cache_lib.init_cache(cfg, {"weight": jnp.zeros((4,), jnp.float32)})
    full = {"weight": jnp.arange(32 * 4, dtype=jnp.float32).reshape(32, 4)}
    prep = jax.jit(lambda f, s, i: cache_lib.prepare(cfg, f, s, i))
    for lo in (0, 4, 8, 12, 16, 20):  # 3 disjoint working sets -> full churn
        ids = jnp.arange(lo, lo + 4, dtype=jnp.int32)
        full, st, _ = prep(full, st, ids)
    assert int(st.tier_promotions) > 0
    assert int(st.tier_demotions) > 0
    # the fp32 arena never crosses a boundary: counters stay zero
    cfg32 = cache_lib.CacheConfig(vocab=32, capacity=8, ids_per_step=4,
                                  buffer_rows=8)
    st32 = cache_lib.init_cache(cfg32, {"weight": jnp.zeros((4,), jnp.float32)})
    f32 = {"weight": jnp.zeros((32, 4), jnp.float32)}
    for lo in (0, 4, 8, 12):
        f32, st32, _ = cache_lib.prepare(cfg32, f32, st32, jnp.arange(lo, lo + 4, dtype=jnp.int32))
    assert int(st32.tier_promotions) == 0 and int(st32.tier_demotions) == 0


def test_demote_evict_promote_round_trip_values():
    """A row's demote -> evict -> re-fault cycle stays consistent: gathers
    always equal the flushed slow tier, and the int8 decode is a stable
    projection (repeat round trips stop losing bits after the first)."""
    from repro.core.policies import Policy

    cfg = cache_lib.CacheConfig(
        vocab=32, capacity=8, ids_per_step=4, buffer_rows=8,
        arena_precision="int8", arena_head_ratio=0.25, policy=Policy.LRU,
    )
    st = cache_lib.init_cache(cfg, {"weight": jnp.zeros((4,), jnp.float32)})
    rng = np.random.default_rng(7)
    full = {"weight": jnp.asarray(rng.normal(size=(32, 4)).astype(np.float32))}
    ids_a = jnp.arange(0, 4, dtype=jnp.int32)
    ids_b = jnp.arange(8, 12, dtype=jnp.int32)
    ids_c = jnp.arange(16, 20, dtype=jnp.int32)
    orig0 = np.asarray(full["weight"][0]).copy()
    # three disjoint working sets over capacity 8: every set is repeatedly
    # evicted (head included, LRU) and re-faulted
    for ids in (ids_a, ids_b, ids_c, ids_a, ids_b, ids_c, ids_a):
        full, st, slots = cache_lib.prepare(cfg, full, st, ids)
        got = cache_lib.lookup_slots(st, slots, "weight")
        ff, _ = cache_lib.flush(cfg, full, st)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(ff["weight"][np.asarray(ids)])
        )
    # quantization error is bounded (one int8 round trip of a unit normal)
    assert float(np.abs(np.asarray(full["weight"][0]) - orig0).max()) < 0.05


def test_arena_store_tail_scatter_gather_is_stable_projection():
    """Re-scattering gathered (decoded) tail rows keeps the int8 PAYLOAD
    bit-stable from the first cycle (the codec's tested projection property)
    and the decoded values within codec tolerance."""
    arena = ArenaStore.create({"weight": jnp.zeros((8, 4), jnp.float32)},
                              head_capacity=2, codec="int8")
    rng = np.random.default_rng(0)
    block = {"weight": jnp.asarray(rng.normal(size=(3, 4)).astype(np.float32))}
    slots = jnp.asarray([2, 5, 7], jnp.int32)  # all tail slots
    arena = arena.scatter_slots(slots, block, jnp.ones((3,), bool))
    once = arena.gather_slots(slots)
    arena2 = arena.scatter_slots(slots, once, jnp.ones((3,), bool))
    twice = arena2.gather_slots(slots)
    np.testing.assert_array_equal(np.asarray(arena.tail["weight"]),
                                  np.asarray(arena2.tail["weight"]))
    np.testing.assert_allclose(np.asarray(once["weight"]),
                               np.asarray(twice["weight"]), atol=1e-6)
    # negative slots gather zero rows (padding contract)
    pad = arena.gather_slots(jnp.asarray([-1, -1], jnp.int32))
    assert bool((np.asarray(pad["weight"]) == 0).all())


def test_refresh_on_tiered_arena_swaps_and_stays_exact():
    """Refresh crosses the precision boundary through its existing machinery
    (invalidate + re-fault): on a flushed int8-arena state the oracle is
    preserved to 1 fp32 ulp (the surgery's jitted tail decode may FMA-fuse
    differently than the eager flush — no codec-step-sized drift), and
    post-refresh lookups still match the oracle exactly."""
    tables = _tables()
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.1,
                                          arena_precision="int8")
    state = coll.flush(_warm_state(coll, tables))
    probe = _fb(tables, 16, 999)
    before = coll.dense_reference(state, probe)
    state2, rep = coll.refresh(state, RefreshConfig(max_swaps=32))
    assert rep.total_swaps > 0
    after = coll.dense_reference(coll.flush(state2), probe)
    for k in before:
        np.testing.assert_allclose(np.asarray(before[k]), np.asarray(after[k]),
                                   rtol=0, atol=1e-6)
    state2, _, rows = coll.lookup(state2, probe)  # eager: same-mode decode
    ref = coll.dense_reference(coll.flush(state2), probe)
    for k in rows:
        np.testing.assert_array_equal(np.asarray(rows[k]), np.asarray(ref[k]))
    m = coll.metrics(state2)
    assert int(m["slab_tier_promotions"][col.SHARED_ARENA]) >= 0
    assert int(m["slab_tier_demotions"][col.SHARED_ARENA]) >= 0


# --------------------------------------------------------------------------
# sharded: tiered arenas under vmap + the replicated hot head
# --------------------------------------------------------------------------


@pytest.mark.parametrize("rep_k", [0, 8])
def test_sharded_tiered_post_flush_exact(rep_k):
    tables = _tables()
    coll = ShardedEmbeddingCollection.create(
        tables, num_shards=2, cache_ratio=0.1, arena_precision="int8",
        replicate_top_k=rep_k,
    )
    state = coll.init(jax.random.PRNGKey(0), counts=_counts(tables))
    jstate = state
    jstep = jax.jit(lambda s, f: coll.lookup(s, f))
    for i in range(8):
        fb = _fb(tables, 16, 700 + i)
        state, _, rows = coll.lookup(state, fb)  # eager: same-mode decode
        ref = coll.dense_reference(coll.flush(state), fb)
        jstate, _, jrows = jstep(jstate, fb)
        for f in fb.features:
            np.testing.assert_array_equal(np.asarray(rows[f]), np.asarray(ref[f]))
            np.testing.assert_allclose(
                np.asarray(jrows[f]), np.asarray(ref[f]), rtol=0, atol=1e-6
            )
    m = coll.metrics(state)
    for sname in coll.cached_slabs:
        assert m["slab_tier_promotions"][sname].dtype == jnp.int32
        assert m["slab_tier_demotions"][sname].dtype == jnp.int32


def test_sharded_rep_arena_charged_per_device():
    """Satellite regression: the replicated hot head lives on EVERY shard —
    device_total must charge it S times, device_per_shard once."""
    tables = _tables()
    S, K, dim = 2, 16, 8
    base = ShardedEmbeddingCollection.create(tables, num_shards=S, cache_ratio=0.1)
    rep = ShardedEmbeddingCollection.create(tables, num_shards=S, cache_ratio=0.1,
                                            replicate_top_k=K)
    db0, db1 = base.device_bytes(), rep.device_bytes()
    n_slabs = len(rep.cached_slabs)
    # rows + score + last_touch per replicated rank (the step scalar exists
    # at K=0 too, so it cancels in the K=16 - K=0 difference)
    rep_rows = K * (dim * 4 + 4 + 4)
    assert db1["device_total"] - db0["device_total"] == S * rep_rows * n_slabs
    assert db1["device_per_shard"] - db0["device_per_shard"] == rep_rows * n_slabs


def test_sharded_tiered_arena_shrinks_device_bytes():
    tables = _tables()
    f32 = ShardedEmbeddingCollection.create(tables, num_shards=2, cache_ratio=0.1)
    i8 = ShardedEmbeddingCollection.create(tables, num_shards=2, cache_ratio=0.1,
                                           arena_precision="int8")
    a, b = f32.device_bytes(), i8.device_bytes()
    assert b["arena_bytes_saved"] > 0
    assert b["device_total"] == a["device_total"] - b["arena_bytes_saved"]


# --------------------------------------------------------------------------
# planner budget accounting (sideband bytes are device-resident)
# --------------------------------------------------------------------------


def test_planner_budget_charges_tail_sideband():
    cap, dim, head_ratio = 128, 16, 0.25
    head = int(round(head_ratio * cap))
    got = col.PlacementPlanner._tiered_weight_bytes(
        cap, dim, jnp.float32, "int8", head_ratio
    )
    row = get_codec("int8").row_bytes((dim,), jnp.float32)
    assert row > dim  # int8 payload + per-row [scale, zero] fp32 sideband
    assert got == head * dim * 4 + (cap - head) * row
    assert got == tiered_arena_bytes(cap, head, dim, jnp.float32, "int8")
    # fp32 is the untiered layout
    assert col.PlacementPlanner._tiered_weight_bytes(
        cap, dim, jnp.float32, "fp32", head_ratio
    ) == cap * dim * 4


def test_budgeted_plan_respects_budget_with_tiered_arena():
    tables = _tables()
    budget = 14_000  # holds "small" resident, forces "big" through the cache
    coll = col.EmbeddingCollection.create(tables, budget_bytes=budget,
                                          arena_precision="int8")
    assert coll.cached_slabs, "want at least one cached slab under the budget"
    db = coll.device_bytes()
    assert db["device_total"] <= budget
    assert db["arena_bytes_saved"] > 0


# --------------------------------------------------------------------------
# "auto" resolution
# --------------------------------------------------------------------------


def test_auto_resolution_is_written_back():
    tables = _tables()
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.1,
                                          arena_precision="auto")
    state = coll.init(jax.random.PRNGKey(0), counts=_counts(tables))
    resolved = coll.arena_precision[col.SHARED_ARENA]
    assert resolved in ("fp32", "fp16", "int8")
    spec = coll.cached_slabs[col.SHARED_ARENA]
    assert spec.arena_precision == resolved
    assert spec.cache_config().arena_precision == resolved
    # the state's container agrees with the resolution
    arena = state.slabs[col.SHARED_ARENA].cache.cached_rows
    assert isinstance(arena, ArenaStore) == (resolved != "fp32")


# --------------------------------------------------------------------------
# checkpoint: arena mismatches fail loudly
# --------------------------------------------------------------------------


def test_checkpoint_tiered_vs_fp32_template_fails_loudly(tmp_path):
    tables = _tables()
    tiered = col.EmbeddingCollection.create(tables, cache_ratio=0.1,
                                            arena_precision="int8")
    ckpt.save(tmp_path, 0, tiered.init(jax.random.PRNGKey(0)))
    f32 = col.EmbeddingCollection.create(tables, cache_ratio=0.1)
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, f32.init(jax.random.PRNGKey(0)))


def test_checkpoint_head_ratio_mismatch_names_the_arena(tmp_path):
    tables = _tables()
    a = col.EmbeddingCollection.create(tables, cache_ratio=0.1,
                                       arena_precision="int8",
                                       arena_head_ratio=0.25)
    ckpt.save(tmp_path, 0, a.init(jax.random.PRNGKey(0)))
    b = col.EmbeddingCollection.create(tables, cache_ratio=0.1,
                                       arena_precision="int8",
                                       arena_head_ratio=0.5)
    with pytest.raises(ValueError, match="arena_precision"):
        ckpt.restore(tmp_path, b.init(jax.random.PRNGKey(0)))


def test_checkpoint_round_trip_same_precision(tmp_path):
    tables = _tables()
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.1,
                                          arena_precision="int8")
    state = coll.flush(_warm_state(coll, tables, steps=4))
    ckpt.save(tmp_path, 0, state)
    restored, step = ckpt.restore(tmp_path, state)
    assert step == 0
    for la, lb in zip(jax.tree_util.tree_leaves(state),
                      jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# --------------------------------------------------------------------------
# obs hub: the tier counter families reconstruct exactly past an int32 wrap
# --------------------------------------------------------------------------


def _wrapped(x: int) -> jnp.ndarray:
    return jnp.asarray(np.int64(x).astype(np.int32))


@pytest.mark.parametrize(
    "family", ["slab_tier_promotions", "slab_tier_demotions"]
)
def test_tier_counter_family_wrap_safe_past_2_31(family):
    hub = MetricsHub()
    hub.observe_embedding_metrics({family: {"s": _wrapped(2**31 - 3)}})
    out = hub.observe_embedding_metrics({family: {"s": _wrapped(2**31 + 3)}})
    assert out[family] == 2**31 + 3
    assert isinstance(out[family], int)
