"""Data pipeline determinism + skew; serving engine; GNN sampler validity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import freq
from repro.data import graphs, synth
from repro.data.pipeline import Prefetcher
from repro.models.dlrm import DLRM, DLRMConfig
from repro.serve.engine import ServeEngine


def test_batches_are_pure_functions_of_seed_and_step():
    spec = synth.ZipfSparseSpec(vocab_sizes=(100, 200), n_dense=4)
    a = synth.sparse_batch(spec, 32, seed=7, step=3)
    b = synth.sparse_batch(spec, 32, seed=7, step=3)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = synth.sparse_batch(spec, 32, seed=7, step=4)
    assert not np.array_equal(a["sparse"], c["sparse"])


def test_zipf_skew_matches_paper_figure2():
    """Paper Fig 2: a tiny head of ids covers most accesses."""
    spec = synth.ZipfSparseSpec(vocab_sizes=(1_000_000,), zipf_a=1.2)
    counts = freq.collect_counts(synth.count_stream(spec, 4096, 20, seed=0), 1_000_000)
    cov = freq.coverage(counts, [0.0014, 0.01])
    assert cov[0.0014] > 0.5  # top 0.14% of ids > half the traffic
    assert cov[0.01] > 0.65


def test_prefetcher_order_and_resume():
    seen = []
    pf = Prefetcher(lambda s: {"x": np.asarray([s])}, start_step=5, depth=2)
    for step, batch in pf:
        seen.append((step, int(batch["x"][0])))
        if len(seen) == 4:
            break
    pf.close()
    assert seen == [(5, 5), (6, 6), (7, 7), (8, 8)]


def test_neighbor_sampler_validity():
    indptr, indices, _ = graphs.random_graph_csr(500, 3000, 0)
    rng = np.random.default_rng(0)
    nodes, src, dst, n_seed = graphs.neighbor_sample(
        indptr, indices, rng.integers(0, 500, 16), (4, 3), rng
    )
    assert n_seed == 16
    assert len(nodes) == 16 * (1 + 4 + 12)
    assert len(src) == 16 * (4 + 12)
    m = src >= 0
    # local indices reference the node array
    assert src[m].max() < len(nodes) and dst[m].max() < len(nodes)
    # every sampled edge's endpoints agree with the global graph arrays
    assert (dst[m] >= 0).all()


def test_serve_engine_pads_and_tracks_stats():
    cfg = DLRMConfig(vocab_sizes=(64, 32), n_dense=4, embed_dim=8, batch_size=16,
                     cache_ratio=0.5, bottom_mlp=(8,), top_mlp=(8,))
    model = DLRM(cfg)
    state = model.init(jax.random.PRNGKey(0))
    pad = {"dense": np.zeros((4,), np.float32), "sparse": np.zeros((2,), np.int32),
           "label": np.zeros((), np.float32)}
    eng = ServeEngine(model.serve_step, state, batch_size=16, pad_example=pad)
    batch = {
        "dense": np.random.default_rng(0).normal(size=(5, 4)).astype(np.float32),
        "sparse": np.zeros((5, 2), np.int32),
        "label": np.zeros((5,), np.float32),
    }
    scores = eng.score(batch)
    assert scores.shape == (5,)
    s = eng.stats.summary()
    assert s["requests"] == 5 and s["batches"] == 1 and s["p99_ms"] > 0
    eng.score(batch)
    assert eng.stats.summary()["requests"] == 10
