"""Planner-driven EmbeddingCollection: placement plans, keyed-feature API,
mixed-plan exactness, and the end-to-end train/serve acceptance path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collection as col
from repro.core.policies import Policy


def small_tables(dim=8, ids=16):
    return [
        col.TableConfig("hot", vocab=64, dim=dim, ids_per_step=ids),
        col.TableConfig("big", vocab=4096, dim=dim, ids_per_step=ids, cache_ratio=0.1),
        col.TableConfig("tiny_a", vocab=24, dim=dim, ids_per_step=ids),
        col.TableConfig("tiny_b", vocab=24, dim=dim, ids_per_step=ids),
    ]


def zipf_fb(tables, n, seed):
    rng = np.random.default_rng(seed)
    return col.FeatureBatch(ids={
        t.name: jnp.asarray((rng.zipf(1.3, n) % t.vocab).astype(np.int32))
        for t in tables
    })


# --------------------------------------------------------------------------
# planner
# --------------------------------------------------------------------------


def test_planner_respects_budget_and_mixes_placements():
    tables = small_tables()
    budget = 80_000  # holds the small tables, not the big one
    plan = col.PlacementPlanner(budget, group_below_rows=32).plan(tables)
    coll = col.EmbeddingCollection(tables, plan)
    placements = {n: p.placement for n, p in plan.placements.items()}
    assert placements["hot"] is col.Placement.DEVICE
    assert placements["big"] is col.Placement.CACHED
    assert placements["tiny_a"] is col.Placement.GROUPED
    assert placements["tiny_b"] is col.Placement.GROUPED
    assert coll.device_bytes()["device_total"] <= budget


def test_planner_prefers_hot_tables_with_counts():
    dim = 8
    tables = [
        col.TableConfig("a", vocab=256, dim=dim, ids_per_step=16),
        col.TableConfig("b", vocab=256, dim=dim, ids_per_step=16),
    ]
    # room for one DEVICE table plus the other table's cache floor (which
    # includes the online frequency tracker's vocab-sized counters), but NOT
    # for both tables resident
    budget = 256 * dim * 4 + col.PlacementPlanner(0)._fast_bytes(tables[0], 0.0) + 64
    counts = {"a": np.ones(256), "b": np.full(256, 1000)}
    plan = col.PlacementPlanner(budget).plan(tables, counts=counts)
    assert plan.placements["b"].placement is col.Placement.DEVICE
    assert plan.placements["a"].placement is col.Placement.CACHED


def test_planner_raises_when_budget_infeasible():
    tables = [col.TableConfig("t", vocab=1000, dim=64, ids_per_step=512)]
    with pytest.raises(ValueError):
        col.PlacementPlanner(100).plan(tables)


def test_floor_scaled_ratio_zero_is_honored():
    """A planner-assigned ratio of 0.0 (exactness floor) must not fall back
    to the table's own ratio — the built slab has floor capacity and the
    device footprint stays within the budget the planner enforced."""
    t = col.TableConfig("big", vocab=100_000, dim=32, ids_per_step=256, cache_ratio=0.05)
    floor_budget = col.PlacementPlanner(0)._fast_bytes(t, 0.0)
    plan = col.PlacementPlanner(floor_budget).plan([t])
    assert plan.placements["big"].cache_ratio == 0.0
    coll = col.EmbeddingCollection([t], plan)
    assert coll.cached_slabs["big"].capacity == t.unique_size()
    assert coll.device_bytes()["device_total"] <= floor_budget


def test_full_lookup_padding_is_zero_on_cached_tables():
    tables = [
        col.TableConfig("a", vocab=32, dim=4, ids_per_step=8),
        col.TableConfig("b", vocab=32, dim=4, ids_per_step=8),
    ]
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.5)  # shared arena
    state = coll.init(jax.random.PRNGKey(0))
    ids = jnp.asarray([3, -1, 7, -1], jnp.int32)
    rows = coll.full_lookup(state, "b", ids)
    assert bool((np.asarray(rows)[[1, 3]] == 0).all())
    assert bool((np.asarray(rows)[[0, 2]] != 0).any())


def test_dlrm_budget_mode_keeps_max_unique_bound():
    from repro.models.dlrm import DLRM, DLRMConfig

    cfg = DLRMConfig(vocab_sizes=(4096, 64), embed_dim=8, batch_size=16,
                     cache_ratio=0.25, max_unique_per_step=8,
                     bottom_mlp=(8,), top_mlp=(8,), device_budget_bytes=80_000)
    model = DLRM(cfg)
    cached = [s for s in model.collection.cached_slabs.values()]
    assert cached and all(s.max_unique_per_step == 8 for s in cached)


def test_explicit_placement_overrides_survive():
    tables = [
        col.TableConfig("pin_dev", vocab=32, dim=4, ids_per_step=8,
                        placement=col.Placement.DEVICE),
        col.TableConfig("pin_cache", vocab=32, dim=4, ids_per_step=8,
                        placement=col.Placement.CACHED, cache_ratio=0.5),
    ]
    plan = col.PlacementPlanner(10**9).plan(tables)
    assert plan.placements["pin_dev"].placement is col.Placement.DEVICE
    assert plan.placements["pin_cache"].placement is col.Placement.CACHED


# --------------------------------------------------------------------------
# mixed-plan exactness (THE paper property, generalized)
# --------------------------------------------------------------------------


def test_mixed_plan_matches_dense_reference_bitwise():
    tables = small_tables()
    plan = col.PlacementPlanner(80_000, group_below_rows=32).plan(tables)
    coll = col.EmbeddingCollection(tables, plan)
    assert coll.device_slabs and coll.cached_slabs, "want a genuinely mixed plan"
    state = coll.init(jax.random.PRNGKey(0))
    step = jax.jit(lambda s, fb: coll.lookup(s, fb))
    for i in range(20):
        fb = zipf_fb(tables, 16, seed=i)
        state, _, rows = step(state, fb)
        ref = coll.dense_reference(coll.flush(state), fb)
        for f in fb.features:
            np.testing.assert_array_equal(np.asarray(rows[f]), np.asarray(ref[f]))


def test_padding_lanes_give_zero_rows_everywhere():
    tables = small_tables()
    plan = col.PlacementPlanner(80_000, group_below_rows=32).plan(tables)
    coll = col.EmbeddingCollection(tables, plan)
    state = coll.init(jax.random.PRNGKey(0))
    fb = col.FeatureBatch(ids={t.name: jnp.full((16,), -1, jnp.int32) for t in tables})
    state, addr, rows = coll.lookup(state, fb)
    for f in fb.features:
        assert bool((np.asarray(addr[f]) == -1).all())
        assert bool((np.asarray(rows[f]) == 0).all())


def test_grads_reach_device_and_cached_tiers():
    tables = small_tables()
    plan = col.PlacementPlanner(80_000, group_below_rows=32).plan(tables)
    coll = col.EmbeddingCollection(tables, plan)
    state = coll.init(jax.random.PRNGKey(0))
    fb = zipf_fb(tables, 16, seed=0)
    state, addr = coll.prepare(state, fb)

    def loss_fn(w):
        rows = coll.gather(w, addr, fb)
        return sum(jnp.sum(r**2) for r in rows.values())

    grads = jax.grad(loss_fn)(coll.weights(state))
    assert any(float(jnp.abs(grads[s]).max()) > 0 for s in coll.device_slabs)
    assert any(float(jnp.abs(grads[s]).max()) > 0 for s in coll.cached_slabs)
    before = coll.weights(state)
    state2 = coll.apply_grads(state, grads, 0.1)
    after = coll.weights(state2)
    for s in before:
        assert not np.array_equal(np.asarray(before[s]), np.asarray(after[s]))


def test_uniq_overflow_counted_under_collection_api():
    tables = [col.TableConfig("t", vocab=100, dim=4, ids_per_step=16,
                              max_unique_per_step=4, cache_ratio=0.3,
                              placement=col.Placement.CACHED)]
    coll = col.EmbeddingCollection(tables, col.PlacementPlanner(10**9).plan(tables))
    state = coll.init(jax.random.PRNGKey(0))
    fb = col.FeatureBatch(ids={"t": jnp.arange(16, dtype=jnp.int32)})  # 16 distinct > 4
    state, _ = coll.prepare(state, fb)
    assert int(coll.metrics(state)["uniq_overflows"]) == 1
    fb2 = col.FeatureBatch(ids={"t": jnp.zeros(16, jnp.int32)})  # 1 distinct: fine
    state, _ = coll.prepare(state, fb2)
    assert int(coll.metrics(state)["uniq_overflows"]) == 1


# --------------------------------------------------------------------------
# FeatureBatch
# --------------------------------------------------------------------------


def test_feature_batch_from_onehot_and_shapes():
    m = jnp.asarray([[1, 2], [3, 4], [5, 6]], jnp.int32)
    fb = col.FeatureBatch.from_onehot(("a", "b"), m)
    np.testing.assert_array_equal(np.asarray(fb.ids["a"]), [1, 3, 5])
    np.testing.assert_array_equal(np.asarray(fb.ids["b"]), [2, 4, 6])


def test_feature_batch_bags_pool_matches_manual_segment_sum():
    tables = [col.TableConfig("t", vocab=50, dim=4, ids_per_step=12,
                              cache_ratio=0.5)]
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.5)
    state = coll.init(jax.random.PRNGKey(0))
    flat = jnp.asarray([1, 2, 3, -1, 4, 5, 6, 7, -1, -1, 8, 9], jnp.int32)
    seg = jnp.asarray([0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2], jnp.int32)
    fb = col.FeatureBatch.from_bags({"t": (flat, seg)}, num_segments=3)
    state, _, rows = coll.lookup(state, fb)
    pooled = coll.pool(rows, fb)["t"]
    manual = np.zeros((3, 4), np.float32)
    r = np.asarray(rows["t"])
    for lane in range(12):
        manual[int(seg[lane])] += r[lane]
    np.testing.assert_allclose(np.asarray(pooled), manual, rtol=1e-6)
    # bag features keep exactness too
    ref = coll.dense_reference(coll.flush(state), fb)
    np.testing.assert_array_equal(np.asarray(rows["t"]), np.asarray(ref["t"]))


def test_unknown_feature_is_rejected():
    tables = [col.TableConfig("t", vocab=10, dim=2, ids_per_step=4)]
    coll = col.EmbeddingCollection.create(tables)
    state = coll.init(jax.random.PRNGKey(0))
    with pytest.raises(KeyError):
        coll.prepare(state, col.FeatureBatch(ids={"nope": jnp.zeros(4, jnp.int32)}))


def test_shard_specs_structure_matches_state():
    tables = small_tables()
    plan = col.PlacementPlanner(80_000, group_below_rows=32).plan(tables)
    coll = col.EmbeddingCollection(tables, plan)
    state = coll.init(jax.random.PRNGKey(0))
    specs = coll.shard_specs("column")
    a = jax.tree_util.tree_structure(state)
    b = jax.tree_util.tree_structure(specs)
    assert a == b


# --------------------------------------------------------------------------
# acceptance: mixed plan trains via Trainer and serves via ServeEngine
# --------------------------------------------------------------------------


def test_mixed_plan_trains_and_serves_end_to_end():
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig
    from repro.serve.engine import ServeEngine
    from repro.train.trainer import Trainer, TrainerConfig

    budget = 90_000  # promotes the small tables, caches the 4096-row one
    cfg = DLRMConfig(vocab_sizes=(4096, 256, 64), embed_dim=8, batch_size=16,
                     cache_ratio=0.25, lr=0.1, bottom_mlp=(16, 8), top_mlp=(16,),
                     device_budget_bytes=budget)
    model = DLRM(cfg)
    placements = {p.placement for p in model.collection.plan.placements.values()}
    assert col.Placement.DEVICE in placements and col.Placement.CACHED in placements
    assert model.collection.device_bytes()["device_total"] <= budget

    spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)

    def make_batch(step):
        return {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, 16, 0, step).items()}

    trainer = Trainer(
        TrainerConfig(max_steps=5),
        init_fn=lambda: model.init(jax.random.PRNGKey(0)),
        step_fn=jax.jit(model.train_step),
        make_batch=make_batch,
        flush_fn=model.flush,
    )
    state = trainer.run()
    assert trainer.history and np.isfinite(trainer.history[-1]["loss"])

    # trained cached lookups still bit-match the dense reference
    fb = model.features(make_batch(99))
    emb_state, _, rows = model.collection.lookup(state["emb"], fb, writeback=False)
    ref = model.collection.dense_reference(model.collection.flush(emb_state), fb)
    for f in fb.features:
        np.testing.assert_array_equal(np.asarray(rows[f]), np.asarray(ref[f]))

    # ...and the same state serves through the engine
    pad = {"dense": np.zeros((13,), np.float32), "sparse": np.zeros((3,), np.int32),
           "label": np.zeros((), np.float32)}
    eng = ServeEngine(model.serve_step, state, batch_size=16, pad_example=pad)
    batch = synth.sparse_batch(spec, 7, 1, 0)
    scores = eng.score(batch)
    assert scores.shape == (7,) and np.isfinite(scores).all()
    assert eng.stats.summary()["requests"] == 7


def test_serve_stats_histogram_is_bounded_and_order_independent():
    """ServeStats latency telemetry is O(1) memory (fixed bucket counts, no
    sample list) and, unlike the reservoir it replaced, deterministic: the
    summary is a pure function of the latency POPULATION, not arrival order."""
    from repro.serve.engine import ServeStats

    lat = [1e-3 * (1 + (i % 7)) for i in range(10_000)]
    st = ServeStats()
    for v in lat:
        st.observe(v)
    assert st.batches == 10_000
    assert len(st.hist.counts) == len(st.hist.bounds) + 1  # fixed, not O(n)
    s = st.summary()
    assert s["p50_ms"] > 0 and s["p99_ms"] >= s["p50_ms"]
    assert s["p999_ms"] >= s["p95_ms"] >= s["p50_ms"]
    # quantile bounds never under-report: p99 covers the true 99th pct value
    assert s["p99_ms"] >= 1e3 * sorted(lat)[int(0.99 * len(lat)) - 1]

    st_rev = ServeStats()
    for v in reversed(lat):
        st_rev.observe(v)
    assert st_rev.summary() == s


def test_host_wire_bytes_exact_past_float32_resolution():
    """Satellite regression: cumulative wire traffic must accumulate as an
    exact Python int.  A float32 accumulator loses integer resolution past
    2^24, so at benchmark rates the old scalar drifted within ~25 steps; the
    per-slab (moved rows, row bytes) counters reconstruct the exact count."""
    import dataclasses

    tables = [col.TableConfig("t", vocab=64, dim=8, ids_per_step=8, cache_ratio=0.5,
                              placement=col.Placement.CACHED)]
    coll = col.EmbeddingCollection(tables, col.PlacementPlanner(10**9).plan(tables))
    state = coll.init(jax.random.PRNGKey(0))
    moved = 2**24 + 1  # row_bytes = 32 -> exact total 2^29 + 32, not a float32
    slab = state.slabs["t"]
    state = col.CollectionState(slabs={"t": dataclasses.replace(
        slab, cache=dataclasses.replace(
            slab.cache, misses=jnp.asarray(moved, jnp.int32)))})
    m = coll.metrics(state)
    expect = moved * 32
    assert col.exact_metric_bytes(m, "host_moved_rows", "host_row_bytes") == expect
    # ...and the in-jit float32 convenience scalar demonstrably drifts
    assert int(m["host_wire_bytes"]) != expect

    # the trainer records the exact int in its host-side history
    from repro.train.trainer import Trainer, TrainerConfig

    tr = Trainer(TrainerConfig(max_steps=1), init_fn=lambda: None,
                 step_fn=None, make_batch=None)
    metrics = dict(m, loss=jnp.asarray(0.0, jnp.float32))
    tr._post_step(0, state, metrics, t0=0.0)
    assert tr.history[-1]["host_wire_bytes"] == expect
    assert isinstance(tr.history[-1]["host_wire_bytes"], int)


def test_serve_summary_reports_exact_wire_bytes():
    """The serve engine's summary must survive (and exploit) the per-slab
    counter dicts in the metrics pytree."""
    from repro.serve.engine import ServeEngine

    tables = [col.TableConfig("t", vocab=64, dim=8, ids_per_step=8, cache_ratio=0.5)]
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.5)
    state = {"emb": coll.init(jax.random.PRNGKey(0))}
    eng = ServeEngine(lambda s, b: (jnp.zeros((1,)), None), state, batch_size=1,
                      pad_example={},
                      state_stats_fn=lambda s: coll.metrics(s["emb"], writeback=False))
    out = eng.summary()
    assert isinstance(out["host_wire_bytes"], int)
    # per-slab counter DICTS stay internal; the hub reconstructs each family
    # to a single exact int instead of leaking the pytree
    assert isinstance(out["host_moved_rows"], int)
    assert "slab_hits" not in out and "slab_misses" not in out


def test_single_arena_plan_is_paper_layout():
    """All-GROUPED = the paper's one concatenated freq-ordered table."""
    tables = small_tables()
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.1)
    assert not coll.device_slabs
    assert list(coll.cached_slabs) == [col.SHARED_ARENA]
    spec = coll.cached_slabs[col.SHARED_ARENA]
    assert spec.vocab == sum(t.vocab for t in tables)
