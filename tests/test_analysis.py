"""Static-analysis subsystem: every check class fires on a deliberately
broken fixture, and the real registry passes clean (modulo the checked-in
known-issue baseline).

The fixtures are the point: a checker that never fires is indistinguishable
from one that works, so each contract class gets a minimal function built to
violate exactly it.
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import ast_lint, hlo_checks, jaxpr_checks, run
from repro.analysis.contracts import Contract, Violation, contract, registry
from repro.analysis.smoke import SmokeCase


def _case(fn, args, name="fixture", advance=None, donate=()):
    return SmokeCase(name, fn, args, advance=advance, donate_argnums=donate)


def _contract(**kw):
    return Contract(name="fixture", **kw)


# --------------------------------------------------------------------------
# jaxpr checks fire on broken fixtures
# --------------------------------------------------------------------------


def test_host_transfer_check_fires_on_pure_callback():
    def bad(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct((), jnp.float32), x.sum()
        )

    vs = jaxpr_checks.check_host_transfer(
        _case(bad, (jnp.zeros((4,)),)), _contract()
    )
    assert vs and vs[0].check == "host-transfer"
    assert "pure_callback" in vs[0].detail


def test_host_transfer_check_ignores_literal_device_put():
    # jnp.unique(..., fill_value=<python int>) places a literal constant —
    # compile-time folded, must NOT count as a host transfer.
    def ok(x):
        return jnp.unique(x, size=4, fill_value=7)

    assert jaxpr_checks.check_host_transfer(
        _case(ok, (jnp.arange(16),)), _contract()
    ) == []


def test_f64_check_fires_on_double_cast():
    from jax.experimental import enable_x64

    def bad(x):
        return x.astype(jnp.float64).sum()

    with enable_x64():
        vs = jaxpr_checks.check_f64(_case(bad, (jnp.zeros((4,)),)), _contract())
    assert vs and vs[0].check == "f64"


def test_f64_check_clean_without_x64():
    def ok(x):
        return (x * 2.0).sum()

    assert jaxpr_checks.check_f64(_case(ok, (jnp.zeros((4,)),)), _contract()) == []


def test_int_counter_check_fires_on_float_counter():
    def bad(state):
        return {"hits": state["hits"].astype(jnp.float32) + 1}

    vs = jaxpr_checks.check_int_counters(
        _case(bad, ({"hits": jnp.zeros((), jnp.int32)},)),
        _contract(int_counters=(r"hits",)),
    )
    assert vs and vs[0].check == "int-counter"
    assert "float32" in vs[0].detail


def test_int_counter_check_passes_on_i32():
    def ok(state):
        return {"hits": state["hits"] + 1}

    assert jaxpr_checks.check_int_counters(
        _case(ok, ({"hits": jnp.zeros((), jnp.int32)},)),
        _contract(int_counters=(r"hits",)),
    ) == []


def test_sort_bound_check_fires_on_full_capacity_argsort():
    def bad(key):
        return jnp.argsort(key, descending=True)

    vs = jaxpr_checks.check_sort_bound(
        _case(bad, (jnp.zeros((4096,)),)), _contract(max_sort_size=64)
    )
    assert vs and vs[0].check == "sort-bound"
    assert "4096" in vs[0].detail


def test_sort_bound_zero_forbids_any_sort():
    vs = jaxpr_checks.check_sort_bound(
        _case(lambda x: jnp.sort(x), (jnp.zeros((8,)),)),
        _contract(max_sort_size=0),
    )
    assert vs and vs[0].check == "sort-bound"


def test_signature_stability_catches_injected_dtype_retrace():
    # step t+1 args drift i32 -> f32: jit would recompile every step.
    def advance(x):
        return (x * 1.0,)

    vs = jaxpr_checks.check_signature_stability(
        _case(lambda x: x, (jnp.zeros((4,), jnp.int32),), advance=advance),
        _contract(),
    )
    assert vs and vs[0].check == "retrace"
    assert "int32" in vs[0].detail and "float32" in vs[0].detail


def test_signature_stability_catches_weak_type_drift():
    # a fresh python-scalar-derived value is WEAKLY typed: same shape+dtype,
    # still a retrace.  This is the classic `state["step"] = 0` bug.
    def advance(x):
        return (jnp.add(1.0, 0.0),)

    vs = jaxpr_checks.check_signature_stability(
        _case(lambda x: x, (jnp.zeros(()),), advance=advance), _contract()
    )
    assert vs and vs[0].check == "retrace"
    assert "weak_type" in vs[0].detail


def test_signature_stability_passes_on_fixed_point_advance():
    assert jaxpr_checks.check_signature_stability(
        _case(lambda x: x + 1, (jnp.zeros((4,), jnp.int32),),
              advance=lambda x: (x + 1,)),
        _contract(),
    ) == []


# --------------------------------------------------------------------------
# HLO checks
# --------------------------------------------------------------------------


def test_donation_check_fires_when_alias_impossible():
    # dtype change makes the donated buffer un-aliasable: double-buffered.
    def bad(state):
        return state["w"].astype(jnp.bfloat16)

    case = _case(bad, ({"w": jnp.zeros((256, 64))},), donate=(0,))
    hlo = hlo_checks.compiled_text(case, donate=True)
    vs = hlo_checks.check_donation(case, _contract(donates=("state",)), hlo)
    assert vs and vs[0].check == "donation"


def test_donation_check_passes_when_elided():
    def ok(state):
        return {"w": state["w"] + 1.0}

    case = _case(ok, ({"w": jnp.zeros((256, 64))},), donate=(0,))
    hlo = hlo_checks.compiled_text(case, donate=True)
    assert hlo_checks.parse_input_output_alias(hlo)
    assert hlo_checks.check_donation(case, _contract(donates=("state",)), hlo) == []


def test_hlo_f64_check_fires():
    from jax.experimental import enable_x64

    def bad(x):
        return x.astype(jnp.float64) * 2.0

    with enable_x64():
        case = _case(bad, (jnp.zeros((32,)),))
        hlo = hlo_checks.compiled_text(case)
        vs = hlo_checks.check_f64_hlo(case, _contract(), hlo)
    assert vs and vs[0].check == "f64"


def test_hlo_host_call_check_fires_on_callback():
    def bad(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct((), jnp.float32), x.sum()
        )

    case = _case(bad, (jnp.zeros((4,)),))
    hlo = hlo_checks.compiled_text(case)
    vs = hlo_checks.check_host_calls(case, _contract(), hlo)
    assert vs and vs[0].check == "host-transfer"


# --------------------------------------------------------------------------
# AST lint
# --------------------------------------------------------------------------


def _lint(src):
    return ast_lint.lint_source(src, path="fixture.py", module="fixture")


def test_ast_lint_flags_item_and_float_in_jit_body():
    vs = _lint(
        "import jax\n"
        "@jax.jit\n"
        "def step(state, batch):\n"
        "    n = state.sum().item()\n"
        "    f = float(batch)\n"
        "    return n + f\n"
    )
    assert [v.check for v in vs] == ["ast-host-sync", "ast-host-sync"]
    assert "item" in vs[0].detail and "float" in vs[1].detail


def test_ast_lint_flags_np_asarray_on_traced_value():
    vs = _lint(
        "import jax, numpy as np\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    return np.asarray(x)\n"
    )
    assert vs and vs[0].check == "ast-host-sync"


def test_ast_lint_flags_tracer_branch():
    vs = _lint(
        "import jax\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    if x > 0:\n"
        "        return x\n"
        "    while x < 3:\n"
        "        x = x + 1\n"
        "    return x\n"
    )
    assert [v.check for v in vs] == ["ast-tracer-branch", "ast-tracer-branch"]


def test_ast_lint_static_branches_are_clean():
    vs = _lint(
        "import jax\n"
        "@jax.jit\n"
        "def step(cfg, x, n: int, rows=None):\n"
        "    if cfg.writeback:\n"
        "        x = x * 2\n"
        "    if rows is None:\n"
        "        x = x + 1\n"
        "    if isinstance(x, tuple):\n"
        "        x = x[0]\n"
        "    if n > 4:\n"
        "        x = x - 1\n"
        "    return x\n"
    )
    assert vs == []


def test_ast_lint_flags_unregistered_array_dataclass():
    vs = _lint(
        "import dataclasses\n"
        "import jax.numpy as jnp\n"
        "@dataclasses.dataclass\n"
        "class State:\n"
        "    weight: jnp.ndarray\n"
        "    step: int\n"
    )
    assert vs and vs[0].check == "ast-unregistered-dataclass"
    assert "weight" in vs[0].detail


def test_ast_lint_registered_dataclass_is_clean():
    assert _lint(
        "import dataclasses, jax\n"
        "@jax.tree_util.register_dataclass\n"
        "@dataclasses.dataclass\n"
        "class State:\n"
        "    weight: jax.Array\n"
    ) == []
    # Callable fields returning arrays are functions, not array leaves
    assert _lint(
        "import dataclasses\n"
        "from typing import Callable\n"
        "import jax.numpy as jnp\n"
        "@dataclasses.dataclass\n"
        "class Step:\n"
        "    fwd: Callable[..., jnp.ndarray]\n"
    ) == []


def test_ast_lint_flags_inplace_state_mutation():
    vs = _lint(
        "import jax\n"
        "@jax.jit\n"
        "def step(state):\n"
        "    state['k'] = 0\n"
        "    state.hits += 1\n"
        "    local = dict(state)\n"
        "    local['k'] = 1\n"
        "    return state\n"
    )
    assert [v.check for v in vs] == ["ast-state-mutation", "ast-state-mutation"]


def test_ast_lint_extra_jit_covers_registry_methods():
    # undecorated method linted as a jit body because the registry names it
    vs = ast_lint.lint_source(
        "class Coll:\n"
        "    def gather(self, w):\n"
        "        return w.sum().item()\n",
        path="x.py",
        module="repro.fake",
        extra_jit={"repro.fake.Coll.gather"},
    )
    assert vs and vs[0].check == "ast-host-sync"


def test_ast_lint_suppression_comment():
    assert _lint(
        "import jax\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    return x.sum().item()  # jaxlint: ok\n"
    ) == []


def test_ast_lint_obs_host_sync_outside_allowed_points():
    # in the metric-collection modules, a stray device_get outside the
    # documented once-per-step sync points is flagged...
    src = (
        "import jax\n"
        "def record_metrics(self, metrics):\n"
        "    return float(jax.device_get(metrics['loss']))\n"
    )
    vs = ast_lint.lint_source(src, path="t.py", module="repro.train.trainer")
    assert [v.check for v in vs] == ["ast-obs-host-sync"]
    # ...but the same source outside those modules is host code, not linted
    assert ast_lint.lint_source(src, path="t.py", module="repro.data.synth") == []


def test_ast_lint_obs_host_sync_allows_documented_points():
    assert ast_lint.lint_source(
        "import jax\n"
        "class Trainer:\n"
        "    def _post_step(self, metrics):\n"
        "        loss = float(jax.device_get(metrics['loss']))\n"
        "        n = metrics['overflow'].item()\n"
        "        return loss + n\n",
        path="t.py",
        module="repro.train.trainer",
    ) == []
    assert ast_lint.lint_source(
        "import jax\n"
        "def observe(self, cumulative):\n"
        "    return int(jax.device_get(cumulative))\n",
        path="h.py",
        module="repro.obs.hub",
    ) == []


# --------------------------------------------------------------------------
# registry + runner integration
# --------------------------------------------------------------------------


def test_contract_decorator_registers_without_wrapping():
    from repro.analysis import contracts as contracts_mod

    try:
        @contract(max_sort_size=7, name="tests.fixture_entry")
        def entry(x):
            return x

        reg = registry()
        fn, c = reg["tests.fixture_entry"]
        assert fn is entry  # not wrapped
        assert c.max_sort_size == 7
        assert entry.__contract__ is c
    finally:
        # keep the global registry clean: analyze() treats a registered entry
        # without a smoke case as a 'no-smoke' violation.
        contracts_mod._REGISTRY.pop("tests.fixture_entry", None)


def test_full_gate_passes_clean_modulo_baseline():
    root = Path(__file__).resolve().parents[1]
    report = run.apply_baseline(
        run.analyze(root, passes=("jaxpr", "ast")),
        run.load_baseline(run._DEFAULT_BASELINE),
    )
    assert report["new"] == [], f"new violations on main: {report['new']}"
    # the ROADMAP-item-3 argsorts are FIXED (bounded top-K + fused prepare):
    # the baseline is empty and must stay empty — a new unbounded sort on a
    # registered entry point is a hard failure, not a baseline candidate.
    assert report["baselined"] == []
    assert report["stale_baseline"] == []
    assert len(report["entries"]) >= 24


def test_baseline_marks_stale_entries():
    report = {
        "entries": [], "passes": [], "ast_files": 0,
        "violations": [Violation("sort-bound", "a.b", "x")],
    }
    out = run.apply_baseline(
        report,
        [
            {"check": "sort-bound", "entry": "a.b", "rationale": "known"},
            {"check": "f64", "entry": "gone.entry", "rationale": "fixed"},
        ],
    )
    assert out["ok"] and len(out["baselined"]) == 1
    assert out["stale_baseline"] == [
        {"check": "f64", "entry": "gone.entry", "rationale": "fixed"}
    ]


def test_cli_json_and_exit_codes(tmp_path, capsys):
    # a NEW violation -> exit 1.  The real tree is clean since PR 10 emptied
    # the baseline, so point the AST pass at a synthetic root with a host
    # sync inside a jit body (the jaxpr pass still traces the real registry).
    bad_root = tmp_path / "badrepo"
    (bad_root / "src").mkdir(parents=True)
    (bad_root / "src" / "bad.py").write_text(
        "import jax\n\n@jax.jit\ndef bad(x):\n    return x.item()\n"
    )
    root = str(Path(__file__).resolve().parents[1])
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"known_issues": []}))
    rc = run.main(["--json", "--skip-hlo", "--baseline", str(empty),
                   "--root", str(bad_root)])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert "ast-host-sync" in {v["check"] for v in out["new"]}

    # the checked-in baseline -> clean -> exit 0 even under --strict
    rc = run.main(["--json", "--skip-hlo", "--strict", "--root", root])
    report = json.loads(capsys.readouterr().out)
    assert rc == 0 and report["ok"]

    # stale entry + --strict -> exit 2
    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps({
        "known_issues": json.loads(run._DEFAULT_BASELINE.read_text())["known_issues"]
        + [{"check": "f64", "entry": "no.such.entry", "rationale": "fixed"}]
    }))
    rc = run.main(["--json", "--skip-hlo", "--strict",
                   "--baseline", str(stale), "--root", root])
    capsys.readouterr()
    assert rc == 2
