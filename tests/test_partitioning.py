"""Logical-axis partitioning helpers."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro.dist.partitioning as dist


def test_constrain_noop_without_scope():
    x = jnp.ones((4, 4))
    y = dist.constrain(x, "batch", None)
    assert (y == x).all()


def test_spec_resolution():
    with dist.axis_rules(None, {"batch": ("pod", "data"), "mlp": "model"}):
        assert dist.spec("batch", None, "mlp") == P(("pod", "data"), None, "model")
        assert dist.spec("unknown") == P(None)


def test_param_split_and_specs():
    tree = {
        "dense": {"w": dist.Param(jnp.ones((4, 8)), ("embed", "mlp"))},
        "scale": dist.Param(jnp.ones((8,)), (None,)),
    }
    values, axes = dist.split_params(tree)
    assert values["dense"]["w"].shape == (4, 8)
    with dist.axis_rules(None, {"embed": "data", "mlp": "model"}):
        specs = dist.specs_for_axes(axes)
    assert specs["dense"]["w"] == P("data", "model")
    assert specs["scale"] == P(None)


def test_param_is_pytree_and_stackable():
    def init(key):
        return {"w": dist.Param(jax.random.normal(key, (3,)), ("mlp",))}

    stacked = jax.vmap(init)(jax.random.split(jax.random.PRNGKey(0), 4))
    stacked = dist.prepend_axis(stacked, "layer_groups")
    values, axes = dist.split_params(stacked)
    assert values["w"].shape == (4, 3)
    assert axes["w"] == ("layer_groups", "mlp")


def test_eval_shape_preserves_axes():
    def init():
        return {"w": dist.Param(jnp.zeros((2, 3)), ("a", "b"))}

    shaped = jax.eval_shape(init)
    values, axes = dist.split_params(shaped)
    assert values["w"].shape == (2, 3)
    assert axes["w"] == ("a", "b")
