"""Quantized tiered HostStore: codec bounds, fp32 bit-exactness, evict/reload
stability, encoded checkpoints, precision policy, and int8 end-to-end parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cache as cache_lib
from repro.core import cached_embedding as ce
from repro.core import collection as col
from repro.core import freq
from repro.store import HostStore, PrecisionPolicy, SlabGeometry, get_codec
from repro.train import checkpoint as C


# --------------------------------------------------------------------------
# codec round trips
# --------------------------------------------------------------------------


def _rows(n=32, d=16, seed=0, scale=1.0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32) * scale
    )


def test_fp32_codec_is_bit_exact():
    x = _rows()
    c = get_codec("fp32")
    p, s = c.encode(x)
    assert s is None
    np.testing.assert_array_equal(np.asarray(c.decode(p, s, jnp.float32)), np.asarray(x))


def test_fp16_codec_error_bound():
    x = _rows(scale=3.0)
    c = get_codec("fp16")
    p, s = c.encode(x)
    assert p.dtype == jnp.float16 and s is None
    y = c.decode(p, s, jnp.float32)
    # half precision: 11-bit significand -> relative error <= 2^-11
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=2 ** -11, atol=1e-7)


def test_int8_codec_error_bound():
    x = _rows(scale=2.0)
    c = get_codec("int8")
    p, s = c.encode(x)
    assert p.dtype == jnp.int8 and s.shape == (x.shape[0], 2)
    y = np.asarray(c.decode(p, s, jnp.float32))
    # affine row-wise: error <= half a quantization step per row
    step = (np.asarray(x).max(1) - np.asarray(x).min(1)) / 254.0
    assert (np.abs(y - np.asarray(x)) <= step[:, None] * 0.5 + 1e-6).all()


def test_int8_constant_row_and_projection_stability():
    c = get_codec("int8")
    const = jnp.full((3, 5), 0.25)
    p, s = c.encode(const)
    np.testing.assert_allclose(np.asarray(c.decode(p, s, jnp.float32)), 0.25, atol=1e-6)
    # decode -> encode is a stable projection: payload identical from cycle 1
    x = _rows(seed=3)
    p1, s1 = c.encode(x)
    y1 = c.decode(p1, s1, jnp.float32)
    p2, s2 = c.encode(y1)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    y2 = c.decode(p2, s2, jnp.float32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)


def test_host_store_accounting():
    full = {"weight": _rows(64, 16), "accum": jnp.zeros((64,), jnp.float32)}
    st8 = HostStore.create(full, "int8")
    st32 = HostStore.create(full, "fp32")
    # int8 row: 16 payload bytes + 8 sideband + 4 raw accum vs fp32 16*4 + 4
    assert st8.row_wire_bytes() == 16 + 8 + 4
    assert st32.row_wire_bytes() == 64 + 4
    assert st8.bytes_saved() == st32.host_bytes() - st8.host_bytes() > 0
    # accum (per-row scalar) is stored raw under every codec
    assert st8.data["accum"].dtype == jnp.float32


# --------------------------------------------------------------------------
# fp32 store is bit-identical to the raw-pytree path through prepare/flush
# --------------------------------------------------------------------------


def test_fp32_store_bit_identical_to_raw_tree():
    cfg = cache_lib.CacheConfig(vocab=60, capacity=12, ids_per_step=8, buffer_rows=5)
    w = _rows(60, 8, seed=1)
    raw = {"weight": w}
    store = HostStore.create({"weight": w}, "fp32")
    st_a = cache_lib.init_cache(cfg, {"weight": jnp.zeros((8,), jnp.float32)})
    st_b = jax.tree_util.tree_map(lambda x: x, st_a)
    rng = np.random.default_rng(0)
    for _ in range(12):
        ids = jnp.asarray(rng.integers(0, 60, 8).astype(np.int32))
        raw, st_a, slots_a = cache_lib.prepare(cfg, raw, st_a, ids)
        store, st_b, slots_b = cache_lib.prepare(cfg, store, st_b, ids)
        np.testing.assert_array_equal(np.asarray(slots_a), np.asarray(slots_b))
        np.testing.assert_array_equal(
            np.asarray(st_a.cached_rows["weight"]), np.asarray(st_b.cached_rows["weight"])
        )
        g = jnp.asarray(rng.normal(size=(12, 8)).astype(np.float32))
        st_a = dataclasses.replace(st_a, cached_rows={"weight": st_a.cached_rows["weight"] + g})
        st_b = dataclasses.replace(st_b, cached_rows={"weight": st_b.cached_rows["weight"] + g})
    raw, st_a = cache_lib.flush(cfg, raw, st_a)
    store, st_b = cache_lib.flush(cfg, store, st_b)
    np.testing.assert_array_equal(np.asarray(raw["weight"]), np.asarray(store["weight"]))


# --------------------------------------------------------------------------
# quantize-on-evict -> dequantize-on-reload: untouched rows are stable
# --------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["fp16", "int8"])
def test_evict_reload_idempotent_for_untouched_rows(codec):
    cfg = ce.CachedEmbeddingConfig(
        vocab_sizes=(64,), dim=8, ids_per_step=8, cache_ratio=0.01,  # capacity = 8
        buffer_rows=4, host_precision=codec,
    )
    st = ce.init_state(jax.random.PRNGKey(0), cfg, warm=False)
    ids_a = jnp.arange(8, dtype=jnp.int32)
    ids_b = jnp.arange(8, 16, dtype=jnp.int32)

    st, slots = ce.prepare_ids(cfg, st, ids_a)  # load (dequantize) A
    v1 = np.asarray(ce.gather_slots(st, slots))
    payload_after = []
    vals = []
    for _ in range(3):  # evict A (quantize) / reload A (dequantize), 3 cycles
        st, _ = ce.prepare_ids(cfg, st, ids_b)
        payload_after.append(np.asarray(st.full.data["weight"][:8]).copy())
        st, slots = ce.prepare_ids(cfg, st, ids_a)
        vals.append(np.asarray(ce.gather_slots(st, slots)))
    # payload is bit-stable from the first writeback on
    np.testing.assert_array_equal(payload_after[0], payload_after[1])
    np.testing.assert_array_equal(payload_after[1], payload_after[2])
    # values drift at most by sideband recompute noise (float ulps), not by
    # a quantization step per cycle
    np.testing.assert_allclose(vals[0], vals[1], atol=1e-6)
    np.testing.assert_allclose(vals[1], vals[2], atol=1e-6)
    np.testing.assert_allclose(v1, vals[0], atol=1e-5)


def test_fp32_evict_reload_bit_exact():
    cfg = ce.CachedEmbeddingConfig(
        vocab_sizes=(64,), dim=8, ids_per_step=8, cache_ratio=0.01, buffer_rows=4,
    )
    st = ce.init_state(jax.random.PRNGKey(0), cfg, warm=False)
    ids_a = jnp.arange(8, dtype=jnp.int32)
    st, slots = ce.prepare_ids(cfg, st, ids_a)
    v1 = np.asarray(ce.gather_slots(st, slots))
    st, _ = ce.prepare_ids(cfg, st, jnp.arange(8, 16, dtype=jnp.int32))
    st, slots = ce.prepare_ids(cfg, st, ids_a)
    np.testing.assert_array_equal(v1, np.asarray(ce.gather_slots(st, slots)))


# --------------------------------------------------------------------------
# quantized lookups stay codec-roundtrip-exact vs the dense oracle
# --------------------------------------------------------------------------


@pytest.mark.parametrize("codec", ["fp16", "int8"])
def test_quantized_store_matches_oracle_after_updates(codec):
    cfg = ce.CachedEmbeddingConfig(
        vocab_sizes=(50, 30), dim=8, ids_per_step=12, cache_ratio=0.2,
        buffer_rows=5, host_precision=codec,
    )
    st = ce.init_state(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    for _ in range(6):
        ids = jnp.asarray(rng.integers(0, (50, 30), size=(6, 2)).astype(np.int32))
        st, slots, emb = ce.embed_onehot(cfg, st, ids)
        st = ce.apply_row_grads(cfg, st, jnp.ones_like(st.cache.cached_rows["weight"]), lr=0.01)
    flushed = ce.flush_state(cfg, st)
    ref = ce.dense_reference_lookup(flushed, ids)
    _, _, emb2 = ce.embed_onehot(cfg, flushed, ids)
    # resident reads and oracle reads agree to within one quantization step
    atol = 0.01 if codec == "int8" else 1e-3
    np.testing.assert_allclose(np.asarray(emb2), np.asarray(ref), atol=atol)


# --------------------------------------------------------------------------
# checkpoints persist the ENCODED store; restore validates codec metadata
# --------------------------------------------------------------------------


def test_checkpoint_roundtrips_encoded_store(tmp_path):
    cfg = ce.CachedEmbeddingConfig(
        vocab_sizes=(64,), dim=8, ids_per_step=8, cache_ratio=0.25,
        host_precision="int8",
    )
    st = ce.init_state(jax.random.PRNGKey(0), cfg)
    st, _ = ce.prepare_ids(cfg, st, jnp.arange(8, dtype=jnp.int32))
    st = ce.flush_state(cfg, st)
    C.save(tmp_path, 3, st)
    # the on-disk leaves are the ENCODED payload + sideband, not fp32
    like = jax.tree_util.tree_map(lambda x: np.asarray(x), st)
    restored, step = C.restore(tmp_path, like)
    assert step == 3
    assert restored.full.data["weight"].dtype == np.int8
    np.testing.assert_array_equal(
        np.asarray(st.full.data["weight"]), restored.full.data["weight"]
    )
    np.testing.assert_array_equal(
        np.asarray(st.full.sideband["weight"]), restored.full.sideband["weight"]
    )


def test_checkpoint_codec_mismatch_raises(tmp_path):
    kw = dict(vocab_sizes=(64,), dim=8, ids_per_step=8, cache_ratio=0.25)
    cfg8 = ce.CachedEmbeddingConfig(**kw, host_precision="int8")
    st8 = ce.init_state(jax.random.PRNGKey(0), cfg8)
    C.save(tmp_path, 1, st8)
    cfg16 = ce.CachedEmbeddingConfig(**kw, host_precision="fp16")
    like = jax.tree_util.tree_map(
        lambda x: np.asarray(x), ce.init_state(jax.random.PRNGKey(0), cfg16)
    )
    with pytest.raises(ValueError, match="host"):
        C.restore(tmp_path, like)


def test_checkpoint_shape_mismatch_raises(tmp_path):
    C.save(tmp_path, 1, {"x": jnp.zeros((4,), jnp.float32)})
    with pytest.raises(ValueError, match="mismatch"):
        C.restore(tmp_path, {"x": np.zeros((5,), np.float32)})


# --------------------------------------------------------------------------
# precision policy + deterministic sampled counts
# --------------------------------------------------------------------------


def test_precision_policy_coverage_thresholds():
    pol = PrecisionPolicy()
    g = SlabGeometry(name="t", vocab=1000, dim=16, capacity=100)
    hot = np.zeros(1000); hot[:100] = 1000.0; hot[100:] = 0.1  # cache covers ~all
    cold = np.ones(1000)  # capacity covers 10 % of accesses
    assert pol.choose(g, hot) == "int8"
    assert pol.choose(g, cold) == "fp32"
    assert pol.choose(g, None) == pol.no_stats == "fp16"


def test_precision_policy_budget_demotes_coldest_first():
    pol = PrecisionPolicy()
    hot = SlabGeometry(name="hot", vocab=1000, dim=16, capacity=500)
    cold = SlabGeometry(name="cold", vocab=1000, dim=16, capacity=10)
    skew = np.zeros(1000); skew[:500] = 100.0; skew[500:] = 1.0
    uniform = np.ones(1000)
    counts = {"hot": skew, "cold": uniform}
    free = pol.assign([hot, cold], counts)
    assert free["cold"] == "fp32"  # low coverage -> full precision...
    tight = pol.assign([hot, cold], counts, host_budget_bytes=2 * 1000 * 24)
    assert tight["cold"] != "fp32"  # ...until the host budget forces demotion
    with pytest.raises(ValueError, match="int8"):
        pol.assign([hot, cold], counts, host_budget_bytes=100)


def test_precision_policy_budget_demotes_best_covered_first():
    """Under pressure the slab whose host tier is read LEAST (highest cache
    coverage) quantizes first — the one the codec noise can hurt least."""
    pol = PrecisionPolicy()
    a = SlabGeometry(name="a", vocab=1000, dim=16, capacity=100)
    b = SlabGeometry(name="b", vocab=1000, dim=16, capacity=100)
    cov45 = np.r_[np.full(100, 0.45), np.full(900, 55.0 / 900)]  # top-100: 45 %
    cov70 = np.r_[np.full(100, 0.70), np.full(900, 30.0 / 900)]  # top-100: 70 %
    counts = {"a": cov45, "b": cov70}
    free = pol.assign([a, b], counts)
    assert free == {"a": "fp16", "b": "fp16"}  # both in the fp16 band
    # budget with room for one fp16 + one int8: the better-covered slab (b)
    # must take the int8 demotion, the hotter host tier (a) keeps fp16
    tight = pol.assign([a, b], counts, host_budget_bytes=1000 * 32 + 1000 * 24)
    assert tight == {"a": "fp16", "b": "int8"}


def test_metrics_writeback_false_counts_loads_only():
    tables = [col.TableConfig("t", vocab=64, dim=8, ids_per_step=8, cache_ratio=0.1)]
    coll = col.EmbeddingCollection.create(tables)
    state = coll.init(jax.random.PRNGKey(0), warm=False)
    # two disjoint batches through a capacity-8 cache: loads + evictions
    for lo in (0, 8, 16):
        fb = col.FeatureBatch(ids={"t": jnp.arange(lo, lo + 8, dtype=jnp.int32)})
        state, _ = coll.prepare(state, fb, writeback=False)
    m_rw = coll.metrics(state)
    m_ro = coll.metrics(state, writeback=False)
    misses = float(m_ro["cache_misses"])
    evs = float(m_ro["cache_evictions"])
    assert evs > 0
    assert float(m_ro["host_wire_bytes"]) == misses * 8 * 4
    assert float(m_rw["host_wire_bytes"]) == (misses + evs) * 8 * 4


def test_host_store_rejects_mixed_encoded_dtypes():
    full = {"w32": _rows(8, 4), "w16": _rows(8, 4).astype(jnp.float16)}
    with pytest.raises(ValueError, match="one decode dtype"):
        HostStore.create(full, "int8")


def test_auto_precision_resolves_at_init_and_specs_match():
    tables = [col.TableConfig("t", vocab=512, dim=8, ids_per_step=16, cache_ratio=0.25)]
    coll = col.EmbeddingCollection.create(tables, host_precision="auto")
    z = np.random.default_rng(0).zipf(1.6, 100_000) % 512
    state = coll.init(jax.random.PRNGKey(0), counts={"t": np.bincount(z, minlength=512)})
    resolved = coll.host_precision[col.SHARED_ARENA]
    assert resolved in ("fp16", "int8")
    assert state.slabs[col.SHARED_ARENA].full.codec == resolved
    specs = coll.shard_specs("column")
    assert jax.tree_util.tree_structure(state) == jax.tree_util.tree_structure(specs)


def test_collect_counts_sampled_deterministic_with_rng():
    batches = [np.random.default_rng(i).integers(0, 50, 64) for i in range(20)]
    a = freq.collect_counts_sampled(batches, 50, 0.5, rng=np.random.default_rng(7))
    b = freq.collect_counts_sampled(batches, 50, 0.5, rng=np.random.default_rng(7))
    c = freq.collect_counts_sampled(batches, 50, 0.5, seed=7)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)


# --------------------------------------------------------------------------
# end-to-end: tiny DLRM trains to loss parity with an int8 host store
# --------------------------------------------------------------------------


def _train_losses(host_precision, steps=25):
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig

    cfg = DLRMConfig(vocab_sizes=(256, 128, 64), embed_dim=8, batch_size=16,
                     cache_ratio=0.15, lr=0.1, bottom_mlp=(16, 8), top_mlp=(16,),
                     host_precision=host_precision)
    model = DLRM(cfg)
    spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)
    state = model.init(jax.random.PRNGKey(0))
    step = jax.jit(model.train_step)
    losses = []
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, 16, 0, s).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return losses, state, model


def test_int8_dlrm_trains_to_loss_parity():
    ref, _, _ = _train_losses("fp32")
    got, state, model = _train_losses("int8")
    # both learn, and the int8 curve tracks fp32 within tolerance
    assert np.mean(got[-5:]) < np.mean(got[:5])
    assert abs(np.mean(got[-5:]) - np.mean(ref[-5:])) < 0.05
    # the quantized host tier really is int8 under the trained state
    slab = state["emb"].slabs[col.SHARED_ARENA]
    assert slab.full.codec == "int8" and slab.full.data["weight"].dtype == jnp.int8
    # wire accounting: int8 rows are cheaper than fp32 rows
    assert slab.full.row_wire_bytes() < 8 * 4


def test_fp32_dlrm_loss_identical_to_pre_store_path():
    """The fp32 codec must not perturb training at all: two independent runs
    (fresh model objects) produce bit-identical losses."""
    a, _, _ = _train_losses("fp32", steps=8)
    b, _, _ = _train_losses("fp32", steps=8)
    assert a == b
