"""Adaptive frequency engine: online decayed counters, incremental
re-ranking refresh purity (the pure-reindexing property), sharded parity,
trainer/serve wiring, drift recovery, and the wrap-free exact counters."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collection as col
from repro.core import freq as freq_lib
from repro.core.refresh import RefreshConfig, plan_swaps
from repro.core.sharded import ShardedEmbeddingCollection, flat_store


def _fb(tables, n, seed):
    rng = np.random.default_rng(seed)
    return col.FeatureBatch(ids={
        t.name: jnp.asarray(rng.integers(-1, t.vocab, n).astype(np.int32))
        for t in tables
    })


def _tables(dim=8, ids=16):
    return [
        col.TableConfig("big", vocab=512, dim=dim, ids_per_step=ids, cache_ratio=0.1),
        col.TableConfig("small", vocab=96, dim=dim, ids_per_step=ids, cache_ratio=0.3),
    ]


def _counts(tables, seed=1):
    rng = np.random.default_rng(seed)
    return {t.name: rng.integers(0, 50, t.vocab) for t in tables}


def _warm_state(coll, tables, steps=12, seed0=100):
    state = coll.init(jax.random.PRNGKey(0), counts=_counts(tables))
    step = jax.jit(lambda s, f: coll.lookup(s, f))
    for i in range(steps):
        state, _, _ = step(state, _fb(tables, 16, seed0 + i))
    return state


# --------------------------------------------------------------------------
# online tracker
# --------------------------------------------------------------------------


def test_tracker_matches_numpy_decay_oracle():
    """In-jit decayed counters == a numpy simulation of per-step decay."""
    tables = [col.TableConfig("t", vocab=32, dim=4, ids_per_step=6, cache_ratio=0.5)]
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.5)
    state = coll.init(jax.random.PRNGKey(0))
    half_life = 1024  # CacheConfig default
    d = 2.0 ** (-1.0 / half_life)
    oracle = np.zeros((32,))
    prep = jax.jit(lambda s, f: coll.prepare(s, f))
    rng = np.random.default_rng(0)
    for _ in range(10):
        ids = rng.integers(-1, 32, 6).astype(np.int32)
        state, _ = prep(state, col.FeatureBatch(ids={"t": jnp.asarray(ids)}))
        oracle *= d  # whole-vocab decay, one step
        # idx_map is identity (no counts): rank == raw id
        for r in np.unique(ids[ids >= 0]):
            oracle[r] += 1.0
    slab = state.slabs[col.SHARED_ARENA]
    tr = slab.cache.tracker
    got = freq_lib.decayed_scores(
        np.asarray(tr.score), np.asarray(tr.last_touch),
        int(slab.cache.step), half_life,
    )
    np.testing.assert_allclose(got, oracle, rtol=1e-5, atol=1e-7)
    # rolling window: hits+misses observed, rate in [0, 1]
    m = coll.metrics(state)
    assert 0.0 <= float(m["window_hit_rate"]) <= 1.0
    assert float(tr.win_hits + tr.win_misses) > 0


def test_plan_swaps_bounded_deterministic_and_boundary_only():
    scores = np.asarray([5.0, 1.0, 0.5, 9.0, 0.2, 7.0], np.float64)
    hot = np.asarray([True, True, True, False, False, False])
    a, b = plan_swaps(scores, hot, max_swaps=8)
    # pairs: coldest-hot vs hottest-cold while cold > hot: (2, 3), (1, 5)
    np.testing.assert_array_equal(a, [2, 1])
    np.testing.assert_array_equal(b, [3, 5])
    # bounded
    a1, b1 = plan_swaps(scores, hot, max_swaps=1)
    np.testing.assert_array_equal(a1, [2])
    np.testing.assert_array_equal(b1, [3])
    # ties never swap (strict comparison), identical inputs -> identical plan
    tied = np.ones((6,), np.float64)
    a2, b2 = plan_swaps(tied, hot, max_swaps=8)
    assert a2.size == 0 and b2.size == 0
    a3, b3 = plan_swaps(scores, hot, max_swaps=8)
    np.testing.assert_array_equal(a, a3)
    np.testing.assert_array_equal(b, b3)
    # min_gain hysteresis: 9-0.5=8.5 and 7-1=6 both clear 5.0; only the
    # first clears 7.0 (and the kept set stays a prefix)
    a4, _ = plan_swaps(scores, hot, max_swaps=8, min_gain=5.0)
    assert a4.tolist() == [2, 1]
    a5, _ = plan_swaps(scores, hot, max_swaps=8, min_gain=7.0)
    assert a5.tolist() == [2]


# --------------------------------------------------------------------------
# refresh purity: pure reindexing (THE acceptance property)
# --------------------------------------------------------------------------


def test_refresh_is_pure_reindexing_bitwise_fp32():
    """dense_reference / full_lookup / cached lookup are bitwise identical
    immediately before vs after a refresh (fp32), including with DIRTY
    resident rows (trained state): the dirty copy is written back before its
    rank moves."""
    tables = _tables()
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.1)
    state = _warm_state(coll, tables)
    # dirty the resident rows (synchronous row update)
    fb = _fb(tables, 16, 777)
    state, addr = coll.prepare(state, fb)
    grads = {k: jnp.ones_like(v) for k, v in coll.weights(state).items()}
    state = coll.apply_grads(state, grads, 0.1)

    probe = _fb(tables, 16, 999)
    ref_before = coll.dense_reference(coll.flush(state), probe)
    ids = jnp.arange(64, dtype=jnp.int32)
    fl_before = coll.full_lookup(coll.flush(state), "big", ids)
    state2, rep = coll.refresh(state, RefreshConfig(max_swaps=32))
    assert rep.total_swaps > 0  # the pass actually did something
    ref_after = coll.dense_reference(coll.flush(state2), probe)
    fl_after = coll.full_lookup(coll.flush(state2), "big", ids)
    for k in ref_before:
        np.testing.assert_array_equal(np.asarray(ref_before[k]), np.asarray(ref_after[k]))
    np.testing.assert_array_equal(np.asarray(fl_before), np.asarray(fl_after))
    # through-cache lookups read the identical values too
    s_a, _, rows_a = coll.lookup(state, probe)
    s_b, _, rows_b = coll.lookup(state2, probe)
    for k in rows_a:
        np.testing.assert_array_equal(np.asarray(rows_a[k]), np.asarray(rows_b[k]))
    # telemetry counters landed in metrics()
    m = coll.metrics(state2)
    assert int(m["refresh_swaps"]) == rep.total_swaps
    assert int(m["refresh_rows_moved"]) == rep.total_rows_moved


def test_refresh_index_maps_stay_consistent():
    """idx_map stays a permutation; row_to_slot/slot_to_row stay mutual
    inverses after surgery (invalidated rows excluded)."""
    tables = _tables()
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.1)
    state = _warm_state(coll, tables)
    state, _ = coll.refresh(state, RefreshConfig(max_swaps=16))
    slab = state.slabs[col.SHARED_ARENA]
    idx = np.asarray(slab.idx_map)
    assert sorted(idx.tolist()) == list(range(idx.shape[0]))
    s2r = np.asarray(slab.cache.slot_to_row)
    r2s = np.asarray(slab.cache.row_to_slot)
    for slot, row in enumerate(s2r):
        if row >= 0:
            assert r2s[row] == slot
    for row, slot in enumerate(r2s):
        if slot >= 0:
            assert s2r[slot] == row


def test_refresh_int8_host_store_is_codec_noise_bounded():
    """With an int8 host store a refresh's only numeric effect is the one
    quantize round trip of the swapped DIRTY rows; clean encoded rows move
    bit-stably (payload permutes encoded)."""
    tables = _tables()
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.1,
                                          host_precision="int8")
    state = _warm_state(coll, tables)
    probe = _fb(tables, 16, 999)
    # clean state (just flushed): refresh must be BIT-exact even for int8 —
    # flush wrote residents back, the extra writeback re-encodes the same
    # decoded values (stable projection), and the permute moves encoded rows.
    state = coll.flush(state)
    before = coll.dense_reference(state, probe)
    state2, rep = coll.refresh(state, RefreshConfig(max_swaps=32))
    assert rep.total_swaps > 0
    after = coll.dense_reference(coll.flush(state2), probe)
    for k in before:
        np.testing.assert_array_equal(np.asarray(before[k]), np.asarray(after[k]))


def test_refresh_noop_when_ranking_already_right():
    """A slab whose decayed ranking agrees with the static one emits no
    swaps — refresh converges instead of churning."""
    tables = [col.TableConfig("t", vocab=64, dim=4, ids_per_step=8, cache_ratio=0.25)]
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.25)
    state = coll.init(jax.random.PRNGKey(0))  # identity idx_map
    prep = jax.jit(lambda s, f: coll.prepare(s, f))
    for _ in range(6):  # traffic on the already-hot head ranks
        ids = jnp.asarray([0, 1, 2, 3, -1, -1, 0, 1], jnp.int32)
        state, _ = prep(state, col.FeatureBatch(ids={"t": ids}))
    state2, rep = coll.refresh(state)
    assert rep.total_swaps == 0
    # unchanged state (no-swap pass returns the slab as-is)
    np.testing.assert_array_equal(
        np.asarray(state.slabs[col.SHARED_ARENA].idx_map),
        np.asarray(state2.slabs[col.SHARED_ARENA].idx_map),
    )


# --------------------------------------------------------------------------
# sharded refresh
# --------------------------------------------------------------------------


def test_one_shard_refresh_bit_identical_to_unsharded():
    tables = _tables()
    un = col.EmbeddingCollection.create(tables, cache_ratio=0.1)
    sh = ShardedEmbeddingCollection.create(tables, num_shards=1, cache_ratio=0.1)
    st_un = _warm_state(un, tables)
    st_sh = _warm_state(sh, tables)
    st_un, rep_un = un.refresh(st_un, RefreshConfig(max_swaps=32))
    st_sh, rep_sh = sh.refresh(st_sh, RefreshConfig(max_swaps=32))
    assert rep_un.swaps == rep_sh.swaps
    for sname in un.cached_slabs:
        a, b = st_un.slabs[sname], st_sh.slabs[sname]
        np.testing.assert_array_equal(np.asarray(a.idx_map), np.asarray(b.idx_map))
        np.testing.assert_array_equal(
            np.asarray(a.full["weight"]),
            np.asarray(flat_store(b.full)["weight"]),
        )
        np.testing.assert_array_equal(
            np.asarray(a.cache.row_to_slot), np.asarray(b.cache.row_to_slot[0])
        )
        np.testing.assert_array_equal(
            np.asarray(a.cache.slot_to_row), np.asarray(b.cache.slot_to_row[0])
        )
        np.testing.assert_array_equal(
            np.asarray(a.cache.cached_rows["weight"]),
            np.asarray(b.cache.cached_rows["weight"][0]),
        )


@pytest.mark.parametrize("num_shards", [3, 4])
def test_sharded_refresh_is_pure_reindexing(num_shards):
    tables = _tables()
    coll = ShardedEmbeddingCollection.create(tables, num_shards=num_shards,
                                             cache_ratio=0.1)
    state = _warm_state(coll, tables)
    probe = _fb(tables, 16, 999)
    before = coll.dense_reference(coll.flush(state), probe)
    state2, rep = coll.refresh(state, RefreshConfig(max_swaps=32))
    after = coll.dense_reference(coll.flush(state2), probe)
    for k in before:
        np.testing.assert_array_equal(np.asarray(before[k]), np.asarray(after[k]))
    # lookups after refresh still match the dense reference (end-to-end)
    step = jax.jit(lambda s, f: coll.lookup(s, f))
    state2, _, rows = step(state2, probe)
    ref = coll.dense_reference(coll.flush(state2), probe)
    for k in rows:
        np.testing.assert_array_equal(np.asarray(rows[k]), np.asarray(ref[k]))


def test_sharded_refresh_exchange_budget_meters_cross_shard_rows():
    tables = _tables()
    coll = ShardedEmbeddingCollection.create(tables, num_shards=4, cache_ratio=0.1)
    state = _warm_state(coll, tables)
    unb, rep_unb = coll.refresh(state, RefreshConfig(max_swaps=32))
    state2, rep = coll.refresh(state, RefreshConfig(max_swaps=32, exchange_budget=4))
    for sname in rep.cross_shard_rows:
        assert rep.cross_shard_rows[sname] <= 4
        # deferral only ever reduces the applied set
        assert rep.swaps[sname] <= rep_unb.swaps[sname]
        assert (
            rep.swaps[sname] + rep.deferred_swaps[sname] == rep_unb.swaps[sname]
        )
    # budget 0 = same-shard swaps only
    _, rep0 = coll.refresh(state, RefreshConfig(max_swaps=32, exchange_budget=0))
    assert all(v == 0 for v in rep0.cross_shard_rows.values())


# --------------------------------------------------------------------------
# drift recovery (the mechanism the engine exists for)
# --------------------------------------------------------------------------


def _drift_hit_rate(with_refresh: bool):
    """Warm on phase-A stats, stream phase-B (shifted hot set), return the
    mean windowed hit rate over the final steps."""
    from repro.data import synth

    vocab, batch, steps = 2000, 128, 40
    tables = [col.TableConfig("t", vocab=vocab, dim=4, ids_per_step=batch,
                              cache_ratio=0.1, freq_half_life=10)]
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.1)
    phase_a = [synth._zipf_ids(np.random.default_rng(1000 + s), vocab, batch, 1.2)
               for s in range(20)]
    counts = np.zeros((vocab,), np.int64)
    for ids in phase_a:
        np.add.at(counts, ids, 1)
    state = coll.init(jax.random.PRNGKey(0), counts={"t": counts})
    prep = jax.jit(lambda s, f: coll.prepare(s, f))
    (sname,) = coll.cached_slabs
    rates, ph, pm = [], 0, 0
    for s in range(steps):
        ids = (synth._zipf_ids(np.random.default_rng(2000 + s), vocab, batch, 1.2)
               + 1000) % vocab  # hot set moved to a disjoint range
        state, _ = prep(state, col.FeatureBatch(ids={"t": jnp.asarray(ids.astype(np.int32))}))
        c = state.slabs[sname].cache
        h, m = int(jax.device_get(c.hits)), int(jax.device_get(c.misses))
        rates.append((h - ph) / max(h - ph + m - pm, 1))
        ph, pm = h, m
        if with_refresh and (s + 1) % 5 == 0:
            state, _ = coll.refresh(state, RefreshConfig(max_swaps=256))
    return float(np.mean(rates[-10:]))


def test_refresh_recovers_hit_rate_after_hot_set_shift():
    no = _drift_hit_rate(with_refresh=False)
    yes = _drift_hit_rate(with_refresh=True)
    assert yes > no + 0.05, (no, yes)


# --------------------------------------------------------------------------
# trainer / serve wiring
# --------------------------------------------------------------------------


def _dlrm_setup():
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig

    cfg = DLRMConfig(vocab_sizes=(4096, 256, 64), embed_dim=8, batch_size=16,
                     cache_ratio=0.25, lr=0.1, bottom_mlp=(16, 8), top_mlp=(16,))
    spec = synth.ZipfSparseSpec(vocab_sizes=cfg.vocab_sizes, n_dense=13)

    def make_batch(step):
        return {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, 16, 0, step).items()}

    return cfg, make_batch


def test_refresh_interval_fp32_losses_bit_identical_to_no_refresh():
    """Refresh is pure reindexing, so the SERIAL fp32 loss trajectory with
    refresh enabled is bit-identical to the run without it — which also
    proves refresh_interval=None is bit-identical to pre-refresh main."""
    from repro.models.dlrm import DLRM
    from repro.train.trainer import Trainer, TrainerConfig

    cfg, make_batch = _dlrm_setup()

    def losses(refresh_interval):
        model = DLRM(cfg)
        tr = Trainer(
            TrainerConfig(max_steps=8, refresh_interval=refresh_interval),
            init_fn=lambda: model.init(jax.random.PRNGKey(0)),
            step_fn=jax.jit(model.train_step),
            make_batch=make_batch, flush_fn=model.flush,
            refresh_fn=model.refresh,
        )
        tr.run()
        return [h["loss"] for h in tr.history], tr.history

    base, _ = losses(None)
    refreshed, hist = losses(3)
    assert base == refreshed
    # the refresh hook really ran (in-state counters surfaced via metrics)
    assert any(h.get("refresh_swaps", 0) > 0 for h in hist)


@pytest.mark.parametrize("depth", [1, 3])
def test_pipelined_trainer_with_refresh_matches_serial_losses(depth):
    """Group-boundary refreshes keep merged plans valid: the pipelined run
    with refresh stays loss-bit-identical to the serial no-refresh oracle."""
    from repro.models.dlrm import DLRM
    from repro.train.trainer import PipelinedTrainer, Trainer, TrainerConfig

    cfg, make_batch = _dlrm_setup()
    model = DLRM(cfg)
    serial = Trainer(TrainerConfig(max_steps=7),
                     init_fn=lambda: model.init(jax.random.PRNGKey(0)),
                     step_fn=jax.jit(model.train_step),
                     make_batch=make_batch, flush_fn=model.flush)
    serial.run()

    model2 = DLRM(cfg)
    piped = PipelinedTrainer(
        TrainerConfig(max_steps=7, pipeline_depth=depth, refresh_interval=2),
        init_fn=lambda: model2.init(jax.random.PRNGKey(0)),
        plan_fn=jax.jit(model2.plan_step),
        compute_fn=jax.jit(model2.compute_step),
        apply_fn=jax.jit(model2.apply_step),
        make_batch=make_batch, flush_fn=model2.flush,
        refresh_fn=model2.refresh)
    piped.run()
    assert [h["loss"] for h in serial.history] == [h["loss"] for h in piped.history]
    assert [h["step"] for h in serial.history] == [h["step"] for h in piped.history]
    assert any(h.get("refresh_swaps", 0) > 0 for h in piped.history)


def test_serve_engine_refresh_hook_scores_unchanged():
    from repro.models.dlrm import DLRM
    from repro.serve.engine import ServeEngine

    cfg, make_batch = _dlrm_setup()

    def build(refresh_every):
        model = DLRM(cfg)
        state = model.init(jax.random.PRNGKey(0))
        return model, ServeEngine(
            model.serve_step, state, batch_size=16,
            pad_example={"dense": np.zeros((13,), np.float32),
                         "sparse": np.zeros((3,), np.int32),
                         "label": np.zeros((), np.float32)},
            state_stats_fn=lambda s: model.collection.metrics(s["emb"], writeback=False),
            refresh_fn=(lambda s: model.refresh(s, writeback=False))
            if refresh_every else None,
            refresh_every=refresh_every,
        )

    _, plain = build(None)
    _, refreshing = build(2)
    for s in range(6):
        batch = {k: np.asarray(v) for k, v in make_batch(s).items()}
        a = plain.score(batch)
        b = refreshing.score(batch)
        np.testing.assert_array_equal(a, b)  # pure reindexing, serve-side
    summ = refreshing.summary()
    assert summ["refresh_swaps"] > 0
    assert summ["cache_hits"] >= 0 and summ["cache_misses"] >= 0


# --------------------------------------------------------------------------
# satellites: stream counts + exact wrap-free counters
# --------------------------------------------------------------------------


def test_collect_counts_stream_matches_materialized_counts():
    from repro.data.pipeline import Prefetcher

    tables = _tables()
    coll = col.EmbeddingCollection.create(tables, cache_ratio=0.2)
    fbs = [_fb(tables, 16, 300 + i) for i in range(5)]

    # oracle: materialized per-table counts
    want = {t.name: np.zeros((t.vocab,), np.int64) for t in tables}
    for fb in fbs:
        for f, ids in fb.ids.items():
            a = np.asarray(ids).reshape(-1).astype(np.int64)
            np.add.at(want[coll.feature_to_table[f]], a[a >= 0], 1)

    # plain iterator of FeatureBatches
    got = coll.collect_counts_stream(iter(fbs))
    for k in want:
        np.testing.assert_array_equal(got[k], want[k])

    # Prefetcher of (step, batch) pairs ending via the StopIteration contract
    def make(step):
        if step >= len(fbs):
            raise StopIteration
        return fbs[step]

    pf = Prefetcher(make, depth=2)
    try:
        got2 = coll.collect_counts_stream(pf)
    finally:
        pf.close()
    for k in want:
        np.testing.assert_array_equal(got2[k], want[k])

    # max_batches bounds an infinite stream
    def infinite(step):
        return fbs[step % len(fbs)]

    pf2 = Prefetcher(infinite, depth=2)
    try:
        got3 = coll.collect_counts_stream(pf2, max_batches=5)
    finally:
        pf2.close()
    for k in want:
        np.testing.assert_array_equal(got3[k], want[k])


def test_exact_counter_totals_survive_int32_wrap():
    """The satellite bugfix: cumulative int32 hit counters wrap past 2^31;
    the host-side accumulator recovers exact Python-int totals."""
    ec = col.ExactCounterTotals()
    step = 1 << 28  # 268M events per observation
    seen = 0
    cur = np.int32(0)
    for _ in range(20):  # crosses the int32 wrap twice
        with np.errstate(over="ignore"):
            cur = np.int32(cur + np.int32(step))
        seen += step
        got = ec.update({"slab": cur})
    assert got == seen  # 5.3B events, far past int32
    assert int(cur) != seen  # the raw counter really did wrap
    # idempotent re-observation
    assert ec.update({"slab": cur}) == seen


def test_trainer_records_exact_hit_totals():
    from repro.models.dlrm import DLRM
    from repro.train.trainer import Trainer, TrainerConfig

    cfg, make_batch = _dlrm_setup()
    model = DLRM(cfg)
    tr = Trainer(TrainerConfig(max_steps=4),
                 init_fn=lambda: model.init(jax.random.PRNGKey(0)),
                 step_fn=jax.jit(model.train_step),
                 make_batch=make_batch, flush_fn=model.flush)
    tr.run()
    h = tr.history[-1]
    assert isinstance(h["cache_hits"], int) and isinstance(h["cache_misses"], int)
    assert 0.0 <= h["hit_rate_exact"] <= 1.0
    # cumulative: totals only grow along the run
    assert h["cache_hits"] >= tr.history[0]["cache_hits"]
