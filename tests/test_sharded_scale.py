"""Scaling fronts of the sharded collection (hot-row replication, dedup'd /
compressed exchange, traffic-aware re-balancing).

Exactness bar (ISSUE PR7): replication off + 1 shard stays bit-identical to
the unsharded collection; fp32 sharded training stays bit-identical to
single-device WITH replication on; the encoded exchange agrees to codec
noise; re-homing is pure data movement (lookups bitwise unchanged).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collection as col
from repro.core import refresh as refresh_lib
from repro.core.sharded import ShardedEmbeddingCollection, flat_store


def small_tables(dim=8, ids=16):
    return [
        col.TableConfig("big", vocab=512, dim=dim, ids_per_step=ids, cache_ratio=0.2),
        col.TableConfig("small", vocab=96, dim=dim, ids_per_step=ids, cache_ratio=0.3),
    ]


def rand_fb(tables, n, seed):
    rng = np.random.default_rng(seed)
    return col.FeatureBatch(ids={
        t.name: jnp.asarray(rng.integers(-1, t.vocab, n).astype(np.int32))
        for t in tables
    })


# --------------------------------------------------------------------------
# placement: replicate_top_k
# --------------------------------------------------------------------------


def test_assign_devices_replicate_top_k_homes():
    counts = 1e6 / (np.arange(1000, dtype=np.float64) + 1) ** 0.8
    a = col.PlacementPlanner.assign_devices(1000, 4, counts, replicate_top_k=32)
    assert a.replicate_top_k == 32
    # every rank (replicated ones included) still has exactly one home
    assert a.shard_rows.sum() == 1000
    for s in range(4):
        got = np.sort(a.local[a.owner == s])
        np.testing.assert_array_equal(got, np.arange(a.shard_rows[s]))
    # replicated ranks carry zero routed load: the metered mass is exactly
    # the non-head mass, and balancing it stays tight
    np.testing.assert_allclose(a.shard_load.sum(), counts[32:].sum())
    assert a.imbalance() < 1.05
    # K = 0 reduces to the historical assignment bit-for-bit
    b0 = col.PlacementPlanner.assign_devices(1000, 4, counts)
    b1 = col.PlacementPlanner.assign_devices(1000, 4, counts, replicate_top_k=0)
    np.testing.assert_array_equal(b0.owner, b1.owner)
    np.testing.assert_array_equal(b0.local, b1.local)


def test_assign_devices_replicate_without_counts_round_robin():
    a = col.PlacementPlanner.assign_devices(10, 3, None, replicate_top_k=4)
    # routed ranks 4..9 first, then the head 0..3 at the coldest positions
    seq = np.concatenate([np.arange(4, 10), np.arange(4)])
    np.testing.assert_array_equal(a.owner[seq], np.arange(10) % 3)
    np.testing.assert_array_equal(a.local[seq], np.arange(10) // 3)


# --------------------------------------------------------------------------
# hot-row replication: exactness + the lanes it removes from the exchange
# --------------------------------------------------------------------------


@pytest.mark.parametrize("num_shards", [1, 3])
def test_replicated_lookup_matches_dense_reference_bitwise(num_shards):
    tables = small_tables()
    coll = ShardedEmbeddingCollection.create(
        tables, num_shards=num_shards, cache_ratio=0.2, replicate_top_k=16
    )
    rng = np.random.default_rng(1)
    counts = {t.name: rng.integers(0, 50, t.vocab) for t in tables}
    state = coll.init(jax.random.PRNGKey(0), counts=counts)
    step = jax.jit(lambda s, fb: coll.lookup(s, fb))
    for i in range(10):
        fb = rand_fb(tables, 16, seed=100 + i)
        state, addr, rows = step(state, fb)
        ref = coll.dense_reference(coll.flush(state), fb)
        for f in fb.features:
            np.testing.assert_array_equal(np.asarray(rows[f]), np.asarray(ref[f]))


def test_replicated_dlrm_loss_bit_identical_fp32():
    """The tentpole exactness property: replication ON, fp32 — the sharded
    loss trajectory equals single-device bit for bit (arena lanes read the
    same values the cache would have served; the combined replicated-slice
    gradient equals the unsharded row gradient)."""
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig

    base = dict(vocab_sizes=(2048, 256, 64), embed_dim=8, batch_size=16,
                cache_ratio=0.15, lr=0.2, bottom_mlp=(16, 8), top_mlp=(16,))
    spec = synth.ZipfSparseSpec(vocab_sizes=base["vocab_sizes"], n_dense=13)

    def make(s):
        return {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, 16, 0, s).items()}

    def losses(shards, k):
        model = DLRM(DLRMConfig(**base, model_shards=shards, replicate_top_k=k))
        state = model.init(jax.random.PRNGKey(0))
        step = jax.jit(model.train_step)
        out = []
        for i in range(8):
            state, m = step(state, make(i))
            out.append(float(m["loss"]))
        return out

    ref = losses(0, 0)
    assert ref == losses(2, 8)
    assert ref == losses(4, 64)


def test_replicated_grads_match_unsharded_leaf_for_leaf():
    """apply_grads through the replicated arena lands the same fp32 values
    the unsharded table update would — checked row-for-row after flush."""
    tables = small_tables()
    rng = np.random.default_rng(5)
    counts = {t.name: rng.integers(0, 50, t.vocab) for t in tables}
    ref = col.EmbeddingCollection.create(tables, cache_ratio=0.2)
    sc = ShardedEmbeddingCollection.create(
        tables, num_shards=2, cache_ratio=0.2, replicate_top_k=12
    )

    def sgd_steps(coll, n=5):
        state = coll.init(jax.random.PRNGKey(0), counts=counts)
        for i in range(n):
            fb = rand_fb(tables, 16, seed=500 + i)
            state, addr = coll.prepare(state, fb)

            def loss_fn(w):
                rows = coll.gather(w, addr, fb)
                return sum(jnp.sum(r * r) for r in rows.values())

            grads = jax.grad(loss_fn)(coll.weights(state))
            state = coll.apply_grads(state, grads, 0.1)
        return coll.flush(state)

    st_ref, st_sh = sgd_steps(ref), sgd_steps(sc)
    for t in tables:
        ids = jnp.arange(t.vocab, dtype=jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(ref.full_lookup(st_ref, t.name, ids)),
            np.asarray(sc.full_lookup(st_sh, t.name, ids)),
        )


def test_fully_replicated_slab_routes_zero_lanes():
    tables = [col.TableConfig("t", vocab=128, dim=8, ids_per_step=8, cache_ratio=0.3)]
    sc = ShardedEmbeddingCollection.create(
        tables, num_shards=2, cache_ratio=0.3, replicate_top_k=128
    )
    state = sc.init(jax.random.PRNGKey(0))
    for i in range(4):
        fb = rand_fb(tables, 8, seed=i)
        state, addr, rows = sc.lookup(state, fb)
        refr = sc.dense_reference(sc.flush(state), fb)
        np.testing.assert_array_equal(np.asarray(rows["t"]), np.asarray(refr["t"]))
    m = sc.metrics(state)
    assert int(m["exchange_routed_lanes"][col.SHARED_ARENA]) == 0
    assert float(m["exchange_bytes"]) == 0.0


# --------------------------------------------------------------------------
# dedup'd exchange
# --------------------------------------------------------------------------


def test_dedup_routes_each_unique_id_once():
    tables = [col.TableConfig("t", vocab=128, dim=8, ids_per_step=8, cache_ratio=0.3)]
    sc = ShardedEmbeddingCollection.create(tables, num_shards=2, cache_ratio=0.3)
    state = sc.init(jax.random.PRNGKey(0))
    fb = col.FeatureBatch(ids={"t": jnp.asarray([3, 3, 3, 7, -1, 7, 9, 3], jnp.int32)})
    state, _, rows = sc.lookup(state, fb)
    state, _, _ = sc.lookup(state, fb)
    m = sc.metrics(state)
    # 3 unique valid ids per step, cumulative over 2 steps — NOT 6 raw lanes
    assert int(m["exchange_routed_lanes"][col.SHARED_ARENA]) == 2 * 3
    ref = sc.dense_reference(sc.flush(state), fb)
    np.testing.assert_array_equal(np.asarray(rows["t"]), np.asarray(ref["t"]))
    # duplicate lanes are literally the same gathered row
    r = np.asarray(rows["t"])
    np.testing.assert_array_equal(r[0], r[1])
    np.testing.assert_array_equal(r[0], r[7])


def test_dedup_across_features_of_a_shared_arena():
    tables = [
        col.TableConfig("a", vocab=64, dim=8, ids_per_step=4, cache_ratio=0.4),
        col.TableConfig("b", vocab=64, dim=8, ids_per_step=4, cache_ratio=0.4),
    ]
    sc = ShardedEmbeddingCollection.create(tables, num_shards=2, cache_ratio=0.4)
    state = sc.init(jax.random.PRNGKey(0))
    fb = col.FeatureBatch(ids={
        "a": jnp.asarray([1, 1, 2, 2], jnp.int32),
        "b": jnp.asarray([1, 2, 2, -1], jnp.int32),
    })
    state, _, rows = sc.lookup(state, fb)
    m = sc.metrics(state)
    # arena-rank dedup spans features: {a:1, a:2, b:1, b:2} -> 4 routed lanes
    assert int(m["exchange_routed_lanes"][col.SHARED_ARENA]) == 4
    ref = sc.dense_reference(sc.flush(state), fb)
    for f in ("a", "b"):
        np.testing.assert_array_equal(np.asarray(rows[f]), np.asarray(ref[f]))


def test_dedup_duplicate_heavy_training_stays_bit_identical():
    """Loss bit-identity under duplicate-heavy batches: the dedup'd routing
    must produce the same gathers AND the same per-row gradient sums."""
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig

    base = dict(vocab_sizes=(64, 16), embed_dim=8, batch_size=32,
                cache_ratio=0.5, lr=0.2, bottom_mlp=(16, 8), top_mlp=(16,))
    spec = synth.ZipfSparseSpec(vocab_sizes=base["vocab_sizes"], n_dense=13)

    def make(s):  # tiny vocabs -> most lanes are duplicates
        return {k: jnp.asarray(v) for k, v in synth.sparse_batch(spec, 32, 0, s).items()}

    def losses(shards):
        model = DLRM(DLRMConfig(**base, model_shards=shards))
        state = model.init(jax.random.PRNGKey(0))
        step = jax.jit(model.train_step)
        out = []
        for i in range(6):
            state, m = step(state, make(i))
            out.append(float(m["loss"]))
        return out

    assert losses(0) == losses(2)


# --------------------------------------------------------------------------
# compressed exchange (row-leg codec)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("codec,atol", [("fp16", 2e-3), ("int8", 5e-2)])
def test_encoded_exchange_gathers_allclose(codec, atol):
    tables = small_tables()
    sc = ShardedEmbeddingCollection.create(
        tables, num_shards=2, cache_ratio=0.2, exchange_codec=codec
    )
    state = sc.init(jax.random.PRNGKey(0))
    for i in range(6):
        fb = rand_fb(tables, 16, seed=700 + i)
        state, _, rows = sc.lookup(state, fb)
        ref = sc.dense_reference(sc.flush(state), fb)
        for f in fb.features:
            np.testing.assert_allclose(
                np.asarray(rows[f]), np.asarray(ref[f]), atol=atol
            )


def test_exchange_codec_fp32_stays_bit_exact():
    """exchange_codec='fp32' is the identity: normalized to the plain gather
    path, bit-identical lookups."""
    tables = small_tables()
    a = ShardedEmbeddingCollection.create(tables, num_shards=2, cache_ratio=0.2)
    b = ShardedEmbeddingCollection.create(
        tables, num_shards=2, cache_ratio=0.2, exchange_codec="fp32"
    )
    assert b.exchange_codec is None
    sa, sb = a.init(jax.random.PRNGKey(0)), b.init(jax.random.PRNGKey(0))
    for i in range(4):
        fb = rand_fb(tables, 16, seed=800 + i)
        sa, _, ra = a.lookup(sa, fb)
        sb, _, rb = b.lookup(sb, fb)
        for f in fb.features:
            np.testing.assert_array_equal(np.asarray(ra[f]), np.asarray(rb[f]))


@pytest.mark.parametrize("codec", ["fp16", "int8"])
def test_encoded_exchange_losses_allclose_to_unsharded(codec):
    from repro.data import synth
    from repro.models.dlrm import DLRM, DLRMConfig

    base = dict(vocab_sizes=(1024, 128), embed_dim=8, batch_size=16,
                cache_ratio=0.1, lr=0.2, bottom_mlp=(16, 8), top_mlp=(16,))
    spec = synth.ZipfSparseSpec(vocab_sizes=base["vocab_sizes"], n_dense=13)

    def losses(shards, **kw):
        model = DLRM(DLRMConfig(**base, model_shards=shards, **kw))
        state = model.init(jax.random.PRNGKey(0))
        step = jax.jit(model.train_step)
        out = []
        for i in range(8):
            batch = {k: jnp.asarray(v)
                     for k, v in synth.sparse_batch(spec, 16, 0, i).items()}
            state, m = step(state, batch)
            out.append(float(m["loss"]))
        return out

    np.testing.assert_allclose(losses(0), losses(2, exchange_codec=codec),
                               atol=5e-3)


def test_exchange_metrics_split_id_and_row_legs():
    tables = [col.TableConfig("t", vocab=128, dim=8, ids_per_step=8, cache_ratio=0.3)]
    sc = ShardedEmbeddingCollection.create(
        tables, num_shards=2, cache_ratio=0.3, exchange_codec="int8"
    )
    state = sc.init(jax.random.PRNGKey(0))
    fb = col.FeatureBatch(ids={"t": jnp.asarray([1, 2, 3, -1, -1, 5, 6, -1], jnp.int32)})
    state, _ = sc.prepare(state, fb)
    state, _ = sc.prepare(state, fb)
    m = sc.metrics(state)
    lanes = int(m["exchange_routed_lanes"][col.SHARED_ARENA])
    assert lanes == 2 * 5
    id_b = int(m["exchange_id_lane_bytes"][col.SHARED_ARENA])
    row_b = int(m["exchange_row_lane_bytes"][col.SHARED_ARENA])
    assert id_b == 4
    assert row_b < 8 * 4  # encoded row-leg beats the fp32 wire
    assert int(m["exchange_lane_bytes"][col.SHARED_ARENA]) == id_b + row_b
    assert float(m["exchange_bytes"]) == lanes * (id_b + row_b)
    assert float(m["exchange_id_bytes"]) == lanes * id_b
    assert float(m["exchange_row_bytes"]) == lanes * row_b
    hist = np.asarray(m["exchange_per_shard_lanes"])
    assert hist.shape == (2,) and hist.sum() == lanes


# --------------------------------------------------------------------------
# live imbalance metric + traffic-aware re-balance
# --------------------------------------------------------------------------


def _skew_collection():
    tables = [col.TableConfig("t", vocab=128, dim=8, ids_per_step=16, cache_ratio=0.25)]
    sc = ShardedEmbeddingCollection.create(tables, num_shards=2, cache_ratio=0.25)
    state = sc.init(jax.random.PRNGKey(0))  # counts=None -> rank == id
    # without counts the round-robin places even ranks on shard 0: feeding
    # only even ids drives ALL routed traffic through shard 0
    for i in range(8):
        ids = (np.arange(16) * 2 + 2 * i) % 128
        fb = col.FeatureBatch(ids={"t": jnp.asarray(ids.astype(np.int32))})
        state, _ = sc.prepare(state, fb)
    return sc, state


def test_shard_imbalance_metric_is_live():
    sc, state = _skew_collection()
    m = sc.metrics(state)
    # all decayed tracker mass sits on shard 0 -> live max/mean == S == 2
    assert float(m["shard_imbalance"]) > 1.8
    assert float(m["shard_imbalance_routed"]) > 1.8
    hist = np.asarray(m["exchange_per_shard_lanes"])
    assert hist[0] > 0 and hist[1] == 0


def test_refresh_rebalance_rehomes_hot_rows_and_stays_exact():
    sc, state = _skew_collection()
    probe = col.FeatureBatch(ids={"t": jnp.asarray(np.arange(128, dtype=np.int32))})
    before = sc.dense_reference(sc.flush(state), probe)
    owner0 = np.asarray(state.slabs[col.SHARED_ARENA].rank_owner).copy()
    imb0 = float(sc.metrics(state)["shard_imbalance"])

    cfg = refresh_lib.RefreshConfig(max_swaps=0, rebalance_threshold=1.2)
    state, report = sc.refresh(state, cfg)
    assert report.rebalance_imbalance[col.SHARED_ARENA] > 1.2
    assert report.rebalance_moves[col.SHARED_ARENA] > 0
    owner1 = np.asarray(state.slabs[col.SHARED_ARENA].rank_owner)
    assert (owner0 != owner1).any()

    # pure data movement: every id reads the exact same row after re-homing
    after = sc.dense_reference(sc.flush(state), probe)
    np.testing.assert_array_equal(np.asarray(before["t"]), np.asarray(after["t"]))
    # and the live imbalance the re-balance planned against actually fell
    assert float(sc.metrics(state)["shard_imbalance"]) < imb0
    # below threshold -> second pass is a no-op
    state2, report2 = sc.refresh(state, cfg)
    assert report2.rebalance_moves[col.SHARED_ARENA] == 0


def test_refresh_rebalance_respects_threshold():
    sc, state = _skew_collection()
    cfg = refresh_lib.RefreshConfig(max_swaps=0, rebalance_threshold=10.0)
    owner0 = np.asarray(state.slabs[col.SHARED_ARENA].rank_owner).copy()
    state, report = sc.refresh(state, cfg)
    assert report.rebalance_moves[col.SHARED_ARENA] == 0
    np.testing.assert_array_equal(
        owner0, np.asarray(state.slabs[col.SHARED_ARENA].rank_owner)
    )


# --------------------------------------------------------------------------
# migration: pre-replication checkpoints fail loudly
# --------------------------------------------------------------------------


def test_checkpoint_from_pre_replication_layout_fails_loudly(tmp_path):
    from repro.train import checkpoint as ckpt

    tables = small_tables()
    old = ShardedEmbeddingCollection.create(tables, num_shards=2, cache_ratio=0.2)
    state = old.init(jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 3, {"emb": old.flush(state)})

    new = ShardedEmbeddingCollection.create(
        tables, num_shards=2, cache_ratio=0.2, replicate_top_k=16
    )
    like = jax.eval_shape(lambda: {"emb": new.init(jax.random.PRNGKey(0), warm=False)})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), like)


def test_replicated_checkpoint_roundtrip_exact(tmp_path):
    from repro.train import checkpoint as ckpt

    tables = small_tables()
    sc = ShardedEmbeddingCollection.create(
        tables, num_shards=2, cache_ratio=0.2, replicate_top_k=16
    )
    state = sc.init(jax.random.PRNGKey(0))
    for i in range(3):
        state, _ = sc.prepare(state, rand_fb(tables, 16, seed=900 + i))
    state = sc.flush(state)
    ckpt.save(str(tmp_path), 5, {"emb": state})
    like = jax.eval_shape(lambda: {"emb": sc.init(jax.random.PRNGKey(0), warm=False)})
    restored, step = ckpt.restore(str(tmp_path), like)
    assert step == 5
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        {"emb": state}, restored,
    )


# --------------------------------------------------------------------------
# bounded per-shard plan width: max_routed_per_shard
# --------------------------------------------------------------------------


def test_bounded_plan_width_stays_bit_identical():
    """With an ample bound the compact [S, W] plan must reproduce the
    full-width path bit for bit: same addresses, same lookup rows, same
    telemetry, zero overflows."""
    tables = small_tables()
    mk = lambda w: ShardedEmbeddingCollection.create(
        tables, num_shards=3, cache_ratio=0.2, replicate_top_k=8,
        max_routed_per_shard=w,
    )
    rng = np.random.default_rng(5)
    counts = {t.name: rng.integers(0, 50, t.vocab) for t in tables}
    a, b = mk(0), mk(24)  # dedup width is 2*16=32 lanes; 24 < 32 compacts
    sa = a.init(jax.random.PRNGKey(0), counts=counts)
    sb = b.init(jax.random.PRNGKey(0), counts=counts)
    step_a = jax.jit(lambda s, fb: a.lookup(s, fb))
    step_b = jax.jit(lambda s, fb: b.lookup(s, fb))
    for i in range(8):
        fb = rand_fb(tables, 16, seed=700 + i)
        sa, addr_a, rows_a = step_a(sa, fb)
        sb, addr_b, rows_b = step_b(sb, fb)
        for f in fb.features:
            np.testing.assert_array_equal(np.asarray(addr_a[f]), np.asarray(addr_b[f]))
            np.testing.assert_array_equal(np.asarray(rows_a[f]), np.asarray(rows_b[f]))
    ma, mb = a.metrics(sa), b.metrics(sb)
    assert int(mb["uniq_overflows"]) == 0
    np.testing.assert_array_equal(
        np.asarray(ma["exchange_per_shard_lanes"]),
        np.asarray(mb["exchange_per_shard_lanes"]),
    )


def test_bounded_plan_width_overflow_is_loud():
    """A bound tighter than one shard's routed demand must surface through
    uniq_overflows (the trainer's exactness guard), never drop lanes
    silently."""
    tables = [col.TableConfig("t", vocab=128, dim=8, ids_per_step=16,
                              cache_ratio=0.5)]
    sc = ShardedEmbeddingCollection.create(
        tables, num_shards=2, cache_ratio=0.5, max_routed_per_shard=3
    )
    state = sc.init(jax.random.PRNGKey(0))  # counts=None -> rank == id
    # 8 distinct even ids: all route to shard 0 (round-robin homes), so a
    # width-3 image must overflow by 5 lanes
    fb = col.FeatureBatch(ids={"t": jnp.arange(0, 16, 2, dtype=jnp.int32)})
    state, _ = sc.prepare(state, fb)
    assert int(sc.metrics(state)["uniq_overflows"]) == 5
