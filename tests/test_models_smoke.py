"""Per-arch smoke tests (assignment requirement f): every assigned arch runs
a REDUCED same-family config for one train (+decode where applicable) step on
CPU, asserting output shapes + finiteness."""
import pytest

from repro.configs import REGISTRY


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_arch_smoke(arch):
    result = REGISTRY[arch].smoke()
    assert result["finite"], f"{arch} produced non-finite outputs: {result}"


def test_registry_covers_assignment():
    assigned = {
        "grok-1-314b", "olmoe-1b-7b", "gemma3-27b", "smollm-360m", "internlm2-20b",
        "gatedgcn", "din", "dien", "fm", "mind",
    }
    assert assigned <= set(REGISTRY)
    # the paper's own model is present too
    assert {"dlrm-criteo", "dlrm-avazu"} <= set(REGISTRY)


def test_cell_matrix_shape():
    """10 assigned archs x their own shape sets = 40 cells (incl. documented skips)."""
    n = 0
    for name in ("grok-1-314b", "olmoe-1b-7b", "gemma3-27b", "smollm-360m",
                 "internlm2-20b", "gatedgcn", "din", "dien", "fm", "mind"):
        n += len(REGISTRY[name].shapes)
    assert n == 40
